"""End-to-end GCN training on a synthetic Cora-like graph: a few hundred
steps with checkpointing, fault injection at step 120, and recovery —
demonstrating the full substrate on CPU.

    PYTHONPATH=src python examples/train_gcn.py
"""

import logging

from repro.launch.train import build_parser, run


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(name)s %(message)s")
    args = build_parser().parse_args([
        "--arch", "gcn-cora", "--steps", "300", "--lr", "5e-3",
        "--gnn-nodes", "512", "--gnn-edges", "2048",
        "--checkpoint-every", "50", "--fail-at", "120",
    ])
    history = run(args)
    first = next(h for h in history if "loss" in h)
    last = history[-1]
    print(f"\nGCN full-batch training: loss {first['loss']:.4f} -> "
          f"{last['loss']:.4f}, acc {last.get('acc', float('nan')):.3f} "
          f"({len(history)} recorded steps, 1 injected failure recovered)")
    assert last["loss"] < first["loss"]


if __name__ == "__main__":
    main()
