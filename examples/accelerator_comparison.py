"""Comparative accelerator study (the paper's Sec. IV narrative, end to end):
EnGN vs HyGCN across tile sizes, bandwidths, and reuse factors, plus the
TPU-pod reading of the same graph workloads.

    PYTHONPATH=src python examples/accelerator_comparison.py
"""

import numpy as np

from repro.core import (EnGNHardwareParams, EnGNModel, HyGCNHardwareParams,
                        HyGCNModel, paper_default_graph)
from repro.core.sweep import fig5_iterations_vs_bandwidth, fig7_systolic_reuse
from repro.core.tpu_model import ring_spmm_traffic, spmm_feature_allgather


def main() -> None:
    engn, hygcn = EnGNModel(), HyGCNModel()

    print("tile size sweep (defaults: N=30, T=5, B=1000, sigma=4, P=10K)")
    print(f"{'K':>7} {'EnGN off-chip':>14} {'HyGCN off-chip':>15} "
          f"{'EnGN on-array':>14} {'HyGCN on-array':>15}")
    for k in (256, 1024, 4096, 16384):
        g = paper_default_graph(float(k))
        eo = engn.evaluate(g)
        ho = hygcn.evaluate(g)
        print(f"{k:>7} {float(eo.offchip_bits()):>14.3e} "
              f"{float(ho.offchip_bits()):>15.3e} "
              f"{float(eo.onchip_bits()):>14.3e} "
              f"{float(ho.onchip_bits()):>15.3e}")
    print("-> (i) aggregation dominates; (ii) HyGCN's inter-phase buffer "
          "costs it off-chip traffic; both scale linearly in K.\n")

    print("bandwidth saturation (total iterations), K=1024:")
    for accel in ("engn", "hygcn"):
        res = fig5_iterations_vs_bandwidth(accel, K=np.array([1024.0]))
        iters = res.total_iterations[:, 0]
        B = res.axes["B"]
        knee = B[np.argmax(iters <= 1.05 * iters.min())]
        print(f"  {accel:6}: saturates at B ~ {knee:.0f} bits/iter "
              f"(floor {iters.min():.0f} iterations)")
    print()

    print("HyGCN systolic reuse (Fig. 7): loadweights bits at N=30:")
    res = fig7_systolic_reuse(gamma=np.array([0.0, 0.5, 0.9, 0.99]))
    lw = res.data_bits["loadweights"][:, 0]
    for gamma, bits in zip(res.axes["gamma"], lw):
        print(f"  Gamma={gamma:.2f}: {bits:>12.4g} bits")
    print()

    print("TPU-pod reading of the same question (our extension): moving")
    print("ogb_products features for one GCN layer on 256 chips —")
    ag = spmm_feature_allgather(2_449_408, 100, 256, dtype_bytes=4)
    ring = ring_spmm_traffic(2_449_408, 100, 256, dtype_bytes=4)
    print(f"  baseline all-gather : {ag.total('ici'):.4g} B/chip "
          f"(features materialized on every chip)")
    print(f"  RER ring (EnGN-style): {ring.total('ici'):.4g} B/chip, "
          f"same volume but shard-resident + hop-overlapped — the paper's")
    print("  'RER keeps the big movement on the fast fabric' lesson at pod scale.")


if __name__ == "__main__":
    main()
