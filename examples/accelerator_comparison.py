"""Comparative accelerator study (the paper's Sec. IV narrative, end to end):
every registered dataflow across tile sizes, bandwidths, and reuse factors;
the full-graph L-layer composition ("GCN-on-Cora, total movement"); and the
TPU-pod reading of the same graph workloads.

    PYTHONPATH=src python examples/accelerator_comparison.py
"""

import numpy as np

from repro.core import (FullGraphParams, MultiLayerModel, TiledGraphModel,
                        paper_default_graph, registry)
from repro.core.sweep import (fig5_iterations_vs_bandwidth, fig7_systolic_reuse,
                              sweep_accelerators)
from repro.core.tpu_model import ring_spmm_traffic, spmm_feature_allgather


def main() -> None:
    names = registry.names()

    print("tile size sweep (defaults: N=30, T=5, B=1000, sigma=4, P=10K)")
    print("one vectorized evaluation per accelerator, stacked:")
    K = np.array([256, 1024, 4096, 16384], dtype=np.float64)
    sw = sweep_accelerators(names, K=K)
    header = f"{'K':>7}" + "".join(f" {n + ' off':>15} {n + ' on':>13}" for n in names)
    print(header)
    for i, k in enumerate(K):
        cells = "".join(
            f" {sw.class_bits['offchip'][a, i]:>15.3e}"
            f" {sw.class_bits['onchip'][a, i]:>13.3e}"
            for a in range(len(names)))
        print(f"{int(k):>7}{cells}")
    print("-> (i) aggregation dominates; (ii) HyGCN's inter-phase buffer "
          "costs it off-chip traffic; (iii) spmm_tiled trades dense topology\n"
          "   blocks for zero inter-phase movement; all scale linearly in K.\n")

    print("bandwidth saturation (total iterations), K=1024 — any registered name:")
    for accel in names:
        res = fig5_iterations_vs_bandwidth(accel, K=np.array([1024.0]))
        iters = res.total_iterations[:, 0]
        B = res.axes["B"]
        knee = B[np.argmax(iters <= 1.05 * iters.min())]
        print(f"  {accel:10}: saturates at B ~ {knee:.0f} bits/iter "
              f"(floor {iters.min():.0f} iterations)")
    print()

    print("HyGCN systolic reuse (Fig. 7): loadweights bits at N=30:")
    res = fig7_systolic_reuse(gamma=np.array([0.0, 0.5, 0.9, 0.99]))
    lw = res.data_bits["loadweights"][:, 0]
    for gamma, bits in zip(res.axes["gamma"], lw):
        print(f"  Gamma={gamma:.2f}: {bits:>12.4g} bits")
    print()

    print("full-graph composition: 2-layer GCN on Cora (V=2708, E=10556,")
    print("widths 1433 -> 16 -> 7), tile capacity 1024, spill vs resident:")
    cora = FullGraphParams(V=2708, E=10556, N=1433, T=7)
    for accel in names:
        row = {}
        for residency in ("spill", "resident"):
            model = TiledGraphModel(
                MultiLayerModel(accel, [1433, 16, 7], residency=residency))
            out = model.evaluate(cora)
            row[residency] = out
        n_tiles = int(row["spill"].meta["n_tiles"])
        print(f"  {accel:10}: {n_tiles} tiles, "
              f"total {float(row['spill'].total_bits()):.4g} bits "
              f"(halo {float(row['spill']['haloreload'].data_bits):.3g}); "
              f"resident saves "
              f"{float(row['spill'].offchip_bits() - row['resident'].offchip_bits()):.3g} "
              "off-chip bits")
    print("-> the question the single-tile tables can't answer: end-to-end")
    print("   movement, including inter-layer spills and inter-tile halos.\n")

    print("TPU-pod reading of the same question (our extension): moving")
    print("ogb_products features for one GCN layer on 256 chips —")
    ag = spmm_feature_allgather(2_449_408, 100, 256, dtype_bytes=4)
    ring = ring_spmm_traffic(2_449_408, 100, 256, dtype_bytes=4)
    print(f"  baseline all-gather : {ag.total('ici'):.4g} B/chip "
          f"(features materialized on every chip)")
    print(f"  RER ring (EnGN-style): {ring.total('ici'):.4g} B/chip, "
          f"same volume but shard-resident + hop-overlapped — the paper's")
    print("  'RER keeps the big movement on the fast fabric' lesson at pod scale.")


if __name__ == "__main__":
    main()
