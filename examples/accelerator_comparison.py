"""Comparative accelerator study (the paper's Sec. IV narrative, end to end)
through the scenario front door (DESIGN.md §11): every evaluation below is
a declarative, JSON-serializable batch handed to the batch planner — one
broadcast closed-form call per dataflow, never a Python loop per point.

    PYTHONPATH=src python examples/accelerator_comparison.py
"""

import numpy as np

from repro.api import Scenario, evaluate_scenarios, template
from repro.core import registry
from repro.core.sweep import fig5_iterations_vs_bandwidth, sweep_accelerators
from repro.core.tpu_model import ring_spmm_traffic, spmm_feature_allgather


def main() -> None:
    names = registry.names()

    print("tile size sweep (defaults: N=30, T=5, B=1000, sigma=4, P=10K)")
    print("one scenario batch, one broadcast evaluation per accelerator:")
    K = np.array([256, 1024, 4096, 16384], dtype=np.float64)
    sw = sweep_accelerators(names, K=K)
    header = f"{'K':>7}" + "".join(f" {n + ' off':>15} {n + ' on':>13}" for n in names)
    print(header)
    for i, k in enumerate(K):
        cells = "".join(
            f" {sw.class_bits['offchip'][a, i]:>15.3e}"
            f" {sw.class_bits['onchip'][a, i]:>13.3e}"
            for a in range(len(names)))
        print(f"{int(k):>7}{cells}")
    print("-> (i) aggregation dominates; (ii) HyGCN's inter-phase buffer "
          "costs it off-chip traffic; (iii) spmm_tiled trades dense topology\n"
          "   blocks for zero inter-phase movement; all scale linearly in K.\n")

    print("bandwidth saturation (total iterations), K=1024 — any registered name:")
    for accel in names:
        res = fig5_iterations_vs_bandwidth(accel, K=np.array([1024.0]))
        iters = res.total_iterations[:, 0]
        B = res.axes["B"]
        knee = B[np.argmax(iters <= 1.05 * iters.min())]
        print(f"  {accel:10}: saturates at B ~ {knee:.0f} bits/iter "
              f"(floor {iters.min():.0f} iterations)")
    print()

    print("HyGCN systolic reuse (Fig. 7 as a scenario batch): loadweights, N=30:")
    gammas = [0.0, 0.5, 0.9, 0.99]
    batch = [Scenario.tile("hygcn", hardware={"gamma": g}, label=f"G={g}")
             for g in gammas]
    res = evaluate_scenarios(batch)
    for gamma, r in zip(gammas, res.results):
        print(f"  Gamma={gamma:.2f}: {r.breakdown['loadweights']:>12.4g} bits")
    print()

    print("full-graph composition: 2-layer GCN on Cora (V=2708, E=10556,")
    print("widths 1433 -> 16 -> 7), tile capacity 1024, spill vs resident:")
    by_policy = {}
    for residency in ("spill", "resident"):
        tb = template("cora_end_to_end", tile_vertices=np.array([1024.0]),
                      residency=residency)
        by_policy[residency] = {r.scenario.dataflow: r
                                for r in evaluate_scenarios(tb.scenarios).results}
    for accel in names:
        spill, resident = by_policy["spill"][accel], by_policy["resident"][accel]
        print(f"  {accel:10}: {int(spill.n_tiles)} tiles, "
              f"total {spill.total_bits:.4g} bits "
              f"(halo {spill.breakdown['haloreload']:.3g}); "
              f"resident saves "
              f"{spill.offchip_bits - resident.offchip_bits:.3g} "
              "off-chip bits")
    print("-> the question the single-tile tables can't answer: end-to-end")
    print("   movement, including inter-layer spills and inter-tile halos.\n")

    print("workload bridges (§5 tile language): one-line queries, e.g. gemma2")
    print("prefill-32k and dlrm serve-p99 across every registered dataflow:")
    from repro.configs import get_arch
    scenarios = (get_arch("gemma2-2b").to_scenarios(shapes=("prefill_32k",))
                 + get_arch("dlrm-mlperf").to_scenarios(shapes=("serve_p99",)))
    res = evaluate_scenarios(scenarios)
    for r in res.results:
        print(f"  {r.scenario.workload:24} {r.scenario.dataflow:12} "
              f"total {r.total_bits:.3e} bits "
              f"(off-chip {r.offchip_bits:.3e})")
    print(f"  [{len(scenarios)} scenarios in {res.n_evaluations} broadcast "
          "evaluations]\n")

    print("TPU-pod reading of the same question (our extension): moving")
    print("ogb_products features for one GCN layer on 256 chips —")
    ag = spmm_feature_allgather(2_449_408, 100, 256, dtype_bytes=4)
    ring = ring_spmm_traffic(2_449_408, 100, 256, dtype_bytes=4)
    print(f"  baseline all-gather : {ag.total('ici'):.4g} B/chip "
          f"(features materialized on every chip)")
    print(f"  RER ring (EnGN-style): {ring.total('ici'):.4g} B/chip, "
          f"same volume but shard-resident + hop-overlapped — the paper's")
    print("  'RER keeps the big movement on the fast fabric' lesson at pod scale.")


if __name__ == "__main__":
    main()
