"""End-to-end LM training driver on the smollm-135m architecture family
(reduced width for CPU; pass --full on a pod for the 135M config): a few
hundred steps with cosine schedule, clipping, checkpoints and deterministic
restart.

    PYTHONPATH=src python examples/train_smollm.py [--steps 200]
"""

import argparse
import logging

from repro.launch.train import build_parser, run


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(name)s %(message)s")
    outer = argparse.ArgumentParser()
    outer.add_argument("--steps", type=int, default=200)
    outer.add_argument("--compress-grads", action="store_true")
    o = outer.parse_args()
    argv = ["--arch", "smollm-135m", "--steps", str(o.steps),
            "--batch", "8", "--seq", "128", "--lr", "3e-3",
            "--checkpoint-every", "100"]
    if o.compress_grads:
        argv.append("--compress-grads")
    history = run(build_parser().parse_args(argv))
    first, last = history[0], history[-1]
    print(f"\nsmollm family LM: loss {first['loss']:.4f} -> {last['loss']:.4f} "
          f"over {len(history)} steps "
          f"({'int8 error-feedback grads' if o.compress_grads else 'f32 grads'})")
    assert last["loss"] < first["loss"]


if __name__ == "__main__":
    main()
