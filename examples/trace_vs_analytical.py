"""Trace-kind scenarios: exact edge-list schedules vs the uniform closed form.

Walkthrough of the DESIGN.md §12 trace backend:

1. a `{"kind": "trace"}` scenario evaluates a real power-law edge list
   (deterministic generator, referenced as pure data) with exact per-tile
   vertex/edge/halo counts;
2. the same query under the paper's uniform-tile approximation, for the
   side-by-side movement gap;
3. the perfectly uniform ring-of-tiles graph, where both backends agree
   bit for bit — the sanity anchor of the whole comparison.

Run: ``PYTHONPATH=src python examples/trace_vs_analytical.py``
"""

from repro.api import Scenario, evaluate_scenarios
from repro.core.trace import resolve_trace_dataset

PARAMS = {"n_nodes": 10000.0, "n_edges": 80000.0, "seed": 0.0, "alpha": 1.8}
CAP = 1024.0


def main() -> None:
    trace = resolve_trace_dataset("power_law", PARAMS)
    sched = trace.schedule(int(CAP))
    print(f"power-law graph: V={trace.n_nodes} E={trace.n_edges} "
          f"-> {sched.n_tiles} tiles of K={sched.K}")
    print(f"  exact unique-remote-source halo: {sched.halo_total}")
    print(f"  paper's E*(1-1/n_tiles) estimate: "
          f"{sched.uniform_halo_estimate():.0f} "
          f"({sched.uniform_halo_estimate() / sched.halo_total:.1f}x over)")
    print(f"  per-tile edge imbalance (max/mean): "
          f"{sched.stats()['edge_imbalance']:.2f}")
    print(f"  degree-aware cache hit fraction (L=K/10): "
          f"{sched.cache_hit_fraction().mean():.3f}")

    pairs = []
    for df in ("engn", "hygcn", "awb_gcn"):
        pairs.append(Scenario.trace(df, dataset="power_law", params=PARAMS,
                                    N=30.0, T=5.0, tile_vertices=CAP,
                                    label=f"{df}/trace"))
        pairs.append(Scenario.full_graph(df, V=PARAMS["n_nodes"],
                                         E=PARAMS["n_edges"], N=30.0, T=5.0,
                                         tile_vertices=CAP,
                                         label=f"{df}/uniform"))
    res = evaluate_scenarios(pairs)
    print("\ntotal movement, exact trace vs uniform closed form:")
    for i in range(0, len(pairs), 2):
        tr, un = res.results[i], res.results[i + 1]
        df = tr.scenario.dataflow
        print(f"  {df:10} trace {tr.total_bits:.4g} bits | uniform "
              f"{un.total_bits:.4g} bits | uniform/trace "
              f"{un.total_bits / tr.total_bits:.3f}")

    # The anchor: on the uniform ring both backends are bit-identical.
    ring = {"n_nodes": 1024.0, "n_tiles": 4.0}
    t = evaluate_scenarios([Scenario.trace(
        "engn", dataset="ring_of_tiles", params=ring, N=30.0, T=5.0,
        tile_vertices=256.0)]).results[0]
    u = evaluate_scenarios([Scenario.full_graph(
        "engn", V=1024.0, E=4096.0, N=30.0, T=5.0,
        tile_vertices=256.0)]).results[0]
    assert t.total_bits == u.total_bits, (t.total_bits, u.total_bits)
    print(f"\nring-of-tiles anchor: trace == uniform == {t.total_bits:.6g} "
          "bits (bit-identical)")


if __name__ == "__main__":
    main()
