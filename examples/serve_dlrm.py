"""DLRM serving example: batched CTR scoring plus retrieval ranking against
100k candidates (batched dot, not a loop), on the smoke config.

    PYTHONPATH=src python examples/serve_dlrm.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import synthetic
from repro.models import dlrm as dlrm_lib


def main() -> None:
    arch = get_arch("dlrm-mlperf")
    cfg = arch.make_smoke_config()
    params = dlrm_lib.init_params(cfg, jax.random.key(0))
    serve = jax.jit(lambda p, b: dlrm_lib.forward(cfg, p, b))

    B = 512
    lat = []
    for step in range(12):
        raw = synthetic.criteo_batch(0, step, batch=B, n_dense=cfg.n_dense,
                                     vocab_sizes=cfg.vocab_sizes,
                                     multi_hot=cfg.multi_hot)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        t0 = time.perf_counter()
        scores = jax.nn.sigmoid(serve(params, batch))
        scores.block_until_ready()
        lat.append(time.perf_counter() - t0)
    lat_ms = sorted(x * 1e3 for x in lat[2:])  # drop warmup
    print(f"online scoring: batch={B}, p50={lat_ms[len(lat_ms)//2]:.2f} ms, "
          f"p99={lat_ms[-1]:.2f} ms, mean CTR={float(scores.mean()):.4f}")

    # retrieval: one query against 100k candidates
    rng = np.random.default_rng(0)
    cands = jnp.asarray(rng.standard_normal((100_000, cfg.embed_dim)), jnp.float32)
    query = {"dense": batch["dense"][:1]}
    scores = dlrm_lib.score_candidates(cfg, params, query, cands)
    top_v, top_i = jax.lax.top_k(scores, 10)
    print("retrieval top-10 candidate ids:", np.asarray(top_i).tolist())
    print("retrieval top-10 scores:", np.round(np.asarray(top_v), 3).tolist())


if __name__ == "__main__":
    main()
