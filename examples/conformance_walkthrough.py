"""Measured vs modeled: close the validation loop the paper left open.

The paper (Sec. III) could not validate its data-movement models — the
accelerators' simulators are closed-source.  Our TPU adaptation can: the
XLA-compiled Pallas programs are open ground truth.  This walkthrough pins
the ``spmm_tiled`` (fused) and ``spmm_unfused`` (HyGCN inter-phase
analogue) dataflows to byte measurements of their compiled kernels at a
few operating points, then shows the fusion claim as a *measured* delta:
the inter-phase buffer the fused kernel eliminates.

    PYTHONPATH=src python examples/conformance_walkthrough.py
"""

from repro.core import registry
from repro.core.conformance import (OperatingPoint, conformance_records,
                                    interphase_delta_records,
                                    summarize_records, verify_numerics)


def main() -> None:
    points = [
        OperatingPoint(256, 16, 8, 128, 128),
        OperatingPoint(512, 32, 8, 128, 256),
        OperatingPoint(256, 16, 8, 256, 256),   # single-block schedule
    ]

    print("dataflows with a runnable kernel analogue:",
          ", ".join(registry.runnable_names()), "\n")

    records = []
    for name in registry.runnable_names():
        spec = registry.get(name)
        analogue = spec.runnable_analogue()
        print(f"== {name}: analytical closed forms vs compiled "
              f"{analogue.__class__.__name__} ==")
        first_point_recs = []
        for pt in points:
            recs = conformance_records(spec, pt, analogue=analogue)
            records.extend(recs)
            if pt == points[0]:
                first_point_recs = recs
            worst = max((abs(r.ratio - 1.0) for r in recs
                         if not r.one_sided), default=0.0)
            print(f"  K={pt.K:4d} N={pt.N:3d} Bn={pt.Bn:3d} Bk={pt.Bk:3d}: "
                  f"{len(recs)} records, max |ratio-1| = {worst:.2e}")
        # the first point in detail: per-movement attribution
        for r in first_point_recs:
            if r.source == "block_schedule":
                print(f"    {r.movement:16} analytical={r.analytical_bytes:10.0f}B"
                      f" measured={r.measured_bytes:10.0f}B ratio={r.ratio:.4f}")
        print()

    print("== the fusion claim, measured (DESIGN.md §3/§10) ==")
    print("fused-minus-unfused HBM bytes vs the paper's eliminated")
    print("K*N*sigma write + P_s*N*sigma read inter-phase terms (P_s = K):")
    for pt in points:
        for r in interphase_delta_records(pt):
            records.append(r)
            print(f"  K={pt.K:4d} N={pt.N:3d} [{r.source:14}] "
                  f"eliminated={r.analytical_bytes:8.0f}B "
                  f"measured delta={r.measured_bytes:8.0f}B ratio={r.ratio:.4f}")

    print("\nexecuting both kernels once against the jnp oracle "
          "(interpret mode):")
    err = verify_numerics(points[0])
    print(f"  max relative error = {err:.3e}")

    summary = summarize_records(records)
    status = "ALL WITHIN DECLARED TOLERANCE" if summary["all_ok"] else "FAILURES"
    print(f"\n{summary['n_ok']}/{summary['n_records']} records ok -> {status}")


if __name__ == "__main__":
    main()
