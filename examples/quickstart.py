"""Quickstart: the scenario front door, then the paper's analytical models
at the published defaults with Table-III/IV-style breakdowns and one mini
sweep.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.api import Scenario, evaluate_scenarios
from repro.core import (EnGNHardwareParams, EnGNModel, HyGCNHardwareParams,
                        HyGCNModel, paper_default_graph, registry, tabulate)
from repro.core.sweep import fig3_engn_movement
from repro.core.tpu_model import (TPU_V5E, dp_gradient_sync, roofline,
                                  spmm_feature_allgather)


def main() -> None:
    g = paper_default_graph(1024.0)

    print("=" * 72)
    print("The front door (DESIGN.md §11): one declarative, serializable")
    print("scenario per evaluation — here every registered dataflow at the")
    print("paper's Sec. IV defaults, batched into one call per dataflow")
    print("=" * 72)
    batch = [Scenario.tile(name, label=name) for name in registry.names()]
    res = evaluate_scenarios(batch)
    print(f"{'dataflow':14}{'total bits':>14}{'iterations':>12}{'off-chip':>14}")
    for r in res.results:
        print(f"{r.scenario.dataflow:14}{r.total_bits:>14.4g}"
              f"{r.total_iterations:>12.0f}{r.offchip_bits:>14.4g}")
    print(f"(JSON round trip: Scenario.from_json(s.to_json()) == s; try\n"
          f" PYTHONPATH=src python -m repro.api --list)\n")

    print("=" * 72)
    print("EnGN per-tile data movement (Table III), K=1024, defaults")
    print("=" * 72)
    print(tabulate(EnGNModel().evaluate(g, EnGNHardwareParams())))

    print()
    print("=" * 72)
    print("HyGCN per-tile data movement (Table IV), K=1024, defaults")
    print("=" * 72)
    print(tabulate(HyGCNModel().evaluate(g, HyGCNHardwareParams())))

    print()
    print("Fig. 3 mini-sweep: EnGN total movement [bits] over (K, M):")
    res = fig3_engn_movement(K=np.array([256.0, 1024.0, 4096.0]),
                             M=np.array([8.0, 32.0, 128.0]))
    total = res.total_bits
    print("        M=8        M=32       M=128")
    for i, k in enumerate(res.axes["K"]):
        print(f"K={int(k):<5}" + "".join(f"{total[i, j]:>12.3e}" for j in range(3)))

    print()
    print("TPU adaptation: the same methodology as a pod roofline —")
    print("e.g. a 1D-SpMM feature all-gather for ogb_products on 256 chips:")
    comm = spmm_feature_allgather(2_449_408, 100, 256, dtype_bytes=4)
    rep = roofline(cell="demo::spmm", chips=256,
                   flops_per_chip=1.2e10, hbm_bytes_per_chip=1.2e10,
                   collective_bytes_per_chip=comm.total("ici"),
                   model_flops=256 * 1.2e10)
    print(f"  analytical all-gather bytes/chip: {comm.total('ici'):.3e}")
    print(f"  three-term roofline: compute {rep.compute_s:.2e}s, "
          f"memory {rep.memory_s:.2e}s, collective {rep.collective_s:.2e}s "
          f"-> dominant: {rep.dominant}")
    print(f"  DP grad sync for a 135M-param model over dp=16: "
          f"{dp_gradient_sync(135e6 * 4, 16).total('ici'):.3e} B/chip")


if __name__ == "__main__":
    main()
