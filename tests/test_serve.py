"""Battery for the §18 scenario-serving engine (ISSUE 10).

Five families of guarantees:

* **Bit-identity** — served results equal the serial
  ``evaluate_scenarios`` oracle exactly, across every scenario kind
  (tile / full / trace / hetero / minibatch / tune), whether requests
  arrive through the synchronous ``run_once`` path or the threaded
  dispatcher.
* **Coalescing** — N duplicate requests in one window cost ONE
  evaluation (asserted via the engine's evaluation counter and the
  ``meta["serve"]`` window record); distinct plan keys still cost one
  broadcast group each.
* **Robustness** — malformed submissions raise :class:`ServeError` in
  the caller's thread without touching the loop; an evaluation-time
  failure (unknown dataflow) fails only the offending request's future
  while window-mates still resolve; ``stop()`` drains the queue.
* **Concurrency safety** — hammer regressions for the process-wide
  trace LRU / stats counters and the per-trace schedule LRU (the PR-10
  locking satellites): exact work counts under concurrent load, no
  corruption, single-flight resolves.
* **Disk-cache races** — two writers racing one ``store_graph`` key are
  benign no-ops (including the TOCTOU window between the exists check
  and the rename), and ``cache_stats()`` is eviction-safe.
"""

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.api import (Scenario, ServeEngine, ServeError, evaluate_scenarios)
from repro.api.planner import coalesce_scenarios
from repro.core import schedule_cache
from repro.core.trace import (GraphTrace, register_trace_dataset,
                              reset_trace_stats, resolve_trace_dataset,
                              trace_cache_info)

TRACE_PARAMS = {"n_nodes": 1500.0, "n_edges": 6000.0, "seed": 3.0}
TYPED_PARAMS = {"n_nodes": 1200.0, "n_edges": 5000.0, "seed": 2.0}


@pytest.fixture(autouse=True)
def _no_disk_cache(monkeypatch):
    """Unit tests never touch the user's on-disk cache by default."""
    monkeypatch.setenv("REPRO_TRACE_CACHE", "0")
    yield


def _pool():
    return [
        Scenario.tile("engn", K=1024.0, label="tile-a"),
        Scenario.tile("hygcn", K=512.0, label="tile-b"),
        Scenario.full_graph("engn", V=2708.0, E=10556.0, N=1433.0, T=7.0,
                            widths=(1433.0, 16.0, 7.0), tile_vertices=512.0,
                            label="full-a"),
        Scenario.trace("engn", dataset="power_law", params=TRACE_PARAMS,
                       N=32.0, T=8.0, tile_vertices=256.0, label="trace-a"),
        Scenario.trace("engn", dataset="power_law", params=TRACE_PARAMS,
                       N=32.0, T=8.0, tile_vertices=512.0, label="trace-b"),
        Scenario.hetero("engn", dataset="typed_power_law", n_relations=3,
                        params=TYPED_PARAMS, N=[30.0, 20.0, 10.0], T=5.0,
                        tile_vertices=256.0, label="hetero-a"),
        Scenario.minibatch("hygcn", dataset="power_law", params=TRACE_PARAMS,
                           batch_nodes=32, fanout=(4, 4), n_batches=3,
                           N=32.0, T=8.0, label="minibatch-a"),
        Scenario.trace("engn", dataset="power_law", params=TRACE_PARAMS,
                       N=16.0, T=4.0, tile_vertices=256.0,
                       optimize={"objective": "movement",
                                 "space": {"tile_vertices": [128.0, 256.0]}},
                       label="tune-a"),
    ]


def _records(results):
    return [(r.total_bits, r.total_iterations, r.offchip_bits,
             r.cache_bits, r.onchip_bits, dict(r.breakdown),
             dict(r.iteration_breakdown), r.n_tiles) for r in results]


# ---------------------------------------------------------------------------
# coalesce_scenarios
# ---------------------------------------------------------------------------
def test_coalesce_scenarios_dedup_and_backmap():
    pool = _pool()
    flat = [pool[0], pool[1], pool[0], pool[3], pool[1], pool[0]]
    distinct, backmap = coalesce_scenarios(flat)
    assert [s.label for s in distinct] == ["tile-a", "tile-b", "trace-a"]
    assert backmap == (0, 1, 0, 2, 1, 0)
    # the scatter identity every consumer relies on
    assert [distinct[j] for j in backmap] == flat


def test_coalesce_scenarios_distinguishes_equal_plan_keys():
    a = Scenario.tile("engn", K=1024.0)
    b = Scenario.tile("engn", K=2048.0)  # same plan key, different leaf
    assert a.plan_key() == b.plan_key()
    distinct, backmap = coalesce_scenarios([a, b, a])
    assert len(distinct) == 2 and backmap == (0, 1, 0)


def test_coalesce_scenarios_rejects_non_scenarios():
    with pytest.raises(TypeError):
        coalesce_scenarios([Scenario.tile("engn"), {"dataflow": "engn"}])


# ---------------------------------------------------------------------------
# Bit-identity: served == serial, every scenario kind.
# ---------------------------------------------------------------------------
def test_run_once_bit_identical_across_kinds():
    pool = _pool()
    requests = [[pool[0], pool[3]], [pool[5]], [pool[6], pool[7]],
                [pool[2]], [pool[4], pool[0]], [pool[1]]]
    serial = [evaluate_scenarios(req).results for req in requests]
    eng = ServeEngine()
    futures = [eng.submit_future(req) for req in requests]
    assert eng.run_once() == len(requests)
    for fut, oracle in zip(futures, serial):
        sr = fut.result(timeout=0)
        assert _records(sr.results) == _records(oracle)
        for r in sr.results:
            assert "serve" in r.meta


def test_threaded_submit_bit_identical():
    pool = _pool()
    requests = [[pool[i % len(pool)]] for i in range(24)]
    serial = [evaluate_scenarios(req).results for req in requests]
    with ServeEngine(window_s=0.005) as eng:
        with ThreadPoolExecutor(max_workers=8) as ex:
            handles = list(ex.map(lambda r: eng.submit_future(r), requests))
        outs = [h.result(timeout=30) for h in handles]
    for sr, oracle in zip(outs, serial):
        assert _records(sr.results) == _records(oracle)


def test_serial_result_meta_keeps_trace_provenance():
    """Scatter merges serve meta in; it must not drop planner meta."""
    s = _pool()[3]
    eng = ServeEngine()
    fut = eng.submit_future([s])
    eng.run_once()
    meta = fut.result(timeout=0).results[0].meta
    assert "trace" in meta and "serve" in meta
    assert meta["trace"]["n_nodes"] == 1500


# ---------------------------------------------------------------------------
# Coalescing: N duplicates -> one evaluation.
# ---------------------------------------------------------------------------
def test_duplicate_requests_one_evaluation():
    s = Scenario.tile("engn", K=1024.0)
    eng = ServeEngine()
    n = 7
    futures = [eng.submit_future([s]) for _ in range(n)]
    eng.run_once()
    m = eng.metrics()
    assert m["requests"] == n and m["scenarios"] == n
    assert m["distinct_scenarios"] == 1
    assert m["evaluations"] == 1
    assert m["coalesce_rate"] == pytest.approx(1 - 1 / n)
    for fut in futures:
        serve = fut.result(timeout=0).serve
        assert serve["n_requests"] == n
        assert serve["n_evaluations"] == 1
        assert serve["coalesce_rate"] == pytest.approx(1 - 1 / n)


def test_distinct_plan_keys_one_group_each():
    a = Scenario.tile("engn", K=1024.0)
    b = Scenario.tile("hygcn", K=1024.0)
    c = Scenario.tile("engn", K=2048.0)  # same group as a (stacked leaf)
    eng = ServeEngine()
    futures = [eng.submit_future([s]) for s in (a, b, c, a, b, c)]
    eng.run_once()
    m = eng.metrics()
    assert m["scenarios"] == 6
    assert m["distinct_scenarios"] == 3
    assert m["evaluations"] == 2  # {a, c} broadcast together; b alone
    for fut in futures:
        fut.result(timeout=0)


def test_duplicate_tune_requests_one_tuner_run():
    tune = _pool()[7]
    reset_trace_stats()
    eng = ServeEngine()
    futures = [eng.submit_future([tune]) for _ in range(5)]
    eng.run_once()
    assert eng.metrics()["evaluations"] == 1  # one tuner run, not five
    recs = [_records(f.result(timeout=0).results) for f in futures]
    assert all(r == recs[0] for r in recs)


def test_windows_share_warm_caches():
    """Second window over the same trace re-uses schedules, not computes."""
    s = _pool()[3]
    eng = ServeEngine()
    eng.submit_future([s])
    eng.run_once()
    eng.submit_future([s])
    eng.run_once()
    f = eng.submit_future([s])
    eng.run_once()
    cache = f.result(timeout=0).serve["cache"]
    assert cache["trace_builds"] == 0
    assert cache["schedule_computes"] == 0
    assert cache["schedule_cache_hits"] >= 1


# ---------------------------------------------------------------------------
# Metrics schema.
# ---------------------------------------------------------------------------
def test_serve_meta_schema():
    eng = ServeEngine()
    fut = eng.submit_future([Scenario.tile("engn")])
    eng.run_once()
    sr = fut.result(timeout=0)
    serve = sr.serve
    for key in ("window", "fallback", "n_requests", "n_scenarios",
                "n_distinct_scenarios", "n_evaluations", "coalesce_rate",
                "eval_s", "cache"):
        assert key in serve
    for key in ("trace_builds", "factorizations", "schedule_computes",
                "schedule_cache_hits", "schedule_disk_hits",
                "schedule_hit_rate", "disk_graph_hits",
                "disk_schedule_hits"):
        assert key in serve["cache"]
    per_result = sr.results[0].meta["serve"]
    assert per_result["request_scenarios"] == 1
    assert per_result["latency_s"] >= 0.0
    # the result dict surfaces the serve block for BENCH JSON consumers
    assert "serve" in sr.results[0].to_dict()
    d = sr.to_dict()
    assert d["serve"]["n_requests"] == 1 and len(d["results"]) == 1


def test_engine_metrics_schema():
    eng = ServeEngine()
    m = eng.metrics()
    for key in ("windows", "requests", "scenarios", "distinct_scenarios",
                "evaluations", "rejected_requests", "failed_requests",
                "fallback_windows", "coalesce_rate"):
        assert key in m
    assert m["windows"] == 0 and m["coalesce_rate"] == 0.0


# ---------------------------------------------------------------------------
# Robustness: malformed requests, evaluation failures, lifecycle.
# ---------------------------------------------------------------------------
def test_malformed_requests_rejected_at_submit():
    eng = ServeEngine()
    for bad in (42, "scenario", [], [42], [{"graph": {}}],
                [{"dataflow": "engn", "graph": {"K": "not-a-number"}}]):
        with pytest.raises(ServeError):
            eng.submit_future(bad)
    assert eng.metrics()["rejected_requests"] == 6
    # the loop survives: a good request still serves
    fut = eng.submit_future([Scenario.tile("engn")])
    eng.run_once()
    assert fut.result(timeout=0).results[0].total_bits > 0


def test_evaluation_failure_isolated_to_offending_request():
    good = Scenario.tile("engn", K=1024.0)
    bad = Scenario.tile("no_such_dataflow", K=1024.0)  # fails at registry.get
    eng = ServeEngine()
    f_good = eng.submit_future([good])
    f_bad = eng.submit_future([bad])
    f_good2 = eng.submit_future([good])
    eng.run_once()
    with pytest.raises(KeyError):
        f_bad.result(timeout=0)
    oracle = evaluate_scenarios([good]).results
    assert _records(f_good.result(timeout=0).results) == _records(oracle)
    assert f_good.result(timeout=0).serve["fallback"] is True
    assert _records(f_good2.result(timeout=0).results) == _records(oracle)
    m = eng.metrics()
    assert m["failed_requests"] == 1 and m["fallback_windows"] == 1
    # and the engine keeps serving coalesced windows afterwards
    f3 = eng.submit_future([good])
    eng.run_once()
    assert f3.result(timeout=0).serve["fallback"] is False


def test_stop_drains_queue():
    s = Scenario.tile("engn")
    eng = ServeEngine(window_s=0.001)
    eng.start()
    futures = [eng.submit_future([s]) for _ in range(10)]
    eng.stop()
    for fut in futures:
        assert fut.result(timeout=0).results[0].total_bits > 0


def test_empty_and_oversize_windows():
    eng = ServeEngine(max_window_scenarios=2)
    assert eng.run_once() == 0  # empty queue is a no-op
    s = Scenario.tile("engn")
    futures = [eng.submit_future([s, s]) for _ in range(3)]
    # budget 2: each 2-scenario request gets its own window
    assert eng.run_once() == 1
    assert eng.run_once() == 1
    assert eng.run_once() == 1
    for fut in futures:
        fut.result(timeout=0)
    with pytest.raises(ValueError):
        ServeEngine(window_s=-1.0)
    with pytest.raises(ValueError):
        ServeEngine(max_window_scenarios=0)


def test_double_start_rejected():
    eng = ServeEngine()
    eng.start()
    try:
        with pytest.raises(RuntimeError):
            eng.start()
    finally:
        eng.stop()


# ---------------------------------------------------------------------------
# Concurrency-safety satellites: trace LRU / stats counters under hammer.
# ---------------------------------------------------------------------------
def test_concurrent_resolve_single_flight():
    """8 threads resolving one cold dataset -> exactly one build."""
    name = "serve_test_single_flight"
    calls = {"n": 0}

    def builder(*, seed=0):
        calls["n"] += 1
        rng = np.random.default_rng(int(seed))
        return GraphTrace(rng.integers(0, 200, 2000),
                          rng.integers(0, 200, 2000), 200)

    register_trace_dataset(name, builder, overwrite=True)
    reset_trace_stats()
    barrier = threading.Barrier(8)
    got = []

    def resolve():
        barrier.wait()
        got.append(resolve_trace_dataset(name, {"seed": 7}))

    threads = [threading.Thread(target=resolve) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert calls["n"] == 1
    assert trace_cache_info()["stats"]["trace_builds"] == 1
    assert all(g is got[0] for g in got)


def test_concurrent_stat_bumps_exact():
    """The unguarded ``+=`` these locks replaced lost increments."""
    from repro.core.trace import _bump_stat

    reset_trace_stats()
    n_threads, n_iter = 8, 2000

    def hammer():
        for _ in range(n_iter):
            _bump_stat("schedule_cache_hits")

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stats = trace_cache_info()["stats"]
    assert stats["schedule_cache_hits"] == n_threads * n_iter
    reset_trace_stats()


def test_concurrent_schedule_same_capacity_one_compute():
    rng = np.random.default_rng(11)
    trace = GraphTrace(rng.integers(0, 500, 4000),
                       rng.integers(0, 500, 4000), 500)
    reset_trace_stats()
    barrier = threading.Barrier(8)
    scheds = []

    def query():
        barrier.wait()
        scheds.append(trace.schedule(64))

    threads = [threading.Thread(target=query) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stats = trace_cache_info()["stats"]
    assert stats["schedule_computes"] == 1
    assert stats["schedule_cache_hits"] == 7
    assert stats["factorizations"] == 1
    assert all(s is scheds[0] for s in scheds)


def test_concurrent_schedule_lru_hammer():
    """Mixed capacities from many threads: LRU order and counts stay
    coherent (this corrupted the OrderedDict before the locks)."""
    rng = np.random.default_rng(13)
    trace = GraphTrace(rng.integers(0, 400, 3000),
                       rng.integers(0, 400, 3000), 400)
    caps = [16, 32, 64, 128, 256, 400]
    reset_trace_stats()
    errors = []

    def hammer(seed):
        r = np.random.default_rng(seed)
        try:
            for _ in range(200):
                cap = caps[int(r.integers(0, len(caps)))]
                sched = trace.schedule(cap)
                assert int(sched.vertex_counts.sum()) == 400
        except Exception as exc:  # pragma: no cover - the regression
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # every capacity computed exactly once, everything else was a hit
    assert trace_cache_info()["stats"]["schedule_computes"] <= len(caps)
    for cap in caps:
        np.testing.assert_array_equal(
            trace.schedule(cap).vertex_counts,
            trace.schedule_reference(cap).vertex_counts)


def test_concurrent_typed_relation_carving():
    from repro.core.trace import TypedGraphTrace

    rng = np.random.default_rng(17)
    trace = TypedGraphTrace(rng.integers(0, 300, 2500),
                            rng.integers(0, 300, 2500),
                            rng.integers(0, 4, 2500), 300, 4)
    reset_trace_stats()
    results = []

    def carve():
        results.append(tuple(trace.relation(r).n_edges for r in range(4)))

    threads = [threading.Thread(target=carve) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert trace_cache_info()["stats"]["factorizations"] == 1
    assert len(set(results)) == 1
    assert sum(results[0]) == 2500


# ---------------------------------------------------------------------------
# Disk-cache race satellite: benign rename races + eviction-safe stats.
# ---------------------------------------------------------------------------
def _store_args(seed=0):
    rng = np.random.default_rng(seed)
    snd = np.sort(rng.integers(0, 50, 300))
    rcv = rng.integers(0, 50, 300)
    trace = GraphTrace(snd, rcv, 50)
    u_snd, u_rcv, _, mp = trace._pair_factorization()
    return dict(n_nodes=50, n_edges=300, row_ptr=trace.row_ptr,
                fact_u_snd=u_snd, fact_u_rcv=u_rcv, fact_mult_prefix=mp)


def test_store_graph_double_store_benign(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", str(tmp_path))
    schedule_cache.reset_cache_stats()
    key = schedule_cache.graph_cache_key("serve-test", "{}", "v1")
    assert schedule_cache.store_graph(key, **_store_args())
    assert schedule_cache.store_graph(key, **_store_args())  # exists branch
    stats = schedule_cache.cache_stats()
    assert stats["counters"]["store_races"] == 1
    assert stats["entries"]["graphs"] == 1
    assert schedule_cache.load_graph(key) is not None


def test_store_graph_toctou_race_benign(tmp_path, monkeypatch):
    """A writer landing the entry *between* the exists check and the
    rename used to turn the loser's os.replace ENOTEMPTY into a failed
    store; now it is a benign no-op."""
    monkeypatch.setenv("REPRO_TRACE_CACHE", str(tmp_path))
    schedule_cache.reset_cache_stats()
    key = schedule_cache.graph_cache_key("serve-test-race", "{}", "v1")
    real_replace = os.replace
    state = {"raced": False}

    def racing_replace(src, dst):
        if not state["raced"] and str(dst).endswith(".graph"):
            state["raced"] = True
            # the winner lands the entry first (recursion passes through
            # the raced flag, so its own rename is the real one)
            assert schedule_cache.store_graph(key, **_store_args())
        return real_replace(src, dst)

    monkeypatch.setattr(os, "replace", racing_replace)
    assert schedule_cache.store_graph(key, **_store_args())  # the loser
    monkeypatch.setattr(os, "replace", real_replace)
    assert schedule_cache.cache_stats()["counters"]["store_races"] == 1
    assert schedule_cache.load_graph(key) is not None
    # no stray tmp dirs survived the race
    stray = [p for p in tmp_path.rglob("*.tmp")]
    assert stray == []


def test_store_graph_threaded_hammer(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", str(tmp_path))
    schedule_cache.reset_cache_stats()
    key = schedule_cache.graph_cache_key("serve-test-hammer", "{}", "v1")
    args = _store_args()
    outcomes = []
    barrier = threading.Barrier(6)

    def store():
        barrier.wait()
        outcomes.append(schedule_cache.store_graph(key, **args))

    threads = [threading.Thread(target=store) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(outcomes)  # every racer reports success
    assert schedule_cache.cache_stats()["entries"]["graphs"] == 1
    assert schedule_cache.load_graph(key) is not None


def test_cache_stats_schema_and_eviction_safety(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", str(tmp_path))
    schedule_cache.reset_cache_stats()
    stats = schedule_cache.cache_stats()
    assert stats["enabled"] and stats["root"] == str(tmp_path)
    assert stats["entries"] == {"graphs": 0, "schedules": 0}
    key = schedule_cache.graph_cache_key("serve-test-stats", "{}", "v1")
    schedule_cache.store_graph(key, **_store_args())
    skey = schedule_cache.schedule_cache_key("serve-test-stats", "{}",
                                             "v1", 16)
    schedule_cache.store_schedule(
        skey, n_tiles=4, capacity=16, K=13,
        vertex_counts=np.ones(4), edge_counts=np.ones(4),
        halo_counts=np.ones(4), remote_edge_counts=np.ones(4))
    stats = schedule_cache.cache_stats()
    assert stats["entries"] == {"graphs": 1, "schedules": 1}
    assert stats["bytes"] > 0
    assert stats["counters"]["graph_stores"] == 1
    assert stats["counters"]["schedule_stores"] == 1
    # eviction mid-walk: a vanished entry is skipped, never an error
    import shutil
    shutil.rmtree(tmp_path)
    stats = schedule_cache.cache_stats()
    assert stats["entries"] == {"graphs": 0, "schedules": 0}

    monkeypatch.setenv("REPRO_TRACE_CACHE", "0")
    assert schedule_cache.cache_stats()["enabled"] is False


def test_serve_window_uses_disk_cache_counters(tmp_path, monkeypatch):
    """End-to-end: a cold trace resolve inside a serve window surfaces
    disk-store activity through ``meta["serve"]["cache"]`` deltas."""
    monkeypatch.setenv("REPRO_TRACE_CACHE", str(tmp_path))
    monkeypatch.setenv("REPRO_TRACE_CACHE_MIN_EDGES", "0")
    from repro.core.trace import clear_trace_cache
    clear_trace_cache()
    params = dict(TRACE_PARAMS)
    params["seed"] = 99.0  # unique key: never resolved by other tests
    s = Scenario.trace("engn", dataset="power_law", params=params,
                       N=16.0, T=4.0, tile_vertices=256.0)
    eng = ServeEngine()
    f = eng.submit_future([s])
    eng.run_once()
    cache = f.result(timeout=0).serve["cache"]
    assert cache["trace_builds"] == 1
    # warm process, cold disk: the resolve stored (not hit) the graph
    assert schedule_cache.cache_stats()["counters"]["graph_stores"] >= 1
    clear_trace_cache()
    eng2 = ServeEngine()
    f2 = eng2.submit_future([s])
    eng2.run_once()
    cache2 = f2.result(timeout=0).serve["cache"]
    assert cache2["trace_builds"] == 0  # disk warm-start, no rebuild
    assert cache2["disk_graph_hits"] == 1
