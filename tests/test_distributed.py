"""Runs the 8-fake-device battery (tests/distributed_checks.py) in a
subprocess — the device count must be forced before jax initializes, which
cannot happen inside an already-initialized pytest process."""

import os
import subprocess
import sys
from pathlib import Path


def test_distributed_battery():
    script = Path(__file__).parent / "distributed_checks.py"
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert "ALL DISTRIBUTED CHECKS PASSED" in proc.stdout
