"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates its REDUCED config and runs one real train (and serve where
applicable) step on CPU, asserting output shapes and no NaNs.  The FULL
configs are exercised only via the dry-run artifacts
(tests/test_dryrun_results.py)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, all_cells, get_arch
from repro.data import synthetic
from repro.data.wigner import rotation_to_z, wigner_stack
from repro.models import dlrm as dlrm_lib
from repro.models import transformer as tf_lib
from repro.models.gnn import equiformer_v2 as eqv2_lib
from repro.models.gnn import gatedgcn as ggcn_lib
from repro.models.gnn import gcn as gcn_lib
from repro.models.gnn import meshgraphnet as mgn_lib
from repro.models.gnn.graph import GraphBatch
from repro.optim.optimizers import adamw

LM_ARCHS = [a for a, d in REGISTRY.items() if d.family == "lm"]
GNN_ARCHS = [a for a, d in REGISTRY.items() if d.family == "gnn"]

_GNN_MODULES = {"gcn-cora": gcn_lib, "gatedgcn": ggcn_lib,
                "meshgraphnet": mgn_lib, "equiformer-v2": eqv2_lib}


def test_registry_covers_assignment():
    assert len(REGISTRY) == 10
    cells = all_cells(include_skipped=True)
    assert len(cells) == 40                       # 10 archs x 4 shapes
    skipped = [c for c in cells if c[2].startswith("SKIP")]
    assert len(skipped) == 4                      # 4 pure-full-attn long_500k
    assert all(c[1] == "long_500k" for c in skipped)


@pytest.mark.parametrize("arch_name", LM_ARCHS)
def test_lm_smoke_train_and_decode(arch_name):
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    params = tf_lib.init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    batch = synthetic.lm_batch(0, 0, batch=B, seq=S, vocab=cfg.vocab)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    opt = adamw(1e-3)
    step = jax.jit(tf_lib.make_train_step(cfg, opt))
    p2, st, m = step(params, opt.init(params), batch)
    assert jnp.isfinite(m["loss"]), arch_name
    # serve one token
    cache = tf_lib.init_cache(cfg, B, S)
    serve = jax.jit(tf_lib.make_serve_step(cfg, S))
    logits, cache = serve(params, cache, batch["tokens"][:, :1],
                          jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, cfg.vocab)
    assert not jnp.isnan(logits).any()


@pytest.mark.parametrize("arch_name", GNN_ARCHS)
def test_gnn_smoke_train(arch_name):
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_config()
    module = _GNN_MODULES[arch_name]
    rng = np.random.default_rng(0)
    n, e = 24, 72
    ga = synthetic.power_law_graph(0, n_nodes=n, n_edges=e, d_feat=cfg.d_in,
                                   n_classes=getattr(cfg, "n_classes", 3),
                                   self_loops=arch_name != "equiformer-v2")
    kw = dict(node_feat=jnp.asarray(ga.node_feat),
              senders=jnp.asarray(ga.senders),
              receivers=jnp.asarray(ga.receivers))
    if arch_name == "gatedgcn":
        kw["edge_feat"] = jnp.ones((ga.n_edges, cfg.d_edge_in), jnp.float32)
        kw["labels"] = jnp.asarray(ga.labels)
    elif arch_name == "meshgraphnet":
        kw["edge_feat"] = jnp.ones((ga.n_edges, cfg.d_edge_in), jnp.float32)
        kw["labels"] = jnp.asarray(rng.standard_normal((ga.n_nodes, cfg.d_out)),
                                   jnp.float32)
    elif arch_name == "equiformer-v2":
        pos = rng.standard_normal((ga.n_nodes, 3))
        vecs = pos[ga.senders] - pos[ga.receivers]
        wig = wigner_stack(np.stack([rotation_to_z(v) for v in vecs]),
                           cfg.l_max, m_max=cfg.m_max)
        kw["wigner"] = {l: jnp.asarray(w) for l, w in wig.items()}
        kw["labels"] = jnp.asarray(rng.standard_normal((1, cfg.d_out)), jnp.float32)
    else:
        kw["labels"] = jnp.asarray(ga.labels)
    g = GraphBatch(**kw)
    params = module.init_params(cfg, jax.random.key(1))
    opt = adamw(1e-3)
    st = opt.init(params)

    @jax.jit
    def step(params, st, g):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: module.loss_fn(cfg, p, g), has_aux=True)(params)
        up, st = opt.update(grads, st, params)
        from repro.optim.optimizers import apply_updates
        return apply_updates(params, up), st, metrics

    p2, st, m = step(params, st, g)
    assert jnp.isfinite(m["loss"]), arch_name
    for leaf in jax.tree_util.tree_leaves(p2):
        assert jnp.isfinite(leaf).all()


def test_dlrm_smoke_train_and_serve():
    arch = get_arch("dlrm-mlperf")
    cfg = arch.make_smoke_config()
    params = dlrm_lib.init_params(cfg, jax.random.key(0))
    batch = synthetic.criteo_batch(0, 0, batch=8, n_dense=cfg.n_dense,
                                   vocab_sizes=cfg.vocab_sizes,
                                   multi_hot=cfg.multi_hot)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    opt = adamw(1e-3)
    st = opt.init(params)

    @jax.jit
    def step(params, st, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: dlrm_lib.loss_fn(cfg, p, batch), has_aux=True)(params)
        up, st = opt.update(grads, st, params)
        from repro.optim.optimizers import apply_updates
        return apply_updates(params, up), st, metrics

    p2, st, m = step(params, st, batch)
    assert jnp.isfinite(m["loss"])
    logits = dlrm_lib.forward(cfg, p2, batch)
    assert logits.shape == (8,) and not jnp.isnan(logits).any()
    # retrieval scoring path
    cands = jnp.asarray(np.random.default_rng(0).standard_normal(
        (1000, cfg.embed_dim)), jnp.float32)
    scores = dlrm_lib.score_candidates(cfg, p2, {"dense": batch["dense"][:1]},
                                       cands)
    assert scores.shape == (1000,) and jnp.isfinite(scores).all()


@pytest.mark.parametrize("arch_name", list(REGISTRY))
def test_full_configs_construct(arch_name):
    """Full published configs must CONSTRUCT (no allocation) and report
    plausible parameter counts."""
    arch = get_arch(arch_name)
    cfg = arch.make_config()
    if arch.family == "lm":
        n = cfg.param_count()
        expected = {"qwen3-moe-30b-a3b": 30e9, "arctic-480b": 480e9,
                    "granite-3-2b": 2.5e9, "gemma2-2b": 2.6e9,
                    "smollm-135m": 135e6}[arch_name]
        assert 0.5 * expected < n < 1.7 * expected, (arch_name, n)
    elif arch.family == "recsys":
        assert cfg.param_count() > 20e9  # ~24B embedding rows x 128 @ Criteo-1TB
