"""Reproduction tests for the paper's quantitative claims (DESIGN.md §8).

Each test pins one statement from Sec. IV of the paper to the analytical
models at the published defaults: N=30, T=5, B=1000, sigma=4, P=10K.
"""

import numpy as np
import pytest

from repro.core import (EnGNHardwareParams, EnGNModel, HyGCNHardwareParams,
                        HyGCNModel, paper_default_graph)
from repro.core.sweep import (fig3_engn_movement, fig4_hygcn_movement,
                              fig5_iterations_vs_bandwidth,
                              fig6_fitting_factor, fig7_systolic_reuse)

ENGN = EnGNModel()
HYGCN = HyGCNModel()


# ---------------------------------------------------------------------------
# Claim 1 — "aggregation dominates and leads to over 10x more data movement
# than loadvertL2" (Sec. IV-A, Fig. 3 discussion).
# ---------------------------------------------------------------------------
def test_engn_aggregate_dominates_loadvert():
    # At EnGN's published 128x16 PE array (the paper's default hardware).
    out = ENGN.evaluate(paper_default_graph(1024.0), EnGNHardwareParams())
    ratio = float(out["aggregate"].data_bits / out["loadvertL2"].data_bits)
    assert ratio > 10.0, f"aggregate/loadvertL2 = {ratio:.2f}, paper claims > 10x"


def test_engn_aggregate_dominates_across_sweep():
    """Fig. 3 shows aggregate as the top curve across the whole M sweep."""
    M = np.array([4, 8, 16, 64, 128, 256], dtype=np.float64)
    out = ENGN.evaluate(paper_default_graph(1024.0), EnGNHardwareParams(M=M, M_prime=M))
    assert np.all(out["aggregate"].data_bits > out["loadvertL2"].data_bits)


def test_engn_aggregate_is_onchip_class():
    out = ENGN.evaluate(paper_default_graph(1024.0))
    assert out["aggregate"].hierarchy == "L1-L1"  # fast path per the paper


# ---------------------------------------------------------------------------
# Claim 2 — EnGN movement is linear in K but non-monotone in M.
# ---------------------------------------------------------------------------
def test_engn_linear_in_K():
    K = np.array([256, 512, 1024, 2048, 4096, 8192], dtype=np.float64)
    total = ENGN.evaluate(paper_default_graph(K)).total_bits()
    # R^2 of a linear fit must be ~1.
    coeffs = np.polyfit(K, total, 1)
    pred = np.polyval(coeffs, K)
    ss_res = np.sum((total - pred) ** 2)
    ss_tot = np.sum((total - total.mean()) ** 2)
    r2 = 1.0 - ss_res / ss_tot
    assert r2 > 0.99, f"R^2 = {r2}"


def test_engn_nonmonotone_in_M():
    """Fig. 3: movement first decreases then increases with the array size."""
    M = np.array([4, 8, 16, 32, 64, 128, 256], dtype=np.float64)
    total = ENGN.evaluate(
        paper_default_graph(1024.0), EnGNHardwareParams(M=M, M_prime=M)
    ).total_bits()
    best = int(np.argmin(total))
    assert 0 < best < len(M) - 1, f"optimum must be interior, got index {best} of {total}"


# ---------------------------------------------------------------------------
# Claim 3 — HyGCN movement is linear in K and independent of array size
# for the off-chip-class terms (Sec. IV-B: "independent of the array size").
# ---------------------------------------------------------------------------
def test_hygcn_linear_in_K():
    K = np.array([256, 512, 1024, 2048, 4096, 8192], dtype=np.float64)
    total = HYGCN.evaluate(paper_default_graph(K)).total_bits()
    coeffs = np.polyfit(K, total, 1)
    pred = np.polyval(coeffs, K)
    r2 = 1.0 - np.sum((total - pred) ** 2) / np.sum((total - total.mean()) ** 2)
    assert r2 > 0.99, f"R^2 = {r2}"


def test_hygcn_offchip_independent_of_Ma():
    Ma = np.array([8, 16, 32, 64, 128], dtype=np.float64)
    out = HYGCN.evaluate(paper_default_graph(1024.0), HyGCNHardwareParams(Ma=Ma))
    offchip = out.offchip_bits() + out.total_bits(("L1-L2",))
    spread = (offchip.max() - offchip.min()) / offchip.mean()
    assert spread < 1e-9, f"off-chip movement varies with Ma: {offchip}"


# ---------------------------------------------------------------------------
# Claim 4 — HyGCN moves significantly more (off-chip-class) data than EnGN
# "due to its dual architecture and the need to write-read from the
# aggregation buffer" (Sec. IV-B).
# ---------------------------------------------------------------------------
def test_hygcn_moves_more_offchip_than_engn():
    g = paper_default_graph(1024.0)
    engn_off = float(EnGNModel().evaluate(g).offchip_bits())
    hygcn_off = float(HyGCNModel().evaluate(g).offchip_bits())
    assert hygcn_off > engn_off, (engn_off, hygcn_off)
    # The inter-phase buffer terms alone account for the gap.
    out = HYGCN.evaluate(g)
    interphase = float(out["writeinterphase"].data_bits + out["readinterphase"].data_bits)
    assert interphase > 0.5 * (hygcn_off - engn_off)


def test_engn_loadvertL2_smaller_than_hygcn():
    """Sec. IV-A: the degree cache relieves EnGN's vertex memory bank.

    Compared at matched PE-array sizes (M = Ma), since the vertex-streaming
    throughput constraint min(B, M*sigma) otherwise differs mechanically.
    """
    g = paper_default_graph(1024.0)
    sizes = np.array([8, 16, 32, 64], dtype=np.float64)
    engn = ENGN.evaluate(g, EnGNHardwareParams(M=sizes, M_prime=sizes))["loadvertL2"].data_bits
    hygcn = HYGCN.evaluate(g, HyGCNHardwareParams(Ma=sizes))["loadvertL2"].data_bits
    assert np.all(engn <= hygcn), (engn, hygcn)
    assert np.any(engn < hygcn)


# ---------------------------------------------------------------------------
# Claim 5 — bandwidth saturation: EnGN's saturation point grows with the
# tile size; HyGCN's knee is abrupt.
# ---------------------------------------------------------------------------
def _saturation_B(res, k_index: int, tol: float = 1.05) -> float:
    iters = res.total_iterations[:, k_index]
    floor = iters.min()
    B = res.axes["B"]
    sat = B[np.argmax(iters <= tol * floor)]
    return float(sat)


def test_engn_saturation_point_grows_with_tile():
    res = fig5_iterations_vs_bandwidth("engn")
    sats = [_saturation_B(res, i) for i in range(len(res.axes["K"]))]
    assert sats == sorted(sats), sats
    assert sats[-1] > sats[0]


def test_hygcn_iterations_decrease_with_bandwidth():
    res = fig5_iterations_vs_bandwidth("hygcn")
    iters = res.total_iterations
    assert np.all(np.diff(iters, axis=0) <= 1e-9)  # monotone non-increasing in B


# ---------------------------------------------------------------------------
# Claim 6 — HyGCN loadweights scales with (1 - Gamma) (Fig. 7).
# ---------------------------------------------------------------------------
def test_hygcn_gamma_suppresses_loadweights():
    res = fig7_systolic_reuse()
    lw = res.data_bits["loadweights"]
    assert np.all(np.diff(lw, axis=0) <= 1e-9), "loadweights must fall as Gamma grows"
    # At Gamma -> 1 the traffic vanishes (full reuse).
    assert lw[-1].max() < lw[0].min()


def test_hygcn_loadweights_grows_with_depth_N():
    res = fig7_systolic_reuse()
    lw = res.data_bits["loadweights"]
    assert np.all(np.diff(lw, axis=1) >= -1e-9)


# ---------------------------------------------------------------------------
# Claim 7 — EnGN iterations jump once the fitting factor K*N/M^2 exceeds 1
# (Fig. 6): small arrays need several steps per tile.
# ---------------------------------------------------------------------------
def test_engn_fitting_factor_knee():
    res = fig6_fitting_factor()
    ff = np.asarray(res.meta["fitting_factor"])
    iters = res.total_iterations
    over = iters[ff > 1.0]
    under = iters[ff <= 1.0]
    assert over.min() > under.max() * 0.99  # loaded arrays take no fewer steps
    assert over.max() > under.max()         # and strictly more at the extreme
    # Iterations increase monotonically with the fitting factor.
    order = np.argsort(ff)
    assert np.all(np.diff(iters[order]) >= -1e-9)


# ---------------------------------------------------------------------------
# Structural checks on the sweep engine itself.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fn,naxes", [
    (fig3_engn_movement, 2),
    (fig4_hygcn_movement, 2),
    (fig6_fitting_factor, 1),
    (fig7_systolic_reuse, 2),
])
def test_sweep_shapes(fn, naxes):
    res = fn()
    assert len(res.axes) == naxes
    shape = tuple(len(v) for v in res.axes.values())
    assert np.broadcast_to(res.total_bits, shape).shape == shape
    rows = res.rows()
    assert len(rows) == int(np.prod(shape))
    assert all(np.isfinite(r["total_bits"]) for r in rows)
