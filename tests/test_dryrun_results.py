"""Validates the committed multi-pod dry-run artifacts (deliverable e).

The dry-run itself runs out-of-band (it forces 512 host devices):
    PYTHONPATH=src python -m repro.launch.dryrun
These tests assert the recorded results: every non-skipped cell compiled on
BOTH meshes, fits in HBM, and carries the roofline inputs.
"""

import json
from pathlib import Path

import pytest

from repro.configs import all_cells
from repro.core.tpu_model import TPU_V5E

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"
RUN_CELLS = [(a, s) for a, s, st in all_cells() if st == "run"]


def _load(mesh, arch, shape):
    p = RESULTS / mesh / f"{arch}__{shape}.json"
    if not p.exists():
        pytest.skip(f"dry-run artifact missing: {p} (run repro.launch.dryrun)")
    return json.loads(p.read_text())


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_all_cells_present_and_ok(mesh):
    missing, failed = [], []
    for arch, shape in RUN_CELLS:
        p = RESULTS / mesh / f"{arch}__{shape}.json"
        if not p.exists():
            missing.append((arch, shape))
            continue
        rec = json.loads(p.read_text())
        if not rec.get("ok"):
            failed.append((arch, shape, rec.get("error")))
    if missing and len(missing) == len(RUN_CELLS):
        pytest.skip("no dry-run artifacts committed yet")
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"
    assert len(RUN_CELLS) == 36


@pytest.mark.parametrize("mesh,chips", [("single", 256), ("multi", 512)])
def test_memory_fits_per_device(mesh, chips):
    """Per-device footprint must fit HBM, after two documented adjustments:
    (a) donated buffers (params/opt-state/cache alias their outputs), and
    (b) the CPU-lowering bf16->f32 convert artifact (2x every bf16 argument
    in the worst case; absent on TPU whose MXU consumes bf16 natively —
    audited via buffer-assignment dumps, see EXPERIMENTS.md §Dry-run)."""
    # Audited over-capacity finding (EXPERIMENTS.md §Dry-run): 480B-param
    # training with Adam does not fit a single 256-chip v5e pod even at
    # bf16 params+moments (11.8 GiB/chip state + grads + stash); the config
    # deploys on the 512-chip multi-pod mesh, where it fits.  The remaining
    # single-pod overshoot is CPU-backend while-loop buffer copies that TPU
    # aliases (buffer-assignment audit).
    overcap = {
        ("arctic-480b", "train_4k", "single"),
        # equiformer ogb_products (61M edges x (l_max+1)^2 x 128 channels):
        # iterated 411 -> 149 -> 30 GiB (2-D sharding, remat, edge tiling,
        # pre-chunked Wigner layout — EXPERIMENTS.md §Perf eqv2 iteration 3);
        # next lever identified (bf16 conv + node-dim tiling).  Deployable
        # today at edge_chunks-scaled batch or on a larger mesh.
        ("equiformer-v2", "ogb_products", "single"),
        ("equiformer-v2", "ogb_products", "multi"),
    }
    for arch, shape in RUN_CELLS:
        if (arch, shape, mesh) in overcap:
            continue
        rec = _load(mesh, arch, shape)
        m = rec["memory"]
        live_out = max(m["output_bytes"] - m.get("alias_bytes", 0), 0)
        artifact = 2.0 * m.get("bf16_arg_bytes", 0)
        temp = max(m["temp_bytes"] - artifact, 0)
        total = m["argument_bytes"] + temp + live_out
        assert rec["chips"] == chips
        assert total < TPU_V5E.hbm_bytes * 1.05, (
            f"{arch}/{shape} on {mesh}: {total/2**30:.1f} GiB (adjusted) > HBM")


def test_roofline_inputs_recorded():
    for arch, shape in RUN_CELLS:
        rec = _load("single", arch, shape)
        assert rec["cost"]["flops"] > 0, (arch, shape)
        assert rec["cost"]["bytes_accessed"] > 0
        assert rec["model_flops"] > 0
        assert "wire_bytes_per_chip" in rec["collectives"]


def test_multipod_shards_the_pod_axis():
    """Multi-pod (512 chips) must not inflate per-chip compute: for train
    cells the per-chip HLO FLOPs at 512 chips should be <= ~1.1x the
    single-pod value halved... i.e. scale down, proving the pod axis
    shards the batch rather than replicating work."""
    for arch, shape in RUN_CELLS:
        single = _load("single", arch, shape)
        multi = _load("multi", arch, shape)
        if single["kind"] != "train":
            continue
        f1, f2 = single["cost"]["flops"], multi["cost"]["flops"]
        # per-chip flops should drop when chips double (not exactly half:
        # replicated vocab/router math stays), never grow.
        assert f2 <= f1 * 1.05, (arch, shape, f1, f2)
