"""8-fake-device distributed correctness battery.

NOT collected by pytest directly (device count must be forced before jax
initializes) — tests/test_distributed.py runs this file in a subprocess and
asserts exit code 0.  Every check compares a distributed execution path
against its single-logical-device oracle.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.tpu_model import (allreduce_bytes, dp_gradient_sync,  # noqa: E402
                                  moe_dispatch_sync, spmm_feature_allgather)
from repro.core.validation import validate_traffic  # noqa: E402
from repro.distributed.pipeline_par import gpipe_apply  # noqa: E402
from repro.distributed.ring import (allgather_spmm, partition_edges_gather,  # noqa: E402
                                    partition_edges_ring, ring_spmm)
from repro.distributed.sharding import make_policy  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.models import dlrm as dlrm_lib  # noqa: E402
from repro.models import transformer as tf_lib  # noqa: E402
from repro.models.moe import MoEConfig  # noqa: E402


def _rel(a, b):
    return float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))


def check_moe_ep_and_ctx_and_decode():
    mesh = make_test_mesh()
    policy = make_policy(mesh)
    moe = tf_lib.TransformerConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, d_head=8,
        d_ff=64, vocab=128,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=8.0),
        dtype="float32", q_chunk=8)
    params = tf_lib.init_params(moe, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, moe.vocab)
    ref, _ = jax.jit(lambda p, t: tf_lib.forward(moe, p, t))(params, tokens)
    dist, _ = jax.jit(lambda p, t: tf_lib.forward(moe, p, t, policy=policy))(
        params, tokens)
    assert _rel(dist, ref) < 2e-4, ("moe ep", _rel(dist, ref))

    ctx = tf_lib.TransformerConfig(
        name="c", n_layers=2, d_model=24, n_heads=3, n_kv_heads=3, d_head=8,
        d_ff=64, vocab=128, dtype="float32", q_chunk=4)
    p2 = tf_lib.init_params(ctx, jax.random.key(2))
    t2 = jax.random.randint(jax.random.key(3), (2, 16), 0, ctx.vocab)
    r2, _ = jax.jit(lambda p, t: tf_lib.forward(ctx, p, t))(p2, t2)
    d2, _ = jax.jit(lambda p, t: tf_lib.forward(ctx, p, t, policy=policy))(p2, t2)
    assert _rel(d2, r2) < 2e-4, ("ctx", _rel(d2, r2))

    dense = tf_lib.TransformerConfig(
        name="d", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
        d_ff=64, vocab=128, window_pattern=(8, None), dtype="float32", q_chunk=8)
    p3 = tf_lib.init_params(dense, jax.random.key(4))
    S3 = 16
    t3 = jax.random.randint(jax.random.key(5), (2, S3), 0, dense.vocab)
    serve_ref = jax.jit(tf_lib.make_serve_step(dense, S3))
    serve_sh = jax.jit(tf_lib.make_serve_step(
        dense, S3, policy=policy,
        decode=tf_lib.DecodePolicy(cache_seq_axes=("model",),
                                   batch_axes=("data",))))
    c1 = tf_lib.init_cache(dense, 2, S3)
    c2 = tf_lib.init_cache(dense, 2, S3)
    for i in range(S3):
        l1, c1 = serve_ref(p3, c1, t3[:, i:i + 1], jnp.asarray(i, jnp.int32))
        l2, c2 = serve_sh(p3, c2, t3[:, i:i + 1], jnp.asarray(i, jnp.int32))
    assert _rel(l2, l1) < 2e-4, ("decode", _rel(l2, l1))

    # prefill == decoding-from-scratch final logits
    prefill = jax.jit(tf_lib.make_prefill_step(dense))
    lp, cache_p = prefill(p3, t3)
    assert _rel(lp, l1) < 2e-4, ("prefill", _rel(lp, l1))
    print("  moe/ctx/decode/prefill OK")


def check_ring_spmm():
    rng = np.random.default_rng(0)
    N, E, F = 64, 300, 12
    snd = rng.integers(0, N, E)
    rcv = rng.integers(0, N, E)
    wgt = rng.random(E).astype(np.float32)
    h = rng.standard_normal((N, F)).astype(np.float32)
    ref = np.zeros((N, F), np.float32)
    np.add.at(ref, rcv, h[snd] * wgt[:, None])
    mesh = make_test_mesh((8,), ("x",))
    rp = partition_edges_ring(snd, rcv, wgt, N, 8)
    gp = partition_edges_gather(snd, rcv, wgt, N, 8)
    hj = jnp.asarray(h)
    out_r = jax.jit(lambda *a: ring_spmm(*a, mesh=mesh, axis_names=("x",)))(
        hj, jnp.asarray(rp.senders), jnp.asarray(rp.receivers),
        jnp.asarray(rp.weights))
    out_g = jax.jit(lambda *a: allgather_spmm(*a, mesh=mesh, axis_names=("x",)))(
        hj, jnp.asarray(gp.senders), jnp.asarray(gp.receivers),
        jnp.asarray(gp.weights))
    assert np.max(np.abs(np.asarray(out_r) - ref)) < 1e-4
    assert np.max(np.abs(np.asarray(out_g) - ref)) < 1e-4
    # grads
    g = jax.jit(jax.grad(lambda hh: jnp.sum(ring_spmm(
        hh, jnp.asarray(rp.senders), jnp.asarray(rp.receivers),
        jnp.asarray(rp.weights), mesh=mesh, axis_names=("x",)) ** 2)))(hj)
    assert jnp.isfinite(g).all()
    print("  ring/allgather spmm OK")


def check_gpipe():
    mesh = make_test_mesh((4,), ("pipe",))
    rng = np.random.default_rng(0)
    S, M, B, D = 4, 6, 3, 8
    ws = jnp.asarray(rng.standard_normal((S, D, D)) / np.sqrt(D), jnp.float32)
    bs = jnp.asarray(rng.standard_normal((S, D)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.standard_normal((M, B, D)), jnp.float32)

    def stage(params, h):
        w, b = params
        return jnp.tanh(h @ w + b)

    out = gpipe_apply(stage, (ws, bs), x, mesh=mesh, axis="pipe")
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ ws[s] + bs[s])
    assert _rel(out, ref) < 1e-5, ("gpipe", _rel(out, ref))
    # differentiable
    g = jax.grad(lambda xx: jnp.sum(
        gpipe_apply(stage, (ws, bs), xx, mesh=mesh, axis="pipe") ** 2))(x)
    assert jnp.isfinite(g).all()
    print("  gpipe OK")


def check_dlrm_vocab_parallel():
    mesh = make_test_mesh()
    policy = make_policy(mesh)
    cfg = dlrm_lib.DLRMConfig(
        name="t", embed_dim=16,
        vocab_sizes=(64, 100, 32, 48) + (16,) * 22,  # mixed shard/replicate
        bot_mlp=(32, 16), top_mlp=(64, 1))
    params = dlrm_lib.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    B = 16
    sparse = np.stack([rng.integers(0, v, (B, 1)) for v in cfg.vocab_sizes], 1)
    batch = {"dense": jnp.asarray(rng.standard_normal((B, 13)), jnp.float32),
             "sparse": jnp.asarray(sparse, jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 2, B), jnp.int32)}
    ref = jax.jit(lambda p, b: dlrm_lib.forward(cfg, p, b))(params, batch)
    dist = jax.jit(lambda p, b: dlrm_lib.forward(cfg, p, b, policy=policy))(
        params, batch)
    assert _rel(dist, ref) < 2e-4, ("dlrm", _rel(dist, ref))
    print("  dlrm vocab-parallel OK")


def check_analytical_vs_hlo():
    """The validation loop: analytical CommModels vs compiled collectives."""
    mesh = make_test_mesh((8,), ("data",))
    # --- pure DP grad all-reduce over 8 devices, exact prediction.
    D, F = 128, 64
    w = jnp.zeros((D, F), jnp.float32)
    x = jnp.zeros((256, D), jnp.float32)

    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    comp = jax.jit(jax.grad(loss), in_shardings=(
        NamedSharding(mesh, P(None, None)), NamedSharding(mesh, P("data", None))),
        out_shardings=NamedSharding(mesh, P(None, None))).lower(w, x).compile()
    model = dp_gradient_sync(D * F * 4, 8)
    rec = validate_traffic("dp_allreduce", model, comp)
    print("  ", rec)
    assert rec.within(0.05), rec

    # --- all-gather SpMM feature collection, exact prediction.
    rng = np.random.default_rng(0)
    N, E, Fq = 64, 256, 16
    snd = rng.integers(0, N, E)
    rcv = rng.integers(0, N, E)
    wgt = rng.random(E).astype(np.float32)
    gp = partition_edges_gather(snd, rcv, wgt, N, 8)
    comp2 = jax.jit(lambda *a: allgather_spmm(
        *a, mesh=mesh, axis_names=("data",))).lower(
        jnp.zeros((N, Fq)), jnp.asarray(gp.senders), jnp.asarray(gp.receivers),
        jnp.asarray(gp.weights)).compile()
    model2 = spmm_feature_allgather(N, Fq, 8, dtype_bytes=4)
    rec2 = validate_traffic("spmm_allgather", model2, comp2)
    print("  ", rec2)
    assert rec2.within(0.05), rec2
    print("  analytical-vs-HLO OK")


if __name__ == "__main__":
    check_moe_ep_and_ctx_and_decode()
    check_ring_spmm()
    check_gpipe()
    check_dlrm_vocab_parallel()
    check_analytical_vs_hlo()
    print("ALL DISTRIBUTED CHECKS PASSED")
