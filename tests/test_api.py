"""Scenario front-door tests (DESIGN.md §11).

Load-bearing guarantees:

* a Scenario is pure data: JSON round trips reproduce the evaluation
  **bit-identically** for every registered dataflow and both composition
  policies;
* the batch planner's stacked broadcast evaluation equals the
  per-scenario loop exactly (same float64 bits), while performing at most
  one broadcast evaluation per distinct dataflow for homogeneous batches
  (and exactly one per figure template);
* the workload configs' §5 tile-language bridges evaluate end-to-end
  across every registered dataflow;
* registry scratch registration (`temporarily_registered`) and the
  compose-layer input validation satellites behave.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.api import (Composition, Scenario, dump_scenarios,
                       evaluate_scenario, evaluate_scenarios, load_scenarios,
                       template, template_names)
from repro.api.cli import main as cli_main
from repro.core import registry
from repro.core.compose import FullGraphParams, TiledGraphModel
from repro.core.validation import SEC4_GOLDEN_TOTALS

ALL_DATAFLOWS = registry.names()


def _policy_scenarios(dataflow: str) -> dict[str, Scenario]:
    """One scenario per structural shape the planner distinguishes."""
    return {
        "tile": Scenario.tile(dataflow, K=512.0),
        "tile_hw": Scenario.tile(dataflow, K=768.0, hardware={"B": 2000.0}),
        "ml_spill": Scenario.tile(
            dataflow, K=512.0, N=64.0, T=4.0,
            composition={"widths": [64, 16, 4], "residency": "spill"}),
        "ml_resident": Scenario.tile(
            dataflow, K=512.0, N=64.0, T=4.0,
            composition={"widths": [64, 16, 4], "residency": "resident"}),
        "tiled_spill": Scenario.full_graph(
            dataflow, V=2708.0, E=10556.0, N=1433.0, T=7.0,
            tile_vertices=512.0, widths=[1433, 16, 7], residency="spill"),
        "tiled_resident": Scenario.full_graph(
            dataflow, V=2708.0, E=10556.0, N=1433.0, T=7.0,
            tile_vertices=512.0, widths=[1433, 16, 7], residency="resident"),
    }


# ---------------------------------------------------------------------------
# JSON round trips: Scenario -> to_json -> from_json -> evaluate, bit for bit.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_DATAFLOWS)
def test_scenario_json_round_trip_bit_identical(name):
    for policy, s in _policy_scenarios(name).items():
        s2 = Scenario.from_json(s.to_json())
        assert s2 == s, policy
        r1, r2 = evaluate_scenario(s), evaluate_scenario(s2)
        assert r1.total_bits == r2.total_bits, policy
        assert r1.total_iterations == r2.total_iterations, policy
        assert r1.breakdown == r2.breakdown, policy
        assert r1.iteration_breakdown == r2.iteration_breakdown, policy


def test_scenario_file_round_trip(tmp_path):
    batch = [s for name in ALL_DATAFLOWS
             for s in _policy_scenarios(name).values()]
    path = tmp_path / "batch.json"
    dump_scenarios(batch, str(path))
    loaded = load_scenarios(str(path))
    assert loaded == batch
    # a bare JSON list loads too
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps([s.to_dict() for s in batch]))
    assert load_scenarios(str(bare)) == batch


# ---------------------------------------------------------------------------
# Batch planner: stacked broadcast == per-scenario loop, exactly.
# ---------------------------------------------------------------------------
def test_batch_equals_per_scenario_loop_exactly():
    rng = np.random.default_rng(7)
    batch = []
    for name in ALL_DATAFLOWS:
        for K in rng.integers(64, 4096, size=3):
            batch.append(Scenario.tile(name, K=float(K)))
            batch.append(Scenario.tile(name, K=float(K),
                                       hardware={"B": float(rng.integers(100, 9999))}))
            batch.append(Scenario.full_graph(
                name, V=float(K * 4), E=float(K * 40), N=96.0, T=8.0,
                tile_vertices=float(K), widths=[96, 32, 8],
                residency="resident"))
    res = evaluate_scenarios(batch)
    assert len(res.results) == len(batch)
    for s, r in zip(batch, res.results):
        assert r.scenario is s
        lone = evaluate_scenario(s)
        assert r.total_bits == lone.total_bits
        assert r.total_iterations == lone.total_iterations
        assert r.breakdown == lone.breakdown
        assert r.iteration_breakdown == lone.iteration_breakdown
        assert r.n_tiles == lone.n_tiles


def test_one_broadcast_evaluation_per_dataflow_homogeneous():
    """The acceptance property: a batch of structurally-uniform scenarios
    costs at most one broadcast evaluation per distinct dataflow."""
    tb = template("comparison")
    res = evaluate_scenarios(tb.scenarios)
    assert res.n_evaluations == len(ALL_DATAFLOWS)
    assert set(res.evaluations_per_dataflow().values()) == {1}
    # ... and the full-graph composition template likewise.
    tb = template("cora_end_to_end")
    res = evaluate_scenarios(tb.scenarios)
    assert res.n_evaluations == len(ALL_DATAFLOWS)
    assert set(res.evaluations_per_dataflow().values()) == {1}


@pytest.mark.parametrize("name", sorted(template_names()))
def test_figure_templates_are_single_plan_groups(name):
    tb = template(name)
    res = evaluate_scenarios(tb.scenarios)
    assert len(res.results) == len(tb.scenarios)
    if any(s.optimize is not None for s in tb.scenarios):
        # Tune templates route through the §15 tuner: their broadcast
        # evaluations are recorded per-tune in meta["tune"]["n_groups"]
        # (capacity batches along the planner axis, so the group count is
        # the dataflow x residency x halo cross product, not per-capacity).
        for r in res.results:
            t = r.meta["tune"]
            space = r.scenario.optimize["space"]
            df = space.get("dataflow")
            n_df = len(ALL_DATAFLOWS) if df == "all" else len(df or [1])
            n_res = len(space.get("residency") or [1])
            n_hd = len(space.get("halo_dedup") or [1])
            assert t["n_groups"] <= n_df * n_res * n_hd
        return
    n_dataflows = len({s.dataflow for s in tb.scenarios})
    assert res.n_evaluations == n_dataflows


def test_comparison_template_matches_sec4_goldens():
    tb = template("comparison", K=np.array([1024.0]))
    res = evaluate_scenarios(tb.scenarios)
    for r in res.results:
        bits, iters = SEC4_GOLDEN_TOTALS[r.scenario.dataflow]
        assert r.total_bits == bits
        assert r.total_iterations == iters


def test_expect_pins_gate_golden_drift():
    good = Scenario.tile("engn", expect={
        "total_bits": SEC4_GOLDEN_TOTALS["engn"][0],
        "total_iterations": SEC4_GOLDEN_TOTALS["engn"][1]})
    bad = Scenario.tile("engn", expect={"total_bits": 123.0})
    res = evaluate_scenarios([good, bad])
    assert res.results[0].expect_ok is True
    assert res.results[1].expect_ok is False
    assert len(res.expect_failures()) == 1
    assert evaluate_scenario(Scenario.tile("engn")).expect_ok is None


# ---------------------------------------------------------------------------
# Scenario schema validation.
# ---------------------------------------------------------------------------
def test_scenario_schema_rejections():
    with pytest.raises(ValueError, match="tile_vertices"):
        Scenario(dataflow="engn",
                 graph={"V": 100, "E": 1000, "N": 30, "T": 5})
    with pytest.raises(ValueError, match="full-graph"):
        Scenario.tile("engn", composition={"tile_vertices": 256})
    with pytest.raises(ValueError, match="exactly"):
        Scenario(dataflow="engn", graph={"K": 1024})
    with pytest.raises(ValueError, match="unknown full-graph keys"):
        Scenario(dataflow="engn",
                 graph={"V": 1, "E": 1, "N": 1, "T": 1, "Z": 9},
                 composition={"tile_vertices": 64})
    with pytest.raises(ValueError, match="widths"):
        Composition(widths=[30])
    with pytest.raises(ValueError, match="residency"):
        Composition(widths=[30, 5], residency="sometimes")
    with pytest.raises(ValueError, match="empty Composition"):
        Composition()
    with pytest.raises(ValueError, match="halo_dedup"):
        Composition(tile_vertices=64, halo_dedup=0.5)
    with pytest.raises(ValueError, match="tile_vertices"):
        Composition(tile_vertices=0)
    with pytest.raises(TypeError, match="pure"):
        Scenario.tile("engn", K="1024")
    with pytest.raises(TypeError, match="pure"):
        Scenario.tile("engn", hardware={"B": np.array([1.0, 2.0])})
    with pytest.raises(ValueError, match="finite"):
        Scenario.tile("engn", P=float("inf"))
    with pytest.raises(ValueError, match="expect"):
        Scenario.tile("engn", expect={"offchip": 1.0})
    with pytest.raises(ValueError, match="unknown Scenario keys"):
        Scenario.from_dict({"dataflow": "engn", "graph": {}, "bogus": 1})


def test_unknown_hardware_override_is_rejected_with_fields():
    s = Scenario.tile("engn", hardware={"warp_size": 32.0})
    with pytest.raises(ValueError, match="warp_size"):
        evaluate_scenario(s)
    with pytest.raises(KeyError, match="registered"):
        evaluate_scenario(Scenario.tile("not_a_dataflow"))


# ---------------------------------------------------------------------------
# Workload bridges: §5 tile language end-to-end across all dataflows.
# ---------------------------------------------------------------------------
WORKLOADS = ("smollm-135m", "gemma2-2b", "equiformer-v2", "dlrm-mlperf")


@pytest.mark.parametrize("arch_name", WORKLOADS)
def test_workload_bridge_evaluates_across_all_dataflows(arch_name):
    configs = pytest.importorskip("repro.configs")
    arch = configs.get_arch(arch_name)
    scenarios = arch.to_scenarios()
    assert {s.dataflow for s in scenarios} == set(ALL_DATAFLOWS)
    res = evaluate_scenarios(scenarios)
    # one broadcast evaluation per dataflow: shapes batch within an arch.
    assert res.n_evaluations == len(ALL_DATAFLOWS)
    for r in res.results:
        assert np.isfinite(r.total_bits) and r.total_bits > 0
        assert np.isfinite(r.total_iterations) and r.total_iterations > 0
        assert r.scenario.workload.startswith(arch_name)


def test_workload_bridge_tile_language_mappings():
    configs = pytest.importorskip("repro.configs")
    # gemma2: the 4k sliding window bounds the banded-graph neighborhood.
    (s,) = configs.get_arch("gemma2-2b").to_scenarios(
        shapes=("prefill_32k",), dataflows=("engn",))
    assert s.graph["K"] == 32768.0
    assert s.graph["P"] == 32768.0 * 4096.0
    assert s.composition.widths == (2304.0,) * 27
    # smollm: full causal attention -> W = seq.
    (s,) = configs.get_arch("smollm-135m").to_scenarios(
        shapes=("train_4k",), dataflows=("engn",))
    assert s.graph["P"] == 4096.0 * 4096.0
    # equiformer: irreps flatten to (l_max+1)^2 * C.
    (s,) = configs.get_arch("equiformer-v2").to_scenarios(
        shapes=("ogb_products",), dataflows=("engn",))
    assert s.composition.widths[1] == (6 + 1) ** 2 * 128
    assert s.graph["V"] == 2449029.0 and s.graph["E"] == 61859140.0
    # dlrm: embedding gather as aggregation.
    (s,) = configs.get_arch("dlrm-mlperf").to_scenarios(
        shapes=("serve_p99",), dataflows=("engn",))
    assert s.graph["K"] == 512.0
    assert s.graph["P"] == 512.0 * 26
    assert s.graph["N"] == 128.0 and s.graph["T"] == 1.0


def test_trace_kind_joins_the_front_door():
    """The third graph kind (DESIGN.md §12) is a first-class scenario:
    structural plan keys, templates, and hashing all treat it like tile
    and full kinds (the deep battery lives in tests/test_trace.py)."""
    s = Scenario.trace("engn", dataset="ring_of_tiles",
                       params={"n_nodes": 64.0, "n_tiles": 2.0},
                       N=8.0, T=4.0, tile_vertices=32.0)
    assert s.graph_kind == "trace"
    assert s.plan_key() != Scenario.full_graph(
        "engn", V=64.0, E=128.0, N=8.0, T=4.0, tile_vertices=32.0).plan_key()
    assert {s, Scenario.from_json(s.to_json())} == {s}
    assert "cora_trace" in template_names()
    r = evaluate_scenario(s)
    assert r.n_tiles == 2.0 and "haloreload" in r.breakdown


# ---------------------------------------------------------------------------
# Satellite: registry scratch registration.
# ---------------------------------------------------------------------------
def test_registry_unregister_round_trip():
    spec = registry.unregister("awb_gcn")
    try:
        assert "awb_gcn" not in registry.names()
        with pytest.raises(KeyError, match="unregister unknown"):
            registry.unregister("awb_gcn")
    finally:
        registry.register(spec)
    assert "awb_gcn" in registry.names()


def test_temporarily_registered_scratch_spec():
    scratch = dataclasses.replace(registry.get("engn"), name="engn_scratch")
    before = registry.names()
    with registry.temporarily_registered(scratch):
        assert "engn_scratch" in registry.names()
        r = evaluate_scenario(Scenario.tile("engn_scratch"))
        assert r.total_bits == SEC4_GOLDEN_TOTALS["engn"][0]
    assert registry.names() == before

    # shadowing an existing name requires overwrite=True and restores it.
    shadow = dataclasses.replace(registry.get("hygcn"), name="engn")
    with pytest.raises(ValueError, match="already registered"):
        with registry.temporarily_registered(shadow):
            pass
    with registry.temporarily_registered(shadow, overwrite=True):
        assert registry.get("engn") is shadow
    assert registry.get("engn") is not shadow
    assert registry.names() == before

    # cleanup happens even when the body raises.
    with pytest.raises(RuntimeError):
        with registry.temporarily_registered(scratch):
            raise RuntimeError("boom")
    assert registry.names() == before

    # ... and when a LATER spec in the same call fails to register: specs
    # already added must roll back, not leak.
    colliding = dataclasses.replace(registry.get("hygcn"), name="engn")
    with pytest.raises(ValueError, match="already registered"):
        with registry.temporarily_registered(scratch, colliding):
            pass
    assert registry.names() == before

    # two temporaries sharing a name under overwrite restore the ORIGINAL
    # spec, not the first temporary.
    orig = registry.get("engn")
    t1 = dataclasses.replace(orig, name="engn", description="t1")
    t2 = dataclasses.replace(orig, name="engn", description="t2")
    with registry.temporarily_registered(t1, t2, overwrite=True):
        assert registry.get("engn") is t2
    assert registry.get("engn") is orig


def test_composition_round_trip_preserves_non_default_fields():
    """Every meaningful non-default field survives serialization: round
    trips are value-identical, so equal scenarios share one plan group."""
    s = Scenario.full_graph("engn", V=100.0, E=500.0, N=8.0, T=4.0,
                            widths=[8, 4], residency="resident",
                            halo_dedup=2.0)
    s2 = Scenario.from_json(s.to_json())
    assert s2 == s and s2.plan_key() == s.plan_key()
    assert s2.composition.residency == "resident"
    assert s2.composition.halo_dedup == 2.0
    res = evaluate_scenarios([s, s2])
    assert res.n_evaluations == 1


def test_composition_rejects_ineffective_knobs():
    """residency without widths / halo_dedup without tiling would be
    silently ignored (and would split plan groups): rejected instead."""
    with pytest.raises(ValueError, match="residency.*no\\s+effect"):
        Scenario.full_graph("engn", V=100.0, E=500.0, N=8.0, T=4.0,
                            residency="resident")
    with pytest.raises(ValueError, match="halo_dedup.*no\\s+effect"):
        Composition(widths=[64, 16], halo_dedup=4.0)


def test_sweep_accelerators_tolerates_duplicate_names():
    from repro.core.sweep import sweep_accelerators
    K = np.array([256.0, 1024.0])
    dup = sweep_accelerators(("engn", "engn", "hygcn"), K=K)
    ref = sweep_accelerators(("engn", "hygcn"), K=K)
    assert dup.accelerators == ("engn", "engn", "hygcn")
    assert dup.meta["n_evaluations"] == 2
    np.testing.assert_array_equal(dup.total_bits[0], dup.total_bits[1])
    np.testing.assert_array_equal(dup.total_bits[0], ref.total_bits[0])
    np.testing.assert_array_equal(dup.total_bits[2], ref.total_bits[1])


def test_trusted_template_scenarios_equal_validated_construction():
    """The templates' fast-path cells must be indistinguishable from
    publicly constructed scenarios (equality, hash, round trip)."""
    tb = template("fig3")
    s = tb.scenarios[0]
    public = Scenario(dataflow=s.dataflow, graph=dict(s.graph),
                      hardware=dict(s.hardware))
    assert s == public and hash(s) == hash(public)
    assert Scenario.from_json(s.to_json()) == s
    assert s.graph_kind == "tile" and s.plan_key() == public.plan_key()


def test_scenario_is_hashable_value_object():
    a, b = Scenario.tile("engn"), Scenario.tile("engn")
    c = Scenario.full_graph("engn", V=10, E=20, N=3, T=2, widths=[3, 2],
                            expect={"total_bits": 1.0})
    assert a == b and hash(a) == hash(b)
    assert {a, b, c} == {a, c}


# ---------------------------------------------------------------------------
# Satellite: compose-layer input validation.
# ---------------------------------------------------------------------------
def test_full_graph_params_validation():
    with pytest.raises(ValueError, match="non-negative"):
        FullGraphParams(V=-1, E=10, N=30, T=5)
    with pytest.raises(ValueError, match="non-negative"):
        FullGraphParams(V=10, E=np.array([5.0, -2.0]), N=30, T=5)
    with pytest.raises(ValueError, match="finite"):
        FullGraphParams(V=float("nan"), E=10, N=30, T=5)
    good = FullGraphParams(V=10, E=10, N=30, T=5)
    with pytest.raises(ValueError, match="non-negative"):
        good.replace(E=-5)
    assert good.replace(E=7).E == 7


def test_tiled_graph_model_tile_vertices_validation():
    for bad in (0, -4, 0.5, float("nan"), np.array([1024.0, 0.0])):
        with pytest.raises(ValueError, match="tile_vertices"):
            TiledGraphModel("engn", tile_vertices=bad)
    TiledGraphModel("engn", tile_vertices=1)  # boundary is legal


# ---------------------------------------------------------------------------
# CLI (the service-shaped front door).
# ---------------------------------------------------------------------------
def test_cli_comparison_batch(tmp_path, capsys):
    out = tmp_path / "BENCH_scenarios.json"
    # strip the conformance flag: kernel compilation is test_conformance's
    # job, and the CLI exercises the same planner path without it.
    scens = [s.replace(conformance=False)
             for s in load_scenarios("examples/scenarios/comparison.json")]
    batch_path = tmp_path / "comparison.json"
    dump_scenarios(scens, str(batch_path))
    rc = cli_main(["--scenario", str(batch_path), "--json", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert payload["status"] == "ok"
    assert payload["n_scenarios"] == len(scens)
    assert all(r.get("expect_ok", True) for r in payload["results"])
    assert "broadcast" in capsys.readouterr().out


def test_cli_exits_nonzero_on_golden_drift(tmp_path):
    drift = [Scenario.tile("engn", expect={"total_bits": 1.0})]
    path = tmp_path / "drift.json"
    dump_scenarios(drift, str(path))
    assert cli_main(["--scenario", str(path)]) == 1


def test_cli_usage_errors(tmp_path, capsys):
    assert cli_main([]) == 2
    # filters that only apply to --workload must not be silently dropped.
    assert cli_main(["--template", "fig6", "--dataflows", "engn"]) == 2
    assert cli_main(["--template", "fig6", "--shape", "train_4k"]) == 2
    bad = tmp_path / "bad.json"
    bad.write_text('{"scenarios": [{"dataflow": "engn"}]}')
    assert cli_main(["--scenario", str(bad)]) == 2
    assert cli_main(["--list"]) == 0
    capsys.readouterr()


def test_cli_template_and_workload_sources(tmp_path):
    out = tmp_path / "t.json"
    assert cli_main(["--template", "fig6", "--json", str(out)]) == 0
    assert json.loads(out.read_text())["n_evaluations"] == 1
    pytest.importorskip("repro.configs")
    assert cli_main(["--workload", "gcn-cora", "--shape", "molecule",
                     "--dataflows", "engn,awb_gcn", "--json", str(out)]) == 0
    payload = json.loads(out.read_text())
    assert payload["n_scenarios"] == 2
    assert payload["n_evaluations"] == 2
