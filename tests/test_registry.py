"""Registry + composition-layer tests (DESIGN.md §4/§7).

The load-bearing guarantees: (1) the declarative DataflowSpec engine
reproduces the seed EnGN/HyGCN implementations *bit-identically* — per-term
at the paper's Sec. IV defaults and as exact checksums across the Fig. 3-7
sweep grids; (2) the composition layer obeys its defining identities
(spill == L x single layer, tiled == n_tiles x per-tile + halo).
"""

import numpy as np
import pytest

from repro.core import (DataflowSpec, FullGraphParams, MultiLayerModel,
                        SpecModel, TiledGraphModel, paper_default_graph,
                        registry)
from repro.core.sweep import (fig3_engn_movement, fig4_hygcn_movement,
                              fig5_iterations_vs_bandwidth,
                              fig6_fitting_factor, fig7_systolic_reuse,
                              sweep_accelerators)
from repro.core.validation import (SEC4_GOLDEN_TOTALS, crosscheck_registry,
                                   validate_dataflow_golden)

# ---------------------------------------------------------------------------
# Golden values captured from the seed (pre-refactor) implementation at the
# paper's Sec. IV defaults: N=30, T=5, K=1024, L=102, P=10240, B=1000, s=4.
# Exact float64 equality is asserted — the refactor may not drift one bit.
# ---------------------------------------------------------------------------
SEED_GOLDEN_TERMS = {
    "engn": [
        ("loadvertcache", "L2*-L1", 12240.0, 1.0),
        ("loadvertL2", "L2-L1", 122880.0, 8.0),
        ("loadedges", "L2-L1", 41000.0, 41.0),
        ("loadweights", "L2-L1", 600.0, 1.0),
        ("aggregate", "L1-L1", 2600960.0, 8.0),
        ("writecache", "L1-L2*", 2040.0, 1.0),
        ("writeL2", "L1-L2", 20480.0, 8.0),
    ],
    "hygcn": [
        ("loadvertL2", "L2-L1", 122880.0, 32.0),
        ("loadedges", "L2-L1", 41000.0, 41.0),
        ("loadweights", "L2-L1", 300.0, 1.0),
        ("aggregate", "L1-L1", 1228800.0, 4800.0),
        ("writeinterphase", "L1-L2", 123000.0, 123.0),
        ("combine", "L1-L1", 123480.0, 1.0),
        ("readinterphase", "L2-L1", 1229000.0, 1229.0),
        ("writeL2", "L1-L2", 21000.0, 21.0),
    ],
}

# Exact float64 sums of total_bits / total_iterations over each figure's
# default sweep grid, captured from the seed implementation.
SEED_SWEEP_CHECKSUMS = {
    "fig3": (330498000.0, 194300.0),
    "fig4": (322443664.0, 1380406.0),
    "fig5a": (483692394.48517907, 106190.0),
    "fig5b": (501306728.39831495, 3823358.0),
    "fig6": (31311440.0, 12255.0),
    "fig7": (2153181014.0, 4681241.0),
}


@pytest.mark.parametrize("name", ["engn", "hygcn"])
def test_registry_bit_identical_to_seed_terms(name):
    out = registry.evaluate(name, paper_default_graph())
    got = [(t.name, t.hierarchy, float(t.data_bits), float(t.iterations))
           for t in out.terms]
    assert got == SEED_GOLDEN_TERMS[name]


@pytest.mark.parametrize("name", sorted(SEC4_GOLDEN_TOTALS))
def test_registry_matches_validation_golden(name):
    """All registered dataflows are regression-locked at Sec. IV defaults:
    engn/hygcn to the seed captures, the extension dataflows to their
    conformance-validated closed forms (DESIGN.md §10)."""
    total, iters = SEC4_GOLDEN_TOTALS[name]
    out = registry.evaluate(name, paper_default_graph())
    assert float(out.total_bits()) == total
    assert float(out.total_iterations()) == iters
    assert validate_dataflow_golden(name).ratio == 1.0


def test_golden_totals_cover_every_registered_dataflow():
    assert set(SEC4_GOLDEN_TOTALS) == set(registry.names())


@pytest.mark.parametrize("fig,fn", [
    ("fig3", fig3_engn_movement),
    ("fig4", fig4_hygcn_movement),
    ("fig5a", lambda: fig5_iterations_vs_bandwidth("engn")),
    ("fig5b", lambda: fig5_iterations_vs_bandwidth("hygcn")),
    ("fig6", fig6_fitting_factor),
    ("fig7", fig7_systolic_reuse),
])
def test_sweep_grids_bit_identical_to_seed(fig, fn):
    res = fn()
    shape = tuple(len(v) for v in res.axes.values())
    bits = float(np.broadcast_to(res.total_bits, shape).sum())
    iters = float(np.broadcast_to(res.total_iterations, shape).sum())
    assert (bits, iters) == SEED_SWEEP_CHECKSUMS[fig]


# ---------------------------------------------------------------------------
# Registry surface.
# ---------------------------------------------------------------------------
def test_registry_has_all_builtin_accelerators():
    for name in ("engn", "hygcn", "spmm_tiled", "spmm_unfused", "awb_gcn"):
        spec = registry.get(name)
        assert isinstance(spec, DataflowSpec)
        assert spec.name == name
        out = spec.evaluate(paper_default_graph())
        assert np.all(np.isfinite(out.total_bits()))
        assert float(out.total_bits()) > 0


def test_registry_unknown_name_lists_available():
    with pytest.raises(KeyError, match="engn"):
        registry.get("nonexistent")


def test_registry_rejects_duplicate_registration():
    with pytest.raises(ValueError, match="already registered"):
        registry.register(registry.get("engn"))


def test_registry_model_adapter():
    m = registry.model("awb_gcn")
    assert isinstance(m, SpecModel)
    out = m.evaluate(paper_default_graph())
    assert out.accelerator == "awb_gcn"


def test_crosscheck_registry_passes():
    records = crosscheck_registry()
    assert set(records) == set(registry.names())
    for name, rec in records.items():
        if rec is not None:
            assert rec.ratio == 1.0, (name, rec)


def test_spmm_tiled_block_sizes_match_kernel():
    """The analytical baseline must model the actual Pallas kernel's tiling."""
    jax = pytest.importorskip("jax")  # noqa: F841 - kernel module needs jax
    from repro.core.spmm_tiled import kernel_matched_hw
    from repro.kernels.edge_aggregate import DEFAULT_BLOCK_K, DEFAULT_BLOCK_N
    hw = kernel_matched_hw()
    assert hw.Bn == DEFAULT_BLOCK_N
    assert hw.Bk == DEFAULT_BLOCK_K
    default = registry.get("spmm_tiled").hw_factory()
    assert (default.Bn, default.Bk) == (DEFAULT_BLOCK_N, DEFAULT_BLOCK_K)


# ---------------------------------------------------------------------------
# Composition layer: multi-layer.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["engn", "hygcn", "spmm_tiled", "spmm_unfused", "awb_gcn"])
@pytest.mark.parametrize("n_layers", [1, 2, 4])
def test_multilayer_spill_equals_L_times_single_layer(name, n_layers):
    """Property: spill residency + equal widths == L x the single layer."""
    w = 30
    graph = paper_default_graph().replace(N=w, T=w)
    single = registry.evaluate(name, graph)
    ml = MultiLayerModel(name, [w] * (n_layers + 1), residency="spill")
    out = ml.evaluate(graph)
    assert float(out.total_bits()) == n_layers * float(single.total_bits())
    assert float(out.total_iterations()) == n_layers * float(single.total_iterations())
    # per-term too: the spill sum keeps each movement level identifiable.
    for t in single.terms:
        assert float(out[t.name].data_bits) == n_layers * float(t.data_bits)


@pytest.mark.parametrize("name", ["engn", "hygcn", "spmm_tiled", "spmm_unfused", "awb_gcn"])
def test_multilayer_resident_saves_offchip(name):
    graph = paper_default_graph().replace(T=30)
    widths = [30, 30, 30]
    spill = MultiLayerModel(name, widths, residency="spill").evaluate(graph)
    resident = MultiLayerModel(name, widths, residency="resident").evaluate(graph)
    offchip_saved = float(spill.offchip_bits() + spill.cache_bits()
                          - resident.offchip_bits() - resident.cache_bits())
    assert offchip_saved > 0
    assert float(resident["residenthandoff"].data_bits) > 0
    assert resident["residenthandoff"].hierarchy == "L1-L1"


def test_multilayer_width_propagation():
    """Layer l must see N=widths[l], T=widths[l+1]: an asymmetric chain
    differs from any single-layer multiple."""
    ml = MultiLayerModel("hygcn", [64, 16, 4])
    graph = paper_default_graph()
    out = ml.evaluate(graph)
    l0 = registry.evaluate("hygcn", graph.replace(N=64, T=16))
    l1 = registry.evaluate("hygcn", graph.replace(N=16, T=4))
    assert float(out.total_bits()) == float(l0.total_bits()) + float(l1.total_bits())


def test_multilayer_rejects_bad_args():
    with pytest.raises(ValueError, match="widths"):
        MultiLayerModel("engn", [30])
    with pytest.raises(ValueError, match="residency"):
        MultiLayerModel("engn", [30, 5], residency="sometimes")


# ---------------------------------------------------------------------------
# Composition layer: tiled full graph.
# ---------------------------------------------------------------------------
def test_tiled_graph_is_ntiles_times_tile_plus_halo():
    full = FullGraphParams(V=4096, E=40960, N=30, T=5)
    model = TiledGraphModel("engn", tile_vertices=1024)
    out = model.evaluate(full)
    n_tiles, tile = model.tile_schedule(full)
    assert float(n_tiles) == 4.0
    per_tile = registry.evaluate("engn", tile)
    for t in per_tile.terms:
        assert float(out[t.name].data_bits) == 4.0 * float(t.data_bits)
    halo = out["haloreload"]
    assert halo.hierarchy == "L2-L1"
    # E * (1 - 1/4) cut edges, N elements, sigma=4 bits each.
    assert float(halo.data_bits) == 40960 * 0.75 * 30 * 4


def test_tiled_graph_single_tile_has_no_halo():
    full = FullGraphParams(V=512, E=5120, N=30, T=5)
    out = TiledGraphModel("hygcn", tile_vertices=1024).evaluate(full)
    assert float(out["haloreload"].data_bits) == 0.0


def test_tiled_multilayer_composition_vectorized():
    """Cora end-to-end, every registered accelerator, one vectorized call
    per dataflow across a tile-capacity grid."""
    caps = np.array([256.0, 512.0, 1024.0, 2048.0])
    cora = FullGraphParams(V=2708, E=10556, N=1433, T=7)
    totals = {}
    for name in registry.names():
        model = TiledGraphModel(MultiLayerModel(name, [1433, 16, 7]),
                                tile_vertices=caps)
        out = model.evaluate(cora)
        arr = np.broadcast_to(out.total_bits(), caps.shape)
        assert np.all(np.isfinite(arr)) and np.all(arr > 0)
        # halo width covers both layer inputs: 1433 + 16 elements.
        halo = np.broadcast_to(out["haloreload"].data_bits, caps.shape)
        n_tiles = np.broadcast_to(out.meta["n_tiles"], caps.shape)
        expect = 10556 * (1.0 - 1.0 / n_tiles) * (1433 + 16) * 4
        np.testing.assert_allclose(halo, expect, rtol=0, atol=0)
        totals[name] = arr
    assert len(totals) >= 4


def test_tiled_graph_halo_dedup_divides():
    full = FullGraphParams(V=4096, E=40960, N=30, T=5)
    plain = TiledGraphModel("engn", tile_vertices=1024).evaluate(full)
    dedup = TiledGraphModel("engn", tile_vertices=1024, halo_dedup=2.0).evaluate(full)
    assert float(dedup["haloreload"].data_bits) == 0.5 * float(plain["haloreload"].data_bits)
    with pytest.raises(ValueError, match="halo_dedup"):
        TiledGraphModel("engn", halo_dedup=0.5)


# ---------------------------------------------------------------------------
# Vectorized all-accelerator sweep.
# ---------------------------------------------------------------------------
def test_sweep_accelerators_stacks_all_registered():
    sw = sweep_accelerators()
    A = len(registry.names())
    assert sw.accelerators == registry.names()
    assert A >= 4
    K = sw.axes["K"]
    assert sw.total_bits.shape == (A, len(K))
    assert sw.total_iterations.shape == (A, len(K))
    for cls in ("offchip", "cache", "onchip"):
        assert sw.class_bits[cls].shape == (A, len(K))
    # engn/hygcn rows agree with direct evaluation, bit for bit.
    for name in ("engn", "hygcn"):
        a = sw.accelerator_index(name)
        direct = registry.evaluate(name, paper_default_graph(K))
        np.testing.assert_array_equal(sw.total_bits[a], direct.total_bits())


def test_sweep_accelerators_rows_flatten():
    sw = sweep_accelerators(("engn", "hygcn"), K=np.array([256.0, 1024.0]))
    rows = sw.rows()
    assert len(rows) == 4
    assert {r["accelerator"] for r in rows} == {"engn", "hygcn"}
    for r in rows:
        assert set(r) == {"accelerator", "K", "total_bits", "total_iterations",
                          "bits_offchip", "bits_cache", "bits_onchip"}
        assert isinstance(r["total_bits"], float)


def test_sweep_rows_np_stack_flatten_matches_meshgrid_reference():
    """rows() must reproduce the former per-record meshgrid loop exactly."""
    res = fig3_engn_movement()
    names = list(res.axes)
    grids = np.meshgrid(*[res.axes[n] for n in names], indexing="ij")
    expected = []
    total_b = np.broadcast_to(res.total_bits, grids[0].shape)
    total_i = np.broadcast_to(res.total_iterations, grids[0].shape)
    for idx in np.ndindex(grids[0].shape):
        rec = {n: float(g[idx]) for n, g in zip(names, grids)}
        rec["total_bits"] = float(total_b[idx])
        rec["total_iterations"] = float(total_i[idx])
        for term, arr in res.data_bits.items():
            rec[f"bits_{term}"] = float(np.broadcast_to(arr, grids[0].shape)[idx])
        expected.append(rec)
    assert res.rows() == expected
