"""Sharded streaming trace pipeline battery (DESIGN.md §14, PR 6).

Load-bearing guarantees:

* **Distributed drift gate** — the sharded pipeline (per-shard block
  generation, local composite-key sorts, range-bucketed exchange,
  per-bucket factorization) produces a unique-pair factorization
  **bit-identical** (values, order, dtypes) to the single-host
  ``GraphTrace._pair_factorization`` for every shard count, and
  ``engine="sharded"`` schedules bit-identical to the amortized engine
  and the PR-4 ``schedule_reference`` oracle;
* **Chunk-size / shard-count invariance** — the streamed edge list is a
  pure function of ``(seed, n_nodes, n_edges, alpha)``: any
  ``chunk_edges`` granularity and any round-robin shard split
  reassembles to the identical edge list (the PR-6 satellite
  regression);
* **Factorization-only traces** — ``GraphTrace.from_factorization``
  round-trips CSR row pointers, lazy CSR columns, degrees, and
  schedules without a materialized edge list, and the PR-4 oracle
  refuses them loudly;
* **mmap-lazy warm resolves** — a warm ``resolve_trace_dataset`` memory
  -maps the stored arrays instead of inflating an npz, and the sharded
  dataset rides the same disk cache;
* **Planner transparency** — ``power_law_sharded`` is a drop-in dataset
  for the scenario front door with bit-equal totals to
  ``power_law_stream``.
"""

import numpy as np
import pytest

from repro.api import Scenario, evaluate_scenario
from repro.core.trace import (GraphTrace, clear_trace_cache,
                              resolve_trace_dataset)
from repro.data import synthetic
from repro.distributed import trace_shard

COUNT_FIELDS = ("vertex_counts", "edge_counts", "halo_counts",
                "remote_edge_counts")

#: Spans 3 generation blocks with a ragged tail, small enough for CI.
V, E, SEED, ALPHA = 3000, 2 * synthetic.POWER_LAW_STREAM_CHUNK + 12345, 11, 1.5


@pytest.fixture(autouse=True)
def _no_disk_cache(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", "0")
    yield


@pytest.fixture(scope="module")
def single_host():
    snd, rcv = synthetic.power_law_edges(SEED, n_nodes=V, n_edges=E,
                                         alpha=ALPHA)
    return GraphTrace(snd, rcv, V)


# ---------------------------------------------------------------------------
# Generator: chunk-size and shard-count invariance (satellite regression).
# ---------------------------------------------------------------------------
def test_edge_stream_chunk_size_invariance():
    base = list(synthetic.power_law_edge_stream(SEED, n_nodes=V, n_edges=E,
                                                alpha=ALPHA))
    snd0 = np.concatenate([p[0] for p in base])
    rcv0 = np.concatenate([p[1] for p in base])
    assert snd0.size == E
    for chunk in (1000, 4096, 99_999, E, 10 * E):
        parts = list(synthetic.power_law_edge_stream(
            SEED, n_nodes=V, n_edges=E, alpha=ALPHA, chunk_edges=chunk))
        assert all(p[0].size == chunk for p in parts[:-1])
        np.testing.assert_array_equal(
            np.concatenate([p[0] for p in parts]), snd0)
        np.testing.assert_array_equal(
            np.concatenate([p[1] for p in parts]), rcv0)


def test_edge_stream_shard_union_is_the_single_stream():
    snd0, rcv0 = synthetic.power_law_edges(SEED, n_nodes=V, n_edges=E,
                                           alpha=ALPHA)
    n_blocks = synthetic.power_law_stream_blocks(E)
    assert n_blocks == 3
    for n_shards in (1, 2, 3, 8):
        # Round-robin block ownership: interleaving the shard streams
        # back in block order must reproduce the single-shard stream.
        B = synthetic.POWER_LAW_STREAM_CHUNK
        got_snd = np.empty_like(snd0)
        got_rcv = np.empty_like(rcv0)
        total = 0
        for shard in range(n_shards):
            s, r = synthetic.power_law_edges(
                SEED, n_nodes=V, n_edges=E, alpha=ALPHA,
                shard=shard, n_shards=n_shards)
            at = 0
            for b in range(shard, n_blocks, n_shards):
                m = min(B, E - b * B)
                got_snd[b * B:b * B + m] = s[at:at + m]
                got_rcv[b * B:b * B + m] = r[at:at + m]
                at += m
            assert at == s.size
            total += s.size
        assert total == E
        np.testing.assert_array_equal(got_snd, snd0)
        np.testing.assert_array_equal(got_rcv, rcv0)
    with pytest.raises(ValueError, match="shard"):
        list(synthetic.power_law_edge_stream(SEED, n_nodes=V, n_edges=E,
                                             shard=2, n_shards=2))


# ---------------------------------------------------------------------------
# Tentpole: sharded factorization == single-host, bit for bit.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_shards", [1, 2, 3, 8])
def test_sharded_factorization_bitmatches_single_host(single_host, n_shards):
    u_snd, u_rcv, _, mp = single_host._pair_factorization()
    fact = trace_shard.sharded_power_law_factorization(
        n_nodes=V, n_edges=E, seed=SEED, alpha=ALPHA, n_shards=n_shards)
    assert trace_shard.factorization_drift(fact, (u_snd, u_rcv, mp)) == []


def test_factorization_drift_reports_mismatches():
    a = (np.array([1, 2], np.int32), np.array([3, 4], np.int32),
         np.array([0, 1, 2], np.int64))
    same = trace_shard.factorization_drift(a, a)
    assert same == []
    b = (a[0].astype(np.int64), a[1][:1], np.array([0, 1, 5], np.int64))
    errs = trace_shard.factorization_drift(a, b)
    assert len(errs) == 3
    assert any("dtype" in e for e in errs)
    assert any("shape" in e for e in errs)
    assert any("mismatch at index 2" in e for e in errs)


def test_sharded_build_stats_and_shard_cap():
    stats = {}
    trace = trace_shard.build_power_law_trace(
        n_nodes=V, n_edges=E, seed=SEED, alpha=ALPHA, n_shards=64,
        stats=stats)
    # Generation parallelism is bounded by the number of stream blocks
    # (a shard without blocks would just idle), but the exchange still
    # buckets into the full requested shard count.
    assert stats["n_shards"] == 64
    assert stats["n_generation_shards"] == \
        synthetic.power_law_stream_blocks(E) == 3
    assert len(stats["bucket_unique"]) <= 64
    assert sum(stats["shard_edges"]) == E
    assert sum(stats["bucket_unique"]) == stats["n_unique_pairs"]
    assert trace.n_edges == E and not trace.has_edge_list
    for key in ("t_generate_sort_s", "t_exchange_factorize_s", "t_csr_s",
                "rss_generate_sort_kb", "rss_csr_kb"):
        assert key in stats


# ---------------------------------------------------------------------------
# Factorization-only traces: CSR, degrees, schedules, oracle refusal.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def sharded_trace():
    return trace_shard.build_power_law_trace(n_nodes=V, n_edges=E,
                                             seed=SEED, alpha=ALPHA,
                                             n_shards=3)


def test_factorized_trace_matches_edge_list_trace(single_host, sharded_trace):
    assert sharded_trace.n_edges == single_host.n_edges == E
    np.testing.assert_array_equal(sharded_trace.row_ptr,
                                  single_host.row_ptr)
    np.testing.assert_array_equal(sharded_trace.csr_senders,
                                  single_host.csr_senders)
    np.testing.assert_array_equal(sharded_trace.in_degrees(),
                                  single_host.in_degrees())
    np.testing.assert_array_equal(sharded_trace.out_degrees(),
                                  single_host.out_degrees())


@pytest.mark.parametrize("engine", ["numpy", "jax", "sharded"])
def test_factorized_trace_schedules_all_engines(single_host, sharded_trace,
                                                engine):
    caps = [97, 500, 1500, V]
    scheds = sharded_trace.schedules(caps, engine=engine)
    sharded_trace.clear_schedules()  # engines must not serve each other
    for cap, sched in zip(caps, scheds):
        ref = single_host.schedule_reference(cap)
        for f in COUNT_FIELDS:
            np.testing.assert_array_equal(
                getattr(sched, f), getattr(ref, f),
                err_msg=f"engine={engine} cap={cap} field={f}")
        np.testing.assert_array_equal(sched.cache_hit_fraction(0.1),
                                      ref.cache_hit_fraction(0.1))


def test_schedule_reference_refuses_factorization_only(sharded_trace):
    with pytest.raises(RuntimeError, match="materialized edge list"):
        sharded_trace.schedule_reference(500)


def test_from_factorization_validates_shapes():
    with pytest.raises(ValueError, match="mult_prefix"):
        GraphTrace.from_factorization(4, [0, 1], [1, 2], [0, 1])  # U+1 != 3
    with pytest.raises(ValueError, match="n_nodes"):
        GraphTrace.from_factorization(0, [], [], [0])
    empty = GraphTrace.from_factorization(5, [], [], [0])
    assert empty.n_edges == 0
    sched = empty.schedule(2)
    assert sched.halo_total == 0


def test_sharded_schedule_counts_chunking_is_invariant(single_host):
    fact = single_host._pair_factorization()
    ref_h, ref_r = trace_shard.sharded_schedule_counts(fact, 500, 6,
                                                       n_shards=1)
    for n_shards in (2, 3, 16, 10_000):
        h, r = trace_shard.sharded_schedule_counts(fact, 500, 6,
                                                   n_shards=n_shards)
        np.testing.assert_array_equal(h, ref_h)
        np.testing.assert_array_equal(r, ref_r)


def test_default_shard_count_env(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_SHARDS", "5")
    assert trace_shard.default_shard_count() == 5
    monkeypatch.setenv("REPRO_TRACE_SHARDS", "zero")
    with pytest.raises(ValueError, match="REPRO_TRACE_SHARDS"):
        trace_shard.default_shard_count()
    monkeypatch.setenv("REPRO_TRACE_SHARDS", "0")
    with pytest.raises(ValueError, match="REPRO_TRACE_SHARDS"):
        trace_shard.default_shard_count()
    monkeypatch.delenv("REPRO_TRACE_SHARDS")
    assert trace_shard.default_shard_count() >= 1


def test_oversized_vertex_space_refused():
    with pytest.raises(NotImplementedError, match="int64"):
        trace_shard.sharded_power_law_factorization(
            n_nodes=trace_shard.MAX_KEY_NODES + 1, n_edges=10)


# ---------------------------------------------------------------------------
# Registry + planner transparency + disk cache.
# ---------------------------------------------------------------------------
def test_sharded_dataset_is_planner_transparent():
    params = {"n_nodes": 900.0, "n_edges": 6000.0, "seed": 2.0,
              "alpha": 1.4}
    res_sharded = evaluate_scenario(Scenario.trace(
        "engn", dataset="power_law_sharded", params=params,
        N=30.0, T=5.0, tile_vertices=300.0))
    res_stream = evaluate_scenario(Scenario.trace(
        "engn", dataset="power_law_stream", params=params,
        N=30.0, T=5.0, tile_vertices=300.0))
    assert res_sharded.total_bits == res_stream.total_bits
    assert res_sharded.breakdown == res_stream.breakdown
    assert res_sharded.n_tiles == res_stream.n_tiles
    # provenance: the result records that an edge-list-free trace backed it
    assert res_sharded.meta["trace"] == {
        "dataset": "power_law_sharded", "n_nodes": 900, "n_edges": 6000,
        "edge_list_free": True}
    assert res_stream.meta["trace"]["edge_list_free"] is False


def test_sharded_dataset_disk_cache_round_trip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", str(tmp_path))
    monkeypatch.setenv("REPRO_TRACE_CACHE_MIN_EDGES", "0")
    params = {"n_nodes": 800, "n_edges": 5000, "seed": 4, "alpha": 1.4}
    clear_trace_cache()
    t1 = resolve_trace_dataset("power_law_sharded", params)
    s1 = t1.schedule(200)
    assert len(list(tmp_path.rglob("*.graph"))) == 1
    # factorization-only payload: no edge-list parts on disk
    assert not list(tmp_path.rglob("*.graph/senders.npy"))
    assert list(tmp_path.rglob("*.graph/fact_u_snd.npy"))
    clear_trace_cache()
    t2 = resolve_trace_dataset("power_law_sharded", params)
    assert t2 is not t1
    # lazy warm resolve: the factorization finish is deferred and the
    # stored arrays are memory-mapped views
    assert t2._fact is None and t2._fact_source is not None
    assert isinstance(t2.row_ptr, np.memmap)
    assert t2.n_edges == 5000 and not t2.has_edge_list
    s2 = t2.schedule(321)
    ref = resolve_trace_dataset(
        "power_law_stream", params).schedule_reference(321)
    for f in COUNT_FIELDS:
        np.testing.assert_array_equal(getattr(s2, f), getattr(ref, f))
    # the schedule stored by t1 round-trips too
    s3 = t2.schedule(200)
    for f in COUNT_FIELDS:
        np.testing.assert_array_equal(getattr(s3, f), getattr(s1, f))
    clear_trace_cache()


def test_warm_resolve_is_mmap_lazy_for_stream_dataset(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", str(tmp_path))
    monkeypatch.setenv("REPRO_TRACE_CACHE_MIN_EDGES", "0")
    params = {"n_nodes": 600, "n_edges": 4000, "seed": 8}
    clear_trace_cache()
    t1 = resolve_trace_dataset("power_law_stream", params)
    ref = t1.schedule(150)
    clear_trace_cache()
    t2 = resolve_trace_dataset("power_law_stream", params)
    for name in ("senders", "receivers", "row_ptr"):
        assert isinstance(getattr(t2, name), np.memmap), name
    # edge list present -> the oracle still runs on the warm trace
    got = t2.schedule_reference(150)
    for f in COUNT_FIELDS:
        np.testing.assert_array_equal(getattr(got, f), getattr(ref, f))
    clear_trace_cache()
