"""Typed-graph + sampled-minibatch battery (DESIGN.md §17).

Load-bearing guarantees:

* **Relational bit-identity** — on the block-diagonal ``typed_blocks``
  fixture, :class:`~repro.core.compose.RelationalGraphModel` terms are
  **bit-identical** (``np.array_equal``, never ``isclose``) to an R-loop
  of homogeneous per-relation evaluations pairwise-combined along the
  relation axis, for every registered dataflow x {single-layer, spill,
  resident, per-relation widths, mixed per-relation residency};
* **Typed schedule drift gate** — per-relation schedules carved from the
  ONE shared typed factorization bit-match R independently constructed
  single-relation ``GraphTrace`` builds, on both the single-host and
  sharded engines;
* **Minibatch oracle** — episode halo / gather counts from the
  mark-array fast path match the independent ``np.unique``-family oracle
  on a >= 1e5-edge graph;
* **Planner grouping** — an R-relation scenario batch evaluates in
  exactly ONE broadcast group per (dataflow, residency), regardless of R;
* **Tuner** — the per-relation residency search equals a brute-force
  cross-product replayed through the front door;
* **Closed-form parity** — the auditable ``COMPOSITION_FORMS`` restate
  exactly (integer-exact, order-free sums below 2^53) what the array
  path charges for halo reload, resident hand-off, and episode gather;
* **Sampler satellites** — the vectorized subgraph sampler is
  bit-identical to the retained per-pick reference under a fixed rng,
  ``build_csr`` rejects the int32 boundary, and ``SampledSubgraph``
  invariants hold (exact {0,1} masks, seeds contained in nodes,
  bijective local-id remap).
"""

import itertools

import numpy as np
import pytest

from repro.api.planner import evaluate_scenarios
from repro.api.scenario import Composition, Scenario
from repro.core import registry
from repro.core.compose import (COMPOSITION_FORMS, FullGraphParams,
                                MultiLayerModel, RelationalGraphModel,
                                TiledGraphModel, _pairwise_sum)
from repro.core.notation import (CompositionHardwareParams,
                                 RelationalScheduleParams)
from repro.core.trace import (GraphTrace, TypedGraphTrace,
                              resolve_trace_dataset)
from repro.data import sampler as sampler_mod
from repro.data.sampler import (build_csr, csr_from_trace,
                                minibatch_oracle_counts, minibatch_schedule,
                                sample_subgraph)

TYPED_PARAMS = {"n_relations": 3, "n_nodes": 200, "n_edges": 900, "seed": 1}
CAPS = (64.0, 100.0, 17.0)
MB_PARAMS = {"n_nodes": 2000, "n_edges": 16000, "seed": 1}
MB_KW = dict(batch_nodes=64, fanout=(10, 5), n_batches=8, seed=0)


@pytest.fixture(scope="module")
def typed_blocks() -> TypedGraphTrace:
    tr = resolve_trace_dataset("typed_blocks", TYPED_PARAMS)
    assert isinstance(tr, TypedGraphTrace)
    return tr


def _terms_by_key(output):
    return {(t.name, t.hierarchy): (np.asarray(t.data_bits, np.float64),
                                    np.asarray(t.iterations, np.float64))
            for t in output.terms}


def _rloop_combined(tr, make_inner, N=30.0, T=5.0):
    """R-loop of homogeneous per-relation evaluations, combined exactly
    the way the relational model reduces its relation axis (pairwise)."""
    outs = []
    for r in range(tr.n_relations):
        rel = tr.relation(r)
        full_r = FullGraphParams(V=tr.n_nodes, E=rel.n_edges, N=N, T=T)
        m = TiledGraphModel(make_inner(r), tile_vertices=CAPS, trace=rel)
        outs.append(_terms_by_key(m.evaluate(full_r)))
    keys = list(dict.fromkeys(k for o in outs for k in o))
    zeros = np.zeros(len(CAPS))
    combined = {}
    for k in keys:
        cols = [o.get(k, (zeros, zeros)) for o in outs]
        combined[k] = (_pairwise_sum(np.stack([c[0] for c in cols], axis=-1)),
                       _pairwise_sum(np.stack([c[1] for c in cols], axis=-1)))
    return combined


def _assert_bit_identical(rel_model, combined, full):
    got = _terms_by_key(rel_model.evaluate(full))
    zeros = np.zeros(len(CAPS))
    for k in dict.fromkeys(list(combined) + list(got)):
        gb, gi = got.get(k, (zeros, zeros))
        cb, ci = combined.get(k, (zeros, zeros))
        assert np.array_equal(gb, cb), (k, gb, cb)
        assert np.array_equal(gi, ci), (k, gi, ci)


# ---------------------------------------------------------------------------
# Relational model bit-identity (the tentpole acceptance gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dataflow", registry.names())
def test_relational_model_bit_matches_r_loop(typed_blocks, dataflow):
    tr = typed_blocks
    full = FullGraphParams(V=tr.n_nodes, E=tr.n_edges, N=30.0, T=5.0)
    widths = (30.0, 16.0, 5.0)
    cases = [
        (dict(), lambda r: dataflow),
        (dict(widths=widths),
         lambda r: MultiLayerModel(dataflow, widths)),
        (dict(widths=widths, residency="resident"),
         lambda r: MultiLayerModel(dataflow, widths, residency="resident")),
    ]
    for kw, make_inner in cases:
        m = RelationalGraphModel(dataflow, tile_vertices=CAPS,
                                 trace=tr, **kw)
        _assert_bit_identical(m, _rloop_combined(tr, make_inner), full)


@pytest.mark.parametrize("dataflow", ("engn", "hygcn"))
def test_relational_model_per_relation_widths_and_residency(typed_blocks,
                                                            dataflow):
    tr = typed_blocks
    full = FullGraphParams(V=tr.n_nodes, E=tr.n_edges, N=30.0, T=5.0)
    w0 = np.array([30.0, 20.0, 10.0])
    w1 = np.array([16.0, 8.0, 12.0])
    w2 = np.array([5.0, 5.0, 5.0])
    m = RelationalGraphModel(dataflow, tile_vertices=CAPS, trace=tr,
                             widths=(w0, w1, w2))
    _assert_bit_identical(
        m, _rloop_combined(tr, lambda r: MultiLayerModel(
            dataflow, (w0[r], w1[r], w2[r]))), full)
    res = ("resident", "spill", "resident")
    m = RelationalGraphModel(dataflow, tile_vertices=CAPS, trace=tr,
                             widths=(30.0, 16.0, 5.0), residency=res)
    _assert_bit_identical(
        m, _rloop_combined(tr, lambda r: MultiLayerModel(
            dataflow, (30.0, 16.0, 5.0), residency=res[r])), full)


def test_relational_model_scalar_capacity_keeps_batch_axis(typed_blocks):
    m = RelationalGraphModel("engn", tile_vertices=64.0, trace=typed_blocks)
    full = FullGraphParams(V=typed_blocks.n_nodes, E=typed_blocks.n_edges,
                           N=30.0, T=5.0)
    out = m.evaluate(full)
    assert np.asarray(out.terms[0].data_bits).shape == (1,)


# ---------------------------------------------------------------------------
# Typed factorization / schedule drift gates
# ---------------------------------------------------------------------------

def test_typed_schedules_bit_match_independent_traces():
    tr = resolve_trace_dataset("typed_power_law", TYPED_PARAMS)
    for r in range(tr.n_relations):
        mask = tr.rels == r
        solo = GraphTrace(tr.senders[mask], tr.receivers[mask], tr.n_nodes)
        rel = tr.relation(r)
        assert rel.n_edges == solo.n_edges
        for cap in (64, 37):
            a = rel.schedule(cap)
            b = solo.schedule(cap)
            for f in ("vertex_counts", "edge_counts", "halo_counts",
                      "remote_edge_counts"):
                assert np.array_equal(getattr(a, f), getattr(b, f)), \
                    (r, cap, f)


def test_typed_sharded_counts_bit_match_single_host(typed_blocks):
    from repro.distributed.trace_shard import typed_sharded_schedule_counts

    tr = typed_blocks
    cap = 64
    n_tiles, K = tr.relation(0)._geometry(cap)
    for n_shards in (1, 3, 7):
        halo, remote = typed_sharded_schedule_counts(tr, K, n_tiles,
                                                     n_shards=n_shards)
        assert halo.shape == (tr.n_relations, n_tiles)
        for r in range(tr.n_relations):
            s = tr.relation(r).schedule(cap)
            assert np.array_equal(halo[r], s.halo_counts.astype(np.int64))
            assert np.array_equal(remote[r],
                                  s.remote_edge_counts.astype(np.int64))


def test_relation_edge_counts_partition_the_edge_list(typed_blocks):
    counts = typed_blocks.relation_edge_counts()
    assert counts.shape == (typed_blocks.n_relations,)
    assert int(counts.sum()) == typed_blocks.n_edges
    assert np.array_equal(counts, np.bincount(
        typed_blocks.rels, minlength=typed_blocks.n_relations))


# ---------------------------------------------------------------------------
# Minibatch episodes: np.unique oracle at acceptance scale
# ---------------------------------------------------------------------------

def test_minibatch_counts_match_unique_oracle_100k_edges():
    g = csr_from_trace(resolve_trace_dataset(
        "power_law", {"n_nodes": 20000, "n_edges": 120000, "seed": 5}))
    kw = dict(batch_nodes=256, fanout=(10, 5), n_batches=6, seed=2)
    assert int(g.ptr[-1]) >= 1e5  # the sampled graph is acceptance-scale
    sched = minibatch_schedule(g, **kw)
    oracle = minibatch_oracle_counts(g, **kw)
    assert sched.n_tiles == kw["n_batches"]
    for f in ("edge_counts", "halo_counts", "remote_edge_counts"):
        assert np.array_equal(getattr(sched, f), oracle[f]), f
    assert np.all(sched.halo_counts <= sched.remote_edge_counts)
    assert np.all(sched.vertex_counts == kw["batch_nodes"])
    # Cached per graph instance: one sampling pass per parameter key.
    assert minibatch_schedule(g, **kw) is sched


def test_minibatch_scenario_charges_episode_schedule():
    s = Scenario.minibatch("engn", dataset="power_law", params=MB_PARAMS,
                           N=30.0, T=16.0, **MB_KW)
    r = evaluate_scenarios([s]).results[0]
    g = csr_from_trace(resolve_trace_dataset("power_law", MB_PARAMS))
    sched = minibatch_schedule(g, **MB_KW)
    assert r.meta["minibatch"]["sampled_edges"] == sched.n_edges
    assert r.meta["minibatch"]["gathered_sources"] == sched.halo_total
    assert np.isfinite(r.total_bits) and r.total_bits > 0


# ---------------------------------------------------------------------------
# Planner grouping + scenario round trips
# ---------------------------------------------------------------------------

def _hetero_scenario(df, tv, *, residency="spill", **kw):
    return Scenario.hetero(
        df, dataset="typed_blocks",
        params={k: v for k, v in TYPED_PARAMS.items()
                if k != "n_relations"},
        n_relations=TYPED_PARAMS["n_relations"],
        N=[30.0, 20.0, 10.0], T=16.0, tile_vertices=tv,
        widths=[[30.0, 20.0, 10.0], 16.0, 5.0], residency=residency, **kw)


def test_hetero_batch_one_group_per_dataflow_residency():
    scen = [_hetero_scenario(df, tv, residency=res)
            for df in ("engn", "hygcn")
            for res in ("spill", ["resident", "spill", "resident"])
            for tv in (64, 128)]
    res = evaluate_scenarios(scen)
    # 2 dataflows x 2 residency structures -> 4 broadcast evaluations for
    # 8 scenarios; the capacity axis batches inside each group, and R
    # never splits a group.
    assert res.n_evaluations == 4
    for g in res.groups:
        assert len(g.indices) == 2
    for r in res.results:
        assert np.isfinite(r.total_bits) and r.total_bits > 0
        assert r.meta["trace"]["n_relations"] == 3


def test_hetero_group_matches_lone_evaluations():
    scen = [_hetero_scenario("engn", tv) for tv in (64, 128, 17)]
    batched = evaluate_scenarios(scen).results
    for s, br in zip(scen, batched):
        lone = evaluate_scenarios([s]).results[0]
        assert lone.total_bits == br.total_bits
        assert lone.total_iterations == br.total_iterations


def test_hetero_and_minibatch_round_trip():
    h = _hetero_scenario("hygcn", 64,
                         residency=["resident", "spill", "resident"])
    m = Scenario.minibatch("engn", dataset="power_law", params=MB_PARAMS,
                           N=30.0, T=16.0, **MB_KW)
    for s in (h, m):
        s2 = Scenario.from_dict(s.to_dict())
        assert s2 == s
        assert s2.plan_key() == s.plan_key()


def test_hetero_and_minibatch_validation_rejections():
    with pytest.raises(ValueError, match="per-relation"):
        Scenario.hetero("engn", dataset="typed_blocks", params={},
                        n_relations=3, N=[1.0, 2.0], T=1.0,
                        tile_vertices=64, widths=[4.0, 4.0])
    with pytest.raises(ValueError, match="n_relations=3"):
        Scenario.hetero("engn", dataset="typed_blocks", params={},
                        n_relations=3, N=1.0, T=1.0, tile_vertices=64,
                        widths=[4.0, 4.0],
                        residency=["spill", "resident"])
    with pytest.raises(ValueError, match="batch_nodes"):
        Scenario.minibatch("engn", dataset="power_law", params={},
                           batch_nodes=0, fanout=(5,), n_batches=2,
                           N=1.0, T=1.0)
    mb_graph = {"kind": "minibatch", "dataset": "power_law", "params": {},
                "batch_nodes": 4, "fanout": [5], "n_batches": 2,
                "seed": 0, "N": 1.0, "T": 1.0}
    with pytest.raises(ValueError, match="seed batch"):
        Scenario(dataflow="engn", graph=mb_graph,
                 composition=Composition(widths=(4.0, 4.0),
                                         tile_vertices=64.0))
    with pytest.raises(ValueError, match="minibatch"):
        Scenario(dataflow="engn", graph=mb_graph,
                 optimize={"objective": "movement"})


# ---------------------------------------------------------------------------
# Tuner: per-relation residency search vs brute force
# ---------------------------------------------------------------------------

def test_tune_hetero_per_relation_residency_matches_brute_force():
    from repro.core.tune import tune_scenario

    params = {"n_nodes": 200, "n_edges": 900, "seed": 3}
    base = Scenario.hetero(
        "engn", dataset="typed_blocks", params=params,
        n_relations=2, N=[30.0, 20.0], T=16.0, tile_vertices=64,
        widths=[[30.0, 20.0], 16.0, 5.0],
        optimize={"objective": "movement",
                  "space": {"dataflow": ["engn", "hygcn"],
                            "tile_vertices": [32, 64, 128],
                            "residency": ["spill", "resident"]}})
    res = tune_scenario(base)
    assert res.method == "exhaustive"
    assert res.n_candidates == 2 * (2 ** 2) * 3  # residency axis is 2^R

    best = (np.inf, None)
    for df in ("engn", "hygcn"):
        for rr in itertools.product(("spill", "resident"), repeat=2):
            for tv in (32, 64, 128):
                s = Scenario.hetero(
                    df, dataset="typed_blocks", params=params,
                    n_relations=2, N=[30.0, 20.0], T=16.0,
                    tile_vertices=tv,
                    widths=[[30.0, 20.0], 16.0, 5.0], residency=list(rr))
                r = evaluate_scenarios([s]).results[0]
                if r.total_bits < best[0]:
                    best = (r.total_bits, (df, float(tv), rr))
    assert res.best.total_bits == best[0]
    # Per-relation residency serializes as a JSON list, not a tuple.
    d = res.best.to_dict()["residency"]
    assert isinstance(d, (str, list))


# ---------------------------------------------------------------------------
# COMPOSITION_FORMS: value parity with the array-path evaluations
# ---------------------------------------------------------------------------

def test_relational_halo_form_matches_model(typed_blocks):
    tr = typed_blocks
    cap = 64
    model = RelationalGraphModel("engn", tile_vertices=float(cap), trace=tr)
    full = FullGraphParams(V=tr.n_nodes, E=tr.n_edges, N=30.0, T=5.0)
    got = _terms_by_key(model.evaluate(full))
    hw = CompositionHardwareParams()
    form = dict(COMPOSITION_FORMS)["relationalhalo"]
    bits = iters = 0.0
    for r in range(tr.n_relations):
        sched = tr.relation(r).schedule(cap)
        g = RelationalScheduleParams(R=1, H=float(sched.halo_total),
                                     K=float(sched.K), W=30.0)
        b, i = form(g, hw)
        bits += float(b)
        iters += float(i)
    gb, gi = got[("haloreload", "L2-L1")]
    assert float(gb.reshape(-1)[0]) == bits
    assert float(gi.reshape(-1)[0]) == iters
    # The R axis of the form is pure multiplicity.
    g4 = RelationalScheduleParams(R=4, H=100.0, K=256.0, W=32.0)
    assert form(g4, hw)[0] == 4 * form(g4.replace(R=1), hw)[0]


def test_relational_handoff_form_matches_model(typed_blocks):
    tr = typed_blocks
    cap = 64
    widths = (30.0, 16.0, 5.0)
    model = RelationalGraphModel("engn", tile_vertices=float(cap), trace=tr,
                                 widths=widths, residency="resident")
    full = FullGraphParams(V=tr.n_nodes, E=tr.n_edges, N=30.0, T=5.0)
    got = _terms_by_key(model.evaluate(full))
    hw = CompositionHardwareParams()
    form = dict(COMPOSITION_FORMS)["relationalhandoff"]
    # The vertex partition is shared across relations (it depends only on
    # V and the capacity), so one form call per (layer boundary, tile)
    # with R = n_relations covers all relations at once.
    sched0 = tr.relation(0).schedule(cap)
    bits = iters = 0.0
    for l in range(len(widths) - 2):
        for K_t in sched0.vertex_counts:
            g = RelationalScheduleParams(R=tr.n_relations, H=0.0,
                                         K=float(K_t),
                                         W=float(widths[l + 1]))
            b, i = form(g, hw)
            bits += float(b)
            iters += float(i)
    gb, gi = got[("residenthandoff", "L1-L1")]
    assert float(gb.reshape(-1)[0]) == bits
    assert float(gi.reshape(-1)[0]) == iters


def test_minibatch_gather_form_matches_episode_model():
    g = csr_from_trace(resolve_trace_dataset("power_law", MB_PARAMS))
    sched = minibatch_schedule(g, **MB_KW)
    model = TiledGraphModel("engn", schedule=sched)
    full = FullGraphParams(V=g.n_nodes, E=float(sched.n_edges),
                           N=30.0, T=16.0)
    got = _terms_by_key(model.evaluate(full))
    hw = CompositionHardwareParams()
    form = dict(COMPOSITION_FORMS)["minibatchgather"]
    gp = RelationalScheduleParams(R=1, H=float(sched.halo_total),
                                  K=float(MB_KW["batch_nodes"]), W=30.0)
    b, i = form(gp, hw)
    gb, gi = got[("haloreload", "L2-L1")]
    assert float(np.asarray(gb).reshape(-1)[0]) == float(b)
    assert float(np.asarray(gi).reshape(-1)[0]) == float(i)


def test_composition_forms_audit_clean():
    from repro.analysis.audit import audit_composition_forms

    a = audit_composition_forms(use_cache=False)
    assert a.name == "composition"
    assert a.ok, a.strict_errors()
    by_name = {m.movement: m for m in a.movements}
    assert set(by_name) == {"relationalhalo", "relationalhandoff",
                            "minibatchgather"}
    for name in ("relationalhalo", "relationalhandoff"):
        assert "graph.R" in by_name[name].symbols
        assert by_name[name].bits_unit == "bits"


# ---------------------------------------------------------------------------
# Sampler satellites
# ---------------------------------------------------------------------------

def _csr_power_law(n_nodes=1500, n_edges=9000, seed=7):
    return csr_from_trace(resolve_trace_dataset(
        "power_law", {"n_nodes": n_nodes, "n_edges": n_edges,
                      "seed": seed}))


def test_sample_subgraph_bit_matches_reference():
    g = _csr_power_law()
    for trial in range(5):
        seeds = np.random.default_rng(100 + trial).choice(
            g.n_nodes, size=40, replace=False)
        a = sample_subgraph(g, seeds, (8, 4),
                            rng=np.random.default_rng(trial),
                            n_pad=4096, e_pad=8192)
        b = sampler_mod._sample_subgraph_reference(
            g, seeds, (8, 4), rng=np.random.default_rng(trial),
            n_pad=4096, e_pad=8192)
        for f in ("node_ids", "senders", "receivers", "node_mask",
                  "edge_mask", "seed_mask"):
            assert np.array_equal(getattr(a, f), getattr(b, f)), (trial, f)
        assert a.n_real_nodes == b.n_real_nodes
        assert a.n_real_edges == b.n_real_edges


def test_sampled_subgraph_invariants():
    g = _csr_power_law()
    seeds = np.random.default_rng(0).choice(g.n_nodes, size=64,
                                            replace=False)
    sub = sample_subgraph(g, seeds, (10, 5),
                          rng=np.random.default_rng(1),
                          n_pad=4096, e_pad=8192)
    # Masks are exact {0, 1} and count the real entries.
    for mask in (sub.node_mask, sub.edge_mask, sub.seed_mask):
        assert set(np.unique(mask)).issubset({0.0, 1.0})
    n_real, e_real = sub.n_real_nodes, sub.n_real_edges
    assert int(sub.node_mask.sum()) == n_real
    assert int(sub.edge_mask.sum()) == e_real
    assert np.all(sub.node_mask[n_real:] == 0.0)
    assert np.all(sub.edge_mask[e_real:] == 0.0)
    # seed_mask is contained in node_mask; seeds lead the node list.
    assert np.all(sub.seed_mask <= sub.node_mask)
    assert int(sub.seed_mask.sum()) == seeds.size
    assert np.array_equal(sub.node_ids[:seeds.size], seeds)
    # Local-id remap is bijective on real entries: global ids unique,
    # every real edge endpoint names a real local node.
    real_ids = sub.node_ids[:n_real]
    assert np.unique(real_ids).size == n_real
    assert np.all((sub.senders[:e_real] >= 0)
                  & (sub.senders[:e_real] < n_real))
    assert np.all((sub.receivers[:e_real] >= 0)
                  & (sub.receivers[:e_real] < n_real))
    # Mapped back through node_ids, every sampled edge exists in the CSR.
    snd_g = real_ids[sub.senders[:e_real]]
    rcv_g = real_ids[sub.receivers[:e_real]]
    for s, r in zip(snd_g[:64], rcv_g[:64]):
        assert s in g.col[g.ptr[r]:g.ptr[r + 1]]


def test_build_csr_rejects_int32_overflow_boundary():
    snd = np.zeros(1, dtype=np.int64)
    rcv = np.zeros(1, dtype=np.int64)
    # 2^31 - 1 is the last representable id count; 2^31 must raise (and
    # point at the int64 trace pipeline) instead of silently wrapping in
    # the int32 narrowing cast.
    with pytest.raises(ValueError, match="int32") as exc:
        build_csr(snd, rcv, n_nodes=2**31)
    assert "int64" in str(exc.value)
    with pytest.raises(ValueError):
        build_csr(snd, np.array([3], dtype=np.int64), n_nodes=3)


def test_build_csr_small_graph_round_trip():
    snd = np.array([0, 2, 2, 1])
    rcv = np.array([1, 1, 0, 2])
    g = build_csr(snd, rcv, n_nodes=3)
    assert g.n_nodes == 3
    assert g.col.dtype == np.int32
    assert int(g.ptr[-1]) == 4
    for r in range(3):
        assert np.array_equal(np.sort(g.col[g.ptr[r]:g.ptr[r + 1]]),
                              np.sort(snd[rcv == r]))
