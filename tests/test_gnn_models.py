"""GNN model tests: shapes, gradients, padding invariance, equivariance."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.wigner import (random_rotation, rotation_to_z, wigner_d_real,
                               wigner_stack)
from repro.models.gnn import equiformer_v2 as eqv2
from repro.models.gnn import gatedgcn, gcn, meshgraphnet
from repro.models.gnn.graph import GraphBatch

RNG = np.random.default_rng(0)


def _graph(n=20, e=60, d=8, n_classes=3, edge_d=None, self_loops=True):
    snd = RNG.integers(0, n, e).astype(np.int32)
    if self_loops:
        rcv = RNG.integers(0, n, e).astype(np.int32)
    else:
        rcv = ((snd + 1 + RNG.integers(0, n - 1, e)) % n).astype(np.int32)
    kw = dict(node_feat=jnp.asarray(RNG.standard_normal((n, d)), jnp.float32),
              senders=jnp.asarray(snd), receivers=jnp.asarray(rcv),
              labels=jnp.asarray(RNG.integers(0, n_classes, n), jnp.int32))
    if edge_d:
        kw["edge_feat"] = jnp.asarray(RNG.standard_normal((e, edge_d)), jnp.float32)
    return GraphBatch(**kw)


def test_gcn_shapes_and_grads():
    cfg = gcn.GCNConfig(d_in=8, d_hidden=16, n_classes=3)
    g = _graph()
    p = gcn.init_params(cfg, jax.random.key(0))
    loss, m = gcn.loss_fn(cfg, p, g)
    assert jnp.isfinite(loss) and 0 <= float(m["acc"]) <= 1
    grads = jax.grad(lambda q: gcn.loss_fn(cfg, q, g)[0])(p)
    assert all(jnp.isfinite(x).all() for x in jax.tree_util.tree_leaves(grads))


def test_gcn_padding_invariance():
    """Padded (masked) nodes/edges must not change real-node logits."""
    cfg = gcn.GCNConfig(d_in=8, d_hidden=16, n_classes=3)
    g = _graph(n=16, e=40)
    p = gcn.init_params(cfg, jax.random.key(0))
    base = gcn.forward(cfg, p, g)
    n_pad, e_pad = 24, 56
    g2 = GraphBatch(
        node_feat=jnp.concatenate([g.node_feat,
                                   jnp.ones((n_pad - 16, 8))* 9.0]),
        senders=jnp.concatenate([g.senders,
                                 jnp.full((e_pad - 40,), 17, jnp.int32)]),
        receivers=jnp.concatenate([g.receivers,
                                   jnp.full((e_pad - 40,), 18, jnp.int32)]),
        labels=jnp.concatenate([g.labels, jnp.zeros((n_pad - 16,), jnp.int32)]),
        node_mask=jnp.concatenate([jnp.ones(16), jnp.zeros(n_pad - 16)]),
        edge_mask=jnp.concatenate([jnp.ones(40), jnp.zeros(e_pad - 40)]))
    out = gcn.forward(cfg, p, g2)
    err = float(jnp.max(jnp.abs(out[:16] - base)))
    assert err < 1e-5, err


def test_gatedgcn_and_meshgraphnet():
    g = _graph(edge_d=4)
    cfg = gatedgcn.GatedGCNConfig(n_layers=3, d_in=8, d_edge_in=4,
                                  d_hidden=12, n_classes=3)
    p = gatedgcn.init_params(cfg, jax.random.key(1))
    loss, _ = gatedgcn.loss_fn(cfg, p, g)
    assert jnp.isfinite(loss)

    g2 = GraphBatch(node_feat=g.node_feat, senders=g.senders,
                    receivers=g.receivers, edge_feat=g.edge_feat,
                    labels=jnp.asarray(RNG.standard_normal((20, 3)), jnp.float32))
    cfg2 = meshgraphnet.MeshGraphNetConfig(n_layers=3, d_in=8, d_hidden=16)
    p2 = meshgraphnet.init_params(cfg2, jax.random.key(2))
    loss2, _ = meshgraphnet.loss_fn(cfg2, p2, g2)
    assert jnp.isfinite(loss2)
    grads = jax.grad(lambda q: meshgraphnet.loss_fn(cfg2, q, g2)[0])(p2)
    assert all(jnp.isfinite(x).all() for x in jax.tree_util.tree_leaves(grads))


def _eqv2_graph(cfg, pos, feats, snd, rcv):
    vecs = pos[snd] - pos[rcv]
    Rs = np.stack([rotation_to_z(v) for v in vecs])
    wig = wigner_stack(Rs, cfg.l_max, m_max=cfg.m_max)
    return GraphBatch(node_feat=jnp.asarray(feats),
                      senders=jnp.asarray(snd), receivers=jnp.asarray(rcv),
                      labels=jnp.asarray(np.ones((1, 1)), jnp.float32),
                      wigner={l: jnp.asarray(w) for l, w in wig.items()})


def test_equiformer_rotation_invariance():
    n, e = 16, 48
    snd = RNG.integers(0, n, e).astype(np.int32)
    rcv = ((snd + 1 + RNG.integers(0, n - 1, e)) % n).astype(np.int32)
    feats = RNG.standard_normal((n, 4)).astype(np.float32)
    pos = RNG.standard_normal((n, 3))
    cfg = eqv2.EquiformerV2Config(n_layers=3, d_hidden=16, l_max=3, m_max=2,
                                  n_heads=4, d_in=4)
    p = eqv2.init_params(cfg, jax.random.key(3))
    R = random_rotation(RNG)
    e1 = eqv2.forward(cfg, p, _eqv2_graph(cfg, pos, feats, snd, rcv))
    e2 = eqv2.forward(cfg, p, _eqv2_graph(cfg, pos @ R.T, feats, snd, rcv))
    err = float(jnp.max(jnp.abs(e1 - e2)) / (jnp.max(jnp.abs(e1)) + 1e-9))
    assert err < 1e-4, err


def test_wigner_matrices_are_representation():
    for _ in range(3):
        R1, R2 = random_rotation(RNG), random_rotation(RNG)
        D1 = wigner_d_real(R1, 6)
        D2 = wigner_d_real(R2, 6)
        D12 = wigner_d_real(R1 @ R2, 6)
        for l in range(7):
            assert np.max(np.abs(D1[l] @ D1[l].T - np.eye(2 * l + 1))) < 1e-9
            assert np.max(np.abs(D1[l] @ D2[l] - D12[l])) < 1e-9


def test_so2_conv_equivariance_isolated():
    cfg = eqv2.EquiformerV2Config(n_layers=1, d_hidden=8, l_max=3, m_max=2,
                                  n_heads=2, d_in=4)
    p = eqv2.init_params(cfg, jax.random.key(0))
    lp = jax.tree_util.tree_map(lambda a: a[0], p["layers"])
    v = RNG.standard_normal(3)
    x = RNG.standard_normal((1, cfg.L2, cfg.d_hidden)).astype(np.float32)

    def conv(vec, feats):
        R = rotation_to_z(vec)
        wig = wigner_stack(R[None], cfg.l_max, m_max=cfg.m_max)
        return eqv2._so2_conv(cfg, lp, {l: jnp.asarray(w) for l, w in wig.items()},
                              jnp.asarray(feats))

    Rg = random_rotation(RNG)
    Ds = wigner_d_real(Rg, cfg.l_max)
    Dg = np.zeros((cfg.L2, cfg.L2))
    off = 0
    for l, D in enumerate(Ds):
        n = 2 * l + 1
        Dg[off:off + n, off:off + n] = D
        off += n
    out1 = np.asarray(conv(v, x))
    out2 = np.asarray(conv(Rg @ v, np.einsum("pq,eqc->epc", Dg, x)))
    pred = np.einsum("pq,eqc->epc", Dg, out1)
    assert np.max(np.abs(out2 - pred)) / (np.max(np.abs(pred)) + 1e-9) < 1e-5
