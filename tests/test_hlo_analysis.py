"""parse_collectives / entry_boundary_bytes edge cases on hand-written HLO.

No compilation anywhere: each fixture is the post-SPMD optimized-HLO text
shape the parser claims to handle (tuple-shaped variadic collectives,
async -start/-done dedup, iota-form replica_groups, unknown dtypes), so
regressions localize to the regexes rather than to jax version drift.
"""

import pytest

from repro.core.hlo_analysis import (CollectiveOp, DTYPE_BYTES,
                                     entry_boundary_bytes, parse_collectives)

# ---------------------------------------------------------------------------
# Tuple-shaped (variadic) collectives sum their components.
# ---------------------------------------------------------------------------
VARIADIC_HLO = """\
HloModule variadic
ENTRY %main (p0: f32[128], p1: bf16[64,8]) -> (f32[128], bf16[64,8]) {
  %p0 = f32[128]{0} parameter(0)
  %p1 = bf16[64,8]{1,0} parameter(1)
  %ar = (f32[128]{0}, bf16[64,8]{1,0}) all-reduce(%p0, %p1), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %t = (f32[128]{0}, bf16[64,8]{1,0}) tuple(%ar, %ar)
}
"""


def test_variadic_tuple_collective_sums_components():
    stats = parse_collectives(VARIADIC_HLO)
    assert len(stats.ops) == 1
    op = stats.ops[0]
    assert op.kind == "all-reduce"
    assert op.group_size == 4
    assert op.result_bytes == 128 * 4 + 64 * 8 * 2
    # all-reduce wire algebra: 2 * s * (g-1)/g
    assert op.wire_bytes_per_chip == pytest.approx(2 * op.result_bytes * 3 / 4)


# ---------------------------------------------------------------------------
# Async pairs: -start counted once, -done skipped.
# ---------------------------------------------------------------------------
ASYNC_HLO = """\
HloModule async_pair
ENTRY %main (p0: bf16[2,1,128]) -> bf16[2,16,128] {
  %p0 = bf16[2,1,128]{2,1,0} parameter(0)
  %ag-start = bf16[2,16,128]{2,1,0} all-gather-start(%p0), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={1}
  ROOT %ag-done = bf16[2,16,128]{2,1,0} all-gather-done(%ag-start)
}
"""


def test_async_start_done_counted_once():
    stats = parse_collectives(ASYNC_HLO)
    assert stats.counts() == {"all-gather": 1}
    op = stats.ops[0]
    assert op.group_size == 16
    assert op.result_bytes == 2 * 16 * 128 * 2
    assert stats.total_wire_bytes_per_chip == pytest.approx(
        op.result_bytes * 15 / 16)


# ---------------------------------------------------------------------------
# Iota-form replica_groups: [num_groups,group_size]<=...
# ---------------------------------------------------------------------------
IOTA_HLO = """\
HloModule iota_groups
ENTRY %main (p0: f32[64,256]) -> f32[64,32] {
  %p0 = f32[64,256]{1,0} parameter(0)
  ROOT %rs = f32[64,32]{1,0} reduce-scatter(%p0), replica_groups=[4,8]<=[32], dimensions={1}, to_apply=%add
}
"""


def test_iota_replica_groups_group_size():
    stats = parse_collectives(IOTA_HLO)
    op = stats.ops[0]
    assert op.kind == "reduce-scatter"
    assert op.group_size == 8          # [num_groups, group_size] iota form
    # reduce-scatter wire bytes: result * (g - 1)
    assert op.wire_bytes_per_chip == pytest.approx(64 * 32 * 4 * 7)


# ---------------------------------------------------------------------------
# Unknown dtypes are silently skipped (token/opaque-typed collectives).
# ---------------------------------------------------------------------------
UNKNOWN_DTYPE_HLO = """\
HloModule unknown_dtype
ENTRY %main (p0: f32[16]) -> f32[16] {
  %p0 = f32[16]{0} parameter(0)
  %cp = token[] collective-permute(%t0), source_target_pairs={{0,1},{1,0}}
  %weird = zz9[8,8]{1,0} all-reduce(%q), replica_groups={{0,1}}
  ROOT %r = f32[16]{0} add(%p0, %p0)
}
"""


def test_unknown_dtypes_silently_skipped():
    stats = parse_collectives(UNKNOWN_DTYPE_HLO)
    assert stats.ops == []
    assert stats.total_wire_bytes_per_chip == 0.0
    assert "zz9" not in DTYPE_BYTES


def test_collective_permute_counts_full_payload():
    hlo = """\
  %cp = f32[4,8]{1,0} collective-permute(%p), source_target_pairs={{0,1},{1,2},{2,0}}
"""
    stats = parse_collectives(hlo)
    op = stats.ops[0]
    assert op.kind == "collective-permute"
    assert op.wire_bytes_per_chip == 4 * 8 * 4   # payload crosses the wire once


def test_empty_and_collective_free_text():
    assert parse_collectives("").ops == []
    assert parse_collectives("ENTRY %main () -> f32[] {}").ops == []


# ---------------------------------------------------------------------------
# entry_boundary_bytes (the conformance boundary measurement).
# ---------------------------------------------------------------------------
def test_entry_boundary_bytes_params_and_result():
    b = entry_boundary_bytes(VARIADIC_HLO)
    assert b["param_bytes"] == 128 * 4 + 64 * 8 * 2
    assert b["result_bytes"] == 128 * 4 + 64 * 8 * 2   # tuple result summed
    assert b["total_bytes"] == b["param_bytes"] + b["result_bytes"]


def test_entry_boundary_bytes_layout_annotated_tuple_result():
    """TPU-style dumps annotate layouts in the ENTRY signature; the result
    capture must reach the body brace, not stop at the first layout brace."""
    hlo = ("HloModule m\n"
           "ENTRY %main.7 (Arg_0.1: f32[128], Arg_1.2: f32[64,8]) "
           "-> (f32[128]{0}, f32[64,8]{1,0}) {\n"
           "  ROOT %t = tuple()\n}\n")
    b = entry_boundary_bytes(hlo)
    assert b["param_bytes"] == 128 * 4 + 64 * 8 * 4
    assert b["result_bytes"] == 128 * 4 + 64 * 8 * 4
    assert b["total_bytes"] == b["param_bytes"] + b["result_bytes"]


def test_entry_boundary_bytes_requires_entry():
    with pytest.raises(ValueError, match="ENTRY"):
        entry_boundary_bytes("HloModule no_entry\n%foo = f32[2]{0} add(...)")


def test_wire_algebra_table():
    """The per-kind ring-schedule algebra, pinned (tpu_model §)."""
    cases = {
        "all-gather": 1024 * 3 / 4,
        "all-reduce": 2 * 1024 * 3 / 4,
        "reduce-scatter": 1024 * 3,
        "all-to-all": 1024 * 3 / 4,
        "collective-permute": 1024,
    }
    for kind, expect in cases.items():
        op = CollectiveOp(kind, 1024.0, 4, 0)
        assert op.wire_bytes_per_chip == pytest.approx(expect), kind
