"""Model-auditor battery (DESIGN.md §16).

What must hold, forever:

* every registered dataflow audits clean under ``--strict`` — zero
  unwaived unit errors, no undeclared dead hardware parameters, golden
  totals pinned — and the *specific* waivers (HyGCN's two Table IV rows,
  EnGN's M_prime) stay exactly as recorded;
* the tracer itself is honest: mismatched units taint, ceil of a
  non-dimensionless quantity is flagged, data-dependent branching
  aborts, and interval bounds catch 2^53 crossings under a widened
  envelope while the default ROADMAP envelope stays exactly
  representable;
* the AST linter fires on each forbidden construct, honors pragmas, and
  reports the shipped tree clean;
* the mutation battery catches 100% of generated mutants;
* audit caching never serves a stale result for a re-registered
  mutated spec (the satellite-4 contract);
* the CLI's exit codes and JSON schema, and the DESIGN.md provenance
  drift gate, behave as documented.
"""

import dataclasses
import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (BITS, DIMENSIONLESS, FLOAT64_EXACT_MAX,
                            SpecAudit, TraceAbort, TraceContext, Unit,
                            analysis_cache_info, audit_composition_forms,
                            audit_registry, audit_spec,
                            clear_analysis_cache, lint_paths, lint_source,
                            mutate_spec, render_provenance,
                            run_mutation_battery, trace_form, traced_record,
                            unit_from_tag)
from repro.analysis import lint as lint_mod
from repro.analysis.__main__ import (PROVENANCE_BEGIN, PROVENANCE_END,
                                     extract_committed_provenance)
from repro.core import registry
from repro.core.dataflow import MOVEMENT_ROLES, DataflowSpec, MovementSpec
from repro.core.notation import (FieldUnit, GraphTileParams, declare_units,
                                 paper_default_graph,
                                 unit_declarations_for)
from repro.core.terms import _VALID_HIERARCHIES
from repro.core.validation import SEC4_GOLDEN_TOTALS, crosscheck_registry

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# unit algebra
# ---------------------------------------------------------------------------

def test_unit_algebra():
    assert BITS * DIMENSIONLESS == BITS
    assert BITS / BITS == DIMENSIONLESS
    assert (BITS * BITS).bits_exp == 2
    assert str(BITS) == "bits"
    assert str(DIMENSIONLESS) == "dimensionless"
    assert str(Unit(2)) == "bits^2"
    assert unit_from_tag("bits") == BITS
    assert unit_from_tag("bits/iter") == BITS
    for tag in ("elements", "vertices", "edges", "PEs", "dimensionless"):
        assert unit_from_tag(tag) == DIMENSIONLESS
    with pytest.raises(ValueError):
        unit_from_tag("furlongs")


def test_unit_declarations_cover_all_records():
    g = paper_default_graph()
    decls = unit_declarations_for(g)
    assert set(decls) == {f.name for f in dataclasses.fields(g)}
    for name in registry.names():
        hw = registry.get(name).hw_factory()
        decls = unit_declarations_for(hw)
        assert set(decls) == {f.name for f in dataclasses.fields(hw)}, name


def test_declare_units_rejects_field_mismatch():
    @dataclasses.dataclass(frozen=True)
    class Rec:
        a: float = 1.0
        b: float = 2.0

    with pytest.raises(ValueError):
        declare_units(Rec, {"a": FieldUnit("bits")})  # missing b
    with pytest.raises(ValueError):
        declare_units(Rec, {"a": FieldUnit("bits"), "b": FieldUnit("bits"),
                            "c": FieldUnit("bits")})  # extra c
    declare_units(Rec, {"a": FieldUnit("bits"), "b": FieldUnit("elements")})


# ---------------------------------------------------------------------------
# tracer semantics
# ---------------------------------------------------------------------------

def _traced_pair():
    ctx = TraceContext(movement="t")
    g = traced_record(paper_default_graph(), "graph", ctx)
    hw = traced_record(registry.get("engn").hw_factory(), "hw", ctx)
    return ctx, g, hw


def test_tracer_unit_mismatch_taints_and_continues():
    ctx, g, hw = _traced_pair()
    bad = g.K + hw.sigma  # vertices + bits
    assert len(ctx.issues) == 1
    assert "mismatched units" in str(ctx.issues[0])
    # tainted value adopts the first operand's unit and tracing continues
    assert bad.unit == DIMENSIONLESS
    more = bad * hw.sigma
    assert more.unit == BITS
    assert "graph.K" in more.symbols and "hw.sigma" in more.symbols


def test_tracer_ceil_requires_dimensionless():
    ctx, g, hw = _traced_pair()
    np.ceil(g.K * hw.sigma)  # vertices * bits -> bits: flagged
    assert any("ceil" in str(i) for i in ctx.issues)
    n0 = len(ctx.issues)
    np.ceil(g.K / g.L)  # dimensionless ratio: clean
    assert len(ctx.issues) == n0


def test_tracer_branching_aborts():
    ctx, g, hw = _traced_pair()
    with pytest.raises(TraceAbort):
        bool(g.K > g.L)
    with pytest.raises(TraceAbort):
        float(g.K)


def test_tracer_where_and_comparison():
    ctx, g, hw = _traced_pair()
    cond = g.K > g.L
    assert cond.unit == DIMENSIONLESS and (cond.lo, cond.hi) == (0.0, 1.0)
    merged = np.where(cond, g.K, g.L)
    assert merged.unit == DIMENSIONLESS
    assert {"graph.K", "graph.L"} <= set(merged.symbols)
    # hull of the branches
    assert merged.lo == 0.0 and merged.hi == 1e7


def test_tracer_interval_overflow_records():
    ctx, g, hw = _traced_pair()
    big = g.P * g.K  # 1e9 * 1e7 = 1e16 > 2^53
    assert big.hi > FLOAT64_EXACT_MAX
    assert len(ctx.overflows) == 1
    rec = ctx.overflows[0]
    assert rec.op == "multiply"
    assert {"graph.P", "graph.K"} <= set(rec.symbols)


def test_trace_form_on_real_movement():
    spec = registry.get("engn")
    ctx = TraceContext(movement="engn.loadvertcache")
    g = traced_record(paper_default_graph(), "graph", ctx)
    hw = traced_record(spec.hw_factory(), "hw", ctx)
    bits, iters = trace_form(spec.movement("loadvertcache").form, g, hw, ctx)
    assert bits.unit == BITS and iters.unit == DIMENSIONLESS
    assert not ctx.issues


# ---------------------------------------------------------------------------
# registry audits: the shipped models
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def audits():
    return audit_registry()


def test_all_registered_specs_audit_clean(audits):
    assert set(audits) == set(registry.names())
    for name, a in audits.items():
        assert a.strict_errors() == (), f"{name}: {a.strict_errors()}"
        assert a.ok and a.golden_ok


def test_hygcn_waivers_exactly_as_recorded(audits):
    a = audits["hygcn"]
    waived = {m.movement: len(m.unit_issues) for m in a.movements if m.waived}
    assert waived == {"aggregate": 2, "readinterphase": 2}
    assert a.unit_error_count == 0 and a.waived_issue_count == 4
    for m in a.movements:
        if m.waived:
            assert "Table IV" in m.audit_note


def test_engn_dead_hw_waiver(audits):
    a = audits["engn"]
    assert a.waived_dead_hw == ("M_prime",)
    assert a.dead_hw == ()
    # B_star=None aliases B: skipped by the tracer, never reported dead
    assert "B_star" not in a.waived_dead_hw


def test_unused_graph_symbols_by_construction(audits):
    assert audits["awb_gcn"].unused_graph == ("L",)
    assert audits["hygcn"].unused_graph == ("L",)
    assert audits["spmm_tiled"].unused_graph == ("L", "P")
    assert audits["spmm_unfused"].unused_graph == ("L", "P")
    assert audits["engn"].unused_graph == ()


def test_provenance_pins(audits):
    lv = next(m for m in audits["engn"].movements
              if m.movement == "loadvertcache")
    assert lv.graph_symbols == ("L", "N")
    assert lv.hw_symbols == ("B", "M", "sigma")
    le = next(m for m in audits["awb_gcn"].movements
              if m.movement == "loadedges")
    assert le.graph_symbols == ("P",)


def test_value_pins_match_golden_totals(audits):
    for name, (total_bits, _) in SEC4_GOLDEN_TOTALS.items():
        a = audits[name]
        assert sum(m.value_bits for m in a.movements) == total_bits
        assert a.golden_actual == total_bits


def test_default_envelope_is_float64_exact(audits):
    # ROADMAP item 1's envelope (P<=1e9, K/L<=1e7, N/T<=1024): every
    # intermediate of every registered form stays under 2^53.
    for name, a in audits.items():
        assert a.overflow_count == 0, name
        for m in a.movements:
            assert m.bits_bound <= FLOAT64_EXACT_MAX, (name, m.movement)


def test_widened_envelope_detects_overflow():
    wide = {"N": (1.0, 4096.0), "T": (1.0, 4096.0)}
    a = audit_spec(registry.get("engn"), envelope=wide)
    assert a.overflow_count > 0
    agg = next(m for m in a.movements if m.movement == "aggregate")
    assert agg.overflows and max(o.bound for o in agg.overflows) > 2**53
    # overflow findings are informational, not strict failures
    assert a.strict_errors() == ()


def test_audit_spec_flags_undeclared_dead_hw():
    bare = dataclasses.replace(registry.get("engn"), unused_hw=())
    a = audit_spec(bare, use_cache=False)
    assert a.dead_hw == ("M_prime",)
    assert any("M_prime" in e for e in a.strict_errors())


# ---------------------------------------------------------------------------
# caching x re-registration (satellite 4)
# ---------------------------------------------------------------------------

def test_audit_cache_hits_and_misses():
    clear_analysis_cache()
    spec = registry.get("awb_gcn")
    a1 = audit_spec(spec)
    info = analysis_cache_info()
    assert info["misses"] >= 1 and info["entries"] >= 1
    a2 = audit_spec(spec)
    assert analysis_cache_info()["hits"] >= 1
    assert a1 is a2
    # a different envelope is a different cache slot, not a stale hit
    a3 = audit_spec(spec, envelope={"P": (0.0, 1e12)})
    assert a3 is not a1 and a3.envelope != a1.envelope


def test_reregistered_mutated_spec_is_reaudited_not_stale():
    base = registry.get("hygcn")
    baseline = audit_spec(base)
    assert baseline.ok
    mutant = next(m for m in mutate_spec(base) if m.name == "drop-sigma")
    swapped = dataclasses.replace(mutant.spec, name="hygcn")
    with registry.temporarily_registered(swapped, overwrite=True):
        assert registry.get("hygcn") is swapped
        audited = audit_spec(registry.get("hygcn"))
        # new form callables -> new cache key -> fresh (failing) audit
        assert audited is not baseline
        assert not audited.golden_ok
        assert audited.strict_errors() != ()
        with pytest.raises(AssertionError, match="model audit failure"):
            crosscheck_registry(analysis=True)
    # restored registry audits clean again (and hits the old cache entry)
    assert audit_spec(registry.get("hygcn")) is baseline


def test_crosscheck_registry_analysis_records():
    records = crosscheck_registry(analysis=True)
    for name in registry.names():
        audit = records[f"{name}::analysis"]
        assert isinstance(audit, SpecAudit) and audit.ok


def test_conformance_preflight_refuses_broken_model():
    # run_conformance statically audits before measuring: a mis-transcribed
    # model must be rejected up front, not lent dynamic-conformance numbers.
    from repro.core.conformance import run_conformance

    base = registry.get("hygcn")
    mutant = next(m for m in mutate_spec(base) if m.name == "drop-sigma")
    swapped = dataclasses.replace(mutant.spec, name="hygcn")
    with registry.temporarily_registered(swapped, overwrite=True):
        with pytest.raises(AssertionError,
                           match="static model audit failure for 'hygcn'"):
            run_conformance(names=["hygcn"], points=())
        # the documented override skips the gate: with the audit bypassed we
        # get past it to the runnable-analogue step (hygcn declares none)
        with pytest.raises(ValueError, match="declares no runnable"):
            run_conformance(names=["hygcn"], points=(),
                            preflight_audit=False)
    # a clean registered model passes the preflight (empty points: gate only)
    runnable = next(s.name for s in registry.specs() if s.has_runnable)
    assert run_conformance(names=[runnable], points=()) == []


# ---------------------------------------------------------------------------
# mutation battery
# ---------------------------------------------------------------------------

def test_mutation_battery_catches_everything():
    outcomes = run_mutation_battery()
    assert outcomes, "battery generated no mutants"
    escaped = [o for o in outcomes if not o.caught]
    assert not escaped, escaped
    by_spec = {(o.spec, o.mutant) for o in outcomes}
    # drop-sigma and swap-NT apply to every spec...
    for name in registry.names():
        assert (name, "drop-sigma") in by_spec
        assert (name, "swap-NT") in by_spec
    # ...degenerate-minimum only where the baseline trace calls minimum
    assert ("engn", "degenerate-minimum") in by_spec
    assert ("hygcn", "degenerate-minimum") in by_spec
    assert ("spmm_tiled", "degenerate-minimum") not in by_spec
    assert ("spmm_unfused", "degenerate-minimum") not in by_spec


def test_drop_sigma_is_caught_by_unit_checker():
    outcomes = run_mutation_battery(specs=[registry.get("engn")])
    drop = next(o for o in outcomes if o.mutant == "drop-sigma")
    assert "unit-checker" in drop.caught_by
    assert "golden-totals" in drop.caught_by


# ---------------------------------------------------------------------------
# linter
# ---------------------------------------------------------------------------

def test_lint_vocabularies_match_runtime():
    assert set(lint_mod.VALID_HIERARCHIES) == set(_VALID_HIERARCHIES)
    assert tuple(lint_mod.VALID_ROLES) == tuple(MOVEMENT_ROLES)


def test_lint_builtin_min_in_form():
    src = (
        "def myform(g, hw):\n"
        "    return min(g.K, hw.B), g.K\n"
        "spec = MovementSpec('m', 'L2-L1', myform, role='edges')\n"
    )
    rules = [v.rule for v in lint_source(src, "core/x.py")]
    assert rules == ["form-builtin-min"]
    # the same builtin outside any form is not the linter's business
    assert lint_source("def helper(a, b):\n    return min(a, b)\n") == []


def test_lint_transitive_helper_and_math_ceil():
    src = (
        "import math\n"
        "def _blocks(k):\n"
        "    return math.ceil(k / 256) * max(k, 1)\n"
        "def myform(g, hw):\n"
        "    return _blocks(g.K), g.K\n"
        "spec = MovementSpec('m', 'L2-L1', myform, role='edges')\n"
    )
    rules = sorted(v.rule for v in lint_source(src, "core/x.py"))
    assert rules == ["form-builtin-max", "form-math-ceil"]


def test_lint_lexsort_and_edge_list_rules():
    src = "def f(a, b):\n    return np.lexsort((a, b))\n"
    assert [v.rule for v in lint_source(src, "src/repro/core/trace.py")] \
        == ["trace-lexsort"]
    # outside a trace path the same code is fine
    assert lint_source(src, "src/repro/core/sweep.py") == []
    dist = "def stage(s, r):\n    return GraphTrace(senders=s, receivers=r)\n"
    assert [v.rule for v in
            lint_source(dist, "src/repro/distributed/x.py")] \
        == ["trace-edge-list"]
    ok = ("def stage(f):\n"
          "    return GraphTrace.from_factorization(*f)\n")
    assert lint_source(ok, "src/repro/distributed/x.py") == []


def test_lint_pragma_suppression():
    src = ("def f(a, b):\n"
           "    return np.lexsort((a, b))  # lint: allow-trace-lexsort\n")
    assert lint_source(src, "src/repro/core/trace.py") == []


def test_lint_movement_vocab():
    bad_h = "spec = MovementSpec('m', 'L3-L1', f, role='edges')\n"
    assert [v.rule for v in lint_source(bad_h)] == ["movement-vocab"]
    bad_r = "spec = MovementSpec('m', 'L2-L1', f, role='topology')\n"
    assert [v.rule for v in lint_source(bad_r)] == ["movement-vocab"]
    dyn = "spec = MovementSpec('m', HIER, f, role='edges')\n"
    assert [v.rule for v in lint_source(dyn)] == ["movement-vocab"]
    good = "spec = MovementSpec('m', 'L2-L1', f, role='edges')\n"
    assert lint_source(good) == []


def test_shipped_tree_lints_clean():
    assert lint_paths() == []


# ---------------------------------------------------------------------------
# CLI + provenance drift gate
# ---------------------------------------------------------------------------

def _cli_env(pythonpath=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(pythonpath or REPO / "src")
    return env


def _run_cli(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd, env=_cli_env())


def test_cli_strict_passes_and_writes_json(tmp_path):
    out = tmp_path / "BENCH_analysis.json"
    r = _run_cli("--strict", "--json", str(out))
    assert r.returncode == 0, r.stdout + r.stderr
    payload = json.loads(out.read_text())
    assert payload["schema"] == "repro.analysis/v1"
    assert payload["ok"] is True
    # §17: the composition pseudo-dataflow joins the strict gate alongside
    # every registered dataflow.
    assert set(payload["dataflows"]) == set(registry.names()) | {"composition"}
    assert payload["lint"]["violations"] == []
    mb = payload["mutation_battery"]
    assert mb["ran"] and mb["caught"] == mb["total"] > 0
    hygcn = payload["dataflows"]["hygcn"]
    assert hygcn["waived_unit_issues"] == 4 and hygcn["unit_errors"] == 0


def test_cli_usage_errors_exit_2():
    assert _run_cli("--check").returncode == 2
    assert _run_cli("--provenance", "--check", "--write").returncode == 2


def test_cli_provenance_check_current_and_tampered(tmp_path):
    r = _run_cli("--provenance", "--check")
    assert r.returncode == 0, r.stdout + r.stderr
    # tamper with a committed row in a scratch copy -> stale, exit 1
    scratch = tmp_path / "DESIGN.md"
    shutil.copy(REPO / "DESIGN.md", scratch)
    scratch.write_text(scratch.read_text().replace(
        "| engn | loadedges |", "| engn | loadedgez |"))
    r = _run_cli("--provenance", "--check", "--design", str(scratch))
    assert r.returncode == 1
    assert "STALE" in r.stderr
    # --write repairs it in place
    r = _run_cli("--provenance", "--write", "--design", str(scratch))
    assert r.returncode == 0
    r = _run_cli("--provenance", "--check", "--design", str(scratch))
    assert r.returncode == 0


def test_committed_appendix_matches_live_render():
    committed = extract_committed_provenance((REPO / "DESIGN.md").read_text())
    assert committed is not None, "DESIGN.md §16 appendix markers missing"
    # Mirror the CLI: the §17 composition pseudo-dataflow renders into the
    # appendix alongside every registered dataflow.
    audits = audit_registry()
    audits["composition"] = audit_composition_forms()
    assert committed == render_provenance(audits)


def test_cli_strict_fails_on_escaped_model_error(tmp_path):
    # A module registering a unit-broken spec must turn --strict red.
    conftest = tmp_path / "sitecustomize.py"
    conftest.write_text(
        "import numpy as np\n"
        "from repro.core import registry\n"
        "from repro.core.dataflow import DataflowSpec, MovementSpec\n"
        "from repro.core.notation import EnGNHardwareParams\n"
        "def bad(g, hw):\n"
        "    bits = np.asarray(g.K * g.N, dtype=np.float64)\n"  # no sigma
        "    return bits, np.ones_like(bits)\n"
        "registry.register(DataflowSpec(\n"
        "    name='zz_bad', movements=(\n"
        "        MovementSpec('only', 'L2-L1', bad, role='other'),),\n"
        "    hw_factory=EnGNHardwareParams))\n")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict",
         "--no-mutations"],
        capture_output=True, text=True, cwd=REPO,
        env=_cli_env(f"{tmp_path}:{REPO / 'src'}"))
    assert r.returncode == 1, r.stdout + r.stderr
    assert "zz_bad" in r.stdout + r.stderr


def test_provenance_markers_present_once():
    text = (REPO / "DESIGN.md").read_text()
    assert text.count(PROVENANCE_BEGIN) == 1
    assert text.count(PROVENANCE_END) == 1
