"""Trace backend battery (DESIGN.md §12) + the satellite bugfix pins.

Load-bearing guarantees:

* the vectorized balanced partitioner's per-tile edge and unique-remote-
  source (halo) counts exactly match a brute-force per-tile ``np.unique``
  reference on a >= 100k-edge power-law graph, evaluated through the
  scenario front door (the ISSUE 4 acceptance criterion);
* on the perfectly uniform ring-of-tiles graph — where the paper's
  ``1 - 1/n_tiles`` expected cut and uniform-tile assumptions are exact —
  trace-kind totals **bit-match** the uniform closed form, for every
  registered dataflow, single- and multi-layer, power-of-two tile counts;
* trace scenarios are pure data: JSON round trips evaluate bit-
  identically, plan-key grouping batches (same dataset, same capacity)
  into one broadcast evaluation per dataflow and splits structural
  differences;
* satellites: the power-law generator can no longer emit self loops, the
  compose/scenario layers reject negative or out-of-range N/T/
  high_degree_fraction, and ``TiledGraphModel`` accepts array-valued
  ``halo_dedup`` like every other ParamArray.
"""

import json

import numpy as np
import pytest

from repro.api import (Scenario, dump_scenarios, evaluate_scenario,
                       evaluate_scenarios, template,
                       trace_scenarios_from_graph)
from repro.api.cli import main as cli_main
from repro.core import registry
from repro.core.compose import FullGraphParams, TiledGraphModel
from repro.core.trace import (CORA_E, CORA_V, GraphTrace,
                              resolve_trace_dataset, trace_dataset_names)
from repro.data import synthetic

ALL_DATAFLOWS = registry.names()

#: >= 100k edges: the acceptance-criterion operating point.
BIG = {"n_nodes": 20000.0, "n_edges": 120000.0, "seed": 0.0, "alpha": 1.3}


# ---------------------------------------------------------------------------
# Partitioner exactness: vectorized schedule == brute-force per-tile unique.
# ---------------------------------------------------------------------------
def test_big_power_law_halo_matches_bruteforce_unique():
    s = Scenario.trace("engn", dataset="power_law", params=BIG,
                       N=30.0, T=5.0, tile_vertices=1024.0)
    res = evaluate_scenarios([s]).results[0]
    trace = resolve_trace_dataset("power_law", BIG)
    assert trace.n_edges >= 100_000
    sched = trace.schedule(1024)
    assert res.n_tiles == float(sched.n_tiles)

    # Brute force per tile: edges by destination tile; halo = unique
    # remote sources among them (np.unique reference).
    K = sched.K
    dst_tile = trace.receivers // K
    for t in range(sched.n_tiles):
        srcs = trace.senders[dst_tile == t]
        assert sched.edge_counts[t] == srcs.size
        remote = srcs[(srcs // K) != t]
        assert sched.halo_counts[t] == np.unique(remote).size
        assert sched.remote_edge_counts[t] == remote.size
    assert sched.vertex_counts.sum() == trace.n_nodes
    assert sched.edge_counts.sum() == trace.n_edges

    # The evaluated haloreload term charges exactly the unique counts.
    hw = registry.get("engn").hw_factory()
    expect_halo = sched.halo_counts.sum() * 30.0 * float(hw.sigma)
    assert res.breakdown["haloreload"] == expect_halo
    # ... which a power-law graph keeps strictly below the paper's
    # expected-cut estimate (the benchmark's headline gap).
    assert sched.halo_total < sched.uniform_halo_estimate()


def test_schedule_vertex_edge_invariants_and_cache_hits():
    trace = resolve_trace_dataset("power_law",
                                  {"n_nodes": 3000, "n_edges": 24000,
                                   "seed": 2, "alpha": 1.0})
    sched = trace.schedule(700)
    assert sched.n_tiles == 5  # ceil(3000/700) -> K = 600
    assert sched.K == 600
    np.testing.assert_array_equal(sched.vertex_counts, [600] * 5)
    assert np.all(sched.halo_counts <= sched.remote_edge_counts)
    frac = sched.cache_hit_fraction(0.1)
    assert frac.shape == (5,)
    assert np.all((frac >= 0) & (frac <= 1))
    # More cache must serve no smaller a share of the tile's reads.
    assert np.all(sched.cache_hit_fraction(0.5) >= frac)
    with pytest.raises(ValueError, match="high_degree_fraction"):
        sched.cache_hit_fraction(1.5)


def test_ring_cache_hits_are_exact():
    """Every (tile, source) pair on the ring has multiplicity 1, so the
    top-L cache serves exactly L of the tile's P = K*n_tiles reads."""
    trace = resolve_trace_dataset("ring_of_tiles",
                                  {"n_nodes": 400, "n_tiles": 4})
    sched = trace.schedule(100)
    frac = sched.cache_hit_fraction(0.1)
    np.testing.assert_array_equal(frac, np.full(4, 10 / 400))


# ---------------------------------------------------------------------------
# The bit-match anchor: uniform ring-of-tiles == uniform closed form.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_DATAFLOWS)
@pytest.mark.parametrize("n_tiles", [1, 2, 4, 8])
def test_trace_bitmatches_uniform_closed_form_on_ring(name, n_tiles):
    V = 1024
    E = V * max(n_tiles, 1)
    ring = {"n_nodes": float(V), "n_tiles": float(n_tiles)}
    cap = float(V // n_tiles)
    for widths in (None, (64.0, 16.0, 8.0)):
        N, T = (30.0, 5.0) if widths is None else (widths[0], widths[-1])
        t = evaluate_scenario(Scenario.trace(
            name, dataset="ring_of_tiles", params=ring, N=N, T=T,
            tile_vertices=cap, widths=widths))
        u = evaluate_scenario(Scenario.full_graph(
            name, V=float(V), E=float(E), N=N, T=T,
            tile_vertices=cap, widths=widths))
        assert t.total_bits == u.total_bits, (name, n_tiles, widths)
        assert t.total_iterations == u.total_iterations
        assert t.breakdown == u.breakdown
        assert t.iteration_breakdown == u.iteration_breakdown
        assert t.n_tiles == u.n_tiles == float(n_tiles)


def test_ring_generator_is_perfectly_uniform():
    ga = synthetic.ring_of_tiles_graph(n_nodes=120, n_tiles=4)
    assert ga.n_edges == 120 * 4
    assert np.all(ga.senders != ga.receivers)
    trace = GraphTrace.from_arrays(ga)
    np.testing.assert_array_equal(trace.in_degrees(), np.full(120, 4))
    np.testing.assert_array_equal(trace.out_degrees(), np.full(120, 4))
    sched = trace.schedule(30)
    np.testing.assert_array_equal(sched.edge_counts, np.full(4, 120))
    # exactly one source in every other tile per vertex, all distinct:
    np.testing.assert_array_equal(sched.halo_counts, np.full(4, 90))
    assert sched.halo_total == sched.uniform_halo_estimate()
    with pytest.raises(ValueError, match="divide"):
        synthetic.ring_of_tiles_graph(n_nodes=100, n_tiles=3)
    with pytest.raises(ValueError, match="2 vertices per tile"):
        synthetic.ring_of_tiles_graph(n_nodes=4, n_tiles=4)


# ---------------------------------------------------------------------------
# Planner: grouping, batching, JSON round trips.
# ---------------------------------------------------------------------------
def test_trace_scenarios_group_into_one_evaluation_per_dataflow():
    params = {"n_nodes": 2000.0, "n_edges": 14000.0, "seed": 1.0,
              "alpha": 1.4}
    batch = [
        Scenario.trace(df, dataset="power_law", params=params, N=N, T=5.0,
                       tile_vertices=512.0,
                       hardware={"B": B})
        for df in ALL_DATAFLOWS
        for N, B in ((16.0, 1000.0), (64.0, 2000.0), (256.0, 4000.0))
    ]
    res = evaluate_scenarios(batch)
    assert res.n_evaluations == len(ALL_DATAFLOWS)
    assert set(res.evaluations_per_dataflow().values()) == {1}
    # stacked broadcast == per-scenario loop, exactly
    for s, r in zip(batch, res.results):
        lone = evaluate_scenario(s)
        assert r.total_bits == lone.total_bits
        assert r.total_iterations == lone.total_iterations
        assert r.breakdown == lone.breakdown
        assert r.n_tiles == lone.n_tiles


def test_trace_structural_differences_split_plan_groups():
    params = {"n_nodes": 1000.0, "n_edges": 6000.0, "seed": 0.0}
    base = Scenario.trace("engn", dataset="power_law", params=params,
                          N=30.0, T=5.0, tile_vertices=256.0)
    other_cap = base.replace(composition={"tile_vertices": 128.0})
    other_seed = Scenario.trace("engn", dataset="power_law",
                                params={**params, "seed": 1.0},
                                N=30.0, T=5.0, tile_vertices=256.0)
    other_set = Scenario.trace("engn", dataset="ring_of_tiles",
                               params={"n_nodes": 1000.0, "n_tiles": 4.0},
                               N=30.0, T=5.0, tile_vertices=256.0)
    # The tile capacity is batchable since DESIGN.md §13: other_cap joins
    # base's plan group (the capacity axis); dataset/params stay structural.
    assert other_cap.plan_key() == base.plan_key()
    assert len({base.plan_key(), other_cap.plan_key(), other_seed.plan_key(),
                other_set.plan_key()}) == 3
    res = evaluate_scenarios([base, other_cap, other_seed, other_set])
    assert res.n_evaluations == 3
    # ... and the shared group is still bit-identical to lone evaluations.
    for s, r in zip([base, other_cap], res.results[:2]):
        lone = evaluate_scenario(s)
        assert r.total_bits == lone.total_bits
        assert r.breakdown == lone.breakdown
        assert r.n_tiles == lone.n_tiles
    # a full-graph scenario never shares a trace group
    full = Scenario.full_graph("engn", V=1000.0, E=6000.0, N=30.0, T=5.0,
                               tile_vertices=256.0)
    assert full.plan_key() != base.plan_key()


def test_trace_scenario_json_round_trip_bit_identical(tmp_path):
    scens = [
        Scenario.trace(df, dataset="power_law",
                       params={"n_nodes": 1500.0, "n_edges": 9000.0,
                               "seed": 0.0, "alpha": 1.7},
                       N=64.0, T=7.0, tile_vertices=512.0,
                       widths=(64.0, 16.0, 7.0), residency=res_)
        for df in ALL_DATAFLOWS for res_ in ("spill", "resident")
    ]
    for s in scens:
        s2 = Scenario.from_json(s.to_json())
        assert s2 == s and hash(s2) == hash(s)
        assert s2.plan_key() == s.plan_key()
        r1, r2 = evaluate_scenario(s), evaluate_scenario(s2)
        assert r1.total_bits == r2.total_bits
        assert r1.breakdown == r2.breakdown
    path = tmp_path / "trace_batch.json"
    dump_scenarios(scens, str(path))
    from repro.api import load_scenarios
    assert load_scenarios(str(path)) == scens


def test_trace_smoke_batch_through_cli(tmp_path):
    out = tmp_path / "out.json"
    rc = cli_main(["--scenario", "examples/scenarios/trace_smoke.json",
                   "--json", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert payload["status"] == "ok"
    assert all(r["expect_ok"] for r in payload["results"])
    assert all(r["scenario"]["graph"]["kind"] == "trace"
               for r in payload["results"])


def test_cora_trace_template_single_group_per_dataflow():
    tb = template("cora_trace")
    res = evaluate_scenarios(tb.scenarios)
    assert res.n_evaluations == len(ALL_DATAFLOWS)
    trace = resolve_trace_dataset("cora", {"seed": 0.0, "alpha": 1.6})
    assert (trace.n_nodes, trace.n_edges) == (CORA_V, CORA_E)
    # kept in sync with the Cora workload config's shape cell
    configs = pytest.importorskip("repro.configs")
    cell = configs.GNN_SHAPES["full_graph_sm"].params
    assert (cell["n_nodes"], cell["n_edges"]) == (CORA_V, CORA_E)


def test_workload_bridge_trace_kind():
    configs = pytest.importorskip("repro.configs")
    arch = configs.get_arch("gcn-cora")
    scens = arch.to_scenarios(shapes=("full_graph_sm", "molecule"),
                              dataflows=("engn",), graph_kind="trace")
    assert [s.graph["dataset"] for s in scens] == ["cora", "molecule"]
    res = evaluate_scenarios(scens)
    for r in res.results:
        assert np.isfinite(r.total_bits) and r.total_bits > 0
    with pytest.raises(ValueError, match="trace"):
        configs.get_arch("smollm-135m").to_scenarios(graph_kind="trace")
    with pytest.raises(ValueError, match="graph_kind"):
        arch.to_scenarios(graph_kind="bogus")


def test_trace_scenarios_from_graph_helper():
    ga = synthetic.power_law_graph(5, n_nodes=800, n_edges=5000, d_feat=1,
                                   self_loops=False)
    scens = trace_scenarios_from_graph(ga, "scratch_graph",
                                       dataflows=("engn", "hygcn"),
                                       tile_vertices=(200.0,),
                                       widths=(32.0, 8.0), overwrite=True)
    assert len(scens) == 2
    assert all(s.graph["dataset"] == "scratch_graph" for s in scens)
    res = evaluate_scenarios(scens)
    assert res.n_evaluations == 2
    assert all(r.total_bits > 0 for r in res.results)
    with pytest.raises(ValueError, match="N and T"):
        trace_scenarios_from_graph(ga, "scratch_graph2")
    assert "scratch_graph" in trace_dataset_names()


# ---------------------------------------------------------------------------
# Schema validation of the trace kind.
# ---------------------------------------------------------------------------
def test_trace_schema_rejections():
    ok = {"dataset": "power_law",
          "params": {"n_nodes": 100.0, "n_edges": 500.0}, "N": 30.0,
          "T": 5.0}
    with pytest.raises(ValueError, match="tile_vertices"):
        Scenario(dataflow="engn", graph=dict(ok, kind="trace"))
    with pytest.raises(ValueError, match="missing"):
        Scenario(dataflow="engn", graph={"kind": "trace", "N": 1.0, "T": 1.0},
                 composition={"tile_vertices": 64})
    with pytest.raises(ValueError, match="unknown trace-graph keys"):
        Scenario(dataflow="engn", graph=dict(ok, V=9.0),
                 composition={"tile_vertices": 64})
    with pytest.raises(ValueError, match="unknown graph kind"):
        Scenario(dataflow="engn", graph={"kind": "mesh"})
    with pytest.raises(ValueError, match="halo_dedup"):
        Scenario(dataflow="engn", graph=dict(ok, kind="trace"),
                 composition={"tile_vertices": 64, "halo_dedup": 2.0})
    with pytest.raises(ValueError, match="non-negative"):
        Scenario(dataflow="engn", graph=dict(ok, N=-3.0),
                 composition={"tile_vertices": 64})
    with pytest.raises(TypeError, match="pure"):
        Scenario(dataflow="engn",
                 graph=dict(ok, params={"n_nodes": "100"}),
                 composition={"tile_vertices": 64})
    with pytest.raises(ValueError, match="dataset"):
        Scenario(dataflow="engn", graph=dict(ok, dataset=""),
                 composition={"tile_vertices": 64})
    # unknown dataset names surface at evaluation time
    with pytest.raises(KeyError, match="unknown trace dataset"):
        evaluate_scenario(Scenario.trace(
            "engn", dataset="no_such_set", N=1.0, T=1.0, tile_vertices=64.0))


def test_graph_trace_input_validation():
    with pytest.raises(ValueError, match="equal length"):
        GraphTrace(np.array([0, 1]), np.array([1]), 2)
    with pytest.raises(ValueError, match="integer"):
        GraphTrace(np.array([0.5]), np.array([1.0]), 2)
    with pytest.raises(ValueError, match="endpoints"):
        GraphTrace(np.array([0, 5]), np.array([1, 0]), 3)
    with pytest.raises(ValueError, match="n_nodes"):
        GraphTrace(np.array([], np.int64), np.array([], np.int64), 0)
    with pytest.raises(ValueError, match="whole number"):
        resolve_trace_dataset(
            "ring_of_tiles",
            {"n_nodes": 100, "n_tiles": 4}).schedule(12.5)


def test_tiled_graph_model_trace_guards():
    trace = resolve_trace_dataset("ring_of_tiles",
                                  {"n_nodes": 100, "n_tiles": 4})
    # 1-D capacity arrays are the capacity axis (DESIGN.md §13); only
    # higher ranks are rejected.
    with pytest.raises(ValueError, match="1-D"):
        TiledGraphModel("engn", tile_vertices=np.array([[64.0, 128.0]]),
                        trace=trace)
    multi = TiledGraphModel("engn", tile_vertices=np.array([25.0, 50.0]),
                            trace=trace)
    out = multi.evaluate(FullGraphParams(V=np.array([100.0, 100.0]),
                                         E=np.array([400.0, 400.0]),
                                         N=np.array([30.0, 30.0]),
                                         T=np.array([5.0, 5.0])))
    for cap, row in zip((25.0, 50.0), range(2)):
        lone = TiledGraphModel("engn", tile_vertices=cap, trace=trace).evaluate(
            FullGraphParams(V=100.0, E=400.0, N=30.0, T=5.0))
        assert float(out.total_bits()[row]) == float(lone.total_bits())
    with pytest.raises(ValueError, match="halo_dedup"):
        TiledGraphModel("engn", tile_vertices=25, halo_dedup=2.0, trace=trace)
    with pytest.raises(TypeError, match="GraphTrace"):
        TiledGraphModel("engn", tile_vertices=25, trace="not a trace")
    model = TiledGraphModel("engn", tile_vertices=25, trace=trace)
    with pytest.raises(ValueError, match="does not match the trace"):
        model.evaluate(FullGraphParams(V=999, E=400, N=30, T=5))


# ---------------------------------------------------------------------------
# Satellite: power-law generator can no longer emit self loops.
# ---------------------------------------------------------------------------
def test_power_law_graph_declash_never_reintroduces_self_loops():
    # Tiny vertex sets + flat exponents force many sender==receiver
    # clashes, the regime where the old modular-increment de-clash was
    # fragile (and biased every clashing edge toward sender + 1).
    for seed in range(8):
        for n_nodes in (2, 3, 5, 17):
            ga = synthetic.power_law_graph(seed, n_nodes=n_nodes,
                                           n_edges=2000, d_feat=1,
                                           alpha=0.2, self_loops=False)
            assert not np.any(ga.senders == ga.receivers), (seed, n_nodes)
            assert ga.n_edges == 2000
    # determinism in (seed, params) is part of the trace-dataset contract
    a = synthetic.power_law_graph(3, n_nodes=50, n_edges=400, d_feat=1)
    b = synthetic.power_law_graph(3, n_nodes=50, n_edges=400, d_feat=1)
    np.testing.assert_array_equal(a.senders, b.senders)
    np.testing.assert_array_equal(a.receivers, b.receivers)
    # the degenerate case where self loops are unavoidable is an error,
    # not a silent contract violation
    with pytest.raises(ValueError, match="n_nodes >= 2"):
        synthetic.power_law_graph(0, n_nodes=1, n_edges=10, d_feat=1)


# ---------------------------------------------------------------------------
# Satellite: FullGraphParams / scenario-normalization validation.
# ---------------------------------------------------------------------------
def test_full_graph_params_validates_all_fields():
    good = FullGraphParams(V=100, E=1000, N=30, T=5)
    for field, bad in (("N", -1.0), ("T", -5.0), ("N", float("nan")),
                       ("T", float("inf")), ("high_degree_fraction", -0.1),
                       ("high_degree_fraction", 1.5)):
        with pytest.raises(ValueError, match=field):
            good.replace(**{field: bad})
    with pytest.raises(ValueError, match="high_degree_fraction"):
        FullGraphParams(V=100, E=1000, N=30, T=5,
                        high_degree_fraction=np.array([0.1, 2.0]))
    assert float(good.replace(high_degree_fraction=1.0).high_degree_fraction) == 1.0


def test_scenario_normalization_mirrors_full_graph_validation():
    with pytest.raises(ValueError, match="non-negative"):
        Scenario.full_graph("engn", V=100.0, E=1000.0, N=-30.0, T=5.0)
    with pytest.raises(ValueError, match="non-negative"):
        Scenario.full_graph("engn", V=-100.0, E=1000.0, N=30.0, T=5.0)
    with pytest.raises(ValueError, match="<= 1"):
        Scenario.full_graph("engn", V=100.0, E=1000.0, N=30.0, T=5.0,
                            high_degree_fraction=2.0)


def test_cli_exits_nonzero_on_invalid_graph_values(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"scenarios": [{
        "dataflow": "engn",
        "graph": {"V": 100.0, "E": 1000.0, "N": -30.0, "T": 5.0},
        "composition": {"tile_vertices": 64.0}}]}))
    assert cli_main(["--scenario", str(bad)]) == 2
    bad2 = tmp_path / "bad2.json"
    bad2.write_text(json.dumps({"scenarios": [{
        "dataflow": "engn",
        "graph": {"V": 100.0, "E": 1000.0, "N": 30.0, "T": 5.0,
                  "high_degree_fraction": 3.0},
        "composition": {"tile_vertices": 64.0}}]}))
    assert cli_main(["--scenario", str(bad2)]) == 2


# ---------------------------------------------------------------------------
# Satellite: array-valued halo_dedup.
# ---------------------------------------------------------------------------
def test_tiled_graph_model_supports_array_halo_dedup():
    full = FullGraphParams(V=4096, E=40960, N=30, T=5)
    dedups = np.array([1.0, 2.0, 4.0])
    swept = TiledGraphModel("engn", tile_vertices=512, halo_dedup=dedups)
    out = swept.evaluate(full)
    ref = [TiledGraphModel("engn", tile_vertices=512,
                           halo_dedup=float(d)).evaluate(full)
           for d in dedups]
    np.testing.assert_array_equal(
        out["haloreload"].data_bits,
        [float(r["haloreload"].data_bits) for r in ref])
    # halo scales inversely; everything else is dedup-independent
    assert (float(ref[0]["haloreload"].data_bits)
            == 2 * float(ref[1]["haloreload"].data_bits))
    for bad in (np.array([1.0, 0.5]), np.array([np.nan]), 0.0):
        with pytest.raises(ValueError, match="halo_dedup"):
            TiledGraphModel("engn", tile_vertices=512, halo_dedup=bad)
