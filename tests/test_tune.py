"""Exhaustive-oracle battery for the §15 design-space auto-tuner (ISSUE 7).

Four families of guarantees:

* **Oracle parity** — on every small search space the tuner must be
  *bit-identical* to an independent brute force: enumerate the full
  cross-product in the same canonical order, evaluate each candidate
  with the one-scenario planner path, mask by the SRAM working-set
  model, ``np.argmin``.  Covered for all five registered dataflows,
  uniform full-graph and trace graph kinds, and both residencies —
  hypothesis-driven where installed, seeded deterministic shim
  otherwise (the :mod:`test_properties` pattern).
* **Search invariants** — the winning objective is monotone
  non-increasing as the SRAM budget relaxes; the Pareto frontier is
  pairwise non-dominated and strictly shaped; a one-point space returns
  exactly that point; a budget below every working set raises the typed
  :class:`repro.core.InfeasibleBudgetError`.
* **Cache reuse** — a multi-capacity tune over a trace dataset performs
  exactly ONE sorted-edge factorization and ONE trace build
  (regression-gated via ``trace_cache_info()["stats"]``).
* **CLI contract** — ``--tune`` schema errors (unknown axis, negative
  budget, non-finite objective weight, plain scenario in a tune batch,
  mode mixing) exit 2 with a one-line ``error:`` message; golden-pin
  drift exits 1; plus the previously-unasserted ``--scenario`` error
  exit codes (missing file, invalid JSON, unknown scenario key, unknown
  dataflow, bad expect key).
"""

import json
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback: same shapes, seeded draws
    HAVE_HYPOTHESIS = False

    class _IntStrategy:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledStrategy:
        def __init__(self, elems):
            self.elems = list(elems)

        def draw(self, rng):
            return self.elems[int(rng.integers(len(self.elems)))]

    class st:  # noqa: N801 - mirrors the hypothesis namespace
        integers = staticmethod(lambda lo, hi: _IntStrategy(lo, hi))
        sampled_from = staticmethod(_SampledStrategy)

    def settings(**_kw):
        return lambda fn: fn

    def given(*strategies, n_examples=8):
        def deco(fn):
            import functools
            import inspect

            sig_params = list(inspect.signature(fn).parameters.values())
            drawn = [p.name
                     for p in sig_params[len(sig_params) - len(strategies):]]

            @functools.wraps(fn)
            def wrapper(**kwargs):
                rng = np.random.default_rng(0)
                for _ in range(n_examples):
                    fn(**kwargs, **{nm: s.draw(rng)
                                    for nm, s in zip(drawn, strategies)})

            wrapper.__signature__ = inspect.Signature(
                [p for p in sig_params if p.name not in drawn])
            return wrapper
        return deco

from repro.api import (Composition, Scenario, evaluate_scenario,
                       evaluate_scenarios)
from repro.api.cli import main as cli_main
from repro.core import (InfeasibleBudgetError, clear_trace_cache, registry,
                        reset_trace_stats, tile_working_set_bits,
                        trace_cache_info, tune_scenario)
from repro.core.tune import normalize_optimize

ALL_DATAFLOWS = registry.names()

# Tiny molecule-batch trace: token-less dataset (no on-disk schedule
# cache) and far below REPRO_TRACE_CACHE_MIN_EDGES, so every cache
# observation below is about the in-process machinery only.
MOL = {"batch": 8, "n_nodes": 30, "n_edges": 64, "seed": 0, "step": 0}


def uniform_scenario(optimize, V=512, widths=(64, 16, 8), tile_vertices=128,
                     **kw):
    return Scenario.full_graph(
        ALL_DATAFLOWS[0], V=float(V), E=float(8 * V), N=float(widths[0]),
        T=float(widths[-1]), widths=widths, tile_vertices=tile_vertices,
        label="tune-uniform", optimize=optimize, **kw)


def trace_scenario(optimize, params=MOL, widths=(16, 16, 16),
                   tile_vertices=32, **kw):
    return Scenario.trace(
        ALL_DATAFLOWS[0], dataset="molecule", params=params,
        N=float(widths[0]), T=float(widths[-1]), widths=widths,
        tile_vertices=tile_vertices, label="tune-trace",
        optimize=optimize, **kw)


def oracle(scenario):
    """Independent brute force in the tuner's canonical enumeration.

    One planner call per candidate (the un-batched path), feasibility
    from the same working-set closed form, winner by masked
    ``np.argmin`` — the reference the tuner must match bit for bit.
    """
    opt = scenario.optimize
    space = opt["space"]
    comp = scenario.composition
    if scenario.graph_kind == "trace":
        from repro.core import resolve_trace_dataset
        V = float(resolve_trace_dataset(scenario.graph["dataset"],
                                        scenario.graph["params"]).n_nodes)
    else:
        V = float(scenario.graph["V"])
    dataflows = space.get("dataflow")
    dataflows = (registry.names() if dataflows == "all"
                 else tuple(dataflows) if dataflows
                 else (scenario.dataflow,))
    residencies = tuple(space.get("residency") or (comp.residency,))
    halos = tuple(space.get("halo_dedup") or (comp.halo_dedup,))
    if "tile_vertices" in space:
        caps = tuple(space["tile_vertices"])
    elif "n_tiles" in space:
        caps = tuple(float(math.ceil(V / nt)) for nt in space["n_tiles"])
    else:
        caps = (float(comp.tile_vertices),)
    budget = opt["budget"]
    budget_bits = None if budget is None else budget["sram_bits"]

    cands, objs, srams = [], [], []
    for df in dataflows:
        sigma = float(scenario.hardware.get(
            "sigma", registry.get(df).hw_factory().sigma))
        for res in residencies:
            for hd in halos:
                for cap in caps:
                    c = scenario.replace(
                        dataflow=df, optimize=None, expect=None,
                        composition=Composition(
                            widths=comp.widths, residency=res,
                            tile_vertices=cap, halo_dedup=hd))
                    r = evaluate_scenario(c)
                    vals = {"movement": r.total_bits,
                            "offchip": r.offchip_bits,
                            "iterations": r.total_iterations}
                    obj = (float(vals[opt["objective"]])
                           if isinstance(opt["objective"], str) else
                           float(sum(w * vals[k]
                                     for k, w in opt["objective"].items())))
                    cands.append((df, cap, res, hd))
                    objs.append(obj)
                    srams.append(float(tile_working_set_bits(
                        cap, V=V, widths=comp.widths, sigma=sigma,
                        residency=res, halo_dedup=hd)))
    objs = np.asarray(objs)
    srams = np.asarray(srams)
    feas = (np.ones(len(cands), bool) if budget_bits is None
            else srams <= budget_bits)
    best = (None if not feas.any()
            else int(np.argmin(np.where(feas, objs, np.inf))))
    return cands, objs, srams, best


def assert_oracle_parity(scenario):
    cands, objs, srams, best = oracle(scenario)
    tr = tune_scenario(scenario)
    assert tr.method == "exhaustive"
    assert tr.n_candidates == tr.n_evaluated == len(cands)
    # every point, bit for bit, in the oracle's enumeration order
    for i, (p, c) in enumerate(zip(tr.points, cands)):
        assert p.index == i
        assert (p.dataflow, p.tile_vertices, p.residency,
                p.halo_dedup) == (c[0], float(c[1]), c[2], float(c[3]))
        assert p.objective == objs[i]
        assert p.sram_bits == srams[i]
    assert tr.best.index == best
    assert tr.best.objective == objs[best]
    return tr


# ---------------------------------------------------------------------------
# 1. Oracle parity
# ---------------------------------------------------------------------------

def test_uniform_oracle_parity_all_dataflows_both_residencies():
    tr = assert_oracle_parity(uniform_scenario({
        "objective": "movement",
        "space": {"dataflow": "all",
                  "tile_vertices": [64, 128, 256, 512],
                  "residency": ["spill", "resident"]}}))
    assert tr.n_candidates == len(ALL_DATAFLOWS) * 2 * 4
    # capacity batches along the planner axis: one broadcast group per
    # (dataflow, residency) cell, never one per capacity
    assert tr.n_groups == len(ALL_DATAFLOWS) * 2


def test_trace_oracle_parity_all_dataflows_both_residencies():
    assert_oracle_parity(trace_scenario({
        "objective": "movement",
        "space": {"dataflow": "all",
                  "tile_vertices": [16, 32, 64],
                  "residency": ["spill", "resident"]}}))


@pytest.mark.parametrize("objective",
                         ["offchip", "iterations",
                          {"movement": 1.0, "iterations": 5e3}])
def test_oracle_parity_alternate_objectives(objective):
    assert_oracle_parity(uniform_scenario({
        "objective": objective,
        "space": {"dataflow": "all", "tile_vertices": [64, 256]}}))


def test_oracle_parity_halo_and_n_tiles_axes():
    assert_oracle_parity(uniform_scenario({
        "objective": "movement",
        "space": {"n_tiles": [1, 2, 4, 8],
                  "halo_dedup": [1.0, 2.0, 4.0]}}))


def test_oracle_parity_budgeted():
    tr = assert_oracle_parity(uniform_scenario({
        "objective": "movement",
        "budget": {"sram_bits": 6e4},
        "space": {"dataflow": "all",
                  "tile_vertices": [64, 128, 256, 512],
                  "residency": ["spill", "resident"]}}))
    assert tr.best.sram_bits <= 6e4
    assert tr.n_feasible < tr.n_candidates  # the budget actually bites


@settings(max_examples=10, deadline=None) if HAVE_HYPOTHESIS else (lambda f: f)
@given(st.integers(64, 2048), st.integers(2, 64), st.integers(1, 4),
       st.sampled_from(["movement", "offchip", "iterations"]))
def test_oracle_parity_hypothesis(V, w_hidden, n_caps, objective):
    caps = [2 ** (4 + i) for i in range(n_caps)]
    assert_oracle_parity(uniform_scenario(
        {"objective": objective,
         "space": {"dataflow": "all", "tile_vertices": caps,
                   "residency": ["spill", "resident"]}},
        V=V, widths=(32, w_hidden, 8)))


def test_coordinate_descent_matches_exhaustive_here():
    """On these small well-behaved spaces the memoized coordinate descent
    lands on the same winner as the oracle (it is guaranteed to when at
    most one axis is multi-valued; these spaces are also unimodal enough
    per axis that the restart schedule finds the global best)."""
    opt = {"objective": "movement",
           "space": {"dataflow": "all",
                     "tile_vertices": [64, 128, 256, 512],
                     "residency": ["spill", "resident"]}}
    ex = tune_scenario(uniform_scenario(opt))
    co = tune_scenario(uniform_scenario({**opt, "method": "coordinate"}))
    assert co.method == "coordinate"
    assert co.n_evaluated < co.n_candidates or co.n_candidates <= 8
    assert co.best.objective == ex.best.objective
    assert (co.best.dataflow, co.best.tile_vertices, co.best.residency) == \
        (ex.best.dataflow, ex.best.tile_vertices, ex.best.residency)


def test_auto_method_switches_on_max_exhaustive():
    opt = {"objective": "movement",
           "space": {"tile_vertices": [64, 128, 256, 512]}}
    assert tune_scenario(uniform_scenario(opt)).method == "exhaustive"
    small = tune_scenario(uniform_scenario({**opt, "max_exhaustive": 2}))
    assert small.method == "coordinate"
    # capacity is the only multi-valued axis: one full sweep of it is a
    # complete enumeration, so even the descent path is oracle-exact
    full = tune_scenario(uniform_scenario(opt))
    assert small.best.objective == full.best.objective
    assert small.best.index == full.best.index


# ---------------------------------------------------------------------------
# 2. Search invariants
# ---------------------------------------------------------------------------

def test_objective_monotone_as_budget_relaxes():
    space = {"dataflow": "all", "tile_vertices": [64, 128, 256, 512],
             "residency": ["spill", "resident"]}
    open_tr = tune_scenario(uniform_scenario(
        {"objective": "movement", "space": space}))
    srams = sorted({p.sram_bits for p in open_tr.points})
    prev = math.inf
    for budget in srams:
        tr = tune_scenario(uniform_scenario(
            {"objective": "movement", "space": space,
             "budget": {"sram_bits": budget}}))
        assert tr.best.sram_bits <= budget
        assert tr.best.objective <= prev
        prev = tr.best.objective
    # fully relaxed == unconstrained winner
    assert prev == open_tr.best.objective


def test_pareto_frontier_is_nondominated_and_strictly_shaped():
    tr = tune_scenario(uniform_scenario({
        "objective": "movement",
        "space": {"dataflow": "all",
                  "tile_vertices": [64, 128, 256, 512],
                  "residency": ["spill", "resident"]}}))
    fr = tr.frontier
    assert fr, "open-budget tune must produce a frontier"
    # strictly increasing sram, strictly decreasing objective
    for a, b in zip(fr, fr[1:]):
        assert a.sram_bits < b.sram_bits
        assert a.objective > b.objective
    # pairwise non-domination over the whole feasible point set
    feas = [p for p in tr.points if p.feasible]
    for p in fr:
        for q in feas:
            assert not (q.sram_bits <= p.sram_bits
                        and q.objective < p.objective)
    # the unconstrained winner is the frontier's last (largest-sram) point
    assert fr[-1].objective == tr.best.objective


def test_one_point_space_returns_that_point():
    base = uniform_scenario(None)
    tr = tune_scenario(base.replace(optimize={
        "objective": "movement",
        "space": {"tile_vertices": [base.composition.tile_vertices]}}))
    assert tr.n_candidates == tr.n_evaluated == 1
    assert tr.best.index == 0
    assert tr.best.tile_vertices == base.composition.tile_vertices
    assert tr.best.dataflow == base.dataflow
    # and it equals the plain evaluation of the base scenario
    plain = evaluate_scenario(base)
    assert tr.best.objective == plain.total_bits
    assert tr.best_result.total_bits == plain.total_bits
    assert tr.frontier == tr.points


def test_budget_below_every_footprint_raises_typed_error():
    with pytest.raises(InfeasibleBudgetError, match="below every explored"):
        tune_scenario(uniform_scenario({
            "objective": "movement",
            "budget": {"sram_bits": 1.0},
            "space": {"dataflow": "all", "tile_vertices": [64, 128]}}))
    # the typed error is a ValueError: the CLI's schema handling applies
    assert issubclass(InfeasibleBudgetError, ValueError)


def test_planner_routes_optimize_scenarios_and_orders_results():
    """A mixed batch: plain scenarios keep the broadcast path, optimize
    scenarios route through the tuner, results stay in input order."""
    plain = uniform_scenario(None)
    tuned = uniform_scenario({"objective": "movement",
                              "space": {"tile_vertices": [64, 128, 256]}})
    res = evaluate_scenarios([plain, tuned, plain])
    assert [r.scenario is s for r, s in
            zip(res.results, [plain, tuned, plain])] == [True] * 3
    assert res.results[0].total_bits == res.results[2].total_bits
    t = res.results[1].meta["tune"]
    assert t["best"]["objective"] == res.results[1].total_bits
    assert res.results[1].total_bits <= res.results[0].total_bits
    # evaluate_groups refuses optimize scenarios outright
    from repro.api import evaluate_groups
    with pytest.raises(ValueError, match="evaluate_scenarios"):
        evaluate_groups([tuned])


def test_tune_expect_pins_gate_best_configuration():
    opt = {"objective": "movement",
           "space": {"dataflow": "all", "tile_vertices": [64, 128, 256]}}
    tr = tune_scenario(uniform_scenario(opt))
    good = uniform_scenario(opt, expect={
        "objective": tr.best.objective,
        "best_dataflow": tr.best.dataflow,
        "best_tile_vertices": tr.best.tile_vertices})
    bad = uniform_scenario(opt, expect={"best_dataflow": "no-such-dataflow"})
    res = evaluate_scenarios([good, bad])
    assert res.results[0].expect_ok is True
    assert res.results[1].expect_ok is False


def test_optimize_block_round_trips_and_extends_plan_key():
    s = uniform_scenario({"objective": "movement",
                          "space": {"tile_vertices": [64, 128]}})
    s2 = Scenario.from_dict(json.loads(json.dumps(s.to_dict())))
    assert s2 == s
    assert s2.plan_key() == s.plan_key()
    assert s2.optimize == normalize_optimize(s2.optimize)  # idempotent
    plain = s.replace(optimize=None)
    assert plain.plan_key() != s.plan_key()


def test_optimize_schema_rejections():
    mk = uniform_scenario
    with pytest.raises(ValueError, match="unknown optimize space axis"):
        mk({"space": {"frobnicate": [1]}})
    with pytest.raises(ValueError, match="negative SRAM budget"):
        mk({"budget": {"sram_bits": -5}})
    with pytest.raises(ValueError, match="non-finite objective weight"):
        mk({"objective": {"movement": float("inf")}})
    with pytest.raises(ValueError, match="unknown objective"):
        mk({"objective": "latency"})
    with pytest.raises(ValueError, match="not both"):
        mk({"space": {"tile_vertices": [64], "n_tiles": [2]}})
    with pytest.raises(ValueError, match="exactly one"):
        mk({"budget": {"sram_bits": 1e6, "sram_bytes": 1e5}})
    with pytest.raises(ValueError, match="must not be empty"):
        mk({"space": {"tile_vertices": []}})
    with pytest.raises((ValueError, TypeError), match="optimize"):
        Scenario.tile(ALL_DATAFLOWS[0], optimize={"objective": "movement"})
    with pytest.raises(ValueError, match="mutually exclusive"):
        mk({"objective": "movement"}, conformance=True)
    with pytest.raises(ValueError, match="resident"):
        Scenario.trace(ALL_DATAFLOWS[0], dataset="molecule", params=MOL,
                       N=16.0, T=16.0, widths=None,
                       optimize={"space": {"residency": ["resident"]}})


# ---------------------------------------------------------------------------
# 3. Cache reuse: one factorization per dataset per tune run
# ---------------------------------------------------------------------------

def test_multi_capacity_trace_tune_is_one_factorization():
    params = {**MOL, "step": 7}  # fresh params: miss any earlier LRU entry
    clear_trace_cache()
    reset_trace_stats()
    tr = tune_scenario(trace_scenario({
        "objective": "movement",
        "space": {"dataflow": "all",
                  "tile_vertices": [8, 16, 32, 64, 128]}}, params=params))
    stats = trace_cache_info()["stats"]
    assert stats["trace_builds"] == 1
    assert stats["factorizations"] == 1
    # every (dataflow, capacity) cell evaluated, one schedule per capacity
    assert tr.n_evaluated == len(ALL_DATAFLOWS) * 5
    assert stats["schedule_computes"] == 5
    assert stats["schedule_cache_hits"] >= (len(ALL_DATAFLOWS) - 1) * 5


def test_reset_trace_stats_zeroes_all_counters():
    reset_trace_stats()
    stats = trace_cache_info()["stats"]
    assert set(stats) == {"factorizations", "schedule_computes",
                          "schedule_cache_hits", "schedule_disk_hits",
                          "trace_builds"}
    assert all(v == 0 for v in stats.values())


# ---------------------------------------------------------------------------
# 4. CLI contract: exit codes and one-line errors
# ---------------------------------------------------------------------------

def _tune_batch(tmp_path, mutate=None, name="batch.json"):
    s = uniform_scenario({"objective": "movement",
                          "space": {"dataflow": "all",
                                    "tile_vertices": [64, 128, 256]}})
    batch = {"scenarios": [s.to_dict()]}
    if mutate is not None:
        mutate(batch)
    path = tmp_path / name
    path.write_text(json.dumps(batch))
    return str(path)


def test_cli_tune_happy_path_writes_json(tmp_path, capsys):
    out = tmp_path / "BENCH_tune.json"
    rc = cli_main(["--tune", _tune_batch(tmp_path), "--json", str(out)])
    cap = capsys.readouterr()
    assert rc == 0
    assert "best_dataflow" in cap.out
    payload = json.loads(out.read_text())
    assert payload["status"] == "ok"
    t = payload["results"][0]["tune"]
    assert t["method"] == "exhaustive"
    assert t["best"]["feasible"] is True
    assert len(t["points"]) == t["n_evaluated"]


@pytest.mark.parametrize("mutate,msg", [
    (lambda b: b["scenarios"][0]["optimize"]["space"].update(bogus=[1]),
     "unknown optimize space axis"),
    (lambda b: b["scenarios"][0]["optimize"].update(
        budget={"sram_bits": -1}), "negative SRAM budget"),
    (lambda b: b["scenarios"][0]["optimize"].update(
        objective={"movement": float("inf")}), "non-finite objective weight"),
    (lambda b: b["scenarios"][0].pop("optimize"), "no 'optimize' block"),
], ids=["unknown-axis", "negative-budget", "inf-weight", "plain-scenario"])
def test_cli_tune_schema_errors_exit_2(tmp_path, capsys, mutate, msg):
    rc = cli_main(["--tune", _tune_batch(tmp_path, mutate)])
    cap = capsys.readouterr()
    assert rc == 2
    err_lines = [ln for ln in cap.err.splitlines() if ln.startswith("error:")]
    assert len(err_lines) == 1 and msg in err_lines[0]


def test_cli_tune_infeasible_budget_exits_2(tmp_path, capsys):
    path = _tune_batch(tmp_path, lambda b: b["scenarios"][0]["optimize"]
                       .update(budget={"sram_bits": 1}))
    rc = cli_main(["--tune", path])
    cap = capsys.readouterr()
    assert rc == 2
    assert "below every explored configuration" in cap.err


def test_cli_tune_refuses_mode_mixing(tmp_path, capsys):
    rc = cli_main(["--tune", _tune_batch(tmp_path), "--template", "fig3"])
    cap = capsys.readouterr()
    assert rc == 2
    assert "cannot be combined" in cap.err


def test_cli_tune_pin_drift_exits_1(tmp_path, capsys):
    path = _tune_batch(
        tmp_path, lambda b: b["scenarios"][0].update(
            expect={"best_dataflow": "no-such-dataflow"}))
    rc = cli_main(["--tune", path])
    cap = capsys.readouterr()
    assert rc == 1
    assert "GOLDEN DRIFT" in cap.err


@pytest.mark.parametrize("argv,msg", [
    (["--scenario", "{tmp}/no-such-file.json"], "error:"),
    (["--scenario", "{tmp}/invalid.json"], "error:"),
    (["--scenario", "{tmp}/unknown-key.json"], "error:"),
    (["--scenario", "{tmp}/unknown-dataflow.json"], "error:"),
    (["--scenario", "{tmp}/bad-expect.json"], "error:"),
    ([], "no scenarios given"),
], ids=["missing-file", "invalid-json", "unknown-scenario-key",
        "unknown-dataflow", "bad-expect-key", "no-sources"])
def test_cli_scenario_error_paths_exit_2(tmp_path, capsys, argv, msg):
    (tmp_path / "invalid.json").write_text("{not json")
    tile = Scenario.tile(ALL_DATAFLOWS[0]).to_dict()
    (tmp_path / "unknown-key.json").write_text(
        json.dumps({"scenarios": [{**tile, "frobnicate": 1}]}))
    (tmp_path / "unknown-dataflow.json").write_text(
        json.dumps({"scenarios": [{**tile, "dataflow": "no-such"}]}))
    (tmp_path / "bad-expect.json").write_text(
        json.dumps({"scenarios": [{**tile, "expect": {"bogus_key": 1.0}}]}))
    rc = cli_main([a.format(tmp=tmp_path) for a in argv])
    cap = capsys.readouterr()
    assert rc == 2
    assert msg in cap.err


def test_cli_scenario_pin_drift_exits_1(tmp_path, capsys):
    tile = Scenario.tile(ALL_DATAFLOWS[0]).to_dict()
    path = tmp_path / "drift.json"
    path.write_text(json.dumps(
        {"scenarios": [{**tile, "expect": {"total_bits": 123.0}}]}))
    rc = cli_main(["--scenario", str(path)])
    cap = capsys.readouterr()
    assert rc == 1
    assert "GOLDEN DRIFT" in cap.err
