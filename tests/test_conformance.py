"""Measured-vs-modeled conformance battery (DESIGN.md §10).

The acceptance bar of the conformance subsystem: on CPU (``interpret=True``
compilation + ``cost_analysis``/HLO parsing), measured HBM bytes of the
fused ``edge_aggregate`` kernel and the unfused two-pass pair must sit
within each record's declared tolerance of the ``spmm_tiled`` /
``spmm_unfused`` (HyGCN-analogue) analytical predictions across the whole
operating-point sweep — and the fused-minus-unfused measured delta must
equal the paper's eliminated ``K*N*sigma + P_s*N*sigma`` inter-phase terms.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

# Kernel-compiling battery: the whole module carries the `slow` marker so
# the fast inner loop (`pytest -m "not slow"`) skips the compiles while the
# default tier-1 run keeps them.
pytestmark = pytest.mark.slow

from repro.core import registry
from repro.core.conformance import (ConformanceRecord, OperatingPoint,
                                    conformance_records,
                                    default_operating_points,
                                    interphase_delta_records, run_conformance,
                                    schedule_stream_bytes, summarize_records,
                                    verify_numerics)
from repro.core.validation import crosscheck_registry

POINTS = default_operating_points()


def _records_cached(name):
    """Compile each dataflow's sweep once per session (compiles are slow)."""
    if name not in _records_cached.cache:
        spec = registry.get(name)
        analogue = spec.runnable_analogue()
        _records_cached.cache[name] = [
            r for pt in POINTS
            for r in conformance_records(spec, pt, analogue=analogue)]
    return _records_cached.cache[name]


_records_cached.cache = {}


# ---------------------------------------------------------------------------
# The acceptance criterion: >= 8 operating points, every record within its
# declared tolerance, for both the fused kernel and the unfused pair.
# ---------------------------------------------------------------------------
def test_sweep_has_at_least_eight_operating_points():
    assert len(POINTS) >= 8
    # the sweep varies node-block size, feature width, and kernel tile shape
    assert len({p.K for p in POINTS}) >= 2
    assert len({p.N for p in POINTS}) >= 2
    assert len({(p.Bn, p.Bk) for p in POINTS}) >= 3


@pytest.mark.parametrize("name", ["spmm_tiled", "spmm_unfused"])
def test_measured_hbm_bytes_conform_across_sweep(name):
    records = _records_cached(name)
    assert len(records) >= 8 * len(POINTS) / 2
    for r in records:
        assert r.ok, f"conformance violation: {r}"


@pytest.mark.parametrize("name", ["spmm_tiled", "spmm_unfused"])
def test_per_movement_attribution_is_exact(name):
    """Every off-chip movement level is individually pinned: the traced DMA
    schedule of the compiled kernel equals the closed form, per level."""
    spec = registry.get(name)
    offchip = {m.name for m in spec.movements if m.hierarchy != "L1-L1"}
    records = [r for r in _records_cached(name) if r.source == "block_schedule"
               and r.movement in offchip]
    assert {r.movement for r in records} == offchip
    for r in records:
        assert r.analytical_bytes > 0
        np.testing.assert_allclose(r.measured_bytes, r.analytical_bytes,
                                   rtol=r.tolerance)


@pytest.mark.parametrize("name", ["spmm_tiled", "spmm_unfused"])
def test_compiled_boundary_matches_block_cover(name):
    """The compiled executable's ENTRY operand/result bytes equal the
    distinct-block footprint of the declared streams at every point."""
    for r in _records_cached(name):
        if r.source == "entry_boundary" and r.movement.startswith("boundary"):
            assert r.ok and r.analytical_bytes > 0, str(r)


@pytest.mark.parametrize("name", ["spmm_tiled", "spmm_unfused"])
def test_cost_analysis_respects_boundary_floor(name):
    """XLA's own bytes-accessed accounting can only exceed the boundary."""
    records = [r for r in _records_cached(name) if r.source == "cost_analysis"]
    assert records
    for r in records:
        assert r.one_sided and r.ok, str(r)
        assert r.measured_bytes >= r.analytical_bytes


@pytest.mark.parametrize("name", ["spmm_tiled", "spmm_unfused"])
def test_single_device_programs_move_no_collective_bytes(name):
    records = [r for r in _records_cached(name)
               if r.source == "hlo_collectives"]
    assert len(records) == len(POINTS)
    for r in records:
        assert r.measured_bytes == 0.0 and r.ok


# ---------------------------------------------------------------------------
# The fusion claim, measured: fused-minus-unfused == eliminated inter-phase.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pt", POINTS[:4] + POINTS[-2:],
                         ids=lambda p: f"K{p.K}N{p.N}Bn{p.Bn}Bk{p.Bk}")
def test_interphase_delta_matches_paper_terms(pt):
    """The measured fused-vs-unfused HBM delta is exactly the paper's
    eliminated K*N*sigma write + P_s*N*sigma read (P_s = K, DESIGN.md §10),
    at both the executable boundary and in the traced DMA schedule."""
    recs = interphase_delta_records(pt)
    assert {r.source for r in recs} == {"entry_boundary", "block_schedule"}
    # K*N*sigma bits each way, sigma = 32 (f32), padded Bn | K here.
    expect_bytes = 2 * pt.K * pt.N * pt.elem_bytes
    for r in recs:
        assert r.analytical_bytes == expect_bytes
        np.testing.assert_allclose(r.measured_bytes, expect_bytes,
                                   rtol=r.tolerance)
        assert r.ok


# ---------------------------------------------------------------------------
# Kernel numerics: the measured programs compute the right thing.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pt", [POINTS[0], POINTS[-2]],
                         ids=lambda p: f"K{p.K}N{p.N}Bn{p.Bn}Bk{p.Bk}")
def test_measured_kernels_match_oracle(pt):
    assert verify_numerics(pt) < 1e-5


# ---------------------------------------------------------------------------
# Harness surface.
# ---------------------------------------------------------------------------
def test_run_conformance_covers_all_runnable_dataflows():
    pts = (OperatingPoint(256, 16, 8, 128, 128),)
    records = run_conformance(points=pts)
    flows = {r.dataflow for r in records}
    assert set(registry.runnable_names()) <= flows
    assert any(r.movement == "interphase_delta" for r in records)
    summary = summarize_records(records)
    assert summary["all_ok"] and summary["n_ok"] == summary["n_records"]
    assert set(summary["by_dataflow"]) == flows


def test_schedule_trace_elides_revisited_blocks():
    """The trace implements Pallas's revisit elision: a constant index map
    transfers once; an innermost-varying one transfers every step."""
    resident = schedule_stream_bytes(
        (4, 4), {"block_shape": (8, 8), "index_map": lambda i, j: (0, 0),
                 "elem_bytes": 4.0, "kind": "read"})
    assert resident["transfers"] == 1
    assert resident["bytes"] == 8 * 8 * 4.0
    streaming = schedule_stream_bytes(
        (4, 4), {"block_shape": (8, 8), "index_map": lambda i, j: (j, 0),
                 "elem_bytes": 4.0, "kind": "read"})
    assert streaming["transfers"] == 16          # j changes every step
    assert streaming["distinct_blocks"] == 4     # but only 4 distinct blocks


def test_operating_point_rejects_nondividing_blocks():
    with pytest.raises(ValueError, match="divide"):
        OperatingPoint(K=300, N=16, T=8, Bn=128, Bk=128)


def test_runnable_hook_registry_surface():
    assert set(registry.runnable_names()) == {"spmm_tiled", "spmm_unfused"}
    assert registry.get("spmm_tiled").has_runnable
    assert not registry.get("engn").has_runnable
    with pytest.raises(ValueError, match="runnable"):
        registry.get("engn").runnable_analogue()


def test_crosscheck_registry_includes_conformance():
    records = crosscheck_registry(conformance=True)
    for name in registry.runnable_names():
        rec = records[f"{name}::conformance"]
        assert rec.ratio == pytest.approx(1.0, rel=1e-9)
    # default call unchanged: no conformance keys, same name set.
    assert set(crosscheck_registry()) == set(registry.names())
