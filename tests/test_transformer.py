"""Transformer unit tests: forward/train/decode parity across the three
structural variants (dense GQA, gemma-style local/global + softcaps, MoE
with dense residual)."""

import jax
import jax.numpy as jnp
import pytest

from repro.models.moe import MoEConfig
from repro.models.transformer import (DecodePolicy, TransformerConfig,
                                      forward, init_cache, init_params,
                                      loss_fn, make_prefill_step,
                                      make_serve_step, make_train_step)
from repro.optim.optimizers import adamw

DENSE = TransformerConfig(name="tiny-dense", n_layers=4, d_model=32, n_heads=4,
                          n_kv_heads=2, d_head=8, d_ff=64, vocab=128,
                          dtype="float32", q_chunk=8)
GEMMA = TransformerConfig(name="tiny-gemma", n_layers=4, d_model=32, n_heads=4,
                          n_kv_heads=2, d_head=8, d_ff=64, vocab=128,
                          window_pattern=(8, None), attn_softcap=50.0,
                          final_softcap=30.0, dtype="float32", q_chunk=8)
MOE = TransformerConfig(name="tiny-moe", n_layers=2, d_model=32, n_heads=4,
                        n_kv_heads=4, d_head=8, d_ff=64, vocab=128,
                        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                                      capacity_factor=2.0,
                                      dense_residual_d_ff=32),
                        dtype="float32", q_chunk=8)


def _rel(a, b):
    return float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))


@pytest.mark.parametrize("cfg", [DENSE, GEMMA, MOE], ids=lambda c: c.name)
def test_forward_and_train(cfg):
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    logits, aux = jax.jit(lambda p, t: forward(cfg, p, t))(params, tokens)
    assert logits.shape == (B, S, cfg.vocab)
    assert not jnp.isnan(logits).any()
    opt = adamw(1e-3)
    st = opt.init(params)
    p2, st2, m = jax.jit(make_train_step(cfg, opt))(
        params, st, {"tokens": tokens, "labels": tokens})
    assert jnp.isfinite(m["loss"])
    # params actually changed
    changed = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p2)
    assert max(jax.tree_util.tree_leaves(changed)) > 0


@pytest.mark.parametrize("cfg", [DENSE, GEMMA, MOE], ids=lambda c: c.name)
def test_decode_matches_forward(cfg):
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    logits, _ = jax.jit(lambda p, t: forward(cfg, p, t))(params, tokens)
    cache = init_cache(cfg, B, S)
    serve = jax.jit(make_serve_step(cfg, S))
    for i in range(S):
        lg, cache = serve(params, cache, tokens[:, i:i + 1],
                          jnp.asarray(i, jnp.int32))
    assert _rel(lg, logits[:, -1]) < 1e-4


def test_prefill_matches_decode_and_continues():
    cfg = GEMMA
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S + 4), 0, cfg.vocab)
    prefill = jax.jit(make_prefill_step(cfg, max_seq=S + 4))
    serve = jax.jit(make_serve_step(cfg, S + 4))
    # decode path from scratch
    cache_d = init_cache(cfg, B, S + 4)
    for i in range(S):
        lg_d, cache_d = serve(params, cache_d, tokens[:, i:i + 1],
                              jnp.asarray(i, jnp.int32))
    lg_p, cache_p = prefill(params, tokens[:, :S])
    assert _rel(lg_p, lg_d) < 1e-4
    # continue decoding from the prefilled cache
    for i in range(S, S + 4):
        lg_p, cache_p = serve(params, cache_p, tokens[:, i:i + 1],
                              jnp.asarray(i, jnp.int32))
        lg_d, cache_d = serve(params, cache_d, tokens[:, i:i + 1],
                              jnp.asarray(i, jnp.int32))
    assert _rel(lg_p, lg_d) < 1e-4


def test_window_pattern_restricts_attention():
    """A token outside every window must not influence the next-token
    logits in a windowed-only model."""
    cfg = TransformerConfig(name="w", n_layers=2, d_model=32, n_heads=4,
                            n_kv_heads=4, d_head=8, d_ff=64, vocab=64,
                            window_pattern=(4,), dtype="float32", q_chunk=8)
    params = init_params(cfg, jax.random.key(0))
    t1 = jax.random.randint(jax.random.key(1), (1, 16), 0, cfg.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab)  # perturb distant token
    l1, _ = forward(cfg, params, t1)
    l2, _ = forward(cfg, params, t2)
    # last position attends only to the final 4 tokens at every layer; with
    # 2 layers the receptive field is 7 < 16, so position 0 cannot leak.
    assert _rel(l1[:, -1], l2[:, -1]) < 1e-6


def test_param_count_formulas():
    assert abs(DENSE.param_count() -
               sum(x.size for x in jax.tree_util.tree_leaves(
                   init_params(DENSE, jax.random.key(0))))) == 0
    assert abs(MOE.param_count() -
               sum(x.size for x in jax.tree_util.tree_leaves(
                   init_params(MOE, jax.random.key(0))))) == 0
    assert MOE.active_param_count() < MOE.param_count()
