"""Property-based invariant battery over every registered dataflow.

Three families of invariants (ISSUE 2 satellite):

* every registered dataflow produces finite, non-negative bits/iterations,
  monotone non-decreasing in tile vertices (K <-> V), edges (P <-> E), and
  feature width (N) — the physical sanity the paper's closed forms imply
  but never state;
* ``MultiLayerModel`` with L=1 and ``"spill"`` residency is the base spec,
  per term, bit for bit;
* ``TiledGraphModel`` with tile capacity >= V degenerates to one tile with
  zero halo-reload bits.

Runs under hypothesis when installed; otherwise a deterministic shim draws
seeded samples from the same strategy ranges so the battery still executes
(the repo's other property modules importorskip hypothesis — these
invariants are pure float64 algebra and too cheap to skip).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback: same shapes, seeded draws
    HAVE_HYPOTHESIS = False

    class _IntStrategy:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledStrategy:
        def __init__(self, elems):
            self.elems = list(elems)

        def draw(self, rng):
            return self.elems[int(rng.integers(len(self.elems)))]

    class st:  # noqa: N801 - mirrors the hypothesis namespace
        integers = staticmethod(lambda lo, hi: _IntStrategy(lo, hi))
        sampled_from = staticmethod(_SampledStrategy)

    def settings(**_kw):
        return lambda fn: fn

    def given(*strategies, n_examples=12):
        """Like hypothesis.given: strategies fill the test's trailing
        parameters (by name, so pytest.parametrize kwargs compose)."""
        def deco(fn):
            import functools
            import inspect

            sig_params = list(inspect.signature(fn).parameters.values())
            drawn = [p.name for p in sig_params[len(sig_params) - len(strategies):]]

            @functools.wraps(fn)
            def wrapper(**kwargs):
                rng = np.random.default_rng(0)
                for _ in range(n_examples):
                    fn(**kwargs,
                       **{nm: s.draw(rng) for nm, s in zip(drawn, strategies)})

            # hide the drawn parameters from pytest's fixture resolution
            wrapper.__signature__ = inspect.Signature(
                [p for p in sig_params if p.name not in drawn])
            return wrapper
        return deco

from repro.core import (FullGraphParams, MultiLayerModel, TiledGraphModel,
                        paper_default_graph, registry)

ALL_DATAFLOWS = registry.names()


def _point(rng_k, n, t):
    return paper_default_graph(float(rng_k)).replace(N=float(n), T=float(t))


def _totals(name, graph):
    out = registry.evaluate(name, graph)
    return float(out.total_bits()), float(out.total_iterations())


# ---------------------------------------------------------------------------
# Finite, non-negative movement at arbitrary operating points.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_DATAFLOWS)
@settings(max_examples=20, deadline=None)
@given(st.integers(1, 1 << 20), st.integers(1, 4096), st.integers(1, 512))
def test_movement_finite_and_nonnegative(name, K, N, T):
    out = registry.evaluate(name, _point(K, N, T))
    for term in out.terms:
        assert np.all(np.isfinite(term.data_bits)), (name, term.name)
        assert np.all(np.isfinite(term.iterations)), (name, term.name)
        assert np.all(term.data_bits >= 0), (name, term.name)
        assert np.all(term.iterations >= 0), (name, term.name)


# ---------------------------------------------------------------------------
# Monotone non-decreasing in vertices, edges, and feature width.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_DATAFLOWS)
@pytest.mark.parametrize("param", ["K", "P", "N"])
@settings(max_examples=15, deadline=None)
@given(st.integers(2, 1 << 16), st.integers(2, 1024), st.integers(1, 12))
def test_movement_monotone(name, param, K, N, factor):
    base = _point(K, N, 8)
    bigger = base.replace(**{param: float(getattr(base, param)) * factor})
    b0, i0 = _totals(name, base)
    b1, i1 = _totals(name, bigger)
    assert b1 >= b0, (name, param, K, N, factor)
    assert i1 >= i0, (name, param, K, N, factor)


# ---------------------------------------------------------------------------
# Composition-layer identities.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_DATAFLOWS)
@settings(max_examples=10, deadline=None)
@given(st.integers(2, 1 << 14), st.integers(1, 512), st.integers(1, 256))
def test_single_layer_spill_is_base_spec(name, K, N, T):
    """MultiLayerModel(L=1, spill) == the base spec, per term, exactly."""
    graph = _point(K, N, T)
    base = registry.evaluate(name, graph)
    ml = MultiLayerModel(name, [N, T], residency="spill").evaluate(graph)
    assert ml.names() == base.names()
    for term in base.terms:
        assert float(ml[term.name].data_bits) == float(term.data_bits)
        assert float(ml[term.name].iterations) == float(term.iterations)


@pytest.mark.parametrize("name", ALL_DATAFLOWS)
@settings(max_examples=10, deadline=None)
@given(st.integers(1, 1 << 14), st.integers(0, 1 << 10), st.integers(1, 256))
def test_tile_capacity_at_least_v_degenerates(name, V, extra_cap, N):
    """Capacity >= V: one tile, zero halo-reload bits, totals == inner."""
    full = FullGraphParams(V=V, E=10 * V, N=N, T=8)
    model = TiledGraphModel(name, tile_vertices=V + extra_cap)
    out = model.evaluate(full)
    n_tiles, tile = model.tile_schedule(full)
    assert float(n_tiles) == 1.0
    assert float(tile.K) == float(V)
    assert float(out["haloreload"].data_bits) == 0.0
    inner = registry.evaluate(name, tile)
    assert float(out.total_bits()) == float(inner.total_bits())


def test_all_registered_dataflows_covered():
    """The battery spans the whole registry (>= 5 dataflows as of PR 2)."""
    assert len(ALL_DATAFLOWS) >= 5
    assert {"engn", "hygcn", "spmm_tiled", "spmm_unfused",
            "awb_gcn"} <= set(ALL_DATAFLOWS)
