"""MoE dispatch tests: routing semantics, capacity drops, path equivalence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; pip install hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.moe import (MoEConfig, init_moe_params, moe_ffn_capacity,
                              moe_ffn_reference, router_topk)


def _setup(t=32, d=16, e=4, k=2, cf=8.0, seed=0):
    cfg = MoEConfig(n_experts=e, top_k=k, d_ff_expert=24, capacity_factor=cf)
    params = init_moe_params(jax.random.key(seed), d, cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (t, d))
    return cfg, params, x


def test_capacity_matches_reference_when_no_drops():
    cfg, params, x = _setup(cf=16.0)
    ref, _ = moe_ffn_reference(params, x, cfg)
    cap, _ = moe_ffn_capacity(params, x, cfg)
    err = float(jnp.max(jnp.abs(ref - cap)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert err < 1e-5, err


def test_gates_normalized_and_topk_unique():
    cfg, params, x = _setup()
    idx, gates, aux = router_topk(x, params["router"], cfg)
    assert np.allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == cfg.top_k
    assert float(aux) >= 0


def test_capacity_drops_reduce_output_norm():
    """With a tiny capacity factor some assignments drop; the capacity path
    must produce a smaller-or-equal contribution than the reference."""
    cfg, params, x = _setup(t=64, cf=16.0)
    tight = MoEConfig(n_experts=cfg.n_experts, top_k=cfg.top_k,
                      d_ff_expert=cfg.d_ff_expert, capacity_factor=0.25)
    full, _ = moe_ffn_capacity(params, x, cfg)
    dropped, _ = moe_ffn_capacity(params, x, tight)
    assert float(jnp.linalg.norm(dropped)) < float(jnp.linalg.norm(full))


def test_grads_flow_through_dispatch():
    cfg, params, x = _setup()
    g = jax.grad(lambda p: jnp.sum(moe_ffn_capacity(p, x, cfg)[0] ** 2))(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert jnp.isfinite(leaf).all()
    assert float(jnp.max(jnp.abs(g["w_gate"]))) > 0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 2, 4]))
def test_capacity_path_token_permutation_equivariance(seed, k):
    """Property: permuting tokens permutes outputs (no cross-token state)."""
    cfg, params, x = _setup(t=16, k=k, cf=16.0, seed=seed % 1000)
    perm = np.random.default_rng(seed).permutation(16)
    y1, _ = moe_ffn_capacity(params, x, cfg)
    y2, _ = moe_ffn_capacity(params, x[perm], cfg)
    err = float(jnp.max(jnp.abs(y1[perm] - y2)) / (jnp.max(jnp.abs(y1)) + 1e-9))
    assert err < 1e-4, err
