"""Substrate tests: checkpointing (atomic, retention, elastic), resilience
(fault injection + recovery), gradient compression, neighbor sampler,
optimizers."""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; pip install hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.data.sampler import build_csr, sample_subgraph
from repro.data import synthetic
from repro.distributed.resilience import (FaultInjector, StepMonitor,
                                          WorkerFailure, run_resilient)
from repro.optim.compression import compress_decompress, wrap_optimizer
from repro.optim.optimizers import adamw, apply_updates, global_norm, sgd


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "b": jnp.arange(4.0),
            "nested": {"s": jnp.asarray(3, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    mgr.save(7, t)
    step, restored = mgr.restore(jax.tree_util.tree_map(jnp.zeros_like, t))
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        assert jnp.allclose(a, b)


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    with pytest.raises(ValueError):
        mgr.restore({"only": jnp.zeros((2,))})


def test_checkpoint_tmp_never_visible(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _tree())
    assert not list(Path(tmp_path).glob("*.tmp"))


def test_elastic_restore_subprocess(tmp_path):
    """Save on 1 device, restore sharded onto an 8-device mesh."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(3, {"w": jnp.arange(64.0).reshape(8, 8)})
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
mesh = jax.make_mesh((8,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
mgr = CheckpointManager({str(tmp_path)!r})
step, out = mgr.restore({{"w": jnp.zeros((8, 8))}},
                        shardings={{"w": NamedSharding(mesh, P("x", None))}})
assert step == 3
assert len(out["w"].sharding.device_set) == 8
assert float(out["w"].sum()) == float(sum(range(64)))
print("ELASTIC OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "ELASTIC OK" in proc.stdout


# ---------------------------------------------------------------------------
# Resilience
# ---------------------------------------------------------------------------

def test_resilient_loop_recovers_from_faults(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    calls = []

    def step_fn(state, batch):
        calls.append(batch)
        return state + 1, {"loss": float(100 - state)}

    inj = FaultInjector(frozenset({7, 13}))
    state, hist = run_resilient(
        state=jnp.asarray(0), step_fn=step_fn, batch_fn=lambda s: s,
        n_steps=20, checkpoint_manager=mgr, checkpoint_every=5,
        injector=inj, log_every=0)
    assert int(state) == 20
    assert [h["step"] for h in hist][-1] == 19
    assert mgr.latest_step() == 20


def test_resilient_loop_gives_up_after_max_restarts(tmp_path):
    class AlwaysFail(FaultInjector):
        def check(self, step):
            if step == 2:
                raise WorkerFailure("persistent fault")

    with pytest.raises(WorkerFailure):
        run_resilient(state=jnp.asarray(0),
                      step_fn=lambda s, b: (s + 1, {}),
                      batch_fn=lambda s: None, n_steps=5,
                      checkpoint_manager=CheckpointManager(tmp_path),
                      checkpoint_every=100, injector=AlwaysFail(),
                      max_restarts=2, log_every=0)


def test_straggler_monitor_flags_slow_steps():
    mon = StepMonitor(threshold=2.0)
    for s in range(10):
        mon.observe(s, 0.1)
    assert mon.observe(10, 1.0)
    assert mon.stragglers == [10]


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_compression_error_feedback_contracts():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)
    err = jnp.zeros(1000)
    deq, err2 = compress_decompress(g, err)
    # int8 quantization error bounded by scale/2 per element
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(err2))) <= scale * 0.5 + 1e-6
    # error feedback: accumulated (deq + err2) == original
    assert jnp.allclose(deq + err2, g, atol=1e-6)


def test_compressed_training_converges_like_uncompressed():
    """Least squares with/without compression reach similar loss."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(64), jnp.float32)

    def loss(w):
        return jnp.mean((A @ w - y) ** 2)

    def run(opt):
        w = jnp.zeros(8)
        st = opt.init(w)
        for _ in range(200):
            g = jax.grad(loss)(w)
            up, st = opt.update(g, st, w)
            w = apply_updates(w, up)
        return float(loss(w))

    plain = run(sgd(0.05, momentum=0.0))
    comp = run(wrap_optimizer(sgd(0.05, momentum=0.0)))
    assert comp < plain * 1.2 + 1e-3, (plain, comp)


# ---------------------------------------------------------------------------
# Neighbor sampler
# ---------------------------------------------------------------------------

def test_sampler_respects_fanout_and_membership():
    rng = np.random.default_rng(0)
    ga = synthetic.power_law_graph(0, n_nodes=500, n_edges=4000, d_feat=4,
                                   self_loops=False)
    csr = build_csr(ga.senders, ga.receivers, 500)
    seeds = rng.choice(500, 32, replace=False)
    sub = sample_subgraph(csr, seeds, (5, 3), rng=rng, n_pad=1024, e_pad=1024)
    assert sub.n_real_nodes <= 32 + 32 * 5 + 32 * 5 * 3
    assert sub.n_real_edges <= 32 * 5 + 32 * 5 * 3
    # every sampled edge exists in the original graph
    edge_set = set(zip(ga.senders.tolist(), ga.receivers.tolist()))
    for i in range(sub.n_real_edges):
        s_g = int(sub.node_ids[sub.senders[i]])
        r_g = int(sub.node_ids[sub.receivers[i]])
        assert (s_g, r_g) in edge_set
    # seeds are the first nodes and flagged by seed_mask
    assert np.array_equal(sub.node_ids[:32], seeds)
    assert sub.seed_mask[:32].sum() == 32


def test_sampler_determinism():
    ga = synthetic.power_law_graph(1, n_nodes=300, n_edges=2000, d_feat=4)
    csr = build_csr(ga.senders, ga.receivers, 300)
    seeds = np.arange(16)
    s1 = sample_subgraph(csr, seeds, (4, 2),
                         rng=np.random.default_rng(42), n_pad=512, e_pad=512)
    s2 = sample_subgraph(csr, seeds, (4, 2),
                         rng=np.random.default_rng(42), n_pad=512, e_pad=512)
    assert np.array_equal(s1.senders, s2.senders)
    assert np.array_equal(s1.node_ids, s2.node_ids)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    def loss(w):
        return jnp.sum((w - 3.0) ** 2)

    opt = adamw(0.1)
    w = jnp.zeros(4)
    st = opt.init(w)
    for _ in range(100):
        up, st = opt.update(jax.grad(loss)(w), st, w)
        w = apply_updates(w, up)
    assert float(loss(w)) < 0.05


@settings(max_examples=20, deadline=None)
@given(st.floats(0.1, 10.0))
def test_clip_bounds_global_norm(max_norm):
    from repro.optim.optimizers import clip_by_global_norm
    g = {"a": jnp.full((10,), 5.0), "b": jnp.full((3, 3), -2.0)}
    clipped, norm = clip_by_global_norm(g, max_norm)
    assert float(global_norm(clipped)) <= max_norm * 1.001


def test_synthetic_determinism():
    b1 = synthetic.lm_batch(0, 5, batch=2, seq=8, vocab=100)
    b2 = synthetic.lm_batch(0, 5, batch=2, seq=8, vocab=100)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    b3 = synthetic.criteo_batch(0, 5, batch=4, n_dense=13,
                                vocab_sizes=(10, 20, 30))
    b4 = synthetic.criteo_batch(0, 5, batch=4, n_dense=13,
                                vocab_sizes=(10, 20, 30))
    assert np.array_equal(b3["sparse"], b4["sparse"])
    assert (b3["sparse"] < np.array([10, 20, 30])[None, :, None]).all()
