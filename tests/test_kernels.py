"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode),
plus hypothesis property tests on the kernels' invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; pip install hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rel(a, b):
    return float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))


# ---------------------------------------------------------------------------
# Fused aggregate+combine (the paper's aggregation hot spot)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,f,t,bn,bk", [
    (256, 32, 8, 128, 128),
    (512, 64, 16, 128, 256),
    (512, 128, 32, 256, 256),
    (1024, 16, 7, 256, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_aggregate_combine(n, f, t, bn, bk, dtype):
    a = (RNG.random((n, n)) < 0.02).astype(np.float32) * RNG.random((n, n))
    x = RNG.standard_normal((n, f))
    w = RNG.standard_normal((f, t))
    a, x, w = (jnp.asarray(v, dtype) for v in (a, x, w))
    out = ops.gnn_aggregate_combine(a, x, w, block_n=bn, block_k=bk)
    expect = ref.fused_aggregate_combine_ref(a, x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert _rel(out.astype(jnp.float32), expect.astype(jnp.float32)) < tol


def test_fused_kernel_matches_edge_list_semantics():
    """Block-dense adjacency path == edge-list segment_sum path."""
    n, f, t, e = 256, 24, 8, 900
    snd = RNG.integers(0, n, e)
    rcv = RNG.integers(0, n, e)
    wgt = RNG.random(e).astype(np.float32)
    a = np.zeros((n, n), np.float32)
    np.add.at(a, (rcv, snd), wgt)
    x = jnp.asarray(RNG.standard_normal((n, f)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((f, t)), jnp.float32)
    agg = ref.edge_list_aggregate_ref(x, jnp.asarray(snd), jnp.asarray(rcv),
                                      jnp.asarray(wgt), n)
    expect = (agg @ w)
    out = ops.gnn_aggregate_combine(jnp.asarray(a), x, w, block_n=128, block_k=128)
    assert _rel(out, expect) < 1e-4


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 3))
def test_fused_kernel_linearity(nb, kb, seed):
    """Property: kernel is linear in X — f(X1+X2) == f(X1)+f(X2)."""
    rng = np.random.default_rng(seed)
    n, f, t = 128 * nb, 16, 8
    bk = 128 * kb
    if n % bk:
        bk = n
    a = jnp.asarray((rng.random((n, n)) < 0.05).astype(np.float32))
    x1 = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    x2 = jnp.asarray(rng.standard_normal((n, f)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((f, t)), jnp.float32)
    f12 = ops.gnn_aggregate_combine(a, x1 + x2, w, block_n=128, block_k=bk)
    f1 = ops.gnn_aggregate_combine(a, x1, w, block_n=128, block_k=bk)
    f2 = ops.gnn_aggregate_combine(a, x2, w, block_n=128, block_k=bk)
    assert _rel(f12, f1 + f2) < 1e-4


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,d,bq,bk,window", [
    (128, 64, 64, 64, None),
    (256, 64, 128, 64, None),
    (256, 32, 64, 128, 64),
    (512, 128, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(s, d, bq, bk, window, dtype):
    b, h = 2, 2
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), dtype)
    out = ops.flash_attention(q, k, v, window=window, block_q=bq, block_k=bk)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert _rel(out.astype(jnp.float32), expect.astype(jnp.float32)) < tol


def test_flash_attention_gqa():
    b, s, h, hk, d = 2, 128, 8, 2, 32
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, hk, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, hk, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    kf = jnp.repeat(k, h // hk, axis=2)
    vf = jnp.repeat(v, h // hk, axis=2)
    expect = ref.flash_attention_ref(q, kf, vf, causal=True)
    assert _rel(out, expect) < 2e-5


def test_flash_attention_softcap():
    b, s, h, d = 1, 128, 2, 32
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, softcap=8.0, block_q=64, block_k=64)
    # oracle with softcap
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * d ** -0.5
    scores = 8.0 * jnp.tanh(scores / 8.0)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    expect = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), v)
    assert _rel(out, expect) < 2e-5


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_flash_attention_rows_are_convex_combos(seed):
    """Property: each output row lies in the convex hull of V rows, so its
    max is bounded by V's max (softmax weights sum to 1)."""
    rng = np.random.default_rng(seed)
    b, s, h, d = 1, 128, 1, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-4
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-4


# ---------------------------------------------------------------------------
# Embedding bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v,d,b,hot", [
    (128, 64, 8, 1),
    (1000, 128, 32, 4),
    (4096, 256, 16, 8),
])
def test_embedding_bag(v, d, b, hot):
    tab = jnp.asarray(RNG.standard_normal((v, d)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, v, (b, hot)), jnp.int32)
    out = ops.embedding_bag(tab, idx)
    expect = ref.embedding_bag_ref(tab, idx)
    assert _rel(out, expect) < 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_embedding_bag_permutation_invariant(seed):
    """Property: sum-pooling is invariant to bag order."""
    rng = np.random.default_rng(seed)
    v, d, b, hot = 64, 32, 4, 6
    tab = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    idx = rng.integers(0, v, (b, hot))
    perm = rng.permutation(hot)
    o1 = ops.embedding_bag(tab, jnp.asarray(idx, jnp.int32))
    o2 = ops.embedding_bag(tab, jnp.asarray(idx[:, perm], jnp.int32))
    assert _rel(o1, o2) < 1e-5
