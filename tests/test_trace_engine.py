"""Amortized trace-engine battery (DESIGN.md §13) + PR-5 satellite pins.

Load-bearing guarantees:

* **Parity battery** — the amortized shared-factorization engine, the
  jitted JAX engine, and the Pallas segment-reduce path produce schedule
  quantities (vertex/edge/halo/cut counts, cache-hit data) **bit
  identical** to the per-capacity PR-4 ``np.unique`` reference
  (``GraphTrace.schedule_reference``) across every registered trace
  dataset x a power-of-two capacity sweep, including the >= 100k-edge
  acceptance operating point;
* **Capacity axis** — a batch of same-dataset trace scenarios differing
  only in ``tile_vertices`` evaluates in exactly ONE planner group, each
  row bit-identical to its lone evaluation;
* **Satellites** — canonical-JSON dataset cache keys (nested params no
  longer raise), byte-budget LRU on the resolved-trace cache, bounded
  per-trace schedule LRU, ``clear_trace_cache`` dropping per-trace
  schedules, vectorized ``cache_hit_fraction``, the streaming power-law
  generator's determinism/contract, the on-disk schedule cache round
  trip, and the ``trace_scale`` benchmark's drift gate.
"""

import json
import os

import numpy as np
import pytest

from repro.api import Scenario, evaluate_scenario, evaluate_scenarios
from repro.core import schedule_cache
from repro.core import trace as trace_mod
from repro.core.trace import (GraphTrace, clear_trace_cache,
                              register_trace_dataset, resolve_trace_dataset,
                              set_trace_cache_budget, trace_cache_info)
from repro.data import synthetic

#: Small deterministic parameters for every registered dataset.
DATASET_PARAMS = {
    "power_law": {"n_nodes": 1200, "n_edges": 9000, "seed": 1, "alpha": 1.5},
    "power_law_stream": {"n_nodes": 1200, "n_edges": 9000, "seed": 1,
                         "alpha": 1.5},
    "power_law_sharded": {"n_nodes": 1200, "n_edges": 9000, "seed": 1,
                          "alpha": 1.5},
    "cora": {},
    "molecule": {"batch": 16, "n_nodes": 12, "n_edges": 30},
    "ring_of_tiles": {"n_nodes": 512, "n_tiles": 8},
}

COUNT_FIELDS = ("vertex_counts", "edge_counts", "halo_counts",
                "remote_edge_counts")


def _pow2_caps(V):
    caps = sorted({max(1, V >> i) for i in range(1, 11, 2)} | {V})
    return caps


@pytest.fixture(autouse=True)
def _no_disk_cache(monkeypatch):
    """Unit tests never touch the user's on-disk cache by default."""
    monkeypatch.setenv("REPRO_TRACE_CACHE", "0")
    yield


# ---------------------------------------------------------------------------
# Parity battery: amortized / jax / pallas engines == PR-4 reference.
# ---------------------------------------------------------------------------
def _reference_trace(name, trace):
    """The trace to run the PR-4 oracle on: ``power_law_sharded`` builds
    factorization-only traces (no edge list -> no oracle), but its graph
    is by contract the same as ``power_law_stream`` for equal params."""
    if trace.has_edge_list:
        return trace
    assert name == "power_law_sharded"
    return resolve_trace_dataset("power_law_stream", DATASET_PARAMS[name])


@pytest.mark.parametrize("name", sorted(DATASET_PARAMS))
def test_amortized_engine_bitmatches_reference(name):
    trace = resolve_trace_dataset(name, DATASET_PARAMS[name])
    oracle = _reference_trace(name, trace)
    for cap in _pow2_caps(trace.n_nodes):
        new = trace.schedule(cap)
        ref = oracle.schedule_reference(cap)
        for f in COUNT_FIELDS:
            np.testing.assert_array_equal(
                getattr(new, f), getattr(ref, f),
                err_msg=f"{name} cap={cap} field={f}")
        for hdf in (0.0, 0.1, 1.0):
            np.testing.assert_array_equal(new.cache_hit_fraction(hdf),
                                          ref.cache_hit_fraction(hdf))
        assert new.halo_total == ref.halo_total
        assert new.cut_edges == ref.cut_edges


@pytest.mark.parametrize("name", sorted(DATASET_PARAMS))
def test_jax_engine_bitmatches_reference(name):
    trace = resolve_trace_dataset(name, DATASET_PARAMS[name])
    oracle = _reference_trace(name, trace)
    trace.clear_schedules()
    caps = _pow2_caps(trace.n_nodes)[:3]
    scheds = trace.schedules(caps, engine="jax")
    for cap, sched in zip(caps, scheds):
        ref = oracle.schedule_reference(cap)
        for f in COUNT_FIELDS:
            np.testing.assert_array_equal(
                getattr(sched, f), getattr(ref, f),
                err_msg=f"{name} cap={cap} field={f}")
        # disk-less schedules still answer cache-hit queries (lazy pairs)
        np.testing.assert_array_equal(sched.cache_hit_fraction(0.2),
                                      ref.cache_hit_fraction(0.2))


def test_pallas_segment_reduce_bitmatches_reference():
    from repro.kernels import segment_reduce as sr

    trace = resolve_trace_dataset("power_law", DATASET_PARAMS["power_law"])
    u_snd, u_rcv, u_new_src, mp = trace._pair_factorization()
    mult = np.diff(mp)
    for cap in (64, 300, 1200):
        ref = trace.schedule_reference(cap)
        halo, cut = sr.schedule_counts_pallas(
            u_snd, u_rcv, u_new_src, mult, ref.K, ref.n_tiles)
        np.testing.assert_array_equal(
            np.asarray(halo, np.float64), ref.halo_counts)
        np.testing.assert_array_equal(
            np.asarray(cut, np.float64), ref.remote_edge_counts)


def test_pallas_tile_histogram_matches_bincount():
    from repro.kernels import segment_reduce as sr

    rng = np.random.default_rng(0)
    ids = rng.integers(0, 37, size=5000).astype(np.int32)
    w = rng.integers(0, 5, size=5000).astype(np.float32)
    out = np.asarray(sr.tile_histogram(ids, w, 37), np.float64)
    np.testing.assert_array_equal(out, np.bincount(ids, weights=w,
                                                   minlength=37))
    with pytest.raises(ValueError, match="equal-length"):
        sr.tile_histogram(ids, w[:-1], 37)
    # float32 exactness is guarded on the accumulated weight, not the
    # edge count: few edges with huge multiplicities must be rejected
    with pytest.raises(ValueError, match="float32"):
        sr.tile_histogram(np.zeros(2, np.int32),
                          np.full(2, 2.0**24, np.float32), 4)


def test_big_power_law_reference_parity_and_bruteforce():
    """The >= 100k-edge acceptance point, rerun through the new engine."""
    params = {"n_nodes": 20000.0, "n_edges": 120000.0, "seed": 0.0,
              "alpha": 1.3}
    trace = resolve_trace_dataset("power_law", params)
    assert trace.n_edges >= 100_000
    sched = trace.schedule(1024)
    ref = trace.schedule_reference(1024)
    for f in COUNT_FIELDS:
        np.testing.assert_array_equal(getattr(sched, f), getattr(ref, f))
    # Brute-force np.unique halo on a few tiles (full check lives in
    # test_trace.py and runs against this same engine).
    K = sched.K
    dst_tile = trace.receivers // K
    for t in (0, sched.n_tiles // 2, sched.n_tiles - 1):
        srcs = trace.senders[dst_tile == t]
        remote = srcs[(srcs // K) != t]
        assert sched.halo_counts[t] == np.unique(remote).size


def test_engine_name_validated():
    trace = resolve_trace_dataset("ring_of_tiles",
                                  {"n_nodes": 64, "n_tiles": 4})
    with pytest.raises(ValueError, match="engine"):
        trace.schedule(16, engine="bogus")
    with pytest.raises(ValueError, match="engine"):
        trace.schedules([16], engine="bogus")


# ---------------------------------------------------------------------------
# Capacity axis: one planner group per (dataflow, dataset), exact rows.
# ---------------------------------------------------------------------------
def test_capacity_sweep_is_one_planner_group():
    params = {"n_nodes": 1500.0, "n_edges": 9000.0, "seed": 0.0,
              "alpha": 1.4}
    caps = (64.0, 128.0, 300.0, 750.0, 1500.0)
    batch = [Scenario.trace("engn", dataset="power_law", params=params,
                            N=30.0, T=5.0, tile_vertices=c) for c in caps]
    res = evaluate_scenarios(batch)
    # THE acceptance assertion: same dataset, capacities only -> 1 group.
    assert res.n_evaluations == 1
    assert len({s.plan_key() for s in batch}) == 1
    for s, r in zip(batch, res.results):
        lone = evaluate_scenario(s)
        assert r.total_bits == lone.total_bits
        assert r.total_iterations == lone.total_iterations
        assert r.breakdown == lone.breakdown
        assert r.iteration_breakdown == lone.iteration_breakdown
        assert r.n_tiles == lone.n_tiles
    # n_tiles must reflect each row's own capacity
    assert [r.n_tiles for r in res.results] == \
        [float(-(-1500 // int(c))) for c in caps]


def test_capacity_axis_with_widths_and_hardware_overrides():
    params = {"n_nodes": 900.0, "n_edges": 5000.0, "seed": 3.0}
    batch = [
        Scenario.trace("hygcn", dataset="power_law", params=params,
                       N=32.0, T=8.0, tile_vertices=cap,
                       widths=(32.0, 16.0, 8.0), hardware={"B": B})
        for cap, B in ((100.0, 1000.0), (450.0, 2000.0), (900.0, 1000.0))
    ]
    res = evaluate_scenarios(batch)
    assert res.n_evaluations == 1
    for s, r in zip(batch, res.results):
        lone = evaluate_scenario(s)
        assert r.total_bits == lone.total_bits
        assert r.breakdown == lone.breakdown


# ---------------------------------------------------------------------------
# Satellite: canonical-JSON cache keys (nested params used to raise).
# ---------------------------------------------------------------------------
def test_cache_key_canonicalizes_nested_params():
    built = []

    def builder(**params):
        built.append(params)
        return GraphTrace(np.array([0, 1]), np.array([1, 0]), 2)

    register_trace_dataset("_nested_params_ds", builder, overwrite=True)
    try:
        nested = {"shape": {"n": 2.0, "m": [1, 2]}, "seed": 0}
        # PR-4's tuple(sorted(...)) key raised TypeError on dict values.
        t1 = resolve_trace_dataset("_nested_params_ds", nested)
        t2 = resolve_trace_dataset(
            "_nested_params_ds",
            {"seed": 0, "shape": {"m": [1, 2], "n": 2.0}})
        assert t1 is t2  # key order canonicalized -> one build
        assert len(built) == 1
        t3 = resolve_trace_dataset("_nested_params_ds",
                                   {"shape": {"n": 3.0, "m": [1, 2]},
                                    "seed": 0})
        assert t3 is not t1 and len(built) == 2
        # numpy scalars canonicalize like their Python values
        t4 = resolve_trace_dataset("_nested_params_ds",
                                   {"shape": {"n": np.float64(2.0),
                                              "m": [1, 2]},
                                    "seed": np.int64(0)})
        assert t4 is t1 and len(built) == 2
        # integer-valued floats merge with ints (the front door
        # normalizes params to floats; direct callers pass ints — both
        # must share one cache/disk entry, like the old tuple key did)
        t5 = resolve_trace_dataset("_nested_params_ds",
                                   {"shape": {"n": 2, "m": [1.0, 2.0]},
                                    "seed": 0.0})
        assert t5 is t1 and len(built) == 2
        assert (trace_mod._canonical_params({"n": 1000000})
                == trace_mod._canonical_params({"n": 1000000.0}))
    finally:
        trace_mod._TRACE_DATASETS.pop("_nested_params_ds", None)
        clear_trace_cache()


# ---------------------------------------------------------------------------
# Satellite: bounded caches.
# ---------------------------------------------------------------------------
def test_trace_cache_byte_budget_evicts_lru():
    clear_trace_cache()
    old_budget = trace_cache_info()["budget_bytes"]
    try:
        a = resolve_trace_dataset("ring_of_tiles",
                                  {"n_nodes": 256, "n_tiles": 4})
        set_trace_cache_budget(max(1, a.nbytes // 2))
        # the most recent entry always survives, even over budget
        assert trace_cache_info()["entries"] == 1
        b = resolve_trace_dataset("ring_of_tiles",
                                  {"n_nodes": 512, "n_tiles": 4})
        info = trace_cache_info()
        assert info["entries"] == 1
        assert resolve_trace_dataset("ring_of_tiles",
                                     {"n_nodes": 512, "n_tiles": 4}) is b
        # raising the budget keeps both
        set_trace_cache_budget(10 * (a.nbytes + b.nbytes))
        resolve_trace_dataset("ring_of_tiles", {"n_nodes": 256, "n_tiles": 4})
        assert trace_cache_info()["entries"] == 2
        with pytest.raises(ValueError, match=">= 0"):
            set_trace_cache_budget(-1)
    finally:
        set_trace_cache_budget(old_budget)
        clear_trace_cache()


def test_per_trace_schedule_lru_bounded(monkeypatch):
    monkeypatch.setattr(GraphTrace, "schedule_cache_entries", 4)
    trace = resolve_trace_dataset("power_law",
                                  {"n_nodes": 600, "n_edges": 3000,
                                   "seed": 0})
    trace.clear_schedules()
    caps = [10, 20, 30, 40, 50, 60]
    for c in caps:
        trace.schedule(c)
    assert len(trace._schedules) == 4
    assert list(trace._schedules) == caps[-4:]
    # an LRU hit refreshes recency
    trace.schedule(30)
    trace.schedule(70)
    assert 30 in trace._schedules and 40 not in trace._schedules


def test_schedules_sweep_wider_than_lru_returns_everything(monkeypatch):
    """A capacity sweep larger than the schedule LRU must still return a
    full schedule per requested capacity (regression: eviction during
    the batch used to surface None entries)."""
    monkeypatch.setattr(GraphTrace, "schedule_cache_entries", 4)
    trace = resolve_trace_dataset("power_law",
                                  {"n_nodes": 600, "n_edges": 3000,
                                   "seed": 6})
    trace.clear_schedules()
    caps = list(range(10, 100, 10))  # 9 distinct > LRU limit of 4
    scheds = trace.schedules(caps)
    assert len(scheds) == len(caps)
    for cap, s in zip(caps, scheds):
        assert s is not None and s.capacity == cap
        ref = trace.schedule_reference(cap)
        np.testing.assert_array_equal(s.halo_counts, ref.halo_counts)
    assert len(trace._schedules) == 4


def test_clear_trace_cache_drops_per_trace_schedules():
    trace = resolve_trace_dataset("power_law",
                                  {"n_nodes": 500, "n_edges": 2500,
                                   "seed": 4})
    trace.schedule(100)
    assert trace._schedules
    clear_trace_cache()
    assert not trace._schedules
    assert trace_cache_info()["entries"] == 0


# ---------------------------------------------------------------------------
# Satellite: vectorized cache_hit_fraction.
# ---------------------------------------------------------------------------
def test_cache_hit_fraction_vectorizes_over_hdf():
    trace = resolve_trace_dataset("power_law",
                                  {"n_nodes": 2000, "n_edges": 16000,
                                   "seed": 2, "alpha": 1.2})
    sched = trace.schedule(512)
    hdf = np.array([0.0, 0.05, 0.1, 0.5, 1.0])
    vec = sched.cache_hit_fraction(hdf)
    assert vec.shape == (5, sched.n_tiles)
    for i, h in enumerate(hdf):
        np.testing.assert_array_equal(vec[i],
                                      sched.cache_hit_fraction(float(h)))
    grid = sched.cache_hit_fraction(hdf.reshape(5, 1))
    assert grid.shape == (5, 1, sched.n_tiles)
    # monotone in the cache size, bounded in [0, 1]
    assert np.all(np.diff(vec, axis=0) >= 0)
    assert np.all((vec >= 0) & (vec <= 1))
    for bad in (1.5, -0.1, float("nan"), np.array([0.1, 2.0])):
        with pytest.raises(ValueError, match="high_degree_fraction"):
            sched.cache_hit_fraction(bad)


# ---------------------------------------------------------------------------
# Satellite: streaming chunked power-law generator.
# ---------------------------------------------------------------------------
def test_power_law_edges_contract():
    snd, rcv = synthetic.power_law_edges(7, n_nodes=5000, n_edges=30000)
    assert snd.dtype == np.int32 and rcv.dtype == np.int32
    assert snd.size == rcv.size == 30000
    assert not np.any(snd == rcv)
    assert snd.min() >= 0 and rcv.max() < 5000
    # deterministic in (seed, params)
    snd2, rcv2 = synthetic.power_law_edges(7, n_nodes=5000, n_edges=30000)
    np.testing.assert_array_equal(snd, snd2)
    np.testing.assert_array_equal(rcv, rcv2)
    # the stream yields the same edges chunk by chunk
    parts = list(synthetic.power_law_edge_stream(7, n_nodes=5000,
                                                 n_edges=30000))
    np.testing.assert_array_equal(np.concatenate([p[0] for p in parts]), snd)
    np.testing.assert_array_equal(np.concatenate([p[1] for p in parts]), rcv)
    # chunked consumption is part of the stream identity: edge counts
    # that straddle chunk boundaries still come out exact
    chunks = list(synthetic.power_law_edge_stream(0, n_nodes=100,
                                                  n_edges=2500,
                                                  chunk_edges=1000))
    assert [c[0].size for c in chunks] == [1000, 1000, 500]
    with pytest.raises(ValueError, match="n_nodes >= 2"):
        list(synthetic.power_law_edge_stream(0, n_nodes=1, n_edges=5))
    with pytest.raises(ValueError, match="chunk_edges"):
        list(synthetic.power_law_edge_stream(0, n_nodes=10, n_edges=5,
                                             chunk_edges=0))
    # power-law shape: destination degrees are heavy-tailed
    degs = np.bincount(rcv, minlength=5000)
    assert degs.max() > 20 * max(1.0, degs.mean())


def test_power_law_stream_dataset_registered():
    trace = resolve_trace_dataset("power_law_stream",
                                  {"n_nodes": 800, "n_edges": 4000,
                                   "seed": 5, "alpha": 1.3})
    assert (trace.n_nodes, trace.n_edges) == (800, 4000)
    s = evaluate_scenario(Scenario.trace(
        "engn", dataset="power_law_stream",
        params={"n_nodes": 800.0, "n_edges": 4000.0, "seed": 5.0,
                "alpha": 1.3},
        N=30.0, T=5.0, tile_vertices=200.0))
    assert np.isfinite(s.total_bits) and s.total_bits > 0


# ---------------------------------------------------------------------------
# Satellite: content-addressed on-disk cache.
# ---------------------------------------------------------------------------
def test_disk_cache_round_trip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", str(tmp_path))
    monkeypatch.setenv("REPRO_TRACE_CACHE_MIN_EDGES", "0")
    params = {"n_nodes": 700, "n_edges": 4200, "seed": 9, "alpha": 1.4}
    clear_trace_cache()
    t1 = resolve_trace_dataset("power_law", params)
    s1 = t1.schedule(128)
    # format v2: one graph part-directory + one schedule npz
    assert len(list(tmp_path.rglob("*.graph"))) == 1
    assert len(list(tmp_path.rglob("*.npz"))) == 1
    clear_trace_cache()
    t2 = resolve_trace_dataset("power_law", params)
    assert t2 is not t1
    np.testing.assert_array_equal(t2.senders, t1.senders)
    np.testing.assert_array_equal(t2.receivers, t1.receivers)
    np.testing.assert_array_equal(t2.row_ptr, t1.row_ptr)
    # schedule comes from disk (counts) and still answers cache-hit
    # queries through the lazily rebuilt pair provider
    s2 = t2.schedule(128)
    for f in COUNT_FIELDS:
        np.testing.assert_array_equal(getattr(s2, f), getattr(s1, f))
    np.testing.assert_array_equal(s2.cache_hit_fraction(0.1),
                                  s1.cache_hit_fraction(0.1))
    ref = t2.schedule_reference(128)
    for f in COUNT_FIELDS:
        np.testing.assert_array_equal(getattr(s2, f), getattr(ref, f))
    clear_trace_cache()


def test_disk_cache_disabled_and_tokenless(tmp_path, monkeypatch):
    params = {"n_nodes": 400, "n_edges": 2000, "seed": 1}
    monkeypatch.setenv("REPRO_TRACE_CACHE", "0")
    monkeypatch.setenv("REPRO_TRACE_CACHE_MIN_EDGES", "0")
    clear_trace_cache()
    resolve_trace_dataset("power_law", params).schedule(64)
    assert schedule_cache.cache_root() is None
    # tokenless datasets (ring_of_tiles, ad-hoc registrations) never
    # write disk entries even when the cache is on
    monkeypatch.setenv("REPRO_TRACE_CACHE", str(tmp_path))
    clear_trace_cache()
    resolve_trace_dataset("ring_of_tiles",
                          {"n_nodes": 400, "n_tiles": 4}).schedule(64)
    assert list(tmp_path.rglob("*.npz")) == []
    assert list(tmp_path.rglob("*.graph")) == []
    clear_trace_cache()


def test_disk_cache_min_edges_threshold(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", str(tmp_path))
    monkeypatch.setenv("REPRO_TRACE_CACHE_MIN_EDGES", "5000")
    clear_trace_cache()
    resolve_trace_dataset("power_law",
                          {"n_nodes": 300, "n_edges": 1000,
                           "seed": 0}).schedule(64)
    # below the threshold: no graph dirs, no schedule npz
    assert list(tmp_path.rglob("*.npz")) == []
    assert list(tmp_path.rglob("*.graph")) == []
    clear_trace_cache()


def test_disk_cache_corrupt_entry_is_a_miss(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE_CACHE", str(tmp_path))
    monkeypatch.setenv("REPRO_TRACE_CACHE_MIN_EDGES", "0")
    params = {"n_nodes": 500, "n_edges": 2500, "seed": 2}
    clear_trace_cache()
    t1 = resolve_trace_dataset("power_law", params)
    for f in tmp_path.rglob("*.npz"):
        f.write_bytes(b"not an npz")
    for f in tmp_path.rglob("*.graph/*"):
        f.write_bytes(b"garbage")  # torn npy parts AND torn meta.json
    clear_trace_cache()
    t2 = resolve_trace_dataset("power_law", params)  # rebuilds, no raise
    np.testing.assert_array_equal(t2.senders, t1.senders)
    # the damaged graph directory was dropped and re-stored clean
    clear_trace_cache()
    t3 = resolve_trace_dataset("power_law", params)
    np.testing.assert_array_equal(np.asarray(t3.row_ptr), t1.row_ptr)
    clear_trace_cache()


# ---------------------------------------------------------------------------
# CI gate: the trace_scale benchmark's drift check.
# ---------------------------------------------------------------------------
def test_trace_scale_benchmark_smoke(tmp_path):
    from benchmarks import trace_scale

    out = tmp_path / "bench.json"
    rc = trace_scale.main(["--edges", "20000,50000", "--points", "6",
                           "--json", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert payload["benchmark"] == "trace_scale"
    assert payload["drift_failures"] == []
    for row in payload["rows"]:
        assert row["drift_errors"] == []
        assert row["edges_per_sec"] > 0
        assert row["speedup_vs_reference"] is not None
        assert row["n_capacities"] == len(row["capacities"]) == 6
        # PR-6 sharded-pipeline stages + peak-RSS tracking per row
        assert row["t_total_sharded_s"] > 0
        assert row["t_total_single_s"] > 0
        assert row["n_shards"] >= 1
        assert row["rss_peak_kb"]["shard_generate_sort_kb"] != 0


@pytest.mark.slow
def test_trace_scale_ten_million_edges_end_to_end(tmp_path):
    """The 10^7-edge sweep (amortized engine only) schedules on CPU."""
    from benchmarks import trace_scale

    out = tmp_path / "bench.json"
    rc = trace_scale.main(["--edges", "10000000", "--ref-max-edges", "0",
                           "--json", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    row = payload["rows"][0]
    assert row["n_edges"] == 10_000_000
    assert row["drift_errors"] == []
    assert row["edges_per_sec"] > 1e6


# ---------------------------------------------------------------------------
# PR-8 satellite: float64-exactness at the 2^53 boundary.  The model
# auditor (repro.analysis) proves the *closed forms* stay exactly
# representable at the ROADMAP envelope; this pins the same property for
# the trace engine's integer pipeline: multiplicity prefix sums and
# schedule counts at 2^53-adjacent edge totals must match a Python-int
# oracle exactly (int64 end to end, no float64 round-trip losses).
# ---------------------------------------------------------------------------

def _python_int_schedule_oracle(u_snd, u_rcv, mult, V, cap):
    """Schedule counts re-derived with arbitrary-precision Python ints."""
    n_tiles = -(-V // cap)
    edge = [0] * n_tiles
    remote = [0] * n_tiles
    halo_sources = [set() for _ in range(n_tiles)]
    for s, r, m in zip(u_snd, u_rcv, mult):
        t = int(r) // cap
        edge[t] += int(m)
        if int(s) // cap != t:
            remote[t] += int(m)
            halo_sources[t].add(int(s))
    return edge, remote, [len(h) for h in halo_sources]


def _dense_pairs(V, seed):
    """A deterministic sender-major unique-pair set over V vertices."""
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.integers(0, V * V, size=4 * V))
    return (keys // V).astype(np.int64), (keys % V).astype(np.int64)


def test_schedule_oracle_convention_matches_engine():
    """Validate the Python-int oracle's tile convention at small scale."""
    V, cap = 96, 32
    u_snd, u_rcv = _dense_pairs(V, seed=7)
    mult = (1 + (u_snd + u_rcv) % 5).astype(np.int64)
    prefix = np.zeros(mult.size + 1, dtype=np.int64)
    np.cumsum(mult, out=prefix[1:])
    trace = GraphTrace.from_factorization(V, u_snd, u_rcv, prefix)
    sched = trace.schedule(cap)
    edge, remote, halo = _python_int_schedule_oracle(
        u_snd, u_rcv, mult, V, cap)
    assert [int(x) for x in sched.edge_counts] == edge
    assert [int(x) for x in sched.remote_edge_counts] == remote
    assert [int(x) for x in sched.halo_counts] == halo


@pytest.mark.parametrize("total", [2**53 - 1, 2**53 + 4097, 10**8 + 7],
                         ids=["2p53-1", "2p53+4097", "1e8"])
def test_schedule_counts_exact_at_2p53_boundary(total):
    """2^53-adjacent multiplicity totals survive the int64 pipeline.

    One unique pair carries nearly the whole edge multiplicity, so prefix
    sums and per-tile totals land at or past 2^53 (where float64 spacing
    is 2.0).  The int64 side — E, CSR row pointers, out-degrees — must
    equal the Python-int oracle *exactly* at any scale; a weighted
    float64 bincount anywhere in the multiplicity path shows up here as
    an off-by-a-few (the pre-PR-8 behavior).  The float64-stored schedule
    counts must be exact up to 2^53 and nearest-representable — one final
    rounding, never accumulated error — beyond it.
    """
    V, cap = 96, 32
    u_snd, u_rcv = _dense_pairs(V, seed=11)
    U = u_snd.size
    mult = np.ones(U, dtype=np.int64)
    mult[U // 3] = total - (U - 1)  # a 2^53-scale hot pair
    prefix = np.zeros(U + 1, dtype=np.int64)
    np.cumsum(mult, out=prefix[1:])
    assert prefix.dtype == np.int64 and int(prefix[-1]) == total

    trace = GraphTrace.from_factorization(V, u_snd, u_rcv, prefix)
    assert trace.n_edges == total  # no float64 narrowing of E
    edge, remote, halo = _python_int_schedule_oracle(
        u_snd, u_rcv, mult, V, cap)

    # int64 pipeline: exact at any scale.
    assert trace.row_ptr.dtype == np.int64
    assert int(trace.row_ptr[-1]) == total
    row_counts = [0] * V
    out_deg = [0] * V
    for s, r, m in zip(u_snd, u_rcv, mult):
        row_counts[int(r)] += int(m)
        out_deg[int(s)] += int(m)
    assert [int(x) for x in np.diff(trace.row_ptr)] == row_counts
    assert [int(x) for x in trace.out_degrees()] == out_deg

    # float64-stored schedule counts: exact <= 2^53, one nearest-
    # representable rounding beyond (never accumulated error).
    sched = trace.schedule(cap)
    assert list(sched.edge_counts) == [float(x) for x in edge]
    assert list(sched.remote_edge_counts) == [float(x) for x in remote]
    assert [int(x) for x in sched.halo_counts] == halo
    if total <= 2**53:
        assert [int(x) for x in sched.edge_counts] == edge
        assert [int(x) for x in sched.remote_edge_counts] == remote
        assert sched.cut_edges == sum(remote)
    assert sched.halo_total == sum(halo)
