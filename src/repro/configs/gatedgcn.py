"""GatedGCN [arXiv:2003.00982 benchmarking config]: 16 layers, d_hidden 70,
gated edge aggregation."""

from ..models.gnn.gatedgcn import GatedGCNConfig
from .base import ArchDef, GNN_SHAPES


def make_config(*, d_in: int = 16, n_classes: int = 10, **kw) -> GatedGCNConfig:
    return GatedGCNConfig(name="gatedgcn", n_layers=16, d_in=d_in,
                          d_edge_in=16, d_hidden=70, n_classes=n_classes, **kw)


def make_smoke_config(**kw) -> GatedGCNConfig:
    return GatedGCNConfig(name="gatedgcn-smoke", n_layers=3, d_in=8,
                          d_edge_in=4, d_hidden=12, n_classes=3, **kw)


ARCH = ArchDef(name="gatedgcn", family="gnn",
               make_config=make_config, make_smoke_config=make_smoke_config,
               shapes=GNN_SHAPES)
