"""Architecture registry: one ArchDef per assigned architecture.

Each ``configs/<id>.py`` exports an ``ARCH`` ArchDef binding:
* the exact published full configuration (used ONLY via ShapeDtypeStructs in
  the dry-run — never allocated on CPU),
* a reduced smoke configuration of the same family (one real train/serve
  step on CPU per smoke test),
* the shape set for its family and any mandated skips.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional, Sequence

__all__ = ["ShapeSpec", "ArchDef", "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES"]


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell of the assignment."""

    name: str
    kind: str                      # train | prefill | decode | train_sampled | serve | retrieval
    params: Mapping[str, Any]


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", {"seq": 4096, "batch": 256}),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", {"seq": 32768, "batch": 32}),
    "decode_32k": ShapeSpec("decode_32k", "decode", {"seq": 32768, "batch": 128}),
    "long_500k": ShapeSpec("long_500k", "decode", {"seq": 524288, "batch": 1}),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "train",
                               {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    "minibatch_lg": ShapeSpec("minibatch_lg", "train_sampled",
                              {"n_nodes": 232965, "n_edges": 114615892,
                               "batch_nodes": 1024, "fanout": (15, 10),
                               "d_feat": 602}),
    "ogb_products": ShapeSpec("ogb_products", "train",
                              {"n_nodes": 2449029, "n_edges": 61859140,
                               "d_feat": 100}),
    "molecule": ShapeSpec("molecule", "train",
                          {"n_nodes": 30, "n_edges": 64, "batch": 128,
                           "d_feat": 16}),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                {"batch": 1, "n_candidates": 1000000}),
}


@dataclass(frozen=True)
class ArchDef:
    name: str
    family: str                            # "lm" | "gnn" | "recsys"
    make_config: Callable[[], Any]         # full published config
    make_smoke_config: Callable[[], Any]   # reduced same-family config
    shapes: Mapping[str, ShapeSpec]
    # shape name -> reason, for mandated skips (long_500k on pure full attn).
    skips: Mapping[str, str] = field(default_factory=dict)
    notes: str = ""
    # DESIGN.md §5 tile-language hook: (config, shape params) -> the
    # per-layer feature widths [N_0, ..., N_L] this architecture chains.
    # None falls back to the family-generic mapping in configs/scenarios.py.
    scenario_widths: Optional[Callable[[Any, Mapping[str, Any]],
                                       Sequence[float]]] = None

    def cells(self) -> list[tuple[str, str]]:
        return [(self.name, s) for s in self.shapes if s not in self.skips]

    def to_scenarios(self, *, shapes: Optional[Sequence[str]] = None,
                     dataflows: Optional[Sequence[str]] = None,
                     **kw: Any) -> list:
        """This workload's §5 tile-language mapping as evaluable scenarios.

        One :class:`repro.api.Scenario` per (shape, dataflow): the
        architecture's movement totals across every registered dataflow
        become one batched ``repro.api.evaluate_scenarios`` query (the
        scenario front door, DESIGN.md §11).
        """
        from .scenarios import arch_scenarios
        return arch_scenarios(self, shapes=shapes, dataflows=dataflows, **kw)
