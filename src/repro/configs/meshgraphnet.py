"""MeshGraphNet [arXiv:2010.03409]: 15 processor layers, d_hidden 128,
sum aggregation, 2-layer MLPs."""

from ..models.gnn.meshgraphnet import MeshGraphNetConfig
from .base import ArchDef, GNN_SHAPES


def make_config(*, d_in: int = 12, **kw) -> MeshGraphNetConfig:
    return MeshGraphNetConfig(name="meshgraphnet", n_layers=15, d_in=d_in,
                              d_hidden=128, mlp_layers=2, **kw)


def make_smoke_config(**kw) -> MeshGraphNetConfig:
    return MeshGraphNetConfig(name="meshgraphnet-smoke", n_layers=3, d_in=8,
                              d_hidden=16, mlp_layers=2, **kw)


ARCH = ArchDef(name="meshgraphnet", family="gnn",
               make_config=make_config, make_smoke_config=make_smoke_config,
               shapes=GNN_SHAPES)
