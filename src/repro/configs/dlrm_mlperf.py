"""DLRM MLPerf [arXiv:1906.00091]: 13 dense + 26 sparse (Criteo-1TB vocabs),
embed dim 128, bottom MLP 512-256-128, top MLP 1024-1024-512-256-1, dot
interaction."""

from ..models.dlrm import CRITEO_1TB_VOCABS, DLRMConfig
from .base import ArchDef, RECSYS_SHAPES


def make_config(**kw) -> DLRMConfig:
    return DLRMConfig(name="dlrm-mlperf", **kw)


def make_smoke_config(**kw) -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-smoke", n_dense=13, n_sparse=26, embed_dim=16,
        vocab_sizes=tuple(min(v, 128) for v in CRITEO_1TB_VOCABS),
        bot_mlp=(32, 16), top_mlp=(64, 32, 1), **kw)


ARCH = ArchDef(name="dlrm-mlperf", family="recsys",
               make_config=make_config, make_smoke_config=make_smoke_config,
               shapes=RECSYS_SHAPES,
               notes="Tables row-sharded over the model axis (vocab-parallel "
                     "lookup + psum baseline; all-to-all is the §Perf "
                     "optimization).  Scenario bridge (§5): a batch is a "
                     "tile of K = batch example-vertices each gathering "
                     "n_sparse embedding rows (P = 26K edges, N = 128); "
                     "combination is the dot interaction + top MLP (T = 1).")
