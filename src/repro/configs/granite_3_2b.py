"""IBM Granite-3.0-2B [hf:ibm-granite/granite-3.0-2b-base]: 40L d2048 32H
(GQA kv=8) head 64, d_ff 8192, vocab 49155."""

from ..models.transformer import TransformerConfig
from .base import ArchDef, LM_SHAPES


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="granite-3-2b",
        n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_head=64,
        d_ff=8192, vocab=49155, rope_theta=1e4, **kw)


def make_smoke_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="granite-smoke",
        n_layers=3, d_model=48, n_heads=4, n_kv_heads=2, d_head=12,
        d_ff=96, vocab=251,       # deliberately non-divisible like 49155
        dtype="float32", q_chunk=16, **kw)


ARCH = ArchDef(
    name="granite-3-2b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=LM_SHAPES,
    skips={"long_500k": "pure full-attention arch; 500k decode requires "
                        "sub-quadratic attention (DESIGN.md §5)"},
    notes="vocab 49155 is not divisible by tp=16; the unembed stays "
          "replicated (param_pspecs falls back) — recorded in EXPERIMENTS.md.",
)
