"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 48L d2048 32H (GQA kv=4) head 128,
MoE 128 experts top-8, expert d_ff 768, vocab 151936."""

from ..models.moe import MoEConfig
from ..models.transformer import TransformerConfig
from .base import ArchDef, LM_SHAPES


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-moe-30b-a3b",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
        d_ff=768, vocab=151936,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768,
                      capacity_factor=1.25),
        rope_theta=1e6, **kw)


def make_smoke_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-moe-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=32, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=2.0),
        dtype="float32", q_chunk=16, **kw)


ARCH = ArchDef(
    name="qwen3-moe-30b-a3b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=LM_SHAPES,
    skips={"long_500k": "pure full-attention arch; 500k decode requires "
                        "sub-quadratic attention (DESIGN.md §5)"},
)
