"""Architecture registry: ``get_arch(name)`` / ``all_archs()``."""

from . import (arctic_480b, dlrm_mlperf, equiformer_v2, gatedgcn, gcn_cora,
               gemma2_2b, granite_3_2b, meshgraphnet, qwen3_moe_30b_a3b,
               smollm_135m)
from .base import ArchDef, GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES, ShapeSpec

_MODULES = (qwen3_moe_30b_a3b, arctic_480b, granite_3_2b, gemma2_2b,
            smollm_135m, gcn_cora, equiformer_v2, meshgraphnet, gatedgcn,
            dlrm_mlperf)

REGISTRY: dict[str, ArchDef] = {m.ARCH.name: m.ARCH for m in _MODULES}


def get_arch(name: str) -> ArchDef:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def all_archs() -> list[ArchDef]:
    return list(REGISTRY.values())


def all_cells(*, include_skipped: bool = False) -> list[tuple[str, str, str]]:
    """(arch, shape, status) for the 40-cell grid."""
    out = []
    for arch in all_archs():
        for shape in arch.shapes:
            if shape in arch.skips:
                if include_skipped:
                    out.append((arch.name, shape, f"SKIP: {arch.skips[shape]}"))
            else:
                out.append((arch.name, shape, "run"))
    return out


def workload_scenarios(archs=None, *, dataflows=None, **kw) -> list:
    """Scenario batch over many workloads: the front-door one-liner.

    ``evaluate_scenarios(workload_scenarios(["smollm-135m", "dlrm-mlperf"]))``
    answers every (workload shape x dataflow) movement query in one
    broadcast evaluation per dataflow (DESIGN.md §11).
    """
    names = list(archs) if archs is not None else sorted(REGISTRY)
    out: list = []
    for name in names:
        out.extend(get_arch(name).to_scenarios(dataflows=dataflows, **kw))
    return out


__all__ = ["REGISTRY", "get_arch", "all_archs", "all_cells", "ArchDef",
           "ShapeSpec", "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES",
           "workload_scenarios"]
