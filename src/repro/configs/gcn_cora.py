"""GCN on Cora [arXiv:1609.02907]: 2 layers, d_hidden 16, symmetric norm."""

from ..models.gnn.gcn import GCNConfig
from .base import ArchDef, GNN_SHAPES


def make_config(*, d_in: int = 1433, n_classes: int = 7, **kw) -> GCNConfig:
    return GCNConfig(name="gcn-cora", n_layers=2, d_in=d_in, d_hidden=16,
                     n_classes=n_classes, norm="sym", **kw)


def make_smoke_config(**kw) -> GCNConfig:
    return GCNConfig(name="gcn-smoke", n_layers=2, d_in=24, d_hidden=8,
                     n_classes=3, **kw)


ARCH = ArchDef(name="gcn-cora", family="gnn",
               make_config=make_config, make_smoke_config=make_smoke_config,
               shapes=GNN_SHAPES)
