"""Snowflake Arctic [hf:Snowflake/snowflake-arctic-base]: 35L d7168 56H
(GQA kv=8) head 128, MoE 128 experts top-2 (expert d_ff 4864) + dense
residual MLP, vocab 32000."""

from ..models.moe import MoEConfig
from ..models.transformer import TransformerConfig
from .base import ArchDef, LM_SHAPES


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="arctic-480b",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
        d_ff=4864, vocab=32000,
        moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                      capacity_factor=1.25, dense_residual_d_ff=4864),
        rope_theta=1e6, **kw)


def make_smoke_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="arctic-smoke",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
        d_ff=48, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=48,
                      capacity_factor=2.0, dense_residual_d_ff=48),
        dtype="float32", q_chunk=16, **kw)


ARCH = ArchDef(
    name="arctic-480b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=LM_SHAPES,
    skips={"long_500k": "pure full-attention arch; 500k decode requires "
                        "sub-quadratic attention (DESIGN.md §5)"},
)
