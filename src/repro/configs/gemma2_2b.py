"""Gemma-2 2B [arXiv:2408.00118]: 26L d2304 8H (GQA kv=4) head 256,
d_ff 9216, vocab 256000, alternating 4k-sliding-window / global attention,
attention softcap 50, final logit softcap 30.

The only LM arch that runs ``long_500k``: its local layers keep a 4096-slot
ring KV cache, so a 524288-token decode is sub-quadratic on half the stack
(hybrid; DESIGN.md §5)."""

from ..models.transformer import TransformerConfig
from .base import ArchDef, LM_SHAPES


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-2b",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_head=256,
        d_ff=9216, vocab=256000,
        window_pattern=(4096, None),
        attn_softcap=50.0, final_softcap=30.0,
        rope_theta=1e4, **kw)


def make_smoke_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-smoke",
        n_layers=4, d_model=48, n_heads=4, n_kv_heads=2, d_head=12,
        d_ff=96, vocab=256, window_pattern=(8, None),
        attn_softcap=50.0, final_softcap=30.0,
        dtype="float32", q_chunk=16, **kw)


ARCH = ArchDef(
    name="gemma2-2b", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=LM_SHAPES,
    notes="8 heads < tp=16: attention uses context parallelism "
          "(shard_map, q sequence-sharded, kv all-gathered).  Scenario "
          "bridge: the 4k sliding window bounds the banded-graph "
          "neighborhood, so P = K * 4096 (DESIGN.md §5).",
)
