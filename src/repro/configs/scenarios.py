"""Workload -> scenario bridges: the DESIGN.md §5 tile language as queries.

Each workload config (``configs/<id>.py``) describes a real architecture;
this module translates one (architecture, input shape) cell into the
paper's (N, T, K, L, P) tile language and emits one evaluable
:class:`repro.api.Scenario` per requested dataflow — so, e.g., smollm /
gemma2 / equiformer-v2 / dlrm movement totals across all five registered
dataflows are a one-line query::

    from repro.api import evaluate_scenarios
    from repro.configs import get_arch
    res = evaluate_scenarios(get_arch("gemma2-2b").to_scenarios())

Family mappings (non-obvious cases recorded in DESIGN.md §5/§11):

* **lm** — attention read as a dense GNN on a banded graph: one sequence
  is one tile of K = seq token-vertices; the tightest attention window W
  (full-causal layers contribute W = seq) bounds the per-token
  neighborhood, so P = K * W; the layer stack chains via a multi-layer
  composition with widths ``[d_model] * (n_layers + 1)``.
* **gnn** — the graph is the graph: V/E from the shape (graph-batched
  shapes multiply by ``batch``), feature widths from the model config
  (the per-arch ``scenario_widths`` hook; EquiformerV2 flattens irreps to
  ``(l_max+1)^2 * C``), covered by a tile schedule (full-graph scenario).
* **recsys** — the embedding gather is the aggregation: a batch of
  examples is a tile of K = batch destination vertices, each pulling
  ``n_sparse * multi_hot`` embedding rows (P edges) of N = embed_dim
  features; combination is the interaction + top MLP (T = its output).
"""

from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

from .base import ArchDef, ShapeSpec

__all__ = ["arch_scenarios"]


def _widths(arch: ArchDef, cfg: Any, params: Mapping[str, Any],
            fallback) -> tuple[float, ...]:
    fn = arch.scenario_widths or fallback
    return tuple(float(w) for w in fn(cfg, params))


def _lm_generic_widths(cfg: Any, params: Mapping[str, Any]) -> list[float]:
    return [cfg.d_model] * (cfg.n_layers + 1)


def _gnn_generic_widths(cfg: Any, params: Mapping[str, Any]) -> list[float]:
    d_in = params.get("d_feat", getattr(cfg, "d_in", None))
    if d_in is None:
        raise ValueError(f"cannot infer feature widths for {cfg!r}; give the "
                         "arch a scenario_widths hook")
    return ([d_in] + [cfg.d_hidden] * (cfg.n_layers - 1)
            + [getattr(cfg, "n_classes", getattr(cfg, "d_out", cfg.d_hidden))])


def _lm_scenarios(arch: ArchDef, shape: ShapeSpec, dataflows, Scenario,
                  *, high_degree_fraction: float, **_kw) -> list:
    cfg = arch.make_config()
    seq = float(shape.params["seq"])
    pattern = getattr(cfg, "window_pattern", (None,)) or (None,)
    windows = [seq if w is None else float(w) for w in pattern]
    W = min(min(windows), seq)
    widths = _widths(arch, cfg, shape.params, _lm_generic_widths)
    return [
        Scenario.tile(
            df, K=seq, N=widths[0], T=widths[-1], P=seq * W,
            high_degree_fraction=high_degree_fraction,
            composition={"widths": list(widths), "residency": "spill"},
            label=f"{arch.name}/{shape.name}@{df}",
            workload=f"{arch.name}/{shape.name}")
        for df in dataflows
    ]


def _gnn_trace_dataset(arch: ArchDef, shape: ShapeSpec) -> tuple[str, dict]:
    """DESIGN.md §12: the deterministic trace dataset behind a GNN shape.

    Batched molecular shapes resolve to the block-diagonal ``molecule``
    union graph; the Cora cell resolves to the Cora-sized ``cora``
    dataset; every other shape replays a seeded ``power_law`` graph at
    the shape's exact V/E (self-loop-free, so E matches the shape).
    """
    p = shape.params
    if "batch" in p:
        return "molecule", {"batch": float(p["batch"]),
                            "n_nodes": float(p["n_nodes"]),
                            "n_edges": float(p["n_edges"]),
                            "seed": 0.0, "step": 0.0}
    if arch.name == "gcn-cora" and shape.name == "full_graph_sm":
        return "cora", {}
    return "power_law", {"n_nodes": float(p["n_nodes"]),
                         "n_edges": float(p["n_edges"]), "seed": 0.0}


def _gnn_scenarios(arch: ArchDef, shape: ShapeSpec, dataflows, Scenario,
                   *, tile_vertices: float, high_degree_fraction: float,
                   graph_kind: str = "full", **_kw) -> list:
    p = shape.params
    batch = float(p.get("batch", 1))
    V = float(p["n_nodes"]) * batch
    E = float(p["n_edges"]) * batch
    cfg = arch.make_config()
    widths = _widths(arch, cfg, p, _gnn_generic_widths)
    if graph_kind == "trace":
        dataset, params = _gnn_trace_dataset(arch, shape)
        return [
            Scenario.trace(
                df, dataset=dataset, params=params,
                N=widths[0], T=widths[-1],
                tile_vertices=min(tile_vertices, max(V, 1.0)),
                widths=widths, residency="spill",
                high_degree_fraction=high_degree_fraction,
                label=f"{arch.name}/{shape.name}@{df}/trace",
                workload=f"{arch.name}/{shape.name}")
            for df in dataflows
        ]
    if graph_kind == "hetero":
        # Typed-relation reading of the same shape (DESIGN.md §17): the
        # shape's edge budget replays as an R-relation typed power-law
        # graph at the same V/E, each relation carrying its own weight
        # stack (RGCN-style).  n_relations comes from the arch config
        # when it declares one (e.g. edge types), else defaults to 3.
        R = int(getattr(cfg, "n_edge_types", 0) or 3)
        return [
            Scenario.hetero(
                df, dataset="typed_power_law",
                params={"n_nodes": float(V), "n_edges": float(E),
                        "seed": 0.0},
                n_relations=R,
                N=widths[0], T=widths[-1],
                tile_vertices=min(tile_vertices, max(V, 1.0)),
                widths=widths, residency="spill",
                high_degree_fraction=high_degree_fraction,
                label=f"{arch.name}/{shape.name}@{df}/hetero",
                workload=f"{arch.name}/{shape.name}")
            for df in dataflows
        ]
    return [
        Scenario.full_graph(
            df, V=V, E=E, N=widths[0], T=widths[-1],
            tile_vertices=min(tile_vertices, max(V, 1.0)),
            widths=widths, residency="spill",
            high_degree_fraction=high_degree_fraction,
            label=f"{arch.name}/{shape.name}@{df}",
            workload=f"{arch.name}/{shape.name}")
        for df in dataflows
    ]


def _recsys_scenarios(arch: ArchDef, shape: ShapeSpec, dataflows, Scenario,
                      *, high_degree_fraction: float, **_kw) -> list:
    cfg = arch.make_config()
    K = float(shape.params.get("batch", 1)) \
        * float(shape.params.get("n_candidates", 1))
    P = K * cfg.n_sparse * getattr(cfg, "multi_hot", 1)
    T = float(cfg.top_mlp[-1])
    return [
        Scenario.tile(
            df, K=K, N=float(cfg.embed_dim), T=T, P=P,
            high_degree_fraction=high_degree_fraction,
            label=f"{arch.name}/{shape.name}@{df}",
            workload=f"{arch.name}/{shape.name}")
        for df in dataflows
    ]


_FAMILIES = {"lm": _lm_scenarios, "gnn": _gnn_scenarios,
             "recsys": _recsys_scenarios}


def arch_scenarios(arch: ArchDef, *,
                   shapes: Optional[Sequence[str]] = None,
                   dataflows: Optional[Sequence[str]] = None,
                   tile_vertices: float = 1024.0,
                   high_degree_fraction: float = 0.1,
                   graph_kind: str = "full") -> list:
    """One Scenario per (shape, dataflow) for a workload config.

    ``shapes`` defaults to every non-skipped shape of the arch;
    ``dataflows`` to every registered dataflow.  The result is pure data —
    hand it to :func:`repro.api.evaluate_scenarios` (the planner batches
    all of it into one broadcast evaluation per dataflow).

    ``graph_kind="trace"`` (GNN family only) swaps the uniform full-graph
    composition for §12 exact-schedule scenarios over the deterministic
    trace dataset matching each shape; ``graph_kind="hetero"`` (also GNN
    only) reads the shape as an R-relation typed graph at the same V/E
    (§17), one RGCN-style weight stack per relation.
    """
    from repro.api.scenario import Scenario
    if arch.family not in _FAMILIES:
        raise ValueError(f"no scenario bridge for family {arch.family!r} "
                         f"(arch {arch.name!r})")
    if graph_kind not in ("full", "trace", "hetero"):
        raise ValueError(f"unknown graph_kind {graph_kind!r}; "
                         "expected 'full', 'trace', or 'hetero'")
    if graph_kind in ("trace", "hetero") and arch.family != "gnn":
        raise ValueError(
            f"graph_kind={graph_kind!r} needs a real edge list, which only "
            f"the gnn family shapes carry (arch {arch.name!r} is "
            f"{arch.family!r}); lm/recsys tiles are synthetic-banded and "
            "stay on the closed-form schedule")
    if dataflows is None:
        from repro.core import registry
        dataflows = registry.names()
    shape_names = (tuple(shapes) if shapes is not None
                   else tuple(s for s in arch.shapes if s not in arch.skips))
    out: list = []
    for sname in shape_names:
        if sname not in arch.shapes:
            raise KeyError(f"arch {arch.name!r} has no shape {sname!r}; "
                           f"available: {sorted(arch.shapes)}")
        out.extend(_FAMILIES[arch.family](
            arch, arch.shapes[sname], tuple(dataflows), Scenario,
            tile_vertices=float(tile_vertices),
            high_degree_fraction=float(high_degree_fraction),
            graph_kind=graph_kind))
    return out
