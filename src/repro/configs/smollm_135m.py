"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M]: 30L d576 9H (GQA kv=3)
head 64, d_ff 1536, vocab 49152 (llama-arch small).

Also serves as the end-to-end training example (~135M params; DESIGN.md)."""

from ..models.transformer import TransformerConfig
from .base import ArchDef, LM_SHAPES


def make_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="smollm-135m",
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_head=64,
        d_ff=1536, vocab=49152, rope_theta=1e4, **kw)


def make_smoke_config(**kw) -> TransformerConfig:
    return TransformerConfig(
        name="smollm-smoke",
        n_layers=3, d_model=36, n_heads=3, n_kv_heads=3, d_head=12,
        d_ff=96, vocab=256, dtype="float32", q_chunk=16, **kw)


ARCH = ArchDef(
    name="smollm-135m", family="lm",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=LM_SHAPES,
    skips={"long_500k": "pure full-attention arch; 500k decode requires "
                        "sub-quadratic attention (DESIGN.md §5)"},
    notes="9 heads < tp=16: context-parallel attention path.  Scenario "
          "bridge: full-causal attention, so the banded-graph window is "
          "W = seq (P = K^2 per tile).",
)
