"""EquiformerV2 [arXiv:2306.12059]: 12 layers, 128 channels, l_max 6,
m_max 2, 8 heads, SO(2)-eSCN convolutions."""

from ..models.gnn.equiformer_v2 import EquiformerV2Config
from .base import ArchDef, GNN_SHAPES


def make_config(*, d_in: int = 16, **kw) -> EquiformerV2Config:
    return EquiformerV2Config(name="equiformer-v2", n_layers=12, d_hidden=128,
                              l_max=6, m_max=2, n_heads=8, d_in=d_in, **kw)


def make_smoke_config(**kw) -> EquiformerV2Config:
    return EquiformerV2Config(name="equiformer-smoke", n_layers=2, d_hidden=16,
                              l_max=2, m_max=1, n_heads=4, d_in=8, **kw)


def scenario_widths(cfg, params) -> list[int]:
    """§5 tile language: irreps flatten to N_eff = (l_max+1)^2 * C per layer."""
    n_eff = (cfg.l_max + 1) ** 2 * cfg.d_hidden
    return [params.get("d_feat", cfg.d_in)] + [n_eff] * cfg.n_layers


ARCH = ArchDef(name="equiformer-v2", family="gnn",
               make_config=make_config, make_smoke_config=make_smoke_config,
               shapes=GNN_SHAPES,
               notes="Irrep features flatten to N_eff = (l_max+1)^2 * C for "
                     "the paper's tile models (DESIGN.md §5). Self-loop-free "
                     "edge lists required (zero edge vectors have no frame).",
               scenario_widths=scenario_widths)
