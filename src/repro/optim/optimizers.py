"""Optimizers: AdamW and SGD with schedules and global-norm clipping.

Functional, optax-shaped API (init/update pytrees) without the dependency:

    opt = adamw(lr=3e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

States are pytrees of arrays, so they shard with the same PartitionSpecs as
the parameters (and over the dp axis when ZeRO-1 is enabled by the policy).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = object

__all__ = ["Optimizer", "adamw", "sgd", "apply_updates", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "linear_warmup"]


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> tuple[PyTree, Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda x: x * scale.astype(x.dtype), tree), norm


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable[[Array], Array]:
    def lr(step: Array) -> Array:
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def linear_warmup(base_lr: float, warmup: int) -> Callable[[Array], Array]:
    def lr(step: Array) -> Array:
        return base_lr * jnp.minimum(1.0, step.astype(jnp.float32) / max(warmup, 1))
    return lr


class AdamWState(NamedTuple):
    step: Array
    mu: PyTree
    nu: PyTree


def adamw(lr: float | Callable[[Array], Array] = 1e-3, *, b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = 1.0,
          state_dtype=jnp.float32) -> Optimizer:
    """AdamW.  ``state_dtype=bf16`` halves m/v memory — required to fit
    arctic-480b's optimizer on 256 chips (DESIGN.md records the numeric
    trade-off; 8-bit blockwise states are the production hardening step).
    Moment math always runs in f32; states are stored in ``state_dtype``."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params: PyTree) -> AdamWState:
        zeros = lambda p: jax.tree_util.tree_map(
            lambda x: jnp.zeros_like(x, dtype=state_dtype), p)
        return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def update(grads: PyTree, state: AdamWState, params: PyTree):
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        else:
            gnorm = global_norm(grads)
        step = state.step + 1
        stepf = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** stepf
        bc2 = 1.0 - b2 ** stepf
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mh = m32 / bc1
            vh = v32 / bc2
            du = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                du = du + weight_decay * p.astype(jnp.float32)
            return ((-lr_t * du).astype(p.dtype), m32.astype(state_dtype),
                    v32.astype(state_dtype))

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        mu = tdef.unflatten([o[1] for o in out])
        nu = tdef.unflatten([o[2] for o in out])
        new_state = AdamWState(step, mu, nu)
        return updates, new_state

    return Optimizer(init=init, update=update)


class SGDState(NamedTuple):
    step: Array
    momentum: PyTree


def sgd(lr: float | Callable[[Array], Array] = 1e-2, *, momentum: float = 0.9,
        clip_norm: Optional[float] = None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params: PyTree) -> SGDState:
        z = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32), params)
        return SGDState(jnp.zeros((), jnp.int32), z)

    def update(grads: PyTree, state: SGDState, params: PyTree):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m):
            m = momentum * m + g.astype(jnp.float32)
            return (-lr_t * m), m

        pairs = jax.tree_util.tree_map(upd, grads, state.momentum)
        updates = jax.tree_util.tree_map(
            lambda p, pair: pair[0].astype(p.dtype), params, pairs,
            is_leaf=lambda x: isinstance(x, tuple))
        mom = jax.tree_util.tree_map(
            lambda pair: pair[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return updates, SGDState(step, mom)

    return Optimizer(init=init, update=update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)
