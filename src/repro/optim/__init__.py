"""Optimizers, schedules, gradient compression."""

from .optimizers import adamw, sgd, apply_updates, global_norm

__all__ = ["adamw", "sgd", "apply_updates", "global_norm"]
