"""int8 error-feedback gradient compression for data-parallel all-reduce.

At 1000+ nodes the DP gradient all-reduce is frequently the collective-term
bottleneck (see §Roofline for the LM train cells).  Quantizing gradients to
int8 with per-tensor scales cuts the wire bytes 4x (f32) / 2x (bf16); the
quantization error is carried in an error-feedback buffer and re-added next
step (Karimireddy et al., arXiv:1901.09847), which preserves convergence.

``compress_decompress`` is the functional core (tested for the
contraction property); ``wrap_optimizer`` composes it with any
:class:`repro.optim.optimizers.Optimizer`.  The wire-byte saving is modeled
by the ``compressed_ratio`` argument of
:func:`repro.core.tpu_model.dp_gradient_sync`.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .optimizers import Optimizer

__all__ = ["compress_decompress", "wrap_optimizer", "CompressedState"]


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (decompressed gradient as seen after all-reduce, new error)."""
    g32 = g.astype(jnp.float32) + err
    q, scale = _quantize(g32)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), g32 - deq


class CompressedState(NamedTuple):
    inner: object
    error: object


def wrap_optimizer(optimizer: Optimizer) -> Optimizer:
    def init(params):
        err = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return CompressedState(optimizer.init(params), err)

    def update(grads, state, params):
        pairs = jax.tree_util.tree_map(compress_decompress, grads, state.error)
        deq = jax.tree_util.tree_map(lambda pr: pr[0], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
        err = jax.tree_util.tree_map(lambda pr: pr[1], pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
        updates, inner = optimizer.update(deq, state.inner, params)
        return updates, CompressedState(inner, err)

    return Optimizer(init=init, update=update)
