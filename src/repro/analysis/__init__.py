"""Static model auditor for the movement-level closed forms (DESIGN.md §16).

Two engines, importable as a library and runnable as a CLI
(``python -m repro.analysis``):

* :mod:`repro.analysis.audit` — a symbolic tracer that runs every
  registered ``MovementSpec.form`` on unit-tagged, interval-bounded
  tracer records and derives dimensional consistency, symbol provenance
  (with dead-hardware detection), and a float64-exactness audit against
  the ROADMAP operating envelope.
* :mod:`repro.analysis.lint` — an AST linter over ``repro.core`` /
  ``repro.distributed`` enforcing closed-form and trace-path idioms
  (no builtin ``min``/``max``/``math.ceil`` in forms, no ``np.lexsort``
  or edge-list materialization in trace paths, literal MovementSpec
  vocabularies).

A mutation battery (:mod:`repro.analysis.mutations`) injects realistic
transcription errors and asserts the auditor catches every one.
"""

from .audit import (DEFAULT_ENVELOPE, MovementAudit, SpecAudit,
                    analysis_cache_info, audit_composition_forms,
                    audit_registry, audit_spec, clear_analysis_cache,
                    render_provenance)
from .lint import LintViolation, default_lint_roots, lint_paths, lint_source
from .mutations import (Mutant, MutationOutcome, mutate_spec,
                        run_mutation_battery)
from .tracer import (FLOAT64_EXACT_MAX, OverflowRecord, SymbolicValue,
                     TraceAbort, TraceContext, UnitIssue, trace_form,
                     traced_record)
from .units import BITS, DIMENSIONLESS, UNIT_TAGS, Unit, unit_from_tag

__all__ = [
    "Unit", "BITS", "DIMENSIONLESS", "UNIT_TAGS", "unit_from_tag",
    "SymbolicValue", "TraceContext", "TraceAbort", "UnitIssue",
    "OverflowRecord", "FLOAT64_EXACT_MAX", "traced_record", "trace_form",
    "MovementAudit", "SpecAudit", "audit_spec", "audit_registry",
    "audit_composition_forms", "analysis_cache_info",
    "clear_analysis_cache", "render_provenance",
    "DEFAULT_ENVELOPE",
    "LintViolation", "lint_source", "lint_paths", "default_lint_roots",
    "Mutant", "MutationOutcome", "mutate_spec", "run_mutation_battery",
]
