"""Symbolic tracer: evaluate ``MovementSpec.form`` with tracer values.

One tracer pass evaluates a closed form exactly as the shared engine does —
same Python code path, same numpy calls — but with :class:`SymbolicValue`
operands that carry, instead of numbers:

* a **unit** (:mod:`repro.analysis.units`) seeded from the Table II
  declarations in :mod:`repro.core.notation`,
* the set of **symbols** (``graph.N``, ``hw.sigma``, ...) that reached the
  value through arithmetic — the provenance record, and
* an **interval bound** ``[lo, hi]`` propagated from the declared operating
  envelope, from which the float64-exactness audit flags any intermediate
  that can exceed 2^53 (the integer-exact range).

Dispatch mechanics: numpy ufuncs (``np.ceil``, ``np.minimum``,
``np.maximum``, arithmetic) reach the tracer through ``__array_ufunc__``
and array functions (``np.where``, ``np.ones_like``) through
``__array_function__`` — both protocols fire for *any* operand defining
them, no ndarray subclassing needed.  The one numpy entry point exempt from
both protocols is ``np.asarray`` (the ``_f64`` helper every closed form
opens with), so :func:`tracing_numpy` patches it for the duration of a
form call to pass tracers through unchanged; the patch is scoped by a
module lock and restored in ``finally``.

Unit violations do not abort the trace: the offending op is recorded as a
:class:`UnitIssue` and evaluation continues with a declared recovery unit,
so one pass yields *all* of a movement's errors plus its full provenance.
Only data-dependent Python control flow (``if tracer:``, ``float(tracer)``)
aborts, because no sound single-path trace exists for it.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field

import numpy as np

from ..core.notation import unit_declarations_for
from .units import BITS, DIMENSIONLESS, Unit, unit_from_tag

__all__ = [
    "FLOAT64_EXACT_MAX",
    "UnitIssue",
    "OverflowRecord",
    "TraceAbort",
    "TraceContext",
    "SymbolicValue",
    "tracing_numpy",
    "traced_record",
    "trace_form",
]

#: Largest magnitude at which every integer is exactly representable in
#: float64 (2^53).  Intermediates whose interval bound exceeds this lose
#: integer exactness — the paper's ceil-of-ratio algebra silently degrades.
FLOAT64_EXACT_MAX = float(2 ** 53)

_TRACE_LOCK = threading.RLock()


class TraceAbort(RuntimeError):
    """A closed form performed an operation no single-path trace covers
    (data-dependent Python branching / scalar coercion of a tracer)."""


@dataclass(frozen=True)
class UnitIssue:
    """One unit-algebra violation inside a traced closed form."""

    movement: str
    op: str
    detail: str

    def __str__(self) -> str:
        return f"{self.movement}: {self.op}: {self.detail}"


@dataclass(frozen=True)
class OverflowRecord:
    """An intermediate whose envelope bound exceeds the 2^53 exact range."""

    movement: str
    op: str
    symbols: tuple[str, ...]
    bound: float

    def __str__(self) -> str:
        return (f"{self.movement}: {self.op} over {', '.join(self.symbols)} "
                f"reaches {self.bound:.4g} > 2^53")


@dataclass
class TraceContext:
    """Mutable collector shared by every tracer of one movement pass."""

    movement: str = "<form>"
    issues: list = field(default_factory=list)
    overflows: list = field(default_factory=list)
    minimum_calls: int = 0

    def issue(self, op: str, detail: str) -> None:
        self.issues.append(UnitIssue(self.movement, op, detail))

    def overflow(self, op: str, symbols: frozenset, bound: float) -> None:
        self.overflows.append(OverflowRecord(
            self.movement, op, tuple(sorted(symbols)), bound))


def _mul_bound(a: float, b: float) -> float:
    """inf * 0 -> 0 convention (an exactly-zero factor kills the product)."""
    if a == 0.0 or b == 0.0:
        return 0.0
    return a * b


def _interval_mul(alo, ahi, blo, bhi):
    c = (_mul_bound(alo, blo), _mul_bound(alo, bhi),
         _mul_bound(ahi, blo), _mul_bound(ahi, bhi))
    return min(c), max(c)


def _interval_div(alo, ahi, blo, bhi):
    if blo <= 0.0 <= bhi:
        return -math.inf, math.inf
    c = (alo / blo, alo / bhi, ahi / blo, ahi / bhi)
    return min(c), max(c)


class SymbolicValue:
    """A traced operand: unit x provenance symbols x interval bound."""

    __slots__ = ("ctx", "unit", "symbols", "lo", "hi", "nominal")

    def __init__(self, ctx: TraceContext, unit: Unit, symbols: frozenset,
                 lo: float, hi: float, nominal: str = "") -> None:
        self.ctx = ctx
        self.unit = unit
        self.symbols = symbols
        self.lo = float(lo)
        self.hi = float(hi)
        self.nominal = nominal

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        syms = ",".join(sorted(self.symbols)) or "const"
        return (f"SymbolicValue({syms}: {self.unit}, "
                f"[{self.lo:.4g}, {self.hi:.4g}])")

    # -- helpers -----------------------------------------------------------
    def _make(self, unit: Unit, symbols: frozenset, lo: float, hi: float,
              op: str) -> "SymbolicValue":
        out = SymbolicValue(self.ctx, unit, symbols, lo, hi)
        if math.isfinite(out.hi) and out.hi > FLOAT64_EXACT_MAX:
            self.ctx.overflow(op, symbols, out.hi)
        return out

    def _coerce(self, x) -> "SymbolicValue":
        """Lift a plain numeric operand to a dimensionless constant."""
        if isinstance(x, SymbolicValue):
            return x
        arr = np.asarray(x, dtype=np.float64)
        lo = float(arr.min()) if arr.size else 0.0
        hi = float(arr.max()) if arr.size else 0.0
        return SymbolicValue(self.ctx, DIMENSIONLESS, frozenset(), lo, hi)

    def _same_unit(self, other: "SymbolicValue", op: str) -> Unit:
        """Units must agree for +/-/min/max/where; record and recover."""
        if other.unit != self.unit:
            self.ctx.issue(op, f"operands carry mismatched units "
                               f"{self.unit} vs {other.unit} "
                               f"(symbols {sorted(self.symbols | other.symbols)})")
        return self.unit

    # -- the op table ------------------------------------------------------
    def _binop(self, other, op: str):
        other = self._coerce(other)
        syms = self.symbols | other.symbols
        if op == "multiply":
            lo, hi = _interval_mul(self.lo, self.hi, other.lo, other.hi)
            return self._make(self.unit * other.unit, syms, lo, hi, op)
        if op in ("divide", "true_divide"):
            lo, hi = _interval_div(self.lo, self.hi, other.lo, other.hi)
            return self._make(self.unit / other.unit, syms, lo, hi, op)
        if op == "add":
            unit = self._same_unit(other, op)
            return self._make(unit, syms, self.lo + other.lo,
                              self.hi + other.hi, op)
        if op == "subtract":
            unit = self._same_unit(other, op)
            return self._make(unit, syms, self.lo - other.hi,
                              self.hi - other.lo, op)
        if op == "minimum":
            self.ctx.minimum_calls += 1
            unit = self._same_unit(other, op)
            return self._make(unit, syms, min(self.lo, other.lo),
                              min(self.hi, other.hi), op)
        if op == "maximum":
            unit = self._same_unit(other, op)
            return self._make(unit, syms, max(self.lo, other.lo),
                              max(self.hi, other.hi), op)
        if op in ("greater", "greater_equal", "less", "less_equal",
                  "equal", "not_equal"):
            self._same_unit(other, op)
            return self._make(DIMENSIONLESS, syms, 0.0, 1.0, op)
        raise AssertionError(f"unhandled binop {op}")  # pragma: no cover

    def _rounding(self, op: str):
        if not self.unit.is_dimensionless:
            self.ctx.issue(op, f"applied to a non-dimensionless quantity "
                               f"({self.unit}; symbols "
                               f"{sorted(self.symbols)}) — ceil/floor are "
                               f"occupancy-ratio operators")
        fn = math.ceil if op == "ceil" else math.floor
        lo = fn(self.lo) if math.isfinite(self.lo) else self.lo
        hi = fn(self.hi) if math.isfinite(self.hi) else self.hi
        return self._make(DIMENSIONLESS, self.symbols, lo, hi, op)

    # -- numpy protocol ----------------------------------------------------
    _UFUNC_BINOPS = {
        np.add: "add", np.subtract: "subtract", np.multiply: "multiply",
        np.divide: "divide", np.true_divide: "true_divide",
        np.minimum: "minimum", np.maximum: "maximum",
        np.greater: "greater", np.greater_equal: "greater_equal",
        np.less: "less", np.less_equal: "less_equal",
        np.equal: "equal", np.not_equal: "not_equal",
    }

    def __array_ufunc__(self, ufunc, method, *inputs, **kwargs):
        if method != "__call__" or kwargs.get("out") is not None:
            self.ctx.issue(getattr(ufunc, "__name__", str(ufunc)),
                           f"unsupported ufunc method {method!r} in a "
                           "closed form")
            return self._conservative(inputs)
        name = self._UFUNC_BINOPS.get(ufunc)
        if name is not None:
            a = self._coerce(inputs[0])
            return a._binop(inputs[1], name)
        if ufunc is np.ceil or ufunc is np.floor:
            return self._coerce(inputs[0])._rounding(ufunc.__name__)
        if ufunc is np.negative:
            a = self._coerce(inputs[0])
            return a._make(a.unit, a.symbols, -a.hi, -a.lo, "negative")
        if ufunc is np.positive:
            return self._coerce(inputs[0])
        self.ctx.issue(ufunc.__name__, "ufunc not in the closed-form "
                                       "vocabulary (terms.ceil / "
                                       "terms.minimum / broadcasting "
                                       "arithmetic)")
        return self._conservative(inputs)

    def __array_function__(self, func, types, args, kwargs):
        if func is np.where and len(args) == 3:
            cond = self._coerce(args[0])
            a, b = self._coerce(args[1]), self._coerce(args[2])
            unit = a._same_unit(b, "where")
            syms = cond.symbols | a.symbols | b.symbols
            return self._make(unit, syms, min(a.lo, b.lo),
                              max(a.hi, b.hi), "where")
        if func is np.ones_like:
            return SymbolicValue(self.ctx, DIMENSIONLESS, frozenset(),
                                 1.0, 1.0)
        if func is np.zeros_like:
            return SymbolicValue(self.ctx, DIMENSIONLESS, frozenset(),
                                 0.0, 0.0)
        self.ctx.issue(getattr(func, "__name__", str(func)),
                       "array function not in the closed-form vocabulary")
        flat = [a for a in args if isinstance(a, SymbolicValue)]
        return self._conservative(flat)

    def _conservative(self, inputs) -> "SymbolicValue":
        syms = frozenset().union(*(i.symbols for i in inputs
                                   if isinstance(i, SymbolicValue)))
        return SymbolicValue(self.ctx, DIMENSIONLESS, syms,
                             -math.inf, math.inf)

    # -- Python operators --------------------------------------------------
    def __add__(self, o): return self._binop(o, "add")
    def __radd__(self, o): return self._coerce(o)._binop(self, "add")
    def __sub__(self, o): return self._binop(o, "subtract")
    def __rsub__(self, o): return self._coerce(o)._binop(self, "subtract")
    def __mul__(self, o): return self._binop(o, "multiply")
    def __rmul__(self, o): return self._coerce(o)._binop(self, "multiply")
    def __truediv__(self, o): return self._binop(o, "divide")
    def __rtruediv__(self, o): return self._coerce(o)._binop(self, "divide")
    def __neg__(self): return self._make(self.unit, self.symbols,
                                         -self.hi, -self.lo, "negative")
    def __lt__(self, o): return self._binop(o, "less")
    def __le__(self, o): return self._binop(o, "less_equal")
    def __gt__(self, o): return self._binop(o, "greater")
    def __ge__(self, o): return self._binop(o, "greater_equal")

    def __pow__(self, k):
        if not isinstance(k, (int, float)) or k != int(k) or k < 0:
            self.ctx.issue("power", f"non-integer exponent {k!r}")
            return self._conservative((self,))
        k = int(k)
        lo, hi = self.lo, self.hi
        for _ in range(k - 1):
            lo, hi = _interval_mul(lo, hi, self.lo, self.hi)
        if k == 0:
            lo = hi = 1.0
        return self._make(self.unit ** k, self.symbols, lo, hi, "power")

    # -- soundness guards --------------------------------------------------
    def __bool__(self):
        raise TraceAbort(
            f"{self.ctx.movement}: data-dependent Python branch on "
            f"{sorted(self.symbols)} — closed forms must stay "
            "branch-free (use np.where / terms.minimum)")

    def __float__(self):
        raise TraceAbort(
            f"{self.ctx.movement}: scalar coercion of a traced value "
            f"({sorted(self.symbols)}) — the form would lose broadcasting")

    __int__ = __float__
    __index__ = __float__


@contextmanager
def tracing_numpy():
    """Patch ``np.asarray`` to pass :class:`SymbolicValue` through.

    The ``_f64`` helpers every closed form opens with call
    ``np.asarray(x, dtype=np.float64)``, which neither ``__array_ufunc__``
    nor ``__array_function__`` can intercept.  Scoped by the module trace
    lock; everything else reaches the tracer via the numpy protocols.
    """
    with _TRACE_LOCK:
        orig = np.asarray

        def _asarray(a, *args, **kwargs):
            if isinstance(a, SymbolicValue):
                return a
            return orig(a, *args, **kwargs)

        np.asarray = _asarray
        try:
            yield
        finally:
            np.asarray = orig


def traced_record(record, role: str, ctx: TraceContext, *,
                  overrides=None):
    """A copy of a parameter record whose fields are seeded tracers.

    ``role`` prefixes the provenance symbols (``graph.N`` / ``hw.sigma``).
    Fields declared without an envelope (``lo``/``hi`` None) are pinned to
    the record's own value — a point interval at the published design
    point.  ``overrides`` maps field names to ``(lo, hi)`` pairs that
    replace the declared envelope (the CLI's --max-edges family).
    ``None``-valued fields (EnGN's ``B_star`` default) are left in place
    so the record's own fallback properties keep working.
    """
    decls = unit_declarations_for(record)
    overrides = overrides or {}
    updates = {}
    for f in dataclasses.fields(record):
        value = getattr(record, f.name)
        if value is None:
            continue
        decl = decls[f.name]
        point = float(np.asarray(value, dtype=np.float64))
        lo = point if decl.lo is None else float(decl.lo)
        hi = point if decl.hi is None else float(decl.hi)
        if f.name in overrides:
            lo, hi = (float(x) for x in overrides[f.name])
        updates[f.name] = SymbolicValue(
            ctx, unit_from_tag(decl.unit),
            frozenset({f"{role}.{f.name}"}), lo, hi, nominal=decl.unit)
    return dataclasses.replace(record, **updates)


def trace_form(form, traced_graph, traced_hw, ctx: TraceContext,
               movement: str = "<form>"):
    """Run one closed form under the tracer; returns (bits, iterations).

    Either result may come back as a plain constant (a degenerate form);
    both are coerced to tracers so the audit can interrogate them
    uniformly.  Unit issues accumulate in ``ctx``; only unsound traces
    (:class:`TraceAbort`) raise.
    """
    ctx.movement = movement
    with tracing_numpy():
        bits, iters = form(traced_graph, traced_hw)
    anchor = SymbolicValue(ctx, DIMENSIONLESS, frozenset(), 0.0, 0.0)
    if not isinstance(bits, SymbolicValue):
        bits = anchor._coerce(bits)
    if not isinstance(iters, SymbolicValue):
        iters = anchor._coerce(iters)
    return bits, iters
