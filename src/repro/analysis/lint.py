"""Repo-wide closed-form linter (AST, no imports of the linted code).

Three rule families over ``src/repro/core/`` and ``src/repro/distributed/``
(the modules holding closed forms and trace-pipeline stages):

``form-builtin-min`` / ``form-builtin-max`` / ``form-math-ceil``
    Inside a *closed form* — any function passed as ``MovementSpec``'s
    ``form`` argument, plus module-local helpers it (transitively) calls —
    Python's ``min``/``max``/``math.ceil`` are forbidden: they coerce
    array operands to scalars, silently breaking the broadcasting contract
    every sweep relies on.  Forms must use ``terms.minimum`` /
    ``terms.ceil`` / ``np.maximum``.

``trace-lexsort`` / ``trace-edge-list``
    The PR-6 invariant, promoted from convention to a check: trace-path
    modules (``core/trace.py`` and everything under ``distributed/``)
    must not call ``np.lexsort`` (the amortized engine's composite-key
    sort replaced it; the one legacy overflow fallback carries a pragma),
    and ``distributed/`` stages must not construct ``GraphTrace(...)``
    directly — edge-list-free construction goes through
    ``GraphTrace.from_factorization``.

``movement-vocab``
    Every ``MovementSpec(...)`` call site must pass its hierarchy and role
    as *string literals* drawn from the declared vocabularies
    (``terms`` hierarchy classes, ``dataflow.MOVEMENT_ROLES``).  The
    runtime validates roles at construction but hierarchies only on first
    evaluation — the linter catches a typo'd hierarchy before any
    evaluation runs.

A violation on a line containing ``# lint: allow-<rule>`` is suppressed;
every suppression is a recorded decision greppable by rule name.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, Optional, Sequence

__all__ = [
    "LintViolation",
    "lint_source",
    "lint_paths",
    "default_lint_roots",
    "VALID_HIERARCHIES",
    "VALID_ROLES",
]

#: Kept in sync with repro.core.terms / repro.core.dataflow (asserted in
#: tests/test_analysis.py so the vocabularies cannot silently diverge).
VALID_HIERARCHIES = ("L2-L1", "L1-L2", "L2*-L1", "L1-L2*", "L1-L1")
VALID_ROLES = ("vertex_in", "vertex_out", "edges", "weights", "compute",
               "interphase", "other")

_FORBIDDEN_BUILTINS = {"min": "form-builtin-min", "max": "form-builtin-max"}


@dataclass(frozen=True)
class LintViolation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def as_dict(self) -> dict:
        return {"path": self.path, "line": self.line, "rule": self.rule,
                "message": self.message}


def default_lint_roots() -> tuple[Path, ...]:
    """``src/repro/core`` and ``src/repro/distributed`` of this checkout."""
    pkg = Path(__file__).resolve().parents[1]
    return (pkg / "core", pkg / "distributed")


def _is_trace_path(path: str) -> bool:
    p = path.replace("\\", "/")
    return "/distributed/" in p or p.endswith("distributed") \
        or p.endswith("trace.py")


def _is_distributed(path: str) -> bool:
    return "/distributed/" in path.replace("\\", "/")


class _ModuleIndex(ast.NodeVisitor):
    """Module-level function defs, math import aliases, MovementSpec calls."""

    def __init__(self) -> None:
        self.functions: dict[str, ast.FunctionDef] = {}
        self.math_aliases: set[str] = set()        # names bound to math
        self.math_ceil_aliases: set[str] = set()   # names bound to math.ceil
        self.movementspec_calls: list[ast.Call] = []

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            if a.name == "math":
                self.math_aliases.add(a.asname or "math")

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "math":
            for a in node.names:
                if a.name == "ceil":
                    self.math_ceil_aliases.add(a.asname or "ceil")

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.functions.setdefault(node.name, node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call) -> None:
        name = None
        if isinstance(node.func, ast.Name):
            name = node.func.id
        elif isinstance(node.func, ast.Attribute):
            name = node.func.attr
        if name == "MovementSpec":
            self.movementspec_calls.append(node)
        self.generic_visit(node)


def _form_argument(call: ast.Call) -> Optional[ast.expr]:
    """The ``form`` argument of a MovementSpec(...) call, if present."""
    if len(call.args) >= 3:
        return call.args[2]
    for kw in call.keywords:
        if kw.arg == "form":
            return kw.value
    return None


def _positional_or_kw(call: ast.Call, index: int,
                      kw_name: str) -> Optional[ast.expr]:
    if len(call.args) > index:
        return call.args[index]
    for kw in call.keywords:
        if kw.arg == kw_name:
            return kw.value
    return None


def _reachable_forms(index: _ModuleIndex) -> dict[str, ast.FunctionDef]:
    """Form functions + transitively-called module-local helpers."""
    seeds = []
    for call in index.movementspec_calls:
        arg = _form_argument(call)
        if isinstance(arg, ast.Name) and arg.id in index.functions:
            seeds.append(arg.id)
    reachable: dict[str, ast.FunctionDef] = {}
    stack = list(seeds)
    while stack:
        name = stack.pop()
        if name in reachable:
            continue
        fn = index.functions.get(name)
        if fn is None:
            continue
        reachable[name] = fn
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                if node.func.id in index.functions:
                    stack.append(node.func.id)
    return reachable


def _suppressed(source_lines: Sequence[str], line: int, rule: str) -> bool:
    if 1 <= line <= len(source_lines):
        return f"# lint: allow-{rule}" in source_lines[line - 1]
    return False


def lint_source(source: str, path: str = "<string>") -> list[LintViolation]:
    """Lint one module's source text; returns violations (pragmas applied)."""
    tree = ast.parse(source, filename=path)
    lines = source.splitlines()
    index = _ModuleIndex()
    index.visit(tree)
    out: list[LintViolation] = []

    def add(line: int, rule: str, message: str) -> None:
        if not _suppressed(lines, line, rule):
            out.append(LintViolation(path, line, rule, message))

    # Rule family 1: builtins inside closed forms.
    for fname, fn in sorted(_reachable_forms(index).items()):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name):
                rule = _FORBIDDEN_BUILTINS.get(node.func.id)
                if rule is not None:
                    add(node.lineno, rule,
                        f"builtin {node.func.id}() inside closed form "
                        f"{fname}() collapses array sweeps to scalars; "
                        f"use terms.{'minimum' if node.func.id == 'min' else 'maximum/np.maximum'}")
                if node.func.id in index.math_ceil_aliases:
                    add(node.lineno, "form-math-ceil",
                        f"math.ceil inside closed form {fname}() breaks "
                        "broadcasting; use terms.ceil")
            elif isinstance(node.func, ast.Attribute):
                base = node.func.value
                if (isinstance(base, ast.Name)
                        and base.id in index.math_aliases
                        and node.func.attr == "ceil"):
                    add(node.lineno, "form-math-ceil",
                        f"math.ceil inside closed form {fname}() breaks "
                        "broadcasting; use terms.ceil")

    # Rule family 2: trace-path invariants.
    if _is_trace_path(path):
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "lexsort"):
                add(node.lineno, "trace-lexsort",
                    "np.lexsort in a trace path — the composite-key sort "
                    "(GraphTrace._pair_factorization) replaced it "
                    "(DESIGN.md §13/§14)")
        if _is_distributed(path):
            for node in ast.walk(tree):
                if not isinstance(node, ast.Call):
                    continue
                callee = node.func
                name = (callee.id if isinstance(callee, ast.Name)
                        else callee.attr if isinstance(callee, ast.Attribute)
                        else None)
                if name == "GraphTrace":
                    add(node.lineno, "trace-edge-list",
                        "direct GraphTrace(...) construction materializes "
                        "an edge list; distributed stages must use "
                        "GraphTrace.from_factorization (DESIGN.md §14)")

    # Rule family 3: MovementSpec vocabularies, statically.
    for call in index.movementspec_calls:
        hier = _positional_or_kw(call, 1, "hierarchy")
        role = _positional_or_kw(call, 3, "role")
        if hier is not None:
            if not (isinstance(hier, ast.Constant)
                    and isinstance(hier.value, str)):
                add(call.lineno, "movement-vocab",
                    "MovementSpec hierarchy must be a string literal from "
                    f"the declared vocabulary {VALID_HIERARCHIES}")
            elif hier.value not in VALID_HIERARCHIES:
                add(call.lineno, "movement-vocab",
                    f"unknown hierarchy {hier.value!r}; declared vocabulary "
                    f"is {VALID_HIERARCHIES}")
        if role is not None:
            if not (isinstance(role, ast.Constant)
                    and isinstance(role.value, str)):
                add(call.lineno, "movement-vocab",
                    "MovementSpec role must be a string literal from "
                    f"the declared vocabulary {VALID_ROLES}")
            elif role.value not in VALID_ROLES:
                add(call.lineno, "movement-vocab",
                    f"unknown role {role.value!r}; declared vocabulary "
                    f"is {VALID_ROLES}")
    return out


def lint_paths(roots: Optional[Iterable[Path]] = None
               ) -> list[LintViolation]:
    """Lint every ``*.py`` under the given roots (default: the repo's
    closed-form and trace-path packages)."""
    roots = tuple(Path(r) for r in (roots or default_lint_roots()))
    out: list[LintViolation] = []
    for root in roots:
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            out.extend(lint_source(f.read_text(), str(f)))
    return out
