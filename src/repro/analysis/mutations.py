"""Mutation battery: prove the auditor has teeth.

Each mutant injects a realistic transcription error into a registered
``DataflowSpec`` (without touching the module source) and re-audits.  The
battery passes only if *every* generated mutant is caught by at least one
engine:

``drop-sigma``
    Evaluate the closed forms with ``sigma = 1.0`` — the classic "forgot
    the word-width factor" bug.  Caught by the unit checker (a
    bits-carrying pin disappears from the reduction is not observable
    symbolically, but the numeric value pins and golden totals move) and
    by the value fingerprint.

``swap-NT``
    Transpose the tile dimensions (``N <-> T``) at the call boundary —
    a row/column mix-up.  Caught by the value fingerprint whenever a form
    is N/T-asymmetric, and by golden drift.

``degenerate-minimum``
    Replace the capacity operator ``terms.minimum`` with "first argument
    wins" inside the form's module globals — i.e. delete the bandwidth
    cap.  Only generated for specs whose baseline trace actually calls
    ``minimum`` (the tiled-SpMM forms do not).  Caught by value pins /
    golden drift, and often by unit errors when the waived mixed-unit
    ``min`` disappears.

"Caught" is decided against the *baseline* audit of the same spec under
the same envelope: new un-waived unit errors, any changed per-movement
fingerprint (symbol set + Sec. IV value pins), or a golden-total
mismatch.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from ..core import registry
from ..core.dataflow import DataflowSpec
from ..core.notation import paper_default_graph
from .audit import SpecAudit, audit_spec
from .tracer import TraceContext, trace_form, traced_record

__all__ = ["Mutant", "MutationOutcome", "mutate_spec", "run_mutation_battery"]


@dataclass(frozen=True)
class Mutant:
    """One mutated spec plus the description of the injected fault."""

    name: str
    description: str
    spec: DataflowSpec


@dataclass(frozen=True)
class MutationOutcome:
    spec: str
    mutant: str
    caught: bool
    caught_by: tuple[str, ...]

    def as_dict(self) -> dict:
        return {"spec": self.spec, "mutant": self.mutant,
                "caught": self.caught, "caught_by": list(self.caught_by)}


def _wrap_movements(spec: DataflowSpec, wrap: Callable, suffix: str
                    ) -> DataflowSpec:
    movements = tuple(
        dataclasses.replace(m, form=wrap(m.form)) for m in spec.movements
    )
    return dataclasses.replace(spec, name=f"{spec.name}::{suffix}",
                               movements=movements)


def _drop_sigma(spec: DataflowSpec) -> Optional[DataflowSpec]:
    hw = spec.hw_factory()
    if not hasattr(hw, "sigma"):
        return None

    def wrap(form):
        def mutated(g, h):
            return form(g, dataclasses.replace(h, sigma=1.0))
        mutated.__name__ = f"{getattr(form, '__name__', 'form')}__drop_sigma"
        return mutated

    return _wrap_movements(spec, wrap, "drop-sigma")


def _swap_nt(spec: DataflowSpec) -> Optional[DataflowSpec]:
    def wrap(form):
        def mutated(g, h):
            return form(dataclasses.replace(g, N=g.T, T=g.N), h)
        mutated.__name__ = f"{getattr(form, '__name__', 'form')}__swap_nt"
        return mutated

    return _wrap_movements(spec, wrap, "swap-NT")


def _spec_calls_minimum(spec: DataflowSpec) -> bool:
    """Baseline symbolic trace: does any movement hit ``terms.minimum``?"""
    graph = paper_default_graph()
    hw = spec.hw_factory()
    for m in spec.movements:
        ctx = TraceContext(movement=m.name)
        try:
            tg = traced_record(graph, "graph", ctx)
            th = traced_record(hw, "hw", ctx)
            trace_form(m.form, tg, th, ctx, movement=m.name)
        except Exception:
            continue
        if ctx.minimum_calls:
            return True
    return False


def _degenerate_minimum(spec: DataflowSpec) -> Optional[DataflowSpec]:
    if not _spec_calls_minimum(spec):
        return None

    def first_arg_wins(*xs):
        return np.asarray(xs[0], dtype=np.float64)

    def wrap(form):
        def mutated(g, h):
            glb = getattr(form, "__globals__", None)
            if glb is None or "minimum" not in glb:
                return form(g, h)
            saved = glb["minimum"]
            glb["minimum"] = first_arg_wins
            try:
                return form(g, h)
            finally:
                glb["minimum"] = saved
        mutated.__name__ = f"{getattr(form, '__name__', 'form')}__degen_min"
        return mutated

    return _wrap_movements(spec, wrap, "degenerate-minimum")


_MUTATORS: tuple[tuple[str, str, Callable], ...] = (
    ("drop-sigma", "evaluate with sigma=1.0 (word width dropped)",
     _drop_sigma),
    ("swap-NT", "transpose tile dimensions N<->T at the call boundary",
     _swap_nt),
    ("degenerate-minimum", "capacity min(...) returns its first argument",
     _degenerate_minimum),
)


def mutate_spec(spec: DataflowSpec) -> list[Mutant]:
    """All applicable mutants of ``spec`` (non-applicable ones skipped)."""
    out = []
    for name, desc, fn in _MUTATORS:
        mutated = fn(spec)
        if mutated is not None:
            out.append(Mutant(name=name, description=desc, spec=mutated))
    return out


def _compare(baseline: SpecAudit, mutated: SpecAudit) -> tuple[str, ...]:
    """Engines that flag the mutant relative to its baseline audit."""
    caught_by = []
    base_unit = {m.movement: len(m.errors) for m in baseline.movements}
    for m in mutated.movements:
        if len(m.errors) > base_unit.get(m.movement, 0):
            caught_by.append("unit-checker")
            break
    base_fp = {m.movement: m.fingerprint for m in baseline.movements}
    for m in mutated.movements:
        if m.fingerprint != base_fp.get(m.movement):
            caught_by.append("provenance/value-pins")
            break
    if baseline.golden_ok and not mutated.golden_ok:
        caught_by.append("golden-totals")
    return tuple(caught_by)


def run_mutation_battery(specs=None, *, envelope=None
                         ) -> list[MutationOutcome]:
    """Audit every applicable mutant of every spec; report catch status.

    ``specs`` defaults to all registered dataflows.  A healthy auditor
    catches 100% of generated mutants (asserted in CI via ``--strict``).
    """
    if specs is None:
        specs = [registry.get(n) for n in registry.names()]
    outcomes: list[MutationOutcome] = []
    for spec in specs:
        baseline = audit_spec(spec, envelope=envelope)
        for mutant in mutate_spec(spec):
            # The mutant's golden lookup must resolve to the parent's pins:
            # audit against the parent name by restoring it post-replace.
            audited = audit_spec(
                dataclasses.replace(mutant.spec, name=spec.name),
                envelope=envelope, use_cache=False)
            caught_by = _compare(baseline, audited)
            outcomes.append(MutationOutcome(
                spec=spec.name, mutant=mutant.name,
                caught=bool(caught_by), caught_by=caught_by))
    return outcomes
