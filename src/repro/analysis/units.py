"""Unit algebra for the model auditor (DESIGN.md §16).

The paper's movement models follow an *iteration-granular* convention
(Table II): ``B`` is the number of bits one iteration can move, so
``bits`` and ``bits/iter`` quantities are directly comparable inside the
capacity operator ``min(K*sigma, M*sigma, B)`` — both reduce to the single
``bits`` dimension.  Counts (``elements``, ``vertices``, ``edges``,
``PEs``) are dimensionless multipliers under this convention.  The payoff
is a one-dimensional algebra with teeth:

* a valid ``data_bits`` closed form must reduce to ``bits^1``,
* a valid ``iterations`` closed form must reduce to ``bits^0``,
* ``min`` / ``max`` / ``+`` / ``-`` / ``where`` require equal exponents,
* ``ceil`` / ``floor`` require a dimensionless operand (they are applied
  to occupancy *ratios*), and
* dropping a ``sigma`` factor, or multiplying two bits-carrying
  quantities, breaks the reduction and is a hard audit failure
  ("count x count products are not bits").

The *nominal* tag (``elements`` vs ``PEs`` vs ``vertices``) does not enter
the algebra — the paper freely multiplies vertex counts by per-vertex
element counts — but it is preserved on seeded symbols for the provenance
table.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["Unit", "BITS", "DIMENSIONLESS", "UNIT_TAGS", "unit_from_tag"]

#: The recognized Table II unit tags (see notation.FieldUnit).
#: ``relations`` is this repo's extension for the typed-graph relation
#: count R (DESIGN.md §17) — a count, hence dimensionless in the algebra.
UNIT_TAGS = ("bits", "bits/iter", "elements", "vertices", "edges", "PEs",
             "relations", "dimensionless")


@dataclass(frozen=True)
class Unit:
    """A unit as an integer exponent of the ``bits`` dimension."""

    bits_exp: int = 0

    def __mul__(self, other: "Unit") -> "Unit":
        return Unit(self.bits_exp + other.bits_exp)

    def __truediv__(self, other: "Unit") -> "Unit":
        return Unit(self.bits_exp - other.bits_exp)

    def __pow__(self, k: int) -> "Unit":
        return Unit(self.bits_exp * int(k))

    @property
    def is_dimensionless(self) -> bool:
        return self.bits_exp == 0

    @property
    def is_bits(self) -> bool:
        return self.bits_exp == 1

    def __str__(self) -> str:
        if self.bits_exp == 0:
            return "dimensionless"
        if self.bits_exp == 1:
            return "bits"
        return f"bits^{self.bits_exp}"


BITS = Unit(1)
DIMENSIONLESS = Unit(0)


def unit_from_tag(tag: str) -> Unit:
    """Map a declared Table II unit tag to its algebraic reduction."""
    if tag not in UNIT_TAGS:
        raise ValueError(f"unknown unit tag {tag!r}; expected one of "
                         f"{UNIT_TAGS}")
    return BITS if tag in ("bits", "bits/iter") else DIMENSIONLESS
