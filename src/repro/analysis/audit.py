"""The model auditor: one symbolic-trace pass per registered movement.

``audit_spec`` runs every ``MovementSpec.form`` of a dataflow under the
:mod:`repro.analysis.tracer` and derives three results per movement:

* **dimensional consistency** — the returned ``data_bits`` must reduce to
  ``bits^1`` and ``iterations`` to ``bits^0`` under the Table II unit
  declarations (:mod:`repro.core.notation`), with every intermediate
  ``min``/``+``/``where`` unit-matched and every ``ceil`` applied to a
  dimensionless ratio.  Violations are hard errors unless the movement
  carries an ``audit_note`` waiver (a verbatim-transcription decision
  recorded in the spec module and DESIGN.md §16).
* **symbol provenance** — the set of graph/hardware fields that reach the
  movement's outputs.  Aggregated across movements this yields the
  spec-level provenance table and the dead-hardware-parameter check: a
  declared hw field no movement reads is a strict error unless listed in
  ``DataflowSpec.unused_hw``.
* **float64-exactness** — interval bounds propagated from the declared
  operating envelope (10^9 edges / 10^7 vertices by default); any
  intermediate whose bound exceeds 2^53 is reported with its witness
  symbols.  These are findings, not strict failures — the envelope
  deliberately overshoots today's workloads to de-risk ROADMAP item 1.

A fourth, dynamic layer pins each movement's ``(data_bits, iterations)``
at the Sec. IV default operating point and the spec total against
``SEC4_GOLDEN_TOTALS`` where one exists; together with provenance this is
the fingerprint the mutation battery (:mod:`repro.analysis.mutations`)
uses to prove the auditor rejects wrong models.

Audits are cached by spec *value* (DataflowSpec is a frozen dataclass, so
a re-registered mutated spec — new form callables — never hits a stale
entry; see ``analysis_cache_info``/``clear_analysis_cache``).
"""

from __future__ import annotations

import dataclasses
import weakref
from dataclasses import dataclass
from typing import Mapping, Optional, Sequence

import numpy as np

from ..core.dataflow import DataflowSpec
from ..core.notation import paper_default_graph
from .tracer import (FLOAT64_EXACT_MAX, OverflowRecord, TraceAbort,
                     TraceContext, UnitIssue, traced_record, trace_form)

__all__ = [
    "MovementAudit",
    "SpecAudit",
    "audit_spec",
    "audit_registry",
    "audit_composition_forms",
    "analysis_cache_info",
    "clear_analysis_cache",
    "render_provenance",
    "DEFAULT_ENVELOPE",
    "COMPOSITION_AUDIT_POINT",
]

#: The ROADMAP item-1 operating envelope the overflow audit defaults to —
#: overriding the per-field declarations in :mod:`repro.core.notation` is
#: only needed to *tighten or widen* the audited scale (CLI --max-edges /
#: --max-vertices / --max-features).
DEFAULT_ENVELOPE: dict[str, tuple[float, float]] = {}


@dataclass(frozen=True)
class MovementAudit:
    """Everything one tracer pass proved about a single movement level."""

    movement: str
    role: str
    hierarchy: str
    bits_unit: str
    iters_unit: str
    symbols: tuple[str, ...]
    unit_issues: tuple[UnitIssue, ...]
    waived: bool
    audit_note: Optional[str]
    overflows: tuple[OverflowRecord, ...]
    minimum_calls: int
    trace_error: Optional[str]
    bits_bound: float
    iters_bound: float
    value_bits: float
    value_iters: float

    @property
    def graph_symbols(self) -> tuple[str, ...]:
        return tuple(s.split(".", 1)[1] for s in self.symbols
                     if s.startswith("graph."))

    @property
    def hw_symbols(self) -> tuple[str, ...]:
        return tuple(s.split(".", 1)[1] for s in self.symbols
                     if s.startswith("hw."))

    @property
    def errors(self) -> tuple[str, ...]:
        """Strict failures: unwaived unit issues and untraceable forms."""
        errs = []
        if self.trace_error:
            errs.append(self.trace_error)
        if not self.waived:
            errs.extend(str(i) for i in self.unit_issues)
        return tuple(errs)

    @property
    def fingerprint(self) -> tuple:
        """What the mutation battery compares: provenance + value pins."""
        return (self.movement, self.symbols, self.bits_unit,
                self.iters_unit, self.value_bits, self.value_iters)

    def as_dict(self) -> dict:
        return {
            "movement": self.movement,
            "role": self.role,
            "hierarchy": self.hierarchy,
            "bits_unit": self.bits_unit,
            "iters_unit": self.iters_unit,
            "graph_symbols": list(self.graph_symbols),
            "hw_symbols": list(self.hw_symbols),
            "unit_issues": [str(i) for i in self.unit_issues],
            "waived": self.waived,
            "audit_note": self.audit_note,
            "overflow_bound": max((o.bound for o in self.overflows),
                                  default=0.0),
            "overflow_ops": len(self.overflows),
            "trace_error": self.trace_error,
            "bits_bound": self.bits_bound,
            "value_bits": self.value_bits,
            "value_iterations": self.value_iters,
        }


@dataclass(frozen=True)
class SpecAudit:
    """The full audit of one dataflow spec."""

    name: str
    movements: tuple[MovementAudit, ...]
    dead_hw: tuple[str, ...]
    waived_dead_hw: tuple[str, ...]
    unused_graph: tuple[str, ...]
    golden_expected: Optional[float]
    golden_actual: Optional[float]
    envelope: tuple[tuple[str, tuple[float, float]], ...]

    @property
    def golden_ok(self) -> bool:
        if self.golden_expected is None:
            return True
        return self.golden_actual == self.golden_expected

    @property
    def unit_error_count(self) -> int:
        return sum(len(m.unit_issues) for m in self.movements
                   if not m.waived)

    @property
    def waived_issue_count(self) -> int:
        return sum(len(m.unit_issues) for m in self.movements if m.waived)

    @property
    def overflow_count(self) -> int:
        return sum(len(m.overflows) for m in self.movements)

    @property
    def symbols(self) -> frozenset:
        out = frozenset()
        for m in self.movements:
            out = out | frozenset(m.symbols)
        return out

    def strict_errors(self) -> tuple[str, ...]:
        """Everything ``--strict`` fails on (overflows are findings only)."""
        errs: list[str] = []
        for m in self.movements:
            errs.extend(f"{self.name}.{e}" if not e.startswith(self.name)
                        else e for e in m.errors)
        for p in self.dead_hw:
            errs.append(f"{self.name}: hardware parameter hw.{p} is never "
                        f"read by any movement (declare it in "
                        f"DataflowSpec.unused_hw with a justification, or "
                        f"fix the form that should read it)")
        if not self.golden_ok:
            errs.append(f"{self.name}: Sec. IV total {self.golden_actual!r} "
                        f"drifted from the pinned golden "
                        f"{self.golden_expected!r}")
        return tuple(errs)

    @property
    def ok(self) -> bool:
        return not self.strict_errors()

    @property
    def fingerprint(self) -> tuple:
        return tuple(m.fingerprint for m in self.movements)

    def as_dict(self) -> dict:
        return {
            "name": self.name,
            "ok": self.ok,
            "unit_errors": self.unit_error_count,
            "waived_unit_issues": self.waived_issue_count,
            "overflow_findings": self.overflow_count,
            "dead_hw": list(self.dead_hw),
            "waived_dead_hw": list(self.waived_dead_hw),
            "unused_graph": list(self.unused_graph),
            "golden_ok": self.golden_ok,
            "strict_errors": list(self.strict_errors()),
            "movements": [m.as_dict() for m in self.movements],
        }


# -- caching ----------------------------------------------------------------
_AUDIT_CACHE: "weakref.WeakKeyDictionary[DataflowSpec, dict]" = \
    weakref.WeakKeyDictionary()
_CACHE_STATS = {"hits": 0, "misses": 0}


def analysis_cache_info() -> dict:
    return {"entries": len(_AUDIT_CACHE), **_CACHE_STATS}


def clear_analysis_cache() -> None:
    _AUDIT_CACHE.clear()
    _COMPOSITION_CACHE.clear()
    _CACHE_STATS["hits"] = _CACHE_STATS["misses"] = 0


def _envelope_key(envelope: Optional[Mapping]) -> tuple:
    if not envelope:
        return ()
    return tuple(sorted((k, (float(lo), float(hi)))
                        for k, (lo, hi) in envelope.items()))


def _declared_movement_waiver(movement) -> Optional[str]:
    return getattr(movement, "audit_note", None)


def _spec_unused_hw(spec: DataflowSpec) -> tuple[str, ...]:
    return tuple(getattr(spec, "unused_hw", ()) or ())


def audit_spec(spec: DataflowSpec, *,
               envelope: Optional[Mapping[str, tuple]] = None,
               use_cache: bool = True) -> SpecAudit:
    """Audit one dataflow spec; results are cached by spec value.

    ``envelope`` overrides the declared graph-field bounds, e.g.
    ``{"P": (0, 1e10)}`` to audit a 10^10-edge push before attempting it.
    """
    key = _envelope_key(envelope)
    if use_cache:
        per_spec = _AUDIT_CACHE.get(spec)
        if per_spec is not None and key in per_spec:
            _CACHE_STATS["hits"] += 1
            return per_spec[key]
        _CACHE_STATS["misses"] += 1

    base_graph = paper_default_graph()
    base_hw = spec.hw_factory()

    # Dynamic value pins at the Sec. IV default operating point.
    values: dict[str, tuple[float, float]] = {}
    golden_actual = None
    try:
        out = spec.evaluate(base_graph)
        for t in out.terms:
            values[t.name] = (float(np.asarray(t.data_bits)),
                              float(np.asarray(t.iterations)))
        golden_actual = float(out.total_bits())
    except Exception as e:  # a spec too broken to evaluate still audits
        values = {}
        golden_actual = float("nan")
        eval_error = f"{spec.name}: evaluation at Sec. IV defaults raised " \
                     f"{type(e).__name__}: {e}"
    else:
        eval_error = None

    from ..core.validation import SEC4_GOLDEN_TOTALS
    golden_expected = (SEC4_GOLDEN_TOTALS[spec.name][0]
                       if spec.name in SEC4_GOLDEN_TOTALS else None)
    if golden_expected is None:
        golden_actual = None

    audits = []
    used_symbols: set[str] = set()
    traced_hw_fields: set[str] = set()
    for m in spec.movements:
        ctx = TraceContext(movement=f"{spec.name}.{m.name}")
        tg = traced_record(base_graph, "graph", ctx, overrides=envelope)
        th = traced_record(base_hw, "hw", ctx)
        traced_hw_fields.update(
            f.name for f in dataclasses.fields(base_hw)
            if getattr(base_hw, f.name) is not None)
        trace_error = None
        bits_unit = iters_unit = "untraced"
        symbols: tuple[str, ...] = ()
        bits_bound = iters_bound = float("nan")
        try:
            bits, iters = trace_form(m.form, tg, th, ctx,
                                     movement=f"{spec.name}.{m.name}")
        except TraceAbort as e:
            trace_error = str(e)
        except Exception as e:
            trace_error = (f"{spec.name}.{m.name}: tracer raised "
                           f"{type(e).__name__}: {e}")
        else:
            bits_unit, iters_unit = str(bits.unit), str(iters.unit)
            if not bits.unit.is_bits:
                ctx.issue("data_bits", f"reduces to {bits.unit}, expected "
                                       f"bits (a count x count product is "
                                       f"not data movement)")
            if not iters.unit.is_dimensionless:
                ctx.issue("iterations", f"reduces to {iters.unit}, "
                                        f"expected dimensionless")
            symbols = tuple(sorted(bits.symbols | iters.symbols))
            bits_bound, iters_bound = bits.hi, iters.hi
        note = _declared_movement_waiver(m)
        vb, vi = values.get(m.name, (float("nan"), float("nan")))
        audits.append(MovementAudit(
            movement=m.name, role=m.role, hierarchy=m.hierarchy,
            bits_unit=bits_unit, iters_unit=iters_unit, symbols=symbols,
            unit_issues=tuple(ctx.issues), waived=note is not None,
            audit_note=note, overflows=tuple(ctx.overflows),
            minimum_calls=ctx.minimum_calls,
            trace_error=trace_error if trace_error else eval_error,
            bits_bound=bits_bound, iters_bound=iters_bound,
            value_bits=vb, value_iters=vi))
        used_symbols.update(symbols)
        # Only the first movement needs to report the spec-wide eval error.
        eval_error = None

    used_hw = {s.split(".", 1)[1] for s in used_symbols
               if s.startswith("hw.")}
    used_graph = {s.split(".", 1)[1] for s in used_symbols
                  if s.startswith("graph.")}
    waivers = _spec_unused_hw(spec)
    dead = sorted(traced_hw_fields - used_hw)
    dead_hw = tuple(p for p in dead if p not in waivers)
    waived_dead = tuple(p for p in dead if p in waivers)
    graph_fields = {f.name for f in dataclasses.fields(base_graph)}
    unused_graph = tuple(sorted(graph_fields - used_graph))

    report = SpecAudit(
        name=spec.name, movements=tuple(audits), dead_hw=dead_hw,
        waived_dead_hw=waived_dead, unused_graph=unused_graph,
        golden_expected=golden_expected, golden_actual=golden_actual,
        envelope=key)
    if use_cache:
        _AUDIT_CACHE.setdefault(spec, {})[key] = report
    return report


def audit_registry(*, envelope: Optional[Mapping[str, tuple]] = None,
                   use_cache: bool = True) -> dict[str, SpecAudit]:
    """Audit every registered dataflow; keyed by registry name."""
    from ..core import registry

    return {name: audit_spec(registry.get(name), envelope=envelope,
                             use_cache=use_cache)
            for name in registry.names()}


# -- composition-layer forms (DESIGN.md §17) --------------------------------

#: (role, hierarchy) of each composition-layer term, matching what the
#: array-path evaluations in :mod:`repro.core.compose` charge.
_COMPOSITION_TERM_INFO = {
    "relationalhalo": ("vertex_in", "L2-L1"),
    "relationalhandoff": ("interphase", "L1-L1"),
    "minibatchgather": ("vertex_in", "L2-L1"),
}

#: The §17 operating point the composition value pins are taken at: a
#: 4-relation typed graph, 256-vertex tiles with 100 unique remote
#: sources, 32 halo feature elements per vertex.
COMPOSITION_AUDIT_POINT = {"R": 4, "H": 100.0, "K": 256.0, "W": 32.0}

#: Forms whose provenance must carry the relation symbol ``graph.R`` —
#: a typed-graph form that drops its R multiplicity is wrong even if its
#: units still reduce (the §17 extension of the provenance contract).
_REQUIRES_R_SYMBOL = ("relationalhalo", "relationalhandoff")

_COMPOSITION_CACHE: dict[tuple, SpecAudit] = {}


def audit_composition_forms(*, envelope: Optional[Mapping[str, tuple]] = None,
                            use_cache: bool = True) -> SpecAudit:
    """Audit the composition-layer closed forms like a pseudo-dataflow.

    The relational / episode evaluations charge movement terms that no
    registered :class:`MovementSpec` owns (exact halo reload, resident
    hand-off, minibatch gather).  ``repro.core.compose.COMPOSITION_FORMS``
    restates them over the declared
    :class:`~repro.core.notation.RelationalScheduleParams` x
    :class:`~repro.core.notation.CompositionHardwareParams` records; this
    pass traces each exactly like a Table III/IV movement — units must
    reduce to ``bits^1`` / ``bits^0``, the 2^53 interval propagates the
    relation-count (R) multiplicity, and the relational forms must read
    the ``graph.R`` symbol (dropping the multiplicity is a strict error,
    not just a smaller number).  Returns a :class:`SpecAudit` named
    ``"composition"`` so the CLI report, ``--strict`` gate, and
    provenance table handle it uniformly.
    """
    key = _envelope_key(envelope)
    if use_cache and key in _COMPOSITION_CACHE:
        _CACHE_STATS["hits"] += 1
        return _COMPOSITION_CACHE[key]
    if use_cache:
        _CACHE_STATS["misses"] += 1

    from ..core.compose import COMPOSITION_FORMS
    from ..core.notation import (CompositionHardwareParams,
                                 RelationalScheduleParams)

    base_graph = RelationalScheduleParams(**COMPOSITION_AUDIT_POINT)
    base_hw = CompositionHardwareParams()
    audits = []
    used_symbols: set[str] = set()
    for name, form in COMPOSITION_FORMS:
        role, hierarchy = _COMPOSITION_TERM_INFO.get(name, ("other", "L2-L1"))
        ctx = TraceContext(movement=f"composition.{name}")
        tg = traced_record(base_graph, "graph", ctx, overrides=envelope)
        th = traced_record(base_hw, "hw", ctx)
        trace_error = None
        bits_unit = iters_unit = "untraced"
        symbols: tuple[str, ...] = ()
        bits_bound = iters_bound = float("nan")
        try:
            bits, iters = trace_form(form, tg, th, ctx,
                                     movement=f"composition.{name}")
        except TraceAbort as e:
            trace_error = str(e)
        except Exception as e:
            trace_error = (f"composition.{name}: tracer raised "
                           f"{type(e).__name__}: {e}")
        else:
            bits_unit, iters_unit = str(bits.unit), str(iters.unit)
            if not bits.unit.is_bits:
                ctx.issue("data_bits", f"reduces to {bits.unit}, expected "
                                       f"bits (a count x count product is "
                                       f"not data movement)")
            if not iters.unit.is_dimensionless:
                ctx.issue("iterations", f"reduces to {iters.unit}, "
                                        f"expected dimensionless")
            symbols = tuple(sorted(bits.symbols | iters.symbols))
            if name in _REQUIRES_R_SYMBOL and "graph.R" not in symbols:
                ctx.issue("provenance",
                          "relational form never reads graph.R — the "
                          "relation multiplicity has been dropped")
            bits_bound, iters_bound = bits.hi, iters.hi
        try:
            vb = float(np.asarray(form(base_graph, base_hw)[0]))
            vi = float(np.asarray(form(base_graph, base_hw)[1]))
        except Exception:
            vb = vi = float("nan")
        audits.append(MovementAudit(
            movement=name, role=role, hierarchy=hierarchy,
            bits_unit=bits_unit, iters_unit=iters_unit, symbols=symbols,
            unit_issues=tuple(ctx.issues), waived=False, audit_note=None,
            overflows=tuple(ctx.overflows),
            minimum_calls=ctx.minimum_calls, trace_error=trace_error,
            bits_bound=bits_bound, iters_bound=iters_bound,
            value_bits=vb, value_iters=vi))
        used_symbols.update(symbols)

    used_hw = {s.split(".", 1)[1] for s in used_symbols
               if s.startswith("hw.")}
    used_graph = {s.split(".", 1)[1] for s in used_symbols
                  if s.startswith("graph.")}
    hw_fields = {f.name for f in dataclasses.fields(base_hw)}
    graph_fields = {f.name for f in dataclasses.fields(base_graph)}
    report = SpecAudit(
        name="composition", movements=tuple(audits),
        dead_hw=tuple(sorted(hw_fields - used_hw)), waived_dead_hw=(),
        unused_graph=tuple(sorted(graph_fields - used_graph)),
        golden_expected=None, golden_actual=None, envelope=key)
    if use_cache:
        _COMPOSITION_CACHE[key] = report
    return report


# -- provenance rendering ---------------------------------------------------

def _units_cell(m: MovementAudit) -> str:
    if m.trace_error:
        return "UNTRACED"
    if m.unit_issues and m.waived:
        return f"waived ({len(m.unit_issues)})"
    if m.unit_issues:
        return f"ERROR ({len(m.unit_issues)})"
    return "ok"


def render_provenance(audits: Mapping[str, SpecAudit]) -> str:
    """The symbol-provenance table as deterministic markdown.

    This exact text is committed as the DESIGN.md §16 appendix; the CLI's
    ``--provenance --check`` drift gate re-renders and compares it.
    """
    lines = [
        "| dataflow | movement | role | hierarchy | graph symbols "
        "| hw symbols | units |",
        "|---|---|---|---|---|---|---|",
    ]
    for name in sorted(audits):
        a = audits[name]
        for m in a.movements:
            lines.append(
                f"| {a.name} | {m.movement} | {m.role} | {m.hierarchy} "
                f"| {', '.join(m.graph_symbols) or '—'} "
                f"| {', '.join(m.hw_symbols) or '—'} "
                f"| {_units_cell(m)} |")
    notes = []
    for name in sorted(audits):
        a = audits[name]
        bits = []
        if a.waived_dead_hw:
            bits.append("unused hw (waived): "
                        + ", ".join(a.waived_dead_hw))
        if a.dead_hw:
            bits.append("DEAD hw: " + ", ".join(a.dead_hw))
        if a.unused_graph:
            bits.append("graph symbols not read: "
                        + ", ".join(a.unused_graph))
        if a.waived_issue_count:
            waived = [m.movement for m in a.movements
                      if m.waived and m.unit_issues]
            bits.append(f"unit waivers in {', '.join(waived)}")
        if bits:
            notes.append(f"- **{a.name}**: " + "; ".join(bits))
    if notes:
        lines.append("")
        lines.extend(notes)
    return "\n".join(lines) + "\n"
