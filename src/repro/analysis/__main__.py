"""``python -m repro.analysis`` — the model-audit CLI.

Default run: audit every registered dataflow, lint the closed-form and
trace-path packages, and run the mutation battery; print a summary.

Flags::

    --strict            exit 1 on any strict audit error, lint violation,
                        or escaped mutant (the CI model-lint gate)
    --json PATH         write the machine-readable report (BENCH_analysis.json)
    --provenance        print the symbol-provenance markdown table
    --check             with --provenance: compare against the committed
                        DESIGN.md §16 appendix; exit 1 if stale
    --write             with --provenance: rewrite the DESIGN.md appendix
                        in place (between the BEGIN/END markers)
    --design PATH       DESIGN.md location (default: repo root)
    --no-mutations      skip the mutation battery (fast pre-commit loop)
    --max-edges F       override the P (edges) envelope upper bound
    --max-vertices F    override the K/L (vertices) envelope upper bound
    --max-features F    override the N/T (elements) envelope upper bound

Exit codes: 0 clean, 1 audit/lint/mutation/drift failure, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .audit import (audit_composition_forms, audit_registry,
                    render_provenance)
from .lint import lint_paths
from .mutations import run_mutation_battery

PROVENANCE_BEGIN = "<!-- BEGIN ANALYSIS PROVENANCE -->"
PROVENANCE_END = "<!-- END ANALYSIS PROVENANCE -->"


def _default_design_path() -> Path:
    return Path(__file__).resolve().parents[3] / "DESIGN.md"


def _build_envelope(args) -> dict:
    envelope: dict[str, tuple[float, float]] = {}
    if args.max_edges is not None:
        envelope["P"] = (0.0, float(args.max_edges))
    if args.max_vertices is not None:
        envelope["K"] = (1.0, float(args.max_vertices))
        envelope["L"] = (0.0, float(args.max_vertices))
    if args.max_features is not None:
        envelope["N"] = (1.0, float(args.max_features))
        envelope["T"] = (1.0, float(args.max_features))
    return envelope


def extract_committed_provenance(design_text: str) -> str | None:
    """The committed appendix between the BEGIN/END markers, or None."""
    try:
        _, rest = design_text.split(PROVENANCE_BEGIN, 1)
        body, _ = rest.split(PROVENANCE_END, 1)
    except ValueError:
        return None
    return body.strip("\n") + "\n"


def replace_committed_provenance(design_text: str, table: str) -> str:
    """Design text with the appendix body replaced (markers must exist)."""
    head, rest = design_text.split(PROVENANCE_BEGIN, 1)
    _, tail = rest.split(PROVENANCE_END, 1)
    return (head + PROVENANCE_BEGIN + "\n" + table.strip("\n") + "\n"
            + PROVENANCE_END + tail)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Symbolic units/provenance/overflow audit + AST lint "
                    "over every registered dataflow model.")
    parser.add_argument("--strict", action="store_true")
    parser.add_argument("--json", metavar="PATH", default=None)
    parser.add_argument("--provenance", action="store_true")
    parser.add_argument("--check", action="store_true")
    parser.add_argument("--write", action="store_true")
    parser.add_argument("--design", metavar="PATH", default=None)
    parser.add_argument("--no-mutations", action="store_true")
    parser.add_argument("--max-edges", type=float, default=None)
    parser.add_argument("--max-vertices", type=float, default=None)
    parser.add_argument("--max-features", type=float, default=None)
    args = parser.parse_args(argv)

    if (args.check or args.write) and not args.provenance:
        print("error: --check/--write require --provenance", file=sys.stderr)
        return 2
    if args.check and args.write:
        print("error: --check and --write are mutually exclusive",
              file=sys.stderr)
        return 2

    envelope = _build_envelope(args)
    audits = audit_registry(envelope=envelope or None)
    # The composition-layer closed forms (DESIGN.md §17) audit as a
    # pseudo-dataflow so strict gating and provenance cover them too.
    audits["composition"] = audit_composition_forms(envelope=envelope or None)
    table = render_provenance(audits)

    # --provenance: table-centric modes short-circuit the full report.
    if args.provenance:
        design_path = Path(args.design) if args.design \
            else _default_design_path()
        if args.check:
            committed = extract_committed_provenance(
                design_path.read_text()) if design_path.exists() else None
            if committed is None:
                print(f"provenance: no committed appendix found in "
                      f"{design_path} (markers missing)", file=sys.stderr)
                return 1
            if committed != table:
                print("provenance: committed DESIGN.md appendix is STALE — "
                      "regenerate with `python -m repro.analysis "
                      "--provenance --write`", file=sys.stderr)
                return 1
            print(f"provenance: DESIGN.md appendix is current "
                  f"({sum(len(a.movements) for a in audits.values())} "
                  f"movements)")
            return 0
        if args.write:
            text = design_path.read_text()
            if PROVENANCE_BEGIN not in text or PROVENANCE_END not in text:
                print(f"provenance: {design_path} lacks the "
                      f"{PROVENANCE_BEGIN} / {PROVENANCE_END} markers",
                      file=sys.stderr)
                return 1
            design_path.write_text(replace_committed_provenance(text, table))
            print(f"provenance: rewrote appendix in {design_path}")
            return 0
        print(table, end="")
        return 0

    violations = lint_paths()
    outcomes = [] if args.no_mutations else run_mutation_battery(
        envelope=envelope or None)

    strict_errors: list[str] = []
    for name in sorted(audits):
        strict_errors.extend(audits[name].strict_errors())
    escaped = [o for o in outcomes if not o.caught]

    report = {
        "schema": "repro.analysis/v1",
        "strict": bool(args.strict),
        "envelope": {k: list(v) for k, v in envelope.items()},
        "dataflows": {name: audits[name].as_dict()
                      for name in sorted(audits)},
        "lint": {
            "roots": ["src/repro/core", "src/repro/distributed"],
            "violations": [v.as_dict() for v in violations],
        },
        "mutation_battery": {
            "ran": not args.no_mutations,
            "total": len(outcomes),
            "caught": sum(o.caught for o in outcomes),
            "outcomes": [o.as_dict() for o in outcomes],
        },
        "ok": not (strict_errors or violations or escaped),
    }
    if args.json:
        Path(args.json).write_text(json.dumps(report, indent=2,
                                              sort_keys=True) + "\n")

    for name in sorted(audits):
        a = audits[name]
        status = "ok" if a.ok else "FAIL"
        print(f"{name:14s} {status:4s} movements={len(a.movements)} "
              f"unit_errors={a.unit_error_count} "
              f"waived={a.waived_issue_count} "
              f"overflow_findings={a.overflow_count} "
              f"dead_hw={','.join(a.dead_hw) or '-'}")
    for err in strict_errors:
        print(f"  strict: {err}", file=sys.stderr)
    if violations:
        print(f"lint: {len(violations)} violation(s)")
        for v in violations:
            print(f"  {v}", file=sys.stderr)
    else:
        print("lint: clean")
    if outcomes:
        print(f"mutation battery: {sum(o.caught for o in outcomes)}"
              f"/{len(outcomes)} mutants caught")
        for o in escaped:
            print(f"  ESCAPED: {o.spec} :: {o.mutant}", file=sys.stderr)

    failed = bool(strict_errors or violations or escaped)
    if args.strict and failed:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
