"""Training driver: config -> data -> resilient loop -> checkpoints.

CPU-runnable with the smoke configs (this is what examples/ call); on a pod
the same driver runs the full configs with the production mesh by passing
``--full --mesh single|multi`` (the step functions are identical to the
dry-run's).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch gcn-cora --steps 300
"""

from __future__ import annotations

import argparse
import logging
import tempfile
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs import get_arch
from ..data import synthetic
from ..distributed.resilience import FaultInjector, StepMonitor, run_resilient
from ..models import dlrm as dlrm_lib
from ..models import transformer as tf_lib
from ..models.gnn import equiformer_v2 as eqv2_lib
from ..models.gnn import gatedgcn as ggcn_lib
from ..models.gnn import gcn as gcn_lib
from ..models.gnn import meshgraphnet as mgn_lib
from ..models.gnn.graph import GraphBatch
from ..optim.optimizers import adamw, apply_updates, cosine_schedule
from ..optim import compression

logger = logging.getLogger("repro.train")

_GNN_MODULES = {"gcn-cora": gcn_lib, "gatedgcn": ggcn_lib,
                "meshgraphnet": mgn_lib, "equiformer-v2": eqv2_lib}


def _lm_setup(arch, args):
    cfg = (arch.make_config() if args.full else arch.make_smoke_config())
    params = tf_lib.init_params(cfg, jax.random.key(args.seed))
    optimizer = adamw(cosine_schedule(args.lr, warmup=20, total=args.steps),
                      weight_decay=0.1)
    if args.compress_grads:
        optimizer = compression.wrap_optimizer(optimizer)
    opt_state = optimizer.init(params)
    train_step = jax.jit(tf_lib.make_train_step(cfg, optimizer))

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = train_step(params, opt_state, batch)
        return (params, opt_state), metrics

    def batch_fn(step):
        b = synthetic.lm_batch(args.seed, step, batch=args.batch,
                               seq=args.seq, vocab=cfg.vocab)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return (params, opt_state), step_fn, batch_fn


def _gnn_setup(arch, args):
    cfg = arch.make_smoke_config() if not args.full else arch.make_config()
    module = _GNN_MODULES[arch.name]
    params = module.init_params(cfg, jax.random.key(args.seed))
    optimizer = adamw(args.lr)
    opt_state = optimizer.init(params)

    # One fixed synthetic graph (full-batch training semantics).
    d_in = cfg.d_in
    n_classes = getattr(cfg, "n_classes", 3)
    ga = synthetic.power_law_graph(
        args.seed, n_nodes=args.gnn_nodes, n_edges=args.gnn_edges,
        d_feat=d_in, n_classes=n_classes,
        self_loops=arch.name != "equiformer-v2")
    kw = dict(node_feat=jnp.asarray(ga.node_feat),
              senders=jnp.asarray(ga.senders),
              receivers=jnp.asarray(ga.receivers))
    if arch.name == "gatedgcn":
        kw["edge_feat"] = jnp.ones((ga.n_edges, cfg.d_edge_in), jnp.float32)
        kw["labels"] = jnp.asarray(ga.labels)
    elif arch.name == "meshgraphnet":
        kw["edge_feat"] = jnp.ones((ga.n_edges, cfg.d_edge_in), jnp.float32)
        rng = np.random.default_rng(args.seed)
        kw["labels"] = jnp.asarray(
            rng.standard_normal((ga.n_nodes, cfg.d_out)), jnp.float32)
    elif arch.name == "equiformer-v2":
        from ..data.wigner import rotation_to_z, wigner_stack
        rng = np.random.default_rng(args.seed)
        pos = rng.standard_normal((ga.n_nodes, 3))
        vecs = pos[ga.senders] - pos[ga.receivers]
        Rs = np.stack([rotation_to_z(v) for v in vecs])
        wig = wigner_stack(Rs, cfg.l_max, m_max=cfg.m_max)
        kw["wigner"] = {l: jnp.asarray(w) for l, w in wig.items()}
        kw["positions"] = jnp.asarray(pos, jnp.float32)
        kw["labels"] = jnp.asarray(rng.standard_normal((1, cfg.d_out)), jnp.float32)
    else:
        kw["labels"] = jnp.asarray(ga.labels)
    g = GraphBatch(**kw)

    loss_fn = partial(module.loss_fn, cfg)

    @jax.jit
    def train_step(params, opt_state, g):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, g), has_aux=True)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, metrics

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = train_step(params, opt_state, batch)
        return (params, opt_state), metrics

    return (params, opt_state), step_fn, lambda step: g


def _dlrm_setup(arch, args):
    cfg = arch.make_smoke_config() if not args.full else arch.make_config()
    params = dlrm_lib.init_params(cfg, jax.random.key(args.seed))
    optimizer = adamw(args.lr)
    opt_state = optimizer.init(params)

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: dlrm_lib.loss_fn(cfg, p, batch), has_aux=True)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, metrics

    def step_fn(state, batch):
        params, opt_state = state
        params, opt_state, metrics = train_step(params, opt_state, batch)
        return (params, opt_state), metrics

    def batch_fn(step):
        b = synthetic.criteo_batch(args.seed, step, batch=args.batch,
                                   n_dense=cfg.n_dense,
                                   vocab_sizes=cfg.vocab_sizes,
                                   multi_hot=cfg.multi_hot)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return (params, opt_state), step_fn, batch_fn


def run(args) -> list[dict]:
    arch = get_arch(args.arch)
    setup = {"lm": _lm_setup, "gnn": _gnn_setup, "recsys": _dlrm_setup}[arch.family]
    state, step_fn, batch_fn = setup(arch, args)
    ckpt = CheckpointManager(args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_"),
                             keep=3)
    injector = FaultInjector(frozenset(args.fail_at or []))
    state, history = run_resilient(
        state=state, step_fn=step_fn, batch_fn=batch_fn, n_steps=args.steps,
        checkpoint_manager=ckpt, checkpoint_every=args.checkpoint_every,
        injector=injector, monitor=StepMonitor())
    if history:
        first, last = history[0], history[-1]
        logger.info("loss: %.4f -> %.4f over %d steps",
                    first.get("loss", float("nan")),
                    last.get("loss", float("nan")), len(history))
    return history


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (pod-scale)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=None,
                    help="inject worker failures at these steps")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--gnn-nodes", type=int, default=256)
    ap.add_argument("--gnn-edges", type=int, default=1024)
    return ap


def main() -> None:
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    run(build_parser().parse_args())


if __name__ == "__main__":
    main()
