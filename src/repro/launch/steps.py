"""Cell builder: (arch x shape x mesh) -> a lowerable step.

For every grid cell this module assembles
  * the step function (train_step / prefill / serve_step / retrieval),
  * abstract arguments (ShapeDtypeStructs — nothing is allocated),
  * in/out shardings,
  * MODEL_FLOPS for the roofline's useful-FLOPs ratio.

Conventions (DESIGN.md §6):
  LM      batch over dp axes, TP/EP/SP over ``model``.
  GNN     node/edge arrays sharded over ALL mesh axes (flattened); graph
          sizes padded to multiples of 512 so both meshes divide evenly.
  DLRM    batch over dp for the embedding stage (tables vocab-parallel over
          ``model``), re-sharded over all axes for the dense stage.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Mapping, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import get_arch
from ..configs.base import ArchDef, ShapeSpec
from ..distributed.sharding import ShardingPolicy, make_policy
from ..models import dlrm as dlrm_lib
from ..models import transformer as tf_lib
from ..models.gnn import equiformer_v2 as eqv2_lib
from ..models.gnn import gatedgcn as ggcn_lib
from ..models.gnn import gcn as gcn_lib
from ..models.gnn import meshgraphnet as mgn_lib
from ..models.gnn.graph import GraphBatch
from ..optim.optimizers import adamw

Array = jax.Array

PAD_TO = 512  # graph dims padded to multiples of this (divides both meshes)

# Node-classification label cardinality per GNN shape (Cora / Reddit / OGBN-
# products; molecule is graph-level).
GNN_N_CLASSES = {"full_graph_sm": 7, "minibatch_lg": 41, "ogb_products": 47,
                 "molecule": 10}


def _pad(n: int, to: int = PAD_TO) -> int:
    return ((n + to - 1) // to) * to


def sampled_subgraph_sizes(batch_nodes: int, fanout: tuple[int, ...]) -> tuple[int, int]:
    """Padded (nodes, edges) of a fanout-sampled k-hop subgraph."""
    nodes, edges, frontier = batch_nodes, 0, batch_nodes
    for f in fanout:
        edges += frontier * f
        frontier *= f
        nodes += frontier
    return _pad(nodes), _pad(edges)


@dataclass
class CellPlan:
    arch: str
    shape: str
    kind: str
    step_fn: Callable
    args: tuple                       # ShapeDtypeStructs pytree
    in_shardings: Any
    out_shardings: Any
    model_flops: float
    donate_argnums: tuple = ()        # train: (params, opt); decode: (cache,)
    meta: dict = field(default_factory=dict)

    def lower(self, mesh: Mesh):
        # All shardings are NamedShardings carrying the mesh; no context
        # manager is required.
        del mesh
        jitted = jax.jit(self.step_fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        return jitted.lower(*self.args)

    def bf16_arg_bytes(self) -> int:
        """PER-DEVICE bf16 input bytes — bounds the CPU-backend f32-convert
        artifact (XLA CPU converts bf16 dot operands to f32 and hoists the
        converts; it also materializes f32 copies of bf16 optimizer moments.
        TPU MXUs consume bf16 natively and fuse the moment math, so these
        temps vanish on target).  Audited against buffer-assignment dumps;
        see EXPERIMENTS.md §Dry-run."""
        total = 0
        leaves = jax.tree_util.tree_leaves(self.args)
        sh_leaves = jax.tree_util.tree_flatten(
            self.in_shardings, is_leaf=lambda x: hasattr(x, "spec"))[0]
        for leaf, sh in zip(leaves, sh_leaves):
            if getattr(leaf, "dtype", None) == jnp.bfloat16:
                shape = (sh.shard_shape(leaf.shape)
                         if hasattr(sh, "shard_shape") else leaf.shape)
                n = 1
                for d in shape:
                    n *= d
                total += n * 2
        return total


def _named(policy: ShardingPolicy, tree, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(policy.mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _abstract_opt_state(optimizer, params_abs):
    return jax.eval_shape(optimizer.init, params_abs)


def _opt_state_specs(param_specs):
    from ..optim.optimizers import AdamWState
    return AdamWState(step=P(), mu=param_specs, nu=param_specs)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_plan(arch: ArchDef, shape: ShapeSpec, policy: ShardingPolicy) -> CellPlan:
    from ..distributed.sharding import fsdp_specs

    cfg: tf_lib.TransformerConfig = arch.make_config()
    B, S = shape.params["batch"], shape.params["seq"]
    dp = policy.dp_spec
    n_active = cfg.active_param_count()

    # Storage-precision policy (dry-run memory iteration, EXPERIMENTS.md):
    #  - training params/opt state f32 unless the f32 triple exceeds ~60% of
    #    the pod's HBM (arctic-480b) -> bf16 params + bf16 moments;
    #  - serving params always bf16.
    n_params = cfg.param_count()
    f32_train_bytes = 12.0 * n_params / policy.n_devices
    big = f32_train_bytes > 9e9
    train_dtype = jnp.bfloat16 if big else jnp.float32

    if shape.kind == "train":
        params_abs = tf_lib.abstract_params(cfg, dtype=train_dtype)
        # FSDP/ZeRO-3: shard every large leaf over the dp axes too.
        param_specs = fsdp_specs(params_abs, tf_lib.param_pspecs(cfg, policy),
                                 policy)
        optimizer = adamw(3e-4, weight_decay=0.1,
                          state_dtype=jnp.bfloat16 if big else jnp.float32)
        opt_abs = _abstract_opt_state(optimizer, params_abs)
        step = tf_lib.make_train_step(cfg, optimizer, policy=policy)
        batch_abs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        batch_specs = {"tokens": P(dp, None), "labels": P(dp, None)}
        in_sh = (_named(policy, params_abs, param_specs),
                 _named(policy, opt_abs, _opt_state_specs(param_specs)),
                 _named(policy, batch_abs, batch_specs))
        out_sh = (in_sh[0], in_sh[1],
                  {"loss": NamedSharding(policy.mesh, P()),
                   "ce": NamedSharding(policy.mesh, P()),
                   "aux": NamedSharding(policy.mesh, P())})
        flops = 6.0 * n_active * B * S
        return CellPlan(arch.name, shape.name, "train", step,
                        (params_abs, opt_abs, batch_abs), in_sh, out_sh, flops,
                        donate_argnums=(0, 1),
                        meta={"loop_scale": cfg.n_groups})

    # Serving: bf16 params; FSDP-shard them over dp too when a TP-only
    # shard would exceed half the HBM (arctic: 58 GB/chip otherwise).
    params_abs = tf_lib.abstract_params(cfg, dtype=jnp.bfloat16)
    param_specs = tf_lib.param_pspecs(cfg, policy)
    if 2.0 * n_params / policy.tp > 8e9:
        param_specs = fsdp_specs(params_abs, param_specs, policy)

    if shape.kind == "prefill":
        step = tf_lib.make_prefill_step(cfg, policy=policy)
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        in_sh = (_named(policy, params_abs, param_specs),
                 NamedSharding(policy.mesh, P(dp, None)))
        flops = 2.0 * n_active * B * S
        return CellPlan(arch.name, shape.name, "prefill", step,
                        (params_abs, tokens), in_sh, None, flops,
                        meta={"loop_scale": cfg.n_groups})

    # decode
    long_ctx = S >= 2 ** 19
    decode = tf_lib.DecodePolicy(
        cache_seq_axes=("data", "model") if long_ctx else ("model",),
        batch_axes=() if B < policy.dp else tuple(policy.dp_axes))
    step = tf_lib.make_serve_step(cfg, S, policy=policy, decode=decode)
    cache_abs = tf_lib.abstract_cache(cfg, B, S)
    cache_specs = tf_lib.cache_pspecs(cfg, policy, decode)
    bat = decode.batch_axes if len(decode.batch_axes) > 1 else (
        decode.batch_axes[0] if decode.batch_axes else None)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    in_sh = (_named(policy, params_abs, param_specs),
             _named(policy, cache_abs, cache_specs),
             NamedSharding(policy.mesh, P(bat, None)),
             NamedSharding(policy.mesh, P()))
    out_sh = (NamedSharding(policy.mesh, P(bat, None)),
              _named(policy, cache_abs, cache_specs))
    flops = 2.0 * n_active * B
    return CellPlan(arch.name, shape.name, "decode", step,
                    (params_abs, cache_abs, tokens, pos), in_sh, out_sh, flops,
                    donate_argnums=(1,),
                    meta={"cache_seq_axes": decode.cache_seq_axes,
                          "loop_scale": cfg.n_groups})


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _wigner_abstract(cfg: eqv2_lib.EquiformerV2Config, E: int) -> dict:
    """Pre-chunked when the conv is edge-tiled (the chunk dim must be a real
    input dim — in-model reshapes of sharded edge arrays force replication)."""
    out = {}
    chunks = max(getattr(cfg, "edge_chunks", 1), 1)
    for l in range(cfg.l_max + 1):
        shape = ((chunks, E // chunks, cfg.m_dim(l), 2 * l + 1)
                 if chunks > 1 else (E, cfg.m_dim(l), 2 * l + 1))
        out[l] = jax.ShapeDtypeStruct(shape, jnp.float32)
    return out


def _gnn_graph_abstract(arch: ArchDef, shape: ShapeSpec, cfg) -> tuple[GraphBatch, dict]:
    p = shape.params
    if shape.kind == "train_sampled":
        N, E = sampled_subgraph_sizes(p["batch_nodes"], tuple(p["fanout"]))
    else:
        N, E = _pad(p["n_nodes"] * p.get("batch", 1)), _pad(p["n_edges"] * p.get("batch", 1))
    if getattr(cfg, "edge_chunks", 1) > 1:
        # chunked edge arrays are (chunks, Ec, ...) with Ec sharded over the
        # dp axes: Ec must divide by 32 (multi-pod dp) -> pad E to 64*32.
        E = _pad(E, cfg.edge_chunks * 32)
    d_feat = p["d_feat"]
    molecule = shape.name == "molecule"
    n_graphs = p.get("batch", 1)

    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)

    kw: dict[str, Any] = dict(
        node_feat=f32(N, d_feat),
        senders=i32(E), receivers=i32(E),
        node_mask=f32(N), edge_mask=f32(E),
        n_graphs=n_graphs if molecule else 1,
    )
    if molecule:
        kw["graph_ids"] = i32(N)

    name = arch.name
    if name == "gcn-cora":
        kw["labels"] = i32(n_graphs) if molecule else i32(N)
    elif name == "gatedgcn":
        kw["edge_feat"] = f32(E, cfg.d_edge_in)
        kw["labels"] = i32(n_graphs) if molecule else i32(N)
    elif name == "meshgraphnet":
        kw["edge_feat"] = f32(E, cfg.d_edge_in)
        kw["labels"] = f32(N, cfg.d_out)
    elif name == "equiformer-v2":
        kw["wigner"] = _wigner_abstract(cfg, E)
        kw["labels"] = f32(n_graphs if molecule else 1, cfg.d_out)
        kw["positions"] = f32(N, 3)
    return GraphBatch(**kw), {"N": N, "E": E}


def _gnn_graph_specs(arch: ArchDef, g: GraphBatch, policy: ShardingPolicy,
                     shape: ShapeSpec) -> GraphBatch:
    # 2-D partitioning for the wide models (meshgraphnet d=128, equiformer
    # C=128): nodes/edges over the dp axes, hidden channels over `model`
    # (applied inside the models via policy constraints).  The narrow models
    # (gcn d=16, gatedgcn d=70) shard nodes/edges over ALL axes instead.
    if arch.name in ("meshgraphnet", "equiformer-v2"):
        axes = policy.dp_spec
    else:
        axes = tuple(policy.dp_axes) + (policy.tp_axis,)
    node = P(axes)
    kw: dict[str, Any] = dict(
        node_feat=P(axes, None), senders=node, receivers=node,
        node_mask=node, edge_mask=node, n_graphs=g.n_graphs)
    if g.graph_ids is not None:
        kw["graph_ids"] = node
    if g.edge_feat is not None:
        kw["edge_feat"] = P(axes, None)
    if g.wigner is not None:
        kw["wigner"] = {
            l: (P(None, axes, None, None) if w.ndim == 4
                else P(axes, None, None))
            for l, w in g.wigner.items()}
    if g.positions is not None:
        kw["positions"] = P(axes, None)
    lbl = g.labels
    if lbl.shape[0] == g.node_feat.shape[0]:
        kw["labels"] = P(axes) if lbl.ndim == 1 else P(axes, None)
    else:
        kw["labels"] = P() if lbl.ndim == 1 else P(*([None] * lbl.ndim))
    return GraphBatch(**kw)


_GNN_MODULES = {"gcn-cora": gcn_lib, "gatedgcn": ggcn_lib,
                "meshgraphnet": mgn_lib, "equiformer-v2": eqv2_lib}


def _gnn_flops(arch: ArchDef, cfg, N: int, E: int) -> float:
    """Documented forward-FLOPs estimates; train = 3x forward."""
    if arch.name == "gcn-cora":
        dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
        fwd = sum(2.0 * N * a * b + 2.0 * E * b
                  for a, b in zip(dims[:-1], dims[1:]))
    elif arch.name == "gatedgcn":
        d = cfg.d_hidden
        fwd = cfg.n_layers * (2.0 * N * 5 * d * d + 2.0 * E * 5 * d)
        fwd += 2.0 * N * cfg.d_in * d + 2.0 * E * cfg.d_edge_in * d
    elif arch.name == "meshgraphnet":
        d = cfg.d_hidden
        per = 2.0 * E * (3 * d * d + d * d) + 2.0 * N * (2 * d * d + d * d)
        fwd = cfg.n_layers * per + 2.0 * N * (cfg.d_in * d + d * d) \
            + 2.0 * E * (cfg.d_edge_in * d + d * d)
    else:  # equiformer-v2
        C = cfg.d_hidden
        rot = sum(cfg.m_dim(l) * (2 * l + 1) for l in range(cfg.l_max + 1)) * C
        n0 = (cfg.l_max + 1) * C
        so2 = n0 ** 2 + 2 * sum((len(cfg.ls_for_m(m)) * C) ** 2
                                for m in range(1, cfg.m_max + 1))
        fwd = cfg.n_layers * (2.0 * E * (2 * rot + so2) + 2.0 * N * 4 * C * C)
    return 3.0 * fwd


def _gnn_plan(arch: ArchDef, shape: ShapeSpec, policy: ShardingPolicy) -> CellPlan:
    p = dict(shape.params)
    mk: dict[str, Any] = {"d_in": p["d_feat"]}
    if arch.name in ("gcn-cora", "gatedgcn"):
        mk["n_classes"] = GNN_N_CLASSES[shape.name]
        if shape.name == "molecule":
            mk["readout"] = "graphs"
    if arch.name == "equiformer-v2":
        # Edge tiling for the eSCN conv (the paper's P-per-tile parameter):
        # 64 chunks bound the per-device message tensor on the 61M-edge
        # shapes; small graphs stay single-tile.
        n_e = (sampled_subgraph_sizes(p["batch_nodes"], tuple(p["fanout"]))[1]
               if shape.kind == "train_sampled"
               else _pad(p["n_edges"] * p.get("batch", 1)))
        if n_e >= 1_000_000:
            mk["edge_chunks"] = 64
    cfg = arch.make_config(**mk)
    module = _GNN_MODULES[arch.name]

    g_abs, sizes = _gnn_graph_abstract(arch, shape, cfg)
    g_specs = _gnn_graph_specs(arch, g_abs, policy, shape)
    params_abs = jax.eval_shape(lambda k: module.init_params(cfg, k),
                                jax.random.key(0))
    param_specs = jax.tree_util.tree_map(lambda _: P(), params_abs)
    optimizer = adamw(1e-3)
    opt_abs = _abstract_opt_state(optimizer, params_abs)
    opt_specs = _opt_state_specs(param_specs)

    def train_step(params, opt_state, g):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: module.loss_fn(cfg, q, g, policy=policy),
            has_aux=True)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        from ..optim.optimizers import apply_updates
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    in_sh = (_named(policy, params_abs, param_specs),
             _named(policy, opt_abs, opt_specs),
             _named(policy, g_abs, g_specs))
    flops = _gnn_flops(arch, cfg, sizes["N"], sizes["E"])
    # Loop-body accounting: gcn's 2 layers are a Python loop (fully counted);
    # the scanned models count one layer body; equiformer additionally scans
    # edge chunks inside the body.
    if arch.name == "gcn-cora":
        scale = 1
    elif arch.name == "equiformer-v2":
        scale = cfg.n_layers  # edge-chunk inner scan undercount documented
    else:
        scale = cfg.n_layers
    sizes["loop_scale"] = scale
    return CellPlan(arch.name, shape.name, "train", train_step,
                    (params_abs, opt_abs, g_abs), in_sh, None, flops,
                    donate_argnums=(0, 1), meta=sizes)


# ---------------------------------------------------------------------------
# DLRM cells
# ---------------------------------------------------------------------------

def _dlrm_flops(cfg: dlrm_lib.DLRMConfig, B: int, *, train: bool) -> float:
    bot = sum(2.0 * a * b for a, b in zip((cfg.n_dense,) + cfg.bot_mlp[:-1],
                                          cfg.bot_mlp))
    top_dims = (cfg.interaction_dim(),) + cfg.top_mlp
    top = sum(2.0 * a * b for a, b in zip(top_dims[:-1], top_dims[1:]))
    f = cfg.n_sparse + 1
    inter = 2.0 * f * f * cfg.embed_dim
    fwd = B * (bot + top + inter)
    return 3.0 * fwd if train else fwd


def _dlrm_plan(arch: ArchDef, shape: ShapeSpec, policy: ShardingPolicy) -> CellPlan:
    cfg: dlrm_lib.DLRMConfig = arch.make_config()
    B = shape.params["batch"]
    dp = policy.dp_spec
    params_abs = dlrm_lib.abstract_params(cfg)
    param_specs = dlrm_lib.param_pspecs(cfg, policy)

    if shape.kind == "retrieval":
        Nc = _pad(shape.params["n_candidates"])
        axes = tuple(policy.dp_axes) + (policy.tp_axis,)

        def retrieve(params, query, candidates):
            scores = dlrm_lib.score_candidates(cfg, params, query, candidates)
            return jax.lax.top_k(scores, 128)

        args = (params_abs,
                {"dense": jax.ShapeDtypeStruct((1, cfg.n_dense), jnp.float32)},
                jax.ShapeDtypeStruct((Nc, cfg.embed_dim), jnp.float32))
        in_sh = (_named(policy, params_abs, param_specs),
                 {"dense": NamedSharding(policy.mesh, P(None, None))},
                 NamedSharding(policy.mesh, P(axes, None)))
        return CellPlan(arch.name, shape.name, "retrieval", retrieve, args,
                        in_sh, None, 2.0 * Nc * cfg.embed_dim,
                        meta={"n_candidates": Nc})

    batch_abs = {
        "dense": jax.ShapeDtypeStruct((B, cfg.n_dense), jnp.float32),
        "sparse": jax.ShapeDtypeStruct((B, cfg.n_sparse, cfg.multi_hot), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B,), jnp.int32),
    }
    batch_specs = {"dense": P(dp, None), "sparse": P(dp, None, None),
                   "labels": P(dp)}

    if shape.kind == "train":
        optimizer = adamw(1e-3)
        opt_abs = _abstract_opt_state(optimizer, params_abs)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda q: dlrm_lib.loss_fn(cfg, q, batch, policy=policy),
                has_aux=True)(params)
            updates, opt_state = optimizer.update(grads, opt_state, params)
            from ..optim.optimizers import apply_updates
            params = apply_updates(params, updates)
            return params, opt_state, metrics

        in_sh = (_named(policy, params_abs, param_specs),
                 _named(policy, opt_abs, _opt_state_specs(param_specs)),
                 _named(policy, batch_abs, batch_specs))
        return CellPlan(arch.name, shape.name, "train", train_step,
                        (params_abs, opt_abs, batch_abs), in_sh, None,
                        _dlrm_flops(cfg, B, train=True), donate_argnums=(0, 1))

    def serve(params, batch):
        return dlrm_lib.forward(cfg, params, batch, policy=policy)

    in_sh = (_named(policy, params_abs, param_specs),
             _named(policy, batch_abs, batch_specs))
    return CellPlan(arch.name, shape.name, "serve", serve,
                    (params_abs, batch_abs), in_sh, None,
                    _dlrm_flops(cfg, B, train=False))


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def build_cell(arch_name: str, shape_name: str, mesh: Mesh,
               **policy_kw) -> CellPlan:
    arch = get_arch(arch_name)
    if shape_name in arch.skips:
        raise ValueError(f"cell ({arch_name}, {shape_name}) is skipped: "
                         f"{arch.skips[shape_name]}")
    shape = arch.shapes[shape_name]
    policy = make_policy(mesh, **policy_kw)
    if arch.family == "lm":
        return _lm_plan(arch, shape, policy)
    if arch.family == "gnn":
        return _gnn_plan(arch, shape, policy)
    if arch.family == "recsys":
        return _dlrm_plan(arch, shape, policy)
    raise ValueError(arch.family)
