import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax import): jax locks the
device count at first initialization, and the production meshes need 512
placeholder host devices.  Smoke tests / benchmarks never import this
module, so they see the single real CPU device.

For each cell we record to results/dryrun/<mesh>/<arch>__<shape>.json:
  * memory_analysis (bytes per device: args/outputs/temps) — proves fit,
  * cost_analysis (HLO FLOPs, bytes accessed) — feeds §Roofline,
  * the collective schedule parsed from optimized HLO (wire bytes per chip
    by kind) — the paper-methodology traffic ground truth.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--force]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import all_archs, get_arch
from ..core.hlo_analysis import parse_collectives
from .mesh import make_production_mesh
from .steps import build_cell

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch_name: str, shape_name: str, mesh_name: str,
             *, force: bool = False, policy_kw: dict | None = None,
             tag: str = "") -> dict:
    out_dir = RESULTS_DIR / mesh_name
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch_name}__{shape_name}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    t0 = time.time()
    record: dict = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
                    "chips": mesh.size}
    try:
        plan = build_cell(arch_name, shape_name, mesh, **(policy_kw or {}))
        record["kind"] = plan.kind
        record["model_flops"] = plan.model_flops
        record["meta"] = {k: str(v) for k, v in plan.meta.items()}
        lowered = plan.lower(mesh)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        stats = parse_collectives(compiled.as_text())

        record.update({
            "ok": True,
            "lower_s": round(t_lower - t0, 2),
            "compile_s": round(t_compile - t_lower, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
                # Upper bound on the CPU-lowering artifact: XLA CPU converts
                # bf16 dot operands to f32 (and hoists the converts out of
                # the layer loop); the TPU MXU consumes bf16 natively, so on
                # target these temps do not exist.  Audited against
                # buffer-assignment dumps (EXPERIMENTS.md §Dry-run).
                "bf16_arg_bytes": plan.bf16_arg_bytes(),
            },
            "cost": {
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            },
            "collectives": stats.summary(),
        })
    except Exception as exc:  # noqa: BLE001 — a failing cell is a bug report
        record.update({"ok": False, "error": f"{type(exc).__name__}: {exc}",
                       "traceback": traceback.format_exc()[-4000:]})
    out_path.write_text(json.dumps(record, indent=2, default=str))
    return record


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    archs = [get_arch(args.arch)] if args.arch else all_archs()

    failures = 0
    for mesh_name in meshes:
        for arch in archs:
            shapes = [args.shape] if args.shape else list(arch.shapes)
            for shape in shapes:
                if shape not in arch.shapes:
                    continue  # CLI filter names a shape of another family
                if shape in arch.skips:
                    print(f"[{mesh_name}] {arch.name} x {shape}: SKIP "
                          f"({arch.skips[shape]})")
                    continue
                rec = run_cell(arch.name, shape, mesh_name, force=args.force)
                if rec.get("ok"):
                    c = rec["cost"]
                    col = rec["collectives"]
                    print(f"[{mesh_name}] {arch.name} x {shape}: OK "
                          f"flops/chip={c['flops']:.3e} "
                          f"hbm={c['bytes_accessed']:.3e} "
                          f"coll={col['wire_bytes_per_chip']:.3e} "
                          f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
                else:
                    failures += 1
                    print(f"[{mesh_name}] {arch.name} x {shape}: FAIL "
                          f"{rec['error']}")
    print(f"dry-run complete; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
