"""Production meshes.

``make_production_mesh`` is a FUNCTION (never module-level state) so that
importing this module touches no jax device machinery.  The dry-run forces
512 host devices via XLA_FLAGS before any jax import; smoke tests and
benchmarks see the real single CPU device.

Mesh geometry (TPU v5e, per the brief):
  single-pod:  (data=16, model=16)            = 256 chips
  multi-pod:   (pod=2, data=16, model=16)     = 512 chips
The ``model`` axis carries TP/EP/SP; ``data`` (x ``pod``) carries DP.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # pinned jax 0.4.x: meshes are implicitly Auto
    AxisType = None

__all__ = ["make_production_mesh", "make_test_mesh"]


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")) -> Mesh:
    """Small mesh for the 8-device subprocess tests."""
    return _make_mesh(shape, axes)
