"""Ring SpMM — EnGN's ring-edge-reduce (RER) dataflow at pod scale.

EnGN aggregates by passing partial results around a physical ring of PEs
(the paper's ``aggregate`` term, M*(M-1)*T moved per pass but all of it on
the fast L1 fabric).  The TPU analogue: node-feature shards circulate the
ICI ring via ``lax.ppermute``; at every hop each chip aggregates the edges
whose sources live in the resident shard into its local destination
accumulator.  Total wire volume equals one all-gather of the feature
matrix, but (a) no chip ever materializes the full matrix (EnGN's lesson:
keep the big movement on the near fabric / in working memory), and (b)
every hop overlaps with the local gather+segment-sum, which XLA pipelines
as async collective-permute.

Two execution paths share one semantics (tests assert equality with the
plain segment_sum oracle):
  * :func:`allgather_spmm` — the paper-faithful baseline: gather ALL vertex
    features (EnGN ``loadvertL2`` with no degree cache), then aggregate.
  * :func:`ring_spmm` — the RER adaptation, hop-overlapped.

Host-side :func:`partition_edges_*` build the static padded layouts (the
paper's tiling/partitioning preprocessing stage, Fig. 1).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..compat import shard_map
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


# ---------------------------------------------------------------------------
# Host-side graph partitioning (pipeline preprocessing)
# ---------------------------------------------------------------------------

@dataclass
class RingEdgePartition:
    """Edges grouped by (dst shard, src block), padded to a static E_blk.

    Arrays are GLOBAL with leading dim n_shards (the dst shard); shard_map
    shards them on that axis.  ``senders`` are indices *within* the src
    block, ``receivers`` indices within the dst shard.  Padding entries have
    weight 0 (and index 0).
    """

    senders: np.ndarray     # (n_shards, n_shards, E_blk) int32
    receivers: np.ndarray   # (n_shards, n_shards, E_blk) int32
    weights: np.ndarray     # (n_shards, n_shards, E_blk) float32
    n_local: int            # nodes per shard
    pad_ratio: float        # padded / real edges (HyGCN's P_s analogue)


def partition_edges_ring(senders: np.ndarray, receivers: np.ndarray,
                         weights: np.ndarray, n_nodes: int,
                         n_shards: int) -> RingEdgePartition:
    assert n_nodes % n_shards == 0, (n_nodes, n_shards)
    n_local = n_nodes // n_shards
    dst_shard = receivers // n_local
    src_block = senders // n_local
    counts = np.zeros((n_shards, n_shards), np.int64)
    np.add.at(counts, (dst_shard, src_block), 1)
    e_blk = max(int(counts.max()), 1)

    snd = np.zeros((n_shards, n_shards, e_blk), np.int32)
    rcv = np.zeros((n_shards, n_shards, e_blk), np.int32)
    wgt = np.zeros((n_shards, n_shards, e_blk), np.float32)
    fill = np.zeros((n_shards, n_shards), np.int64)
    for e in range(senders.shape[0]):
        d, s = dst_shard[e], src_block[e]
        k = fill[d, s]
        snd[d, s, k] = senders[e] - s * n_local
        rcv[d, s, k] = receivers[e] - d * n_local
        wgt[d, s, k] = weights[e]
        fill[d, s] = k + 1
    pad_ratio = (n_shards * n_shards * e_blk) / max(senders.shape[0], 1)
    return RingEdgePartition(snd, rcv, wgt, n_local, pad_ratio)


@dataclass
class GatherEdgePartition:
    """Edges grouped by dst shard only (baseline layout)."""

    senders: np.ndarray     # (n_shards, E_loc) int32, GLOBAL src index
    receivers: np.ndarray   # (n_shards, E_loc) int32, local dst index
    weights: np.ndarray     # (n_shards, E_loc) float32
    n_local: int
    pad_ratio: float


def partition_edges_gather(senders: np.ndarray, receivers: np.ndarray,
                           weights: np.ndarray, n_nodes: int,
                           n_shards: int) -> GatherEdgePartition:
    assert n_nodes % n_shards == 0
    n_local = n_nodes // n_shards
    dst_shard = receivers // n_local
    counts = np.bincount(dst_shard, minlength=n_shards)
    e_loc = max(int(counts.max()), 1)
    snd = np.zeros((n_shards, e_loc), np.int32)
    rcv = np.zeros((n_shards, e_loc), np.int32)
    wgt = np.zeros((n_shards, e_loc), np.float32)
    fill = np.zeros(n_shards, np.int64)
    for e in range(senders.shape[0]):
        d = dst_shard[e]
        k = fill[d]
        snd[d, k] = senders[e]
        rcv[d, k] = receivers[e] - d * n_local
        wgt[d, k] = weights[e]
        fill[d] = k + 1
    pad_ratio = (n_shards * e_loc) / max(senders.shape[0], 1)
    return GatherEdgePartition(snd, rcv, wgt, n_local, pad_ratio)


# ---------------------------------------------------------------------------
# Device-side aggregation
# ---------------------------------------------------------------------------

def _flat_rank(axis_names: tuple[str, ...], mesh: Mesh) -> Array:
    r = jnp.zeros((), jnp.int32)
    for a in axis_names:
        r = r * mesh.shape[a] + jax.lax.axis_index(a)
    return r


def allgather_spmm(h: Array, part_senders: Array, part_receivers: Array,
                   part_weights: Array, *, mesh: Mesh,
                   axis_names: Optional[tuple[str, ...]] = None) -> Array:
    """Baseline 1D SpMM: all-gather features, local gather + segment-sum.

    h: (N, F) sharded on dim 0 over ``axis_names``; edge arrays sharded on
    their leading (dst shard) dim.  Returns (N, F) sharded like h.
    """
    axis_names = axis_names or mesh.axis_names
    ax = axis_names if len(axis_names) > 1 else axis_names[0]

    def local(h_loc, snd, rcv, wgt):
        n_local = h_loc.shape[0]
        h_full = jax.lax.all_gather(h_loc, axis_names, axis=0, tiled=True)
        msgs = h_full[snd[0]] * wgt[0][:, None]
        return jax.ops.segment_sum(msgs, rcv[0], num_segments=n_local)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(ax, None), P(ax, None), P(ax, None), P(ax, None)),
        out_specs=P(ax, None),
        check_vma=False,
    )(h, part_senders, part_receivers, part_weights)


def ring_spmm(h: Array, part_senders: Array, part_receivers: Array,
              part_weights: Array, *, mesh: Mesh,
              axis_names: Optional[tuple[str, ...]] = None) -> Array:
    """RER ring SpMM: feature shards circulate; each hop aggregates the
    resident src block's edges into the local dst accumulator.

    h: (N, F) sharded on dim 0; edge arrays (N_shards, n_blocks, E_blk)
    sharded on dim 0 (dst), indexed by src block on dim 1.
    """
    axis_names = axis_names or mesh.axis_names
    ax = axis_names if len(axis_names) > 1 else axis_names[0]
    n_shards = 1
    for a in axis_names:
        n_shards *= mesh.shape[a]
    # ppermute along the flattened ring: shard i -> shard i+1.  With multiple
    # axes we ring over each axis in sequence via a single flat permutation
    # on the *last* axis plus a carry hop on the outer axes; for simplicity
    # and because XLA maps it to ICI neighbours anyway, we express the flat
    # ring on one axis when single-axis, else nested ppermutes.
    def local(h_loc, snd, rcv, wgt):
        n_local = h_loc.shape[0]
        f = h_loc.shape[1]
        me = _flat_rank(axis_names, mesh)

        def hop(t, carry):
            block, acc = carry
            src_block = (me - t) % n_shards
            s = jax.lax.dynamic_index_in_dim(snd[0], src_block, 0, keepdims=False)
            r = jax.lax.dynamic_index_in_dim(rcv[0], src_block, 0, keepdims=False)
            w = jax.lax.dynamic_index_in_dim(wgt[0], src_block, 0, keepdims=False)
            msgs = block[s] * w[:, None]
            acc = acc + jax.ops.segment_sum(msgs, r, num_segments=n_local)
            # pass the resident block to the next rank (ring hop)
            block = _ring_permute(block, axis_names, mesh)
            return block, acc

        acc0 = jnp.zeros((n_local, f), h_loc.dtype)
        _, acc = jax.lax.fori_loop(0, n_shards, hop, (h_loc, acc0))
        return acc

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(ax, None), P(ax, None, None), P(ax, None, None),
                  P(ax, None, None)),
        out_specs=P(ax, None),
        check_vma=False,
    )(h, part_senders, part_receivers, part_weights)


def _ring_permute(x: Array, axis_names: tuple[str, ...], mesh: Mesh) -> Array:
    """One hop of the flat ring over (possibly nested) mesh axes: flat rank
    r receives from r-1 (mod n)."""
    if len(axis_names) == 1:
        a = axis_names[0]
        n = mesh.shape[a]
        perm = [(i, (i + 1) % n) for i in range(n)]
        return jax.lax.ppermute(x, a, perm)
    # Nested ring: inner axis hops every step; when the inner axis wraps the
    # block must ALSO hop on the outer axis.  We implement the flat ring as
    # a single ppermute over the innermost axis plus a conditional outer hop
    # — equivalently, permute on the flattened index.  jax.lax.ppermute
    # accepts multi-axis via axis tuple with flat index pairs.
    sizes = [mesh.shape[a] for a in axis_names]
    n = int(np.prod(sizes))
    perm = [(i, (i + 1) % n) for i in range(n)]
    return jax.lax.ppermute(x, axis_names, perm)
