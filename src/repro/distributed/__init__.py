"""Distribution substrate: meshes, sharding policies, collectives, pipeline
parallelism, resilience."""

from .sharding import ShardingPolicy, make_policy

__all__ = ["ShardingPolicy", "make_policy"]
