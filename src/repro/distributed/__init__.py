"""Distribution substrate: meshes, sharding policies, collectives, pipeline
parallelism, resilience.

``ShardingPolicy`` / ``make_policy`` are re-exported lazily: importing
them pulls in jax, while :mod:`repro.distributed.trace_shard` (the
sharded trace pipeline, DESIGN.md §14) is pure numpy and must stay
importable — and fast to import — without touching jax.
"""

__all__ = ["ShardingPolicy", "make_policy", "trace_shard"]


def __getattr__(name: str):
    # importlib.import_module, not `from . import x`: the latter probes
    # this very __getattr__ via hasattr before importing -> recursion.
    import importlib

    if name in ("ShardingPolicy", "make_policy"):
        return getattr(importlib.import_module(".sharding", __name__), name)
    if name == "trace_shard":
        return importlib.import_module(".trace_shard", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
