"""Sharded streaming trace pipeline: the 10⁸–10⁹-edge exact-trace path.

PR 5 made the exact-trace backend *amortized* — one sorted-edge
factorization shared by every tile capacity — but every stage of that
pipeline (generation, the composite-key sort, CSR-ification) was a
single-host, single-array NumPy pass, capping it near 10⁷ edges.  This
module shards all three stages (DESIGN.md §14) while keeping the result
**bit-identical** to the single-host path:

1. **Device-parallel generation.**  The streaming generator
   (:func:`repro.data.synthetic.power_law_edge_stream`) draws edges in
   fixed blocks, each from its own ``(seed, block_index)`` rng, so
   shard ``s`` of ``S`` independently generates the blocks
   ``block_index % S == s`` — no coordination, no full edge list on any
   host, and the union over shards is exactly the single-shard stream.

2. **Sharded sort / factorization (sample sort).**  Each shard folds
   its edges into composite ``sender * V + receiver`` keys and sorts
   them in place.  Deterministic splitters — regular samples of every
   sorted shard, merged, then cut at regular quantiles — define
   ``S`` half-open key ranges; each shard's sorted run is split against
   the splitters by ``searchsorted`` (a binary search, not a scan) and
   the per-range pieces are exchanged (the all-to-all of the simulated
   mesh).  Because the ranges are disjoint and cover the key space,
   *all* copies of any key land in exactly one bucket, so per-bucket
   merge + boundary-flag dedup produces, in bucket order, the globally
   sorted unique ``(sender, receiver)`` factorization — the identical
   object :meth:`GraphTrace._pair_factorization` computes, consumed
   unchanged by PR 5's O(U) per-capacity pass.

3. **Sharded CSR + halo counting.**  From the factorization the CSR
   row pointer is an O(U) weighted bincount
   (:meth:`GraphTrace.from_factorization`) — the E-sized receiver-major
   sort never happens at all.  Per-capacity tile/halo counts split the
   factorization at *new-sender boundaries* (every deduplicated
   ``(dst_tile, source)`` run lives wholly inside one sender segment),
   run the boundary-flag pass per chunk, and sum the partial integer
   bincounts — bit-identical to the single-host pass by construction
   (:func:`sharded_schedule_counts`, ``engine="sharded"``).

Shards execute as a thread pool (NumPy's sort/searchsorted release the
GIL) sized by :func:`default_shard_count` — ``REPRO_TRACE_SHARDS`` if
set, else the host's CPU count.  The shard count is an execution
detail, never identity: the drift gate (tests +
``benchmarks/trace_scale.py``) pins every shard count to the same
factorization, schedules, and halo counts as the single-host oracle.
"""

from __future__ import annotations

import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Sequence

import numpy as np

from repro.data import synthetic

__all__ = [
    "default_shard_count",
    "sharded_power_law_factorization",
    "build_power_law_trace",
    "sharded_schedule_counts",
    "typed_sharded_schedule_counts",
    "factorization_drift",
]

#: Largest vertex count whose composite ``sender * V + receiver`` keys fit
#: int64 (the same bound the single-host factorization uses before falling
#: back to lexsort).
MAX_KEY_NODES = int((2**63 - 1) ** 0.5)

#: Regular samples taken per shard per splitter when choosing bucket
#: boundaries.  Oversampling keeps bucket sizes within a small factor of
#: E/S even on skewed (power-law) key distributions.
_SPLITTER_OVERSAMPLE = 64


def default_shard_count() -> int:
    """Shard count: ``REPRO_TRACE_SHARDS`` env, else the CPU count.

    When jax is already loaded (e.g. under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``) the local
    device count wins over the CPU count, so the simulated-mesh CI job
    exercises one shard per simulated device without extra plumbing.
    """
    raw = os.environ.get("REPRO_TRACE_SHARDS", "").strip()
    if raw:
        try:
            n = int(raw)
        except ValueError as exc:
            raise ValueError(
                f"REPRO_TRACE_SHARDS must be a positive integer, "
                f"got {raw!r}") from exc
        if n < 1:
            raise ValueError(
                f"REPRO_TRACE_SHARDS must be a positive integer, got {n}")
        return n
    import sys
    jax = sys.modules.get("jax")
    if jax is not None:
        try:
            return max(1, int(jax.local_device_count()))
        except Exception:
            pass
    return max(1, os.cpu_count() or 1)


def _peak_rss_kb() -> int:
    """Peak resident set size of this process in KiB (-1 if unavailable)."""
    try:
        import resource
        return int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)
    except Exception:
        return -1


def _map_shards(fn, items: Sequence, n_workers: int) -> list:
    """Run ``fn`` over ``items`` on a thread pool (serial when 1 worker)."""
    if n_workers <= 1 or len(items) <= 1:
        return [fn(it) for it in items]
    with ThreadPoolExecutor(max_workers=min(n_workers, len(items))) as ex:
        return list(ex.map(fn, items))


# ---------------------------------------------------------------------------
# Stage 1+2a: per-shard generation and local sort
# ---------------------------------------------------------------------------

def _sorted_shard_keys(seed: int, n_nodes: int, n_edges: int, alpha: float,
                       shard: int, n_shards: int) -> np.ndarray:
    """Shard ``shard``'s edges as a sorted int64 composite-key array.

    Streams the shard's generation blocks, folds each chunk straight
    into ``sender * V + receiver`` keys (the snd/rcv chunk arrays are
    transient — peak memory is one key array plus one block), then
    sorts in place.
    """
    B = synthetic.POWER_LAW_STREAM_CHUNK
    n_blocks = synthetic.power_law_stream_blocks(n_edges)
    owned = sum(min(B, n_edges - b * B)
                for b in range(shard, n_blocks, n_shards))
    keys = np.empty(owned, dtype=np.int64)
    at = 0
    for snd, rcv in synthetic.power_law_edge_stream(
            seed, n_nodes=n_nodes, n_edges=n_edges, alpha=alpha,
            shard=shard, n_shards=n_shards):
        k = np.multiply(snd, n_nodes, dtype=np.int64)
        k += rcv
        keys[at:at + k.size] = k
        at += k.size
    keys.sort()
    return keys


# ---------------------------------------------------------------------------
# Stage 2b: splitters, exchange, per-bucket factorization
# ---------------------------------------------------------------------------

def _sample_splitters(sorted_shards: Sequence[np.ndarray],
                      n_buckets: int) -> np.ndarray:
    """Deterministic bucket boundaries from regular per-shard samples.

    Returns ``<= n_buckets - 1`` strictly increasing keys; bucket ``b``
    owns the half-open key range ``[split[b-1], split[b])`` (with
    ``-inf`` / ``+inf`` at the ends).  Boundaries are a pure function of
    the shard contents, so every shard computes the same split without
    communication beyond the (tiny) sample exchange.
    """
    samples = []
    for ks in sorted_shards:
        if not ks.size:
            continue
        take = min(ks.size, n_buckets * _SPLITTER_OVERSAMPLE)
        idx = (np.arange(take, dtype=np.int64) * ks.size) // take
        samples.append(ks[idx])
    if not samples or n_buckets <= 1:
        return np.empty(0, dtype=np.int64)
    s = np.sort(np.concatenate(samples))
    cut = (np.arange(1, n_buckets, dtype=np.int64) * s.size) // n_buckets
    # Duplicate sample values would only create empty buckets; unique
    # keeps the boundary list strictly increasing.
    return np.unique(s[cut])


def _bucket_pieces(keys: np.ndarray, split: np.ndarray) -> list[np.ndarray]:
    """Split one shard's sorted keys into per-bucket contiguous views.

    ``side="left"`` sends keys equal to a boundary to the bucket on its
    right — the half-open ``[split[b-1], split[b])`` convention every
    shard shares, which is what guarantees all copies of a key meet in
    one bucket.
    """
    cuts = np.searchsorted(keys, split, side="left")
    bounds = np.concatenate(
        [np.zeros(1, np.int64), cuts, np.full(1, keys.size, np.int64)])
    return [keys[bounds[i]:bounds[i + 1]] for i in range(bounds.size - 1)]


def _factorize_bucket(pieces: Sequence[np.ndarray]
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Merge one bucket's per-shard pieces into (unique keys, counts)."""
    pieces = [p for p in pieces if p.size]
    if not pieces:
        z = np.empty(0, dtype=np.int64)
        return z, z
    if len(pieces) == 1:
        merged = pieces[0]  # a sorted view: read-only here, no copy needed
    else:
        merged = np.concatenate(pieces)
        merged.sort()  # fresh array: in-place is safe
    change = np.empty(merged.size, dtype=bool)
    change[0] = True
    np.not_equal(merged[1:], merged[:-1], out=change[1:])
    idx = np.flatnonzero(change)
    u_key = merged[idx]
    counts = np.empty(idx.size, dtype=np.int64)
    counts[:-1] = np.diff(idx)
    counts[-1] = merged.size - idx[-1]
    return u_key, counts


def sharded_power_law_factorization(*, n_nodes: int, n_edges: int,
                                    seed: int = 0, alpha: float = 1.6,
                                    n_shards: Optional[int] = None,
                                    stats: Optional[dict] = None,
                                    ) -> tuple[np.ndarray, np.ndarray,
                                               np.ndarray]:
    """Sharded build of the sender-major unique-pair factorization.

    Returns ``(u_snd, u_rcv, mult_prefix)`` — bit-identical (values,
    order, dtypes) to what :meth:`GraphTrace._pair_factorization`
    derives from the materialized ``power_law_stream`` edge list with
    the same parameters, for **every** shard count (the drift-gate
    contract).  ``stats``, when a dict, receives per-stage wall times,
    per-shard edge counts, and peak-RSS snapshots.
    """
    n_nodes = int(n_nodes)
    n_edges = int(n_edges)
    if n_nodes > MAX_KEY_NODES:
        raise NotImplementedError(
            f"sharded factorization needs composite int64 keys "
            f"(n_nodes <= {MAX_KEY_NODES}); got n_nodes={n_nodes}. "
            f"Use the single-host lexsort path.")
    if n_shards is None:
        n_shards = default_shard_count()
    n_shards = max(1, int(n_shards))
    # Generation shards own whole blocks, so more shards than blocks
    # would just idle — but the exchange still buckets into ``n_shards``
    # key ranges (one per device), so small graphs exercise the full
    # all-to-all of an 8-device mesh too.
    n_gen = max(1, min(n_shards, synthetic.power_law_stream_blocks(n_edges)))
    n_workers = min(n_shards, os.cpu_count() or 1)

    t0 = time.perf_counter()
    sorted_shards = _map_shards(
        lambda s: _sorted_shard_keys(seed, n_nodes, n_edges, alpha,
                                     s, n_gen),
        range(n_gen), n_workers)
    t1 = time.perf_counter()
    rss_gen = _peak_rss_kb()

    split = _sample_splitters(sorted_shards, n_shards)
    # The "all-to-all": shard s splits its run against the shared
    # boundaries; bucket b then owns piece b of every shard.
    pieces = _map_shards(lambda ks: _bucket_pieces(ks, split),
                         sorted_shards, n_workers)
    buckets = _map_shards(_factorize_bucket,
                          [[p[b] for p in pieces]
                           for b in range(split.size + 1)], n_workers)
    u_key = np.concatenate([b[0] for b in buckets])
    counts = np.concatenate([b[1] for b in buckets])
    dt = np.int32 if n_nodes <= np.iinfo(np.int32).max else np.int64
    u_snd = (u_key // n_nodes).astype(dt, copy=False)
    u_rcv = (u_key % n_nodes).astype(dt, copy=False)
    mult_prefix = np.zeros(counts.size + 1, dtype=np.int64)
    np.cumsum(counts, out=mult_prefix[1:])
    t2 = time.perf_counter()

    if stats is not None:
        stats.update({
            "n_shards": int(n_shards),
            "n_generation_shards": int(n_gen),
            "shard_edges": [int(ks.size) for ks in sorted_shards],
            "bucket_unique": [int(b[0].size) for b in buckets],
            "n_unique_pairs": int(counts.size),
            "t_generate_sort_s": t1 - t0,
            "t_exchange_factorize_s": t2 - t1,
            "rss_generate_sort_kb": rss_gen,
            "rss_exchange_factorize_kb": _peak_rss_kb(),
        })
    return u_snd, u_rcv, mult_prefix


def build_power_law_trace(*, n_nodes: int, n_edges: int, seed: int = 0,
                          alpha: float = 1.6,
                          n_shards: Optional[int] = None,
                          stats: Optional[dict] = None):
    """Sharded end-to-end build: factorization → edge-list-free trace.

    The returned :class:`~repro.core.trace.GraphTrace` carries the
    unique-pair factorization and an O(U)-recovered CSR row pointer but
    no materialized edge list — peak memory is the factorization plus
    one shard's keys, which is what lets ``power_law_sharded`` datasets
    reach 10⁸–10⁹ edges on one host.
    """
    from repro.core.trace import GraphTrace

    u_snd, u_rcv, mult_prefix = sharded_power_law_factorization(
        n_nodes=n_nodes, n_edges=n_edges, seed=seed, alpha=alpha,
        n_shards=n_shards, stats=stats)
    t0 = time.perf_counter()
    trace = GraphTrace.from_factorization(
        int(n_nodes), u_snd, u_rcv, mult_prefix)
    if stats is not None:
        stats["t_csr_s"] = time.perf_counter() - t0
        stats["rss_csr_kb"] = _peak_rss_kb()
    return trace


# ---------------------------------------------------------------------------
# Stage 3: sharded per-capacity schedule counts (engine="sharded")
# ---------------------------------------------------------------------------

def _segment_chunk_bounds(u_new_src: np.ndarray, n_parts: int) -> np.ndarray:
    """Chunk boundaries over the factorization, aligned to new-sender
    boundaries so no deduplicated ``(dst_tile, source)`` run crosses a
    chunk edge (runs end where the sender changes)."""
    U = int(u_new_src.size)
    if n_parts <= 1 or U == 0:
        return np.array([0, U], dtype=np.int64)
    targets = (np.arange(1, n_parts, dtype=np.int64) * U) // n_parts
    ns_idx = np.flatnonzero(u_new_src)
    pos = np.minimum(np.searchsorted(ns_idx, targets, side="left"),
                     ns_idx.size - 1)
    return np.unique(np.concatenate(
        [np.zeros(1, np.int64), ns_idx[pos], np.full(1, U, np.int64)]))


def sharded_schedule_counts(fact: tuple, K: int, n_tiles: int,
                            n_shards: Optional[int] = None,
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Per-tile (halo, remote-edge) counts via sharded boundary-flag passes.

    ``fact`` is ``(u_snd, u_rcv, u_new_src, mult_prefix)`` from
    :meth:`GraphTrace._pair_factorization`.  The factorization is split
    at new-sender boundaries (:func:`_segment_chunk_bounds`), each chunk
    runs the same O(U) pass as the single-host engine — every chunk
    start is a pair start in the global pass, so per-chunk
    ``boundary[0] = True`` is exact, not an approximation — and the
    partial per-tile bincounts are summed.  Integer counts throughout:
    the result is bit-identical to the single-host engine for any shard
    count.
    """
    u_snd, u_rcv, u_new_src, mp = fact
    U = int(u_snd.size)
    halo = np.zeros(n_tiles, dtype=np.int64)
    remote_edges = np.zeros(n_tiles, dtype=np.int64)
    if U == 0:
        return halo, remote_edges
    if n_shards is None:
        n_shards = default_shard_count()
    bounds = _segment_chunk_bounds(u_new_src, int(n_shards))
    Kd = u_rcv.dtype.type(K)

    def one_chunk(se: tuple[int, int]) -> tuple[np.ndarray, np.ndarray]:
        s, e = se
        tile_u = u_rcv[s:e] // Kd
        n = e - s
        boundary = np.empty(n, dtype=bool)
        boundary[0] = True
        np.logical_or(u_new_src[s + 1:e], tile_u[1:] != tile_u[:-1],
                      out=boundary[1:])
        pidx = np.flatnonzero(boundary)
        nxt = np.empty(pidx.size, dtype=np.int64)
        nxt[:-1] = pidx[1:]
        nxt[-1] = n
        pair_tile = tile_u[pidx].astype(np.int64, copy=False)
        pair_count = np.asarray(mp)[s + nxt] - np.asarray(mp)[s + pidx]
        remote = (u_snd[s + pidx] // Kd) != tile_u[pidx]
        h = np.bincount(pair_tile[remote], minlength=n_tiles)
        # weighted bincount returns float64; multiplicities are ints
        # < 2^53, so the partial (and its sum below) is exact
        r = np.bincount(pair_tile[remote], weights=pair_count[remote],
                        minlength=n_tiles)
        return h.astype(np.int64, copy=False), r.astype(np.int64)

    chunks = list(zip(bounds[:-1].tolist(), bounds[1:].tolist()))
    n_workers = min(len(chunks), os.cpu_count() or 1)
    for h, r in _map_shards(one_chunk, chunks, n_workers):
        halo += h
        remote_edges += r
    return halo, remote_edges


def typed_sharded_schedule_counts(typed_trace, K: int, n_tiles: int,
                                  n_shards: Optional[int] = None,
                                  ) -> tuple[np.ndarray, np.ndarray]:
    """Per-relation per-tile (halo, remote-edge) counts, sharded.

    The typed factorization (DESIGN.md §17) keeps every relation's
    unique-pair factorization as a contiguous slice of one shared sort,
    so the sharded boundary-flag pass applies per relation unchanged:
    relation ``r``'s slice is itself a sender-major factorization, and
    :func:`sharded_schedule_counts` runs on it exactly as on a
    homogeneous trace.  Returns ``(halo, remote_edges)`` as
    ``(n_relations, n_tiles)`` int64 arrays — row ``r`` bit-identical to
    the single-host counts of ``typed_trace.relation(r)`` for any shard
    count (the typed extension of the drift-gate contract).
    """
    R = int(typed_trace.n_relations)
    halo = np.zeros((R, n_tiles), dtype=np.int64)
    remote = np.zeros((R, n_tiles), dtype=np.int64)
    for r in range(R):
        fact = typed_trace.relation(r)._pair_factorization()
        halo[r], remote[r] = sharded_schedule_counts(
            fact, K, n_tiles, n_shards=n_shards)
    return halo, remote


# ---------------------------------------------------------------------------
# Drift gate helper
# ---------------------------------------------------------------------------

def factorization_drift(fact_a: Sequence, fact_b: Sequence,
                        names: Sequence[str] = ("u_snd", "u_rcv",
                                                "mult_prefix")) -> list[str]:
    """Bit-exact comparison of two factorizations; [] means zero drift.

    Checks values, order, *and* dtypes — the sharded path must be a
    drop-in for the single-host factorization, so a silent int64
    widening counts as drift too.
    """
    errs = []
    for name, a, b in zip(names, fact_a, fact_b):
        a = np.asarray(a)
        b = np.asarray(b)
        if a.dtype != b.dtype:
            errs.append(f"{name}: dtype {a.dtype} != {b.dtype}")
        if a.shape != b.shape:
            errs.append(f"{name}: shape {a.shape} != {b.shape}")
            continue
        if not np.array_equal(a, b):
            i = int(np.flatnonzero(a != b)[0])
            errs.append(f"{name}: first mismatch at index {i}: "
                        f"{a[i]} != {b[i]}")
    return errs
