"""Fault tolerance: step retry from checkpoint, straggler detection, and a
deterministic fault injector for tests.

On a real pod the failure signal comes from the runtime (missing heartbeat,
ICI timeout); in this container :class:`FaultInjector` raises
:class:`WorkerFailure` on a scheduled set of steps, and the loop's recovery
path is identical to production: restore the latest checkpoint (optionally
onto a DIFFERENT mesh — elastic restart, exercised by
tests/test_checkpoint.py) and resume from the data stream position derived
from the restored step (the pipeline is a pure function of (seed, step), so
no data is lost or duplicated).

Straggler mitigation: :class:`StepMonitor` keeps an EWMA of step wall time
and flags steps slower than ``threshold`` x the average.  The hook is
pluggable; the default action logs and (in production) would trigger
re-sharding away from the slow host — here it increments counters the tests
assert on.
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

logger = logging.getLogger("repro.resilience")

__all__ = ["WorkerFailure", "FaultInjector", "StepMonitor", "run_resilient"]


class WorkerFailure(RuntimeError):
    """Simulated loss of a worker (heartbeat timeout / hardware fault)."""


@dataclass
class FaultInjector:
    fail_at_steps: frozenset[int] = frozenset()
    _fired: set[int] = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise WorkerFailure(f"injected worker failure at step {step}")


@dataclass
class StepMonitor:
    threshold: float = 3.0
    ewma_alpha: float = 0.2
    ewma_s: Optional[float] = None
    stragglers: list[int] = field(default_factory=list)
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if self.ewma_s is not None and dt > self.threshold * self.ewma_s:
            is_straggler = True
            self.stragglers.append(step)
            logger.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                           step, dt, self.ewma_s)
            if self.on_straggler:
                self.on_straggler(step, dt, self.ewma_s)
        self.ewma_s = (dt if self.ewma_s is None
                       else (1 - self.ewma_alpha) * self.ewma_s
                       + self.ewma_alpha * dt)
        return is_straggler


def run_resilient(
    *,
    state,                               # initial (params, opt_state, ...)
    step_fn: Callable,                   # (state, batch) -> (state, metrics)
    batch_fn: Callable[[int], object],   # step -> batch (pure in step)
    n_steps: int,
    checkpoint_manager=None,
    checkpoint_every: int = 50,
    injector: Optional[FaultInjector] = None,
    monitor: Optional[StepMonitor] = None,
    max_restarts: int = 8,
    log_every: int = 10,
) -> tuple[object, list[dict]]:
    """Train loop with checkpoint/restart recovery.

    Returns (final state, metrics history).  Each recovery restores the
    latest checkpoint and replays the deterministic data stream from there.
    """
    monitor = monitor or StepMonitor()
    history: list[dict] = []
    step = 0
    restarts = 0
    if checkpoint_manager is not None and checkpoint_manager.latest_step() is not None:
        step, state = checkpoint_manager.restore(state)
        logger.info("resumed from checkpoint step %d", step)

    while step < n_steps:
        try:
            if injector is not None:
                injector.check(step)
            t0 = time.time()
            state, metrics = step_fn(state, batch_fn(step))
            dt = time.time() - t0
            monitor.observe(step, dt)
            rec = {"step": step, "dt": dt,
                   **{k: float(v) for k, v in metrics.items()}}
            history.append(rec)
            if log_every and step % log_every == 0:
                logger.info("step %d: %s", step,
                            {k: round(v, 4) for k, v in rec.items() if k != "step"})
            step += 1
            if checkpoint_manager is not None and step % checkpoint_every == 0:
                checkpoint_manager.save(step, state)
        except WorkerFailure as exc:
            restarts += 1
            logger.warning("%s — recovering (restart %d/%d)", exc, restarts,
                           max_restarts)
            if restarts > max_restarts:
                raise
            if checkpoint_manager is not None and checkpoint_manager.latest_step() is not None:
                step, state = checkpoint_manager.restore(state)
                logger.info("rolled back to step %d", step)
            else:
                logger.warning("no checkpoint yet; restarting from step 0 state")
                step = 0
    if checkpoint_manager is not None:
        checkpoint_manager.save(step, state)
    return state, history
