"""Sharding policy: the single place that knows the mesh axes.

A :class:`ShardingPolicy` binds a mesh and its role split — which axes carry
data parallelism and which carry model parallelism (TP/EP/SP all ride the
``model`` axis; the optional ``pod`` axis extends data parallelism across
pods).  Model code never hard-codes axis names; it asks the policy to
constrain intermediates and the launcher asks it for parameter/batch specs.

``policy=None`` everywhere means single-device execution (CPU tests).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

__all__ = ["ShardingPolicy", "make_policy"]


@dataclass(frozen=True)
class ShardingPolicy:
    """Axis roles over a mesh.

    dp_axes: axes that shard the batch (("pod", "data") or ("data",)).
    tp_axis: the model-parallel axis (TP heads/ffn, EP experts, SP sequence).
    """

    mesh: Mesh
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    # knobs the §Perf hillclimb flips:
    seq_parallel_residual: bool = True     # residual stream sharded over tp
    zero1: bool = False                    # shard optimizer state over dp

    # ---- sizes -----------------------------------------------------------
    @property
    def dp(self) -> int:
        out = 1
        for a in self.dp_axes:
            out *= self.mesh.shape[a]
        return out

    @property
    def tp(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def n_devices(self) -> int:
        return self.dp * self.tp

    # ---- spec helpers ----------------------------------------------------
    @property
    def dp_spec(self):
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x: Array, spec: P) -> Array:
        return jax.lax.with_sharding_constraint(x, self.sharding(spec))

    # Residual-stream activations (B, S, d).
    def act_spec(self) -> P:
        if self.seq_parallel_residual:
            return P(self.dp_spec, self.tp_axis, None)
        return P(self.dp_spec, None, None)

    def batch_spec(self) -> P:
        return P(self.dp_spec, None)


def make_policy(mesh: Mesh, **kw) -> ShardingPolicy:
    names = mesh.axis_names
    dp_axes = tuple(a for a in names if a in ("pod", "data")) or names[:1]
    tp_axis = "model" if "model" in names else names[-1]
    return ShardingPolicy(mesh=mesh, dp_axes=dp_axes, tp_axis=tp_axis, **kw)


def fsdp_specs(abstract_params, base_specs, policy: ShardingPolicy,
               *, min_bytes: int = 1 << 20):
    """ZeRO-3/FSDP: additionally shard every large parameter leaf over the
    dp axes (XLA all-gathers each layer's slice on use and reduce-scatters
    its grads — the standard fully-sharded schedule).

    For each leaf >= ``min_bytes`` the largest dimension not already
    sharded and divisible by dp picks up the dp axes.
    """
    dp = policy.dp
    dp_axes = policy.dp_spec

    def one(leaf, spec: P) -> P:
        nbytes = leaf.size * leaf.dtype.itemsize
        if nbytes < min_bytes or dp <= 1:
            return spec
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        best, best_dim = -1, -1
        for d, (size, cur) in enumerate(zip(leaf.shape, entries)):
            if cur is None and size % dp == 0 and size > best:
                best, best_dim = size, d
        if best_dim < 0:
            return spec
        entries[best_dim] = dp_axes
        return P(*entries)

    return jax.tree_util.tree_map(one, abstract_params, base_specs)
