"""GPipe-style pipeline parallelism over a dedicated ``pipe`` mesh axis.

For trillion-parameter configs (arctic-480b at fp32 optimizer states) a
third parallelism dimension becomes necessary; this module provides the
schedule as a composable primitive: stages hold contiguous layer groups,
microbatches stream through ``ppermute`` hops, outputs collect on the last
stage and broadcast.  The schedule below is plain GPipe (fill + drain
bubble of (S-1)/(M+S-1)); 1F1B re-ordering is an orthogonal optimization
recorded as future work in DESIGN.md.

Differentiable end-to-end: ppermute/fori_loop transpose cleanly, so
``jax.grad`` through :func:`gpipe_apply` yields pipeline-parallel BPTT.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from ..compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array

__all__ = ["gpipe_apply"]


def gpipe_apply(stage_fn: Callable, stage_params, x_micro: Array, *,
                mesh: Mesh, axis: str = "pipe") -> Array:
    """Run ``stage_fn`` S times (once per stage) over M microbatches.

    stage_params: pytree with leading dim S (sharded over ``axis``).
    x_micro: (M, micro_batch, ...) replicated input.
    Returns (M, micro_batch, ...) — final-stage outputs, replicated.
    """
    n_stages = mesh.shape[axis]

    def local(params_loc, xs):
        params_loc = jax.tree_util.tree_map(lambda a: a[0], params_loc)
        r = jax.lax.axis_index(axis)
        m = xs.shape[0]
        total = m + n_stages - 1
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def step(t, carry):
            buf_in, outs = carry
            mb_idx = jnp.clip(t, 0, m - 1)
            x_in = jnp.where(r == 0, xs[mb_idx], buf_in)
            active = (t - r >= 0) & (t - r < m)
            y = stage_fn(params_loc, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            store = (r == n_stages - 1) & (t >= n_stages - 1)
            outs = jnp.where(store, outs.at[out_idx].set(y), outs)
            buf_next = jax.lax.ppermute(y, axis, fwd_perm)
            return buf_next, outs

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        _, outs = jax.lax.fori_loop(0, total, step, (buf0, outs0))
        # Broadcast final-stage outputs to every rank (replicated out-spec).
        outs = jax.lax.psum(
            jnp.where(r == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x_micro)
