"""Data pipeline: synthetic generators, neighbor sampler, Wigner blocks."""
