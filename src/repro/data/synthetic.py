"""Synthetic data generators (host-side numpy, deterministic by (seed, step)).

Every generator is a pure function of (seed, step) so a restarted job
regenerates exactly the batch stream it was consuming — the data-pipeline
half of fault tolerance (checkpoint/manager.py handles the model half).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

__all__ = ["lm_batch", "power_law_graph", "criteo_batch", "molecule_batch",
           "GraphArrays"]


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def lm_batch(seed: int, step: int, *, batch: int, seq: int,
             vocab: int) -> dict[str, np.ndarray]:
    """Zipfian token stream (vocabulary rank-frequency like real text)."""
    r = _rng(seed, step)
    toks = r.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
    toks = np.minimum(toks - 1, vocab - 1).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class GraphArrays:
    senders: np.ndarray
    receivers: np.ndarray
    node_feat: np.ndarray
    labels: np.ndarray
    edge_weight: Optional[np.ndarray] = None

    @property
    def n_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def n_edges(self) -> int:
        return self.senders.shape[0]


def power_law_graph(seed: int, *, n_nodes: int, n_edges: int, d_feat: int,
                    n_classes: int = 7, alpha: float = 1.6,
                    self_loops: bool = True) -> GraphArrays:
    """Preferential-attachment-flavoured random graph: destination degrees
    follow a power law (the workload imbalance the paper highlights)."""
    r = _rng(seed, 0)
    # power-law weights over nodes for choosing edge endpoints
    w = (np.arange(1, n_nodes + 1, dtype=np.float64)) ** (-alpha)
    w /= w.sum()
    perm = r.permutation(n_nodes)
    senders = perm[r.choice(n_nodes, size=n_edges, p=w)]
    receivers = perm[r.choice(n_nodes, size=n_edges, p=w)]
    # avoid self loops (equivariant-model contract; GCN re-adds them)
    clash = senders == receivers
    receivers[clash] = (receivers[clash] + 1) % n_nodes
    if self_loops:
        senders = np.concatenate([senders, np.arange(n_nodes)])
        receivers = np.concatenate([receivers, np.arange(n_nodes)])
    feat = r.standard_normal((n_nodes, d_feat)).astype(np.float32)
    labels = r.integers(0, n_classes, n_nodes).astype(np.int32)
    return GraphArrays(senders.astype(np.int32), receivers.astype(np.int32),
                       feat, labels)


def criteo_batch(seed: int, step: int, *, batch: int, n_dense: int,
                 vocab_sizes: tuple[int, ...], multi_hot: int = 1,
                 zipf: float = 1.2) -> dict[str, np.ndarray]:
    """Criteo-like batch: log-normal dense features, Zipfian categorical ids
    (hot rows dominate — the degree-aware-cache workload of the paper)."""
    r = _rng(seed, step)
    dense = r.lognormal(0.0, 1.0, (batch, n_dense)).astype(np.float32)
    dense = np.log1p(dense)
    sparse = np.zeros((batch, len(vocab_sizes), multi_hot), np.int64)
    for t, v in enumerate(vocab_sizes):
        raw = r.zipf(zipf, size=(batch, multi_hot))
        sparse[:, t, :] = np.minimum(raw - 1, v - 1)
    # ~3% positive CTR-ish labels correlated with first dense feature
    p = 1.0 / (1.0 + np.exp(2.5 - dense[:, 0]))
    labels = (r.random(batch) < p).astype(np.int32)
    return {"dense": dense, "sparse": sparse.astype(np.int32),
            "labels": labels}


def molecule_batch(seed: int, step: int, *, batch: int, n_nodes: int,
                   n_edges: int, d_feat: int) -> dict[str, np.ndarray]:
    """Batched random 3D molecules (positions + kNN-ish edges, no self
    loops); graph-level scalar target = a smooth function of geometry."""
    r = _rng(seed, step)
    pos = r.standard_normal((batch, n_nodes, 3)).astype(np.float64)
    snd = np.zeros((batch, n_edges), np.int64)
    rcv = np.zeros((batch, n_edges), np.int64)
    for b in range(batch):
        s = r.integers(0, n_nodes, n_edges)
        d = (s + 1 + r.integers(0, n_nodes - 1, n_edges)) % n_nodes
        snd[b], rcv[b] = s, d
    feat = r.standard_normal((batch, n_nodes, d_feat)).astype(np.float32)
    # invariant target: mean pairwise distance per graph
    tgt = np.stack([np.linalg.norm(pos[b][snd[b]] - pos[b][rcv[b]], axis=-1).mean()
                    for b in range(batch)]).astype(np.float32)
    return {"positions": pos, "senders": snd.astype(np.int32),
            "receivers": rcv.astype(np.int32), "node_feat": feat,
            "labels": tgt[:, None]}
