"""Synthetic data generators (host-side numpy, deterministic by (seed, step)).

Every generator is a pure function of (seed, step) so a restarted job
regenerates exactly the batch stream it was consuming — the data-pipeline
half of fault tolerance (checkpoint/manager.py handles the model half).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

__all__ = ["lm_batch", "power_law_graph", "power_law_edge_stream",
           "power_law_edges", "power_law_stream_blocks",
           "ring_of_tiles_graph", "criteo_batch", "molecule_batch",
           "GraphArrays"]


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def lm_batch(seed: int, step: int, *, batch: int, seq: int,
             vocab: int) -> dict[str, np.ndarray]:
    """Zipfian token stream (vocabulary rank-frequency like real text)."""
    r = _rng(seed, step)
    toks = r.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
    toks = np.minimum(toks - 1, vocab - 1).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class GraphArrays:
    senders: np.ndarray
    receivers: np.ndarray
    node_feat: np.ndarray
    labels: np.ndarray
    edge_weight: Optional[np.ndarray] = None

    @property
    def n_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def n_edges(self) -> int:
        return self.senders.shape[0]


def power_law_graph(seed: int, *, n_nodes: int, n_edges: int, d_feat: int,
                    n_classes: int = 7, alpha: float = 1.6,
                    self_loops: bool = True) -> GraphArrays:
    """Preferential-attachment-flavoured random graph: destination degrees
    follow a power law (the workload imbalance the paper highlights)."""
    if n_nodes < 2 and n_edges > 0:
        raise ValueError(
            f"power_law_graph needs n_nodes >= 2 to draw self-loop-free "
            f"edges (got n_nodes={n_nodes}, n_edges={n_edges})")
    r = _rng(seed, 0)
    # power-law weights over nodes for choosing edge endpoints
    w = (np.arange(1, n_nodes + 1, dtype=np.float64)) ** (-alpha)
    w /= w.sum()
    perm = r.permutation(n_nodes)
    senders = perm[r.choice(n_nodes, size=n_edges, p=w)]
    receivers = perm[r.choice(n_nodes, size=n_edges, p=w)]
    # avoid self loops (equivariant-model contract; GCN re-adds them): a
    # clashing receiver is re-drawn as sender + uniform offset in
    # [1, n_nodes), which can never land back on the sender.  (The old
    # modular increment `receivers[clash] + 1` could only re-clash in the
    # degenerate n_nodes == 1 case, but it also silently biased every
    # clashing edge toward sender + 1; the re-draw removes both.)
    clash = senders == receivers
    if np.any(clash):
        offsets = r.integers(1, n_nodes, size=int(clash.sum()))
        receivers[clash] = (senders[clash] + offsets) % n_nodes
    if self_loops:
        senders = np.concatenate([senders, np.arange(n_nodes)])
        receivers = np.concatenate([receivers, np.arange(n_nodes)])
    feat = r.standard_normal((n_nodes, d_feat)).astype(np.float32)
    labels = r.integers(0, n_classes, n_nodes).astype(np.int32)
    return GraphArrays(senders.astype(np.int32), receivers.astype(np.int32),
                       feat, labels)


#: Edges per *generation block* of the streaming power-law generator.
#: Part of the stream's identity: the rng is re-seeded per block index,
#: so the edge list is a pure function of (seed, params) alone — the
#: ``chunk_edges`` a consumer asks for only controls emission
#: granularity and never changes the graph (DESIGN.md §14).  Changing
#: this constant *does* change every streamed graph; it is a format
#: decision, not a tuning knob.
POWER_LAW_STREAM_CHUNK = 1 << 20


def _power_law_stream_setup(seed: int, n_nodes: int, alpha: float):
    """(cdf, perm) shared by every block of one stream."""
    w = (np.arange(1, n_nodes + 1, dtype=np.float64)) ** (-float(alpha))
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    perm = _rng(seed, 0).permutation(n_nodes)
    return cdf, perm


def _power_law_block(seed: int, block_index: int, m: int, cdf, perm,
                     n_nodes: int):
    """Block ``block_index`` of the stream: ``m`` edges from its own rng."""
    r = _rng(seed, block_index + 1)
    snd_rank = np.searchsorted(cdf, r.random(m), side="right")
    rcv_rank = np.searchsorted(cdf, r.random(m), side="right")
    # float roundoff can push a draw past cdf[-1]; clamp to the last rank
    np.minimum(snd_rank, n_nodes - 1, out=snd_rank)
    np.minimum(rcv_rank, n_nodes - 1, out=rcv_rank)
    snd = perm[snd_rank].astype(np.int64, copy=False)
    rcv = perm[rcv_rank].astype(np.int64, copy=False)
    clash = snd == rcv
    if np.any(clash):
        # same de-clash as power_law_graph: sender + uniform offset in
        # [1, n_nodes) can never land back on the sender
        offsets = r.integers(1, n_nodes, size=int(clash.sum()))
        rcv[clash] = (snd[clash] + offsets) % n_nodes
    return snd, rcv


def power_law_stream_blocks(n_edges: int) -> int:
    """Number of fixed-size generation blocks in an ``n_edges`` stream."""
    n_edges = int(n_edges)
    return -(-n_edges // POWER_LAW_STREAM_CHUNK) if n_edges > 0 else 0


def power_law_edge_stream(seed: int, *, n_nodes: int, n_edges: int,
                          alpha: float = 1.6,
                          chunk_edges: int = POWER_LAW_STREAM_CHUNK,
                          shard: int = 0, n_shards: int = 1):
    """Chunk-streamed power-law edge generator for ≥10⁶-edge graphs.

    Yields ``(senders, receivers)`` int64 chunks of at most
    ``chunk_edges`` edges with the same contract as
    :func:`power_law_graph` (destination degrees follow a power law over
    a permuted rank order; no self loops) but O(block + n_nodes) peak
    memory: endpoints are drawn by inverse-CDF ``searchsorted`` against
    the rank-weight cumulative.

    The stream is generated in fixed internal blocks of
    :data:`POWER_LAW_STREAM_CHUNK` edges, each from its own
    ``(seed, block_index)`` rng, so the concatenated edge list is a pure
    function of ``(seed, n_nodes, n_edges, alpha)`` — **invariant to
    ``chunk_edges``** (which only sets emission granularity) and to how
    the blocks are divided among shards.  ``shard`` / ``n_shards``
    restrict the stream to the blocks ``block_index % n_shards ==
    shard`` (round-robin ownership): the shard streams are disjoint,
    together cover every block, and interleaving them back in block
    order reproduces the single-shard stream exactly — the generation
    half of the sharded trace pipeline
    (:mod:`repro.distributed.trace_shard`, DESIGN.md §14).
    Feature/label matrices are deliberately absent — the trace backend
    only needs topology (DESIGN.md §13).
    """
    n_nodes = int(n_nodes)
    n_edges = int(n_edges)
    chunk_edges = int(chunk_edges)
    shard = int(shard)
    n_shards = int(n_shards)
    if n_edges < 0 or chunk_edges < 1:
        raise ValueError(f"need n_edges >= 0 and chunk_edges >= 1, got "
                         f"n_edges={n_edges}, chunk_edges={chunk_edges}")
    if n_shards < 1 or not 0 <= shard < n_shards:
        raise ValueError(f"need 0 <= shard < n_shards, got shard={shard}, "
                         f"n_shards={n_shards}")
    if n_nodes < 2 and n_edges > 0:
        raise ValueError(
            f"power_law_edge_stream needs n_nodes >= 2 to draw "
            f"self-loop-free edges (got n_nodes={n_nodes}, "
            f"n_edges={n_edges})")
    cdf, perm = _power_law_stream_setup(seed, n_nodes, alpha)
    B = POWER_LAW_STREAM_CHUNK
    n_blocks = power_law_stream_blocks(n_edges)
    pending: list[tuple[np.ndarray, np.ndarray]] = []
    buffered = 0
    for b in range(shard, n_blocks, n_shards):
        m = min(B, n_edges - b * B)
        snd, rcv = _power_law_block(seed, b, m, cdf, perm, n_nodes)
        pending.append((snd, rcv))
        buffered += m
        while buffered >= chunk_edges:
            # emit exactly chunk_edges from the buffered block slices
            if len(pending) == 1 and pending[0][0].size == chunk_edges:
                (out,) = pending
                pending = []
            else:
                snd_c = np.concatenate([p[0] for p in pending])
                rcv_c = np.concatenate([p[1] for p in pending])
                out = (snd_c[:chunk_edges], rcv_c[:chunk_edges])
                tail = (snd_c[chunk_edges:], rcv_c[chunk_edges:])
                pending = [tail] if tail[0].size else []
            buffered -= chunk_edges
            yield out
    if buffered:
        if len(pending) == 1:
            yield pending[0]
        else:
            yield (np.concatenate([p[0] for p in pending]),
                   np.concatenate([p[1] for p in pending]))


def power_law_edges(seed: int, *, n_nodes: int, n_edges: int,
                    alpha: float = 1.6,
                    chunk_edges: int = POWER_LAW_STREAM_CHUNK,
                    shard: int = 0, n_shards: int = 1,
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Materialize :func:`power_law_edge_stream` into compact arrays.

    Senders/receivers come back in the narrowest integer dtype that
    holds the vertex ids (int32 below 2^31 vertices), filled chunk by
    chunk into preallocated arrays — the 10⁷-edge path of
    ``benchmarks/trace_scale.py`` without a 10⁷-scale intermediate per
    draw.  With ``n_shards > 1`` only the blocks owned by ``shard``
    materialize (in block order); the multiset union over all shards is
    exactly the single-shard edge list.
    """
    n_edges = int(n_edges)
    dtype = (np.int32 if int(n_nodes) <= np.iinfo(np.int32).max
             else np.int64)
    B = POWER_LAW_STREAM_CHUNK
    owned = sum(min(B, n_edges - b * B)
                for b in range(int(shard), power_law_stream_blocks(n_edges),
                               int(n_shards)))
    senders = np.empty(owned, dtype=dtype)
    receivers = np.empty(owned, dtype=dtype)
    at = 0
    for snd, rcv in power_law_edge_stream(seed, n_nodes=n_nodes,
                                          n_edges=n_edges, alpha=alpha,
                                          chunk_edges=chunk_edges,
                                          shard=shard, n_shards=n_shards):
        senders[at:at + snd.size] = snd
        receivers[at:at + rcv.size] = rcv
        at += snd.size
    return senders, receivers


def ring_of_tiles_graph(*, n_nodes: int, n_tiles: int,
                        d_feat: int = 1) -> GraphArrays:
    """Perfectly uniform ring-of-tiles graph: the fixture on which the
    composition layer's uniform-tile approximation is *exact*.

    With ``K = n_nodes / n_tiles`` (``n_tiles`` must divide ``n_nodes``),
    every vertex ``i`` receives one local ring edge (its predecessor
    within the tile, cyclically) plus one edge from the vertex ``t * K``
    positions behind it for every ``t in 1..n_tiles-1`` — i.e. exactly one
    source in every other tile.  Under the balanced contiguous partition
    into ``n_tiles`` tiles this gives every tile identical ``K`` vertices,
    ``P = K * n_tiles`` edges, a remote fraction of exactly
    ``1 - 1/n_tiles`` (the paper's random-partition expected cut), and
    all remote sources distinct (halo dedup is trivial) — so the exact
    trace schedule and the uniform closed form must agree bit for bit
    (pinned in tests).  Deterministic; no self loops (needs ``K >= 2``).
    """
    if n_tiles < 1 or n_nodes % n_tiles:
        raise ValueError(f"n_tiles must divide n_nodes for a uniform ring "
                         f"(got n_nodes={n_nodes}, n_tiles={n_tiles})")
    K = n_nodes // n_tiles
    if K < 2:
        raise ValueError(f"ring_of_tiles_graph needs >= 2 vertices per tile "
                         f"to avoid self loops (got {K})")
    i = np.arange(n_nodes, dtype=np.int64)
    tile = i // K
    local_src = (i - tile * K - 1) % K + tile * K   # in-tile ring predecessor
    senders = [local_src]
    receivers = [i]
    for t in range(1, n_tiles):
        senders.append((i - t * K) % n_nodes)       # one source per other tile
        receivers.append(i)
    snd = np.concatenate(senders).astype(np.int32)
    rcv = np.concatenate(receivers).astype(np.int32)
    feat = np.ones((n_nodes, d_feat), np.float32)
    labels = np.zeros(n_nodes, np.int32)
    return GraphArrays(snd, rcv, feat, labels)


def criteo_batch(seed: int, step: int, *, batch: int, n_dense: int,
                 vocab_sizes: tuple[int, ...], multi_hot: int = 1,
                 zipf: float = 1.2) -> dict[str, np.ndarray]:
    """Criteo-like batch: log-normal dense features, Zipfian categorical ids
    (hot rows dominate — the degree-aware-cache workload of the paper)."""
    r = _rng(seed, step)
    dense = r.lognormal(0.0, 1.0, (batch, n_dense)).astype(np.float32)
    dense = np.log1p(dense)
    sparse = np.zeros((batch, len(vocab_sizes), multi_hot), np.int64)
    for t, v in enumerate(vocab_sizes):
        raw = r.zipf(zipf, size=(batch, multi_hot))
        sparse[:, t, :] = np.minimum(raw - 1, v - 1)
    # ~3% positive CTR-ish labels correlated with first dense feature
    p = 1.0 / (1.0 + np.exp(2.5 - dense[:, 0]))
    labels = (r.random(batch) < p).astype(np.int32)
    return {"dense": dense, "sparse": sparse.astype(np.int32),
            "labels": labels}


def molecule_batch(seed: int, step: int, *, batch: int, n_nodes: int,
                   n_edges: int, d_feat: int) -> dict[str, np.ndarray]:
    """Batched random 3D molecules (positions + kNN-ish edges, no self
    loops); graph-level scalar target = a smooth function of geometry."""
    r = _rng(seed, step)
    pos = r.standard_normal((batch, n_nodes, 3)).astype(np.float64)
    snd = np.zeros((batch, n_edges), np.int64)
    rcv = np.zeros((batch, n_edges), np.int64)
    for b in range(batch):
        s = r.integers(0, n_nodes, n_edges)
        d = (s + 1 + r.integers(0, n_nodes - 1, n_edges)) % n_nodes
        snd[b], rcv[b] = s, d
    feat = r.standard_normal((batch, n_nodes, d_feat)).astype(np.float32)
    # invariant target: mean pairwise distance per graph
    tgt = np.stack([np.linalg.norm(pos[b][snd[b]] - pos[b][rcv[b]], axis=-1).mean()
                    for b in range(batch)]).astype(np.float32)
    return {"positions": pos, "senders": snd.astype(np.int32),
            "receivers": rcv.astype(np.int32), "node_feat": feat,
            "labels": tgt[:, None]}
