"""Real spherical-harmonic rotation (Wigner) matrices, numpy host-side.

EquiformerV2's eSCN convolution rotates each edge's irrep features so the
edge vector aligns with +z, applies an SO(2)-block linear map, and rotates
back.  The per-edge rotation matrices are data-pipeline products (host
numpy), shipped to the device as regular arrays — exactly how OCP's eSCN
implementation treats them.

The recursion below is Ivanic & Ruedenberg (J. Phys. Chem. 1996, 1998
erratum): real-SH rotation matrices R^l are built from R^1 and R^{l-1}
via the u,v,w coefficient tables.  Conventions: real SH ordering
m = -l..l; R^1 acts on (Y_1^{-1}, Y_1^0, Y_1^1) ~ (y, z, x).
"""

from __future__ import annotations

import math

import numpy as np

__all__ = ["wigner_d_real", "wigner_stack", "rotation_to_z", "random_rotation"]


def _r1_from_rotation(R: np.ndarray) -> np.ndarray:
    """Map a 3x3 Cartesian rotation (acting on x,y,z) to the l=1 real-SH
    basis ordered (m=-1,0,1) ~ (y, z, x)."""
    # permutation P: (y,z,x) ordering
    P = np.array([[0, 1, 0], [0, 0, 1], [1, 0, 0]], dtype=np.float64)
    return P @ R @ P.T


def _P(i: int, l: int, mu: int, m_: int, r1: np.ndarray, rlm1: np.ndarray) -> float:
    """Helper P_i^{mu,m} of the recursion (Ivanic & Ruedenberg Table 1)."""
    # r1 indices: -1,0,1 -> 0,1,2 ; rlm1 indices: -(l-1)..(l-1) -> offset l-1
    ri = lambda a, b: r1[a + 1, b + 1]
    rl = lambda a, b: rlm1[a + l - 1, b + l - 1]
    if m_ == l:
        return ri(i, 1) * rl(mu, l - 1) - ri(i, -1) * rl(mu, -(l - 1))
    if m_ == -l:
        return ri(i, 1) * rl(mu, -(l - 1)) + ri(i, -1) * rl(mu, l - 1)
    return ri(i, 0) * rl(mu, m_)


def _uvw(l: int, mu: int, m_: int) -> tuple[float, float, float]:
    d = 1.0 if mu == 0 else 0.0
    if abs(m_) < l:
        denom = (l + m_) * (l - m_)
    else:
        denom = (2 * l) * (2 * l - 1)
    u = math.sqrt((l + mu) * (l - mu) / denom)
    v = 0.5 * math.sqrt((1 + d) * (l + abs(mu) - 1) * (l + abs(mu)) / denom) * (1 - 2 * d)
    w = -0.5 * math.sqrt((l - abs(mu) - 1) * (l - abs(mu)) / denom) * (1 - d)
    return u, v, w


def _wigner_next(l: int, r1: np.ndarray, rlm1: np.ndarray) -> np.ndarray:
    """R^l from R^1 and R^{l-1}."""
    size = 2 * l + 1
    out = np.zeros((size, size), dtype=np.float64)
    for mu in range(-l, l + 1):
        for m_ in range(-l, l + 1):
            u, v, w = _uvw(l, mu, m_)
            val = 0.0
            if u:
                val += u * _P(0, l, mu, m_, r1, rlm1)
            if v:
                if mu == 0:
                    val += v * (_P(1, l, 1, m_, r1, rlm1)
                                + _P(-1, l, -1, m_, r1, rlm1))
                elif mu > 0:
                    val += v * (_P(1, l, mu - 1, m_, r1, rlm1)
                                * math.sqrt(1 + (1.0 if mu == 1 else 0.0))
                                - _P(-1, l, -mu + 1, m_, r1, rlm1)
                                * (0.0 if mu == 1 else 1.0))
                else:
                    val += v * (_P(1, l, mu + 1, m_, r1, rlm1)
                                * (0.0 if mu == -1 else 1.0)
                                + _P(-1, l, -mu - 1, m_, r1, rlm1)
                                * math.sqrt(1 + (1.0 if mu == -1 else 0.0)))
            if w:
                if mu > 0:
                    val += w * (_P(1, l, mu + 1, m_, r1, rlm1)
                                + _P(-1, l, -mu - 1, m_, r1, rlm1))
                elif mu < 0:
                    val += w * (_P(1, l, mu - 1, m_, r1, rlm1)
                                - _P(-1, l, -mu + 1, m_, r1, rlm1))
            out[mu + l, m_ + l] = val
    return out


def wigner_d_real(R: np.ndarray, l_max: int) -> list[np.ndarray]:
    """Real-SH rotation matrices [R^0 .. R^{l_max}] for Cartesian rotation R."""
    mats = [np.ones((1, 1), dtype=np.float64)]
    if l_max >= 1:
        mats.append(_r1_from_rotation(np.asarray(R, np.float64)))
    for l in range(2, l_max + 1):
        mats.append(_wigner_next(l, mats[1], mats[-1]))
    return mats


def wigner_stack(Rs: np.ndarray, l_max: int, *, m_max: int | None = None,
                 dtype=np.float32) -> dict[int, np.ndarray]:
    """Per-edge rotation blocks {l: (E, m_dim, 2l+1)}.

    With eSCN's m_max restriction only the rows |m| <= m_max are kept (the
    SO(2) conv never reads the others).  Rows are ALWAYS reordered to
    (m=0, m=1c, m=1s, ..., c, s) — real-SH index l+m supplies the 'cos' row
    and l-m the 'sin' row — matching the layout
    :func:`repro.models.gnn.equiformer_v2._so2_conv` consumes.
    """
    E = Rs.shape[0]
    out: dict[int, np.ndarray] = {}
    per_edge = [wigner_d_real(Rs[e], l_max) for e in range(E)]
    for l in range(l_max + 1):
        full = np.stack([pe[l] for pe in per_edge])  # (E, 2l+1, 2l+1)
        m_keep = l if m_max is None else min(l, m_max)
        rows = [l]  # m = 0 row index in (-l..l) offset l
        for m in range(1, m_keep + 1):
            rows.extend([l + m, l - m])
        out[l] = full[:, rows, :].astype(dtype)
    return out


def rotation_to_z(vec: np.ndarray) -> np.ndarray:
    """Rotation matrix taking ``vec`` (3,) to +z (Rodrigues)."""
    v = np.asarray(vec, np.float64)
    n = np.linalg.norm(v)
    if n < 1e-12:
        return np.eye(3)
    v = v / n
    z = np.array([0.0, 0.0, 1.0])
    axis = np.cross(v, z)
    s = np.linalg.norm(axis)
    c = float(v @ z)
    if s < 1e-12:
        return np.eye(3) if c > 0 else np.diag([1.0, -1.0, -1.0])
    axis = axis / s
    K = np.array([[0, -axis[2], axis[1]],
                  [axis[2], 0, -axis[0]],
                  [-axis[1], axis[0], 0]])
    return np.eye(3) + s * K + (1 - c) * (K @ K)


def random_rotation(rng: np.random.Generator) -> np.ndarray:
    """Uniform random rotation via QR of a Gaussian matrix."""
    A = rng.standard_normal((3, 3))
    Q, R = np.linalg.qr(A)
    Q = Q @ np.diag(np.sign(np.diag(R)))
    if np.linalg.det(Q) < 0:
        Q[:, 0] = -Q[:, 0]
    return Q
