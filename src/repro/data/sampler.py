"""k-hop fanout neighbor sampler (GraphSAGE-style) over a CSR adjacency.

``minibatch_lg`` requires a real sampler, not a stub: given seed nodes and
per-layer fanouts it walks the CSR structure, uniformly samples up to
``fanout[l]`` in-neighbors per frontier node, and emits a PADDED subgraph
with static shapes (the padded sizes match
:func:`repro.launch.steps.sampled_subgraph_sizes`, so one compiled
train-step serves every sampled batch).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["CSRGraph", "build_csr", "sample_subgraph", "SampledSubgraph"]


@dataclass
class CSRGraph:
    """In-neighbor CSR: for node v, neighbors are col[ptr[v]:ptr[v+1]]."""

    ptr: np.ndarray
    col: np.ndarray
    n_nodes: int


def build_csr(senders: np.ndarray, receivers: np.ndarray,
              n_nodes: int) -> CSRGraph:
    order = np.argsort(receivers, kind="stable")
    col = senders[order].astype(np.int32)
    counts = np.bincount(receivers, minlength=n_nodes)
    ptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    return CSRGraph(ptr=ptr, col=col, n_nodes=n_nodes)


@dataclass
class SampledSubgraph:
    """Padded sampled subgraph with LOCAL node ids (0..n_sub)."""

    node_ids: np.ndarray       # (N_pad,) global ids (0-padded)
    senders: np.ndarray        # (E_pad,) local ids
    receivers: np.ndarray      # (E_pad,) local ids
    node_mask: np.ndarray      # (N_pad,) float {0,1}
    edge_mask: np.ndarray      # (E_pad,) float {0,1}
    seed_mask: np.ndarray      # (N_pad,) float — loss restricted to seeds
    n_real_nodes: int
    n_real_edges: int


def sample_subgraph(g: CSRGraph, seeds: np.ndarray, fanout: tuple[int, ...],
                    *, rng: np.random.Generator, n_pad: int,
                    e_pad: int) -> SampledSubgraph:
    node_ids: list[int] = list(seeds)
    local = {int(v): i for i, v in enumerate(seeds)}
    snd_l: list[int] = []
    rcv_l: list[int] = []
    frontier = list(seeds)
    for f in fanout:
        nxt: list[int] = []
        for v in frontier:
            lo, hi = g.ptr[v], g.ptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, int(deg))
            picks = g.col[lo + rng.choice(deg, size=take, replace=False)]
            for u in picks:
                u = int(u)
                if u not in local:
                    local[u] = len(node_ids)
                    node_ids.append(u)
                snd_l.append(local[u])
                rcv_l.append(local[int(v)])
                nxt.append(u)
        frontier = nxt
    n_real, e_real = len(node_ids), len(snd_l)
    if n_real > n_pad or e_real > e_pad:
        raise ValueError(f"sample exceeds padding: nodes {n_real}>{n_pad} "
                         f"or edges {e_real}>{e_pad}")

    ids = np.zeros(n_pad, np.int32)
    ids[:n_real] = node_ids
    snd = np.zeros(e_pad, np.int32)
    snd[:e_real] = snd_l
    rcv = np.zeros(e_pad, np.int32)
    rcv[:e_real] = rcv_l
    nmask = np.zeros(n_pad, np.float32)
    nmask[:n_real] = 1.0
    emask = np.zeros(e_pad, np.float32)
    emask[:e_real] = 1.0
    smask = np.zeros(n_pad, np.float32)
    smask[:len(seeds)] = 1.0
    return SampledSubgraph(ids, snd, rcv, nmask, emask, smask, n_real, e_real)
