"""k-hop fanout neighbor sampler (GraphSAGE-style) over a CSR adjacency.

``minibatch_lg`` requires a real sampler, not a stub: given seed nodes and
per-layer fanouts it walks the CSR structure, uniformly samples up to
``fanout[l]`` in-neighbors per frontier node, and emits a PADDED subgraph
with static shapes (the padded sizes match
:func:`repro.launch.steps.sampled_subgraph_sizes`, so one compiled
train-step serves every sampled batch).

The sampler doubles as the **minibatch workload generator** of the
movement model (DESIGN.md §17): :func:`minibatch_schedule` runs
``n_batches`` independent sampling episodes and returns a
:class:`~repro.core.trace.TraceSchedule` whose "tiles" are episodes —
``vertex_counts`` the seed batch, ``edge_counts`` the sampled message
edges, and ``halo_counts`` the exact number of **unique non-seed** source
vertices each episode gathers (the neighbor-sampling gather traffic).
``TiledGraphModel(schedule=...)`` then charges the episodes with the same
closed forms as any trace schedule.  A brute-force ``np.unique`` oracle
(:func:`minibatch_oracle_counts`) recomputes every count through an
independent code path for the drift gate in ``tests/test_hetero.py``.

Random protocol: episode ``b`` of ``seed`` uses
``np.random.default_rng(np.random.SeedSequence([seed, b]))``, draws the
seed batch with ``rng.choice(n_nodes, size=batch_nodes, replace=False)``,
then samples hops via :func:`_sample_edge_stream` — one
``rng.choice(deg, size=take, replace=False)`` call per frontier node with
nonzero in-degree, in frontier order.  :func:`sample_subgraph` consumes
the identical call sequence, so episode counts and training subgraphs
agree bit-for-bit for the same (seed, batch) pair.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.trace import TraceSchedule

__all__ = [
    "CSRGraph",
    "build_csr",
    "csr_from_trace",
    "sample_subgraph",
    "SampledSubgraph",
    "minibatch_schedule",
    "minibatch_oracle_counts",
]

_INT32_MAX = np.iinfo(np.int32).max


@dataclass
class CSRGraph:
    """In-neighbor CSR: for node v, neighbors are col[ptr[v]:ptr[v+1]]."""

    ptr: np.ndarray
    col: np.ndarray
    n_nodes: int


def build_csr(senders: np.ndarray, receivers: np.ndarray,
              n_nodes: int) -> CSRGraph:
    """Build the in-neighbor CSR from a (senders, receivers) edge list.

    ``col`` is stored int32 for footprint; ``n_nodes`` (and hence every
    stored sender id) must fit int32 — validated up front rather than
    silently wrapped by the narrowing cast.  Graphs beyond 2^31 - 1
    vertices belong to the int64 trace pipeline (``repro.core.trace``).
    """
    n_nodes = int(n_nodes)
    if n_nodes < 0:
        raise ValueError(f"n_nodes must be non-negative, got {n_nodes}")
    if n_nodes > _INT32_MAX:
        raise ValueError(
            f"build_csr stores neighbor columns as int32, so n_nodes must "
            f"be <= {_INT32_MAX} (got {n_nodes}); use the int64 trace "
            "pipeline (repro.core.trace) for larger graphs")
    senders = np.asarray(senders)
    receivers = np.asarray(receivers)
    if senders.shape != receivers.shape or senders.ndim != 1:
        raise ValueError("senders/receivers must be equal-length 1-D arrays")
    if senders.size:
        if int(senders.min()) < 0 or int(senders.max()) >= n_nodes:
            raise ValueError(f"sender ids must lie in [0, {n_nodes})")
        if int(receivers.min()) < 0 or int(receivers.max()) >= n_nodes:
            raise ValueError(f"receiver ids must lie in [0, {n_nodes})")
    order = np.argsort(receivers, kind="stable")
    col = senders[order].astype(np.int32)
    counts = np.bincount(receivers, minlength=n_nodes)
    ptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    return CSRGraph(ptr=ptr, col=col, n_nodes=n_nodes)


def csr_from_trace(trace) -> CSRGraph:
    """View a (typed or plain) GraphTrace's destination-major factorization
    as a sampler CSR — no re-sort, no int32 narrowing (trace ids are kept
    in the trace's own dtype; within a row, neighbors are sender-sorted
    instead of stream-ordered, which uniform sampling is insensitive to).
    """
    return CSRGraph(ptr=np.asarray(trace.row_ptr, dtype=np.int64),
                    col=np.asarray(trace.csr_senders),
                    n_nodes=int(trace.n_nodes))


@dataclass
class SampledSubgraph:
    """Padded sampled subgraph with LOCAL node ids (0..n_sub)."""

    node_ids: np.ndarray       # (N_pad,) global ids (0-padded)
    senders: np.ndarray        # (E_pad,) local ids
    receivers: np.ndarray      # (E_pad,) local ids
    node_mask: np.ndarray      # (N_pad,) float {0,1}
    edge_mask: np.ndarray      # (E_pad,) float {0,1}
    seed_mask: np.ndarray      # (N_pad,) float — loss restricted to seeds
    n_real_nodes: int
    n_real_edges: int


def _sample_edge_stream(g: CSRGraph, seeds: np.ndarray,
                        fanout: tuple[int, ...],
                        rng: np.random.Generator
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Sampled message edges as GLOBAL-id streams (senders, receivers).

    The shared core of :func:`sample_subgraph` and
    :func:`minibatch_schedule`.  Only the per-node
    ``rng.choice(deg, size=take, replace=False)`` draws stay in a Python
    loop — they are an inherently sequential rng-stream protocol — and
    they are issued in exactly the frontier order of the original
    implementation (zero-degree nodes skipped), so the produced stream is
    bit-identical to the per-node-append version under the same rng.
    The next frontier is the pick stream itself, duplicates included.
    """
    snd_parts: list[np.ndarray] = []
    rcv_parts: list[np.ndarray] = []
    col = g.col
    frontier = np.asarray(seeds, dtype=np.int64)
    for f in fanout:
        lo = g.ptr[frontier]
        deg = g.ptr[frontier + 1] - lo
        keep = deg > 0
        v_k = frontier[keep]
        lo_k = lo[keep]
        take_k = np.minimum(int(f), deg[keep])
        offs = [rng.choice(int(d), size=int(t), replace=False)
                for d, t in zip(deg[keep].tolist(), take_k.tolist())]
        if offs:
            off = np.concatenate([np.asarray(o, dtype=np.int64)
                                  for o in offs])
        else:
            off = np.zeros(0, dtype=np.int64)
        picks = np.asarray(col[np.repeat(lo_k, take_k) + off],
                           dtype=np.int64)
        snd_parts.append(picks)
        rcv_parts.append(np.repeat(v_k, take_k))
        frontier = picks
    if not snd_parts:
        z = np.zeros(0, dtype=np.int64)
        return z, z
    return np.concatenate(snd_parts), np.concatenate(rcv_parts)


def sample_subgraph(g: CSRGraph, seeds: np.ndarray, fanout: tuple[int, ...],
                    *, rng: np.random.Generator, n_pad: int,
                    e_pad: int) -> SampledSubgraph:
    """Vectorized sampler: one edge-stream pass plus an O(V + E) remap.

    Bit-identical to :func:`_sample_subgraph_reference` under the same
    rng (regression-pinned in tests): local ids are assigned in first-
    appearance order over the concatenated pick stream (seeds first),
    which is exactly the discovery order of the per-pick dict insert.
    ``seeds`` must be duplicate-free (they are drawn without replacement).
    """
    seeds = np.asarray(seeds, dtype=np.int64)
    snd_g, rcv_g = _sample_edge_stream(g, seeds, fanout, rng)
    loc = np.full(g.n_nodes, -1, dtype=np.int64)
    loc[seeds] = np.arange(seeds.size)
    uniq, first = np.unique(snd_g, return_index=True)
    new_mask = loc[uniq] < 0
    new_vals = uniq[new_mask][np.argsort(first[new_mask])]
    loc[new_vals] = seeds.size + np.arange(new_vals.size)
    n_real = int(seeds.size + new_vals.size)
    e_real = int(snd_g.size)
    if n_real > n_pad or e_real > e_pad:
        raise ValueError(f"sample exceeds padding: nodes {n_real}>{n_pad} "
                         f"or edges {e_real}>{e_pad}")

    ids = np.zeros(n_pad, np.int32)
    ids[:seeds.size] = seeds
    ids[seeds.size:n_real] = new_vals
    snd = np.zeros(e_pad, np.int32)
    snd[:e_real] = loc[snd_g]
    rcv = np.zeros(e_pad, np.int32)
    rcv[:e_real] = loc[rcv_g]
    nmask = np.zeros(n_pad, np.float32)
    nmask[:n_real] = 1.0
    emask = np.zeros(e_pad, np.float32)
    emask[:e_real] = 1.0
    smask = np.zeros(n_pad, np.float32)
    smask[:seeds.size] = 1.0
    return SampledSubgraph(ids, snd, rcv, nmask, emask, smask, n_real, e_real)


def _sample_subgraph_reference(g: CSRGraph, seeds: np.ndarray,
                               fanout: tuple[int, ...],
                               *, rng: np.random.Generator, n_pad: int,
                               e_pad: int) -> SampledSubgraph:
    """Pre-vectorization per-pick implementation, kept VERBATIM as the
    bit-identity regression pin for :func:`sample_subgraph`."""
    node_ids: list[int] = list(seeds)
    local = {int(v): i for i, v in enumerate(seeds)}
    snd_l: list[int] = []
    rcv_l: list[int] = []
    frontier = list(seeds)
    for f in fanout:
        nxt: list[int] = []
        for v in frontier:
            lo, hi = g.ptr[v], g.ptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, int(deg))
            picks = g.col[lo + rng.choice(deg, size=take, replace=False)]
            for u in picks:
                u = int(u)
                if u not in local:
                    local[u] = len(node_ids)
                    node_ids.append(u)
                snd_l.append(local[u])
                rcv_l.append(local[int(v)])
                nxt.append(u)
        frontier = nxt
    n_real, e_real = len(node_ids), len(snd_l)
    if n_real > n_pad or e_real > e_pad:
        raise ValueError(f"sample exceeds padding: nodes {n_real}>{n_pad} "
                         f"or edges {e_real}>{e_pad}")

    ids = np.zeros(n_pad, np.int32)
    ids[:n_real] = node_ids
    snd = np.zeros(e_pad, np.int32)
    snd[:e_real] = snd_l
    rcv = np.zeros(e_pad, np.int32)
    rcv[:e_real] = rcv_l
    nmask = np.zeros(n_pad, np.float32)
    nmask[:n_real] = 1.0
    emask = np.zeros(e_pad, np.float32)
    emask[:e_real] = 1.0
    smask = np.zeros(n_pad, np.float32)
    smask[:len(seeds)] = 1.0
    return SampledSubgraph(ids, snd, rcv, nmask, emask, smask, n_real, e_real)


# ---------------------------------------------------------------------------
# Sampled-minibatch episodes as a trace schedule (DESIGN.md §17)
# ---------------------------------------------------------------------------

def _episode_stream(g: CSRGraph, *, batch_nodes: int,
                    fanout: tuple[int, ...], episode: int,
                    seed: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(seeds, senders, receivers) of one sampling episode."""
    rng = np.random.default_rng(
        np.random.SeedSequence([int(seed), int(episode)]))
    seeds = rng.choice(g.n_nodes, size=int(batch_nodes), replace=False)
    seeds = np.asarray(seeds, dtype=np.int64)
    snd, rcv = _sample_edge_stream(g, seeds, tuple(fanout), rng)
    return seeds, snd, rcv


def _validate_minibatch_args(g: CSRGraph, batch_nodes: int,
                             fanout, n_batches: int) -> tuple[int, ...]:
    fanout = tuple(int(f) for f in fanout)
    if not fanout or any(f < 1 for f in fanout):
        raise ValueError(f"fanout must be a non-empty tuple of >= 1 "
                         f"neighbor budgets, got {fanout!r}")
    if not (1 <= int(batch_nodes) <= g.n_nodes):
        raise ValueError(f"batch_nodes must lie in [1, n_nodes={g.n_nodes}], "
                         f"got {batch_nodes}")
    if int(n_batches) < 1:
        raise ValueError(f"n_batches must be >= 1, got {n_batches}")
    return fanout


def minibatch_schedule(g: CSRGraph, *, batch_nodes: int,
                       fanout, n_batches: int,
                       seed: int = 0) -> TraceSchedule:
    """Measure ``n_batches`` sampling episodes as an exact TraceSchedule.

    Episode ``b`` draws ``batch_nodes`` seed vertices without replacement
    and samples a ``fanout``-bounded k-hop in-neighborhood.  Schedule
    semantics mirror the graph-tiling trace exactly:

    * ``vertex_counts[b]`` — owned vertices: the seed batch,
    * ``edge_counts[b]`` — sampled message edges of the episode,
    * ``halo_counts[b]`` — **unique non-seed** source vertices gathered
      (the deduplicated neighbor-sampling gather the paper's halo-reload
      term charges at the halo feature width),
    * ``remote_edge_counts[b]`` — sampled edges whose source is not a
      seed (pre-dedup; ``halo <= remote`` as for tiles).

    The fast counting path marks V-sized boolean scratch arrays; the
    independent :func:`minibatch_oracle_counts` recomputes everything
    with ``np.unique`` / ``np.isin`` for the drift gate.  The schedule
    carries a ``(episode, source)`` multiplicity source, so
    ``cache_hit_fraction`` works for episodes too.  Results are cached
    per graph instance under the full parameter key.
    """
    fanout = _validate_minibatch_args(g, batch_nodes, fanout, n_batches)
    key = (int(batch_nodes), fanout, int(n_batches), int(seed))
    cache = getattr(g, "_episode_cache", None)
    if cache is None:
        cache = {}
        g._episode_cache = cache
    if key in cache:
        return cache[key]
    n_batches = int(n_batches)
    edge_counts = np.zeros(n_batches, dtype=np.float64)
    halo_counts = np.zeros(n_batches, dtype=np.float64)
    remote_counts = np.zeros(n_batches, dtype=np.float64)
    pair_tiles: list[np.ndarray] = []
    pair_counts: list[np.ndarray] = []
    is_seed = np.zeros(g.n_nodes, dtype=bool)
    seen = np.zeros(g.n_nodes, dtype=bool)
    for b in range(n_batches):
        seeds, snd, _ = _episode_stream(
            g, batch_nodes=batch_nodes, fanout=fanout, episode=b, seed=seed)
        is_seed[seeds] = True
        nonseed = snd[~is_seed[snd]]
        seen[nonseed] = True
        edge_counts[b] = snd.size
        remote_counts[b] = nonseed.size
        halo_counts[b] = np.count_nonzero(seen)
        # reset scratch in O(touched), not O(V)
        seen[nonseed] = False
        is_seed[seeds] = False
        src, cnt = np.unique(snd, return_counts=True)
        pair_tiles.append(np.full(src.size, b, dtype=np.int64))
        pair_counts.append(cnt.astype(np.int64))

    def _pairs() -> tuple[np.ndarray, np.ndarray]:
        if not pair_tiles:
            z = np.zeros(0, dtype=np.int64)
            return z, z
        return np.concatenate(pair_tiles), np.concatenate(pair_counts)

    sched = TraceSchedule(
        n_tiles=n_batches, capacity=int(batch_nodes), K=int(batch_nodes),
        vertex_counts=np.full(n_batches, float(batch_nodes)),
        edge_counts=edge_counts, halo_counts=halo_counts,
        remote_edge_counts=remote_counts, _pair_source=_pairs)
    cache[key] = sched
    return sched


def minibatch_oracle_counts(g: CSRGraph, *, batch_nodes: int,
                            fanout, n_batches: int,
                            seed: int = 0) -> dict[str, np.ndarray]:
    """Brute-force ``np.unique`` oracle for :func:`minibatch_schedule`.

    Replays the identical episode rng protocol but counts through an
    independent path: per-episode gather/halo is
    ``np.setdiff1d(senders, seeds).size`` and remote edges are
    ``(~np.isin(senders, seeds)).sum()`` — no mark arrays shared with the
    fast path.
    """
    fanout = _validate_minibatch_args(g, batch_nodes, fanout, n_batches)
    n_batches = int(n_batches)
    edge_counts = np.zeros(n_batches, dtype=np.float64)
    halo_counts = np.zeros(n_batches, dtype=np.float64)
    remote_counts = np.zeros(n_batches, dtype=np.float64)
    for b in range(n_batches):
        seeds, snd, _ = _episode_stream(
            g, batch_nodes=batch_nodes, fanout=fanout, episode=b, seed=seed)
        edge_counts[b] = snd.size
        halo_counts[b] = np.setdiff1d(snd, seeds).size
        remote_counts[b] = int(np.sum(~np.isin(snd, seeds)))
    return {"edge_counts": edge_counts, "halo_counts": halo_counts,
            "remote_edge_counts": remote_counts}
