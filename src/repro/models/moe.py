"""Mixture-of-Experts FFN: routing, capacity dispatch, expert parallelism.

Three execution paths, one semantics:

* :func:`moe_ffn_reference` — every expert processes every token, gated
  combine.  O(E) overcompute; used as the numerical oracle in tests and as
  the decode path (at decode batch sizes all experts are hit anyway, and the
  step is weight-read-bound — see DESIGN.md).
* :func:`moe_ffn_capacity` — single-device capacity-bucketed dispatch:
  tokens scatter into an (E, C, d) buffer, batched expert GEMMs, gather back.
  Active-only FLOPs (x capacity factor).  This is what the EP path reduces
  to on one device.
* :func:`moe_ffn_ep` — expert-parallel shard_map: tokens are
  sequence-sharded over the ``model`` axis, packed into per-destination
  capacity buckets, exchanged with ``all_to_all`` (dispatch), processed by
  the shard-local experts, and returned with a second ``all_to_all``
  (combine).  This is the production path whose two a2a ops per layer are
  the traffic characterized by
  :func:`repro.core.tpu_model.moe_dispatch_sync`.

Over-capacity assignments are dropped (standard Switch/GShard semantics);
the capacity factor is configurable per arch config.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..compat import axis_size

Array = jax.Array


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # Snowflake-Arctic-style dense residual MLP running in parallel with the
    # experts (d_ff of that branch); None disables it.
    dense_residual_d_ff: Optional[int] = None
    aux_loss_weight: float = 0.01


def router_topk(x: Array, w_router: Array, cfg: MoEConfig):
    """Softmax-then-top-k routing with renormalized gates (Qwen3/Mixtral).

    Returns (expert_idx (T,k) int32, gates (T,k) f32, aux_loss scalar).
    """
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)
    # Switch-style load-balance auxiliary loss.
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, cfg.n_experts, dtype=jnp.float32), axis=1),
        axis=0)
    aux = cfg.n_experts * jnp.sum(me * ce) * cfg.aux_loss_weight
    return expert_idx, gates, aux


def _expert_ffn(h: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    """SwiGLU expert: h (..., d); weights (..., d, f) / (..., f, d)."""
    a = jnp.einsum("...gd,...df->...gf", h, w_gate)
    b = jnp.einsum("...gd,...df->...gf", h, w_up)
    return jnp.einsum("...gf,...fd->...gd", jax.nn.silu(a) * b, w_down)


def moe_ffn_reference(params: dict, x: Array, cfg: MoEConfig):
    """All-expert compute with gated combine.  x: (T, d)."""
    expert_idx, gates, aux = router_topk(x, params["router"], cfg)
    # (E, T, d): every expert sees every token.
    h = _expert_ffn(x[None].astype(x.dtype),
                    params["w_gate"], params["w_up"], params["w_down"])
    mask = jax.nn.one_hot(expert_idx, cfg.n_experts, dtype=jnp.float32)  # (T,k,E)
    weights = jnp.einsum("tk,tke->et", gates, mask).astype(x.dtype)      # (E,T)
    out = jnp.einsum("et,etd->td", weights, h)
    return out, aux


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    return max(1, math.ceil(tokens * cfg.top_k * cfg.capacity_factor
                            / cfg.n_experts))


def _pack_assignments(x: Array, expert_idx: Array, gates: Array,
                      n_experts: int, capacity: int):
    """Flatten (token, k) assignments and compute per-expert slot positions.

    Returns (token_of_assignment, flat_expert, slot, keep, flat_gate).
    """
    T, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)                                  # (A,)
    flat_g = gates.reshape(-1)
    token_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)      # (A, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0),
                              flat_e[:, None], axis=1)[:, 0] - 1     # (A,)
    keep = pos < capacity
    slot = jnp.where(keep, pos, 0)
    return token_of, flat_e, jax.lax.stop_gradient(slot), keep, flat_g


def moe_ffn_capacity(params: dict, x: Array, cfg: MoEConfig):
    """Single-device capacity-bucketed dispatch.  x: (T, d)."""
    T, d = x.shape
    C = _capacity(T, cfg)
    expert_idx, gates, aux = router_topk(x, params["router"], cfg)
    token_of, flat_e, slot, keep, flat_g = _pack_assignments(
        x, expert_idx, gates, cfg.n_experts, C)
    x_a = x[token_of] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((cfg.n_experts, C, d), x.dtype).at[flat_e, slot].add(x_a)
    out_buf = _expert_ffn(buf, params["w_gate"], params["w_up"], params["w_down"])
    y_a = out_buf[flat_e, slot] * (keep.astype(jnp.float32) * flat_g)[:, None].astype(x.dtype)
    out = jax.ops.segment_sum(y_a, token_of, num_segments=T)
    return out, aux


def moe_ffn_ep(params: dict, x: Array, cfg: MoEConfig, *, axis_name: str):
    """Expert-parallel dispatch inside shard_map.

    Called per shard: x (T_loc, d); expert weights are the shard-local slice
    (E_loc, d, f).  Two all_to_all ops move capacity buckets to/from expert
    owners.
    """
    ep = axis_size(axis_name)
    E, E_loc = cfg.n_experts, cfg.n_experts // ep
    T, d = x.shape
    C = _capacity(T, cfg)  # per-expert capacity contributed by this sender

    expert_idx, gates, aux = router_topk(x, params["router"], cfg)
    token_of, flat_e, slot, keep, flat_g = _pack_assignments(
        x, expert_idx, gates, E, C)
    dest = flat_e // E_loc
    local_e = flat_e % E_loc

    x_a = x[token_of] * keep[:, None].astype(x.dtype)
    send = jnp.zeros((ep, E_loc, C, d), x.dtype).at[dest, local_e, slot].add(x_a)
    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0)
    # (ep_src, E_loc, C, d) -> (E_loc, ep_src * C, d): batched local-expert GEMM.
    h = recv.transpose(1, 0, 2, 3).reshape(E_loc, ep * C, d)
    out = _expert_ffn(h, params["w_gate"], params["w_up"], params["w_down"])
    back = out.reshape(E_loc, ep, C, d).transpose(1, 0, 2, 3)
    ret = jax.lax.all_to_all(back, axis_name, split_axis=0, concat_axis=0)
    y_a = ret[dest, local_e, slot] * (keep.astype(jnp.float32) * flat_g)[:, None].astype(x.dtype)
    y = jax.ops.segment_sum(y_a, token_of, num_segments=T)
    # aux loss is computed on local routing stats; average across shards.
    aux = jax.lax.pmean(aux, axis_name)
    return y, aux


def init_moe_params(rng: Array, d_model: int, cfg: MoEConfig,
                    *, dtype=jnp.float32) -> dict:
    from .common import dense_init
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    f = cfg.d_ff_expert
    return {
        "router": dense_init(k1, (d_model, cfg.n_experts), dtype=dtype),
        "w_gate": dense_init(k2, (cfg.n_experts, d_model, f), fan_in=d_model, dtype=dtype),
        "w_up": dense_init(k3, (cfg.n_experts, d_model, f), fan_in=d_model, dtype=dtype),
        "w_down": dense_init(k4, (cfg.n_experts, f, d_model), fan_in=f, dtype=dtype),
    }
