"""DLRM (Naumov et al., arXiv:1906.00091), MLPerf Criteo-1TB configuration.

13 dense features -> bottom MLP 512-256-128; 26 categorical features ->
embedding tables (row counts below, dim 128) looked up with an
EmbeddingBag built from ``jnp.take`` + ``jax.ops.segment_sum`` (JAX has no
native EmbeddingBag — the brief makes this lookup part of the system);
pairwise-dot feature interaction over the 27 vectors; top MLP
1024-1024-512-256-1.

Distribution (MLPerf hybrid): tables are model-parallel over the ``model``
axis (row-sharded via shard_map so each lookup routes to the owning shard),
MLPs are data-parallel.  The pooled-embedding all-to-all this produces is
the traffic characterized by
:func:`repro.core.tpu_model.dlrm_embedding_exchange`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from ..compat import shard_map
from jax.sharding import PartitionSpec as P

from .common import embed_init, mlp_apply, mlp_init
from ..distributed.sharding import ShardingPolicy

Array = jax.Array

# MLPerc Criteo-1TB per-feature cardinalities (day-0..22 preprocessing,
# capped at 40M rows as in the MLPerf reference implementation).
CRITEO_1TB_VOCABS: tuple[int, ...] = (
    40000000, 39060, 17295, 7424, 20265, 3, 7122, 1543, 63, 40000000,
    3067956, 405282, 10, 2209, 11938, 155, 4, 976, 14, 40000000,
    40000000, 40000000, 590152, 12973, 108, 36)


@dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-mlperf"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 128
    vocab_sizes: tuple[int, ...] = CRITEO_1TB_VOCABS
    bot_mlp: tuple[int, ...] = (512, 256, 128)
    top_mlp: tuple[int, ...] = (1024, 1024, 512, 256, 1)
    interaction: str = "dot"
    multi_hot: int = 1            # lookups per sparse feature (bag size)

    def __post_init__(self):
        assert len(self.vocab_sizes) == self.n_sparse
        assert self.bot_mlp[-1] == self.embed_dim

    def interaction_dim(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2 + self.embed_dim

    def param_count(self) -> int:
        emb = sum(self.vocab_sizes) * self.embed_dim
        bot = sum(a * b + b for a, b in zip((self.n_dense,) + self.bot_mlp[:-1],
                                            self.bot_mlp))
        top_dims = (self.interaction_dim(),) + self.top_mlp
        top = sum(a * b + b for a, b in zip(top_dims[:-1], top_dims[1:]))
        return emb + bot + top


def init_params(cfg: DLRMConfig, rng: Array, *, dtype=jnp.float32) -> dict:
    k_emb, k_bot, k_top = jax.random.split(rng, 3)
    emb_keys = jax.random.split(k_emb, cfg.n_sparse)
    tables = [embed_init(k, (v, cfg.embed_dim), dtype=dtype)
              for k, v in zip(emb_keys, cfg.vocab_sizes)]
    return {
        "tables": tables,
        "bot": mlp_init(k_bot, (cfg.n_dense,) + cfg.bot_mlp, dtype=dtype),
        "top": mlp_init(k_top, (cfg.interaction_dim(),) + cfg.top_mlp, dtype=dtype),
    }


def abstract_params(cfg: DLRMConfig, *, dtype=jnp.float32):
    return jax.eval_shape(lambda k: init_params(cfg, k, dtype=dtype),
                          jax.random.key(0))


def param_pspecs(cfg: DLRMConfig, policy: ShardingPolicy) -> dict:
    """Tables row-sharded over ALL mesh axes where the row count divides
    (the 40M-row Criteo tables shard 512 ways -> ~10 MB/chip instead of
    20 GB replicated); tp-only or replicated as divisibility degrades.
    MLPs are replicated (DP)."""
    tp = policy.tp_axis
    all_axes = tuple(policy.dp_axes) + (tp,)
    n_all = policy.n_devices

    def table_spec(v: int) -> P:
        if v % n_all == 0:
            return P(all_axes, None)
        if v % policy.tp == 0:
            return P(tp, None)
        return P(None, None)

    bot = {"w": [P(None, None)] * len(cfg.bot_mlp),
           "b": [P(None)] * len(cfg.bot_mlp)}
    top = {"w": [P(None, None)] * len(cfg.top_mlp),
           "b": [P(None)] * len(cfg.top_mlp)}
    return {"tables": [table_spec(v) for v in cfg.vocab_sizes],
            "bot": bot, "top": top}


def embedding_bag(table: Array, indices: Array, *, weights: Optional[Array] = None,
                  combine: str = "sum") -> Array:
    """(B, bag) indices -> (B, d) pooled embeddings (take + reduce)."""
    vecs = jnp.take(table, indices, axis=0)          # (B, bag, d)
    if weights is not None:
        vecs = vecs * weights[..., None]
    if combine == "sum":
        return jnp.sum(vecs, axis=1)
    if combine == "mean":
        return jnp.mean(vecs, axis=1)
    raise ValueError(combine)


def dot_interaction(vectors: Array) -> Array:
    """(B, F, d) -> (B, F*(F-1)/2) lower-triangle pairwise dots."""
    b, f, d = vectors.shape
    prods = jnp.einsum("bfd,bgd->bfg", vectors, vectors)
    iu, ju = jnp.tril_indices(f, k=-1)
    return prods[:, iu, ju]


def vocab_parallel_embeddings(cfg: DLRMConfig, tables: Sequence[Array],
                              sparse: Array, policy: ShardingPolicy) -> Array:
    """Row-sharded embedding-bag: masked local lookup + psum over the table
    shards (Megatron vocab-parallel pattern).  Big tables shard over ALL
    mesh axes (the lookup batch is replicated during the embedding stage);
    non-divisible tables degrade to tp-only or replicated.  Returns
    (B, n_sparse, d), replicated.

    Traffic: one all-reduce of (B, n_sharded_tables, d) per step — the DLRM
    analogue of the paper's loadvert terms; modeled by
    :func:`repro.core.tpu_model.dlrm_embedding_exchange` (a2a variant is the
    §Perf optimization).
    """
    tp, tp_size = policy.tp_axis, policy.tp
    all_axes = tuple(policy.dp_axes) + (tp,)
    n_all = policy.n_devices

    def shards_of(v: int) -> int:
        if v % n_all == 0:
            return n_all
        if v % tp_size == 0:
            return tp_size
        return 1

    specs = []
    for v in cfg.vocab_sizes:
        s = shards_of(v)
        specs.append(P(all_axes, None) if s == n_all
                     else P(tp, None) if s == tp_size else P(None, None))

    def local(tables_loc, sparse_rep):
        outs = [None] * cfg.n_sparse
        r_all = jnp.zeros((), jnp.int32)
        for a in all_axes:
            r_all = r_all * policy.mesh.shape[a] + jax.lax.axis_index(a)
        r_tp = jax.lax.axis_index(tp)
        partials_all, idx_all = [], []
        partials_tp, idx_tp = [], []
        for t, (tab, v) in enumerate(zip(tables_loc, cfg.vocab_sizes)):
            idx = sparse_rep[:, t, :]
            s = shards_of(v)
            if s == 1:
                outs[t] = jnp.sum(jnp.take(tab, idx, axis=0), axis=1)
                continue
            rows = v // s
            r = r_all if s == n_all else r_tp
            loc = idx - r * rows
            ok = (loc >= 0) & (loc < rows)
            vecs = jnp.take(tab, jnp.clip(loc, 0, rows - 1), axis=0)
            pooled = jnp.sum(vecs * ok[..., None], axis=1)
            if s == n_all:
                partials_all.append(pooled)
                idx_all.append(t)
            else:
                partials_tp.append(pooled)
                idx_tp.append(t)
        if partials_all:
            red = jax.lax.psum(jnp.stack(partials_all, 1), all_axes)
            for j, t in enumerate(idx_all):
                outs[t] = red[:, j]
        if partials_tp:
            red = jax.lax.psum(jnp.stack(partials_tp, 1), tp)
            # still differs across dp groups? no: sparse is replicated, and
            # tp-sharded tables psum over tp give identical values on every
            # dp rank.
            for j, t in enumerate(idx_tp):
                outs[t] = red[:, j]
        return jnp.stack(outs, axis=1)

    return shard_map(
        local, mesh=policy.mesh,
        in_specs=(specs, P(None, None, None)),   # batch replicated for lookup
        out_specs=P(None, None, None),
        check_vma=False,
    )(list(tables), sparse)


def forward(cfg: DLRMConfig, params: dict, batch: dict,
            *, policy: Optional[ShardingPolicy] = None) -> Array:
    """batch: dense (B, 13) float; sparse (B, 26, multi_hot) int32 -> logits (B,)."""
    dense, sparse = batch["dense"], batch["sparse"]
    b = dense.shape[0]
    bot = mlp_apply(params["bot"], dense, final_act=True)    # (B, d)
    if policy is not None:
        emb = vocab_parallel_embeddings(cfg, params["tables"], sparse, policy)
    else:
        pooled = []
        for t, table in enumerate(params["tables"]):
            pooled.append(embedding_bag(table, sparse[:, t, :]))
        emb = jnp.stack(pooled, axis=1)                      # (B, 26, d)
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, 27, d)
    if policy is not None:
        # Re-shard the batch over ALL axes for the interaction + top MLP so
        # the dense compute is data-parallel across the whole mesh.
        all_axes = tuple(policy.dp_axes) + (policy.tp_axis,)
        feats = policy.constrain(feats, P(all_axes, None, None))
        bot = policy.constrain(bot, P(all_axes, None))
    inter = dot_interaction(feats)
    top_in = jnp.concatenate([bot, inter], axis=-1)
    return mlp_apply(params["top"], top_in)[:, 0]


def loss_fn(cfg: DLRMConfig, params: dict, batch: dict,
            *, policy: Optional[ShardingPolicy] = None) -> tuple[Array, dict]:
    logits = forward(cfg, params, batch, policy=policy)
    labels = batch["labels"].astype(jnp.float32)
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    loss = -jnp.mean(labels * logp + (1 - labels) * lognp)
    acc = jnp.mean((logits > 0) == (labels > 0.5))
    return loss, {"loss": loss, "acc": acc}


def score_candidates(cfg: DLRMConfig, params: dict, query: dict,
                     candidates: Array) -> Array:
    """Retrieval scoring: one query's user vector dotted against (Nc, d)
    candidate item embeddings — a batched matvec, not a loop."""
    bot = mlp_apply(params["bot"], query["dense"], final_act=True)  # (1, d)
    return (candidates @ bot[0]).astype(jnp.float32)                # (Nc,)
