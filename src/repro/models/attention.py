"""Attention primitives: chunked (flash-style) training attention and
sequence-sharded decode attention.

Training/prefill attention is computed as a ``lax.scan`` over query chunks so
the materialized score block is ``(B, H, q_chunk, S)`` rather than
``(B, H, S, S)`` — the HLO-level analogue of the Pallas flash kernel in
:mod:`repro.kernels.flash_attention` (which replaces this path on real TPU
hardware via ``repro.kernels.ops``).

Decode attention supports a KV cache sequence-sharded over the ``model`` mesh
axis (flash-decoding style): each shard computes a partial softmax over its
chunk and the partials combine with a logsumexp reduction — a psum of
``(B, H, d+2)`` instead of an all-gather of the cache.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import softcap

Array = jax.Array

_NEG_INF = -1e30


def _mask_value(scores_dtype):
    return jnp.asarray(_NEG_INF, scores_dtype)


def repeat_kv(x: Array, n_rep: int) -> Array:
    """(B, S, Hk, D) -> (B, S, Hk * n_rep, D) for GQA."""
    if n_rep == 1:
        return x
    b, s, hk, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, hk, n_rep, d)).reshape(
        b, s, hk * n_rep, d)


def chunked_causal_attention(
    q: Array,                 # (B, Sq, H, D)
    k: Array,                 # (B, Skv, Hk, D)
    v: Array,                 # (B, Skv, Hk, D)
    *,
    window: Optional[int] = None,      # sliding window; None = global causal
    attn_softcap: Optional[float] = None,
    q_chunk: int = 1024,
    q_offset: Array | int = 0,         # global position of q row 0 (context parallelism)
    shard_divisor: int = 1,            # how many ways B*H is sharded (budget calc)
    score_budget_bytes: int = 1 << 29, # cap per-device fp32 score block (512 MiB)
) -> Array:
    """Causal (optionally sliding-window) attention, scanned over Q chunks.

    The chunk size adapts so the per-device fp32 score block
    (B*H/shard_divisor, q_chunk, S_kv) stays under ``score_budget_bytes`` —
    the dry-run memory gate found 7.5 GB score blocks at 32k context
    otherwise (EXPERIMENTS.md §Dry-run iteration 1)."""
    b, s, h, d = q.shape
    s_kv = k.shape[1]
    hk = k.shape[2]
    n_rep = h // hk
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    scale = d ** -0.5

    q_chunk = min(q_chunk, s)
    per_row_bytes = max(b * h // max(shard_divisor, 1), 1) * s_kv * 4
    while q_chunk > 16 and q_chunk * per_row_bytes > score_budget_bytes \
            and s % (q_chunk // 2) == 0:
        q_chunk //= 2
    if s % q_chunk:
        q_chunk = s  # fallback: irregular sizes take the single-block path
    n_chunks = s // q_chunk

    kt = k.transpose(0, 2, 3, 1)      # (B, H, D, Skv)
    vt = v.transpose(0, 2, 1, 3)      # (B, H, Skv, D)
    qs = q.transpose(0, 2, 1, 3).reshape(b, h, n_chunks, q_chunk, d)
    qs = qs.transpose(2, 0, 1, 3, 4)  # (n_chunks, B, H, qc, D)

    kv_pos = jnp.arange(s_kv, dtype=jnp.int32)

    def one_chunk(ci: Array, qc: Array) -> Array:
        q_pos = q_offset + ci * q_chunk + jnp.arange(q_chunk, dtype=jnp.int32)
        scores = jnp.einsum("bhqd,bhdk->bhqk", qc.astype(jnp.float32) * scale,
                            kt.astype(jnp.float32))
        if attn_softcap is not None:
            scores = softcap(scores, attn_softcap)
        causal = kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            causal &= (q_pos[:, None] - kv_pos[None, :]) < window
        scores = jnp.where(causal[None, None], scores, _mask_value(scores.dtype))
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", probs, vt.astype(jnp.float32))

    # Per-chunk remat: without it the backward pass stores every chunk's
    # (B, H, qc, S_kv) fp32 score block stacked — 14 GB/layer at arctic's
    # train_4k shape (dry-run audit, EXPERIMENTS.md §Dry-run iteration 2).
    chunk_fn = jax.checkpoint(one_chunk,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if n_chunks == 1:
        out = chunk_fn(jnp.asarray(0, jnp.int32), qs[0])[None]
    else:
        out = jax.lax.map(lambda args: chunk_fn(*args),
                          (jnp.arange(n_chunks, dtype=jnp.int32), qs))
    # (n_chunks, B, H, qc, D) -> (B, S, H, D)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, s, h, d)
    return out.astype(q.dtype)


def decode_attention(
    q: Array,            # (B, 1, H, D)
    k_cache: Array,      # (B, S, Hk, D)
    v_cache: Array,      # (B, S, Hk, D)
    *,
    length_mask: Array,  # (B, S) bool — True where the cache slot is valid
    attn_softcap: Optional[float] = None,
) -> Array:
    """Single-token attention over a (local) KV cache."""
    b, _, h, d = q.shape
    hk = k_cache.shape[2]
    k = repeat_kv(k_cache, h // hk).astype(jnp.float32)
    v = repeat_kv(v_cache, h // hk).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32) * d ** -0.5, k)
    if attn_softcap is not None:
        scores = softcap(scores, attn_softcap)
    scores = jnp.where(length_mask[:, None, None, :], scores,
                       _mask_value(scores.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    return out.astype(q.dtype)


def decode_attention_partial(
    q: Array, k_shard: Array, v_shard: Array, *,
    length_mask: Array, attn_softcap: Optional[float] = None,
) -> tuple[Array, Array, Array]:
    """Partial-softmax statistics over one sequence shard of the cache.

    Returns (weighted_values (B,1,H,D), max (B,H,1), sumexp (B,H,1)) so that
    shards combine associatively — the flash-decoding split-K scheme.
    """
    b, _, h, d = q.shape
    hk = k_shard.shape[2]
    k = repeat_kv(k_shard, h // hk).astype(jnp.float32)
    v = repeat_kv(v_shard, h // hk).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32) * d ** -0.5, k)
    if attn_softcap is not None:
        scores = softcap(scores, attn_softcap)
    scores = jnp.where(length_mask[:, None, None, :], scores,
                       _mask_value(scores.dtype))
    m = jnp.max(scores, axis=-1)                        # (B,H,1)
    e = jnp.exp(scores - m[..., None])
    z = jnp.sum(e, axis=-1)                             # (B,H,1)
    wv = jnp.einsum("bhqs,bshd->bqhd", e, v)            # un-normalized
    return wv, m, z


def combine_decode_partials(wv: Array, m: Array, z: Array, axis_name: str) -> Array:
    """psum-combine flash-decoding partials across ``axis_name`` shards."""
    g_max = jax.lax.pmax(m, axis_name)                  # (B,H,1)
    corr = jnp.exp(m - g_max)                           # (B,H,1)
    wv = wv * corr.transpose(0, 2, 1)[..., None]        # (B,1,H,D)
    z = z * corr
    wv = jax.lax.psum(wv, axis_name)
    z = jax.lax.psum(z, axis_name)
    return wv / z.transpose(0, 2, 1)[..., None]
