"""Shared functional building blocks for the model zoo.

Everything is pure-functional: parameters are pytrees of ``jnp`` arrays,
built by ``init_*`` helpers and consumed by stateless apply functions.  No
framework dependency (flax/haiku are not installed) — the structure mirrors
what a production JAX stack keeps under its own control anyway: explicit
parameter trees shard cleanly under pjit and checkpoint trivially.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "Initializer", "dense_init", "he_init", "embed_init",
    "rms_norm", "layer_norm", "mlp_init", "mlp_apply",
    "rope_freqs", "apply_rope", "softcap",
    "segment_softmax", "cross_entropy_loss", "count_params",
]

Array = jax.Array


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(rng: Array, shape: Sequence[int], *, fan_in: int | None = None,
               dtype=jnp.float32) -> Array:
    """LeCun-normal: the default for matmul weights."""
    fan = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(max(fan, 1))
    return (jax.random.normal(rng, tuple(shape)) * std).astype(dtype)


def he_init(rng: Array, shape: Sequence[int], *, fan_in: int | None = None,
            dtype=jnp.float32) -> Array:
    fan = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    std = math.sqrt(2.0 / max(fan, 1))
    return (jax.random.normal(rng, tuple(shape)) * std).astype(dtype)


def embed_init(rng: Array, shape: Sequence[int], *, dtype=jnp.float32) -> Array:
    return (jax.random.normal(rng, tuple(shape)) * 0.02).astype(dtype)


Initializer = dense_init


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, *, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return y.astype(dtype)


def layer_norm(x: Array, scale: Array, bias: Array, *, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(dtype)


def softcap(x: Array, cap: float) -> Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Generic MLP (used by GNN/DLRM substrates)
# ---------------------------------------------------------------------------

def mlp_init(rng: Array, dims: Sequence[int], *, layer_norm_out: bool = False,
             dtype=jnp.float32) -> dict:
    keys = jax.random.split(rng, len(dims) - 1)
    params = {
        "w": [he_init(k, (a, b), dtype=dtype) for k, a, b in zip(keys, dims[:-1], dims[1:])],
        "b": [jnp.zeros((b,), dtype) for b in dims[1:]],
    }
    if layer_norm_out:
        params["ln_scale"] = jnp.ones((dims[-1],), dtype)
        params["ln_bias"] = jnp.zeros((dims[-1],), dtype)
    return params


def mlp_apply(params: dict, x: Array, *, act=jax.nn.relu,
              final_act: bool = False) -> Array:
    n = len(params["w"])
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        x = x @ w + b
        if i < n - 1 or final_act:
            x = act(x)
    if "ln_scale" in params:
        x = layer_norm(x, params["ln_scale"], params["ln_bias"])
    return x


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, *, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: Array, positions: Array, freqs: Array) -> Array:
    """x: (..., seq, heads, d_head); positions: (..., seq)."""
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Segment ops / losses
# ---------------------------------------------------------------------------

def segment_softmax(logits: Array, segment_ids: Array, num_segments: int) -> Array:
    """Numerically-stable softmax over variable-size segments (edge softmax)."""
    seg_max = jax.ops.segment_max(logits, segment_ids, num_segments=num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = logits - seg_max[segment_ids]
    expd = jnp.exp(shifted)
    seg_sum = jax.ops.segment_sum(expd, segment_ids, num_segments=num_segments)
    return expd / (seg_sum[segment_ids] + 1e-9)


def cross_entropy_loss(logits: Array, labels: Array, *, mask: Array | None = None) -> Array:
    """Token-level CE in fp32; shards cleanly with vocab-partitioned logits
    (XLA turns the reductions into psums over the model axis)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "size"))
