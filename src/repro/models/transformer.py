"""Decoder-only transformer covering the five assigned LM architectures.

One config class expresses dense (granite, smollm), alternating local/global
with soft-caps (gemma2), and MoE with optional dense residual branch
(qwen3-moe, arctic).  Layers run as a ``lax.scan`` over *pattern groups* —
gemma2's (local, global) alternation becomes a 2-entry pattern whose KV
caches are sized per entry (the local entries keep a ring buffer of
``window`` slots, the global entries the full sequence) — so the compiled
HLO stays one-layer-sized and 500k-token decode does not over-allocate.

Distribution (via :class:`repro.distributed.ShardingPolicy`):
* batch over the dp axes; residual stream sequence-sharded over ``model``
  (Megatron-SP) when the policy enables it;
* attention TP over heads when ``n_heads % tp == 0``, otherwise context
  parallelism (shard_map over ``model``: q stays sequence-sharded, kv is
  all-gathered — the layout used by gemma2's 8-head / smollm's 9-head
  configs on a 16-wide model axis);
* MoE experts sharded over ``model`` (EP) with capacity-bucketed all-to-all
  dispatch (:func:`repro.models.moe.moe_ffn_ep`);
* decode KV caches sequence-sharded over configurable axes with
  flash-decoding partial-softmax combination.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from ..compat import shard_map
from jax.sharding import PartitionSpec as P

from . import attention as attn_lib
from . import moe as moe_lib
from .common import (apply_rope, cross_entropy_loss, dense_init, embed_init,
                     rms_norm, rope_freqs, softcap)
from .moe import MoEConfig
from ..distributed.sharding import ShardingPolicy

Array = jax.Array


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    moe: Optional[MoEConfig] = None
    # Repeating per-layer window pattern; None entries are global-causal.
    # gemma2: (4096, None).  Length must divide n_layers.
    window_pattern: tuple[Optional[int], ...] = (None,)
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    remat: str = "full"              # "none" | "full" | "dots"
    q_chunk: int = 1024
    tie_embeddings: bool = True

    def __post_init__(self):
        assert self.n_layers % len(self.window_pattern) == 0, (
            self.name, self.n_layers, self.window_pattern)
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.window_pattern)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        d, H, Hk, dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        attn = d * (H * dh) + 2 * d * (Hk * dh) + (H * dh) * d
        per_layer = attn + 2 * d  # + norms
        if self.moe is not None:
            m = self.moe
            per_layer += d * m.n_experts + 3 * m.n_experts * d * m.d_ff_expert
            if m.dense_residual_d_ff:
                per_layer += 3 * d * m.dense_residual_d_ff
        else:
            per_layer += 3 * d * self.d_ff
        total = self.n_layers * per_layer + self.vocab * d + d
        if not self.tie_embeddings:
            total += self.vocab * d
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        expert_all = 3 * m.n_experts * d * m.d_ff_expert
        expert_act = 3 * m.top_k * d * m.d_ff_expert
        return self.param_count() - self.n_layers * (expert_all - expert_act)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: TransformerConfig, rng: Array, *, dtype=jnp.float32) -> dict:
    d, H, Hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = cfg.n_groups
    keys = jax.random.split(rng, 2 + len(cfg.window_pattern))

    def block_params(key: Array) -> dict:
        ks = jax.random.split(key, 8)
        blk = {
            "ln1": jnp.zeros((G, d), dtype),
            "ln2": jnp.zeros((G, d), dtype),
            "wq": dense_init(ks[0], (G, d, H * dh), fan_in=d, dtype=dtype),
            "wk": dense_init(ks[1], (G, d, Hk * dh), fan_in=d, dtype=dtype),
            "wv": dense_init(ks[2], (G, d, Hk * dh), fan_in=d, dtype=dtype),
            "wo": dense_init(ks[3], (G, H * dh, d), fan_in=H * dh, dtype=dtype),
        }
        if cfg.moe is not None:
            m = cfg.moe
            moe_keys = jax.random.split(ks[4], G)
            stacked = jax.vmap(lambda k: moe_lib.init_moe_params(
                k, d, m, dtype=dtype))(moe_keys)
            blk["moe"] = stacked
            if m.dense_residual_d_ff:
                f = m.dense_residual_d_ff
                blk["res_gate"] = dense_init(ks[5], (G, d, f), fan_in=d, dtype=dtype)
                blk["res_up"] = dense_init(ks[6], (G, d, f), fan_in=d, dtype=dtype)
                blk["res_down"] = dense_init(ks[7], (G, f, d), fan_in=f, dtype=dtype)
        else:
            blk["w_gate"] = dense_init(ks[5], (G, d, cfg.d_ff), fan_in=d, dtype=dtype)
            blk["w_up"] = dense_init(ks[6], (G, d, cfg.d_ff), fan_in=d, dtype=dtype)
            blk["w_down"] = dense_init(ks[7], (G, cfg.d_ff, d), fan_in=cfg.d_ff, dtype=dtype)
        return blk

    params = {
        "embed": embed_init(keys[0], (cfg.vocab, d), dtype=dtype),
        "final_norm": jnp.zeros((d,), dtype),
        "blocks": [block_params(k) for k in keys[2:]],
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], (d, cfg.vocab), fan_in=d, dtype=dtype)
    return params


def abstract_params(cfg: TransformerConfig, *, dtype=jnp.float32):
    """Parameter tree as ShapeDtypeStructs — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype=dtype), jax.random.key(0))


def param_pspecs(cfg: TransformerConfig, policy: ShardingPolicy) -> dict:
    """PartitionSpec tree matching init_params' structure."""
    tp = policy.tp_axis
    tp_heads = cfg.n_heads % policy.tp == 0 and cfg.n_kv_heads % policy.tp == 0

    def block_spec() -> dict:
        hspec = tp if tp_heads else None
        blk = {
            "ln1": P(None, None), "ln2": P(None, None),
            "wq": P(None, None, hspec),
            "wk": P(None, None, hspec),
            "wv": P(None, None, hspec),
            "wo": P(None, hspec, None),
        }
        if cfg.moe is not None:
            blk["moe"] = {
                "router": P(None, None, None),
                "w_gate": P(None, tp, None, None),
                "w_up": P(None, tp, None, None),
                "w_down": P(None, tp, None, None),
            }
            if cfg.moe.dense_residual_d_ff:
                blk["res_gate"] = P(None, None, tp)
                blk["res_up"] = P(None, None, tp)
                blk["res_down"] = P(None, tp, None)
        else:
            blk["w_gate"] = P(None, None, tp)
            blk["w_up"] = P(None, None, tp)
            blk["w_down"] = P(None, tp, None)
        return blk

    specs = {
        "embed": P(tp, None) if cfg.vocab % policy.tp == 0 else P(None, None),
        "final_norm": P(None),
        "blocks": [block_spec() for _ in cfg.window_pattern],
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(None, tp) if cfg.vocab % policy.tp == 0 else P(None, None)
    return specs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _attention_block(cfg: TransformerConfig, blk: dict, x: Array,
                     window: Optional[int], policy: Optional[ShardingPolicy],
                     freqs: Array) -> Array:
    b, s, d = x.shape
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rms_norm(x, blk["ln1"])
    tp_heads = (policy is None or
                (H % policy.tp == 0 and Hk % policy.tp == 0))

    q = (h @ blk["wq"]).reshape(b, s, H, dh)
    k = (h @ blk["wk"]).reshape(b, s, Hk, dh)
    v = (h @ blk["wv"]).reshape(b, s, Hk, dh)
    positions = jnp.arange(s, dtype=jnp.int32)[None]
    q = apply_rope(q, positions, freqs)
    k = apply_rope(k, positions, freqs)

    if policy is not None and tp_heads:
        dp, tp = policy.dp_spec, policy.tp_axis
        q = policy.constrain(q, P(dp, None, tp, None))
        k = policy.constrain(k, P(dp, None, tp, None))
        v = policy.constrain(v, P(dp, None, tp, None))
        out = attn_lib.chunked_causal_attention(
            q, k, v, window=window, attn_softcap=cfg.attn_softcap,
            q_chunk=cfg.q_chunk, shard_divisor=policy.n_devices)
    elif policy is not None:
        out = _context_parallel_attention(cfg, policy, q, k, v, window)
    else:
        out = attn_lib.chunked_causal_attention(
            q, k, v, window=window, attn_softcap=cfg.attn_softcap,
            q_chunk=cfg.q_chunk)

    out = out.reshape(b, s, H * dh) @ blk["wo"]
    if policy is not None:
        out = policy.constrain(out, policy.act_spec())
    return x + out


def _context_parallel_attention(cfg, policy, q, k, v, window):
    """shard_map context parallelism: q sequence-sharded, kv all-gathered.

    Used when the head count does not divide the model axis (gemma2: 8 heads,
    smollm: 9 heads on tp=16).
    """
    tp_axis = policy.tp_axis
    dp = policy.dp_spec
    mesh = policy.mesh
    s = q.shape[1]
    s_loc = s // policy.tp

    def local(qs, ks, vs):
        r = jax.lax.axis_index(tp_axis)
        kg = jax.lax.all_gather(ks, tp_axis, axis=1, tiled=True)
        vg = jax.lax.all_gather(vs, tp_axis, axis=1, tiled=True)
        return attn_lib.chunked_causal_attention(
            qs, kg, vg, window=window, attn_softcap=cfg.attn_softcap,
            q_chunk=min(cfg.q_chunk, s_loc), q_offset=r * s_loc)

    spec_q = P(dp, tp_axis, None, None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(spec_q, spec_q, spec_q),
        out_specs=spec_q,
        check_vma=False,
    )(q, k, v)


def _ffn_block(cfg: TransformerConfig, blk: dict, x: Array,
               policy: Optional[ShardingPolicy]) -> tuple[Array, Array]:
    b, s, d = x.shape
    h = rms_norm(x, blk["ln2"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is None:
        gate = h @ blk["w_gate"]
        up = h @ blk["w_up"]
        out = (jax.nn.silu(gate) * up) @ blk["w_down"]
    else:
        flat = h.reshape(b * s, d)
        if policy is not None and cfg.moe.n_experts % policy.tp == 0:
            out, aux = _moe_ep_sharded(cfg, policy, blk["moe"], h)
        else:
            out2, aux = moe_lib.moe_ffn_capacity(blk["moe"], flat, cfg.moe)
            out = out2.reshape(b, s, d)
        if cfg.moe.dense_residual_d_ff:
            res = (jax.nn.silu(h @ blk["res_gate"]) * (h @ blk["res_up"])) @ blk["res_down"]
            out = out + res
    if policy is not None:
        out = policy.constrain(out, policy.act_spec())
    return x + out, aux


def _moe_ep_sharded(cfg, policy, moe_params, h):
    """Sequence-shard tokens over the model axis, run EP all-to-all MoE."""
    tp_axis = policy.tp_axis
    dp = policy.dp_spec
    b, s, d = h.shape

    def local(params_loc, h_loc):
        bl, sl, _ = h_loc.shape
        flat = h_loc.reshape(bl * sl, d)
        y, aux = moe_lib.moe_ffn_ep(params_loc, flat, cfg.moe, axis_name=tp_axis)
        # Replicate the aux scalar across every mesh axis so the P() out-spec
        # is sound (routing stats differ per data shard otherwise).
        aux = jax.lax.pmean(aux, policy.mesh.axis_names)
        return y.reshape(bl, sl, d), aux

    pspecs = {
        "router": P(None, None),
        "w_gate": P(tp_axis, None, None),
        "w_up": P(tp_axis, None, None),
        "w_down": P(tp_axis, None, None),
    }
    out, aux = shard_map(
        local, mesh=policy.mesh,
        in_specs=(pspecs, P(dp, tp_axis, None)),
        out_specs=(P(dp, tp_axis, None), P()),
        check_vma=False,
    )(moe_params, h)
    return out, aux


def _decoder_group(cfg: TransformerConfig, policy: Optional[ShardingPolicy],
                   freqs: Array, x: Array, group_slices: Sequence[dict]):
    """Apply one pattern group: each entry with its own window config."""
    aux_total = jnp.zeros((), jnp.float32)
    for blk, window in zip(group_slices, cfg.window_pattern):
        x = _attention_block(cfg, blk, x, window, policy, freqs)
        x, aux = _ffn_block(cfg, blk, x, policy)
        aux_total = aux_total + aux
    return x, aux_total


# ---------------------------------------------------------------------------
# Forward / loss / train step
# ---------------------------------------------------------------------------

def forward_hidden(cfg: TransformerConfig, params: dict, tokens: Array,
                   *, policy: Optional[ShardingPolicy] = None) -> tuple[Array, Array]:
    """tokens (B, S) -> (final normed hidden (B, S, d), aux_loss)."""
    cdt = cfg.compute_dtype
    embed = params["embed"].astype(cdt)
    x = embed[tokens]
    if policy is not None:
        x = policy.constrain(x, policy.act_spec())
    freqs = rope_freqs(cfg.d_head, theta=cfg.rope_theta)

    blocks = [jax.tree_util.tree_map(lambda a: a.astype(cdt) if a.dtype in
                                     (jnp.float32, jnp.bfloat16) else a, b)
              for b in params["blocks"]]

    def body(carry, slices):
        x, aux = carry
        fn = partial(_decoder_group, cfg, policy, freqs)
        if cfg.remat == "full":
            fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
        elif cfg.remat == "dots":
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        x, a = fn(x, slices)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               xs=tuple(blocks))
    x = rms_norm(x, params["final_norm"].astype(cdt))
    return x, aux


def _unembed_weight(cfg: TransformerConfig, params: dict) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return w.astype(cfg.compute_dtype)


def forward(cfg: TransformerConfig, params: dict, tokens: Array,
            *, policy: Optional[ShardingPolicy] = None) -> tuple[Array, Array]:
    """tokens (B, S) int32 -> (logits (B, S, V), aux_loss)."""
    x, aux = forward_hidden(cfg, params, tokens, policy=policy)
    unembed = _unembed_weight(cfg, params)
    if policy is not None and cfg.vocab % policy.tp == 0:
        # vocab-parallel logits: gather the sequence, shard the vocab.
        x = policy.constrain(x, P(policy.dp_spec, None, None))
    logits = x @ unembed
    if policy is not None and cfg.vocab % policy.tp == 0:
        logits = policy.constrain(logits, P(policy.dp_spec, None, policy.tp_axis))
    if cfg.final_softcap is not None:
        logits = softcap(logits, cfg.final_softcap)
    return logits, aux


# (B*S*V) elements above which the loss switches to sequence-chunked CE —
# the (B, S, V) logits tensor (and its cotangent) would otherwise dominate
# HBM at 32k+ vocab (the gemma2 dry-run found 19 GB of loss temps).
_CE_CHUNK_THRESHOLD = 1 << 24
_CE_CHUNK = 256


def _ce_token_nll(cfg, x_chunk, unembed, labels_chunk, policy):
    """(B, c, d) -> summed nll + count over one sequence chunk, fp32."""
    logits = x_chunk @ unembed
    if policy is not None and cfg.vocab % policy.tp == 0:
        logits = policy.constrain(logits, P(policy.dp_spec, None, policy.tp_axis))
    if cfg.final_softcap is not None:
        logits = softcap(logits, cfg.final_softcap)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_chunk[..., None], axis=-1)[..., 0]
    return jnp.sum(logz - gold)


def loss_fn(cfg: TransformerConfig, params: dict, batch: dict,
            *, policy: Optional[ShardingPolicy] = None) -> tuple[Array, dict]:
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    x, aux = forward_hidden(cfg, params, tokens, policy=policy)
    unembed = _unembed_weight(cfg, params)

    if b * s * cfg.vocab <= _CE_CHUNK_THRESHOLD or s % _CE_CHUNK:
        if policy is not None and cfg.vocab % policy.tp == 0:
            x = policy.constrain(x, P(policy.dp_spec, None, None))
        logits = x @ unembed
        if cfg.final_softcap is not None:
            logits = softcap(logits, cfg.final_softcap)
        ce = cross_entropy_loss(logits, labels, mask=batch.get("mask"))
    else:
        # Sequence-chunked CE: logits for one chunk at a time, rematerialized
        # in the backward pass.
        n_chunks = s // _CE_CHUNK
        if policy is not None:
            x = policy.constrain(x, P(policy.dp_spec, None, None))
        xs = x.reshape(b, n_chunks, _CE_CHUNK, cfg.d_model).transpose(1, 0, 2, 3)
        ls = labels.reshape(b, n_chunks, _CE_CHUNK).transpose(1, 0, 2)
        chunk_fn = jax.checkpoint(
            lambda xc, lc: _ce_token_nll(cfg, xc, unembed, lc, policy),
            policy=jax.checkpoint_policies.nothing_saveable)

        def body(tot, xl):
            xc, lc = xl
            return tot + chunk_fn(xc, lc), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
        ce = total / (b * s)

    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


def make_train_step(cfg: TransformerConfig, optimizer,
                    *, policy: Optional[ShardingPolicy] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, policy=policy), has_aux=True)
        (loss, metrics), grads = grad_fn(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        from ..optim.optimizers import apply_updates
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: TransformerConfig, *,
                      policy: Optional[ShardingPolicy] = None,
                      max_seq: Optional[int] = None):
    """Returns prefill(params, tokens (B,S)) -> (last_logits (B,V), cache).

    One inference prefill: the forward pass plus materialization of the KV
    cache (ring-local entries store the last ``window`` positions in ring
    layout, so decode can continue at pos = S).  ``max_seq`` sizes the cache
    for continued decoding (defaults to the prompt length).
    """

    def prefill(params, tokens):
        cdt = cfg.compute_dtype
        b, s = tokens.shape
        H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        x = params["embed"].astype(cdt)[tokens]
        if policy is not None:
            x = policy.constrain(x, policy.act_spec())
        freqs = rope_freqs(cfg.d_head, theta=cfg.rope_theta)
        blocks = [jax.tree_util.tree_map(lambda a: a.astype(cdt), blk)
                  for blk in params["blocks"]]

        def group_body(x, slices):
            kvs = []
            for blk, window in zip(slices, cfg.window_pattern):
                h = rms_norm(x, blk["ln1"])
                q = (h @ blk["wq"]).reshape(b, s, H, dh)
                k = (h @ blk["wk"]).reshape(b, s, Hk, dh)
                v = (h @ blk["wv"]).reshape(b, s, Hk, dh)
                positions = jnp.arange(s, dtype=jnp.int32)[None]
                q = apply_rope(q, positions, freqs)
                k = apply_rope(k, positions, freqs)
                if policy is not None and (H % policy.tp == 0
                                           and Hk % policy.tp == 0):
                    dp, tp = policy.dp_spec, policy.tp_axis
                    q = policy.constrain(q, P(dp, None, tp, None))
                    k = policy.constrain(k, P(dp, None, tp, None))
                    v = policy.constrain(v, P(dp, None, tp, None))
                    out = attn_lib.chunked_causal_attention(
                        q, k, v, window=window, attn_softcap=cfg.attn_softcap,
                        q_chunk=cfg.q_chunk)
                elif policy is not None:
                    out = _context_parallel_attention(cfg, policy, q, k, v, window)
                else:
                    out = attn_lib.chunked_causal_attention(
                        q, k, v, window=window, attn_softcap=cfg.attn_softcap,
                        q_chunk=cfg.q_chunk)
                x = x + out.reshape(b, s, H * dh) @ blk["wo"]
                if policy is not None:
                    x = policy.constrain(x, policy.act_spec())
                x, _ = _ffn_block(cfg, blk, x, policy)
                # Cache entry: full sequence, or the last `window` slots in
                # ring layout so decode continues seamlessly at pos = s.
                target = max_seq or s
                s_entry = min(window, target) if window is not None else target
                if window is not None and s > s_entry:
                    kc = jnp.roll(k[:, s - s_entry:],
                                  shift=(s - s_entry) % s_entry, axis=1)
                    vc = jnp.roll(v[:, s - s_entry:],
                                  shift=(s - s_entry) % s_entry, axis=1)
                else:
                    pad = [(0, 0), (0, s_entry - s), (0, 0), (0, 0)]
                    kc = jnp.pad(k, pad) if s_entry > s else k
                    vc = jnp.pad(v, pad) if s_entry > s else v
                kvs.extend([kc, vc])
            return x, tuple(kvs)

        x, kv_stacks = jax.lax.scan(group_body, x, xs=tuple(blocks))
        x = rms_norm(x[:, -1:], params["final_norm"].astype(cdt))
        unembed = (params["embed"].T if cfg.tie_embeddings
                   else params["unembed"]).astype(cdt)
        logits = (x @ unembed)[:, 0]
        if cfg.final_softcap is not None:
            logits = softcap(logits, cfg.final_softcap)
        cache = {}
        for i in range(len(cfg.window_pattern)):
            cache[f"k{i}"] = kv_stacks[2 * i]
            cache[f"v{i}"] = kv_stacks[2 * i + 1]
        return logits, cache

    return prefill


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DecodePolicy:
    """How the KV cache is laid out on the mesh.

    cache_seq_axes: mesh axes sharding the cache sequence dimension.  decode
    shapes use ("model",); the 500k single-sequence shape uses
    ("data", "model") so 256 chips each hold 2k slots.
    batch_axes: axes sharding the decode batch (() when batch == 1).
    """

    cache_seq_axes: tuple[str, ...] = ("model",)
    batch_axes: tuple[str, ...] = ("data",)


def cache_shapes(cfg: TransformerConfig, batch: int, max_seq: int) -> list[tuple]:
    """Per-pattern-entry cache shapes (G, B, S_entry, Hk, dh)."""
    out = []
    for window in cfg.window_pattern:
        s_entry = min(window, max_seq) if window is not None else max_seq
        out.append((cfg.n_groups, batch, s_entry, cfg.n_kv_heads, cfg.d_head))
    return out


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               *, dtype=None) -> dict:
    dtype = dtype or cfg.compute_dtype
    caches = {}
    for i, shape in enumerate(cache_shapes(cfg, batch, max_seq)):
        caches[f"k{i}"] = jnp.zeros(shape, dtype)
        caches[f"v{i}"] = jnp.zeros(shape, dtype)
    return caches


def abstract_cache(cfg: TransformerConfig, batch: int, max_seq: int,
                   *, dtype=None) -> dict:
    dtype = dtype or cfg.compute_dtype
    out = {}
    for i, shape in enumerate(cache_shapes(cfg, batch, max_seq)):
        out[f"k{i}"] = jax.ShapeDtypeStruct(shape, dtype)
        out[f"v{i}"] = jax.ShapeDtypeStruct(shape, dtype)
    return out


def cache_pspecs(cfg: TransformerConfig, policy: ShardingPolicy,
                 decode: DecodePolicy) -> dict:
    seq = decode.cache_seq_axes if len(decode.cache_seq_axes) > 1 else (
        decode.cache_seq_axes[0] if decode.cache_seq_axes else None)
    bat = decode.batch_axes if len(decode.batch_axes) > 1 else (
        decode.batch_axes[0] if decode.batch_axes else None)
    spec = P(None, bat, seq, None, None)
    out = {}
    for i in range(len(cfg.window_pattern)):
        out[f"k{i}"] = spec
        out[f"v{i}"] = spec
    return out


def _decode_attention_sharded(cfg, policy, decode, q, k_cache, v_cache,
                              k_new, v_new, pos, window, max_seq):
    """shard_map decode attention over sequence-sharded cache shards.

    Each shard updates its slice of the ring/global cache if the write index
    lands in range, computes flash-decoding partials over its slots, and the
    partials psum-combine over the cache_seq axes.
    """
    mesh = policy.mesh
    seq_axes = decode.cache_seq_axes
    bat = decode.batch_axes if len(decode.batch_axes) > 1 else (
        decode.batch_axes[0] if decode.batch_axes else None)
    seq = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    cache_spec = P(bat, seq, None, None)   # (B, S, Hk, dh) per layer-slice
    q_spec = P(bat, None, None, None)

    s_entry = k_cache.shape[1]
    n_shards = 1
    for a in seq_axes:
        n_shards *= mesh.shape[a]
    s_loc = s_entry // n_shards

    def local(qs, kc, vc, kn, vn, pos):
        # Flat shard rank across the (possibly multiple) seq axes.
        r = jnp.zeros((), jnp.int32)
        for a in seq_axes:
            r = r * mesh.shape[a] + jax.lax.axis_index(a)
        write_pos = pos % s_entry if window is not None else pos
        w = write_pos - r * s_loc
        in_range = (w >= 0) & (w < s_loc)
        wc = jnp.clip(w, 0, s_loc - 1)
        kc2 = jax.lax.dynamic_update_slice(kc, kn, (0, wc, 0, 0))
        vc2 = jax.lax.dynamic_update_slice(vc, vn, (0, wc, 0, 0))
        kc = jnp.where(in_range, kc2, kc)
        vc = jnp.where(in_range, vc2, vc)
        # Valid slots: global slot index <= pos (or the whole ring once full).
        slots = r * s_loc + jnp.arange(s_loc, dtype=jnp.int32)
        if window is not None:
            valid = (slots <= pos) | (pos >= s_entry)
        else:
            valid = slots <= pos
        mask = jnp.broadcast_to(valid[None], (qs.shape[0], s_loc))
        wv, m, z = attn_lib.decode_attention_partial(
            qs, kc, vc, length_mask=mask, attn_softcap=cfg.attn_softcap)
        out = attn_lib.combine_decode_partials(
            wv, m, z, seq_axes if len(seq_axes) > 1 else seq_axes[0])
        return out.astype(qs.dtype), kc, vc

    return shard_map(
        local, mesh=mesh,
        in_specs=(q_spec, cache_spec, cache_spec, q_spec, q_spec, P()),
        out_specs=(q_spec, cache_spec, cache_spec),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, pos)


def make_serve_step(cfg: TransformerConfig, max_seq: int,
                    *, policy: Optional[ShardingPolicy] = None,
                    decode: DecodePolicy = DecodePolicy()):
    """Returns serve_step(params, cache, tokens (B,1), pos) -> (logits, cache).

    One decode step: append the token's KV at ``pos`` and attend over the
    cache.  MoE layers run the all-expert reference path (DESIGN.md §6).
    """

    def serve_step(params, cache, tokens, pos):
        cdt = cfg.compute_dtype
        b = tokens.shape[0]
        H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        embed = params["embed"].astype(cdt)
        x = embed[tokens]                                     # (B, 1, d)
        freqs = rope_freqs(cfg.d_head, theta=cfg.rope_theta)
        blocks = [jax.tree_util.tree_map(lambda a: a.astype(cdt), blk)
                  for blk in params["blocks"]]

        def group_body(carry, xs):
            x = carry
            slices, caches = xs
            new_caches = []
            for i, (blk, window) in enumerate(zip(slices, cfg.window_pattern)):
                s_entry = min(window, max_seq) if window is not None else max_seq
                h = rms_norm(x, blk["ln1"])
                q = (h @ blk["wq"]).reshape(b, 1, H, dh)
                kn = (h @ blk["wk"]).reshape(b, 1, Hk, dh)
                vn = (h @ blk["wv"]).reshape(b, 1, Hk, dh)
                posb = jnp.full((b, 1), pos, jnp.int32)
                q = apply_rope(q, posb, freqs)
                kn = apply_rope(kn, posb, freqs)
                kc, vc = caches[2 * i], caches[2 * i + 1]
                if policy is not None:
                    out, kc, vc = _decode_attention_sharded(
                        cfg, policy, decode, q, kc, vc, kn, vn, pos, window, max_seq)
                else:
                    write = pos % s_entry if window is not None else pos
                    kc = jax.lax.dynamic_update_slice(kc, kn, (0, write, 0, 0))
                    vc = jax.lax.dynamic_update_slice(vc, vn, (0, write, 0, 0))
                    slots = jnp.arange(s_entry, dtype=jnp.int32)
                    valid = (slots <= pos) | (jnp.asarray(window is not None) & (pos >= s_entry))
                    mask = jnp.broadcast_to(valid[None], (b, s_entry))
                    out = attn_lib.decode_attention(
                        q, kc, vc, length_mask=mask, attn_softcap=cfg.attn_softcap)
                x = x + out.reshape(b, 1, H * dh) @ blk["wo"]
                # FFN (reference MoE path for decode).
                h2 = rms_norm(x, blk["ln2"])
                if cfg.moe is None:
                    y = (jax.nn.silu(h2 @ blk["w_gate"]) * (h2 @ blk["w_up"])) @ blk["w_down"]
                else:
                    flat = h2.reshape(b, cfg.d_model)
                    y, _ = moe_lib.moe_ffn_reference(blk["moe"], flat, cfg.moe)
                    y = y.reshape(b, 1, cfg.d_model)
                    if cfg.moe.dense_residual_d_ff:
                        y = y + (jax.nn.silu(h2 @ blk["res_gate"]) *
                                 (h2 @ blk["res_up"])) @ blk["res_down"]
                x = x + y
                new_caches.extend([kc, vc])
            return x, tuple(new_caches)

        cache_xs = []
        for i in range(len(cfg.window_pattern)):
            cache_xs.extend([cache[f"k{i}"], cache[f"v{i}"]])
        x, new_cache_xs = jax.lax.scan(group_body, x,
                                       xs=(tuple(blocks), tuple(cache_xs)))
        x = rms_norm(x, params["final_norm"].astype(cdt))
        unembed = (params["embed"].T if cfg.tie_embeddings
                   else params["unembed"]).astype(cdt)
        logits = x @ unembed
        if cfg.final_softcap is not None:
            logits = softcap(logits, cfg.final_softcap)
        new_cache = {}
        for i in range(len(cfg.window_pattern)):
            new_cache[f"k{i}"] = new_cache_xs[2 * i]
            new_cache[f"v{i}"] = new_cache_xs[2 * i + 1]
        return logits[:, 0], new_cache

    return serve_step
