"""GatedGCN (Bresson & Laurent; config from Dwivedi et al., arXiv:2003.00982).

Edge-gated message passing:
    e'_ij = e_ij + ReLU(LN(C e_ij + D h_i + E h_j))
    eta_ij = sigma(e'_ij) / (sum_j' sigma(e'_ij') + eps)
    h'_i  = h_i + ReLU(LN(A h_i + sum_j eta_ij * (B h_j)))

LayerNorm replaces the reference BatchNorm (batch statistics don't shard;
recorded in DESIGN.md).  Benchmarking-GNNs config: 16 layers, d_hidden 70.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..common import dense_init, layer_norm
from .graph import GraphBatch
from .layers import scatter_sum

Array = jax.Array


@dataclass(frozen=True)
class GatedGCNConfig:
    name: str = "gatedgcn"
    n_layers: int = 16
    d_in: int = 16
    d_edge_in: int = 16
    d_hidden: int = 70
    n_classes: int = 10
    readout: str = "nodes"        # "nodes" | "graphs"


def init_params(cfg: GatedGCNConfig, rng: Array, *, dtype=jnp.float32) -> dict:
    d = cfg.d_hidden
    k_in, k_ein, k_out, *keys = jax.random.split(rng, 3 + cfg.n_layers)

    def layer(k):
        ks = jax.random.split(k, 5)
        return {
            "A": dense_init(ks[0], (d, d), dtype=dtype),
            "B": dense_init(ks[1], (d, d), dtype=dtype),
            "C": dense_init(ks[2], (d, d), dtype=dtype),
            "D": dense_init(ks[3], (d, d), dtype=dtype),
            "E": dense_init(ks[4], (d, d), dtype=dtype),
            "ln_h_s": jnp.ones((d,), dtype), "ln_h_b": jnp.zeros((d,), dtype),
            "ln_e_s": jnp.ones((d,), dtype), "ln_e_b": jnp.zeros((d,), dtype),
        }

    # Stack layers for lax.scan.
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                     *[layer(k) for k in keys])
    return {
        "embed_h": dense_init(k_in, (cfg.d_in, d), dtype=dtype),
        "embed_e": dense_init(k_ein, (cfg.d_edge_in, d), dtype=dtype),
        "out": dense_init(k_out, (d, cfg.n_classes), dtype=dtype),
        "layers": stacked,
    }


def forward(cfg: GatedGCNConfig, params: dict, g: GraphBatch,
            *, policy=None, remat: bool = True) -> Array:
    h = g.node_feat @ params["embed_h"]
    e = (g.edge_feat if g.edge_feat is not None
         else jnp.ones((g.n_edges, cfg.d_edge_in), h.dtype)) @ params["embed_e"]
    emask = g.emask()[:, None]
    snd, rcv, n = g.senders, g.receivers, g.n_nodes

    def body(carry, lp):
        h, e = carry
        e_hat = e @ lp["C"] + (h @ lp["D"])[snd] + (h @ lp["E"])[rcv]
        e = e + jax.nn.relu(layer_norm(e_hat, lp["ln_e_s"], lp["ln_e_b"]))
        eta = jax.nn.sigmoid(e) * emask
        denom = scatter_sum(eta, rcv, n) + 1e-6
        msgs = scatter_sum(eta * (h @ lp["B"])[snd], rcv, n) / denom
        h = h + jax.nn.relu(layer_norm(h @ lp["A"] + msgs,
                                       lp["ln_h_s"], lp["ln_h_b"]))
        return (h, e), None

    scan_body = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
    (h, e), _ = jax.lax.scan(scan_body, (h, e), params["layers"])
    if cfg.readout == "graphs":
        pooled = jax.ops.segment_sum(h * g.nmask()[:, None], g.graph_ids,
                                     num_segments=g.n_graphs)
        cnt = jax.ops.segment_sum(g.nmask(), g.graph_ids, num_segments=g.n_graphs)
        return (pooled / jnp.maximum(cnt, 1.0)[:, None]) @ params["out"]
    return h @ params["out"]


def loss_fn(cfg: GatedGCNConfig, params: dict, g: GraphBatch,
            *, policy=None) -> tuple[Array, dict]:
    logits = forward(cfg, params, g, policy=policy)
    if cfg.readout == "graphs":
        labels = g.labels
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                   labels[:, None], axis=-1)[:, 0]
        loss = jnp.mean(logz - gold)
        acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    else:
        mask = g.nmask()
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                   g.labels[:, None], axis=-1)[:, 0]
        loss = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        acc = jnp.sum((jnp.argmax(logits, -1) == g.labels) * mask) / jnp.maximum(
            jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "acc": acc}
