"""MeshGraphNet (Pfaff et al., arXiv:2010.03409): encode-process-decode.

Processor step (x15, d=128, 2-layer MLPs with LayerNorm):
    e'_ij = e_ij + MLP_e([e_ij, h_i, h_j])
    h'_i  = h_i + MLP_v([h_i, sum_j e'_ij])
Decoder regresses per-node targets (mesh dynamics).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..common import mlp_apply, mlp_init
from .graph import GraphBatch
from .layers import scatter_sum

Array = jax.Array


@dataclass(frozen=True)
class MeshGraphNetConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15            # processor message-passing steps
    d_in: int = 12                # node input features (velocity, type, ...)
    d_edge_in: int = 4            # relative displacement + norm
    d_hidden: int = 128
    mlp_layers: int = 2
    d_out: int = 3                # predicted acceleration / field delta


def _mlp_dims(cfg: MeshGraphNetConfig, d_in: int) -> list[int]:
    return [d_in] + [cfg.d_hidden] * cfg.mlp_layers


def init_params(cfg: MeshGraphNetConfig, rng: Array, *, dtype=jnp.float32) -> dict:
    d = cfg.d_hidden
    k_ne, k_ee, k_dec, *keys = jax.random.split(rng, 3 + cfg.n_layers)

    def proc(k):
        k1, k2 = jax.random.split(k)
        return {
            "edge_mlp": mlp_init(k1, _mlp_dims(cfg, 3 * d), layer_norm_out=True,
                                 dtype=dtype),
            "node_mlp": mlp_init(k2, _mlp_dims(cfg, 2 * d), layer_norm_out=True,
                                 dtype=dtype),
        }

    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                     *[proc(k) for k in keys])
    return {
        "node_enc": mlp_init(k_ne, _mlp_dims(cfg, cfg.d_in),
                             layer_norm_out=True, dtype=dtype),
        "edge_enc": mlp_init(k_ee, _mlp_dims(cfg, cfg.d_edge_in),
                             layer_norm_out=True, dtype=dtype),
        "decoder": mlp_init(k_dec, [d] * cfg.mlp_layers + [cfg.d_out], dtype=dtype),
        "processors": stacked,
    }


def forward(cfg: MeshGraphNetConfig, params: dict, g: GraphBatch,
            *, policy=None, remat: bool = True) -> Array:
    from jax.sharding import PartitionSpec as P
    h = mlp_apply(params["node_enc"], g.node_feat, final_act=True)
    ef = (g.edge_feat if g.edge_feat is not None
          else jnp.ones((g.n_edges, cfg.d_edge_in), h.dtype))
    e = mlp_apply(params["edge_enc"], ef, final_act=True)
    emask = g.emask()[:, None]
    snd, rcv, n = g.senders, g.receivers, g.n_nodes
    constrain = (
        (lambda t: policy.constrain(
            t, P(policy.dp_spec,
                 policy.tp_axis if cfg.d_hidden % policy.tp == 0 else None)))
        if policy is not None else (lambda t: t))
    h, e = constrain(h), constrain(e)

    def body(carry, lp):
        h, e = carry
        e = e + mlp_apply(lp["edge_mlp"],
                          jnp.concatenate([e, h[snd], h[rcv]], axis=-1),
                          final_act=True)
        agg = scatter_sum(e * emask, rcv, n)
        h = h + mlp_apply(lp["node_mlp"], jnp.concatenate([h, agg], axis=-1),
                          final_act=True)
        return (constrain(h), constrain(e)), None

    scan_body = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable) if remat else body
    (h, e), _ = jax.lax.scan(scan_body, (h, e), params["processors"])
    return mlp_apply(params["decoder"], h)


def loss_fn(cfg: MeshGraphNetConfig, params: dict, g: GraphBatch,
            *, policy=None) -> tuple[Array, dict]:
    pred = forward(cfg, params, g, policy=policy)
    mask = g.nmask()[:, None]
    err = jnp.square((pred - g.labels).astype(jnp.float32)) * mask
    loss = jnp.sum(err) / jnp.maximum(jnp.sum(mask) * cfg.d_out, 1.0)
    return loss, {"loss": loss, "rmse": jnp.sqrt(loss)}
