"""EquiformerV2-style equivariant graph attention via eSCN SO(2) convolutions
(Liao et al., arXiv:2306.12059; eSCN trick from Passaro & Zitnick).

Irrep features are packed (N, (l_max+1)^2, C).  Each edge rotates the source
features so the edge vector aligns with +z (per-edge real-Wigner blocks are
*data*, produced host-side by :mod:`repro.data.wigner`), applies an
SO(2)-equivariant linear map restricted to |m| <= m_max — this is the
O(L^6) -> O(L^3) reduction that defines eSCN — un-rotates, weighs by graph
attention (from the invariant l=0 channel), and scatter-sums to receivers.

Faithful elements: irrep feature algebra, m-restricted SO(2) complex
structure (commutes with the residual z-gauge, so outputs are exactly
equivariant), attention from invariants, equivariant RMS-norm and gated
nonlinearity.  Simplified vs the reference: no S2-grid pointwise activation
and a plain invariant FFN on l=0 (DESIGN.md records this).

Data contract: edges must have NON-ZERO edge vectors — self-loops have no
defined edge frame and break equivariance (the reference models likewise
build radius graphs without self-loops).  Padding edges must carry
``edge_mask = 0`` so their (arbitrary) Wigner blocks never contribute.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..common import dense_init, mlp_apply, mlp_init, segment_softmax
from .graph import GraphBatch

Array = jax.Array


@dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128           # channels per irrep degree
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    d_in: int = 4                 # scalar input features per node (atom embed)
    d_out: int = 1                # invariant readout (energy)
    # Edge tiling: the eSCN conv processes edges in this many chunks so the
    # (E_chunk, L2, C) message tensor bounds VMEM/HBM — the paper's tile
    # parameter P applied to the pod (61M-edge ogb_products needs it).
    edge_chunks: int = 1

    @property
    def L2(self) -> int:
        return (self.l_max + 1) ** 2

    def m_dim(self, l: int) -> int:
        return min(2 * l + 1, 2 * self.m_max + 1)

    def ls_for_m(self, m: int) -> list[int]:
        return list(range(max(m, 1) if m > 0 else 0, self.l_max + 1))


# §Perf hillclimb flag (benchmarks/hillclimb.py): gather/replicate the node
# features ONCE per layer before the edge-chunk scan instead of letting the
# partitioner re-all-gather them for every chunk's edge gather.
_GATHER_ONCE = False


def _l_slices(l_max: int) -> list[tuple[int, int]]:
    """(start, size) of each degree block in the packed (l_max+1)^2 axis."""
    out, off = [], 0
    for l in range(l_max + 1):
        out.append((off, 2 * l + 1))
        off += 2 * l + 1
    return out


def init_params(cfg: EquiformerV2Config, rng: Array, *, dtype=jnp.float32) -> dict:
    C, lm = cfg.d_hidden, cfg.l_max
    keys = jax.random.split(rng, 4 + cfg.n_layers)

    def so2_layer(k):
        ks = jax.random.split(k, 3 + 2 * cfg.m_max + 2)
        p = {}
        n0 = (lm + 1) * C
        p["w_m0"] = dense_init(ks[0], (n0, n0), fan_in=n0, dtype=dtype)
        for m in range(1, cfg.m_max + 1):
            nm = len(cfg.ls_for_m(m)) * C
            p[f"w_m{m}_r"] = dense_init(ks[2 * m - 1], (nm, nm), fan_in=nm, dtype=dtype)
            p[f"w_m{m}_i"] = dense_init(ks[2 * m], (nm, nm), fan_in=nm, dtype=dtype)
        p["attn_mlp"] = mlp_init(ks[-3], [2 * C, C, cfg.n_heads], dtype=dtype)
        p["gate"] = dense_init(ks[-2], (C, lm * C), fan_in=C, dtype=dtype)
        p["ffn"] = mlp_init(ks[-1], [C, 2 * C, C], dtype=dtype)
        p["norm_scale"] = jnp.ones((lm + 1, C), dtype)
        return p

    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                     *[so2_layer(k) for k in keys[4:]])
    return {
        "embed": dense_init(keys[0], (cfg.d_in, C), dtype=dtype),
        "out_mlp": mlp_init(keys[1], [C, C, cfg.d_out], dtype=dtype),
        "layers": stacked,
    }


def equivariant_rms_norm(cfg: EquiformerV2Config, x: Array, scale: Array) -> Array:
    """Normalize each degree block by its RMS norm over (m, C)."""
    parts = []
    for l, (s, n) in enumerate(_l_slices(cfg.l_max)):
        blk = x[:, s:s + n, :]
        rms = jnp.sqrt(jnp.mean(jnp.square(blk), axis=(1, 2), keepdims=True) + 1e-6)
        parts.append(blk / rms * scale[l][None, None, :])
    return jnp.concatenate(parts, axis=1)


def _so2_conv(cfg: EquiformerV2Config, lp: dict, rot: Array | dict,
              x_edge: Array) -> Array:
    """Rotate -> SO(2) linear (m-restricted) -> un-rotate.  x_edge (E, L2, C)."""
    E, _, C = x_edge.shape
    slices = _l_slices(cfg.l_max)

    # Rotate into edge-aligned frame, keeping only |m| <= m_max rows.
    rot_feats = []   # per l: (E, m_dim, C)
    for l, (s, n) in enumerate(slices):
        D = rot[l]                                   # (E, m_dim, 2l+1)
        rot_feats.append(jnp.einsum("emn,enc->emc", D, x_edge[:, s:s + n, :]))

    # Row layout within each l block (wigner_stack): [m=0, 1c, 1s, 2c, 2s, ...]
    def row(l: int, m: int, part: str) -> Array:
        if m == 0:
            return rot_feats[l][:, 0, :]
        base = 1 + 2 * (m - 1)
        return rot_feats[l][:, base + (0 if part == "c" else 1), :]

    out_rows = {l: {} for l in range(cfg.l_max + 1)}

    # m = 0: plain linear over stacked (l, C).
    x0 = jnp.concatenate([row(l, 0, "c") for l in range(cfg.l_max + 1)], axis=-1)
    y0 = x0 @ lp["w_m0"]
    for i, l in enumerate(range(cfg.l_max + 1)):
        out_rows[l][(0, "c")] = y0[:, i * C:(i + 1) * C]

    # m >= 1: complex linear (commutes with the residual z-rotation gauge).
    for m in range(1, cfg.m_max + 1):
        ls = cfg.ls_for_m(m)
        xc = jnp.concatenate([row(l, m, "c") for l in ls], axis=-1)
        xs = jnp.concatenate([row(l, m, "s") for l in ls], axis=-1)
        wr, wi = lp[f"w_m{m}_r"], lp[f"w_m{m}_i"]
        yc = xc @ wr - xs @ wi
        ys = xs @ wr + xc @ wi
        for i, l in enumerate(ls):
            out_rows[l][(m, "c")] = yc[:, i * C:(i + 1) * C]
            out_rows[l][(m, "s")] = ys[:, i * C:(i + 1) * C]

    # Reassemble m-restricted blocks and rotate back with D^T.
    outs = []
    for l, (s, n) in enumerate(slices):
        rows = [out_rows[l][(0, "c")]]
        for m in range(1, min(l, cfg.m_max) + 1):
            rows.extend([out_rows[l][(m, "c")], out_rows[l][(m, "s")]])
        y = jnp.stack(rows, axis=1)                  # (E, m_dim, C)
        D = rot[l]
        outs.append(jnp.einsum("emn,emc->enc", D, y))
    return jnp.concatenate(outs, axis=1)             # (E, L2, C)


def forward(cfg: EquiformerV2Config, params: dict, g: GraphBatch,
            *, policy=None, remat: bool = True) -> Array:
    """Returns invariant per-graph predictions (n_graphs, d_out).

    With a :class:`~repro.distributed.sharding.ShardingPolicy`, nodes shard
    over the dp axes and channels over the model axis (2-D GNN partitioning
    — the all-gathered feature matrix per layer is C/tp narrower, which is
    what lets ogb_products fit; EXPERIMENTS.md §Dry-run iteration 2).
    """
    from jax.sharding import PartitionSpec as P
    N, C = g.n_nodes, cfg.d_hidden
    x = jnp.zeros((N, cfg.L2, C), params["embed"].dtype)
    x = x.at[:, 0, :].set(g.node_feat @ params["embed"])
    constrain = (
        (lambda t: policy.constrain(
            t, P(policy.dp_spec, None,
                 policy.tp_axis if C % policy.tp == 0 else None)))
        if policy is not None else (lambda t: t))
    x = constrain(x)
    snd, rcv = g.senders, g.receivers
    emask = g.emask()

    E = snd.shape[0]
    # The data pipeline may deliver the Wigner blocks PRE-CHUNKED
    # (n_chunks, Ec, m, 2l+1) — reshaping a sharded (E, ...) array in-model
    # would split across shard boundaries and force XLA to replicate the
    # full tensor (a 150 GB/device lesson from the ogb_products dry-run).
    pre_chunked = (g.wigner is not None
                   and next(iter(g.wigner.values())).ndim == 4)
    if pre_chunked:
        n_chunks = next(iter(g.wigner.values())).shape[0]
    else:
        n_chunks = cfg.edge_chunks if E % max(cfg.edge_chunks, 1) == 0 else 1

    def _weighted_scatter(lp, wig_c, snd_c, rcv_c, alpha_c, h):
        msg = _so2_conv(cfg, lp, wig_c, h[snd_c])
        mh = msg.reshape(msg.shape[0], cfg.L2, cfg.n_heads, C // cfg.n_heads)
        mh = mh * alpha_c[:, None, :, None]
        return jax.ops.segment_sum(
            mh.reshape(msg.shape[0], cfg.L2, C), rcv_c, num_segments=N)

    def body(x, lp):
        h = equivariant_rms_norm(cfg, x, lp["norm_scale"])
        # Attention from invariant channels (cheap, full edge set).
        inv = jnp.concatenate([h[snd][:, 0, :], h[rcv][:, 0, :]], axis=-1)
        scores = mlp_apply(lp["attn_mlp"], inv)              # (E, heads)
        scores = jnp.where(emask[:, None] > 0, scores, -1e30)
        alpha = segment_softmax(scores, rcv, N)              # (E, heads)
        alpha = alpha * emask[:, None]
        if n_chunks == 1:
            agg = _weighted_scatter(lp, g.wigner, snd, rcv, alpha, h)
        else:
            h_src = h
            if _GATHER_ONCE and policy is not None:
                # Hoist the feature gather out of the chunk loop: replicate
                # the node dim once per layer (C stays model-sharded).
                h_src = policy.constrain(
                    h, P(None, None,
                         policy.tp_axis if C % policy.tp == 0 else None))
            ec = E // n_chunks
            wig_xs = (g.wigner if pre_chunked else
                      {l: w.reshape(n_chunks, ec, *w.shape[1:])
                       for l, w in g.wigner.items()})
            xs = (
                wig_xs,
                snd.reshape(n_chunks, ec),
                rcv.reshape(n_chunks, ec),
                alpha.reshape(n_chunks, ec, cfg.n_heads),
            )

            def chunk_body(acc, c):
                wig_c, snd_c, rcv_c, alpha_c = c
                return acc + _weighted_scatter(lp, wig_c, snd_c, rcv_c,
                                               alpha_c, h_src), None

            agg, _ = jax.lax.scan(
                jax.checkpoint(chunk_body,
                               policy=jax.checkpoint_policies.nothing_saveable),
                jnp.zeros((N, cfg.L2, C), x.dtype), xs)
        x = x + agg
        # Gated nonlinearity: l=0 drives sigmoid gates for l > 0.
        s0 = x[:, 0, :]
        gates = jax.nn.sigmoid(s0 @ lp["gate"]).reshape(N, cfg.l_max, C)
        parts = [jax.nn.silu(s0)[:, None, :] + 0 * x[:, :1, :]]
        for l, (s, n) in enumerate(_l_slices(cfg.l_max)[1:], start=1):
            parts.append(x[:, s:s + n, :] * gates[:, l - 1][:, None, :])
        x = jnp.concatenate(parts, axis=1)
        # Invariant FFN on l=0.
        x = x.at[:, 0, :].add(mlp_apply(lp["ffn"], x[:, 0, :]))
        return constrain(x), None

    scan_body = body
    if remat:
        scan_body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(scan_body, x, params["layers"])
    inv = x[:, 0, :] * g.nmask()[:, None]
    gid = g.graph_ids if g.graph_ids is not None else jnp.zeros((N,), jnp.int32)
    pooled = jax.ops.segment_sum(inv, gid, num_segments=g.n_graphs)
    return mlp_apply(params["out_mlp"], pooled)


def loss_fn(cfg: EquiformerV2Config, params: dict, g: GraphBatch,
            *, policy=None) -> tuple[Array, dict]:
    pred = forward(cfg, params, g, policy=policy)
    err = jnp.square((pred - g.labels).astype(jnp.float32))
    loss = jnp.mean(err)
    return loss, {"loss": loss, "mae": jnp.mean(jnp.abs(pred - g.labels))}
