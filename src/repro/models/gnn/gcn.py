"""GCN (Kipf & Welling, arXiv:1609.02907) — the paper's canonical workload.

h^{l+1} = act( D^{-1/2} (A + I) D^{-1/2} h^l W^l ), with the transform
applied *before* aggregation (X W then A ·) so the aggregated feature width
is d_hidden, not d_in — the same ordering EnGN streams tiles in.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..common import dense_init
from .graph import GraphBatch, sym_norm_coeffs
from .layers import gather_scatter_sum

Array = jax.Array


@dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    norm: str = "sym"
    aggregator: str = "mean"      # applied as the sym-norm weighting
    readout: str = "nodes"        # "nodes" | "graphs" (molecule batching)


def init_params(cfg: GCNConfig, rng: Array, *, dtype=jnp.float32) -> dict:
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(rng, cfg.n_layers)
    return {"w": [dense_init(k, (a, b), dtype=dtype)
                  for k, a, b in zip(keys, dims[:-1], dims[1:])],
            "b": [jnp.zeros((b,), dtype) for b in dims[1:]]}


def forward(cfg: GCNConfig, params: dict, g: GraphBatch,
            *, aggregate_fn: Optional[Callable] = None,
            agg_dtype=None) -> Array:
    """Returns per-node logits (N, n_classes).

    ``agg_dtype`` (e.g. bf16) casts the transformed features before
    aggregation — halves the distributed gather/scatter wire bytes (§Perf
    hillclimb); logits return in f32.
    """
    agg = aggregate_fn or gather_scatter_sum
    coeff = sym_norm_coeffs(g)
    h = g.node_feat
    for i, (w, b) in enumerate(zip(params["w"], params["b"])):
        h = h @ w + b                 # transform first (cheaper aggregate)
        if agg_dtype is not None:
            h = h.astype(agg_dtype)
            coeff_l = coeff.astype(agg_dtype)
        else:
            coeff_l = coeff
        h = agg(h, g.senders, g.receivers, g.n_nodes, edge_weight=coeff_l)
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    return h.astype(jnp.float32)


def loss_fn(cfg: GCNConfig, params: dict, g: GraphBatch,
            *, aggregate_fn: Optional[Callable] = None,
            policy=None) -> tuple[Array, dict]:
    del policy  # 2-layer GCN needs no activation constraints (fits everywhere)
    logits = forward(cfg, params, g, aggregate_fn=aggregate_fn)
    if cfg.readout == "graphs":
        pooled = jax.ops.segment_sum(logits * g.nmask()[:, None], g.graph_ids,
                                     num_segments=g.n_graphs)
        cnt = jax.ops.segment_sum(g.nmask(), g.graph_ids,
                                  num_segments=g.n_graphs)
        logits = pooled / jnp.maximum(cnt, 1.0)[:, None]
        labels, mask = g.labels, jnp.ones((g.n_graphs,), jnp.float32)
    else:
        labels, mask = g.labels, g.nmask()
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[:, None], axis=-1)[:, 0]
    nll = (logz - gold) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / jnp.maximum(
        jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "acc": acc}
