"""GNN family: GCN, GatedGCN, MeshGraphNet, EquiformerV2 (eSCN)."""

from . import gcn, gatedgcn, meshgraphnet, equiformer_v2
from .graph import GraphBatch

__all__ = ["gcn", "gatedgcn", "meshgraphnet", "equiformer_v2", "GraphBatch"]
