"""Message-passing primitives over edge lists.

JAX has no sparse SpMM beyond BCOO, so (per the brief) message passing is
built from gathers + ``jax.ops.segment_sum``/``segment_max`` over the edge
index — this module IS the system's aggregation substrate.  The pluggable
``aggregate_fn`` hook lets the distributed runtime swap in the ring-SpMM
(EnGN RER adaptation, :mod:`repro.distributed.ring`) or the fused Pallas
kernel (:mod:`repro.kernels`) without touching model code.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

AggregateFn = Callable[..., Array]


def gather_scatter_sum(node_values: Array, senders: Array, receivers: Array,
                       n_nodes: int, *, edge_weight: Optional[Array] = None) -> Array:
    """sum_j w_ij * x_j for each receiver i — the SpMM A @ X as gather+segment_sum."""
    msgs = node_values[senders]
    if edge_weight is not None:
        msgs = msgs * edge_weight[:, None]
    return jax.ops.segment_sum(msgs, receivers, num_segments=n_nodes)


def scatter_sum(edge_values: Array, receivers: Array, n_nodes: int,
                *, edge_mask: Optional[Array] = None) -> Array:
    if edge_mask is not None:
        edge_values = edge_values * edge_mask[..., None]
    return jax.ops.segment_sum(edge_values, receivers, num_segments=n_nodes)


def scatter_mean(edge_values: Array, receivers: Array, n_nodes: int,
                 *, edge_mask: Optional[Array] = None) -> Array:
    mask = edge_mask if edge_mask is not None else jnp.ones(edge_values.shape[0])
    tot = scatter_sum(edge_values, receivers, n_nodes, edge_mask=edge_mask)
    cnt = jax.ops.segment_sum(mask, receivers, num_segments=n_nodes)
    return tot / jnp.maximum(cnt, 1.0)[:, None]


def scatter_max(edge_values: Array, receivers: Array, n_nodes: int,
                *, edge_mask: Optional[Array] = None) -> Array:
    if edge_mask is not None:
        neg = jnp.asarray(-1e30, edge_values.dtype)
        edge_values = jnp.where(edge_mask[..., None] > 0, edge_values, neg)
    out = jax.ops.segment_max(edge_values, receivers, num_segments=n_nodes)
    return jnp.where(jnp.isfinite(out) & (out > -1e29), out, 0.0)
