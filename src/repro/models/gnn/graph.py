"""Graph batch containers (padded, fixed-shape, pytree-registered).

All graphs are padded to static shapes: masked edges carry zero weight and
point at node 0, masked nodes contribute nothing to losses.  Batched small
graphs (the ``molecule`` shape) concatenate nodes/edges and carry
``graph_ids`` for segment readouts.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@partial(jax.tree_util.register_dataclass,
         data_fields=["node_feat", "senders", "receivers", "edge_feat",
                      "labels", "node_mask", "edge_mask", "graph_ids",
                      "positions", "wigner"],
         meta_fields=["n_graphs"])
@dataclass
class GraphBatch:
    node_feat: Array                       # (N, F)
    senders: Array                         # (E,) int32
    receivers: Array                       # (E,) int32
    edge_feat: Optional[Array] = None      # (E, Fe)
    labels: Optional[Array] = None         # (N,) int or (n_graphs, ...) float
    node_mask: Optional[Array] = None      # (N,) float {0,1}
    edge_mask: Optional[Array] = None      # (E,) float {0,1}
    graph_ids: Optional[Array] = None      # (N,) int32, molecule batching
    positions: Optional[Array] = None      # (N, 3), equivariant models
    wigner: Optional[dict] = None          # {l: (E, m_dim, 2l+1)} eSCN blocks
    n_graphs: int = 1

    @property
    def n_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def n_edges(self) -> int:
        return self.senders.shape[0]

    def emask(self) -> Array:
        if self.edge_mask is None:
            return jnp.ones((self.n_edges,), jnp.float32)
        return self.edge_mask

    def nmask(self) -> Array:
        if self.node_mask is None:
            return jnp.ones((self.n_nodes,), jnp.float32)
        return self.node_mask


def degrees(g: GraphBatch, *, direction: str = "in") -> Array:
    idx = g.receivers if direction == "in" else g.senders
    return jax.ops.segment_sum(g.emask(), idx, num_segments=g.n_nodes)


def sym_norm_coeffs(g: GraphBatch, *, eps: float = 1e-9) -> Array:
    """GCN symmetric normalization 1/sqrt(d_i d_j) per edge (self-loops are
    expected to already be present as edges)."""
    deg_in = degrees(g, direction="in")
    deg_out = degrees(g, direction="out")
    inv_i = jax.lax.rsqrt(jnp.maximum(deg_in, eps))[g.receivers]
    inv_j = jax.lax.rsqrt(jnp.maximum(deg_out, eps))[g.senders]
    return inv_i * inv_j * g.emask()
