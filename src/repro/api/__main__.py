"""Entry point: ``PYTHONPATH=src python -m repro.api`` (see cli.py)."""

from .cli import main

if __name__ == "__main__":
    try:
        raise SystemExit(main())
    except BrokenPipeError:  # stdout piped into head/less that exited
        raise SystemExit(0)
