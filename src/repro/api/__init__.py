"""`repro.api` — the scenario front door (DESIGN.md §11).

One declarative, serializable query API for every
(dataflow x workload x graph x hardware x composition) evaluation:

* :class:`~repro.api.scenario.Scenario` / :class:`~repro.api.scenario.
  Composition` — pure-data, JSON-round-trippable description of one
  evaluation.
* :func:`~repro.api.planner.evaluate_scenarios` — the batch planner: one
  broadcast closed-form call per plan group (no Python loop per
  scenario), results in input order with per-term breakdowns.
* :mod:`~repro.api.templates` — the paper's figures as named scenario
  batches; the legacy ``figN_*`` sweep functions are thin clients.
* :class:`~repro.api.serve.ServeEngine` — the §18 serving engine:
  concurrent scenario-batch requests coalesced across callers inside a
  micro-batching window, bit-identical to serial evaluation, with
  per-request coalesce / cache metrics under ``meta["serve"]``.
* ``python -m repro.api`` — the service-shaped CLI: evaluate scenario
  files (``--scenario batch.json``), named templates (``--template``),
  workload bridges (``--workload``), run the §15 design-space auto-tuner
  (``--tune batch.json``), serve a batch through the coalescing engine
  (``--serve``), and emit ``BENCH_scenarios.json`` / ``BENCH_tune.json``.

Workload configs join through :meth:`repro.configs.base.ArchDef.
to_scenarios`, which translates each architecture's DESIGN.md §5
tile-language mapping into evaluable scenarios across any set of
registered dataflows.
"""

from repro.core.tune import (InfeasibleBudgetError, TunePoint, TuneResult,
                             tune_scenario)

from .planner import (BatchResult, GroupResult, ScenarioResult,
                      coalesce_scenarios, evaluate_groups, evaluate_scenario,
                      evaluate_scenarios)
from .scenario import (Composition, FULL_GRAPH_FIELDS, Scenario,
                       TILE_GRAPH_FIELDS, TRACE_GRAPH_FIELDS, dump_scenarios,
                       load_scenarios, scenarios_to_dicts)
from .serve import ServeEngine, ServeError, ServeResult
from .templates import (TEMPLATES, TemplateBatch, template, template_names,
                        tile_scenarios_from_graph, trace_scenarios_from_graph)

__all__ = [
    "Scenario",
    "Composition",
    "TILE_GRAPH_FIELDS",
    "FULL_GRAPH_FIELDS",
    "TRACE_GRAPH_FIELDS",
    "load_scenarios",
    "dump_scenarios",
    "scenarios_to_dicts",
    "ScenarioResult",
    "GroupResult",
    "BatchResult",
    "evaluate_scenario",
    "evaluate_scenarios",
    "evaluate_groups",
    "coalesce_scenarios",
    # §18 serving engine
    "ServeEngine",
    "ServeResult",
    "ServeError",
    "TemplateBatch",
    "TEMPLATES",
    "template",
    "template_names",
    "tile_scenarios_from_graph",
    "trace_scenarios_from_graph",
    # §15 design-space auto-tuner (re-exported from repro.core.tune)
    "InfeasibleBudgetError",
    "TunePoint",
    "TuneResult",
    "tune_scenario",
]
