"""Batch planner: evaluate scenario batches with one broadcast call per plan.

``evaluate_scenarios`` groups scenarios by :meth:`Scenario.plan_key` —
(dataflow, graph kind, hardware-override keys, composition structure) —
and evaluates each group in **one** closed-form call: every numeric leaf
(graph fields, hardware overrides, layer widths, tile capacities) is
stacked along a leading batch axis and handed to the §4 broadcasting
engine.  There is no Python loop per scenario at evaluation time; a batch
of homogeneous scenarios costs exactly one evaluation per distinct
dataflow (asserted in tests, DESIGN.md §11).

Because the closed forms are elementwise float64 algebra, the stacked
evaluation is bit-identical to evaluating each scenario alone — the
pinned-golden and property tests rely on this.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Iterable, Mapping, Optional, Sequence

import numpy as np

from repro.core import registry
from repro.core.compose import (FullGraphParams, MultiLayerModel,
                                RelationalGraphModel, TiledGraphModel)
from repro.core.notation import GraphTileParams
from repro.core.terms import ModelOutput
from repro.core.trace import TypedGraphTrace, resolve_trace_dataset

from .scenario import Scenario, TILE_GRAPH_FIELDS

__all__ = [
    "ScenarioResult",
    "GroupResult",
    "BatchResult",
    "coalesce_scenarios",
    "evaluate_scenario",
    "evaluate_scenarios",
    "evaluate_groups",
]

#: Relative tolerance for ``Scenario.expect`` pins.  The planner is
#: bit-identical, but pinned values travel through JSON decimal repr.
EXPECT_REL_TOL = 1e-12


@dataclass(frozen=True)
class ScenarioResult:
    """One scenario's evaluated movement totals and per-term breakdown."""

    scenario: Scenario
    total_bits: float
    total_iterations: float
    offchip_bits: float
    cache_bits: float
    onchip_bits: float
    breakdown: Mapping[str, float]
    iteration_breakdown: Mapping[str, float]
    n_tiles: Optional[float] = None
    conformance: Optional[Mapping[str, Any]] = None
    meta: Mapping[str, Any] = field(default_factory=dict)

    @property
    def expect_ok(self) -> Optional[bool]:
        """None when the scenario pins nothing; else whether pins hold."""
        if self.scenario.expect is None:
            return None
        return not self.expect_failures()

    def expect_failures(self) -> list[str]:
        fails = []
        if self.scenario.expect is not None:
            got: dict[str, Any] = {"total_bits": self.total_bits,
                                   "total_iterations": self.total_iterations}
            tune = (self.meta or {}).get("tune")
            if tune is not None:
                got["objective"] = tune["best"]["objective"]
                got["best_dataflow"] = tune["best"]["dataflow"]
                got["best_tile_vertices"] = tune["best"]["tile_vertices"]
            for key, want in self.scenario.expect.items():
                have = got.get(key)
                if isinstance(want, str) or isinstance(have, str):
                    if have != want:
                        fails.append(f"{key}: expected {want!r}, got {have!r}")
                elif have is None or not np.isclose(have, want,
                                                    rtol=EXPECT_REL_TOL,
                                                    atol=0.0):
                    fails.append(f"{key}: expected {want!r}, got {have!r}")
        return fails

    def to_dict(self) -> dict:
        out = {
            "scenario": self.scenario.to_dict(),
            "total_bits": self.total_bits,
            "total_iterations": self.total_iterations,
            "offchip_bits": self.offchip_bits,
            "cache_bits": self.cache_bits,
            "onchip_bits": self.onchip_bits,
            "breakdown": dict(self.breakdown),
            "iteration_breakdown": dict(self.iteration_breakdown),
        }
        if self.n_tiles is not None:
            out["n_tiles"] = self.n_tiles
        if self.scenario.expect is not None:
            out["expect_ok"] = self.expect_ok
        if self.conformance is not None:
            out["conformance"] = dict(self.conformance)
        tune = (self.meta or {}).get("tune")
        if tune is not None:
            out["tune"] = tune
        serve = (self.meta or {}).get("serve")
        if serve is not None:
            out["serve"] = dict(serve)
        return out


@dataclass(frozen=True)
class GroupResult:
    """One broadcast evaluation: the scenarios it covered and the raw output.

    ``output`` is the stacked :class:`~repro.core.terms.ModelOutput` whose
    term arrays carry the batch axis (length ``len(indices)``); ``indices``
    map batch positions back to the input scenario order.
    """

    dataflow: str
    plan_key: tuple
    indices: tuple[int, ...]
    output: ModelOutput


@dataclass(frozen=True)
class BatchResult:
    """Results in input order plus the evaluation plan that produced them."""

    results: tuple[ScenarioResult, ...]
    groups: tuple[GroupResult, ...]

    @property
    def n_evaluations(self) -> int:
        """Broadcast closed-form calls performed (== number of groups)."""
        return len(self.groups)

    def evaluations_per_dataflow(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for g in self.groups:
            counts[g.dataflow] = counts.get(g.dataflow, 0) + 1
        return counts

    def expect_failures(self) -> list[tuple[Scenario, list[str]]]:
        out = []
        for r in self.results:
            fails = r.expect_failures()
            if fails:
                out.append((r.scenario, fails))
        return out

    def rows(self) -> list[dict]:
        """Flat records (one per scenario) for CSV/JSON dumps."""
        rows = []
        for r in self.results:
            s = r.scenario
            rows.append({
                "label": s.label, "workload": s.workload,
                "dataflow": s.dataflow, "graph_kind": s.graph_kind,
                "total_bits": r.total_bits,
                "total_iterations": r.total_iterations,
                "offchip_bits": r.offchip_bits,
                "cache_bits": r.cache_bits,
                "onchip_bits": r.onchip_bits,
            })
        return rows

    def to_dict(self) -> dict:
        return {
            "n_scenarios": len(self.results),
            "n_evaluations": self.n_evaluations,
            "evaluations_per_dataflow": self.evaluations_per_dataflow(),
            "results": [r.to_dict() for r in self.results],
        }


def coalesce_scenarios(scenarios: Sequence[Scenario]
                       ) -> tuple[list[Scenario], tuple[int, ...]]:
    """Cross-request dedup: ``(distinct, backmap)`` over a flat batch.

    ``distinct`` holds the unique scenarios in first-seen order and
    ``backmap[i]`` is the position of ``scenarios[i]`` inside it, so a
    caller can evaluate ``distinct`` once and scatter results back with
    ``[results[j] for j in backmap]``.  Equality is full scenario
    equality (:class:`Scenario` is frozen and hashable), which is finer
    than :meth:`Scenario.plan_key` — two equal-plan-key scenarios with
    different numeric leaves stay distinct here and coalesce into one
    broadcast group later, inside :func:`evaluate_scenarios`.  This is
    the serve engine's (DESIGN.md §18) cross-request collapse: N callers
    asking the same question cost one evaluated scenario.
    """
    distinct: list[Scenario] = []
    index: dict[Scenario, int] = {}
    backmap: list[int] = []
    for i, s in enumerate(scenarios):
        if not isinstance(s, Scenario):
            raise TypeError(f"scenarios[{i}] is {type(s).__name__}, "
                            "expected Scenario")
        j = index.get(s)
        if j is None:
            j = len(distinct)
            index[s] = j
            distinct.append(s)
        backmap.append(j)
    return distinct, tuple(backmap)


def _stack(values: Iterable[float]) -> np.ndarray:
    return np.asarray(list(values), dtype=np.float64)


def _group_hw(spec, scenarios: Sequence[Scenario]):
    """Default hardware with the group's overrides stacked per field."""
    keys = sorted(scenarios[0].hardware)
    if not keys:
        return None
    hw = spec.hw_factory()
    valid = {f.name for f in dataclasses.fields(hw)}
    unknown = set(keys) - valid
    if unknown:
        raise ValueError(
            f"unknown hardware override(s) {sorted(unknown)} for dataflow "
            f"{spec.name!r}; valid fields: {sorted(valid)}")
    return hw.replace(**{k: _stack(s.hardware[k] for s in scenarios)
                         for k in keys})


def _stack_rel(values) -> np.ndarray:
    """Stack relation-carrying leaves of a hetero group.

    The RelationalGraphModel convention is "relation axis LAST": a
    per-relation list stacks to ``(B, R)``; a scalar leaf stacks to
    ``(B, 1)`` so its batch axis cannot collide with the relation axis.
    Arity is uniform within a plan group (it is structural).
    """
    vals = list(values)
    if vals and isinstance(vals[0], (tuple, list)):
        return np.asarray(vals, dtype=np.float64)
    return np.asarray(vals, dtype=np.float64)[:, None]


def _resolve_group_trace(first: Scenario):
    """Resolve the edge list behind a trace / hetero / minibatch group."""
    if first.graph_kind == "hetero":
        params = dict(first.graph["params"])
        params["n_relations"] = first.graph["n_relations"]
        trace = resolve_trace_dataset(first.graph["dataset"], params)
        if not isinstance(trace, TypedGraphTrace):
            raise TypeError(
                f"hetero scenario dataset {first.graph['dataset']!r} "
                f"resolved to {type(trace).__name__}, not a "
                "TypedGraphTrace; register a typed dataset (e.g. "
                "typed_power_law / typed_blocks / typed_cora) or use "
                "kind='trace' for homogeneous edge lists")
        if trace.n_relations != first.graph["n_relations"]:
            raise ValueError(
                f"dataset {first.graph['dataset']!r} produced "
                f"{trace.n_relations} relations but the scenario declares "
                f"n_relations={first.graph['n_relations']}")
        return trace
    return resolve_trace_dataset(first.graph["dataset"],
                                 first.graph["params"])


def _group_schedule(first: Scenario, trace):
    """The measured episode schedule of a minibatch group (cached per
    trace-backed CSR via minibatch_schedule's own parameter-keyed cache)."""
    from repro.data.sampler import csr_from_trace, minibatch_schedule

    g = getattr(trace, "_sampler_csr", None)
    if g is None:
        g = csr_from_trace(trace)
        trace._sampler_csr = g
    return minibatch_schedule(
        g, batch_nodes=first.graph["batch_nodes"],
        fanout=first.graph["fanout"], n_batches=first.graph["n_batches"],
        seed=first.graph["seed"])


def _group_model(spec, scenarios: Sequence[Scenario], trace=None,
                 schedule=None):
    """The (possibly composed) model shared by one plan group.

    ``trace`` (resolved once per group) switches the tiled model onto the
    exact edge-list schedule; tile capacities stack along the capacity
    axis (DESIGN.md §13), so same-dataset scenarios differing only in
    ``tile_vertices`` share this one evaluation.  A
    :class:`~repro.core.trace.TypedGraphTrace` (hetero group) builds ONE
    :class:`~repro.core.compose.RelationalGraphModel` covering every
    relation; ``schedule`` (minibatch group) pins the episode schedule.
    """
    comp = scenarios[0].composition
    kind = scenarios[0].graph_kind
    if kind == "hetero":
        widths = None
        if comp.widths is not None:
            widths = tuple(
                _stack_rel(s.composition.widths[i] for s in scenarios)
                for i in range(len(comp.widths)))
        return RelationalGraphModel(
            spec,
            tile_vertices=_stack(s.composition.tile_vertices
                                 for s in scenarios),
            trace=trace, widths=widths, residency=comp.residency)
    if comp is None:
        if schedule is not None:
            return TiledGraphModel(spec, schedule=schedule)
        return spec
    inner = spec
    if comp.widths is not None:
        widths = tuple(
            _stack(s.composition.widths[i] for s in scenarios)
            for i in range(len(comp.widths)))
        inner = MultiLayerModel(spec, widths, residency=comp.residency)
    if schedule is not None:
        return TiledGraphModel(inner, schedule=schedule)
    if comp.tile_vertices is not None:
        if trace is not None:
            return TiledGraphModel(
                inner,
                tile_vertices=_stack(s.composition.tile_vertices
                                     for s in scenarios),
                trace=trace)
        return TiledGraphModel(
            inner,
            tile_vertices=_stack(s.composition.tile_vertices
                                 for s in scenarios),
            halo_dedup=comp.halo_dedup)
    return inner


def _group_graph(scenarios: Sequence[Scenario], trace=None, schedule=None):
    kind = scenarios[0].graph_kind
    if kind == "tile":
        return GraphTileParams(**{
            f: _stack(s.graph[f] for s in scenarios)
            for f in TILE_GRAPH_FIELDS})
    if kind in ("trace", "hetero"):
        # V/E are properties of the resolved edge list (shared across the
        # group: the dataset reference is part of the plan key).  Hetero
        # N/T may be per-relation vectors; their arity is structural, so
        # the stack is rectangular, with the relation axis kept LAST.
        stack = _stack_rel if kind == "hetero" else _stack
        return FullGraphParams(
            V=float(trace.n_nodes),
            E=float(trace.n_edges),
            N=stack(s.graph["N"] for s in scenarios),
            T=stack(s.graph["T"] for s in scenarios),
            high_degree_fraction=_stack(s.graph["high_degree_fraction"]
                                        for s in scenarios),
        )
    if kind == "minibatch":
        # E is the measured total of sampled episode edges — the explicit
        # schedule is exact, so the declared graph must match it.
        return FullGraphParams(
            V=float(trace.n_nodes),
            E=float(schedule.n_edges),
            N=_stack(s.graph["N"] for s in scenarios),
            T=_stack(s.graph["T"] for s in scenarios),
            high_degree_fraction=_stack(s.graph["high_degree_fraction"]
                                        for s in scenarios),
        )
    return FullGraphParams(
        V=_stack(s.graph["V"] for s in scenarios),
        E=_stack(s.graph["E"] for s in scenarios),
        N=_stack(s.graph["N"] for s in scenarios),
        T=_stack(s.graph["T"] for s in scenarios),
        high_degree_fraction=_stack(s.graph["high_degree_fraction"]
                                    for s in scenarios),
    )


def _evaluate_group(scenarios: Sequence[Scenario]) -> ModelOutput:
    first = scenarios[0]
    spec = registry.get(first.dataflow)
    trace = None
    schedule = None
    if first.graph_kind in ("trace", "hetero", "minibatch"):
        trace = _resolve_group_trace(first)
    if first.graph_kind == "minibatch":
        schedule = _group_schedule(first, trace)
    model = _group_model(spec, scenarios, trace=trace, schedule=schedule)
    graph = _group_graph(scenarios, trace=trace, schedule=schedule)
    hw = _group_hw(spec, scenarios)
    # THE one broadcast closed-form call for this group.
    return model.evaluate(graph, hw)


def _conformance_summary(dataflow: str, points=None) -> dict:
    """One-point §10 measured-vs-modeled check (lazy: compiles kernels)."""
    spec = registry.get(dataflow)
    if not spec.has_runnable:
        return {"checked": False, "ok": True,
                "reason": "no runnable kernel analogue (analytical-only)"}
    from repro.core.conformance import OperatingPoint, conformance_records

    pts = points if points is not None else (OperatingPoint(256, 16, 8, 128, 128),)
    analogue = spec.runnable_analogue()
    n = n_bad = 0
    analytical = measured = 0.0
    for pt in pts:
        for rec in conformance_records(spec, pt, analogue=analogue):
            n += 1
            if not rec.ok:
                n_bad += 1
            if rec.movement == "hbm_total":
                analytical += rec.analytical_bytes
                measured += rec.measured_bytes
    return {"checked": True, "ok": n_bad == 0, "records": n,
            "violations": n_bad, "hbm_analytical_bytes": analytical,
            "hbm_measured_bytes": measured}


def evaluate_groups(scenarios: Sequence[Scenario]) -> tuple[GroupResult, ...]:
    """Group a batch by plan key and run one broadcast call per group.

    The sweep engine's hot path: it needs only the stacked per-group
    :class:`~repro.core.terms.ModelOutput` (to reshape onto a figure
    grid), so the per-scenario result materialization of
    :func:`evaluate_scenarios` is skipped.
    """
    for i, s in enumerate(scenarios):
        if not isinstance(s, Scenario):
            raise TypeError(f"scenarios[{i}] is {type(s).__name__}, "
                            "expected Scenario")
        if s.optimize is not None:
            raise ValueError(
                f"scenarios[{i}] carries an optimize block; "
                "evaluate_groups evaluates concrete scenarios only — "
                "optimize scenarios go through evaluate_scenarios, which "
                "routes them to the §15 tuner (repro.core.tune)")
    groups: dict[tuple, list[int]] = {}
    for i, s in enumerate(scenarios):
        groups.setdefault(s.plan_key(), []).append(i)
    return tuple(
        GroupResult(dataflow=scenarios[indices[0]].dataflow, plan_key=key,
                    indices=tuple(indices),
                    output=_evaluate_group([scenarios[i] for i in indices]))
        for key, indices in groups.items())


def evaluate_scenarios(scenarios: Sequence[Scenario], *,
                       conformance_points=None) -> BatchResult:
    """Evaluate a scenario batch: one broadcast call per plan group.

    Results come back in input order.  Scenarios with ``conformance=True``
    additionally trigger at most one §10 kernel-conformance run per
    dataflow per batch (shared across the group — it compiles kernels, so
    it is cached, never repeated per scenario).

    Scenarios carrying an ``optimize`` block are routed through the §15
    tuner (:func:`repro.core.tune.tune_scenario`) instead of a broadcast
    group: their result slot holds the *winning* configuration's totals
    and breakdown, with the full search record under ``meta["tune"]``.
    The tuner's internal probe batches recurse through this function, so
    its candidates still batch one stacked evaluation per plan group.
    """
    scenarios = list(scenarios)
    plain_idx = [i for i, s in enumerate(scenarios) if s.optimize is None]
    opt_idx = [i for i, s in enumerate(scenarios) if s.optimize is not None]
    raw_groups = evaluate_groups([scenarios[i] for i in plain_idx])
    # evaluate_groups indexed into the plain sublist; translate back to
    # input positions so GroupResult.indices keep their contract.
    group_results = tuple(
        GroupResult(dataflow=g.dataflow, plan_key=g.plan_key,
                    indices=tuple(plain_idx[i] for i in g.indices),
                    output=g.output)
        for g in raw_groups)
    slots: list[Optional[ScenarioResult]] = [None] * len(scenarios)
    if opt_idx:
        from repro.core.tune import tune_scenario

        for i in opt_idx:
            tr = tune_scenario(scenarios[i])
            w = tr.best_result
            slots[i] = ScenarioResult(
                scenario=scenarios[i],
                total_bits=w.total_bits,
                total_iterations=w.total_iterations,
                offchip_bits=w.offchip_bits,
                cache_bits=w.cache_bits,
                onchip_bits=w.onchip_bits,
                breakdown=dict(w.breakdown),
                iteration_breakdown=dict(w.iteration_breakdown),
                n_tiles=w.n_tiles,
                conformance=None,
                meta={**dict(w.meta), "tune": tr.to_dict()},
            )
    conformance_cache: dict[str, dict] = {}
    for grp in group_results:
        indices = grp.indices
        members = [scenarios[i] for i in indices]
        out = grp.output
        n = len(members)

        def col(arr) -> np.ndarray:
            return np.broadcast_to(np.asarray(arr, np.float64), (n,))

        total_bits = col(out.total_bits())
        total_iters = col(out.total_iterations())
        offchip = col(out.offchip_bits())
        cache = col(out.cache_bits())
        onchip = col(out.onchip_bits())
        per_term_bits = {t.name: col(t.data_bits) for t in out.terms}
        per_term_iters = {t.name: col(t.iterations) for t in out.terms}
        n_tiles = out.meta.get("n_tiles")
        n_tiles_col = None if n_tiles is None else col(n_tiles)
        # Trace provenance for the whole group (one in-process-LRU hit,
        # not a rebuild): sharded / factorization-only datasets resolve
        # transparently, so the result records what actually backed the
        # numbers — e.g. an edge-list-free 10⁸-edge sharded build.
        meta: dict = {}
        if members[0].graph_kind == "trace":
            tr = resolve_trace_dataset(members[0].graph["dataset"],
                                       members[0].graph["params"])
            meta["trace"] = {"dataset": members[0].graph["dataset"],
                             "n_nodes": int(tr.n_nodes),
                             "n_edges": int(tr.n_edges),
                             "edge_list_free": not tr.has_edge_list}
        elif members[0].graph_kind == "hetero":
            tr = _resolve_group_trace(members[0])
            meta["trace"] = {
                "dataset": members[0].graph["dataset"],
                "n_nodes": int(tr.n_nodes),
                "n_edges": int(tr.n_edges),
                "n_relations": int(tr.n_relations),
                "relation_edge_counts": [
                    int(c) for c in tr.relation_edge_counts()],
            }
        elif members[0].graph_kind == "minibatch":
            tr = _resolve_group_trace(members[0])
            sched = _group_schedule(members[0], tr)
            meta["minibatch"] = {
                "dataset": members[0].graph["dataset"],
                "n_nodes": int(tr.n_nodes),
                "n_episodes": int(sched.n_tiles),
                "batch_nodes": int(sched.capacity),
                "sampled_edges": int(sched.n_edges),
                "gathered_sources": int(sched.halo_total),
            }
        for j, i in enumerate(indices):
            s = members[j]
            conf = None
            if s.conformance:
                if s.dataflow not in conformance_cache:
                    conformance_cache[s.dataflow] = _conformance_summary(
                        s.dataflow, conformance_points)
                conf = conformance_cache[s.dataflow]
            slots[i] = ScenarioResult(
                scenario=s,
                total_bits=float(total_bits[j]),
                total_iterations=float(total_iters[j]),
                offchip_bits=float(offchip[j]),
                cache_bits=float(cache[j]),
                onchip_bits=float(onchip[j]),
                breakdown={k: float(v[j]) for k, v in per_term_bits.items()},
                iteration_breakdown={k: float(v[j])
                                     for k, v in per_term_iters.items()},
                n_tiles=None if n_tiles_col is None else float(n_tiles_col[j]),
                conformance=conf,
                meta=meta,
            )
    return BatchResult(results=tuple(slots), groups=group_results)


def evaluate_scenario(scenario: Scenario, **kw) -> ScenarioResult:
    """Evaluate one scenario (a batch of one)."""
    return evaluate_scenarios([scenario], **kw).results[0]
