"""`Scenario`: one (dataflow x workload x graph x hardware x composition)
evaluation as pure, serializable data.

The paper's stated goal is *comparative* analysis "for a set of hardware,
GNN model and input graph parameters"; a :class:`Scenario` is the repo's
single declarative description of one cell of that cross-product
(DESIGN.md §11).  It is a plain frozen dataclass of JSON-able scalars —
no numpy arrays, no callables, no registry handles — so a scenario can be
written to disk, shipped over a wire, diffed, or replayed bit-identically.
The batch planner (:mod:`repro.api.planner`) groups scenarios that share a
*plan signature* and evaluates each group in ONE broadcast closed-form
call, stacking every numeric leaf along a batch axis.

Graph kinds
-----------
``tile``  — the paper's Table II single-tile parameters ``N, T, K, L, P``.
``full``  — a whole graph ``V, E, N, T`` (plus ``high_degree_fraction``),
            evaluated through the §7 composition layer; requires a
            :class:`Composition` with ``tile_vertices``.
``trace`` — an *actual* graph: ``{"kind": "trace", "dataset": name,
            "params": {...}, "N": ..., "T": ...}`` references a registered
            deterministic trace dataset (:mod:`repro.core.trace`), and the
            §12 exact edge-list schedule replaces the uniform-tile
            approximation.  Requires ``tile_vertices`` (scalar per plan
            group) and forbids ``halo_dedup != 1`` — the trace measures
            the dedup exactly.
``hetero`` — a *typed* graph (DESIGN.md §17): ``{"kind": "hetero",
            "dataset": ..., "params": {...}, "n_relations": R, "N": ...,
            "T": ...}`` references a registered typed trace dataset and
            evaluates a :class:`~repro.core.compose.RelationalGraphModel`
            over all R relations at once.  ``N`` / ``T`` (and each
            ``composition.widths`` entry) may be a scalar or a length-R
            list of per-relation values; ``composition.residency`` may be
            one policy or a length-R list.  Same tiling rules as
            ``trace``: ``tile_vertices`` required, ``halo_dedup`` pinned
            to 1.
``minibatch`` — a sampled-minibatch training workload (DESIGN.md §17):
            ``{"kind": "minibatch", "dataset": ..., "params": {...},
            "batch_nodes": ..., "fanout": [...], "n_batches": ...,
            "seed": ..., "N": ..., "T": ...}`` measures ``n_batches``
            fanout-sampling episodes over the dataset's graph
            (:func:`repro.data.sampler.minibatch_schedule`) and charges
            each episode as one exact schedule tile — the gather of
            unique non-seed sources is the halo term.  ``tile_vertices``
            is forbidden (the seed batch *is* the tile) and ``optimize``
            is rejected (the §15 axes are tiling knobs).

A scenario's ``composition`` adds the §7 layers on top of the dataflow:
``widths`` chains an L-layer :class:`~repro.core.compose.MultiLayerModel`
(``residency`` = ``"spill"`` / ``"resident"``), ``tile_vertices`` covers a
full graph with a :class:`~repro.core.compose.TiledGraphModel` schedule.

``hardware`` holds overrides applied to the dataflow's default hardware
record (``spec.hw_factory().replace(**hardware)``); ``expect`` optionally
pins totals (``total_bits`` / ``total_iterations``) so a checked-in
scenario file doubles as a golden-drift gate (the CLI exits non-zero on
mismatch); ``conformance`` requests the DESIGN.md §10 measured-vs-modeled
check for dataflows with a runnable kernel analogue.

A fourth block, ``optimize`` (DESIGN.md §15), turns a full-graph or
trace scenario into a *search request*: ``{"optimize": {"objective":
"movement", "budget": {"sram_bits": ...}, "space": {...}}}`` asks the
planner for the objective-minimizing (dataflow, tile capacity,
residency, halo policy) configuration within the space, evaluated by
:mod:`repro.core.tune`.  The block is normalized at construction
(:func:`repro.core.tune.normalize_optimize`) so it stays pure data;
optimize scenarios may additionally pin ``expect.objective`` /
``expect.best_dataflow`` / ``expect.best_tile_vertices``.
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Sequence

__all__ = [
    "Composition",
    "Scenario",
    "TILE_GRAPH_FIELDS",
    "FULL_GRAPH_FIELDS",
    "TRACE_GRAPH_FIELDS",
    "HETERO_GRAPH_FIELDS",
    "MINIBATCH_GRAPH_FIELDS",
    "load_scenarios",
    "dump_scenarios",
    "scenarios_to_dicts",
]

#: Table II single-tile graph parameters, in the paper's order.
TILE_GRAPH_FIELDS = ("N", "T", "K", "L", "P")
#: Full-graph (composition-layer) parameters; high_degree_fraction optional.
FULL_GRAPH_FIELDS = ("V", "E", "N", "T")
#: Trace-graph required fields; ``params`` / ``high_degree_fraction`` optional.
TRACE_GRAPH_FIELDS = ("dataset", "N", "T")
#: Typed-graph required fields; ``params`` / ``high_degree_fraction`` optional.
HETERO_GRAPH_FIELDS = ("dataset", "n_relations", "N", "T")
#: Minibatch required fields; ``params`` / ``seed`` / hdf optional.
MINIBATCH_GRAPH_FIELDS = ("dataset", "batch_nodes", "fanout", "n_batches",
                          "N", "T")

_RESIDENCIES = ("spill", "resident")


def _require_number(value: Any, what: str) -> float:
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        raise TypeError(f"{what} must be a plain number (scenarios are pure "
                        f"data); got {value!r} of type {type(value).__name__}")
    out = float(value)
    if not math.isfinite(out):
        raise ValueError(f"{what} must be finite, got {value!r}")
    return out


def _require_nonneg(value: Any, what: str) -> float:
    out = _require_number(value, what)
    if out < 0:
        raise ValueError(f"{what} must be non-negative, got {value!r}: a "
                         "negative graph quantity silently produces "
                         "negative movement totals")
    return out


def _require_fraction(value: Any, what: str) -> float:
    out = _require_nonneg(value, what)
    if out > 1.0:
        raise ValueError(f"{what} is a fraction of the tile's vertices and "
                         f"must be <= 1, got {value!r}")
    return out


def _require_count(value: Any, what: str, *, minimum: int = 1) -> int:
    out = _require_number(value, what)
    if out != int(out) or out < minimum:
        raise ValueError(f"{what} must be an integer >= {minimum}, "
                         f"got {value!r}")
    return int(out)


def _number_or_vector(value: Any, what: str):
    """A scalar, or a per-relation list of scalars (hetero graphs)."""
    if isinstance(value, (list, tuple)):
        if not value:
            raise ValueError(f"{what} must not be an empty list; give a "
                             "scalar or one value per relation")
        return tuple(_require_nonneg(v, f"{what}[{i}]")
                     for i, v in enumerate(value))
    return _require_nonneg(value, what)


@dataclass(frozen=True)
class Composition:
    """Declarative §7 composition policy: layer widths + residency + tiling.

    ``widths`` (``[N_0, ..., N_L]``, >= 2 entries) chains L layers;
    ``tile_vertices`` (>= 1) covers a full graph with a tile schedule and
    halo reloads (``halo_dedup >= 1`` divides halo traffic).  Both are
    optional and compose; a ``Composition()`` with neither is rejected.

    For hetero scenarios (DESIGN.md §17), each ``widths`` entry may be a
    length-R list of per-relation widths, and ``residency`` may be a
    length-R list of per-relation policies; both are rejected on every
    other graph kind (the relation axis does not exist there).
    """

    widths: Optional[tuple] = None
    residency: Any = "spill"
    tile_vertices: Optional[float] = None
    halo_dedup: float = 1.0

    def __post_init__(self) -> None:
        if self.widths is not None:
            w = tuple(_number_or_vector(x, "Composition.widths entry")
                      for x in self.widths)
            if len(w) < 2:
                raise ValueError(f"Composition.widths needs >= 2 entries "
                                 f"(got {list(w)}): a layer maps "
                                 "widths[l] -> widths[l+1]")
            object.__setattr__(self, "widths", w)
        if isinstance(self.residency, (list, tuple)):
            res = tuple(self.residency)
            if not res:
                raise ValueError("Composition.residency must not be an "
                                 "empty list; give one policy or one "
                                 "policy per relation")
            for p in res:
                if p not in _RESIDENCIES:
                    raise ValueError(f"unknown residency {p!r}; expected "
                                     f"one of {_RESIDENCIES}")
            object.__setattr__(self, "residency", res)
        elif self.residency not in _RESIDENCIES:
            raise ValueError(f"unknown residency {self.residency!r}; "
                             f"expected one of {_RESIDENCIES}")
        if self.tile_vertices is not None:
            tv = _require_number(self.tile_vertices, "Composition.tile_vertices")
            if tv < 1:
                raise ValueError(f"Composition.tile_vertices must be >= 1, "
                                 f"got {self.tile_vertices!r}")
            object.__setattr__(self, "tile_vertices", tv)
        object.__setattr__(self, "halo_dedup",
                           _require_number(self.halo_dedup,
                                           "Composition.halo_dedup"))
        if self.halo_dedup < 1.0:
            raise ValueError("Composition.halo_dedup must be >= 1 "
                             "(it divides halo traffic)")
        if self.widths is None and self.tile_vertices is None:
            raise ValueError("empty Composition: give widths (multi-layer) "
                             "and/or tile_vertices (full-graph tiling), or "
                             "omit the composition entirely")
        # Reject knobs that would be silently ignored: residency only
        # matters between chained layers, halo_dedup only divides tiled
        # halo traffic.  Accepting them would also split plan groups on a
        # value with zero effect.
        if self.widths is None and self.residency != "spill":
            # A per-relation residency list also lands here: residency
            # (uniform or not) only governs inter-layer hand-off.
            raise ValueError(
                f"residency={self.residency!r} without widths has no "
                "effect (residency governs inter-layer hand-off); give "
                "widths or drop the residency")
        if self.tile_vertices is None and self.halo_dedup != 1.0:
            raise ValueError(
                f"halo_dedup={self.halo_dedup!r} without tile_vertices has "
                "no effect (it divides inter-tile halo traffic); give "
                "tile_vertices or drop the halo_dedup")

    @property
    def n_layers(self) -> Optional[int]:
        return None if self.widths is None else len(self.widths) - 1

    def relation_arity(self) -> Optional[int]:
        """Max per-relation vector length used (None if all-scalar)."""
        arities = []
        if self.widths is not None:
            arities += [len(w) for w in self.widths if isinstance(w, tuple)]
        if isinstance(self.residency, tuple):
            arities.append(len(self.residency))
        return max(arities) if arities else None

    def signature(self) -> tuple:
        """Structural part of the plan key: what cannot batch numerically.

        Layer count, residency, tiled-or-not, the (scalar-only)
        halo_dedup, and the per-relation arity of each widths entry must
        match for two scenarios to share one broadcast evaluation; the
        widths *values* and tile_vertices stack.
        """
        widths_shape = (None if self.widths is None else
                        tuple(len(w) if isinstance(w, tuple) else None
                              for w in self.widths))
        return (self.n_layers, self.residency,
                self.tile_vertices is not None, self.halo_dedup,
                widths_shape)

    def to_dict(self) -> dict:
        # Fields at their from_dict defaults may be omitted; anything else
        # must serialize regardless of which other fields are set, or the
        # round trip would not be value-identical.
        out: dict[str, Any] = {}
        if self.widths is not None:
            out["widths"] = [list(w) if isinstance(w, tuple) else w
                             for w in self.widths]
        if self.residency != "spill":
            out["residency"] = (list(self.residency)
                                if isinstance(self.residency, tuple)
                                else self.residency)
        if self.tile_vertices is not None:
            out["tile_vertices"] = self.tile_vertices
        if self.halo_dedup != 1.0:
            out["halo_dedup"] = self.halo_dedup
        return out

    @classmethod
    def from_dict(cls, data: Mapping[str, Any]) -> "Composition":
        known = {"widths", "residency", "tile_vertices", "halo_dedup"}
        unknown = set(data) - known
        if unknown:
            raise ValueError(f"unknown Composition keys {sorted(unknown)}; "
                             f"expected a subset of {sorted(known)}")
        widths = data.get("widths")
        return cls(
            widths=None if widths is None else tuple(widths),
            residency=data.get("residency", "spill"),
            tile_vertices=data.get("tile_vertices"),
            halo_dedup=data.get("halo_dedup", 1.0),
        )


def _normalized_trace_graph(graph: Mapping[str, Any]) -> dict:
    keys = set(graph)
    missing = set(TRACE_GRAPH_FIELDS) - keys
    if missing:
        raise ValueError(f"trace scenario is missing {sorted(missing)}; "
                         f"required: {TRACE_GRAPH_FIELDS} "
                         "(plus optional params / high_degree_fraction)")
    allowed = set(TRACE_GRAPH_FIELDS) | {"kind", "params",
                                         "high_degree_fraction"}
    extra = keys - allowed
    if extra:
        raise ValueError(f"unknown trace-graph keys {sorted(extra)}; "
                         f"allowed: {sorted(allowed)}")
    dataset = graph["dataset"]
    if not isinstance(dataset, str) or not dataset:
        raise ValueError(f"graph.dataset must be a non-empty registered "
                         f"trace-dataset name, got {dataset!r}")
    params = graph.get("params", {})
    if not isinstance(params, Mapping):
        raise ValueError(f"graph.params must be a mapping of numeric "
                         f"dataset parameters, got {params!r}")
    return {
        "kind": "trace",
        "dataset": dataset,
        "params": {str(k): _require_number(v, f"graph.params.{k}")
                   for k, v in params.items()},
        "N": _require_nonneg(graph["N"], "graph.N"),
        "T": _require_nonneg(graph["T"], "graph.T"),
        "high_degree_fraction": _require_fraction(
            graph.get("high_degree_fraction", 0.1),
            "graph.high_degree_fraction"),
    }


def _dataset_and_params(graph: Mapping[str, Any], kind: str) -> dict:
    dataset = graph["dataset"]
    if not isinstance(dataset, str) or not dataset:
        raise ValueError(f"graph.dataset must be a non-empty registered "
                         f"trace-dataset name, got {dataset!r}")
    params = graph.get("params", {})
    if not isinstance(params, Mapping):
        raise ValueError(f"graph.params must be a mapping of numeric "
                         f"dataset parameters, got {params!r}")
    return {
        "kind": kind,
        "dataset": dataset,
        "params": {str(k): _require_number(v, f"graph.params.{k}")
                   for k, v in params.items()},
        "high_degree_fraction": _require_fraction(
            graph.get("high_degree_fraction", 0.1),
            "graph.high_degree_fraction"),
    }


def _normalized_hetero_graph(graph: Mapping[str, Any]) -> dict:
    keys = set(graph)
    missing = set(HETERO_GRAPH_FIELDS) - keys
    if missing:
        raise ValueError(f"hetero scenario is missing {sorted(missing)}; "
                         f"required: {HETERO_GRAPH_FIELDS} "
                         "(plus optional params / high_degree_fraction)")
    allowed = set(HETERO_GRAPH_FIELDS) | {"kind", "params",
                                          "high_degree_fraction"}
    extra = keys - allowed
    if extra:
        raise ValueError(f"unknown hetero-graph keys {sorted(extra)}; "
                         f"allowed: {sorted(allowed)}")
    out = _dataset_and_params(graph, "hetero")
    R = _require_count(graph["n_relations"], "graph.n_relations")
    for f in ("N", "T"):
        v = _number_or_vector(graph[f], f"graph.{f}")
        if isinstance(v, tuple) and len(v) != R:
            raise ValueError(
                f"graph.{f} is per-relation but has {len(v)} entries for "
                f"n_relations={R}; give a scalar or exactly R values")
        out[f] = v
    out["n_relations"] = R
    return out


def _normalized_minibatch_graph(graph: Mapping[str, Any]) -> dict:
    keys = set(graph)
    missing = set(MINIBATCH_GRAPH_FIELDS) - keys
    if missing:
        raise ValueError(f"minibatch scenario is missing {sorted(missing)}; "
                         f"required: {MINIBATCH_GRAPH_FIELDS} "
                         "(plus optional params / seed / "
                         "high_degree_fraction)")
    allowed = set(MINIBATCH_GRAPH_FIELDS) | {"kind", "params", "seed",
                                             "high_degree_fraction"}
    extra = keys - allowed
    if extra:
        raise ValueError(f"unknown minibatch-graph keys {sorted(extra)}; "
                         f"allowed: {sorted(allowed)}")
    out = _dataset_and_params(graph, "minibatch")
    fanout = graph["fanout"]
    if not isinstance(fanout, (list, tuple)) or not fanout:
        raise ValueError(f"graph.fanout must be a non-empty list of "
                         f"per-hop neighbor budgets, got {fanout!r}")
    out["fanout"] = tuple(_require_count(f, f"graph.fanout[{i}]")
                          for i, f in enumerate(fanout))
    out["batch_nodes"] = _require_count(graph["batch_nodes"],
                                        "graph.batch_nodes")
    out["n_batches"] = _require_count(graph["n_batches"], "graph.n_batches")
    out["seed"] = _require_count(graph.get("seed", 0), "graph.seed",
                                 minimum=0)
    out["N"] = _require_nonneg(graph["N"], "graph.N")
    out["T"] = _require_nonneg(graph["T"], "graph.T")
    return out


def _normalized_graph(graph: Mapping[str, Any]) -> tuple[dict, str]:
    keys = set(graph)
    kind = graph.get("kind")
    if kind is not None and kind not in ("trace", "hetero", "minibatch"):
        raise ValueError(f"unknown graph kind {kind!r}; the explicit kinds "
                         "are 'trace', 'hetero', and 'minibatch' (tile and "
                         "full graphs are recognized by their field sets)")
    if kind == "hetero":
        return _normalized_hetero_graph(graph), "hetero"
    if kind == "minibatch":
        return _normalized_minibatch_graph(graph), "minibatch"
    if kind == "trace" or "dataset" in keys:
        return _normalized_trace_graph(graph), "trace"
    if {"V", "E"} & keys:
        missing = set(FULL_GRAPH_FIELDS) - keys
        if missing:
            raise ValueError(f"full-graph scenario is missing {sorted(missing)}; "
                             f"required: {FULL_GRAPH_FIELDS}")
        allowed = set(FULL_GRAPH_FIELDS) | {"high_degree_fraction"}
        extra = keys - allowed
        if extra:
            raise ValueError(f"unknown full-graph keys {sorted(extra)}; "
                             f"allowed: {sorted(allowed)}")
        out = {f: _require_nonneg(graph[f], f"graph.{f}")
               for f in FULL_GRAPH_FIELDS}
        out["high_degree_fraction"] = _require_fraction(
            graph.get("high_degree_fraction", 0.1),
            "graph.high_degree_fraction")
        return out, "full"
    missing = set(TILE_GRAPH_FIELDS) - keys
    extra = keys - set(TILE_GRAPH_FIELDS)
    if missing or extra:
        raise ValueError(
            f"tile scenario graph must give exactly {TILE_GRAPH_FIELDS} "
            f"(missing {sorted(missing)}, unknown {sorted(extra)}); "
            "use Scenario.tile(...) to fill the paper's defaults, give "
            "V/E for a full-graph scenario, or kind='trace' with a "
            "dataset reference for an exact edge-list scenario")
    return ({f: _require_number(graph[f], f"graph.{f}")
             for f in TILE_GRAPH_FIELDS}, "tile")


@dataclass(frozen=True)
class Scenario:
    """One declarative, JSON-round-trippable evaluation request.

    Attributes:
      dataflow: registered accelerator name (``repro.core.registry``).
      graph: tile parameters (``N,T,K,L,P``) or full-graph parameters
        (``V,E,N,T`` + optional ``high_degree_fraction``).
      hardware: overrides applied to the dataflow's default hardware
        record; keys must be fields of that record.
      composition: optional §7 policy (layer widths / residency / tiling).
      conformance: request the §10 measured-vs-modeled check (one
        operating point) for dataflows with a runnable kernel analogue.
      expect: optional pinned totals (``total_bits``, ``total_iterations``;
        plus ``objective`` / ``best_dataflow`` / ``best_tile_vertices``
        for optimize scenarios) — the golden-drift gate for checked-in
        scenario files.
      label / workload: free-form identification carried through results.
      optimize: optional §15 search block (``objective`` / ``budget`` /
        ``space`` / ``method``); normalized via
        :func:`repro.core.tune.normalize_optimize`.  The planner routes
        optimize scenarios through the tuner; ``dataflow`` and the
        composition then act as the search's base point (axes missing
        from the space pin to their values).
    """

    dataflow: str
    graph: Mapping[str, float]
    hardware: Mapping[str, float] = field(default_factory=dict)
    composition: Optional[Composition] = None
    conformance: bool = False
    expect: Optional[Mapping[str, float]] = None
    label: str = ""
    workload: str = ""
    optimize: Optional[Mapping[str, Any]] = None

    def __post_init__(self) -> None:
        if not isinstance(self.dataflow, str) or not self.dataflow:
            raise ValueError(f"dataflow must be a non-empty accelerator "
                             f"name, got {self.dataflow!r}")
        graph, kind = _normalized_graph(dict(self.graph))
        object.__setattr__(self, "graph", graph)
        object.__setattr__(self, "_graph_kind", kind)
        hardware = {str(k): _require_number(v, f"hardware.{k}")
                    for k, v in dict(self.hardware).items()}
        object.__setattr__(self, "hardware", hardware)
        if self.composition is not None and not isinstance(self.composition,
                                                           Composition):
            object.__setattr__(self, "composition",
                               Composition.from_dict(self.composition))
        tiled = (self.composition is not None
                 and self.composition.tile_vertices is not None)
        if kind == "full" and not tiled:
            raise ValueError(
                "a full-graph scenario (V/E) needs a composition with "
                "tile_vertices — the tile schedule is what maps V/E onto "
                "the per-tile closed forms (DESIGN.md §7)")
        if kind == "tile" and tiled:
            raise ValueError(
                "tile_vertices tiling requires a full-graph scenario "
                "(give V/E instead of K/L/P)")
        if kind in ("trace", "hetero"):
            if not tiled:
                raise ValueError(
                    f"a {kind} scenario needs a composition with "
                    "tile_vertices — the capacity sets the exact tile "
                    "schedule the edge list is partitioned into "
                    "(DESIGN.md §12)")
            if self.composition.halo_dedup != 1.0:
                raise ValueError(
                    f"halo_dedup must stay 1 for a {kind} scenario: the "
                    "exact schedule already deduplicates remote sources "
                    "per tile, so a divisor would double-count the dedup")
        if kind == "minibatch" and tiled:
            raise ValueError(
                "a minibatch scenario must not set tile_vertices: each "
                "sampling episode is already one exact schedule tile "
                "(the seed batch), so a second tiling layer has no "
                "meaning (DESIGN.md §17)")
        arity = (None if self.composition is None
                 else self.composition.relation_arity())
        if kind == "hetero":
            R = self.graph["n_relations"]
            if arity is not None and arity != R:
                raise ValueError(
                    f"per-relation composition values have arity {arity} "
                    f"but the graph declares n_relations={R}; every "
                    "per-relation widths entry / residency list must have "
                    "exactly R entries")
            if self.composition.widths is not None:
                for i, w in enumerate(self.composition.widths):
                    if isinstance(w, tuple) and len(w) != R:
                        raise ValueError(
                            f"composition.widths[{i}] has {len(w)} "
                            f"per-relation entries for n_relations={R}")
            if (isinstance(self.composition.residency, tuple)
                    and len(self.composition.residency) != R):
                raise ValueError(
                    f"composition.residency lists "
                    f"{len(self.composition.residency)} policies for "
                    f"n_relations={R}; give one policy or exactly R")
        elif arity is not None:
            raise ValueError(
                f"per-relation composition values (arity {arity}) are "
                f"only meaningful for a hetero scenario, not kind "
                f"{kind!r}: other graph kinds have no relation axis")
        if self.optimize is not None:
            # The schema lives next to the engine that interprets it
            # (repro.core.tune is import-light: stdlib + numpy).
            from repro.core.tune import normalize_optimize
            opt = normalize_optimize(self.optimize)
            object.__setattr__(self, "optimize", opt)
            if kind == "tile":
                raise ValueError(
                    "an optimize block needs a full-graph or trace "
                    "scenario: the search axes (tile capacity, residency, "
                    "halo policy) are composition-layer knobs with no "
                    "meaning for a single Table-II tile")
            if kind == "minibatch":
                raise ValueError(
                    "an optimize block cannot attach to a minibatch "
                    "scenario: its search axes (tile capacity, halo "
                    "policy) are tiling knobs, and the episode schedule "
                    "is fixed by the sampler; tune the sampling "
                    "parameters by sweeping scenarios instead")
            if self.conformance:
                raise ValueError(
                    "optimize and conformance are mutually exclusive on "
                    "one scenario: run the §10 check on the tuned winner "
                    "as a concrete scenario instead")
            space = opt["space"]
            if kind in ("trace", "hetero"):
                for h in space.get("halo_dedup", ()):
                    if h != 1.0:
                        raise ValueError(
                            f"space.halo_dedup must stay [1] for a {kind} "
                            "scenario: the exact schedule already "
                            "deduplicates remote sources per tile")
            if ("resident" in space.get("residency", ())
                    and self.composition.widths is None):
                raise ValueError(
                    "space.residency includes 'resident' but the scenario "
                    "has no layer widths; residency governs inter-layer "
                    "hand-off, so give composition.widths")
        if self.expect is not None:
            known = {"total_bits", "total_iterations"}
            if self.optimize is not None:
                known |= {"objective", "best_dataflow", "best_tile_vertices"}
            unknown = set(self.expect) - known
            if unknown:
                raise ValueError(f"unknown expect keys {sorted(unknown)}; "
                                 f"expected a subset of {sorted(known)}")
            normalized: dict[str, Any] = {}
            for k, v in dict(self.expect).items():
                if k == "best_dataflow":
                    if not isinstance(v, str) or not v:
                        raise ValueError(f"expect.best_dataflow must be a "
                                         f"non-empty dataflow name, got {v!r}")
                    normalized[k] = v
                else:
                    normalized[k] = _require_number(v, f"expect.{k}")
            object.__setattr__(self, "expect", normalized)

    # -- constructors -----------------------------------------------------
    @classmethod
    def tile(cls, dataflow: str, *, K: float = 1024.0, N: float = 30.0,
             T: float = 5.0, L: Optional[float] = None,
             P: Optional[float] = None, edge_factor: float = 10.0,
             high_degree_fraction: float = 0.1, **kw: Any) -> "Scenario":
        """Single-tile scenario at the paper's Sec. IV defaults.

        Mirrors :func:`repro.core.notation.paper_default_graph`: unless
        given, ``L = floor(K * high_degree_fraction)`` and
        ``P = K * edge_factor``.
        """
        K = _require_number(K, "K")
        graph = {
            "N": _require_number(N, "N"), "T": _require_number(T, "T"),
            "K": K,
            "L": (math.floor(K * high_degree_fraction) if L is None
                  else _require_number(L, "L")),
            "P": K * edge_factor if P is None else _require_number(P, "P"),
        }
        return cls(dataflow=dataflow, graph=graph, **kw)

    @classmethod
    def full_graph(cls, dataflow: str, *, V: float, E: float, N: float,
                   T: float, tile_vertices: float = 1024.0,
                   widths: Optional[Sequence[float]] = None,
                   residency: str = "spill", halo_dedup: float = 1.0,
                   high_degree_fraction: float = 0.1, **kw: Any) -> "Scenario":
        """Full-graph scenario: tile schedule + optional multi-layer chain."""
        comp = Composition(
            widths=None if widths is None else tuple(widths),
            residency=residency, tile_vertices=tile_vertices,
            halo_dedup=halo_dedup)
        graph = {"V": V, "E": E, "N": N, "T": T,
                 "high_degree_fraction": high_degree_fraction}
        return cls(dataflow=dataflow, graph=graph, composition=comp, **kw)

    @classmethod
    def trace(cls, dataflow: str, *, dataset: str,
              params: Optional[Mapping[str, float]] = None, N: float,
              T: float, tile_vertices: float = 1024.0,
              widths: Optional[Sequence[float]] = None,
              residency: str = "spill",
              high_degree_fraction: float = 0.1, **kw: Any) -> "Scenario":
        """Trace scenario: exact edge-list schedule over a named dataset.

        ``dataset`` / ``params`` reference a registered deterministic
        trace dataset (:func:`repro.core.trace.resolve_trace_dataset`);
        the graph's V/E come from the resolved edge list, so only the
        feature widths are declared here (DESIGN.md §12).
        """
        comp = Composition(
            widths=None if widths is None else tuple(widths),
            residency=residency, tile_vertices=tile_vertices)
        graph = {"kind": "trace", "dataset": dataset,
                 "params": dict(params or {}), "N": N, "T": T,
                 "high_degree_fraction": high_degree_fraction}
        return cls(dataflow=dataflow, graph=graph, composition=comp, **kw)

    @classmethod
    def hetero(cls, dataflow: str, *, dataset: str, n_relations: int,
               params: Optional[Mapping[str, float]] = None,
               N: Any = 30.0, T: Any = 5.0,
               tile_vertices: float = 1024.0,
               widths: Optional[Sequence[Any]] = None,
               residency: Any = "spill",
               high_degree_fraction: float = 0.1, **kw: Any) -> "Scenario":
        """Typed-graph scenario: relational schedule over a typed dataset.

        ``N`` / ``T`` / each ``widths`` entry may be a scalar or a
        length-``n_relations`` list; ``residency`` one policy or a
        per-relation list (DESIGN.md §17).
        """
        comp = Composition(
            widths=None if widths is None else tuple(widths),
            residency=residency, tile_vertices=tile_vertices)
        graph = {"kind": "hetero", "dataset": dataset,
                 "params": dict(params or {}), "n_relations": n_relations,
                 "N": N, "T": T,
                 "high_degree_fraction": high_degree_fraction}
        return cls(dataflow=dataflow, graph=graph, composition=comp, **kw)

    @classmethod
    def minibatch(cls, dataflow: str, *, dataset: str,
                  params: Optional[Mapping[str, float]] = None,
                  batch_nodes: int, fanout: Sequence[int],
                  n_batches: int, seed: int = 0,
                  N: float = 30.0, T: float = 5.0,
                  widths: Optional[Sequence[float]] = None,
                  residency: str = "spill",
                  high_degree_fraction: float = 0.1, **kw: Any) -> "Scenario":
        """Sampled-minibatch scenario: fanout episodes as schedule tiles."""
        comp = (None if widths is None else Composition(
            widths=tuple(widths), residency=residency))
        graph = {"kind": "minibatch", "dataset": dataset,
                 "params": dict(params or {}), "batch_nodes": batch_nodes,
                 "fanout": tuple(fanout), "n_batches": n_batches,
                 "seed": seed, "N": N, "T": T,
                 "high_degree_fraction": high_degree_fraction}
        return cls(dataflow=dataflow, graph=graph, composition=comp, **kw)

    # -- structure --------------------------------------------------------
    def _graph_key(self) -> tuple:
        """Canonical hashable view of the graph mapping (nested params)."""
        return tuple(
            (k, tuple(sorted(v.items())) if isinstance(v, Mapping) else v)
            for k, v in sorted(self.graph.items()))

    def _optimize_key(self) -> Optional[str]:
        """Canonical (sorted-JSON) form of the normalized optimize block."""
        if self.optimize is None:
            return None
        return json.dumps(self.optimize, sort_keys=True,
                          separators=(",", ":"))

    def __hash__(self) -> int:
        # frozen=True would auto-hash over the dict fields and raise; hash
        # the canonical tuple instead so scenarios work in sets/dict keys.
        expect = (None if self.expect is None
                  else tuple(sorted(self.expect.items())))
        return hash((self.dataflow, self._graph_key(),
                     tuple(sorted(self.hardware.items())), self.composition,
                     self.conformance, expect, self.label, self.workload,
                     self._optimize_key()))

    @property
    def graph_kind(self) -> str:
        """``"tile"``, ``"full"``, ``"trace"``, ``"hetero"``, or
        ``"minibatch"``."""
        return self._graph_kind  # type: ignore[attr-defined]

    def plan_key(self) -> tuple:
        """Hashable signature of everything that cannot batch numerically.

        Scenarios sharing a plan key differ only in numeric leaves (graph
        values, hardware override values, widths values, tile capacities),
        all of which stack along one batch axis for a single broadcast
        evaluation (DESIGN.md §11).  For trace scenarios the dataset
        reference is structural too (it fixes the concrete edge list),
        but the tile capacity is **not** (DESIGN.md §13): same-dataset
        trace scenarios differing only in ``tile_vertices`` stack along
        the capacity axis of one exact-schedule evaluation, every
        capacity's schedule amortized over one shared edge-list
        factorization.
        """
        comp = None if self.composition is None else self.composition.signature()
        key = (self.dataflow, self.graph_kind,
               tuple(sorted(self.hardware)), comp)
        if self.graph_kind == "trace":
            key += (self.graph["dataset"],
                    tuple(sorted(self.graph["params"].items())))
        elif self.graph_kind == "hetero":
            # The relation signature is structural (DESIGN.md §17): the
            # dataset+params+R fix the typed edge list, and scalar-vs-
            # per-relation N/T fix the stacked leaves' shapes.  Tile
            # capacity still stacks along the capacity axis, so one group
            # serves an R-relation batch regardless of R.
            key += (self.graph["dataset"],
                    tuple(sorted(self.graph["params"].items())),
                    self.graph["n_relations"],
                    isinstance(self.graph["N"], tuple),
                    isinstance(self.graph["T"], tuple))
        elif self.graph_kind == "minibatch":
            # The whole sampling protocol is structural: it fixes the
            # episode schedule (its rng stream included), so only N/T and
            # hardware values batch.
            key += (self.graph["dataset"],
                    tuple(sorted(self.graph["params"].items())),
                    self.graph["batch_nodes"], self.graph["fanout"],
                    self.graph["n_batches"], self.graph["seed"])
        if self.optimize is not None:
            # An optimize scenario is a search request, not a concrete
            # evaluation: it never batches with plain scenarios (the
            # planner routes it through repro.core.tune), and two
            # searches share a key only for identical canonical blocks.
            key += ("optimize", self._optimize_key())
        return key

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> dict:
        graph = {k: dict(v) if isinstance(v, Mapping) else v
                 for k, v in self.graph.items()}
        out: dict[str, Any] = {"dataflow": self.dataflow, "graph": graph}
        if self.hardware:
            out["hardware"] = dict(self.hardware)
        if self.composition is not None:
            out["composition"] = self.composition.to_dict()
        if self.conformance:
            out["conformance"] = True
        if self.expect is not None:
            out["expect"] = dict(self.expect)
        if self.label:
            out["label"] = self.label
        if self.workload:
            out["workload"] = self.workload
        if self.optimize is not None:
            # Deep-copy through JSON: the normalized block is JSON-able
            # by construction and the caller must not alias our state.
            out["optimize"] = json.loads(self._optimize_key())
        return out

    @classmethod
    def from_dict(cls, data: Mapping[str, Any]) -> "Scenario":
        known = {"dataflow", "graph", "hardware", "composition",
                 "conformance", "expect", "label", "workload", "optimize"}
        unknown = set(data) - known
        if unknown:
            raise ValueError(f"unknown Scenario keys {sorted(unknown)}; "
                             f"expected a subset of {sorted(known)}")
        for req in ("dataflow", "graph"):
            if req not in data:
                raise ValueError(f"Scenario is missing required key {req!r}")
        comp = data.get("composition")
        return cls(
            dataflow=data["dataflow"],
            graph=data["graph"],
            hardware=data.get("hardware", {}),
            composition=(None if comp is None else
                         Composition.from_dict(comp)),
            conformance=bool(data.get("conformance", False)),
            expect=data.get("expect"),
            label=data.get("label", ""),
            workload=data.get("workload", ""),
            optimize=data.get("optimize"),
        )

    def to_json(self, **json_kw: Any) -> str:
        return json.dumps(self.to_dict(), sort_keys=True, **json_kw)

    @classmethod
    def from_json(cls, text: str) -> "Scenario":
        return cls.from_dict(json.loads(text))

    def replace(self, **kw: Any) -> "Scenario":
        return dataclasses.replace(self, **kw)


def _trusted_tile(dataflow: str, graph: Mapping[str, float],
                  hardware: Mapping[str, float], label: str = "",
                  workload: str = "") -> Scenario:
    """Construct a plain tile Scenario bypassing validation (hot path).

    For the figure templates, which build one scenario per grid cell from
    values they already normalized (finite float64s, exactly the tile
    field set, no composition): skipping ``__post_init__`` keeps the
    legacy sweep functions within a small factor of their pre-redesign
    cost.  Callers outside :mod:`repro.api.templates` must use the public
    constructors.
    """
    s = object.__new__(Scenario)
    set_ = object.__setattr__
    set_(s, "dataflow", dataflow)
    set_(s, "graph", dict(graph))
    set_(s, "hardware", dict(hardware))
    set_(s, "composition", None)
    set_(s, "conformance", False)
    set_(s, "expect", None)
    set_(s, "label", label)
    set_(s, "workload", workload)
    set_(s, "optimize", None)
    set_(s, "_graph_kind", "tile")
    return s


def scenarios_to_dicts(scenarios: Sequence[Scenario]) -> dict:
    return {"scenarios": [s.to_dict() for s in scenarios]}


def dump_scenarios(scenarios: Sequence[Scenario], path: str) -> None:
    """Write a scenario batch file: ``{"scenarios": [...]}``."""
    with open(path, "w") as f:
        json.dump(scenarios_to_dicts(scenarios), f, indent=2, sort_keys=True)
        f.write("\n")


def load_scenarios(path: str) -> list[Scenario]:
    """Read a batch file: ``{"scenarios": [...]}`` or a bare JSON list."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, Mapping):
        if "scenarios" not in data:
            raise ValueError(f"{path}: scenario batch object must carry a "
                             "'scenarios' list")
        data = data["scenarios"]
    if not isinstance(data, list):
        raise ValueError(f"{path}: expected a scenario list or "
                         "{'scenarios': [...]} object")
    return [Scenario.from_dict(d) for d in data]
