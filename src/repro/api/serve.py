"""Scenario-serving engine: cross-request coalescing over warm caches.

The scenario front door (DESIGN.md §11) evaluates one batch per call —
every caller pays its own planner pass, trace resolution, and broadcast
evaluation even when thousands of concurrent queries ask the same
question.  :class:`ServeEngine` closes that gap (DESIGN.md §18): it
accepts concurrent scenario-batch requests, holds them for a bounded
micro-batching **window**, deduplicates identical scenarios **across
requests** (:func:`~repro.api.planner.coalesce_scenarios`), evaluates
the distinct set through the ordinary batch planner — which collapses
the survivors further into one broadcast call per plan group — and
scatters results back per caller.

Bit-identity is inherited, not re-proved: the window evaluates through
the same :func:`~repro.api.planner.evaluate_scenarios` a serial caller
would use, and scattering only *copies* result slots, so every served
number is exactly the serial oracle's (pinned in tests/test_serve.py
and gated in benchmarks/serve.py).

Shared warm state does the rest of the work: the process-wide resolved-
trace LRU and the content-addressed on-disk
:mod:`~repro.core.schedule_cache` (both made concurrency-safe in this
PR) mean the first window pays for trace resolution and schedule
computes and every later window rides the caches.  Each result carries
``meta["serve"]`` — the window's coalesce rate, evaluation count, and
cache hit/miss deltas plus the request's own latency — so a caller can
see exactly what its query cost.

Threading model
---------------
One dispatcher thread owns the queue: it wakes on the first enqueue,
sleeps ``window_s`` to let concurrent arrivals pile up, drains the
queue (bounded by ``max_window_scenarios``), and processes the batch.
Submissions are validated in the *caller's* thread — a malformed
request raises :class:`ServeError` at ``submit`` time and never reaches
the loop.  Evaluation-time failures (e.g. an unregistered dataflow) are
isolated by falling back to per-request evaluation, failing only the
offending requests' futures; the loop itself never dies.

``run_once()`` drains one window synchronously on the calling thread —
no dispatcher, no timing — which is what the tests and the benchmark's
deterministic sections use.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Mapping, Optional, Sequence

from repro.core import schedule_cache
from repro.core.trace import trace_cache_info

from .planner import ScenarioResult, coalesce_scenarios, evaluate_scenarios
from .scenario import Scenario

__all__ = ["ServeEngine", "ServeResult", "ServeError"]

#: Stats whose per-window deltas feed the ``meta["serve"]["cache"]``
#: block (keys of ``trace_cache_info()["stats"]``).
_TRACE_STAT_KEYS = ("trace_builds", "factorizations", "schedule_computes",
                    "schedule_cache_hits", "schedule_disk_hits")


class ServeError(ValueError):
    """A malformed serve request, rejected at submit time."""


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One request's results plus the window record that produced them.

    ``results`` are in the request's scenario order, each additionally
    carrying the same window record under ``meta["serve"]``.
    """

    results: tuple[ScenarioResult, ...]
    serve: Mapping[str, Any]

    def to_dict(self) -> dict:
        return {"results": [r.to_dict() for r in self.results],
                "serve": dict(self.serve)}


@dataclasses.dataclass
class _Request:
    scenarios: list[Scenario]
    future: Future
    t_submit: float


def _normalize_request(scenarios) -> list[Scenario]:
    """Validate a submission into a non-empty list of Scenarios.

    Accepts a single :class:`Scenario` / scenario dict or a sequence of
    them; anything else raises :class:`ServeError` in the caller's
    thread, so bad input can never poison the dispatcher.
    """
    if isinstance(scenarios, (Scenario, Mapping)):
        scenarios = [scenarios]
    if not isinstance(scenarios, Sequence) or isinstance(scenarios,
                                                         (str, bytes)):
        raise ServeError(
            f"a serve request is a Scenario, a scenario dict, or a "
            f"sequence of them; got {type(scenarios).__name__}")
    out: list[Scenario] = []
    for i, s in enumerate(scenarios):
        if isinstance(s, Scenario):
            out.append(s)
        elif isinstance(s, Mapping):
            try:
                out.append(Scenario.from_dict(s))
            except (TypeError, ValueError, KeyError) as exc:
                raise ServeError(
                    f"request scenario #{i} is malformed: {exc}") from exc
        else:
            raise ServeError(
                f"request scenario #{i} is {type(s).__name__}, expected "
                f"Scenario or mapping")
    if not out:
        raise ServeError("empty request: a serve request needs >= 1 scenario")
    return out


class ServeEngine:
    """Micro-batching scenario evaluation service (DESIGN.md §18).

    Args:
      window_s: how long the dispatcher waits after the first arrival
        for more requests to coalesce with (seconds; 0 processes each
        wakeup's backlog immediately).
      max_window_scenarios: scenario budget per window; a window closes
        early rather than exceed it (a single over-budget request still
        gets its own window — requests are never split).
      conformance_points: forwarded to
        :func:`~repro.api.planner.evaluate_scenarios`.

    Use as a context manager (``with ServeEngine() as eng: ...``) or
    call :meth:`start` / :meth:`stop` explicitly; :meth:`stop` drains
    every queued request before returning, so no accepted future is
    left dangling.  For synchronous, timing-free operation skip
    ``start()`` entirely and call :meth:`run_once` after submitting.
    """

    def __init__(self, *, window_s: float = 0.002,
                 max_window_scenarios: int = 4096,
                 conformance_points=None) -> None:
        window_s = float(window_s)
        if not window_s >= 0.0:
            raise ValueError(f"window_s must be >= 0, got {window_s!r}")
        max_window_scenarios = int(max_window_scenarios)
        if max_window_scenarios < 1:
            raise ValueError(f"max_window_scenarios must be >= 1, "
                             f"got {max_window_scenarios!r}")
        self.window_s = window_s
        self.max_window_scenarios = max_window_scenarios
        self._conformance_points = conformance_points
        self._cond = threading.Condition()
        self._queue: deque[_Request] = deque()
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._metrics_lock = threading.Lock()
        self._metrics = {
            "windows": 0,
            "requests": 0,
            "scenarios": 0,
            "distinct_scenarios": 0,
            "evaluations": 0,
            "rejected_requests": 0,
            "failed_requests": 0,
            "fallback_windows": 0,
        }

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "ServeEngine":
        with self._cond:
            if self._running:
                raise RuntimeError("ServeEngine is already running")
            self._running = True
        self._thread = threading.Thread(target=self._loop,
                                        name="repro-serve", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the dispatcher, draining queued requests first."""
        with self._cond:
            self._running = False
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        # Anything submitted after the dispatcher exited still resolves.
        while self.run_once():
            pass

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission --------------------------------------------------------
    def submit_future(self, scenarios) -> Future:
        """Enqueue one request; returns a Future of :class:`ServeResult`."""
        try:
            normalized = _normalize_request(scenarios)
        except ServeError:
            with self._metrics_lock:
                self._metrics["rejected_requests"] += 1
            raise
        req = _Request(scenarios=normalized, future=Future(),
                       t_submit=time.perf_counter())
        with self._cond:
            self._queue.append(req)
            self._cond.notify()
        return req.future

    def submit(self, scenarios, timeout: Optional[float] = None) -> ServeResult:
        """Blocking submit: enqueue and wait for the ServeResult."""
        return self.submit_future(scenarios).result(timeout)

    async def asubmit(self, scenarios) -> ServeResult:
        """Awaitable submit for asyncio callers (wraps the Future)."""
        import asyncio

        return await asyncio.wrap_future(self.submit_future(scenarios))

    def run_once(self) -> int:
        """Drain one window synchronously; returns requests processed.

        The deterministic path: no dispatcher thread, no window timing —
        whatever is queued *now* (bounded by ``max_window_scenarios``)
        becomes exactly one coalesced window on the calling thread.
        """
        batch = self._pop_window()
        if batch:
            self._process_window(batch)
        return len(batch)

    def metrics(self) -> dict:
        """Cumulative engine counters plus the derived coalesce rate."""
        with self._metrics_lock:
            out = dict(self._metrics)
        n = out["scenarios"]
        out["coalesce_rate"] = (1.0 - out["evaluations"] / n) if n else 0.0
        return out

    # -- the dispatcher ----------------------------------------------------
    def _loop(self) -> None:
        while True:
            with self._cond:
                while self._running and not self._queue:
                    self._cond.wait()
                if not self._queue:
                    return  # stopped and drained
                running = self._running
            if running and self.window_s > 0.0:
                time.sleep(self.window_s)  # let concurrent arrivals land
            batch = self._pop_window()
            if batch:
                self._process_window(batch)

    def _pop_window(self) -> list[_Request]:
        out: list[_Request] = []
        n = 0
        with self._cond:
            while self._queue:
                take = len(self._queue[0].scenarios)
                if out and n + take > self.max_window_scenarios:
                    break  # next request opens the next window
                out.append(self._queue.popleft())
                n += take
        return out

    def _process_window(self, batch: list[_Request]) -> None:
        t0 = time.perf_counter()
        flat: list[Scenario] = []
        spans: list[tuple[int, int]] = []
        for req in batch:
            start = len(flat)
            flat.extend(req.scenarios)
            spans.append((start, len(flat)))
        distinct, backmap = coalesce_scenarios(flat)
        stats0 = trace_cache_info()["stats"]
        disk0 = schedule_cache.cache_stats()["counters"]
        try:
            res = evaluate_scenarios(
                distinct, conformance_points=self._conformance_points)
        except Exception:
            # One bad scenario must not fail its window-mates: re-evaluate
            # per request, failing only the offenders' futures.
            self._fallback(batch)
            return
        stats1 = trace_cache_info()["stats"]
        disk1 = schedule_cache.cache_stats()["counters"]
        # Broadcast groups + tuner runs = closed-form planner invocations
        # this window actually performed for len(flat) requested scenarios.
        n_evals = res.n_evaluations + sum(
            1 for s in distinct if s.optimize is not None)
        n = len(flat)
        sched_hits = (stats1["schedule_cache_hits"]
                      - stats0["schedule_cache_hits"])
        sched_disk = (stats1["schedule_disk_hits"]
                      - stats0["schedule_disk_hits"])
        sched_miss = (stats1["schedule_computes"]
                      - stats0["schedule_computes"])
        probed = sched_hits + sched_disk + sched_miss
        with self._metrics_lock:
            window_id = self._metrics["windows"]
            self._metrics["windows"] += 1
            self._metrics["requests"] += len(batch)
            self._metrics["scenarios"] += n
            self._metrics["distinct_scenarios"] += len(distinct)
            self._metrics["evaluations"] += n_evals
        window = {
            "window": window_id,
            "fallback": False,
            "n_requests": len(batch),
            "n_scenarios": n,
            "n_distinct_scenarios": len(distinct),
            "n_evaluations": n_evals,
            "coalesce_rate": (1.0 - n_evals / n) if n else 0.0,
            "eval_s": time.perf_counter() - t0,
            "cache": {
                **{k: stats1[k] - stats0[k] for k in _TRACE_STAT_KEYS},
                "schedule_hit_rate": ((sched_hits + sched_disk) / probed
                                      if probed else None),
                "disk_graph_hits": (disk1["graph_hits"]
                                    - disk0["graph_hits"]),
                "disk_schedule_hits": (disk1["schedule_hits"]
                                       - disk0["schedule_hits"]),
            },
        }
        done = time.perf_counter()
        for (lo, hi), req in zip(spans, batch):
            serve = {**window,
                     "request_scenarios": hi - lo,
                     "latency_s": done - req.t_submit}
            results = tuple(
                dataclasses.replace(
                    res.results[backmap[j]],
                    meta={**dict(res.results[backmap[j]].meta),
                          "serve": serve})
                for j in range(lo, hi))
            self._finish(req, ServeResult(results=results, serve=serve))

    def _fallback(self, batch: list[_Request]) -> None:
        """Per-request isolation after a window-level evaluation failure."""
        with self._metrics_lock:
            window_id = self._metrics["windows"]
            self._metrics["windows"] += 1
            self._metrics["fallback_windows"] += 1
            self._metrics["requests"] += len(batch)
        for req in batch:
            n = len(req.scenarios)
            try:
                res = evaluate_scenarios(
                    req.scenarios,
                    conformance_points=self._conformance_points)
            except Exception as exc:
                with self._metrics_lock:
                    self._metrics["failed_requests"] += 1
                self._finish(req, exc, is_error=True)
                continue
            n_evals = res.n_evaluations + sum(
                1 for s in req.scenarios if s.optimize is not None)
            with self._metrics_lock:
                self._metrics["scenarios"] += n
                self._metrics["distinct_scenarios"] += n
                self._metrics["evaluations"] += n_evals
            serve = {
                "window": window_id,
                "fallback": True,
                "n_requests": 1,
                "n_scenarios": n,
                "n_distinct_scenarios": n,
                "n_evaluations": n_evals,
                "coalesce_rate": 0.0,
                "request_scenarios": n,
                "latency_s": time.perf_counter() - req.t_submit,
            }
            results = tuple(
                dataclasses.replace(r, meta={**dict(r.meta), "serve": serve})
                for r in res.results)
            self._finish(req, ServeResult(results=results, serve=serve))

    @staticmethod
    def _finish(req: _Request, payload, *, is_error: bool = False) -> None:
        try:
            if is_error:
                req.future.set_exception(payload)
            else:
                req.future.set_result(payload)
        except Exception:
            pass  # caller cancelled the future; nothing left to deliver
