"""Named scenario templates: the paper's figures as declarative batches.

Each template builds the exact scenario batch behind one legacy surface —
the ``figN_*`` sweep functions of :mod:`repro.core.sweep` are thin clients
that evaluate these batches and reshape the stacked results onto the
figure's grid (bit-identical to the seed implementation, pinned in
``tests/test_registry.py``).  Templates return a :class:`TemplateBatch`:
the scenarios plus labelled grid axes (meshgrid ``ij`` order, C-raveled),
so both the sweep engine and the ``python -m repro.api`` CLI can replay
them.

``TEMPLATES`` is the by-name directory (``fig3`` .. ``fig7``,
``comparison``, ``cora_end_to_end``) served by ``--template`` and
``--list``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Mapping, Optional, Sequence

import numpy as np

from repro.core import registry
from repro.core.notation import GraphTileParams, paper_default_graph
from repro.core.trace import GraphTrace, register_trace_dataset

from .scenario import Scenario, _trusted_tile

__all__ = [
    "TemplateBatch",
    "TEMPLATES",
    "template",
    "template_names",
    "tile_scenarios_from_graph",
    "trace_scenarios_from_graph",
    "DEFAULT_K_SWEEP",
    "DEFAULT_M_SWEEP",
    "DEFAULT_B_SWEEP",
]

# Canonical sweep grids (Sec. IV operating ranges); re-exported by
# repro.core.sweep for backwards compatibility.
DEFAULT_K_SWEEP = np.array([64, 128, 256, 512, 1024, 2048, 4096, 8192],
                           dtype=np.float64)
DEFAULT_M_SWEEP = np.array([4, 8, 16, 32, 64, 128, 256], dtype=np.float64)
DEFAULT_B_SWEEP = np.logspace(1, 5, 33, dtype=np.float64)  # 10..100k bits/iter


@dataclass(frozen=True)
class TemplateBatch:
    """A scenario batch plus the labelled grid it flattens (C order)."""

    figure: str
    scenarios: tuple[Scenario, ...]
    axes: Mapping[str, np.ndarray]
    meta: Mapping[str, object] = field(default_factory=dict)

    @property
    def grid_shape(self) -> tuple[int, ...]:
        return tuple(len(np.atleast_1d(v)) for v in self.axes.values())


def _f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def _grid(*axes: np.ndarray) -> tuple[np.ndarray, ...]:
    return tuple(np.meshgrid(*axes, indexing="ij"))


def tile_scenarios_from_graph(
    dataflow: str,
    graph: GraphTileParams,
    shape: tuple[int, ...],
    hardware: Optional[Mapping[str, np.ndarray]] = None,
    **scenario_kw,
) -> list[Scenario]:
    """Flatten (possibly broadcast/array-valued) tile params to scenarios.

    Every graph field and hardware override is broadcast to ``shape`` and
    C-raveled; cell ``j`` of the flat order becomes one scenario.  The
    planner re-stacks the cells into one broadcast evaluation, so the
    round trip through pure data is bit-identical to evaluating the
    original array-valued graph directly.
    """
    fields = {f: np.broadcast_to(_f64(getattr(graph, f)), shape).ravel()
              for f in ("N", "T", "K", "L", "P")}
    hw = {k: np.broadcast_to(_f64(v), shape).ravel()
          for k, v in (hardware or {}).items()}
    if not np.all([np.isfinite(col).all() for col in fields.values()] +
                  [np.isfinite(col).all() for col in hw.values()]):
        raise ValueError(f"non-finite graph/hardware values for {dataflow!r}")
    # One tolist per column (not one numpy scalar read per cell) keeps the
    # flatten within a small factor of the pre-redesign meshgrid path.
    fnames, fcols = list(fields), [c.tolist() for c in fields.values()]
    hnames, hcols = list(hw), [c.tolist() for c in hw.values()]
    n = int(np.prod(shape)) if shape else 1
    if set(scenario_kw) <= {"label", "workload"}:
        # Values were validated above in one vectorized shot, so the cells
        # can take the trusted fast path (hot: one object per grid cell).
        return [
            _trusted_tile(dataflow,
                          dict(zip(fnames, cell)),
                          dict(zip(hnames, hcell)),
                          **scenario_kw)
            for cell, hcell in zip(zip(*fcols), zip(*hcols) if hcols
                                   else ((),) * n)
        ]
    return [
        Scenario(dataflow=dataflow,
                 graph=dict(zip(fnames, cell)),
                 hardware=dict(zip(hnames, hcell)),
                 **scenario_kw)
        for cell, hcell in zip(zip(*fcols), zip(*hcols) if hcols
                               else ((),) * n)
    ]


def trace_scenarios_from_graph(
    graph,
    name: str,
    *,
    dataflows: Optional[Sequence[str]] = None,
    tile_vertices: Sequence[float] = (1024.0,),
    N: Optional[float] = None,
    T: Optional[float] = None,
    widths: Optional[Sequence[float]] = None,
    residency: str = "spill",
    high_degree_fraction: float = 0.1,
    workload: str = "",
    overwrite: bool = False,
) -> list[Scenario]:
    """Exact-schedule scenarios over an in-memory graph (DESIGN.md §12).

    ``graph`` is a :class:`~repro.core.trace.GraphTrace` or anything with
    ``senders``/``receivers``/``n_nodes`` (e.g. a
    :class:`repro.data.synthetic.GraphArrays`).  It is registered as the
    parameterless trace dataset ``name``, and one ``{"kind": "trace"}``
    scenario per (dataflow, tile capacity) referencing it is returned.
    The scenarios are pure data, but they replay only where ``name`` is
    registered — for cross-process scenario files, reference the built-in
    deterministic datasets (``power_law``, ``cora``, ...) instead.

    Either ``widths`` (multi-layer chain; N/T default to its endpoints)
    or explicit ``N``/``T`` must be given.
    """
    trace = graph if isinstance(graph, GraphTrace) else GraphTrace.from_arrays(graph)
    if widths is not None:
        widths = tuple(float(w) for w in widths)
        N = widths[0] if N is None else N
        T = widths[-1] if T is None else T
    if N is None or T is None:
        raise ValueError("give widths (multi-layer) or explicit N and T "
                         "feature widths for the trace scenarios")
    register_trace_dataset(name, lambda: trace, overwrite=overwrite)
    names = tuple(dataflows) if dataflows is not None else registry.names()
    return [
        Scenario.trace(df, dataset=name, N=float(N), T=float(T),
                       tile_vertices=float(cap), widths=widths,
                       residency=residency,
                       high_degree_fraction=high_degree_fraction,
                       label=f"{name}@{df}/tile{int(cap)}",
                       workload=workload or name)
        for df in names for cap in tile_vertices
    ]


def fig3(K: Optional[np.ndarray] = None,
         M: Optional[np.ndarray] = None) -> TemplateBatch:
    """Fig. 3: EnGN movement over (tile size K, PE array M = M')."""
    K = _f64(DEFAULT_K_SWEEP if K is None else K)
    M = _f64(DEFAULT_M_SWEEP if M is None else M)
    Kg, Mg = _grid(K, M)
    scenarios = tile_scenarios_from_graph(
        "engn", paper_default_graph(Kg), Kg.shape,
        hardware={"M": Mg, "M_prime": Mg})
    return TemplateBatch(figure="fig3", scenarios=tuple(scenarios),
                         axes={"K": K, "M": M}, meta={"model": "engn"})


def fig4(K: Optional[np.ndarray] = None,
         Ma: Optional[np.ndarray] = None) -> TemplateBatch:
    """Fig. 4: HyGCN movement over (tile size K, SIMD cores Ma)."""
    K = _f64(DEFAULT_K_SWEEP if K is None else K)
    Ma = _f64(DEFAULT_M_SWEEP if Ma is None else Ma)
    Kg, Mag = _grid(K, Ma)
    scenarios = tile_scenarios_from_graph(
        "hygcn", paper_default_graph(Kg), Kg.shape, hardware={"Ma": Mag})
    return TemplateBatch(figure="fig4", scenarios=tuple(scenarios),
                         axes={"K": K, "Ma": Ma}, meta={"model": "hygcn"})


def fig5(accelerator: str, B: Optional[np.ndarray] = None,
         K: Optional[np.ndarray] = None) -> TemplateBatch:
    """Fig. 5: iterations vs L2 bandwidth per workload size, any dataflow."""
    B = _f64(DEFAULT_B_SWEEP if B is None else B)
    K = _f64(np.array([256, 1024, 4096], dtype=np.float64) if K is None else K)
    registry.get(accelerator)  # fail fast on unknown names
    Bg, Kg = _grid(B, K)
    scenarios = tile_scenarios_from_graph(
        accelerator, paper_default_graph(Kg), Bg.shape, hardware={"B": Bg})
    figure = {"engn": "fig5a", "hygcn": "fig5b"}.get(accelerator,
                                                     f"fig5_{accelerator}")
    return TemplateBatch(figure=figure, scenarios=tuple(scenarios),
                         axes={"B": B, "K": K}, meta={"model": accelerator})


def fig6(K: float = 1024.0, M: Optional[np.ndarray] = None) -> TemplateBatch:
    """Fig. 6: EnGN iterations vs the array-fitting factor K*N / M^2."""
    M = _f64(np.array([4, 8, 16, 32, 64, 128, 256, 512], dtype=np.float64)
             if M is None else M)
    scenarios = tile_scenarios_from_graph(
        "engn", paper_default_graph(K), M.shape,
        hardware={"M": M, "M_prime": M})
    return TemplateBatch(figure="fig6", scenarios=tuple(scenarios),
                         axes={"M": M}, meta={"model": "engn", "K": K})


def fig7(gamma: Optional[np.ndarray] = None,
         N: Optional[np.ndarray] = None) -> TemplateBatch:
    """Fig. 7: HyGCN loadweights vs systolic reuse Gamma and depth N."""
    gamma = _f64(np.linspace(0.0, 0.99, 34) if gamma is None else gamma)
    N = _f64(np.array([30, 128, 512], dtype=np.float64) if N is None else N)
    Gg, Ng = _grid(gamma, N)
    scenarios = tile_scenarios_from_graph(
        "hygcn", paper_default_graph(1024.0).replace(N=Ng), Gg.shape,
        hardware={"gamma": Gg})
    return TemplateBatch(figure="fig7", scenarios=tuple(scenarios),
                         axes={"gamma": gamma, "N": N},
                         meta={"model": "hygcn"})


def comparison(accelerators: Optional[Sequence[str]] = None,
               K: Optional[np.ndarray] = None) -> TemplateBatch:
    """Every registered dataflow over one tile-size grid, Sec. IV defaults.

    The batch behind ``sweep_accelerators()`` (and the checked-in
    ``examples/scenarios/comparison.json``): A dataflows x |K| cells,
    evaluated in exactly A broadcast calls.
    """
    names = tuple(accelerators) if accelerators is not None else registry.names()
    K = np.atleast_1d(_f64(DEFAULT_K_SWEEP if K is None else K))
    graph = paper_default_graph(K)
    scenarios: list[Scenario] = []
    for name in names:
        scenarios.extend(tile_scenarios_from_graph(name, graph, K.shape,
                                                   label=name))
    return TemplateBatch(figure="comparison", scenarios=tuple(scenarios),
                         axes={"K": K}, meta={"accelerators": names})


def cora_end_to_end(
        accelerators: Optional[Sequence[str]] = None,
        tile_vertices: Optional[np.ndarray] = None,
        widths: Sequence[float] = (1433, 16, 7),
        V: float = 2708, E: float = 10556,
        residency: str = "spill") -> TemplateBatch:
    """Full-graph composition: L-layer GCN on Cora for every dataflow."""
    names = tuple(accelerators) if accelerators is not None else registry.names()
    caps = np.atleast_1d(_f64(np.array([256, 512, 1024, 2048], np.float64)
                              if tile_vertices is None else tile_vertices))
    widths = tuple(float(w) for w in widths)
    scenarios = tuple(
        Scenario.full_graph(name, V=V, E=E, N=widths[0], T=widths[-1],
                            tile_vertices=float(cap), widths=widths,
                            residency=residency,
                            label=f"{name}@tile{int(cap)}",
                            workload="gcn-cora")
        for name in names for cap in caps)
    return TemplateBatch(figure="cora_end_to_end", scenarios=scenarios,
                         axes={"tile_vertices": caps},
                         meta={"accelerators": names, "widths": widths,
                               "residency": residency})


def cora_trace(
        accelerators: Optional[Sequence[str]] = None,
        tile_vertices: Optional[np.ndarray] = None,
        widths: Sequence[float] = (1433, 16, 7),
        seed: float = 0.0, alpha: float = 1.6,
        residency: str = "spill") -> TemplateBatch:
    """Exact-schedule companion of ``cora_end_to_end``: the same L-layer
    GCN-on-Cora query over the deterministic Cora-sized power-law trace
    (dataset ``"cora"``), one plan group per (dataflow, capacity).  The
    tile capacity is structural for a trace (it fixes the tile-axis
    length), so the default sweeps a single capacity to keep the template
    at one broadcast evaluation per dataflow."""
    names = tuple(accelerators) if accelerators is not None else registry.names()
    caps = np.atleast_1d(_f64(np.array([1024], np.float64)
                              if tile_vertices is None else tile_vertices))
    widths = tuple(float(w) for w in widths)
    params = {"seed": float(seed), "alpha": float(alpha)}
    scenarios = tuple(
        Scenario.trace(name, dataset="cora", params=params,
                       N=widths[0], T=widths[-1], tile_vertices=float(cap),
                       widths=widths, residency=residency,
                       label=f"{name}@tile{int(cap)}/trace",
                       workload="gcn-cora-trace")
        for name in names for cap in caps)
    return TemplateBatch(figure="cora_trace", scenarios=scenarios,
                         axes={"tile_vertices": caps},
                         meta={"accelerators": names, "widths": widths,
                               "residency": residency, "dataset": "cora"})


def rgcn_cora(
        accelerators: Optional[Sequence[str]] = None,
        tile_vertices: Optional[np.ndarray] = None,
        widths: Sequence[float] = (1433, 16, 7),
        n_relations: int = 3,
        seed: float = 0.0, alpha: float = 1.6,
        residency: str = "spill") -> TemplateBatch:
    """Typed-graph companion of ``cora_trace``: an R-relation RGCN-style
    layer chain over the deterministic Cora-sized typed power-law trace
    (dataset ``"typed_cora"``).  Every relation carries its own weight
    matrices (graphstorm's ``RelGraphConvEncoder`` shape), so weight-load
    traffic scales with R while the shared vertex set keeps one partition
    geometry; the planner evaluates all relations in ONE broadcast
    :class:`~repro.core.compose.RelationalGraphModel` call per
    (dataflow, residency) group (DESIGN.md §17)."""
    names = tuple(accelerators) if accelerators is not None else registry.names()
    caps = np.atleast_1d(_f64(np.array([1024], np.float64)
                              if tile_vertices is None else tile_vertices))
    widths = tuple(float(w) for w in widths)
    params = {"seed": float(seed), "alpha": float(alpha)}
    scenarios = tuple(
        Scenario.hetero(name, dataset="typed_cora", params=params,
                        n_relations=int(n_relations),
                        N=widths[0], T=widths[-1],
                        tile_vertices=float(cap), widths=widths,
                        residency=residency,
                        label=f"{name}@tile{int(cap)}/rgcn",
                        workload="rgcn-cora-trace")
        for name in names for cap in caps)
    return TemplateBatch(figure="rgcn_cora", scenarios=scenarios,
                         axes={"tile_vertices": caps},
                         meta={"accelerators": names, "widths": widths,
                               "residency": residency,
                               "dataset": "typed_cora",
                               "n_relations": int(n_relations)})


def tune_cora(
        tile_vertices: Optional[np.ndarray] = None,
        widths: Sequence[float] = (1433, 16, 7),
        V: float = 2708, E: float = 10556,
        sram_bits: Optional[float] = None) -> TemplateBatch:
    """§15 auto-tune of the L-layer GCN-on-Cora workload.

    One optimize scenario searching (all dataflows) x (capacity sweep) x
    (both residencies): with ``sram_bits`` unset the budget is left open
    and the result carries the movement-vs-SRAM Pareto frontier; set a
    budget to get the cheapest configuration that fits.
    """
    caps = np.atleast_1d(_f64(np.array([256, 512, 1024, 2048], np.float64)
                              if tile_vertices is None else tile_vertices))
    widths = tuple(float(w) for w in widths)
    optimize = {
        "objective": "movement",
        "space": {"dataflow": "all",
                  "tile_vertices": [float(c) for c in caps],
                  "residency": ["spill", "resident"]},
    }
    if sram_bits is not None:
        optimize["budget"] = {"sram_bits": float(sram_bits)}
    scenario = Scenario.full_graph(
        registry.names()[0], V=V, E=E, N=widths[0], T=widths[-1],
        tile_vertices=float(caps[0]), widths=widths,
        label="tune-cora-gcn", workload="gcn-cora",
        optimize=optimize)
    return TemplateBatch(figure="tune_cora", scenarios=(scenario,),
                         axes={"tile_vertices": caps},
                         meta={"widths": widths, "optimize": optimize})


TEMPLATES: dict[str, Callable[..., TemplateBatch]] = {
    "fig3": fig3,
    "fig4": fig4,
    "fig5a": lambda **kw: fig5("engn", **kw),
    "fig5b": lambda **kw: fig5("hygcn", **kw),
    "fig6": fig6,
    "fig7": fig7,
    "comparison": comparison,
    "cora_end_to_end": cora_end_to_end,
    "cora_trace": cora_trace,
    "rgcn_cora": rgcn_cora,
    "tune_cora": tune_cora,
}


def template(name: str, **kw) -> TemplateBatch:
    """Build a named template's scenario batch."""
    try:
        builder = TEMPLATES[name]
    except KeyError:
        raise KeyError(f"unknown template {name!r}; "
                       f"available: {template_names()}") from None
    return builder(**kw)


def template_names() -> tuple[str, ...]:
    return tuple(TEMPLATES)
