"""``python -m repro.api`` — the service-shaped scenario front door.

Evaluate declarative scenario batches from any of three sources and print
one result row per scenario (CSV on stdout), optionally emitting a
machine-readable ``BENCH_scenarios.json``:

* ``--scenario batch.json``  — a checked-in / client-supplied batch file
  (``{"scenarios": [...]}`` or a bare list); repeatable.
* ``--template fig3``        — a named figure template
  (:mod:`repro.api.templates`).
* ``--workload gcn-cora``    — a workload config's §5 tile-language bridge
  (``ArchDef.to_scenarios``), optionally restricted by ``--shape`` /
  ``--dataflows``.

A fourth mode, ``--tune batch.json``, runs the §15 design-space
auto-tuner: every scenario in the batch must carry an ``{"optimize":
...}`` block, and the CLI prints one tuned row per scenario (winning
configuration, objective, SRAM working set, search statistics) instead
of plain totals.  ``--json BENCH_tune.json`` emits the full search
records including the movement-vs-SRAM Pareto frontier.

``--serve`` routes the batch through the §18 serving engine instead of
one direct planner call: every scenario becomes its own request,
submitted concurrently from ``--serve-clients`` threads, coalesced
across requests inside ``--serve-window``-second micro-batching
windows.  Results (and the exit-status gates) are identical to the
direct path — the serve engine is bit-exact by construction — with the
engine's coalesce / cache metrics appended to the summary line and the
JSON payload.

Exit status is non-zero on schema errors (2: unknown optimize axis,
negative budget, non-finite objective weight, infeasible budget, ...),
on any ``expect`` golden-drift mismatch (1), and on any failed §10
conformance check (1) — so a checked-in batch file is a CI gate (see
``.github/workflows/ci.yml``).
"""

from __future__ import annotations

import argparse
import csv
import io
import json
import sys
from typing import Optional, Sequence

from .planner import BatchResult, evaluate_scenarios
from .scenario import Scenario, load_scenarios
from .templates import template, template_names

__all__ = ["main", "build_scenarios"]


def _print_listing() -> None:
    from repro.core import registry

    print("registered dataflows:")
    for name in registry.names():
        spec = registry.get(name)
        runnable = " [runnable analogue]" if spec.has_runnable else ""
        print(f"  {name:14} {len(spec.movements)} movement levels{runnable}")
    # Kind tags let load generators (benchmarks/serve.py, external
    # clients) assemble mixed serve workloads without trial and error:
    # every template is evaluable, but its scenario kinds decide which
    # caches (trace LRU, disk schedule store) a served batch exercises.
    print("\nscenario templates (--template NAME) [scenario kinds]:")
    for name in template_names():
        batch = template(name)
        kinds = sorted({("tune" if s.optimize is not None else s.graph_kind)
                        for s in batch.scenarios})
        print(f"  {name:18} {len(batch.scenarios):3d} scenarios "
              f"[{', '.join(kinds)}]")
    from repro.core.trace import trace_dataset_names

    print("\ntrace datasets ({'kind': 'trace', 'dataset': NAME, ...}):")
    for name in trace_dataset_names():
        print(f"  {name}")
    try:
        from repro.configs import all_archs
    except Exception as exc:  # pragma: no cover - configs need jax
        print(f"\nworkload bridges unavailable ({type(exc).__name__}: {exc})")
        return
    print("\nworkload bridges (--workload NAME [--shape SHAPE]):")
    for arch in all_archs():
        shapes = [s for s in arch.shapes if s not in arch.skips]
        print(f"  {arch.name:20} [{arch.family}] shapes: {', '.join(shapes)}")


def build_scenarios(args: argparse.Namespace) -> list[Scenario]:
    if (args.shape or args.dataflows) and not args.workload:
        raise ValueError("--shape/--dataflows only filter --workload "
                         "bridges; they would be silently ignored for "
                         "--scenario/--template sources")
    scenarios: list[Scenario] = []
    for path in args.scenario or ():
        scenarios.extend(load_scenarios(path))
    for name in args.template or ():
        scenarios.extend(template(name).scenarios)
    dataflows = (tuple(args.dataflows.split(",")) if args.dataflows else None)
    for name in args.workload or ():
        from repro.configs import get_arch

        arch = get_arch(name)
        shapes = tuple(args.shape) if args.shape else None
        scenarios.extend(arch.to_scenarios(shapes=shapes,
                                           dataflows=dataflows))
    return scenarios


def _print_rows(res: BatchResult) -> None:
    rows = res.rows()
    cols = list(rows[0]) if rows else []
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow(r)
    print(buf.getvalue(), end="")


def _print_tune_rows(res: BatchResult) -> None:
    rows = []
    for r in res.results:
        t = r.meta["tune"]
        best = t["best"]
        rows.append({
            "label": r.scenario.label, "workload": r.scenario.workload,
            "graph_kind": r.scenario.graph_kind,
            "best_dataflow": best["dataflow"],
            "best_tile_vertices": best["tile_vertices"],
            "best_n_tiles": best.get("n_tiles"),
            "residency": best["residency"],
            "halo_dedup": best["halo_dedup"],
            "objective": best["objective"],
            "sram_bits": best["sram_bits"],
            "total_bits": r.total_bits,
            "method": t["method"],
            "n_candidates": t["n_candidates"],
            "n_feasible": t["n_feasible"],
            "n_groups": t["n_groups"],
            "frontier_size": len(t["frontier"]),
        })
    cols = list(rows[0]) if rows else []
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=cols)
    w.writeheader()
    for row in rows:
        w.writerow(row)
    print(buf.getvalue(), end="")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description="Declarative scenario front door: evaluate "
                    "(dataflow x workload x graph x hardware x composition) "
                    "batches in broadcast closed form.")
    ap.add_argument("--scenario", action="append", metavar="PATH",
                    help="scenario batch JSON file (repeatable)")
    ap.add_argument("--template", action="append", metavar="NAME",
                    help=f"named template: {', '.join(template_names())}")
    ap.add_argument("--workload", action="append", metavar="ARCH",
                    help="workload config bridge (repro.configs name)")
    ap.add_argument("--tune", action="append", metavar="PATH",
                    help="tune batch JSON (repeatable): every scenario "
                         "must carry an {'optimize': ...} block; prints "
                         "one tuned row per scenario (§15)")
    ap.add_argument("--shape", action="append", metavar="SHAPE",
                    help="restrict --workload to these shapes (repeatable)")
    ap.add_argument("--dataflows", default=None, metavar="A,B,C",
                    help="comma-separated dataflows for --workload "
                         "(default: all registered)")
    ap.add_argument("--list", action="store_true",
                    help="list dataflows, templates, and workload bridges")
    ap.add_argument("--serve", action="store_true",
                    help="evaluate through the §18 coalescing serve engine "
                         "(one concurrent request per scenario)")
    ap.add_argument("--serve-window", type=float, default=0.002,
                    metavar="SECONDS",
                    help="micro-batching window for --serve (default 0.002)")
    ap.add_argument("--serve-clients", type=int, default=8, metavar="N",
                    help="concurrent submitter threads for --serve "
                         "(default 8)")
    ap.add_argument("--json", nargs="?", const="BENCH_scenarios.json",
                    default=None, metavar="PATH",
                    help="write results JSON (default BENCH_scenarios.json)")
    args = ap.parse_args(argv)

    if args.list:
        _print_listing()
        if not (args.scenario or args.template or args.workload
                or args.tune):
            return 0

    if args.tune:
        return _tune_main(args)

    try:
        scenarios = build_scenarios(args)
    except (ValueError, TypeError, KeyError, OSError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    if not scenarios:
        ap.print_usage(sys.stderr)
        print("error: no scenarios given (use --scenario/--template/"
              "--workload, or --list)", file=sys.stderr)
        return 2

    serve_metrics = None
    if args.serve:
        try:
            res, serve_metrics = _serve_batch(args, scenarios)
        except (ValueError, TypeError, KeyError) as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 2
        _print_rows(res)
        print(f"# {len(res.results)} scenarios served in "
              f"{serve_metrics['windows']} windows / "
              f"{serve_metrics['evaluations']} evaluations "
              f"(coalesce rate {serve_metrics['coalesce_rate']:.3f})")
    else:
        try:
            res = evaluate_scenarios(scenarios)
        except (ValueError, TypeError, KeyError) as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 2
        _print_rows(res)
        print(f"# {len(res.results)} scenarios in {res.n_evaluations} "
              f"broadcast evaluations "
              f"({len(res.evaluations_per_dataflow())} dataflows)")

    status = 0
    for scenario, fails in res.expect_failures():
        status = 1
        name = scenario.label or scenario.workload or scenario.dataflow
        for f in fails:
            print(f"# GOLDEN DRIFT {name}: {f}", file=sys.stderr)
    for r in res.results:
        if r.conformance is not None and not r.conformance.get("ok", True):
            status = 1
            print(f"# CONFORMANCE FAILURE {r.scenario.dataflow}: "
                  f"{r.conformance}", file=sys.stderr)

    if args.json is not None:
        payload = res.to_dict()
        payload["status"] = "ok" if status == 0 else "failed"
        if serve_metrics is not None:
            payload["serve"] = serve_metrics
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}")
    return status


def _serve_batch(args: argparse.Namespace, scenarios: list[Scenario]
                 ) -> tuple[BatchResult, dict]:
    """Evaluate the batch through the §18 serve engine.

    Each scenario becomes its own request, submitted concurrently from a
    client thread pool, so same-plan scenarios actually coalesce across
    requests the way independent callers would.  Results come back in
    input order wrapped as a groupless :class:`BatchResult` — rows,
    golden-drift gates, and conformance gates run unchanged.
    """
    from concurrent.futures import ThreadPoolExecutor

    from .serve import ServeEngine

    engine = ServeEngine(window_s=args.serve_window)
    with engine:
        with ThreadPoolExecutor(
                max_workers=max(1, args.serve_clients)) as pool:
            handles = [pool.submit(engine.submit, [s]) for s in scenarios]
            served = [h.result() for h in handles]
    results = tuple(sr.results[0] for sr in served)
    return (BatchResult(results=results, groups=()), engine.metrics())


def _tune_main(args: argparse.Namespace) -> int:
    """The ``--tune`` mode: every scenario must be an optimize scenario."""
    if args.scenario or args.template or args.workload:
        print("error: --tune is its own mode; a tune batch cannot be "
              "combined with --scenario/--template/--workload sources",
              file=sys.stderr)
        return 2
    try:
        scenarios: list[Scenario] = []
        for path in args.tune:
            scenarios.extend(load_scenarios(path))
        if not scenarios:
            raise ValueError("no scenarios in the tune batch")
        for i, s in enumerate(scenarios):
            if s.optimize is None:
                raise ValueError(
                    f"tune scenario #{i} ({s.label or s.dataflow}) has no "
                    "'optimize' block; use --scenario for plain "
                    "evaluation")
    except (ValueError, TypeError, KeyError, OSError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    try:
        res = evaluate_scenarios(scenarios)
    except (ValueError, TypeError, KeyError) as exc:
        # Includes InfeasibleBudgetError (a typed ValueError): a budget
        # below every configuration's working set is a client error.
        print(f"error: {exc}", file=sys.stderr)
        return 2

    _print_tune_rows(res)
    n_cands = sum(r.meta["tune"]["n_candidates"] for r in res.results)
    n_groups = sum(r.meta["tune"]["n_groups"] for r in res.results)
    print(f"# {len(res.results)} tunes over {n_cands} candidate "
          f"configurations in {n_groups} broadcast evaluations")

    status = 0
    for scenario, fails in res.expect_failures():
        status = 1
        name = scenario.label or scenario.workload or scenario.dataflow
        for f in fails:
            print(f"# GOLDEN DRIFT {name}: {f}", file=sys.stderr)

    if args.json is not None:
        payload = res.to_dict()
        payload["status"] = "ok" if status == 0 else "failed"
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}")
    return status
