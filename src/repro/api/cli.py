"""``python -m repro.api`` — the service-shaped scenario front door.

Evaluate declarative scenario batches from any of three sources and print
one result row per scenario (CSV on stdout), optionally emitting a
machine-readable ``BENCH_scenarios.json``:

* ``--scenario batch.json``  — a checked-in / client-supplied batch file
  (``{"scenarios": [...]}`` or a bare list); repeatable.
* ``--template fig3``        — a named figure template
  (:mod:`repro.api.templates`).
* ``--workload gcn-cora``    — a workload config's §5 tile-language bridge
  (``ArchDef.to_scenarios``), optionally restricted by ``--shape`` /
  ``--dataflows``.

Exit status is non-zero on schema errors, on any ``expect`` golden-drift
mismatch, and on any failed §10 conformance check — so a checked-in batch
file is a CI gate (see ``.github/workflows/ci.yml``).
"""

from __future__ import annotations

import argparse
import csv
import io
import json
import sys
from typing import Optional, Sequence

from .planner import BatchResult, evaluate_scenarios
from .scenario import Scenario, load_scenarios
from .templates import template, template_names

__all__ = ["main", "build_scenarios"]


def _print_listing() -> None:
    from repro.core import registry

    print("registered dataflows:")
    for name in registry.names():
        spec = registry.get(name)
        runnable = " [runnable analogue]" if spec.has_runnable else ""
        print(f"  {name:14} {len(spec.movements)} movement levels{runnable}")
    print("\nscenario templates (--template NAME):")
    for name in template_names():
        print(f"  {name}")
    from repro.core.trace import trace_dataset_names

    print("\ntrace datasets ({'kind': 'trace', 'dataset': NAME, ...}):")
    for name in trace_dataset_names():
        print(f"  {name}")
    try:
        from repro.configs import all_archs
    except Exception as exc:  # pragma: no cover - configs need jax
        print(f"\nworkload bridges unavailable ({type(exc).__name__}: {exc})")
        return
    print("\nworkload bridges (--workload NAME [--shape SHAPE]):")
    for arch in all_archs():
        shapes = [s for s in arch.shapes if s not in arch.skips]
        print(f"  {arch.name:20} [{arch.family}] shapes: {', '.join(shapes)}")


def build_scenarios(args: argparse.Namespace) -> list[Scenario]:
    if (args.shape or args.dataflows) and not args.workload:
        raise ValueError("--shape/--dataflows only filter --workload "
                         "bridges; they would be silently ignored for "
                         "--scenario/--template sources")
    scenarios: list[Scenario] = []
    for path in args.scenario or ():
        scenarios.extend(load_scenarios(path))
    for name in args.template or ():
        scenarios.extend(template(name).scenarios)
    dataflows = (tuple(args.dataflows.split(",")) if args.dataflows else None)
    for name in args.workload or ():
        from repro.configs import get_arch

        arch = get_arch(name)
        shapes = tuple(args.shape) if args.shape else None
        scenarios.extend(arch.to_scenarios(shapes=shapes,
                                           dataflows=dataflows))
    return scenarios


def _print_rows(res: BatchResult) -> None:
    rows = res.rows()
    cols = list(rows[0]) if rows else []
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=cols)
    w.writeheader()
    for r in rows:
        w.writerow(r)
    print(buf.getvalue(), end="")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description="Declarative scenario front door: evaluate "
                    "(dataflow x workload x graph x hardware x composition) "
                    "batches in broadcast closed form.")
    ap.add_argument("--scenario", action="append", metavar="PATH",
                    help="scenario batch JSON file (repeatable)")
    ap.add_argument("--template", action="append", metavar="NAME",
                    help=f"named template: {', '.join(template_names())}")
    ap.add_argument("--workload", action="append", metavar="ARCH",
                    help="workload config bridge (repro.configs name)")
    ap.add_argument("--shape", action="append", metavar="SHAPE",
                    help="restrict --workload to these shapes (repeatable)")
    ap.add_argument("--dataflows", default=None, metavar="A,B,C",
                    help="comma-separated dataflows for --workload "
                         "(default: all registered)")
    ap.add_argument("--list", action="store_true",
                    help="list dataflows, templates, and workload bridges")
    ap.add_argument("--json", nargs="?", const="BENCH_scenarios.json",
                    default=None, metavar="PATH",
                    help="write results JSON (default BENCH_scenarios.json)")
    args = ap.parse_args(argv)

    if args.list:
        _print_listing()
        if not (args.scenario or args.template or args.workload):
            return 0

    try:
        scenarios = build_scenarios(args)
    except (ValueError, TypeError, KeyError, OSError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    if not scenarios:
        ap.print_usage(sys.stderr)
        print("error: no scenarios given (use --scenario/--template/"
              "--workload, or --list)", file=sys.stderr)
        return 2

    try:
        res = evaluate_scenarios(scenarios)
    except (ValueError, TypeError, KeyError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2

    _print_rows(res)
    print(f"# {len(res.results)} scenarios in {res.n_evaluations} broadcast "
          f"evaluations ({len(res.evaluations_per_dataflow())} dataflows)")

    status = 0
    for scenario, fails in res.expect_failures():
        status = 1
        name = scenario.label or scenario.workload or scenario.dataflow
        for f in fails:
            print(f"# GOLDEN DRIFT {name}: {f}", file=sys.stderr)
    for r in res.results:
        if r.conformance is not None and not r.conformance.get("ok", True):
            status = 1
            print(f"# CONFORMANCE FAILURE {r.scenario.dataflow}: "
                  f"{r.conformance}", file=sys.stderr)

    if args.json is not None:
        payload = res.to_dict()
        payload["status"] = "ok" if status == 0 else "failed"
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json}")
    return status
