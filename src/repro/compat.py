"""Version compatibility shims for the pinned jax.

The repo targets the modern jax API surface but must run on the baked-in
jax 0.4.x toolchain, where ``shard_map`` still lives under
``jax.experimental`` and takes ``check_rep`` instead of ``check_vma``.
Import :func:`shard_map` from here instead of ``jax`` directly.
"""

from __future__ import annotations

import functools

import jax

__all__ = ["shard_map", "axis_size"]


def axis_size(axis_name) -> jax.Array:
    """``jax.lax.axis_size`` with a 0.4.x fallback (psum of ones)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    @functools.wraps(_legacy_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
