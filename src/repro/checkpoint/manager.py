"""Checkpointing: atomic save/restore with retention and elastic resharding.

Layout (one directory per step):
    <root>/step_000123.tmp/   -> written, fsynced, then atomically renamed
    <root>/step_000123/
        manifest.json         tree structure, shapes, dtypes, step, extras
        arrays.npz            flattened leaves (host numpy, full arrays)

Restore is *elastic*: arrays are saved unsharded (gathered to host), so a
restart may load them onto ANY mesh — pass ``shardings`` and each leaf is
device_put with the new layout.  On a real multi-host pod the same manifest
format would reference per-host shard files; the single-process container
writes one file (DESIGN.md §6).

Retention keeps the newest ``keep`` checkpoints; a crashed write never
corrupts the latest good step because of the tmp-rename protocol.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, root: str | os.PathLike, *, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, extras: Optional[dict] = None) -> Path:
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]
        tmp = self.root / f"step_{step:09d}.tmp"
        final = self.root / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **{f"leaf_{i}": a for i, a in enumerate(host)})
        manifest = {
            "step": step,
            "n_leaves": len(host),
            "treedef": str(treedef),
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "time": time.time(),
            "extras": extras or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        # fsync the directory contents before the atomic publish
        for f in tmp.iterdir():
            fd = os.open(f, os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
        if final.exists():
            # re-saving an existing step (e.g. final save landing on a
            # periodic one): replace it wholesale, never partially
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._retain()
        return final

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.root.iterdir():
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
                out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> tuple[int, Any]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (optional pytree of NamedSharding,
        same structure) resharding-places each leaf — elastic restart."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:09d}"
        data = np.load(d / "arrays.npz")
        leaves, treedef = _flatten(like)
        manifest = json.loads((d / "manifest.json").read_text())
        if manifest["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, target tree "
                f"has {len(leaves)} — structure mismatch")
        restored = []
        sh_leaves = (jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))[0]
            if shardings is not None else [None] * len(leaves))
        for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
            arr = data[f"leaf_{i}"]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
            arr = arr.astype(ref.dtype)
            restored.append(jax.device_put(arr, sh) if sh is not None
                            else jax.device_put(arr))
        return step, treedef.unflatten(restored)

    # ------------------------------------------------------------------
    def _retain(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)
