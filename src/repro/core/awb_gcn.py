"""AWB-GCN-style column-balanced dataflow as a declarative spec.

AWB-GCN (Geng et al., MICRO 2020) computes SpMM by **column-wise product**:
each nonzero A[v,u] scales the full feature row of u into a partial output
row for v, and an autotuning balancer redistributes nonzeros so all M PEs
stay busy (efficiency ``eta``) at the cost of rerouting a fraction ``rho``
of partial results through the task-distribution network.

Modelled in the paper's movement-level style (this repo's extension; the
paper covers only EnGN/HyGCN): vertices and edges stream once, the
column-product accumulation is on-array traffic proportional to P*T, and
the balancer adds an extra on-array rerouting level that neither EnGN nor
HyGCN has.  Its absence of an inter-phase buffer (combination is chained
behind aggregation on the same PEs) places its off-chip class close to
EnGN's, while the rerouting term grows with imbalance — the trade the
MICRO paper quantifies.

Model-audit note (DESIGN.md §16): the symbolic auditor confirms no
movement reads ``graph.L`` — correct by construction, since AWB-GCN has
no high-degree vertex cache to size; reported as an informational unused
graph symbol.
"""

from __future__ import annotations

import numpy as np

from .dataflow import DataflowSpec, MovementSpec, SpecModel
from .notation import AWBGCNHardwareParams, GraphTileParams
from .terms import ceil, minimum

__all__ = ["AWBGCNModel", "AWB_GCN_SPEC"]


def _f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def loadvertcols(g: GraphTileParams, hw: AWBGCNHardwareParams):
    """Stream the K x N feature matrix once, column-major."""
    N, _, K, _, _ = g.astuple_f64()
    s, B = _f64(hw.sigma), _f64(hw.B)
    iters = ceil(K * N * s / B)
    bits = minimum(K * N * s, B) * iters
    return bits, iters


def loadedges(g: GraphTileParams, hw: AWBGCNHardwareParams):
    """Stream the P nonzeros (CSC column pointers + row indices)."""
    _, _, _, _, P = g.astuple_f64()
    s, B = _f64(hw.sigma), _f64(hw.B)
    iters = ceil(P * s / B)
    bits = minimum(P * s, B) * iters
    return bits, iters


def loadweights(g: GraphTileParams, hw: AWBGCNHardwareParams):
    """Load the N x T combination weights across the PE array."""
    N, T, _, _, _ = g.astuple_f64()
    s, B, M = _f64(hw.sigma), _f64(hw.B), _f64(hw.M)
    iters = ceil(N * T * s / minimum(B, M * s))
    bits = minimum(N * T * s, M * s, B) * iters
    return bits, iters


def columnproduct(g: GraphTileParams, hw: AWBGCNHardwareParams):
    """Column-wise-product accumulation: read+write a T-wide partial per edge."""
    _, T, _, _, P = g.astuple_f64()
    s, M, eta = _f64(hw.sigma), _f64(hw.M), _f64(hw.eta)
    bits = 2.0 * P * T * s
    iters = ceil(P * T / (M * eta))
    return bits, iters


def rebalance(g: GraphTileParams, hw: AWBGCNHardwareParams):
    """Autotuner rerouting: rho of the partial results cross the task network."""
    _, T, _, _, P = g.astuple_f64()
    s, M, rho = _f64(hw.sigma), _f64(hw.M), _f64(hw.rho)
    bits = rho * P * T * s
    iters = ceil(rho * P / M)
    return bits, iters


def writeout(g: GraphTileParams, hw: AWBGCNHardwareParams):
    """Write the K x T output features back to the memory bank."""
    _, T, K, _, _ = g.astuple_f64()
    s, B = _f64(hw.sigma), _f64(hw.B)
    iters = ceil(K * T * s / B)
    bits = minimum(K * T * s, B) * iters
    return bits, iters


AWB_GCN_SPEC = DataflowSpec(
    name="awb_gcn",
    movements=(
        MovementSpec("loadvertcols", "L2-L1", loadvertcols, role="vertex_in"),
        MovementSpec("loadedges", "L2-L1", loadedges, role="edges"),
        MovementSpec("loadweights", "L2-L1", loadweights, role="weights"),
        MovementSpec("columnproduct", "L1-L1", columnproduct, role="compute"),
        MovementSpec("rebalance", "L1-L1", rebalance, role="compute"),
        MovementSpec("writeout", "L1-L2", writeout, role="vertex_out"),
    ),
    hw_factory=AWBGCNHardwareParams,
    description="AWB-GCN column-wise-product SpMM with autotuned workload "
                "balancing (MICRO 2020), in the paper's movement-level style.",
)


class AWBGCNModel(SpecModel):
    """Class-API adapter for the AWB-GCN-style dataflow."""

    spec = AWB_GCN_SPEC
