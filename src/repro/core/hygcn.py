"""HyGCN analytical data-movement model — Table IV of the paper, verbatim.

HyGCN (Yan et al., HPCA 2020) pipelines two engines: an aggregation engine
of Ma = 32 SIMD cores (each covering up to 8 feature components per step)
and a combination engine — an 8 x 4 x 128 systolic array with weight reuse
factor Gamma.  Intermediate (aggregated) features cross an inter-phase
buffer, which is why HyGCN's off-chip-class movement exceeds EnGN's at
matched parameters (Sec. IV-B).

Each closed form implements one row of Table IV; the rows are assembled
declaratively into :data:`HYGCN_SPEC` and evaluated by the shared engine in
:mod:`repro.core.dataflow`.  P_s (edges surviving HyGCN's window sliding)
is modelled as ``Ps_ratio * P`` with the paper's default P_s ~ P (ratio 1).
"""

from __future__ import annotations

import numpy as np

from .dataflow import DataflowSpec, MovementSpec, SpecModel
from .notation import GraphTileParams, HyGCNHardwareParams
from .terms import ceil, minimum

__all__ = ["HyGCNModel", "HYGCN_SPEC"]


def _f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def loadvertL2(g: GraphTileParams, hw: HyGCNHardwareParams):
    """Row 1: stream all K vertices of the tile into the aggregation engine."""
    N, _, K, _, _ = g.astuple_f64()
    s, B, Ma = _f64(hw.sigma), _f64(hw.B), _f64(hw.Ma)
    iters = ceil(K * s / minimum(B, Ma * s))
    bits = minimum(K * s, Ma * s, B) * N * iters
    return bits, iters


def loadedges(g: GraphTileParams, hw: HyGCNHardwareParams):
    """Row 2: stream the P_s window-slid edges."""
    _, _, _, _, P = g.astuple_f64()
    s, B = _f64(hw.sigma), _f64(hw.B)
    Ps = hw.Ps(P)
    iters = ceil(Ps * s / B)
    bits = minimum(Ps * s, B) * iters
    return bits, iters


def loadweights(g: GraphTileParams, hw: HyGCNHardwareParams):
    """Row 3: load the (1 - Gamma) non-reused fraction of the N x T weights."""
    N, T, _, _, _ = g.astuple_f64()
    s, B, Mc = _f64(hw.sigma), _f64(hw.B), _f64(hw.Mc)
    gamma = _f64(hw.gamma)
    fresh = N * T * s * (1.0 - gamma)
    iters = ceil(fresh / minimum(B, Mc * s))
    bits = minimum(fresh, Mc * s, B) * iters
    return bits, iters


def aggregate(g: GraphTileParams, hw: HyGCNHardwareParams):
    """Row 4: SIMD aggregation — every core handles <= 8 feature components."""
    N, _, _, _, P = g.astuple_f64()
    s, Ma = _f64(hw.sigma), _f64(hw.Ma)
    Ps = hw.Ps(P)
    iters = ceil(N * Ps * s / (Ma * 8.0))
    bits = minimum(N * Ps * s, Ma * 8.0) * iters
    return bits, iters


def writeinterphase(g: GraphTileParams, hw: HyGCNHardwareParams):
    """Row 5: spill aggregated K x N features to the inter-phase buffer."""
    N, _, K, _, _ = g.astuple_f64()
    s, B = _f64(hw.sigma), _f64(hw.B)
    iters = ceil(K * N * s / B)
    bits = minimum(K * N * s, B) * iters
    return bits, iters


def combine(g: GraphTileParams, hw: HyGCNHardwareParams):
    """Row 6: systolic matrix-vector combination (single on-array pass)."""
    N, T, K, _, _ = g.astuple_f64()
    s = _f64(hw.sigma)
    bits = K * N * s + N * T * s
    return bits, np.ones_like(bits)


def readinterphase(g: GraphTileParams, hw: HyGCNHardwareParams):
    """Row 7: the combination engine fetches aggregated features back."""
    N, _, _, _, P = g.astuple_f64()
    s, B, Mc = _f64(hw.sigma), _f64(hw.B), _f64(hw.Mc)
    Ps = hw.Ps(P)
    iters = ceil(Ps * N * s / minimum(B, Mc))
    bits = minimum(Ps * N * s, B, Mc) * iters
    return bits, iters


def writeL2(g: GraphTileParams, hw: HyGCNHardwareParams):
    """Row 8: write the K x T output features to the output buffer."""
    _, T, K, _, _ = g.astuple_f64()
    s, B = _f64(hw.sigma), _f64(hw.B)
    iters = ceil(K * T * s / B)
    bits = minimum(K * T * s, B) * iters
    return bits, iters


#: Table IV, declaratively: the rows in published order.
HYGCN_SPEC = DataflowSpec(
    name="hygcn",
    movements=(
        MovementSpec("loadvertL2", "L2-L1", loadvertL2, role="vertex_in"),
        MovementSpec("loadedges", "L2-L1", loadedges, role="edges"),
        MovementSpec("loadweights", "L2-L1", loadweights, role="weights"),
        MovementSpec("aggregate", "L1-L1", aggregate, role="compute",
                     audit_note="Table IV verbatim: the aggregation row "
                                "caps N*Ps*sigma (bits) against Ma (a PE "
                                "count) scaled by 8.0, and ceils the bits "
                                "ratio directly; transcribed as published "
                                "(DESIGN.md §16)."),
        MovementSpec("writeinterphase", "L1-L2", writeinterphase, role="interphase"),
        MovementSpec("combine", "L1-L1", combine, role="compute"),
        MovementSpec("readinterphase", "L2-L1", readinterphase, role="interphase",
                     audit_note="Table IV verbatim: min(B, Mc) compares "
                                "bits-per-iteration bandwidth against a "
                                "systolic-array PE count; transcribed as "
                                "published (DESIGN.md §16)."),
        MovementSpec("writeL2", "L1-L2", writeL2, role="vertex_out"),
    ),
    hw_factory=HyGCNHardwareParams,
    description="HyGCN dual-engine (SIMD aggregation + systolic combination) "
                "dataflow with an inter-phase buffer (Table IV).",
)


class HyGCNModel(SpecModel):
    """Table IV assembled: the HyGCN per-tile data-movement model."""

    spec = HYGCN_SPEC
