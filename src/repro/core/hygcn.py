"""HyGCN analytical data-movement model — Table IV of the paper, verbatim.

HyGCN (Yan et al., HPCA 2020) pipelines two engines: an aggregation engine
of Ma = 32 SIMD cores (each covering up to 8 feature components per step)
and a combination engine — an 8 x 4 x 128 systolic array with weight reuse
factor Gamma.  Intermediate (aggregated) features cross an inter-phase
buffer, which is why HyGCN's off-chip-class movement exceeds EnGN's at
matched parameters (Sec. IV-B).

Each function implements one row of Table IV.  P_s (edges surviving HyGCN's
window sliding) is modelled as ``Ps_ratio * P`` with the paper's default
P_s ~ P (ratio 1).
"""

from __future__ import annotations

import numpy as np

from .notation import GraphTileParams, HyGCNHardwareParams
from .terms import AcceleratorModel, ModelOutput, MovementTerm, ceil, minimum

__all__ = ["HyGCNModel"]


def _f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def loadvertL2(g: GraphTileParams, hw: HyGCNHardwareParams) -> MovementTerm:
    """Row 1: stream all K vertices of the tile into the aggregation engine."""
    N, _, K, _, _ = g.astuple_f64()
    s, B, Ma = _f64(hw.sigma), _f64(hw.B), _f64(hw.Ma)
    iters = ceil(K * s / minimum(B, Ma * s))
    bits = minimum(K * s, Ma * s, B) * N * iters
    return MovementTerm("loadvertL2", "L2-L1", bits, iters)


def loadedges(g: GraphTileParams, hw: HyGCNHardwareParams) -> MovementTerm:
    """Row 2: stream the P_s window-slid edges."""
    _, _, _, _, P = g.astuple_f64()
    s, B = _f64(hw.sigma), _f64(hw.B)
    Ps = hw.Ps(P)
    iters = ceil(Ps * s / B)
    bits = minimum(Ps * s, B) * iters
    return MovementTerm("loadedges", "L2-L1", bits, iters)


def loadweights(g: GraphTileParams, hw: HyGCNHardwareParams) -> MovementTerm:
    """Row 3: load the (1 - Gamma) non-reused fraction of the N x T weights."""
    N, T, _, _, _ = g.astuple_f64()
    s, B, Mc = _f64(hw.sigma), _f64(hw.B), _f64(hw.Mc)
    gamma = _f64(hw.gamma)
    fresh = N * T * s * (1.0 - gamma)
    iters = ceil(fresh / minimum(B, Mc * s))
    bits = minimum(fresh, Mc * s, B) * iters
    return MovementTerm("loadweights", "L2-L1", bits, iters)


def aggregate(g: GraphTileParams, hw: HyGCNHardwareParams) -> MovementTerm:
    """Row 4: SIMD aggregation — every core handles <= 8 feature components."""
    N, _, _, _, P = g.astuple_f64()
    s, Ma = _f64(hw.sigma), _f64(hw.Ma)
    Ps = hw.Ps(P)
    iters = ceil(N * Ps * s / (Ma * 8.0))
    bits = minimum(N * Ps * s, Ma * 8.0) * iters
    return MovementTerm("aggregate", "L1-L1", bits, iters)


def writeinterphase(g: GraphTileParams, hw: HyGCNHardwareParams) -> MovementTerm:
    """Row 5: spill aggregated K x N features to the inter-phase buffer."""
    N, _, K, _, _ = g.astuple_f64()
    s, B = _f64(hw.sigma), _f64(hw.B)
    iters = ceil(K * N * s / B)
    bits = minimum(K * N * s, B) * iters
    return MovementTerm("writeinterphase", "L1-L2", bits, iters)


def combine(g: GraphTileParams, hw: HyGCNHardwareParams) -> MovementTerm:
    """Row 6: systolic matrix-vector combination (single on-array pass)."""
    N, T, K, _, _ = g.astuple_f64()
    s = _f64(hw.sigma)
    bits = K * N * s + N * T * s
    return MovementTerm("combine", "L1-L1", bits, np.ones_like(bits))


def readinterphase(g: GraphTileParams, hw: HyGCNHardwareParams) -> MovementTerm:
    """Row 7: the combination engine fetches aggregated features back."""
    N, _, _, _, P = g.astuple_f64()
    s, B, Mc = _f64(hw.sigma), _f64(hw.B), _f64(hw.Mc)
    Ps = hw.Ps(P)
    iters = ceil(Ps * N * s / minimum(B, Mc))
    bits = minimum(Ps * N * s, B, Mc) * iters
    return MovementTerm("readinterphase", "L2-L1", bits, iters)


def writeL2(g: GraphTileParams, hw: HyGCNHardwareParams) -> MovementTerm:
    """Row 8: write the K x T output features to the output buffer."""
    _, T, K, _, _ = g.astuple_f64()
    s, B = _f64(hw.sigma), _f64(hw.B)
    iters = ceil(K * T * s / B)
    bits = minimum(K * T * s, B) * iters
    return MovementTerm("writeL2", "L1-L2", bits, iters)


_ROWS = (loadvertL2, loadedges, loadweights, aggregate, writeinterphase,
         combine, readinterphase, writeL2)


class HyGCNModel(AcceleratorModel):
    """Table IV assembled: the HyGCN per-tile data-movement model."""

    name = "hygcn"

    def evaluate(
        self,
        graph: GraphTileParams,
        hw: HyGCNHardwareParams | None = None,
    ) -> ModelOutput:
        hw = hw or HyGCNHardwareParams()
        return ModelOutput(
            accelerator=self.name,
            terms=tuple(row(graph, hw) for row in _ROWS),
            meta={"hw": hw, "graph": graph},
        )
