"""EnGN analytical data-movement model — Table III of the paper, verbatim.

EnGN (Liang et al., IEEE TC 2020) processes aggregation and combination
sequentially on a single M x M' PE array, with a ring-edge-reduce (RER)
dataflow for aggregation and a dedicated cache (L2*) for high-degree
vertices.  Each closed form below implements one row of Table III; the
rows are assembled declaratively into :data:`ENGN_SPEC`
(a :class:`~repro.core.dataflow.DataflowSpec`) and evaluated by the shared
engine — :class:`EnGNModel` is the thin class-API adapter.

Faithfulness notes
------------------
* Every closed form matches Table III symbol-for-symbol.
* ``aggregate`` contains the sub-expression ``ceil(K (N - M) / M)``: for
  M >= N it would go negative (more PE rows than feature elements — the
  second streaming pass never happens).  We clamp the inner numerator at 0,
  which is the only reading that reproduces Fig. 3's reported non-monotone
  behaviour of data movement in M (decreasing, then increasing).  Recorded in
  DESIGN.md as an interpretation decision.
* The paper's prose mentions an ``intertile`` step (loading the next tile)
  that has no row in Table III; :meth:`EnGNModel.evaluate` can optionally
  append it as a repeat of the vertex loads (``include_intertile=True``),
  default off so totals match the published table.
"""

from __future__ import annotations

import numpy as np

from .dataflow import DataflowSpec, MovementSpec, SpecModel
from .notation import EnGNHardwareParams, GraphTileParams
from .terms import ModelOutput, MovementTerm, ceil, minimum

__all__ = ["EnGNModel", "ENGN_SPEC"]


def _f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def loadvertcache(g: GraphTileParams, hw: EnGNHardwareParams):
    """Row 1: stream the L high-degree vertices from the dedicated cache."""
    N, _, _, L, _ = g.astuple_f64()
    s, Bs, M = _f64(hw.sigma), hw.b_star, _f64(hw.M)
    iters = ceil(L * s / minimum(Bs, M * s))
    bits = minimum(L * s, M * s, Bs) * N * iters
    return bits, iters


def loadvertL2(g: GraphTileParams, hw: EnGNHardwareParams):
    """Row 2: stream the remaining K - L vertices from the L2 bank."""
    N, _, K, L, _ = g.astuple_f64()
    s, B, M = _f64(hw.sigma), _f64(hw.B), _f64(hw.M)
    rem = np.maximum(K - L, 0.0)
    iters = ceil(rem * s / minimum(B, M * s))
    bits = minimum(rem * s, M * s, B) * N * iters
    return bits, iters


def loadedges(g: GraphTileParams, hw: EnGNHardwareParams):
    """Row 3: stream the tile's P edges."""
    _, _, _, _, P = g.astuple_f64()
    s, B = _f64(hw.sigma), _f64(hw.B)
    iters = ceil(P * s / B)
    bits = minimum(P * s, B) * iters
    return bits, iters


def loadweights(g: GraphTileParams, hw: EnGNHardwareParams):
    """Row 4: load the N x T combination weights, streamed by output column."""
    N, T, _, _, _ = g.astuple_f64()
    s, B, M = _f64(hw.sigma), _f64(hw.B), _f64(hw.M)
    iters = ceil(T * s / minimum(B, M * s))
    bits = minimum(T * s, M * s, B) * N * iters
    return bits, iters


def aggregate(g: GraphTileParams, hw: EnGNHardwareParams):
    """Row 5: ring-edge-reduce aggregation across the PE array (L1-L1).

    Each of the ceil(K/M) vertex groups circulates partial sums around the
    M-PE ring (M-1 hops of T outputs each); features beyond the first M
    elements require extra streaming passes, ceil(K (N - M)+ / M).
    """
    N, T, K, _, _ = g.astuple_f64()
    s, M = _f64(hw.sigma), _f64(hw.M)
    passes = ceil(K / M) + ceil(K * np.maximum(N - M, 0.0) / M)
    bits = M * (M - 1.0) * T * passes * s
    return bits, passes


def writecache(g: GraphTileParams, hw: EnGNHardwareParams):
    """Row 6: write high-degree vertex results back to the dedicated cache."""
    _, T, _, L, _ = g.astuple_f64()
    s, Bs, M = _f64(hw.sigma), hw.b_star, _f64(hw.M)
    iters = ceil(L * s / minimum(M * s, Bs))
    bits = minimum(M * s, L * s, Bs) * T * iters
    return bits, iters


def writeL2(g: GraphTileParams, hw: EnGNHardwareParams):
    """Row 7: write the remaining results to the L2 bank."""
    _, T, K, L, _ = g.astuple_f64()
    s, B, M = _f64(hw.sigma), _f64(hw.B), _f64(hw.M)
    rem = np.maximum(K - L, 0.0)
    iters = ceil(rem * s / minimum(M * s, B))
    bits = minimum(M * s, rem * s, B) * T * iters
    return bits, iters


#: Table III, declaratively: the rows in published order.
ENGN_SPEC = DataflowSpec(
    name="engn",
    movements=(
        MovementSpec("loadvertcache", "L2*-L1", loadvertcache, role="vertex_in"),
        MovementSpec("loadvertL2", "L2-L1", loadvertL2, role="vertex_in"),
        MovementSpec("loadedges", "L2-L1", loadedges, role="edges"),
        MovementSpec("loadweights", "L2-L1", loadweights, role="weights"),
        MovementSpec("aggregate", "L1-L1", aggregate, role="compute"),
        MovementSpec("writecache", "L1-L2*", writecache, role="vertex_out"),
        MovementSpec("writeL2", "L1-L2", writeL2, role="vertex_out"),
    ),
    hw_factory=EnGNHardwareParams,
    description="EnGN single-array RER dataflow with a high-degree vertex "
                "cache (Table III).",
    # M_prime (the paper's M') enters only the fitting-factor diagnostic
    # (EnGNModel.fitting_factor), never a Table III movement row; B_star=None
    # aliases B and is skipped by the tracer, so it is not listed here.
    unused_hw=("M_prime",),
)


class EnGNModel(SpecModel):
    """Table III assembled: the EnGN per-tile data-movement model."""

    spec = ENGN_SPEC

    def evaluate(
        self,
        graph: GraphTileParams,
        hw: EnGNHardwareParams | None = None,
        *,
        include_intertile: bool = False,
    ) -> ModelOutput:
        hw = self.spec.resolve_hw(hw)
        out = self.spec.evaluate(
            graph, hw, extra_meta={"include_intertile": include_intertile})
        if include_intertile:
            nxt_cache = out["loadvertcache"]
            nxt_l2 = out["loadvertL2"]
            out = ModelOutput(
                accelerator=out.accelerator,
                terms=out.terms + (MovementTerm(
                    "intertile",
                    "L2-L1",
                    nxt_cache.data_bits + nxt_l2.data_bits,
                    nxt_cache.iterations + nxt_l2.iterations,
                ),),
                meta=out.meta,
            )
        return out

    def fitting_factor(self, graph: GraphTileParams, hw: EnGNHardwareParams) -> np.ndarray:
        """EnGN array-fitting factor K*N / M^2 studied in Fig. 6 (M = M')."""
        N, _, K, _, _ = graph.astuple_f64()
        return K * N / (_f64(hw.M) * _f64(hw.M_prime))
