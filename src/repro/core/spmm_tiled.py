"""Tiled block-dense SpMM baseline — the TPU/Pallas analogue as a dataflow.

This is the analytical counterpart of the fused Pallas kernel in
:mod:`repro.kernels.edge_aggregate`: the adjacency of a K-vertex tile is
cut into (Bn x Bk) dense blocks, each block-step performs
``acc += A[i,j] @ X[j]`` on the matrix unit, and on the last source block
the combine weight is applied straight out of the accumulator — so, unlike
HyGCN, there is **no inter-phase buffer movement level at all**.  The block
sizes default to the kernel's ``DEFAULT_BLOCK_N``/``DEFAULT_BLOCK_K``.

The price of the fusion shows up in topology traffic: block-dense storage
streams ``ceil(K/Bn)*ceil(K/Bk)`` full dense blocks regardless of sparsity,
where EnGN/HyGCN stream only the P edges.  The comparison between
``loadadjblocks`` here and ``loadedges`` there is exactly the
density-threshold question the kernel's DESIGN.md §3 entry records.

Model-audit note (DESIGN.md §16): the symbolic auditor confirms these
forms read neither ``graph.P`` nor ``graph.L`` — by construction, not
omission: block-dense traffic is sparsity-independent (no P), and there
is no high-degree vertex cache (no L).  ``python -m repro.analysis``
reports both as informational unused graph symbols.
"""

from __future__ import annotations

import numpy as np

from .dataflow import DataflowSpec, MovementSpec, SpecModel
from .notation import GraphTileParams, TiledSpMMHardwareParams
from .terms import ceil, minimum

__all__ = ["TiledSpMMModel", "SPMM_TILED_SPEC", "kernel_matched_hw"]


def _f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def _blocks(g: GraphTileParams, hw: TiledSpMMHardwareParams):
    _, _, K, _, _ = g.astuple_f64()
    nbn = ceil(K / _f64(hw.Bn))
    nbk = ceil(K / _f64(hw.Bk))
    return nbn, nbk


def loadadjblocks(g: GraphTileParams, hw: TiledSpMMHardwareParams):
    """Stream every (Bn x Bk) dense adjacency block once (zeros included)."""
    s_adj, B = _f64(hw.sigma_adj), _f64(hw.B)
    Bn, Bk = _f64(hw.Bn), _f64(hw.Bk)
    nbn, nbk = _blocks(g, hw)
    block_bits = Bn * Bk * s_adj
    iters = nbn * nbk * ceil(block_bits / B)
    bits = nbn * nbk * block_bits
    return bits, iters


def loadvertblocks(g: GraphTileParams, hw: TiledSpMMHardwareParams):
    """Stream a (Bk x N) feature block whenever its block index changes.

    The Pallas pipeline elides the DMA when consecutive grid steps map to
    the same block (DESIGN.md §10): with the source-block index innermost,
    X block j is re-fetched on every step — ``nbn * nbk`` fetches — except
    in the single-source-block schedule (nbk == 1), where the index is
    constant and X is fetched exactly once.
    """
    N, _, _, _, _ = g.astuple_f64()
    s, B, Bk = _f64(hw.sigma), _f64(hw.B), _f64(hw.Bk)
    nbn, nbk = _blocks(g, hw)
    n_fetch = np.where(nbk > 1.0, nbn * nbk, 1.0)
    block_bits = Bk * N * s
    iters = n_fetch * ceil(block_bits / B)
    bits = n_fetch * block_bits
    return bits, iters


def loadweights(g: GraphTileParams, hw: TiledSpMMHardwareParams):
    """Load the (N x T) combine weight once: its block index is constant
    over the whole grid, so the weight stays resident in VMEM."""
    N, T, _, _, _ = g.astuple_f64()
    s, B = _f64(hw.sigma), _f64(hw.B)
    iters = ceil(N * T * s / B)
    bits = N * T * s
    return bits, iters


def accumulate(g: GraphTileParams, hw: TiledSpMMHardwareParams):
    """VMEM accumulator read+write per block-step (the MXU aggregation)."""
    N, _, _, _, _ = g.astuple_f64()
    s, Bn = _f64(hw.sigma), _f64(hw.Bn)
    nbn, nbk = _blocks(g, hw)
    bits = 2.0 * nbn * nbk * Bn * N * s
    return bits, nbn * nbk


def combinefuse(g: GraphTileParams, hw: TiledSpMMHardwareParams):
    """Fused combine: one accumulator read + output-tile write per dst block."""
    N, T, _, _, _ = g.astuple_f64()
    s, Bn = _f64(hw.sigma), _f64(hw.Bn)
    nbn, _ = _blocks(g, hw)
    bits = nbn * Bn * (N + T) * s
    return bits, nbn


def writeout(g: GraphTileParams, hw: TiledSpMMHardwareParams):
    """Write the padded (ceil(K/Bn)*Bn x T) output tiles back to L2."""
    _, T, _, _, _ = g.astuple_f64()
    s, B, Bn = _f64(hw.sigma), _f64(hw.B), _f64(hw.Bn)
    nbn, _ = _blocks(g, hw)
    tile_bits = Bn * T * s
    iters = nbn * ceil(tile_bits / B)
    bits = nbn * tile_bits
    return bits, iters


def _runnable_analogue():
    """Conformance hook (DESIGN.md §10): the fused Pallas kernel analogue."""
    from .conformance import FusedSpMMAnalogue
    return FusedSpMMAnalogue()


SPMM_TILED_SPEC = DataflowSpec(
    name="spmm_tiled",
    movements=(
        MovementSpec("loadadjblocks", "L2-L1", loadadjblocks, role="edges"),
        MovementSpec("loadvertblocks", "L2-L1", loadvertblocks, role="vertex_in"),
        MovementSpec("loadweights", "L2-L1", loadweights, role="weights"),
        MovementSpec("accumulate", "L1-L1", accumulate, role="compute"),
        MovementSpec("combinefuse", "L1-L1", combinefuse, role="compute"),
        MovementSpec("writeout", "L1-L2", writeout, role="vertex_out"),
    ),
    hw_factory=TiledSpMMHardwareParams,
    description="Generic fused block-dense SpMM (the repo's Pallas-kernel "
                "analogue): no inter-phase buffer, dense topology blocks.",
    runnable=_runnable_analogue,
)


def kernel_matched_hw(**overrides) -> TiledSpMMHardwareParams:
    """Hardware params with Bn/Bk taken from the live Pallas kernel module.

    Falls back to the notation defaults when jax/pallas is not importable
    (the kernel module hard-imports both).
    """
    try:
        from ..kernels.edge_aggregate import DEFAULT_BLOCK_K, DEFAULT_BLOCK_N
        overrides.setdefault("Bn", DEFAULT_BLOCK_N)
        overrides.setdefault("Bk", DEFAULT_BLOCK_K)
    except Exception:  # pragma: no cover - jax always present in CI
        pass
    return TiledSpMMHardwareParams(**overrides)


class TiledSpMMModel(SpecModel):
    """Class-API adapter for the tiled-SpMM baseline."""

    spec = SPMM_TILED_SPEC
