"""The paper's methodology adapted to a TPU v5e pod.

The paper characterizes one ASIC: traffic between L2 and L1 plus on-array
movement, term by term, as closed forms in graph/hardware parameters.  On a
TPU pod the same decomposition becomes the *three-term roofline*:

=====================  =============================================
paper                  this module
=====================  =============================================
L2 <-> L1 traffic      HBM <-> VMEM bytes      -> ``memory_s``
on-array (L1-L1)       MXU compute             -> ``compute_s``
inter-PE ring (RER)    ICI collective bytes    -> ``collective_s``
iterations             seconds (bandwidth-normalized)
=====================  =============================================

Two kinds of objects live here:

1. :class:`TPUHardware` + :func:`roofline` — convert the dry-run's compiled
   HLO counters (FLOPs, HBM bytes, collective wire bytes) into the
   three seconds-valued roofline terms and identify the dominant one.
2. Analytical *collective primitives* (:func:`allgather_bytes`, ...) and
   per-parallel-strategy traffic models (:class:`CommTerm` lists) — the
   TPU analogues of Table III/IV rows, later validated against the HLO
   parser in :mod:`repro.core.hlo_analysis`.

Conventions
-----------
* All byte quantities are **wire bytes received per chip** for one executed
  step, assuming ring/bidirectional schedules (the standard XLA lowering).
* FLOPs / HBM bytes from ``compiled.cost_analysis()`` are per-chip (the SPMD
  module is the per-device program), so ``compute_s = flops / peak`` equals
  the brief's ``HLO_FLOPs_global / (chips * peak)``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Mapping, Sequence

__all__ = [
    "TPUHardware",
    "TPU_V5E",
    "RooflineReport",
    "roofline",
    "allgather_bytes",
    "reduce_scatter_bytes",
    "allreduce_bytes",
    "all_to_all_bytes",
    "collective_permute_bytes",
    "CommTerm",
    "CommModel",
    "dp_gradient_sync",
    "tp_activation_sync",
    "moe_dispatch_sync",
    "spmm_feature_allgather",
    "ring_spmm_traffic",
    "dlrm_embedding_exchange",
]


@dataclass(frozen=True)
class TPUHardware:
    """Per-chip TPU constants (brief-specified v5e numbers)."""

    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12        # FLOP/s
    hbm_bandwidth: float = 819e9           # bytes/s
    ici_bandwidth_per_link: float = 50e9   # bytes/s per link
    ici_links: int = 4                     # 2D-torus links per chip
    hbm_bytes: int = 16 * 2**30
    vmem_bytes: int = 128 * 2**20
    mxu_dim: int = 128                     # systolic tile (alignment analysis)
    dcn_bandwidth: float = 25e9            # bytes/s per chip, pod-to-pod


TPU_V5E = TPUHardware()


@dataclass(frozen=True)
class RooflineReport:
    """Three-term roofline for one (arch x shape x mesh) cell.

    The brief's formulae:
      compute_s    = HLO_FLOPs / (chips * peak)      [per-chip form]
      memory_s     = HLO_bytes / (chips * hbm_bw)
      collective_s = collective_bytes / (chips * link_bw)
    """

    cell: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float = 0.0
    meta: Mapping[str, object] = field(default_factory=dict)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic overlapped bound: the slowest term gates the step."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def step_time_serial_s(self) -> float:
        """Pessimistic bound with zero overlap."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat / padding / redundancy waste."""
        total_hlo = self.hlo_flops_per_chip * self.chips
        return self.model_flops / total_hlo if total_hlo else float("nan")

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the overlapped bound.

        = useful-FLOPs time / step time; 1.0 means perfectly compute-bound
        with zero wasted FLOPs.  This is the §Perf score.
        """
        if not self.model_flops:
            return float("nan")
        ideal = self.model_flops / (self.chips * TPU_V5E.peak_flops_bf16)
        return ideal / self.step_time_s if self.step_time_s else float("nan")

    def row(self) -> dict[str, object]:
        return {
            "cell": self.cell,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "model_flops": self.model_flops,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline(
    *,
    cell: str,
    chips: int,
    flops_per_chip: float,
    hbm_bytes_per_chip: float,
    collective_bytes_per_chip: float,
    model_flops: float = 0.0,
    hw: TPUHardware = TPU_V5E,
    meta: Mapping[str, object] | None = None,
) -> RooflineReport:
    return RooflineReport(
        cell=cell,
        chips=chips,
        compute_s=flops_per_chip / hw.peak_flops_bf16,
        memory_s=hbm_bytes_per_chip / hw.hbm_bandwidth,
        collective_s=collective_bytes_per_chip / hw.ici_bandwidth_per_link,
        hlo_flops_per_chip=flops_per_chip,
        hbm_bytes_per_chip=hbm_bytes_per_chip,
        collective_bytes_per_chip=collective_bytes_per_chip,
        model_flops=model_flops,
        meta=dict(meta or {}),
    )


# ---------------------------------------------------------------------------
# Collective primitives: wire bytes received per chip for ring schedules.
# These are the TPU analogues of the paper's min(.)*ceil(.) capacity forms;
# on a ring the "iterations" are the n-1 hops and the per-hop payload is the
# shard, so data movement is shard * (n-1) exactly as EnGN's RER moves
# M*(M-1)*T elements around its PE ring.
# ---------------------------------------------------------------------------

def allgather_bytes(global_bytes: float, n: int) -> float:
    """Ring all-gather of a tensor of ``global_bytes``: recv (n-1)/n of it."""
    return global_bytes * (n - 1) / n if n > 1 else 0.0


def reduce_scatter_bytes(global_bytes: float, n: int) -> float:
    return global_bytes * (n - 1) / n if n > 1 else 0.0


def allreduce_bytes(global_bytes: float, n: int) -> float:
    """Ring all-reduce = reduce-scatter + all-gather."""
    return 2.0 * global_bytes * (n - 1) / n if n > 1 else 0.0


def all_to_all_bytes(per_chip_bytes: float, n: int) -> float:
    """Each chip re-distributes its shard: keeps 1/n, exchanges the rest."""
    return per_chip_bytes * (n - 1) / n if n > 1 else 0.0


def collective_permute_bytes(per_chip_bytes: float) -> float:
    return per_chip_bytes


@dataclass(frozen=True)
class CommTerm:
    """One analytical communication term (a TPU 'movement level')."""

    name: str
    fabric: str                  # "ici" | "dcn" | "hbm"
    bytes_per_chip: float
    description: str = ""


@dataclass(frozen=True)
class CommModel:
    """A list of CommTerms = the communication model of one strategy."""

    strategy: str
    terms: tuple[CommTerm, ...]

    def total(self, fabric: str | None = None) -> float:
        return sum(t.bytes_per_chip for t in self.terms
                   if fabric is None or t.fabric == fabric)

    def __getitem__(self, name: str) -> CommTerm:
        for t in self.terms:
            if t.name == name:
                return t
        raise KeyError(name)


# ---------------------------------------------------------------------------
# Per-strategy analytical models.
# ---------------------------------------------------------------------------

def dp_gradient_sync(param_bytes: float, dp: int, *,
                     compressed_ratio: float = 1.0) -> CommModel:
    """Data-parallel gradient all-reduce over ``dp`` chips per step.

    ``compressed_ratio`` < 1 models int8 error-feedback compression
    (repro.optim.compression): wire bytes scale with the compressed width.
    """
    return CommModel("dp", (
        CommTerm("grad_allreduce", "ici",
                 allreduce_bytes(param_bytes * compressed_ratio, dp),
                 f"ring all-reduce of {param_bytes:.3g}B grads over dp={dp}"),
    ))


def tp_activation_sync(act_bytes_per_layer: float, layers: int, tp: int,
                       *, seq_sharded: bool = True) -> CommModel:
    """Megatron-style tensor parallelism: per layer, one all-gather into each
    of the two blocks (attn, mlp) and one reduce-scatter out of each when
    activations are sequence-sharded; plain all-reduce otherwise."""
    if seq_sharded:
        per_layer = 2 * (allgather_bytes(act_bytes_per_layer, tp)
                         + reduce_scatter_bytes(act_bytes_per_layer, tp))
        desc = "AG+RS x2 blocks/layer (sequence-sharded residual)"
    else:
        per_layer = 2 * allreduce_bytes(act_bytes_per_layer, tp)
        desc = "all-reduce x2 blocks/layer"
    return CommModel("tp", (
        CommTerm("tp_collectives", "ici", per_layer * layers, desc),
    ))


def moe_dispatch_sync(tokens_per_chip: int, d_model: int, top_k: int,
                      ep: int, layers: int, *, dtype_bytes: int = 2) -> CommModel:
    """Expert-parallel all-to-all: dispatch + return, per MoE layer."""
    payload = tokens_per_chip * top_k * d_model * dtype_bytes
    per_layer = 2 * all_to_all_bytes(payload, ep)
    return CommModel("ep", (
        CommTerm("moe_all_to_all", "ici", per_layer * layers,
                 f"dispatch+combine a2a of {payload:.3g}B x {layers} layers"),
    ))


def spmm_feature_allgather(n_nodes: int, d_feat: int, n: int,
                           *, dtype_bytes: int = 4, layers: int = 1) -> CommModel:
    """Baseline 1D-partitioned SpMM (paper-faithful "stream all vertices"):
    every chip all-gathers the full feature matrix each layer — the pod-scale
    analogue of EnGN's loadvertL2 with no degree cache."""
    global_bytes = n_nodes * d_feat * dtype_bytes
    return CommModel("spmm_1d", (
        CommTerm("feature_allgather", "ici",
                 allgather_bytes(global_bytes, n) * layers,
                 f"all-gather {global_bytes:.3g}B node features x {layers} layers"),
    ))


def ring_spmm_traffic(n_nodes: int, d_feat: int, n: int,
                      *, dtype_bytes: int = 4, layers: int = 1) -> CommModel:
    """RER-adapted ring SpMM: feature shards circulate the ICI ring, each hop
    overlapped with the local segment-sum of the resident shard.

    Total wire volume equals the all-gather (the ring moves the same bytes —
    EnGN's Fig. 3 lesson that RER movement is large but cheap because it
    stays on the fast fabric), but no chip ever materializes the full
    feature matrix, and each hop is overlappable with compute.
    """
    global_bytes = n_nodes * d_feat * dtype_bytes
    return CommModel("spmm_ring", (
        CommTerm("ring_hops", "ici",
                 allgather_bytes(global_bytes, n) * layers,
                 f"{n - 1} ppermute hops of {global_bytes / max(n,1):.3g}B shards"),
    ))


def dlrm_embedding_exchange(batch_per_chip: int, n_tables: int, embed_dim: int,
                            n: int, *, dtype_bytes: int = 4,
                            with_backward: bool = True) -> CommModel:
    """Model-parallel embedding tables + data-parallel MLPs: the MLPerf DLRM
    hybrid.  Forward: pooled embeddings all-to-all from table-major to
    batch-major; backward mirrors it with gradients."""
    payload = batch_per_chip * n_tables * embed_dim * dtype_bytes
    factor = 2 if with_backward else 1
    return CommModel("dlrm_hybrid", (
        CommTerm("embedding_all_to_all", "ici",
                 factor * all_to_all_bytes(payload, n),
                 f"a2a of {payload:.3g}B pooled embeddings (fwd{'+bwd' if with_backward else ''})"),
    ))


def mxu_padding_waste(dim: int, hw: TPUHardware = TPU_V5E) -> float:
    """Fraction of MXU work wasted padding ``dim`` to the systolic tile —
    the TPU re-statement of EnGN's array-fitting factor (Fig. 6)."""
    padded = math.ceil(dim / hw.mxu_dim) * hw.mxu_dim
    return 1.0 - dim / padded
