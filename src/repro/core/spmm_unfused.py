"""Unfused two-pass block-dense SpMM — the HyGCN inter-phase analogue.

HyGCN's defining cost (Table IV, Fig. 4) is the inter-phase buffer between
its aggregation and combination engines: aggregated features are written
off-array (``writeinterphase`` = K*N*sigma bits) and read back by the
combination engine (``readinterphase``).  The fused kernel analogue
(:mod:`repro.core.spmm_tiled` / :mod:`repro.kernels.edge_aggregate`)
eliminates exactly those terms by keeping the aggregate in a VMEM
accumulator.

This spec models the *unfused* TPU pipeline — two separately-compiled
Pallas kernels (:mod:`repro.kernels.edge_aggregate_unfused`): pass 1
aggregates ``Y_agg = A @ X`` and writes the (K x N) aggregate to HBM;
pass 2 reads it back and combines ``Y = Y_agg @ W``.  Every other movement
level is identical to ``spmm_tiled``, so the analytical fused-minus-unfused
delta is precisely the two inter-phase terms — which the conformance
subsystem (:mod:`repro.core.conformance`) pins against measured bytes of
the compiled programs.

On the paper's ``P_s`` (edges surviving window sliding): block-dense
aggregation materializes each destination vertex's aggregate exactly once,
so the combination pass re-reads K dense rows rather than P_s edge-wise
gathers — the analogue realizes the paper's ``P_s*N*sigma`` read term at
``P_s = K`` (DESIGN.md §10).

Model-audit note (DESIGN.md §16): like :mod:`repro.core.spmm_tiled`,
these forms are independent of ``graph.P``/``graph.L`` by construction
(sparsity-independent block streaming, no vertex cache); the auditor
lists both as informational unused graph symbols.
"""

from __future__ import annotations

from .dataflow import DataflowSpec, MovementSpec, SpecModel
from .notation import GraphTileParams, TiledSpMMHardwareParams
from .spmm_tiled import (accumulate, combinefuse, loadadjblocks,
                         loadvertblocks, loadweights, writeout, _blocks, _f64)
from .terms import ceil

__all__ = ["UnfusedSpMMModel", "SPMM_UNFUSED_SPEC"]


def writeinterphase(g: GraphTileParams, hw: TiledSpMMHardwareParams):
    """Pass 1 spills the padded (ceil(K/Bn)*Bn x N) aggregate to L2."""
    N, _, _, _, _ = g.astuple_f64()
    s, B, Bn = _f64(hw.sigma), _f64(hw.B), _f64(hw.Bn)
    nbn, _ = _blocks(g, hw)
    tile_bits = Bn * N * s
    iters = nbn * ceil(tile_bits / B)
    bits = nbn * tile_bits
    return bits, iters


def readinterphase(g: GraphTileParams, hw: TiledSpMMHardwareParams):
    """Pass 2 fetches each aggregate tile back — the P_s = K dense-row
    realization of the paper's ``P_s*N*sigma`` read term."""
    return writeinterphase(g, hw)


def _runnable_analogue():
    """Conformance hook (DESIGN.md §10): the two-pass Pallas kernel pair."""
    from .conformance import UnfusedSpMMAnalogue
    return UnfusedSpMMAnalogue()


SPMM_UNFUSED_SPEC = DataflowSpec(
    name="spmm_unfused",
    movements=(
        MovementSpec("loadadjblocks", "L2-L1", loadadjblocks, role="edges"),
        MovementSpec("loadvertblocks", "L2-L1", loadvertblocks, role="vertex_in"),
        MovementSpec("accumulate", "L1-L1", accumulate, role="compute"),
        MovementSpec("writeinterphase", "L1-L2", writeinterphase, role="interphase"),
        MovementSpec("readinterphase", "L2-L1", readinterphase, role="interphase"),
        MovementSpec("loadweights", "L2-L1", loadweights, role="weights"),
        # same on-array combine as the fused kernel (one aggregate-tile read
        # + output write per dst block) — shared so the fused-minus-unfused
        # delta stays exactly the two interphase terms.
        MovementSpec("combine", "L1-L1", combinefuse, role="compute"),
        MovementSpec("writeout", "L1-L2", writeout, role="vertex_out"),
    ),
    hw_factory=TiledSpMMHardwareParams,
    description="Unfused two-pass block-dense SpMM (HyGCN inter-phase "
                "analogue): the aggregate round-trips through HBM between "
                "separately-compiled aggregation and combination kernels.",
    runnable=_runnable_analogue,
)


class UnfusedSpMMModel(SpecModel):
    """Class-API adapter for the unfused two-pass baseline."""

    spec = SPMM_UNFUSED_SPEC
