"""Movement-term algebra shared by all analytical accelerator models.

The paper characterizes an accelerator dataflow as a list of *movement
levels*, each with (a) an amount of data movement in bits, (b) a number of
iterations implied by PE / bandwidth constraints, and (c) the memory-hierarchy
levels the traffic crosses.  This module provides the shared representation
plus the handful of arithmetic helpers every closed form in Tables III/IV
uses (``min`` of capacity constraints, ``ceil`` of occupancy ratios).

Hierarchy classes
-----------------
``L2-L1`` / ``L1-L2``  off-array traffic through the memory bank (expensive,
                       the paper quotes ~6x an L1 access);
``L2*-L1`` / ``L1-L2*`` traffic through EnGN's dedicated high-degree vertex
                       cache;
``L1-L1``              on-array traffic (EnGN's ring-edge-reduce, HyGCN's
                       SIMD aggregation / systolic combination).

On the TPU adaptation (:mod:`repro.core.tpu_model`) the same classes are
reused with ``L2 := HBM``, ``L1 := VMEM`` and the ``L1-L1`` class standing in
for on-chip / inter-chip fabric traffic.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

__all__ = [
    "ceil",
    "minimum",
    "MovementTerm",
    "ModelOutput",
    "AcceleratorModel",
    "L2_CLASSES",
    "L1_CLASSES",
    "CACHE_CLASSES",
]

L2_CLASSES = ("L2-L1", "L1-L2")
CACHE_CLASSES = ("L2*-L1", "L1-L2*")
L1_CLASSES = ("L1-L1",)
_VALID_HIERARCHIES = frozenset(L2_CLASSES + CACHE_CLASSES + L1_CLASSES)


def _f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def ceil(x) -> np.ndarray:
    """Exact ceiling in float64 (all operands in the models are integral)."""
    return np.ceil(_f64(x))


def minimum(*xs) -> np.ndarray:
    """Variadic broadcasting minimum — the capacity-constraint operator."""
    out = _f64(xs[0])
    for x in xs[1:]:
        out = np.minimum(out, _f64(x))
    return out


@dataclass(frozen=True)
class MovementTerm:
    """One movement level of Table III / Table IV.

    ``data_bits`` and ``iterations`` broadcast together — array-valued when a
    parameter sweep is evaluated.
    """

    name: str
    hierarchy: str
    data_bits: np.ndarray
    iterations: np.ndarray

    def __post_init__(self) -> None:
        if self.hierarchy not in _VALID_HIERARCHIES:
            raise ValueError(
                f"unknown hierarchy {self.hierarchy!r} for term {self.name!r}; "
                f"expected one of {sorted(_VALID_HIERARCHIES)}"
            )
        object.__setattr__(self, "data_bits", _f64(self.data_bits))
        object.__setattr__(self, "iterations", _f64(self.iterations))

    @property
    def is_offchip(self) -> bool:
        return self.hierarchy in L2_CLASSES

    @property
    def is_cache(self) -> bool:
        return self.hierarchy in CACHE_CLASSES

    @property
    def is_onchip(self) -> bool:
        return self.hierarchy in L1_CLASSES


@dataclass(frozen=True)
class ModelOutput:
    """Evaluated model: the full movement-level breakdown for one dataflow."""

    accelerator: str
    terms: tuple[MovementTerm, ...]
    meta: Mapping[str, object] = field(default_factory=dict)

    def __getitem__(self, name: str) -> MovementTerm:
        for t in self.terms:
            if t.name == name:
                return t
        raise KeyError(f"{self.accelerator} model has no term {name!r}; "
                       f"available: {[t.name for t in self.terms]}")

    def names(self) -> list[str]:
        return [t.name for t in self.terms]

    def select(self, hierarchies: Sequence[str] | None = None) -> tuple[MovementTerm, ...]:
        if hierarchies is None:
            return self.terms
        keep = frozenset(hierarchies)
        return tuple(t for t in self.terms if t.hierarchy in keep)

    def total_bits(self, hierarchies: Sequence[str] | None = None) -> np.ndarray:
        terms = self.select(hierarchies)
        return sum((t.data_bits for t in terms), start=_f64(0.0))

    def total_iterations(self, hierarchies: Sequence[str] | None = None) -> np.ndarray:
        terms = self.select(hierarchies)
        return sum((t.iterations for t in terms), start=_f64(0.0))

    def scaled(self, factor) -> "ModelOutput":
        """Every term's bits and iterations multiplied by ``factor``.

        The composition layer uses this to repeat a per-tile evaluation over
        a tile schedule (:mod:`repro.core.compose`).
        """
        f = _f64(factor)
        return ModelOutput(
            accelerator=self.accelerator,
            terms=tuple(MovementTerm(t.name, t.hierarchy,
                                     t.data_bits * f, t.iterations * f)
                        for t in self.terms),
            meta=self.meta,
        )

    def breakdown(self) -> dict[str, np.ndarray]:
        return {t.name: t.data_bits for t in self.terms}

    def iteration_breakdown(self) -> dict[str, np.ndarray]:
        return {t.name: t.iterations for t in self.terms}

    # Convenience groupings used throughout Sec. IV of the paper.
    def offchip_bits(self) -> np.ndarray:
        return self.total_bits(L2_CLASSES)

    def cache_bits(self) -> np.ndarray:
        return self.total_bits(CACHE_CLASSES)

    def onchip_bits(self) -> np.ndarray:
        return self.total_bits(L1_CLASSES)


class AcceleratorModel:
    """Base class: an analytical data-movement model of one accelerator.

    Subclasses implement :meth:`evaluate` mapping (graph-tile params,
    hardware params) -> :class:`ModelOutput`.  All closed forms broadcast, so
    array-valued parameters evaluate whole sweeps in one call.
    """

    name: str = "abstract"

    def evaluate(self, graph, hw) -> ModelOutput:  # pragma: no cover - interface
        raise NotImplementedError

    def total_bits(self, graph, hw, hierarchies=None) -> np.ndarray:
        return self.evaluate(graph, hw).total_bits(hierarchies)

    def total_iterations(self, graph, hw, hierarchies=None) -> np.ndarray:
        return self.evaluate(graph, hw).total_iterations(hierarchies)


def tabulate(output: ModelOutput, *, scalar_fmt: str = "{:>14.4g}") -> str:
    """Render a ModelOutput of scalar terms as the paper's table layout."""
    rows = [f"{'movement level':<18}{'data movement [bits]':>22}{'iterations':>14}  hierarchy"]
    for t in output.terms:
        bits = np.asarray(t.data_bits)
        iters = np.asarray(t.iterations)
        if bits.ndim == 0:
            rows.append(
                f"{t.name:<18}{scalar_fmt.format(float(bits)):>22}"
                f"{scalar_fmt.format(float(iters)):>14}  {t.hierarchy}"
            )
        else:
            rows.append(f"{t.name:<18}{'<array sweep>':>22}{'<array sweep>':>14}  {t.hierarchy}")
    return "\n".join(rows)
