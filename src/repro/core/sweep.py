"""Parameter-sweep engine reproducing the paper's Figures 3-7 — and beyond.

As of the scenario front-door redesign (DESIGN.md §11) every ``figN_*``
function is a thin client of :mod:`repro.api`: it builds the figure's
named scenario template (:mod:`repro.api.templates`), hands the batch to
the planner (one broadcast closed-form call per dataflow — no Python loop
per grid cell), and reshapes the stacked results onto the figure's grid.
The outputs are bit-identical to the pre-redesign meshgrid evaluation
(pinned in ``tests/test_registry.py``): the closed forms are elementwise
float64 algebra, so stacking cells along a batch axis instead of a
meshgrid cannot change a single bit.

Each ``figN_*`` mirrors one figure at its Sec. IV defaults (N=30, T=5,
B=1000, sigma=4, P=10K) and returns a :class:`SweepResult` with labelled
axes and a per-term breakdown grid.  :func:`sweep_accelerators` broadcasts
one parameter grid across *every* registered dataflow — one evaluation per
accelerator — and stacks the results along a leading accelerator axis
(:class:`AcceleratorSweepResult`), the comparative study the paper's
Sec. IV narrates for any number of dataflows.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.api import evaluate_groups, templates, tile_scenarios_from_graph

from . import registry
from .engn import EnGNModel
from .notation import EnGNHardwareParams, GraphTileParams, paper_default_graph
from .terms import CACHE_CLASSES, L1_CLASSES, L2_CLASSES, ModelOutput

__all__ = [
    "SweepResult",
    "AcceleratorSweepResult",
    "sweep_accelerators",
    "fig3_engn_movement",
    "fig4_hygcn_movement",
    "fig5_iterations_vs_bandwidth",
    "fig6_fitting_factor",
    "fig7_systolic_reuse",
    "DEFAULT_K_SWEEP",
    "DEFAULT_M_SWEEP",
    "DEFAULT_B_SWEEP",
]

# Canonical grids live with the templates; re-exported here for the
# pre-redesign import surface.
DEFAULT_K_SWEEP = templates.DEFAULT_K_SWEEP
DEFAULT_M_SWEEP = templates.DEFAULT_M_SWEEP
DEFAULT_B_SWEEP = templates.DEFAULT_B_SWEEP


def _flatten_columns(axes: Mapping[str, np.ndarray],
                     columns: Mapping[str, np.ndarray]) -> tuple[list[str], np.ndarray]:
    """One np.stack flatten: (column names, (n_cells, n_cols) float matrix).

    Axis columns come first (meshgrid order), then the value columns
    broadcast to the grid shape and raveled.  This replaces the former
    per-record Python loop: a whole sweep flattens in one vectorized shot.
    """
    names = list(axes)
    grids = np.meshgrid(*[axes[n] for n in names], indexing="ij")
    shape = grids[0].shape if grids else ()
    cols = names + list(columns)
    mat = np.stack(
        [g.ravel() for g in grids]
        + [np.broadcast_to(np.asarray(v, np.float64), shape).ravel()
           for v in columns.values()],
        axis=1,
    )
    return cols, mat


@dataclass(frozen=True)
class SweepResult:
    """A labelled sweep: ``axes`` name the grid dims of every value array."""

    figure: str
    axes: Mapping[str, np.ndarray]
    data_bits: Mapping[str, np.ndarray]        # per movement level
    iterations: Mapping[str, np.ndarray]       # per movement level
    meta: Mapping[str, object] = field(default_factory=dict)

    @property
    def total_bits(self) -> np.ndarray:
        return sum(self.data_bits.values())

    @property
    def total_iterations(self) -> np.ndarray:
        return sum(self.iterations.values())

    def rows(self) -> list[dict[str, float]]:
        """Flatten to records — the benchmark harness prints these as CSV."""
        columns = {"total_bits": self.total_bits,
                   "total_iterations": self.total_iterations}
        columns.update({f"bits_{term}": arr for term, arr in self.data_bits.items()})
        cols, mat = _flatten_columns(self.axes, columns)
        return [dict(zip(cols, row)) for row in mat.tolist()]


@dataclass(frozen=True)
class AcceleratorSweepResult:
    """A sweep stacked across accelerators: arrays have shape (A, *grid).

    ``total_bits`` / ``total_iterations`` / the per-hierarchy-class maps all
    carry a leading axis indexed by ``accelerators``; a row dump tags each
    record with its accelerator name.
    """

    figure: str
    accelerators: tuple[str, ...]
    axes: Mapping[str, np.ndarray]
    total_bits: np.ndarray
    total_iterations: np.ndarray
    class_bits: Mapping[str, np.ndarray]   # offchip / cache / onchip -> (A, *grid)
    meta: Mapping[str, object] = field(default_factory=dict)

    def accelerator_index(self, name: str) -> int:
        return self.accelerators.index(name)

    def rows(self) -> list[dict[str, object]]:
        out: list[dict[str, object]] = []
        for a, name in enumerate(self.accelerators):
            columns = {"total_bits": self.total_bits[a],
                       "total_iterations": self.total_iterations[a]}
            columns.update({f"bits_{cls}": arr[a]
                            for cls, arr in self.class_bits.items()})
            cols, mat = _flatten_columns(self.axes, columns)
            out.extend({"accelerator": name, **dict(zip(cols, row))}
                       for row in mat.tolist())
        return out


def _sweep_result_from_template(tb: "templates.TemplateBatch",
                                **extra_meta) -> SweepResult:
    """Evaluate a figure template and reshape the stacked output to its grid.

    A figure template is one plan group (one dataflow, one override-key
    set), so the planner performs exactly one broadcast evaluation; each
    movement term's batch column C-reshapes onto the meshgrid ``ij`` grid.
    (`evaluate_groups` is the materialization-free planner path — the
    figure only needs the stacked group output, not per-cell results.)
    """
    (group,) = evaluate_groups(tb.scenarios)
    out = group.output
    shape = tb.grid_shape
    n = len(tb.scenarios)

    def grid(arr) -> np.ndarray:
        return np.broadcast_to(np.asarray(arr, np.float64), (n,)).reshape(shape)

    return SweepResult(
        figure=tb.figure,
        axes={k: np.asarray(v, np.float64) for k, v in tb.axes.items()},
        data_bits={t.name: grid(t.data_bits) for t in out.terms},
        iterations={t.name: grid(t.iterations) for t in out.terms},
        meta={**dict(tb.meta), **extra_meta},
    )


def sweep_accelerators(
    accelerators: Sequence[str] | None = None,
    K: np.ndarray = DEFAULT_K_SWEEP,
    *,
    graph: GraphTileParams | None = None,
    axes: Mapping[str, np.ndarray] | None = None,
    figure: str = "sweep_accelerators",
) -> AcceleratorSweepResult:
    """Evaluate every (registered) accelerator over one grid, stacked.

    The grid flattens to a scenario batch (one scenario per accelerator
    per cell) and the planner evaluates each dataflow **once** on the
    whole stacked batch; the per-accelerator totals are then reshaped and
    ``np.stack``-ed along a leading accelerator axis.  Pass ``graph`` to
    sweep a custom array-valued tile instead of the Sec. IV defaults; when
    exactly one graph field is array-valued the sweep axis is inferred,
    otherwise label the grid explicitly via ``axes`` (meshgrid ``ij``
    order, like :class:`SweepResult`).
    """
    names = tuple(accelerators) if accelerators is not None else registry.names()
    K = np.atleast_1d(np.asarray(K, np.float64))
    g = graph if graph is not None else paper_default_graph(K)
    shape = np.broadcast_shapes(*(np.shape(v) for v in g.astuple_f64()))
    if graph is None:
        axes = {"K": K}
    elif axes is None:
        arr_fields = {f: np.asarray(getattr(g, f), np.float64)
                      for f in ("N", "T", "K", "L", "P")
                      if np.ndim(getattr(g, f)) == 1}
        if len(arr_fields) != 1:
            raise ValueError(
                "cannot infer the sweep axes of a custom graph with "
                f"{len(arr_fields)} 1-D array-valued fields; pass axes= "
                "naming the grid explicitly")
        axes = arr_fields
    grid_shape = tuple(len(np.atleast_1d(v)) for v in axes.values())
    if grid_shape != shape:
        raise ValueError(f"axes grid shape {grid_shape} does not match the "
                         f"graph broadcast shape {shape}")
    # dict.fromkeys: dedupe while preserving order — a repeated name costs
    # one evaluation and reuses the stacked output for every occurrence.
    scenarios = [s for name in dict.fromkeys(names)
                 for s in tile_scenarios_from_graph(name, g, shape)]
    groups = evaluate_groups(scenarios)
    outputs: dict[str, ModelOutput] = {grp.dataflow: grp.output
                                       for grp in groups}
    assert len(groups) == len(set(names)), "one broadcast call per dataflow"
    n = int(np.prod(shape)) if shape else 1

    def stack(fn):
        return np.stack([
            np.broadcast_to(np.asarray(fn(outputs[name]), np.float64),
                            (n,)).reshape(shape)
            for name in names])

    return AcceleratorSweepResult(
        figure=figure,
        accelerators=names,
        axes={k: np.atleast_1d(np.asarray(v, np.float64))
              for k, v in axes.items()},
        total_bits=stack(lambda o: o.total_bits()),
        total_iterations=stack(lambda o: o.total_iterations()),
        class_bits={
            "offchip": stack(lambda o: o.total_bits(L2_CLASSES)),
            "cache": stack(lambda o: o.total_bits(CACHE_CLASSES)),
            "onchip": stack(lambda o: o.total_bits(L1_CLASSES)),
        },
        meta={"outputs": tuple(outputs[name] for name in names),
              "n_evaluations": len(groups)},
    )


def fig3_engn_movement(
    K: np.ndarray = DEFAULT_K_SWEEP,
    M: np.ndarray = DEFAULT_M_SWEEP,
) -> SweepResult:
    """Fig. 3: EnGN per-level data movement across tile size and PE array.

    The paper plots M = M' ("for the sake of clarity"); we sweep both equal.
    """
    return _sweep_result_from_template(templates.fig3(K=K, M=M))


def fig4_hygcn_movement(
    K: np.ndarray = DEFAULT_K_SWEEP,
    Ma: np.ndarray = DEFAULT_M_SWEEP,
) -> SweepResult:
    """Fig. 4: HyGCN per-level data movement across tile size and SIMD cores."""
    return _sweep_result_from_template(templates.fig4(K=K, Ma=Ma))


def fig5_iterations_vs_bandwidth(
    accelerator: str,
    B: np.ndarray = DEFAULT_B_SWEEP,
    K: np.ndarray = np.array([256, 1024, 4096], dtype=np.float64),
) -> SweepResult:
    """Fig. 5(a)/(b): total iterations vs memory bandwidth per workload size.

    Any registered accelerator works — every hardware record has a ``B``
    (L2 bandwidth) field to sweep.
    """
    return _sweep_result_from_template(templates.fig5(accelerator, B=B, K=K))


def fig6_fitting_factor(
    K: float = 1024.0,
    M: np.ndarray = np.array([4, 8, 16, 32, 64, 128, 256, 512], dtype=np.float64),
) -> SweepResult:
    """Fig. 6: EnGN iterations vs the array-fitting factor K*N / M^2."""
    M = np.asarray(M, np.float64)
    ff = EnGNModel().fitting_factor(paper_default_graph(K),
                                    EnGNHardwareParams(M=M, M_prime=M))
    return _sweep_result_from_template(templates.fig6(K=K, M=M),
                                       fitting_factor=ff)


def fig7_systolic_reuse(
    gamma: np.ndarray = np.linspace(0.0, 0.99, 34),
    N: np.ndarray = np.array([30, 128, 512], dtype=np.float64),
) -> SweepResult:
    """Fig. 7: HyGCN loadweights movement vs systolic reuse Gamma and depth N."""
    return _sweep_result_from_template(templates.fig7(gamma=gamma, N=N))
