"""Parameter-sweep engine reproducing the paper's Figures 3-7.

All closed forms in :mod:`repro.core.engn` / :mod:`repro.core.hygcn`
broadcast, so a 2-D sweep is a single evaluation over ``np.meshgrid`` inputs
— no Python loops.  Each ``figN_*`` function mirrors one figure of the paper
at its Sec. IV defaults (N=30, T=5, B=1000, sigma=4, P=10K) and returns a
:class:`SweepResult` with labelled axes and a per-term breakdown grid.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping

import numpy as np

from .engn import EnGNModel
from .hygcn import HyGCNModel
from .notation import (EnGNHardwareParams, GraphTileParams,
                       HyGCNHardwareParams, paper_default_graph)

__all__ = [
    "SweepResult",
    "fig3_engn_movement",
    "fig4_hygcn_movement",
    "fig5_iterations_vs_bandwidth",
    "fig6_fitting_factor",
    "fig7_systolic_reuse",
    "DEFAULT_K_SWEEP",
    "DEFAULT_M_SWEEP",
    "DEFAULT_B_SWEEP",
]

DEFAULT_K_SWEEP = np.array([64, 128, 256, 512, 1024, 2048, 4096, 8192], dtype=np.float64)
DEFAULT_M_SWEEP = np.array([4, 8, 16, 32, 64, 128, 256], dtype=np.float64)
DEFAULT_B_SWEEP = np.logspace(1, 5, 33, dtype=np.float64)  # 10 .. 100k bits/iter


@dataclass(frozen=True)
class SweepResult:
    """A labelled sweep: ``axes`` name the grid dims of every value array."""

    figure: str
    axes: Mapping[str, np.ndarray]
    data_bits: Mapping[str, np.ndarray]        # per movement level
    iterations: Mapping[str, np.ndarray]       # per movement level
    meta: Mapping[str, object] = field(default_factory=dict)

    @property
    def total_bits(self) -> np.ndarray:
        return sum(self.data_bits.values())

    @property
    def total_iterations(self) -> np.ndarray:
        return sum(self.iterations.values())

    def rows(self) -> list[dict[str, float]]:
        """Flatten to records — the benchmark harness prints these as CSV."""
        names = list(self.axes)
        grids = np.meshgrid(*[self.axes[n] for n in names], indexing="ij")
        out: list[dict[str, float]] = []
        total_b = np.broadcast_to(self.total_bits, grids[0].shape)
        total_i = np.broadcast_to(self.total_iterations, grids[0].shape)
        for idx in np.ndindex(grids[0].shape):
            rec = {n: float(g[idx]) for n, g in zip(names, grids)}
            rec["total_bits"] = float(total_b[idx])
            rec["total_iterations"] = float(total_i[idx])
            for term, arr in self.data_bits.items():
                rec[f"bits_{term}"] = float(np.broadcast_to(arr, grids[0].shape)[idx])
            out.append(rec)
        return out


def _grid(*axes: np.ndarray) -> tuple[np.ndarray, ...]:
    return tuple(np.meshgrid(*axes, indexing="ij"))


def fig3_engn_movement(
    K: np.ndarray = DEFAULT_K_SWEEP,
    M: np.ndarray = DEFAULT_M_SWEEP,
) -> SweepResult:
    """Fig. 3: EnGN per-level data movement across tile size and PE array.

    The paper plots M = M' ("for the sake of clarity"); we sweep both equal.
    """
    Kg, Mg = _grid(np.asarray(K, np.float64), np.asarray(M, np.float64))
    graph = paper_default_graph(Kg)
    hw = EnGNHardwareParams(M=Mg, M_prime=Mg)
    out = EnGNModel().evaluate(graph, hw)
    return SweepResult(
        figure="fig3",
        axes={"K": np.asarray(K, np.float64), "M": np.asarray(M, np.float64)},
        data_bits=out.breakdown(),
        iterations=out.iteration_breakdown(),
        meta={"model": "engn"},
    )


def fig4_hygcn_movement(
    K: np.ndarray = DEFAULT_K_SWEEP,
    Ma: np.ndarray = DEFAULT_M_SWEEP,
) -> SweepResult:
    """Fig. 4: HyGCN per-level data movement across tile size and SIMD cores."""
    Kg, Mag = _grid(np.asarray(K, np.float64), np.asarray(Ma, np.float64))
    graph = paper_default_graph(Kg)
    hw = HyGCNHardwareParams(Ma=Mag)
    out = HyGCNModel().evaluate(graph, hw)
    return SweepResult(
        figure="fig4",
        axes={"K": np.asarray(K, np.float64), "Ma": np.asarray(Ma, np.float64)},
        data_bits=out.breakdown(),
        iterations=out.iteration_breakdown(),
        meta={"model": "hygcn"},
    )


def fig5_iterations_vs_bandwidth(
    accelerator: str,
    B: np.ndarray = DEFAULT_B_SWEEP,
    K: np.ndarray = np.array([256, 1024, 4096], dtype=np.float64),
) -> SweepResult:
    """Fig. 5(a)/(b): total iterations vs memory bandwidth per workload size."""
    Bg, Kg = _grid(np.asarray(B, np.float64), np.asarray(K, np.float64))
    graph = paper_default_graph(Kg)
    if accelerator == "engn":
        out = EnGNModel().evaluate(graph, EnGNHardwareParams(B=Bg))
    elif accelerator == "hygcn":
        out = HyGCNModel().evaluate(graph, HyGCNHardwareParams(B=Bg))
    else:
        raise ValueError(f"unknown accelerator {accelerator!r}")
    return SweepResult(
        figure="fig5a" if accelerator == "engn" else "fig5b",
        axes={"B": np.asarray(B, np.float64), "K": np.asarray(K, np.float64)},
        data_bits=out.breakdown(),
        iterations=out.iteration_breakdown(),
        meta={"model": accelerator},
    )


def fig6_fitting_factor(
    K: float = 1024.0,
    M: np.ndarray = np.array([4, 8, 16, 32, 64, 128, 256, 512], dtype=np.float64),
) -> SweepResult:
    """Fig. 6: EnGN iterations vs the array-fitting factor K*N / M^2."""
    M = np.asarray(M, np.float64)
    graph = paper_default_graph(K)
    hw = EnGNHardwareParams(M=M, M_prime=M)
    model = EnGNModel()
    out = model.evaluate(graph, hw)
    ff = model.fitting_factor(graph, hw)
    return SweepResult(
        figure="fig6",
        axes={"M": M},
        data_bits=out.breakdown(),
        iterations=out.iteration_breakdown(),
        meta={"model": "engn", "fitting_factor": ff, "K": K},
    )


def fig7_systolic_reuse(
    gamma: np.ndarray = np.linspace(0.0, 0.99, 34),
    N: np.ndarray = np.array([30, 128, 512], dtype=np.float64),
) -> SweepResult:
    """Fig. 7: HyGCN loadweights movement vs systolic reuse Gamma and depth N."""
    Gg, Ng = _grid(np.asarray(gamma, np.float64), np.asarray(N, np.float64))
    graph = paper_default_graph(1024.0).replace(N=Ng)
    out = HyGCNModel().evaluate(graph, HyGCNHardwareParams(gamma=Gg))
    return SweepResult(
        figure="fig7",
        axes={"gamma": np.asarray(gamma, np.float64), "N": np.asarray(N, np.float64)},
        data_bits=out.breakdown(),
        iterations=out.iteration_breakdown(),
        meta={"model": "hygcn"},
    )
