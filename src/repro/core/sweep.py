"""Parameter-sweep engine reproducing the paper's Figures 3-7 — and beyond.

All closed forms in the registered dataflow specs broadcast, so a 2-D sweep
is a single evaluation over ``np.meshgrid`` inputs — no Python loops.  Each
``figN_*`` function mirrors one figure of the paper at its Sec. IV defaults
(N=30, T=5, B=1000, sigma=4, P=10K) and returns a :class:`SweepResult` with
labelled axes and a per-term breakdown grid.

Accelerators are resolved by name through :mod:`repro.core.registry`;
:func:`sweep_accelerators` broadcasts one parameter grid across *every*
registered dataflow in a single vectorized evaluation per accelerator and
stacks the results along a leading accelerator axis
(:class:`AcceleratorSweepResult`) — the comparative study the paper's
Sec. IV narrates, for any number of dataflows.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from . import registry
from .engn import EnGNModel
from .notation import EnGNHardwareParams, GraphTileParams, paper_default_graph
from .terms import CACHE_CLASSES, L1_CLASSES, L2_CLASSES

__all__ = [
    "SweepResult",
    "AcceleratorSweepResult",
    "sweep_accelerators",
    "fig3_engn_movement",
    "fig4_hygcn_movement",
    "fig5_iterations_vs_bandwidth",
    "fig6_fitting_factor",
    "fig7_systolic_reuse",
    "DEFAULT_K_SWEEP",
    "DEFAULT_M_SWEEP",
    "DEFAULT_B_SWEEP",
]

DEFAULT_K_SWEEP = np.array([64, 128, 256, 512, 1024, 2048, 4096, 8192], dtype=np.float64)
DEFAULT_M_SWEEP = np.array([4, 8, 16, 32, 64, 128, 256], dtype=np.float64)
DEFAULT_B_SWEEP = np.logspace(1, 5, 33, dtype=np.float64)  # 10 .. 100k bits/iter


def _flatten_columns(axes: Mapping[str, np.ndarray],
                     columns: Mapping[str, np.ndarray]) -> tuple[list[str], np.ndarray]:
    """One np.stack flatten: (column names, (n_cells, n_cols) float matrix).

    Axis columns come first (meshgrid order), then the value columns
    broadcast to the grid shape and raveled.  This replaces the former
    per-record Python loop: a whole sweep flattens in one vectorized shot.
    """
    names = list(axes)
    grids = np.meshgrid(*[axes[n] for n in names], indexing="ij")
    shape = grids[0].shape if grids else ()
    cols = names + list(columns)
    mat = np.stack(
        [g.ravel() for g in grids]
        + [np.broadcast_to(np.asarray(v, np.float64), shape).ravel()
           for v in columns.values()],
        axis=1,
    )
    return cols, mat


@dataclass(frozen=True)
class SweepResult:
    """A labelled sweep: ``axes`` name the grid dims of every value array."""

    figure: str
    axes: Mapping[str, np.ndarray]
    data_bits: Mapping[str, np.ndarray]        # per movement level
    iterations: Mapping[str, np.ndarray]       # per movement level
    meta: Mapping[str, object] = field(default_factory=dict)

    @property
    def total_bits(self) -> np.ndarray:
        return sum(self.data_bits.values())

    @property
    def total_iterations(self) -> np.ndarray:
        return sum(self.iterations.values())

    def rows(self) -> list[dict[str, float]]:
        """Flatten to records — the benchmark harness prints these as CSV."""
        columns = {"total_bits": self.total_bits,
                   "total_iterations": self.total_iterations}
        columns.update({f"bits_{term}": arr for term, arr in self.data_bits.items()})
        cols, mat = _flatten_columns(self.axes, columns)
        return [dict(zip(cols, row)) for row in mat.tolist()]


@dataclass(frozen=True)
class AcceleratorSweepResult:
    """A sweep stacked across accelerators: arrays have shape (A, *grid).

    ``total_bits`` / ``total_iterations`` / the per-hierarchy-class maps all
    carry a leading axis indexed by ``accelerators``; a row dump tags each
    record with its accelerator name.
    """

    figure: str
    accelerators: tuple[str, ...]
    axes: Mapping[str, np.ndarray]
    total_bits: np.ndarray
    total_iterations: np.ndarray
    class_bits: Mapping[str, np.ndarray]   # offchip / cache / onchip -> (A, *grid)
    meta: Mapping[str, object] = field(default_factory=dict)

    def accelerator_index(self, name: str) -> int:
        return self.accelerators.index(name)

    def rows(self) -> list[dict[str, object]]:
        out: list[dict[str, object]] = []
        for a, name in enumerate(self.accelerators):
            columns = {"total_bits": self.total_bits[a],
                       "total_iterations": self.total_iterations[a]}
            columns.update({f"bits_{cls}": arr[a]
                            for cls, arr in self.class_bits.items()})
            cols, mat = _flatten_columns(self.axes, columns)
            out.extend({"accelerator": name, **dict(zip(cols, row))}
                       for row in mat.tolist())
        return out


def _grid(*axes: np.ndarray) -> tuple[np.ndarray, ...]:
    return tuple(np.meshgrid(*axes, indexing="ij"))


def sweep_accelerators(
    accelerators: Sequence[str] | None = None,
    K: np.ndarray = DEFAULT_K_SWEEP,
    *,
    graph: GraphTileParams | None = None,
    axes: Mapping[str, np.ndarray] | None = None,
    figure: str = "sweep_accelerators",
) -> AcceleratorSweepResult:
    """Evaluate every (registered) accelerator over one grid, stacked.

    Each dataflow is evaluated **once** on the whole array-valued grid at
    its default hardware parameters; the per-accelerator totals are then
    ``np.stack``-ed along a leading accelerator axis.  Pass ``graph`` to
    sweep a custom array-valued tile instead of the Sec. IV defaults; when
    exactly one graph field is array-valued the sweep axis is inferred,
    otherwise label the grid explicitly via ``axes`` (meshgrid ``ij``
    order, like :class:`SweepResult`).
    """
    names = tuple(accelerators) if accelerators is not None else registry.names()
    K = np.atleast_1d(np.asarray(K, np.float64))
    g = graph if graph is not None else paper_default_graph(K)
    shape = np.broadcast_shapes(*(np.shape(v) for v in g.astuple_f64()))
    if graph is None:
        axes = {"K": K}
    elif axes is None:
        arr_fields = {f: np.asarray(getattr(g, f), np.float64)
                      for f in ("N", "T", "K", "L", "P")
                      if np.ndim(getattr(g, f)) == 1}
        if len(arr_fields) != 1:
            raise ValueError(
                "cannot infer the sweep axes of a custom graph with "
                f"{len(arr_fields)} 1-D array-valued fields; pass axes= "
                "naming the grid explicitly")
        axes = arr_fields
    grid_shape = tuple(len(np.atleast_1d(v)) for v in axes.values())
    if grid_shape != shape:
        raise ValueError(f"axes grid shape {grid_shape} does not match the "
                         f"graph broadcast shape {shape}")
    outputs = [registry.evaluate(name, g) for name in names]

    def stack(fn):
        return np.stack([np.broadcast_to(fn(o), shape) for o in outputs])

    return AcceleratorSweepResult(
        figure=figure,
        accelerators=names,
        axes={k: np.atleast_1d(np.asarray(v, np.float64))
              for k, v in axes.items()},
        total_bits=stack(lambda o: o.total_bits()),
        total_iterations=stack(lambda o: o.total_iterations()),
        class_bits={
            "offchip": stack(lambda o: o.total_bits(L2_CLASSES)),
            "cache": stack(lambda o: o.total_bits(CACHE_CLASSES)),
            "onchip": stack(lambda o: o.total_bits(L1_CLASSES)),
        },
        meta={"outputs": tuple(outputs)},
    )


def fig3_engn_movement(
    K: np.ndarray = DEFAULT_K_SWEEP,
    M: np.ndarray = DEFAULT_M_SWEEP,
) -> SweepResult:
    """Fig. 3: EnGN per-level data movement across tile size and PE array.

    The paper plots M = M' ("for the sake of clarity"); we sweep both equal.
    """
    Kg, Mg = _grid(np.asarray(K, np.float64), np.asarray(M, np.float64))
    graph = paper_default_graph(Kg)
    hw = EnGNHardwareParams(M=Mg, M_prime=Mg)
    out = registry.evaluate("engn", graph, hw)
    return SweepResult(
        figure="fig3",
        axes={"K": np.asarray(K, np.float64), "M": np.asarray(M, np.float64)},
        data_bits=out.breakdown(),
        iterations=out.iteration_breakdown(),
        meta={"model": "engn"},
    )


def fig4_hygcn_movement(
    K: np.ndarray = DEFAULT_K_SWEEP,
    Ma: np.ndarray = DEFAULT_M_SWEEP,
) -> SweepResult:
    """Fig. 4: HyGCN per-level data movement across tile size and SIMD cores."""
    Kg, Mag = _grid(np.asarray(K, np.float64), np.asarray(Ma, np.float64))
    graph = paper_default_graph(Kg)
    spec = registry.get("hygcn")
    out = spec.evaluate(graph, spec.hw_factory().replace(Ma=Mag))
    return SweepResult(
        figure="fig4",
        axes={"K": np.asarray(K, np.float64), "Ma": np.asarray(Ma, np.float64)},
        data_bits=out.breakdown(),
        iterations=out.iteration_breakdown(),
        meta={"model": "hygcn"},
    )


def fig5_iterations_vs_bandwidth(
    accelerator: str,
    B: np.ndarray = DEFAULT_B_SWEEP,
    K: np.ndarray = np.array([256, 1024, 4096], dtype=np.float64),
) -> SweepResult:
    """Fig. 5(a)/(b): total iterations vs memory bandwidth per workload size.

    Any registered accelerator works — every hardware record has a ``B``
    (L2 bandwidth) field to sweep.
    """
    Bg, Kg = _grid(np.asarray(B, np.float64), np.asarray(K, np.float64))
    graph = paper_default_graph(Kg)
    spec = registry.get(accelerator)
    out = spec.evaluate(graph, spec.hw_factory().replace(B=Bg))
    figure = {"engn": "fig5a", "hygcn": "fig5b"}.get(accelerator,
                                                     f"fig5_{accelerator}")
    return SweepResult(
        figure=figure,
        axes={"B": np.asarray(B, np.float64), "K": np.asarray(K, np.float64)},
        data_bits=out.breakdown(),
        iterations=out.iteration_breakdown(),
        meta={"model": accelerator},
    )


def fig6_fitting_factor(
    K: float = 1024.0,
    M: np.ndarray = np.array([4, 8, 16, 32, 64, 128, 256, 512], dtype=np.float64),
) -> SweepResult:
    """Fig. 6: EnGN iterations vs the array-fitting factor K*N / M^2."""
    M = np.asarray(M, np.float64)
    graph = paper_default_graph(K)
    hw = EnGNHardwareParams(M=M, M_prime=M)
    model = EnGNModel()
    out = model.evaluate(graph, hw)
    ff = model.fitting_factor(graph, hw)
    return SweepResult(
        figure="fig6",
        axes={"M": M},
        data_bits=out.breakdown(),
        iterations=out.iteration_breakdown(),
        meta={"model": "engn", "fitting_factor": ff, "K": K},
    )


def fig7_systolic_reuse(
    gamma: np.ndarray = np.linspace(0.0, 0.99, 34),
    N: np.ndarray = np.array([30, 128, 512], dtype=np.float64),
) -> SweepResult:
    """Fig. 7: HyGCN loadweights movement vs systolic reuse Gamma and depth N."""
    Gg, Ng = _grid(np.asarray(gamma, np.float64), np.asarray(N, np.float64))
    graph = paper_default_graph(1024.0).replace(N=Ng)
    spec = registry.get("hygcn")
    out = spec.evaluate(graph, spec.hw_factory().replace(gamma=Gg))
    return SweepResult(
        figure="fig7",
        axes={"gamma": np.asarray(gamma, np.float64), "N": np.asarray(N, np.float64)},
        data_bits=out.breakdown(),
        iterations=out.iteration_breakdown(),
        meta={"model": "hygcn"},
    )
