"""Design-space auto-tuner over the closed-form movement models (§15).

The repo can evaluate any (dataflow x graph x hardware x composition)
point in one broadcast closed-form call; this module closes the loop and
*searches*: given a workload scenario and an SRAM budget, find the
movement-minimizing ``(dataflow, tile capacity, partition count,
inter-layer residency, halo policy)`` configuration, and the
movement-vs-SRAM Pareto frontier when the budget is left open.

The search rides the existing machinery rather than re-deriving it:

* Every candidate is a plain concrete :class:`~repro.api.scenario
  .Scenario`, so one call to ``evaluate_scenarios`` per probe batch
  evaluates all candidates sharing a plan key in ONE stacked closed-form
  call — for a capacity sweep that is one evaluation group per
  (dataflow, residency, halo) cell, capacities batched along the
  planner's capacity axis (DESIGN.md §13).
* Trace candidates share the dataset's one sorted-edge factorization
  through the resolved-trace LRU / on-disk ``schedule_cache``: a
  multi-capacity tune performs **exactly one** factorization
  (regression-gated via :func:`repro.core.trace.trace_cache_info`).
* Small spaces (``<= max_exhaustive`` candidates, default 4096) are
  swept exhaustively — the tuner then *is* the brute-force oracle, and
  the test battery pins it bit-identical to an independent
  ``np.argmin`` over the full cross-product.  Larger spaces run
  coordinate descent with a deterministic restart schedule; every probe
  is memoized, and the answer is the best feasible point *seen*, so the
  method can only improve with more restarts.

Feasibility is a closed-form SRAM working-set model
(:func:`repro.core.compose.tile_working_set_bits`): weights + per-tile
activations (+ a halo-dedup cache when ``halo_dedup > 1``).  A budget
below every candidate's working set raises the typed
:class:`InfeasibleBudgetError` (a ``ValueError``, so the CLI exits 2
with a one-line message, matching the PR-4 validation convention).

This module is import-light (stdlib + numpy) so the scenario layer can
normalize ``{"optimize": ...}`` blocks without dragging in the engine;
everything heavy (registry, compose, planner) is imported lazily inside
:func:`tune_scenario`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Mapping, Optional, Sequence

import numpy as np

__all__ = [
    "OBJECTIVE_METRICS",
    "SPACE_AXES",
    "TUNE_METHODS",
    "DEFAULT_MAX_EXHAUSTIVE",
    "DEFAULT_RESTARTS",
    "InfeasibleBudgetError",
    "TunePoint",
    "TuneResult",
    "normalize_optimize",
    "tune_scenario",
]

#: Scalar objectives a tune may minimize (or weight in a mapping).
OBJECTIVE_METRICS = ("movement", "offchip", "iterations")
#: Searchable axes of the ``optimize.space`` block.
SPACE_AXES = ("dataflow", "tile_vertices", "n_tiles", "residency",
              "halo_dedup")
TUNE_METHODS = ("auto", "exhaustive", "coordinate")
#: ``method="auto"`` sweeps exhaustively up to this many candidates.
DEFAULT_MAX_EXHAUSTIVE = 4096
#: Default coordinate-descent restart count.
DEFAULT_RESTARTS = 3

_RESIDENCIES = ("spill", "resident")
_BUDGET_KEYS = ("sram_bits", "sram_bytes")
#: ``TuneResult.to_dict`` embeds the full evaluated point list only up
#: to this size (the frontier and the winner are always embedded).
_POINTS_EMBED_LIMIT = 512


class InfeasibleBudgetError(ValueError):
    """No point in the search space fits the SRAM budget.

    A ``ValueError`` subclass so schema-level CLI handling (exit 2, one
    line) applies, but typed so callers can distinguish "your budget is
    too small" from "your scenario is malformed" and, e.g., relax the
    budget programmatically.
    """


# ---------------------------------------------------------------------------
# {"optimize": ...} schema normalization (pure data -> pure data).
# Lives here rather than in repro.api.scenario so the schema and the
# engine that interprets it cannot drift apart; Scenario.__post_init__
# calls normalize_optimize and stores the canonical form.
# ---------------------------------------------------------------------------

def _finite_number(value: Any, what: str) -> float:
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        raise TypeError(f"{what} must be a plain number, got {value!r} "
                        f"of type {type(value).__name__}")
    out = float(value)
    if not math.isfinite(out):
        raise ValueError(f"{what} must be finite, got {value!r}")
    return out


def _value_list(value: Any, what: str) -> list:
    if isinstance(value, (str, bytes)) or not isinstance(value, Sequence):
        raise TypeError(f"{what} must be a list of values, got {value!r}")
    out = list(value)
    if not out:
        raise ValueError(f"{what} must not be empty: an empty axis makes "
                         "the search space empty")
    return out


def _normalized_objective(obj: Any):
    if isinstance(obj, str):
        if obj not in OBJECTIVE_METRICS:
            raise ValueError(
                f"unknown objective {obj!r}; expected one of "
                f"{list(OBJECTIVE_METRICS)} or a {{metric: weight}} mapping")
        return obj
    if isinstance(obj, Mapping):
        if not obj:
            raise ValueError("empty objective mapping: give at least one "
                             f"of {list(OBJECTIVE_METRICS)} with a weight")
        unknown = set(map(str, obj)) - set(OBJECTIVE_METRICS)
        if unknown:
            raise ValueError(
                f"unknown objective metric(s) {sorted(unknown)}; "
                f"expected a subset of {list(OBJECTIVE_METRICS)}")
        weights = {}
        for key in OBJECTIVE_METRICS:
            if key in obj:
                v = obj[key]
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    raise TypeError(f"objective weight for {key!r} must be "
                                    f"a plain number, got {v!r}")
                w = float(v)
                if not math.isfinite(w):
                    raise ValueError(f"non-finite objective weight for "
                                     f"{key!r}: {v!r}")
                weights[key] = w
        return weights
    raise TypeError(f"optimize.objective must be a metric name or a "
                    f"{{metric: weight}} mapping, got {obj!r}")


def _normalized_budget(budget: Any) -> Optional[dict]:
    if budget is None:
        return None
    if not isinstance(budget, Mapping):
        raise TypeError(f"optimize.budget must be a mapping like "
                        f"{{'sram_bits': ...}}, got {budget!r}")
    unknown = set(map(str, budget)) - set(_BUDGET_KEYS)
    if unknown:
        raise ValueError(f"unknown budget key(s) {sorted(unknown)}; "
                         f"expected one of {list(_BUDGET_KEYS)}")
    if len(budget) != 1:
        raise ValueError("optimize.budget must give exactly one of "
                         f"{list(_BUDGET_KEYS)}")
    key, value = next(iter(budget.items()))
    bits = _finite_number(value, f"optimize.budget.{key}")
    if key == "sram_bytes":
        bits *= 8.0
    if bits < 0:
        raise ValueError(
            f"negative SRAM budget ({key}={value!r}): a budget is an "
            "on-chip capacity and must be >= 0")
    return {"sram_bits": bits}


def _normalized_space(space: Any) -> dict:
    if not isinstance(space, Mapping):
        raise TypeError(f"optimize.space must be a mapping of axes, "
                        f"got {space!r}")
    unknown = set(map(str, space)) - set(SPACE_AXES)
    if unknown:
        raise ValueError(f"unknown optimize space axis(es) {sorted(unknown)}; "
                         f"searchable axes: {list(SPACE_AXES)}")
    if "tile_vertices" in space and "n_tiles" in space:
        raise ValueError(
            "give one of space.tile_vertices / space.n_tiles, not both "
            "(n_tiles converts to a capacity via ceil(V / n_tiles))")
    out: dict[str, Any] = {}
    if "dataflow" in space:
        v = space["dataflow"]
        if v == "all":
            out["dataflow"] = "all"
        elif isinstance(v, str):
            raise ValueError(f"space.dataflow must be 'all' or a list of "
                             f"registered names, got {v!r}")
        else:
            names = _value_list(v, "space.dataflow")
            seen: list[str] = []
            for name in names:
                if not isinstance(name, str) or not name:
                    raise ValueError(f"space.dataflow entries must be "
                                     f"non-empty names, got {name!r}")
                if name not in seen:
                    seen.append(name)
            out["dataflow"] = seen
    if "tile_vertices" in space:
        caps = _value_list(space["tile_vertices"], "space.tile_vertices")
        vals = []
        for c in caps:
            cv = _finite_number(c, "space.tile_vertices entry")
            if cv < 1:
                raise ValueError(f"space.tile_vertices entries must be "
                                 f">= 1, got {c!r}")
            vals.append(cv)
        out["tile_vertices"] = vals
    if "n_tiles" in space:
        tiles = _value_list(space["n_tiles"], "space.n_tiles")
        vals = []
        for t in tiles:
            tv = _finite_number(t, "space.n_tiles entry")
            if tv < 1 or tv != int(tv):
                raise ValueError(f"space.n_tiles entries must be whole "
                                 f"numbers >= 1, got {t!r}")
            vals.append(int(tv))
        out["n_tiles"] = vals
    if "residency" in space:
        res = _value_list(space["residency"], "space.residency")
        seen = []
        for r in res:
            if r not in _RESIDENCIES:
                raise ValueError(f"unknown residency {r!r} in "
                                 f"space.residency; expected a subset of "
                                 f"{list(_RESIDENCIES)}")
            if r not in seen:
                seen.append(r)
        out["residency"] = seen
    if "halo_dedup" in space:
        halos = _value_list(space["halo_dedup"], "space.halo_dedup")
        vals = []
        for h in halos:
            hv = _finite_number(h, "space.halo_dedup entry")
            if hv < 1.0:
                raise ValueError(f"space.halo_dedup entries must be >= 1 "
                                 f"(they divide halo traffic), got {h!r}")
            vals.append(hv)
        out["halo_dedup"] = vals
    return out


def normalize_optimize(data: Any) -> dict:
    """Validate an ``{"optimize": ...}`` block into its canonical form.

    Pure data in, pure data out (JSON-able, idempotent): the scenario
    layer stores the result, hashes/plan-keys its sorted-JSON dump, and
    round-trips it through ``to_dict``/``from_dict`` unchanged.  Raises
    ``ValueError``/``TypeError`` with a one-line message on any schema
    violation (unknown axis, negative budget, non-finite objective
    weight, ...), which the CLI maps to exit code 2.
    """
    if not isinstance(data, Mapping):
        raise TypeError(f"optimize must be a mapping, got "
                        f"{type(data).__name__}")
    known = {"objective", "budget", "space", "method", "max_exhaustive",
             "restarts"}
    unknown = set(map(str, data)) - known
    if unknown:
        raise ValueError(f"unknown optimize key(s) {sorted(unknown)}; "
                         f"expected a subset of {sorted(known)}")
    method = data.get("method", "auto")
    if method not in TUNE_METHODS:
        raise ValueError(f"unknown optimize method {method!r}; expected "
                         f"one of {list(TUNE_METHODS)}")
    max_exh = _finite_number(data.get("max_exhaustive",
                                      DEFAULT_MAX_EXHAUSTIVE),
                             "optimize.max_exhaustive")
    if max_exh < 1 or max_exh != int(max_exh):
        raise ValueError(f"optimize.max_exhaustive must be a whole number "
                         f">= 1, got {data.get('max_exhaustive')!r}")
    restarts = _finite_number(data.get("restarts", DEFAULT_RESTARTS),
                              "optimize.restarts")
    if restarts < 1 or restarts != int(restarts):
        raise ValueError(f"optimize.restarts must be a whole number >= 1, "
                         f"got {data.get('restarts')!r}")
    return {
        "objective": _normalized_objective(data.get("objective", "movement")),
        "budget": _normalized_budget(data.get("budget")),
        "space": _normalized_space(data.get("space", {})),
        "method": method,
        "max_exhaustive": int(max_exh),
        "restarts": int(restarts),
    }


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TunePoint:
    """One evaluated configuration of the search space.

    ``index`` is the configuration's position in the canonical
    cross-product enumeration (dataflow-major, capacity innermost) —
    the tie-break order shared with the exhaustive oracle.
    """

    index: int
    dataflow: str
    tile_vertices: float
    residency: Any  # one policy name, or a per-relation tuple (§17)
    halo_dedup: float
    objective: float
    sram_bits: float
    total_bits: float
    total_iterations: float
    n_tiles: Optional[float]
    feasible: bool

    def to_dict(self) -> dict:
        out = {
            "index": self.index,
            "dataflow": self.dataflow,
            "tile_vertices": self.tile_vertices,
            "residency": (list(self.residency)
                          if isinstance(self.residency, tuple)
                          else self.residency),
            "halo_dedup": self.halo_dedup,
            "objective": self.objective,
            "sram_bits": self.sram_bits,
            "total_bits": self.total_bits,
            "total_iterations": self.total_iterations,
            "feasible": self.feasible,
        }
        if self.n_tiles is not None:
            out["n_tiles"] = self.n_tiles
        return out


@dataclass(frozen=True)
class TuneResult:
    """A finished tune: the winner, the frontier, and the search record.

    ``best_result`` is the winner's full planner
    :class:`~repro.api.planner.ScenarioResult` (breakdown and all), so
    the planner can surface a tuned scenario exactly like a concrete
    one.  ``points`` holds every *distinct* configuration evaluated, in
    canonical index order (for an exhaustive run that is the whole
    space); ``frontier`` is the movement-vs-SRAM Pareto frontier over
    the feasible evaluated points (sram ascending, objective strictly
    descending — non-domination is property-tested).
    """

    scenario: Any
    method: str
    objective: Any
    budget_bits: Optional[float]
    axes: Mapping[str, tuple]
    best: TunePoint
    best_result: Any
    points: tuple[TunePoint, ...]
    frontier: tuple[TunePoint, ...]
    n_candidates: int
    n_evaluated: int
    n_feasible: int
    n_groups: int

    def to_dict(self) -> dict:
        out = {
            "method": self.method,
            "objective": self.objective,
            "budget": (None if self.budget_bits is None
                       else {"sram_bits": self.budget_bits}),
            "axes": {k: list(v) for k, v in self.axes.items()},
            "n_candidates": self.n_candidates,
            "n_evaluated": self.n_evaluated,
            "n_feasible": self.n_feasible,
            "n_groups": self.n_groups,
            "best": self.best.to_dict(),
            "frontier": [p.to_dict() for p in self.frontier],
        }
        if self.best_result is not None and self.best_result.n_tiles is not None:
            out["best"]["n_tiles"] = float(self.best_result.n_tiles)
        if self.n_evaluated <= _POINTS_EMBED_LIMIT:
            out["points"] = [p.to_dict() for p in self.points]
        return out


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

def _objective_value(objective, result) -> float:
    vals = {"movement": result.total_bits,
            "offchip": result.offchip_bits,
            "iterations": result.total_iterations}
    if isinstance(objective, str):
        out = float(vals[objective])
    else:
        out = float(sum(w * vals[k] for k, w in objective.items()))
    if not math.isfinite(out):
        raise ValueError(f"objective evaluated to a non-finite value "
                         f"({out!r}) — the closed forms should never do "
                         "this; check the objective weights")
    return out


def _pareto_frontier(points: Sequence[TunePoint]) -> tuple[TunePoint, ...]:
    """Non-dominated (sram_bits, objective) subset of the feasible points.

    Sort by (sram, objective, index) and keep the strict prefix-minimum
    of the objective: every kept pair then has strictly larger sram AND
    strictly smaller objective than its predecessor, so no kept point
    dominates another, and every dropped point is dominated by a kept
    one at equal-or-smaller sram.
    """
    pts = sorted((p for p in points if p.feasible),
                 key=lambda p: (p.sram_bits, p.objective, p.index))
    out: list[TunePoint] = []
    best = math.inf
    for p in pts:
        if p.objective < best:
            out.append(p)
            best = p.objective
    return tuple(out)


def tune_scenario(scenario) -> TuneResult:
    """Run the §15 search for one ``{"optimize": ...}`` scenario.

    Resolves the space axes against the base scenario (missing axes pin
    to the scenario's own value; ``dataflow: "all"`` expands to the
    registry), enumerates the cross-product in canonical order, and
    either sweeps it exhaustively (one ``evaluate_scenarios`` call — the
    planner batches capacities per (dataflow, residency, halo) group) or
    runs memoized coordinate descent from a deterministic restart
    schedule.  Returns the arg-min feasible configuration, bit-identical
    on exhaustive runs to ``np.argmin`` over the same enumeration.
    """
    opt = getattr(scenario, "optimize", None)
    if opt is None:
        raise ValueError("tune_scenario needs a scenario with an "
                         "{'optimize': ...} block; plain scenarios go "
                         "through evaluate_scenarios directly")
    if getattr(scenario, "graph_kind", None) == "minibatch":
        # The scenario layer rejects this combination at construction;
        # keep the engine-side check so a hand-built object fails the
        # same way instead of deep in the search.
        raise ValueError(
            "minibatch scenarios have no searchable tiling: the sampling "
            "episode (batch_nodes/fanout) fixes the schedule, so there is "
            "no tile_vertices axis to optimize")
    # Lazy imports: this module stays import-light for the scenario layer,
    # and importing the planner at module level would be circular.
    from repro.api.planner import evaluate_scenarios
    from repro.api.scenario import Composition

    from . import registry
    from .compose import tile_working_set_bits

    comp = scenario.composition
    kind = scenario.graph_kind
    space = opt["space"]

    n_relations = 1
    if kind in ("trace", "hetero"):
        from .trace import resolve_trace_dataset
        params = dict(scenario.graph["params"])
        if kind == "hetero":
            n_relations = int(scenario.graph["n_relations"])
            params["n_relations"] = n_relations
        trace = resolve_trace_dataset(scenario.graph["dataset"], params)
        V = float(trace.n_nodes)
    else:
        V = float(scenario.graph["V"])

    # -- resolve axes ------------------------------------------------------
    dataflows = space.get("dataflow")
    if dataflows == "all":
        dataflows = registry.names()
    elif dataflows is None:
        dataflows = (scenario.dataflow,)
    dataflows = tuple(dataflows)
    for name in dataflows:
        registry.get(name)  # unknown dataflow fails now, not mid-search
    res_axis = space.get("residency")
    if res_axis is not None and kind == "hetero" and n_relations > 1:
        # Per-relation residency search (§17): the policy axis expands to
        # the cross-product of per-relation assignments.  Homogeneous
        # tuples are kept as tuples; the planner's plan key treats the
        # tuple arity structurally, so each assignment still lands in one
        # broadcast group per (dataflow, residency).
        expanded = len(res_axis) ** n_relations
        if expanded > DEFAULT_MAX_EXHAUSTIVE:
            raise ValueError(
                f"per-relation residency search is "
                f"{len(res_axis)}^{n_relations} = {expanded} assignments, "
                f"above the {DEFAULT_MAX_EXHAUSTIVE}-point expansion cap; "
                "pin composition.residency or reduce n_relations")
        import itertools
        residencies = tuple(itertools.product(res_axis,
                                              repeat=n_relations))
    else:
        residencies = tuple(res_axis or (comp.residency,))
    halos = tuple(space.get("halo_dedup") or (comp.halo_dedup,))
    if "tile_vertices" in space:
        caps = tuple(space["tile_vertices"])
    elif "n_tiles" in space:
        caps = tuple(float(math.ceil(V / nt)) for nt in space["n_tiles"])
    else:
        caps = (float(comp.tile_vertices),)
    if kind in ("trace", "hetero"):
        for c in caps:
            if c != int(c):
                raise ValueError(f"{kind} tile capacities must be whole "
                                 f"numbers >= 1, got {c!r}")
    axes = {"dataflow": dataflows, "residency": residencies,
            "halo_dedup": halos, "tile_vertices": caps}

    objective = opt["objective"]
    budget = opt["budget"]
    budget_bits = None if budget is None else float(budget["sram_bits"])
    widths = (comp.widths if comp.widths is not None
              else (scenario.graph["N"], scenario.graph["T"]))
    sigma = {}
    for name in dataflows:
        hw = registry.get(name).hw_factory()
        sigma[name] = float(scenario.hardware.get("sigma", hw.sigma))

    def working_set(cap, sig, res, hd) -> float:
        """Feasibility SRAM model for one candidate.

        Homogeneous scenarios call :func:`tile_working_set_bits`
        directly.  Hetero scenarios sum it over relations (§17): every
        relation's weights are resident for the pass and each holds its
        own per-relation activation slice, under its own residency
        policy when ``res`` is a per-relation tuple.
        """
        if kind != "hetero":
            return float(tile_working_set_bits(
                cap, V=V, widths=widths, sigma=sig, residency=res,
                halo_dedup=hd))
        total = 0.0
        for r in range(n_relations):
            w_r = tuple(w[r] if isinstance(w, (tuple, list)) else w
                        for w in widths)
            res_r = res[r] if isinstance(res, (tuple, list)) else res
            total += float(tile_working_set_bits(
                cap, V=V, widths=w_r, sigma=sig, residency=res_r,
                halo_dedup=hd))
        return total

    # -- canonical enumeration (the oracle's order) ------------------------
    # A candidate is (dataflow, tile_vertices, residency, halo_dedup);
    # capacity is innermost so one (dataflow, residency, halo) run is one
    # contiguous capacity-batched planner group.
    def cand_index(c) -> int:
        return ((dataflows.index(c[0]) * len(residencies)
                 + residencies.index(c[2])) * len(halos)
                + halos.index(c[3])) * len(caps) + caps.index(c[1])

    all_candidates = [(df, cap, res, hd)
                      for df in dataflows
                      for res in residencies
                      for hd in halos
                      for cap in caps]
    n_candidates = len(all_candidates)
    method = opt["method"]
    if method == "auto":
        method = ("exhaustive" if n_candidates <= opt["max_exhaustive"]
                  else "coordinate")

    def candidate_scenario(c):
        df, cap, res, hd = c
        return scenario.replace(
            dataflow=df,
            composition=Composition(widths=comp.widths, residency=res,
                                    tile_vertices=cap, halo_dedup=hd),
            optimize=None, expect=None, conformance=False,
            label=(f"{scenario.label or 'tune'}"
                   f"/{df}/tv{cap:g}/"
                   f"{res if isinstance(res, str) else '+'.join(res)}/"
                   f"hd{hd:g}"))

    evaluated: dict[tuple, TunePoint] = {}
    results: dict[tuple, Any] = {}
    n_groups = 0

    def eval_candidates(cands) -> None:
        nonlocal n_groups
        todo = [c for c in dict.fromkeys(cands) if c not in evaluated]
        if not todo:
            return
        batch = evaluate_scenarios([candidate_scenario(c) for c in todo])
        n_groups += batch.n_evaluations
        for c, r in zip(todo, batch.results):
            sram = working_set(c[1], sigma[c[0]], c[2], c[3])
            evaluated[c] = TunePoint(
                index=cand_index(c), dataflow=c[0],
                tile_vertices=float(c[1]), residency=c[2],
                halo_dedup=float(c[3]),
                objective=_objective_value(objective, r),
                sram_bits=sram,
                total_bits=r.total_bits,
                total_iterations=r.total_iterations,
                n_tiles=r.n_tiles,
                feasible=(budget_bits is None or sram <= budget_bits))
            results[c] = r

    # -- search ------------------------------------------------------------
    if method == "exhaustive":
        # ONE planner call for the whole space: the oracle path.
        eval_candidates(all_candidates)
        obj = np.array([evaluated[c].objective for c in all_candidates])
        feas = np.array([evaluated[c].feasible for c in all_candidates])
        if not feas.any():
            _raise_infeasible(budget_bits, evaluated)
        best_c = all_candidates[int(np.argmin(np.where(feas, obj, np.inf)))]
    else:
        axis_vals: list[tuple] = [dataflows, residencies, halos, caps]
        restarts = opt["restarts"]
        for r in range(restarts):
            # Deterministic restart schedule: restart r starts at the
            # evenly spaced position along each axis (first corner, ...,
            # last corner), so restarts cover the space without RNG.
            idx = [((len(vals) - 1) * r) // max(restarts - 1, 1)
                   for vals in axis_vals]
            cur = (axis_vals[0][idx[0]], axis_vals[3][idx[3]],
                   axis_vals[1][idx[1]], axis_vals[2][idx[2]])
            eval_candidates([cur])
            p = evaluated[cur]
            cur_obj = p.objective if p.feasible else math.inf
            for _ in range(16):  # bounded descent cycles
                moved = False
                for a, vals in enumerate(axis_vals):
                    if len(vals) == 1:
                        continue
                    sweeps = []
                    for v in vals:
                        c = list((cur[0], cur[2], cur[3], cur[1]))
                        c[a] = v
                        sweeps.append((c[0], c[3], c[1], c[2]))
                    eval_candidates(sweeps)
                    move_to = None
                    move_obj = cur_obj
                    for c in sweeps:
                        pt = evaluated[c]
                        if pt.feasible and pt.objective < move_obj:
                            move_to, move_obj = c, pt.objective
                    if move_to is not None:
                        cur, cur_obj, moved = move_to, move_obj, True
                if not moved:
                    break
        feasible_pts = [p for p in evaluated.values() if p.feasible]
        if not feasible_pts:
            _raise_infeasible(budget_bits, evaluated)
        best_p = min(feasible_pts, key=lambda p: (p.objective, p.index))
        best_c = (best_p.dataflow, best_p.tile_vertices, best_p.residency,
                  best_p.halo_dedup)

    points = tuple(sorted(evaluated.values(), key=lambda p: p.index))
    return TuneResult(
        scenario=scenario,
        method=method,
        objective=objective,
        budget_bits=budget_bits,
        axes=axes,
        best=evaluated[best_c],
        best_result=results[best_c],
        points=points,
        frontier=_pareto_frontier(points),
        n_candidates=n_candidates,
        n_evaluated=len(evaluated),
        n_feasible=sum(1 for p in points if p.feasible),
        n_groups=n_groups,
    )


def _raise_infeasible(budget_bits, evaluated) -> None:
    min_sram = min(p.sram_bits for p in evaluated.values())
    raise InfeasibleBudgetError(
        f"SRAM budget {budget_bits:.6g} bits is below every explored "
        f"configuration's working set (minimum {min_sram:.6g} bits over "
        f"{len(evaluated)} candidates); relax the budget or widen the "
        "search space")
