"""Trace-driven graph backend: exact tile schedules from real edge lists.

The paper's composition layer (DESIGN.md §7) covers a full graph with
*uniform* tiles — `K = ceil(V / n_tiles)` vertices, `P = ceil(E / n_tiles)`
edges per tile — and charges halo reloads at the random-partition expected
cut `E * (1 - 1/n_tiles)`.  Its own narrative (echoed by the GNN computing
surveys in PAPERS.md) is that real-world degree imbalance is what actually
drives communication, yet the closed forms never touch an actual graph.

This module closes that gap (DESIGN.md §12).  A :class:`GraphTrace` wraps
one concrete edge list (CSR-ified by destination vertex) and derives, for
a balanced contiguous vertex partition, the **exact** quantities the
uniform schedule approximates:

* per-tile vertex counts ``K_t`` and destination-edge counts ``P_t``
  (straight from the CSR row pointer — no per-edge Python loop anywhere);
* per-tile **unique remote source** counts — the true halo traffic, with
  within-tile duplicate sources deduplicated exactly (so the uniform
  model's ``halo_dedup`` knob is replaced by measurement);
* degree-aware cache hit fractions: the share of a tile's aggregation
  reads served if the L most-referenced sources of the tile pass are
  pinned in a dedicated cache (EnGN's L2* narrative, measured).

:class:`~repro.core.compose.TiledGraphModel` accepts a trace as an
alternative schedule source; the scenario front door exposes it as the
third graph kind ``{"kind": "trace", "dataset": ..., "params": ...}``
with dataset references resolving to the deterministic generators in
:mod:`repro.data.synthetic` (see ``TRACE_DATASETS`` below), so trace
scenarios stay pure, serializable data.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional

import numpy as np

__all__ = [
    "GraphTrace",
    "TraceSchedule",
    "register_trace_dataset",
    "resolve_trace_dataset",
    "trace_dataset_names",
    "clear_trace_cache",
    "CORA_V",
    "CORA_E",
]

#: Cora citation-graph size (kept in sync with ``configs.base.GNN_SHAPES
#: ["full_graph_sm"]`` and the gcn-cora config; asserted in tests).
CORA_V = 2708
CORA_E = 10556


def _f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


@dataclass(frozen=True)
class TraceSchedule:
    """Exact per-tile schedule of one (trace, tile capacity) pair.

    Tile ``t`` owns the contiguous vertex range ``[t*K, min((t+1)*K, V))``
    with ``n_tiles = ceil(V / capacity)`` and ``K = ceil(V / n_tiles)`` —
    the same balanced split the uniform schedule assumes, so the two
    backends differ only by what the edge list actually does.

    Attributes:
      n_tiles: number of tiles.
      capacity: requested tile vertex capacity.
      K: owned-vertex stride (``ceil(V / n_tiles)``).
      vertex_counts: ``(n_tiles,)`` exact vertices per tile.
      edge_counts: ``(n_tiles,)`` exact edges per destination tile.
      halo_counts: ``(n_tiles,)`` exact **unique** remote sources per tile
        (the halo features a tile pass must fetch from other tiles).
      remote_edge_counts: ``(n_tiles,)`` cut edges per destination tile
        (before dedup; ``halo_counts <= remote_edge_counts``).
    """

    n_tiles: int
    capacity: int
    K: int
    vertex_counts: np.ndarray
    edge_counts: np.ndarray
    halo_counts: np.ndarray
    remote_edge_counts: np.ndarray
    # Per-(tile, source) reference multiplicities, sorted by (tile,
    # -count): the basis of the degree-aware cache-hit computation.
    _pair_tile: np.ndarray = field(repr=False)
    _pair_count: np.ndarray = field(repr=False)
    _pair_rank: np.ndarray = field(repr=False)

    @property
    def n_edges(self) -> int:
        return int(self.edge_counts.sum())

    @property
    def cut_edges(self) -> int:
        """Total edges whose source tile differs from their destination tile."""
        return int(self.remote_edge_counts.sum())

    @property
    def halo_total(self) -> int:
        """Total unique-remote-source fetches across all tiles (exact halo)."""
        return int(self.halo_counts.sum())

    def uniform_halo_estimate(self) -> float:
        """The paper's random-partition expected cut, ``E * (1 - 1/n_tiles)``."""
        return float(self.n_edges) * (1.0 - 1.0 / self.n_tiles)

    def cache_hit_fraction(self, high_degree_fraction: float = 0.1) -> np.ndarray:
        """Exact per-tile degree-aware cache hit fractions.

        If tile ``t`` pins its ``L_t = floor(K_t * high_degree_fraction)``
        most-referenced source vertices in a dedicated cache (EnGN's L2*
        high-degree cache), this is the fraction of the tile's aggregation
        reads those sources serve — computed from the actual reference
        multiplicities, vectorized over all tiles at once.
        """
        hdf = float(high_degree_fraction)
        if not np.isfinite(hdf) or not 0.0 <= hdf <= 1.0:
            raise ValueError(f"high_degree_fraction must be in [0, 1], "
                             f"got {high_degree_fraction!r}")
        L_t = np.floor(self.vertex_counts * hdf)
        hit = self._pair_rank < L_t[self._pair_tile]
        hits = np.bincount(self._pair_tile[hit],
                           weights=self._pair_count[hit],
                           minlength=self.n_tiles)
        return hits / np.maximum(self.edge_counts, 1.0)

    def stats(self, high_degree_fraction: float = 0.1) -> dict:
        """Summary record for benchmarks / result metadata (JSON-able)."""
        est = self.uniform_halo_estimate()
        exact = self.halo_total
        edge = _f64(self.edge_counts)
        hit = self.cache_hit_fraction(high_degree_fraction)
        return {
            "n_tiles": int(self.n_tiles),
            "capacity": int(self.capacity),
            "n_edges": int(self.n_edges),
            "cut_edges": int(self.cut_edges),
            "halo_exact": int(exact),
            "halo_uniform_estimate": est,
            "halo_estimate_over_exact": (est / exact) if exact else None,
            "edge_imbalance": float(edge.max() / max(edge.mean(), 1e-300)),
            "cache_hit_fraction_mean": float(hit.mean()),
            "cache_hit_fraction_min": float(hit.min()),
            "cache_hit_fraction_max": float(hit.max()),
        }


class GraphTrace:
    """One concrete directed edge list, CSR-ified by destination vertex.

    ``senders[i] -> receivers[i]`` is edge ``i``; aggregation reads source
    (sender) features into destination (receiver) vertices, matching the
    destination-stationary tiling of the paper's dataflows.  Construction
    sorts the edge list by destination once (the CSR row pointer), after
    which every schedule quantity is segment algebra — ``np.bincount`` /
    ``np.unique`` / ``np.lexsort`` over whole arrays, never a Python loop
    over edges.
    """

    def __init__(self, senders, receivers, n_nodes: int) -> None:
        snd = np.asarray(senders)
        rcv = np.asarray(receivers)
        if snd.ndim != 1 or rcv.ndim != 1 or snd.shape != rcv.shape:
            raise ValueError(
                f"senders/receivers must be 1-D arrays of equal length, got "
                f"shapes {snd.shape} and {rcv.shape}")
        if not (np.issubdtype(snd.dtype, np.integer)
                and np.issubdtype(rcv.dtype, np.integer)):
            raise ValueError("senders/receivers must be integer vertex ids")
        n_nodes = int(n_nodes)
        if n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
        snd = snd.astype(np.int64, copy=False)
        rcv = rcv.astype(np.int64, copy=False)
        if snd.size and (snd.min() < 0 or snd.max() >= n_nodes
                         or rcv.min() < 0 or rcv.max() >= n_nodes):
            raise ValueError(
                f"edge endpoints must lie in [0, {n_nodes}); got sender "
                f"range [{snd.min()}, {snd.max()}] and receiver range "
                f"[{rcv.min()}, {rcv.max()}]")
        self.n_nodes = n_nodes
        self.senders = snd
        self.receivers = rcv
        # CSR by destination: row_ptr[v] .. row_ptr[v+1] indexes the
        # (stable-sorted) edges aggregating INTO vertex v.
        order = np.argsort(rcv, kind="stable")
        self.csr_senders = snd[order]
        counts = np.bincount(rcv, minlength=n_nodes)
        self.row_ptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=self.row_ptr[1:])
        self._schedules: dict[int, TraceSchedule] = {}

    # -- construction ------------------------------------------------------
    @classmethod
    def from_arrays(cls, graph) -> "GraphTrace":
        """From anything with ``senders`` / ``receivers`` / ``n_nodes``
        attributes (e.g. :class:`repro.data.synthetic.GraphArrays`)."""
        return cls(graph.senders, graph.receivers, graph.n_nodes)

    # -- basic measures ----------------------------------------------------
    @property
    def n_edges(self) -> int:
        return int(self.senders.shape[0])

    def in_degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.senders, minlength=self.n_nodes)

    # -- the partitioner ---------------------------------------------------
    def schedule(self, tile_vertices) -> TraceSchedule:
        """Exact balanced-partition schedule for one tile capacity (cached).

        Vectorized end to end: tile membership is integer division by the
        stride, per-tile edge counts are CSR row-pointer differences at
        the tile boundaries, and halo / cache statistics are one
        ``np.unique`` + ``np.lexsort`` over ``(tile, source)`` keys.
        """
        cap = int(tile_vertices)
        if cap != float(tile_vertices) or cap < 1:
            raise ValueError(f"tile_vertices must be a whole number >= 1 "
                             f"for a trace schedule, got {tile_vertices!r}")
        if cap in self._schedules:
            return self._schedules[cap]
        V = self.n_nodes
        n_tiles = -(-V // cap)
        K = -(-V // n_tiles)
        boundaries = np.minimum(np.arange(n_tiles + 1, dtype=np.int64) * K, V)
        vertex_counts = np.diff(boundaries).astype(np.float64)
        # Per-tile destination edges: CSR row pointer at the boundaries.
        edge_counts = np.diff(self.row_ptr[boundaries]).astype(np.float64)
        dst_tile = self.receivers // K
        src_tile = self.senders // K
        remote = src_tile != dst_tile
        remote_edge_counts = np.bincount(
            dst_tile[remote], minlength=n_tiles).astype(np.float64)
        # Reference multiplicity of every (tile, source) pair — one dedup
        # of composite integer keys serves both the halo counts and the
        # cache-hit ranking (the only O(E log E) pass in the schedule).
        keys = dst_tile * np.int64(V) + self.senders
        pairs, pair_count = np.unique(keys, return_counts=True)
        pair_tile = (pairs // V).astype(np.int64)
        # Unique remote sources per destination tile: pairs whose source
        # lives in a different tile than the destination.
        remote_pair = (pairs % V) // K != pair_tile
        halo_counts = np.bincount(
            pair_tile[remote_pair], minlength=n_tiles).astype(np.float64)
        order = np.lexsort((-pair_count, pair_tile))
        pair_tile = pair_tile[order]
        pair_count = pair_count[order].astype(np.float64)
        seg_start = np.searchsorted(pair_tile, np.arange(n_tiles))
        pair_rank = np.arange(pair_tile.size) - seg_start[pair_tile]
        sched = TraceSchedule(
            n_tiles=int(n_tiles), capacity=cap, K=int(K),
            vertex_counts=vertex_counts, edge_counts=edge_counts,
            halo_counts=halo_counts, remote_edge_counts=remote_edge_counts,
            _pair_tile=pair_tile, _pair_count=pair_count,
            _pair_rank=pair_rank)
        self._schedules[cap] = sched
        return sched


# ---------------------------------------------------------------------------
# Dataset registry: names a scenario file can reference, resolving to the
# deterministic generators in repro.data.synthetic (pure data stays pure).
# ---------------------------------------------------------------------------
_TRACE_DATASETS: dict[str, Callable[..., GraphTrace]] = {}
_TRACE_CACHE: dict[tuple, GraphTrace] = {}


def register_trace_dataset(name: str, builder: Callable[..., GraphTrace], *,
                           overwrite: bool = False) -> None:
    """Register a named trace dataset builder (kwargs -> GraphTrace).

    Builders must be deterministic in their parameters so a serialized
    trace scenario replays bit-identically; anything random must be keyed
    by an explicit ``seed`` parameter.
    """
    if not name or not isinstance(name, str):
        raise ValueError(f"dataset name must be a non-empty string, got {name!r}")
    if name in _TRACE_DATASETS and not overwrite:
        raise ValueError(f"trace dataset {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _TRACE_DATASETS[name] = builder
    # Replacing a builder must invalidate any traces resolved under the
    # old one, or resolve_trace_dataset would keep serving stale graphs.
    for key in [k for k in _TRACE_CACHE if k[0] == name]:
        del _TRACE_CACHE[key]


def trace_dataset_names() -> tuple[str, ...]:
    return tuple(sorted(_TRACE_DATASETS))


def _cache_key(name: str, params: Mapping[str, Any]) -> tuple:
    return (name, tuple(sorted(params.items())))


def resolve_trace_dataset(name: str,
                          params: Optional[Mapping[str, Any]] = None,
                          ) -> GraphTrace:
    """Build (or fetch from the in-process cache) a registered dataset."""
    params = dict(params or {})
    if name not in _TRACE_DATASETS:
        raise KeyError(f"unknown trace dataset {name!r}; "
                       f"registered: {list(trace_dataset_names())}")
    key = _cache_key(name, params)
    if key not in _TRACE_CACHE:
        try:
            _TRACE_CACHE[key] = _TRACE_DATASETS[name](**params)
        except TypeError as exc:
            raise ValueError(
                f"bad parameters {sorted(params)} for trace dataset "
                f"{name!r}: {exc}") from exc
    return _TRACE_CACHE[key]


def clear_trace_cache() -> None:
    """Drop resolved traces (tests / long-lived services reclaiming memory)."""
    _TRACE_CACHE.clear()


def _power_law_trace(*, n_nodes, n_edges, seed=0, alpha=1.6) -> GraphTrace:
    from repro.data import synthetic

    ga = synthetic.power_law_graph(
        int(seed), n_nodes=int(n_nodes), n_edges=int(n_edges), d_feat=1,
        alpha=float(alpha), self_loops=False)
    return GraphTrace.from_arrays(ga)


def _cora_trace(*, seed=0, alpha=1.6) -> GraphTrace:
    """Cora-sized deterministic power-law graph (V/E from the Cora config)."""
    return _power_law_trace(n_nodes=CORA_V, n_edges=CORA_E,
                            seed=int(seed), alpha=float(alpha))


def _molecule_trace(*, batch=128, n_nodes=30, n_edges=64, seed=0,
                    step=0) -> GraphTrace:
    """A molecule batch as one block-diagonal disjoint-union graph."""
    from repro.data import synthetic

    b = synthetic.molecule_batch(int(seed), int(step), batch=int(batch),
                                 n_nodes=int(n_nodes), n_edges=int(n_edges),
                                 d_feat=1)
    offsets = (np.arange(int(batch), dtype=np.int64) * int(n_nodes))[:, None]
    snd = (b["senders"].astype(np.int64) + offsets).ravel()
    rcv = (b["receivers"].astype(np.int64) + offsets).ravel()
    return GraphTrace(snd, rcv, int(batch) * int(n_nodes))


def _ring_of_tiles_trace(*, n_nodes, n_tiles) -> GraphTrace:
    from repro.data import synthetic

    ga = synthetic.ring_of_tiles_graph(n_nodes=int(n_nodes),
                                       n_tiles=int(n_tiles))
    return GraphTrace.from_arrays(ga)


register_trace_dataset("power_law", _power_law_trace)
register_trace_dataset("cora", _cora_trace)
register_trace_dataset("molecule", _molecule_trace)
register_trace_dataset("ring_of_tiles", _ring_of_tiles_trace)
