"""Trace-driven graph backend: exact tile schedules from real edge lists.

The paper's composition layer (DESIGN.md §7) covers a full graph with
*uniform* tiles — `K = ceil(V / n_tiles)` vertices, `P = ceil(E / n_tiles)`
edges per tile — and charges halo reloads at the random-partition expected
cut `E * (1 - 1/n_tiles)`.  Its own narrative (echoed by the GNN computing
surveys in PAPERS.md) is that real-world degree imbalance is what actually
drives communication, yet the closed forms never touch an actual graph.

This module closes that gap (DESIGN.md §12) and keeps it fast at paper
scale (DESIGN.md §13).  A :class:`GraphTrace` wraps one concrete edge
list (CSR-ified by destination vertex) and derives, for a balanced
contiguous vertex partition, the **exact** quantities the uniform
schedule approximates:

* per-tile vertex counts ``K_t`` and destination-edge counts ``P_t``
  (straight from the CSR row pointer — no per-edge Python loop anywhere);
* per-tile **unique remote source** counts — the true halo traffic, with
  within-tile duplicate sources deduplicated exactly (so the uniform
  model's ``halo_dedup`` knob is replaced by measurement);
* degree-aware cache hit fractions: the share of a tile's aggregation
  reads served if the L most-referenced sources of the tile pass are
  pinned in a dedicated cache (EnGN's L2* narrative, measured).

**Amortized multi-capacity engine (§13).**  Because every tile is a
contiguous receiver range, ``dst_tile = receiver // K`` is monotone in
the receiver for *every* capacity.  One global sender-major sort (an
in-place composite-key ``np.sort``) — performed once per trace and
collapsed to the unique ``(sender, receiver)`` pairs with an
edge-multiplicity prefix — makes the deduplicated ``(dst_tile, source)``
pairs of any capacity appear as single contiguous runs (tile monotone
within each sender segment), so a capacity sweep costs **one sort plus
one O(U) boundary-flag pass per capacity** (U = unique pairs) instead
of a fresh ``np.unique`` sort each time.
:meth:`GraphTrace.schedules` batches a whole capacity sweep;
:meth:`GraphTrace.schedule_reference` keeps the per-capacity PR-4
``np.unique`` algorithm as the bit-exactness oracle.  A jitted JAX
engine (``engine="jax"``, :mod:`repro.kernels.segment_reduce`, with a
Pallas segment-reduce kernel) and a content-addressed on-disk cache
(:mod:`repro.core.schedule_cache`) ride on the same factorization.

:class:`~repro.core.compose.TiledGraphModel` accepts a trace as an
alternative schedule source; the scenario front door exposes it as the
third graph kind ``{"kind": "trace", "dataset": ..., "params": ...}``
with dataset references resolving to the deterministic generators in
:mod:`repro.data.synthetic` (see ``TRACE_DATASETS`` below), so trace
scenarios stay pure, serializable data.
"""

from __future__ import annotations

import functools
import json
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional, Sequence

import numpy as np

__all__ = [
    "GraphTrace",
    "TypedGraphTrace",
    "TraceSchedule",
    "register_trace_dataset",
    "resolve_trace_dataset",
    "trace_dataset_names",
    "clear_trace_cache",
    "set_trace_cache_budget",
    "trace_cache_info",
    "reset_trace_stats",
    "CORA_V",
    "CORA_E",
]

#: Cora citation-graph size (kept in sync with ``configs.base.GNN_SHAPES
#: ["full_graph_sm"]`` and the gcn-cora config; asserted in tests).
CORA_V = 2708
CORA_E = 10556

_ENGINES = ("numpy", "jax", "sharded")

#: Process-wide work counters (observability, not behaviour): how many
#: edge-list sorts, schedule computations, schedule-cache hits, and
#: builder invocations actually happened.  The §15 tuner's cache-reuse
#: regression gates on ``factorizations`` — a multi-capacity tune must
#: never silently re-sort the edge list per candidate.
_TRACE_STATS = {
    "factorizations": 0,     # actual sorts (not disk-cache rehydrations)
    "schedule_computes": 0,  # per-capacity O(U) boundary-flag passes
    "schedule_cache_hits": 0,  # per-trace LRU hits
    "schedule_disk_hits": 0,   # on-disk schedule_cache hits
    "trace_builds": 0,       # dataset builder invocations (cold resolves)
}

#: Guards ``_TRACE_STATS`` read-modify-write cycles.  The serve engine
#: (DESIGN.md §18) hammers the counters from many request threads; an
#: unguarded ``+=`` loses increments under the GIL's bytecode-boundary
#: preemption.
_STATS_LOCK = threading.Lock()

#: Guards the process-wide resolved-trace LRU (``_TRACE_CACHE``), its
#: byte budget, and the dataset registry.  Reentrant because a cold
#: ``resolve_trace_dataset`` holds it across the builder call, and the
#: builder may consult registry metadata.
_CACHE_LOCK = threading.RLock()


def _bump_stat(name: str, n: int = 1) -> None:
    with _STATS_LOCK:
        _TRACE_STATS[name] += n


def reset_trace_stats() -> None:
    """Zero the process-wide trace work counters (see trace_cache_info)."""
    with _STATS_LOCK:
        for key in _TRACE_STATS:
            _TRACE_STATS[key] = 0


def _f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


@dataclass(frozen=True)
class TraceSchedule:
    """Exact per-tile schedule of one (trace, tile capacity) pair.

    Tile ``t`` owns the contiguous vertex range ``[t*K, min((t+1)*K, V))``
    with ``n_tiles = ceil(V / capacity)`` and ``K = ceil(V / n_tiles)`` —
    the same balanced split the uniform schedule assumes, so the two
    backends differ only by what the edge list actually does.

    Attributes:
      n_tiles: number of tiles.
      capacity: requested tile vertex capacity.
      K: owned-vertex stride (``ceil(V / n_tiles)``).
      vertex_counts: ``(n_tiles,)`` exact vertices per tile.
      edge_counts: ``(n_tiles,)`` exact edges per destination tile.
      halo_counts: ``(n_tiles,)`` exact **unique** remote sources per tile
        (the halo features a tile pass must fetch from other tiles).
      remote_edge_counts: ``(n_tiles,)`` cut edges per destination tile
        (before dedup; ``halo_counts <= remote_edge_counts``).

    The ranked per-(tile, source) reference multiplicities behind
    :meth:`cache_hit_fraction` are O(unique pairs) large and only needed
    for cache statistics, so they are derived lazily from
    ``_pair_source`` (a callable returning ``(pair_tile, pair_count)``)
    and memoized — disk-cached schedules rebuild them from the trace on
    first use.
    """

    n_tiles: int
    capacity: int
    K: int
    vertex_counts: np.ndarray
    edge_counts: np.ndarray
    halo_counts: np.ndarray
    remote_edge_counts: np.ndarray
    _pair_source: Optional[Callable[[], tuple]] = field(
        default=None, repr=False, compare=False)
    _ranked_cache: Optional[tuple] = field(
        default=None, repr=False, compare=False)

    @property
    def n_edges(self) -> int:
        return int(self.edge_counts.sum())

    @property
    def cut_edges(self) -> int:
        """Total edges whose source tile differs from their destination tile."""
        return int(self.remote_edge_counts.sum())

    @property
    def halo_total(self) -> int:
        """Total unique-remote-source fetches across all tiles (exact halo)."""
        return int(self.halo_counts.sum())

    def uniform_halo_estimate(self) -> float:
        """The paper's random-partition expected cut, ``E * (1 - 1/n_tiles)``."""
        return float(self.n_edges) * (1.0 - 1.0 / self.n_tiles)

    def counts_dict(self) -> dict:
        """The integer count arrays (the disk-cache / parity payload)."""
        return {"n_tiles": self.n_tiles, "capacity": self.capacity,
                "K": self.K, "vertex_counts": self.vertex_counts,
                "edge_counts": self.edge_counts,
                "halo_counts": self.halo_counts,
                "remote_edge_counts": self.remote_edge_counts}

    def _ranked_pairs(self) -> tuple:
        """(seg_ptr, prefix): per-tile segments of count-descending pairs.

        Pairs are ranked by ``(tile asc, count desc, source asc)`` — the
        exact order of the PR-4 reference — and reduced to a segment
        pointer plus an inclusive int64 prefix sum, so the top-L cache
        hits of *any* L are two gather-subtractions (all counts are
        integers, so prefix differencing is exact).
        """
        cached = self._ranked_cache
        if cached is None:
            if self._pair_source is None:
                raise RuntimeError(
                    "this TraceSchedule carries no pair source; cache-hit "
                    "statistics need the (tile, source) multiplicities")
            pair_tile, pair_count = self._pair_source()
            # Stable sort: ties in (tile, -count) keep the provider's
            # source-ascending order, matching the np.unique reference.
            # U-sized (unique pairs), not E-sized — outside the ban's scope.
            order = np.lexsort((-pair_count, pair_tile))  # lint: allow-trace-lexsort
            pt = pair_tile[order]
            pc = pair_count[order]
            seg_ptr = np.searchsorted(pt, np.arange(self.n_tiles + 1))
            prefix = np.zeros(pc.size + 1, dtype=np.int64)
            np.cumsum(pc, out=prefix[1:])
            cached = (seg_ptr.astype(np.int64), prefix)
            object.__setattr__(self, "_ranked_cache", cached)
        return cached

    def cache_hit_fraction(self, high_degree_fraction=0.1) -> np.ndarray:
        """Exact per-tile degree-aware cache hit fractions.

        If tile ``t`` pins its ``L_t = floor(K_t * high_degree_fraction)``
        most-referenced source vertices in a dedicated cache (EnGN's L2*
        high-degree cache), this is the fraction of the tile's aggregation
        reads those sources serve — computed from the actual reference
        multiplicities.  ``high_degree_fraction`` may be a scalar or an
        array of any shape; the result broadcasts to
        ``hdf.shape + (n_tiles,)``, so hdf sweeps share one ranked-pair
        factorization instead of recomputing per value.
        """
        hdf = _f64(high_degree_fraction)
        if not np.all(np.isfinite(hdf)) or np.any(hdf < 0.0) or np.any(hdf > 1.0):
            raise ValueError(f"high_degree_fraction must be in [0, 1], "
                             f"got {high_degree_fraction!r}")
        seg_ptr, prefix = self._ranked_pairs()
        seg_start = seg_ptr[:-1]
        seg_len = np.diff(seg_ptr)
        L = np.floor(self.vertex_counts * hdf[..., None]).astype(np.int64)
        take = np.minimum(L, seg_len)
        hits = (prefix[seg_start + take] - prefix[seg_start]).astype(np.float64)
        return hits / np.maximum(self.edge_counts, 1.0)

    def stats(self, high_degree_fraction: float = 0.1) -> dict:
        """Summary record for benchmarks / result metadata (JSON-able)."""
        est = self.uniform_halo_estimate()
        exact = self.halo_total
        edge = _f64(self.edge_counts)
        hit = self.cache_hit_fraction(high_degree_fraction)
        return {
            "n_tiles": int(self.n_tiles),
            "capacity": int(self.capacity),
            "n_edges": int(self.n_edges),
            "cut_edges": int(self.cut_edges),
            "halo_exact": int(exact),
            "halo_uniform_estimate": est,
            "halo_estimate_over_exact": (est / exact) if exact else None,
            "edge_imbalance": float(edge.max() / max(edge.mean(), 1e-300)),
            "cache_hit_fraction_mean": float(hit.mean()),
            "cache_hit_fraction_min": float(hit.min()),
            "cache_hit_fraction_max": float(hit.max()),
        }


class GraphTrace:
    """One concrete directed edge list, CSR-ified by destination vertex.

    ``senders[i] -> receivers[i]`` is edge ``i``; aggregation reads source
    (sender) features into destination (receiver) vertices, matching the
    destination-stationary tiling of the paper's dataflows.  Construction
    sorts the edge list by destination once (the CSR row pointer); the
    first schedule request additionally builds the one sender-major
    unique-pair factorization that every capacity shares (DESIGN.md
    §13), after which each schedule quantity is O(U) segment algebra —
    ``np.bincount`` / boundary flags over whole arrays, never a Python
    loop over edges.
    """

    #: Per-trace schedule LRU bound (distinct capacities kept in memory).
    schedule_cache_entries: int = 64

    def __init__(self, senders, receivers, n_nodes: int) -> None:
        snd = np.asarray(senders)
        rcv = np.asarray(receivers)
        if snd.ndim != 1 or rcv.ndim != 1 or snd.shape != rcv.shape:
            raise ValueError(
                f"senders/receivers must be 1-D arrays of equal length, got "
                f"shapes {snd.shape} and {rcv.shape}")
        if not (np.issubdtype(snd.dtype, np.integer)
                and np.issubdtype(rcv.dtype, np.integer)):
            raise ValueError("senders/receivers must be integer vertex ids")
        n_nodes = int(n_nodes)
        if n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
        if snd.size and (snd.min() < 0 or snd.max() >= n_nodes
                         or rcv.min() < 0 or rcv.max() >= n_nodes):
            raise ValueError(
                f"edge endpoints must lie in [0, {n_nodes}); got sender "
                f"range [{snd.min()}, {snd.max()}] and receiver range "
                f"[{rcv.min()}, {rcv.max()}]")
        self.n_nodes = n_nodes
        # Edge arrays keep their (validated) integer dtype — int32 input
        # stays int32, halving the footprint at 10⁸ edges; every
        # downstream op promotes explicitly where int64 range is needed.
        self.senders = snd
        self.receivers = rcv
        self._n_edges = int(snd.size)
        # CSR row pointer by destination: row_ptr[v] .. row_ptr[v+1]
        # indexes the edges aggregating INTO vertex v.  O(E) bincount —
        # the E-sized sort behind the CSR *column* array is deferred to
        # first csr_senders access (most schedule queries never need it).
        counts = np.bincount(rcv, minlength=n_nodes)
        self.row_ptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=self.row_ptr[1:])
        self._csr_senders: Optional[np.ndarray] = None
        self._fact: Optional[tuple] = None
        self._fact_source: Optional[tuple] = None
        self._schedules: "OrderedDict[int, TraceSchedule]" = OrderedDict()
        self._disk_identity: Optional[tuple[str, str, str]] = None
        # Reentrant: schedule() holds it across _pair_factorization().
        self._lock = threading.RLock()

    # -- construction ------------------------------------------------------
    @classmethod
    def from_arrays(cls, graph) -> "GraphTrace":
        """From anything with ``senders`` / ``receivers`` / ``n_nodes``
        attributes (e.g. :class:`repro.data.synthetic.GraphArrays`)."""
        return cls(graph.senders, graph.receivers, graph.n_nodes)

    @classmethod
    def from_factorization(cls, n_nodes: int, u_snd, u_rcv, mult_prefix, *,
                           row_ptr=None) -> "GraphTrace":
        """Build an **edge-list-free** trace from a unique-pair factorization.

        ``(u_snd, u_rcv)`` are the unique (sender, receiver) pairs in
        sender-major order and ``mult_prefix`` the int64 edge-multiplicity
        prefix (length ``U + 1``; ``mult_prefix[-1] == E``) — exactly what
        :meth:`_pair_factorization` derives, or what the sharded pipeline
        (:mod:`repro.distributed.trace_shard`) produces without ever
        materializing the full edge list on one host.  The CSR row
        pointer is recovered in O(U) from the factorization
        (``row_counts[v] = Σ multiplicity over pairs with receiver v``)
        unless a precomputed ``row_ptr`` is supplied.  Every schedule
        quantity (including lazy CSR columns and cache-hit ranking)
        works; only :meth:`schedule_reference` — the PR-4 oracle, which
        by definition re-derives everything from raw edges — requires
        the materialized edge list and raises without one.
        """
        n_nodes = int(n_nodes)
        if n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
        u_snd = np.asarray(u_snd)
        u_rcv = np.asarray(u_rcv)
        if not np.issubdtype(u_snd.dtype, np.integer):
            u_snd = u_snd.astype(np.int64)  # e.g. an empty Python list
        if not np.issubdtype(u_rcv.dtype, np.integer):
            u_rcv = u_rcv.astype(np.int64)
        mult_prefix = np.asarray(mult_prefix, dtype=np.int64)
        if not (u_snd.ndim == u_rcv.ndim == mult_prefix.ndim == 1
                and u_snd.size == u_rcv.size == mult_prefix.size - 1):
            raise ValueError(
                f"need 1-D u_snd/u_rcv of equal length U and a length-U+1 "
                f"mult_prefix; got {u_snd.shape}, {u_rcv.shape}, "
                f"{mult_prefix.shape}")
        obj = cls.__new__(cls)
        obj.n_nodes = n_nodes
        edge_dt = u_snd.dtype if u_snd.size else np.int64
        obj.senders = np.empty(0, dtype=edge_dt)
        obj.receivers = np.empty(0, dtype=edge_dt)
        obj._n_edges = int(mult_prefix[-1]) if mult_prefix.size else 0
        if row_ptr is not None:
            obj.row_ptr = np.asarray(row_ptr, dtype=np.int64)
            if obj.row_ptr.shape != (n_nodes + 1,):
                raise ValueError(f"row_ptr must have shape ({n_nodes + 1},), "
                                 f"got {obj.row_ptr.shape}")
        else:
            # Exact int64 accumulation: a weighted np.bincount would go
            # through float64 and silently round multiplicity sums past
            # 2^53 (pinned in tests/test_trace_engine.py).
            counts = np.zeros(n_nodes, dtype=np.int64)
            np.add.at(counts, u_rcv, np.diff(mult_prefix))
            obj.row_ptr = np.zeros(n_nodes + 1, dtype=np.int64)
            np.cumsum(counts, out=obj.row_ptr[1:])
        obj._csr_senders = None
        obj._fact = cls._finish_factorization(
            u_snd, u_rcv, mult_prefix[:-1], obj._n_edges)
        obj._fact_source = None
        obj._schedules = OrderedDict()
        obj._disk_identity = None
        obj._lock = threading.RLock()
        return obj

    @classmethod
    def _from_cached(cls, d: Mapping[str, Any]) -> "GraphTrace":
        """Rebuild from a :mod:`repro.core.schedule_cache` graph payload.

        Trusted: skips validation and every sort.  Arrays may be
        memory-mapped (the lazy warm-resolve path): nothing here touches
        their contents, so a warm resolve costs directory stats + header
        reads only — the factorization's derived new-sender mask is
        finished lazily on the first schedule query.
        """
        has_fact = all(k in d for k in ("fact_u_snd", "fact_u_rcv",
                                        "fact_mult_prefix"))
        has_edges = "senders" in d and "receivers" in d
        if "row_ptr" not in d or not (has_fact or has_edges):
            return cls(d["senders"], d["receivers"], d["n_nodes"])
        obj = cls.__new__(cls)
        obj.n_nodes = int(d["n_nodes"])
        if has_edges:
            obj.senders = d["senders"]
            obj.receivers = d["receivers"]
            obj._n_edges = int(obj.senders.shape[0])
        else:
            obj.senders = np.empty(0, dtype=np.int64)
            obj.receivers = np.empty(0, dtype=np.int64)
            obj._n_edges = int(d["n_edges"])
        obj.row_ptr = d["row_ptr"]
        obj._csr_senders = d.get("csr_senders")
        obj._fact = None
        obj._fact_source = None
        if has_fact:
            obj._fact_source = (d["fact_u_snd"], d["fact_u_rcv"],
                                d["fact_mult_prefix"])
        obj._schedules = OrderedDict()
        obj._disk_identity = None
        obj._lock = threading.RLock()
        return obj

    # -- basic measures ----------------------------------------------------
    @property
    def n_edges(self) -> int:
        return self._n_edges

    @property
    def has_edge_list(self) -> bool:
        """False for factorization-only traces (sharded / streamed builds)."""
        return self.senders.shape[0] == self._n_edges

    @property
    def csr_senders(self) -> np.ndarray:
        """CSR column array: source vertices in destination-major order
        (senders ascend within each destination row).

        Built lazily on first access — schedule queries never need it,
        and skipping its E-sized sort is what makes trace construction
        O(E) bincount work (DESIGN.md §14).  Edge-list traces sort a
        receiver-major composite key; factorization-only traces expand
        the unique pairs re-sorted receiver-major (same result: within a
        (receiver, sender) run the expansion is order-free).
        """
        with self._lock:
            return self._csr_senders_locked()

    def _csr_senders_locked(self) -> np.ndarray:
        if self._csr_senders is None:
            V = self.n_nodes
            E = self._n_edges
            if E == 0:
                self._csr_senders = np.empty(0, dtype=np.int64)
            elif self.has_edge_list:
                if V <= int((2**63 - 1) ** 0.5):
                    key = np.multiply(self.receivers, V, dtype=np.int64)
                    key += self.senders
                    key.sort()
                    key %= V  # in place: the sorted keys become the columns
                    self._csr_senders = key
                else:
                    # V^2 would overflow the int64 composite key.
                    order = np.lexsort((self.senders, self.receivers))  # lint: allow-trace-lexsort
                    self._csr_senders = np.asarray(
                        self.senders, dtype=np.int64)[order]
            else:
                u_snd, u_rcv, _, mp = self._pair_factorization()
                # U-sized (unique pairs), not E-sized.
                order = np.lexsort((u_snd, u_rcv))  # lint: allow-trace-lexsort
                self._csr_senders = np.repeat(
                    np.asarray(u_snd, dtype=np.int64)[order],
                    np.diff(mp)[order])
        return self._csr_senders

    @property
    def nbytes(self) -> int:
        """In-memory footprint estimate (edge arrays, factorizations, and
        cached schedules) — the quantity the trace-cache budget bounds."""
        n = (self.senders.nbytes + self.receivers.nbytes
             + self.row_ptr.nbytes)
        if self._csr_senders is not None:
            n += self._csr_senders.nbytes
        fact = self._fact
        if fact is not None:
            n += sum(a.nbytes for a in fact)
        # Snapshot: the budget evictor reads concurrently with schedule
        # inserts on other threads (an estimate either way).
        for s in list(self._schedules.values()):
            n += (s.vertex_counts.nbytes + s.edge_counts.nbytes
                  + s.halo_counts.nbytes + s.remote_edge_counts.nbytes)
            if s._ranked_cache is not None:
                n += sum(a.nbytes for a in s._ranked_cache)
        return int(n)

    def in_degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr)

    def out_degrees(self) -> np.ndarray:
        if not self.has_edge_list:
            u_snd, _, _, mp = self._pair_factorization()
            # int64-exact (a weighted bincount would round past 2^53)
            deg = np.zeros(self.n_nodes, dtype=np.int64)
            np.add.at(deg, u_snd, np.diff(mp))
            return deg
        return np.bincount(self.senders, minlength=self.n_nodes)

    # -- the shared factorization (DESIGN.md §13) --------------------------
    def _pair_factorization(self) -> tuple[np.ndarray, np.ndarray,
                                           np.ndarray, np.ndarray]:
        """The one sorted-edge factorization every capacity shares.

        Returns ``(u_snd, u_rcv, u_new_src, mult_prefix)``: the unique
        ``(sender, receiver)`` pairs in sender-major order (compact
        dtype), a precomputed new-sender boundary mask, and the int64
        edge-multiplicity prefix (``mult_prefix[j]`` = edges in pairs
        ``< j``; length ``U+1``).

        Receivers ascend within each sender segment, so ``receiver // K``
        is monotone there for *every* stride K: the deduplicated
        ``(dst_tile, source)`` pairs of any capacity are contiguous runs
        of this list, and one capacity's halo / cut / multiplicity
        counts are a single O(U) boundary-flag pass (U = unique pairs,
        typically a small fraction of E on power-law graphs).  The sort
        itself is one in-place ``np.sort`` over composite
        ``sender * V + receiver`` keys — no stable two-pass lexsort, no
        argsort indirection — performed once and reused by every
        capacity, engine, and cache-hit query.
        """
        with self._lock:
            return self._pair_factorization_locked()

    def _pair_factorization_locked(self) -> tuple[np.ndarray, np.ndarray,
                                                  np.ndarray, np.ndarray]:
        if self._fact is None:
            V = self.n_nodes
            E = self.n_edges
            if self._fact_source is not None:
                # Disk-cached (possibly memory-mapped) factorization: the
                # derived new-sender mask is the only thing left to build
                # — O(U), no sort, touched only on first schedule query.
                u_snd, u_rcv, mp = self._fact_source
                mp = np.asarray(mp, dtype=np.int64)
                self._fact = self._finish_factorization(
                    u_snd, u_rcv, mp[:-1], int(mp[-1]))
                self._fact_source = None
            elif E == 0:
                z = np.zeros(0, dtype=np.int64)
                self._fact = (z, z, np.zeros(0, dtype=bool),
                              np.zeros(1, dtype=np.int64))
            elif not self.has_edge_list:
                raise RuntimeError(
                    "factorization-only trace lost its factorization")
            elif V <= int((2**63 - 1) ** 0.5):
                _bump_stat("factorizations")
                # dtype pinned: int32 edge arrays must not decide the key
                # width (the composite range is V^2, not V)
                key = np.multiply(self.senders, V, dtype=np.int64)
                key += self.receivers  # in place: one less E-sized pass
                key.sort()  # fresh array: safe to sort in place
                change = np.empty(E, dtype=bool)
                change[0] = True
                np.not_equal(key[1:], key[:-1], out=change[1:])
                idx = np.flatnonzero(change)
                u_key = key[idx]
                dt = (np.int32 if V <= np.iinfo(np.int32).max else np.int64)
                u_snd = (u_key // V).astype(dt, copy=False)
                u_rcv = (u_key % V).astype(dt, copy=False)
                self._fact = self._finish_factorization(u_snd, u_rcv, idx, E)
            else:
                # Composite keys would overflow int64: stable lexsort path.
                _bump_stat("factorizations")
                order = np.lexsort((self.receivers, self.senders))  # lint: allow-trace-lexsort
                snd_s = self.senders[order]
                rcv_s = self.receivers[order]
                change = np.empty(E, dtype=bool)
                change[0] = True
                np.logical_or(snd_s[1:] != snd_s[:-1],
                              rcv_s[1:] != rcv_s[:-1], out=change[1:])
                idx = np.flatnonzero(change)
                self._fact = self._finish_factorization(
                    snd_s[idx], rcv_s[idx], idx, E)
        return self._fact

    @staticmethod
    def _finish_factorization(u_snd, u_rcv, idx, E):
        u_new_src = np.empty(u_snd.size, dtype=bool)
        if u_snd.size:
            u_new_src[0] = True
            np.not_equal(u_snd[1:], u_snd[:-1], out=u_new_src[1:])
        # idx[j] is the edge offset of pair j's first edge, so idx itself
        # IS the multiplicity prefix (append E to close the last run).
        mult_prefix = np.empty(idx.size + 1, dtype=np.int64)
        mult_prefix[:-1] = idx
        mult_prefix[-1] = E
        return (u_snd, u_rcv, u_new_src, mult_prefix)

    def _geometry(self, cap: int) -> tuple[int, int]:
        n_tiles = -(-self.n_nodes // cap)
        K = -(-self.n_nodes // n_tiles)
        return n_tiles, K

    def _tile_boundaries(self, n_tiles: int, K: int) -> np.ndarray:
        return np.minimum(np.arange(n_tiles + 1, dtype=np.int64) * K,
                          self.n_nodes)

    def _pair_runs(self, K: int) -> tuple[np.ndarray, np.ndarray,
                                          np.ndarray, np.ndarray]:
        """(pair_tile, pair_count, remote, src_at_run) for stride K.

        One O(U) pass over the shared factorization: a ``(dst_tile,
        source)`` pair starts wherever the sender changes or the tile of
        the (per-sender ascending) receiver does; its edge multiplicity
        is a difference of the precomputed multiplicity prefix.
        """
        u_snd, u_rcv, u_new_src, mp = self._pair_factorization()
        U = u_snd.size
        if not U:
            z = np.zeros(0, dtype=np.int64)
            return z, z, np.zeros(0, dtype=bool), z
        Kd = u_rcv.dtype.type(K)
        tile_u = u_rcv // Kd
        boundary = np.empty(U, dtype=bool)
        boundary[0] = True
        np.logical_or(u_new_src[1:], tile_u[1:] != tile_u[:-1],
                      out=boundary[1:])
        pidx = np.flatnonzero(boundary)
        nxt = np.empty(pidx.size, dtype=np.int64)
        nxt[:-1] = pidx[1:]
        nxt[-1] = U
        pair_tile = tile_u[pidx].astype(np.int64, copy=False)
        pair_count = mp[nxt] - mp[pidx]
        src = u_snd[pidx]
        remote = (src // Kd) != tile_u[pidx]
        return pair_tile, pair_count, remote, src

    def _pairs_for(self, K: int) -> tuple[np.ndarray, np.ndarray]:
        """Deduplicated ``(dst_tile, source)`` pairs for stride K, in
        source-major order (tile ascending within each source)."""
        pair_tile, pair_count, _, _ = self._pair_runs(K)
        return pair_tile, pair_count

    @staticmethod
    def _validate_cap(tile_vertices) -> int:
        cap = int(tile_vertices)
        if cap != float(tile_vertices) or cap < 1:
            raise ValueError(f"tile_vertices must be a whole number >= 1 "
                             f"for a trace schedule, got {tile_vertices!r}")
        return cap

    def _compute_schedule(self, cap: int) -> TraceSchedule:
        """One capacity via the shared factorization: O(U) after the sort."""
        _bump_stat("schedule_computes")
        n_tiles, K = self._geometry(cap)
        boundaries = self._tile_boundaries(n_tiles, K)
        vertex_counts = np.diff(boundaries).astype(np.float64)
        edge_counts = np.diff(self.row_ptr[boundaries]).astype(np.float64)
        pair_tile, pair_count, remote, _ = self._pair_runs(K)
        if pair_tile.size:
            # A pair is remote when its source lives outside the
            # destination tile; summing the run multiplicities recovers
            # the (pre-dedup) cut edges.
            halo_counts = np.bincount(
                pair_tile[remote], minlength=n_tiles).astype(np.float64)
            # int64 accumulation, float64 only at the boundary: a
            # weighted bincount rounds in float64 *while summing*, which
            # is lossier than one final cast for totals near 2^53.
            rec = np.zeros(n_tiles, dtype=np.int64)
            np.add.at(rec, pair_tile[remote],
                      np.asarray(pair_count[remote], dtype=np.int64))
            remote_edge_counts = rec.astype(np.float64)
        else:
            halo_counts = np.zeros(n_tiles, dtype=np.float64)
            remote_edge_counts = np.zeros(n_tiles, dtype=np.float64)
        return TraceSchedule(
            n_tiles=int(n_tiles), capacity=cap, K=int(K),
            vertex_counts=vertex_counts, edge_counts=edge_counts,
            halo_counts=halo_counts, remote_edge_counts=remote_edge_counts,
            _pair_source=functools.partial(self._pairs_for, K))

    # -- schedule cache plumbing ------------------------------------------
    def _cached_schedule(self, cap: int) -> Optional[TraceSchedule]:
        sched = self._schedules.get(cap)
        if sched is not None:
            self._schedules.move_to_end(cap)
            _bump_stat("schedule_cache_hits")
            return sched
        return self._schedule_from_disk(cap)

    def _remember_schedule(self, cap: int, sched: TraceSchedule,
                           *, to_disk: bool = True) -> None:
        self._schedules[cap] = sched
        self._schedules.move_to_end(cap)
        limit = max(1, int(self.schedule_cache_entries))
        while len(self._schedules) > limit:
            self._schedules.popitem(last=False)
        if to_disk:
            self._schedule_to_disk(cap, sched)

    def clear_schedules(self) -> None:
        """Drop the per-trace schedule LRU (memory reclaim)."""
        with self._lock:
            self._schedules.clear()

    def _schedule_from_disk(self, cap: int) -> Optional[TraceSchedule]:
        if self._disk_identity is None:
            return None
        from . import schedule_cache
        if self.n_edges < schedule_cache.min_cached_edges():
            return None
        key = schedule_cache.schedule_cache_key(*self._disk_identity, cap)
        d = schedule_cache.load_schedule(key)
        if d is None:
            return None
        _bump_stat("schedule_disk_hits")
        sched = TraceSchedule(
            n_tiles=d["n_tiles"], capacity=d["capacity"], K=d["K"],
            vertex_counts=d["vertex_counts"], edge_counts=d["edge_counts"],
            halo_counts=d["halo_counts"],
            remote_edge_counts=d["remote_edge_counts"],
            _pair_source=functools.partial(self._pairs_for, d["K"]))
        self._remember_schedule(cap, sched, to_disk=False)
        return sched

    def _schedule_to_disk(self, cap: int, sched: TraceSchedule) -> None:
        if self._disk_identity is None:
            return
        from . import schedule_cache
        if self.n_edges < schedule_cache.min_cached_edges():
            return
        key = schedule_cache.schedule_cache_key(*self._disk_identity, cap)
        schedule_cache.store_schedule(key, **sched.counts_dict())

    # -- the partitioner ---------------------------------------------------
    def schedule(self, tile_vertices, *, engine: str = "numpy") -> TraceSchedule:
        """Exact balanced-partition schedule for one tile capacity (cached).

        Amortized across capacities: tile membership is integer division
        by the stride, per-tile edge counts are CSR row-pointer
        differences at the tile boundaries, and halo / multiplicity
        counts are one boundary-flag pass over the shared sender-major
        unique-pair factorization (DESIGN.md §13).  ``engine="jax"`` routes the
        segmented counts through the jitted path in
        :mod:`repro.kernels.segment_reduce` (bit-identical integers).
        """
        cap = self._validate_cap(tile_vertices)
        if engine not in _ENGINES:
            raise ValueError(f"unknown trace engine {engine!r}; "
                             f"expected one of {_ENGINES}")
        # Held across the compute so concurrent callers of the same
        # capacity see exactly one schedule_computes bump (the §18 serve
        # metrics count on it) instead of racing duplicate passes.
        with self._lock:
            sched = self._cached_schedule(cap)
            if sched is None:
                if engine == "jax":
                    sched = self._compute_schedules_jax([cap])[0]
                elif engine == "sharded":
                    sched = self._compute_schedules_sharded([cap])[0]
                else:
                    sched = self._compute_schedule(cap)
                self._remember_schedule(cap, sched)
            return sched

    def schedules(self, tile_vertices: Sequence, *,
                  engine: str = "numpy") -> tuple[TraceSchedule, ...]:
        """Batched multi-capacity schedules sharing one factorization.

        The whole sweep costs one shared (cached) sorted-edge
        factorization plus a linear segmented pass per *distinct*
        capacity; results come back in input order (duplicates allowed)
        and land in the same per-trace LRU that :meth:`schedule` uses.
        """
        caps = [self._validate_cap(c) for c in tile_vertices]
        if engine not in _ENGINES:
            raise ValueError(f"unknown trace engine {engine!r}; "
                             f"expected one of {_ENGINES}")
        # Results are held locally so a sweep wider than the schedule LRU
        # still returns every schedule (the LRU may evict early entries
        # while later capacities compute).
        found: dict[int, TraceSchedule] = {}
        missing = []
        with self._lock:
            for cap in dict.fromkeys(caps):
                sched = self._cached_schedule(cap)
                if sched is None:
                    missing.append(cap)
                else:
                    found[cap] = sched
            if missing:
                if engine == "jax":
                    computed = self._compute_schedules_jax(missing)
                elif engine == "sharded":
                    computed = self._compute_schedules_sharded(missing)
                else:
                    computed = [self._compute_schedule(c) for c in missing]
                for cap, sched in zip(missing, computed):
                    self._remember_schedule(cap, sched)
                    found[cap] = sched
        return tuple(found[c] for c in caps)

    def _compute_schedules_jax(self, caps: Sequence[int]) -> list[TraceSchedule]:
        """The jitted engine: one compile per sweep (padded tile axis)."""
        from repro.kernels import segment_reduce

        u_snd, u_rcv, u_new_src, mp = self._pair_factorization()
        mult = np.diff(mp)
        geos = [(cap, *self._geometry(cap)) for cap in caps]
        n_pad = max(n_tiles for _, n_tiles, _ in geos)
        out = []
        for cap, n_tiles, K in geos:
            _bump_stat("schedule_computes")
            halo, remote = segment_reduce.schedule_counts(
                u_snd, u_rcv, u_new_src, mult, K, n_pad)
            boundaries = self._tile_boundaries(n_tiles, K)
            out.append(TraceSchedule(
                n_tiles=int(n_tiles), capacity=int(cap), K=int(K),
                vertex_counts=np.diff(boundaries).astype(np.float64),
                edge_counts=np.diff(
                    self.row_ptr[boundaries]).astype(np.float64),
                halo_counts=np.asarray(halo)[:n_tiles].astype(np.float64),
                remote_edge_counts=np.asarray(
                    remote)[:n_tiles].astype(np.float64),
                _pair_source=functools.partial(self._pairs_for, K)))
        return out

    def _compute_schedules_sharded(self, caps: Sequence[int]
                                   ) -> list[TraceSchedule]:
        """The sharded engine: the O(U) boundary-flag pass split at
        new-sender boundaries and run per shard (bit-identical partial
        bincounts summed; :mod:`repro.distributed.trace_shard`)."""
        from repro.distributed import trace_shard

        out = []
        for cap in caps:
            _bump_stat("schedule_computes")
            n_tiles, K = self._geometry(cap)
            boundaries = self._tile_boundaries(n_tiles, K)
            halo, remote = trace_shard.sharded_schedule_counts(
                self._pair_factorization(), K, n_tiles)
            out.append(TraceSchedule(
                n_tiles=int(n_tiles), capacity=int(cap), K=int(K),
                vertex_counts=np.diff(boundaries).astype(np.float64),
                edge_counts=np.diff(
                    self.row_ptr[boundaries]).astype(np.float64),
                halo_counts=halo.astype(np.float64),
                remote_edge_counts=remote.astype(np.float64),
                _pair_source=functools.partial(self._pairs_for, K)))
        return out

    def schedule_reference(self, tile_vertices) -> TraceSchedule:
        """The PR-4 per-capacity algorithm, kept verbatim as the oracle.

        One ``np.unique`` over composite ``(tile, source)`` keys plus an
        eager ranking lexsort per call — O(E log E) per capacity.  The
        parity battery and ``benchmarks/trace_scale.py`` pin the
        amortized engines bit-identical to (and ≥10x faster than) this.
        Results are not cached: every call pays the full PR-4 cost.
        """
        cap = self._validate_cap(tile_vertices)
        if not self.has_edge_list:
            raise RuntimeError(
                "schedule_reference needs the materialized edge list; this "
                "trace is factorization-only (sharded/streamed build). "
                "Rebuild it from raw senders/receivers to run the oracle.")
        V = self.n_nodes
        n_tiles, K = self._geometry(cap)
        boundaries = self._tile_boundaries(n_tiles, K)
        vertex_counts = np.diff(boundaries).astype(np.float64)
        edge_counts = np.diff(self.row_ptr[boundaries]).astype(np.float64)
        dst_tile = self.receivers // K
        src_tile = self.senders // K
        remote = src_tile != dst_tile
        remote_edge_counts = np.bincount(
            dst_tile[remote], minlength=n_tiles).astype(np.float64)
        keys = dst_tile * np.int64(V) + self.senders
        pairs, pair_count = np.unique(keys, return_counts=True)
        pair_tile = (pairs // V).astype(np.int64)
        remote_pair = (pairs % V) // K != pair_tile
        halo_counts = np.bincount(
            pair_tile[remote_pair], minlength=n_tiles).astype(np.float64)
        # Eager ranking, exactly as PR 4 paid it per capacity (the new
        # engines defer this to the first cache-hit query).
        order = np.lexsort((-pair_count, pair_tile))  # lint: allow-trace-lexsort
        ranked_tile = pair_tile[order]
        ranked_count = pair_count[order]
        seg_ptr = np.searchsorted(ranked_tile, np.arange(n_tiles + 1))
        prefix = np.zeros(ranked_count.size + 1, dtype=np.int64)
        np.cumsum(ranked_count, out=prefix[1:])
        return TraceSchedule(
            n_tiles=int(n_tiles), capacity=cap, K=int(K),
            vertex_counts=vertex_counts, edge_counts=edge_counts,
            halo_counts=halo_counts, remote_edge_counts=remote_edge_counts,
            _pair_source=lambda: (pair_tile, pair_count),
            _ranked_cache=(seg_ptr.astype(np.int64), prefix))


class TypedGraphTrace:
    """A heterogeneous (typed) edge list: ``senders[i] -> receivers[i]``
    carries relation ``rels[i]`` (an RGCN-style edge type).

    The single-relation amortization generalizes without a new algorithm:
    folding ``rel`` into the composite sort key —
    ``(rel * V + sender) * V + receiver`` — makes the one in-place
    ``np.sort`` produce the unique ``(rel, sender, receiver)`` triples in
    relation-major, sender-major order, so every relation's unique-pair
    factorization is a contiguous **slice** of one shared sort.
    :meth:`relation` hands each slice to
    :meth:`GraphTrace.from_factorization` (edge-list-free, zero
    additional sorts), after which per-relation schedules fall out of the
    same one-sort-many-capacities boundary-flag pass the homogeneous
    engine uses; the drift gate in ``tests/test_hetero.py`` pins them
    bit-identical to R independently-built single-relation traces.
    """

    def __init__(self, senders, receivers, rels, n_nodes: int,
                 n_relations: int) -> None:
        snd = np.asarray(senders)
        rcv = np.asarray(receivers)
        rel = np.asarray(rels)
        if not (snd.ndim == rcv.ndim == rel.ndim == 1
                and snd.shape == rcv.shape == rel.shape):
            raise ValueError(
                f"senders/receivers/rels must be 1-D arrays of equal "
                f"length, got shapes {snd.shape}, {rcv.shape}, {rel.shape}")
        if not all(np.issubdtype(a.dtype, np.integer)
                   for a in (snd, rcv, rel)):
            raise ValueError("senders/receivers/rels must be integer arrays")
        n_nodes = int(n_nodes)
        n_relations = int(n_relations)
        if n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {n_nodes}")
        if n_relations < 1:
            raise ValueError(f"n_relations must be >= 1, got {n_relations}")
        if snd.size and (snd.min() < 0 or snd.max() >= n_nodes
                         or rcv.min() < 0 or rcv.max() >= n_nodes):
            raise ValueError(
                f"edge endpoints must lie in [0, {n_nodes})")
        if rel.size and (rel.min() < 0 or rel.max() >= n_relations):
            raise ValueError(
                f"relation ids must lie in [0, {n_relations}); got range "
                f"[{rel.min()}, {rel.max()}]")
        self.n_nodes = n_nodes
        self.n_relations = n_relations
        self.senders = snd
        self.receivers = rcv
        self.rels = rel
        self._n_edges = int(snd.size)
        self._fact: Optional[tuple] = None
        self._relation_traces: dict[int, GraphTrace] = {}
        # Reentrant: relation() holds it across _typed_factorization().
        self._lock = threading.RLock()

    # -- basic measures ----------------------------------------------------
    @property
    def n_edges(self) -> int:
        return self._n_edges

    @property
    def nbytes(self) -> int:
        """In-memory footprint (edge arrays, shared factorization, and the
        per-relation traces carved out of it) — the trace-cache unit."""
        n = self.senders.nbytes + self.receivers.nbytes + self.rels.nbytes
        fact = self._fact
        if fact is not None:
            n += sum(a.nbytes for a in fact)
        for t in list(self._relation_traces.values()):
            n += t.nbytes
        return int(n)

    def clear_schedules(self) -> None:
        """Drop every per-relation schedule LRU (memory reclaim)."""
        for t in list(self._relation_traces.values()):
            t.clear_schedules()

    def relation_edge_counts(self) -> np.ndarray:
        """``(n_relations,)`` int64 edges per relation (exact)."""
        _, _, _, mp, rel_ptr = self._typed_factorization()
        return np.diff(mp[rel_ptr])

    # -- the shared typed factorization ------------------------------------
    def _typed_factorization(self) -> tuple[np.ndarray, np.ndarray,
                                            np.ndarray, np.ndarray,
                                            np.ndarray]:
        """One sort shared by every (relation, capacity) query.

        Returns ``(u_rel, u_snd, u_rcv, mult_prefix, rel_ptr)``: unique
        ``(rel, sender, receiver)`` triples in relation-major sender-major
        order, the int64 edge-multiplicity prefix over the triples
        (length ``U+1``), and ``rel_ptr`` (length ``R+1``) delimiting
        each relation's contiguous triple range.
        """
        with self._lock:
            return self._typed_factorization_locked()

    def _typed_factorization_locked(self):
        if self._fact is None:
            V = self.n_nodes
            R = self.n_relations
            E = self._n_edges
            if E == 0:
                z = np.zeros(0, dtype=np.int64)
                self._fact = (z, z, z, np.zeros(1, dtype=np.int64),
                              np.zeros(R + 1, dtype=np.int64))
                return self._fact
            if R * V <= (2**63 - 1) // V:
                _bump_stat("factorizations")
                # rel folded into the PR-5 composite key: one in-place
                # sort covers every relation (range R*V^2, checked).
                key = np.multiply(self.rels, V, dtype=np.int64)
                key += self.senders
                key *= V
                key += self.receivers
                key.sort()
                change = np.empty(E, dtype=bool)
                change[0] = True
                np.not_equal(key[1:], key[:-1], out=change[1:])
                idx = np.flatnonzero(change)
                u_key = key[idx]
                dt = (np.int32 if V <= np.iinfo(np.int32).max else np.int64)
                u_rcv = (u_key % V).astype(dt, copy=False)
                u_key //= V
                u_snd = (u_key % V).astype(dt, copy=False)
                u_rel = (u_key // V).astype(np.int64, copy=False)
            else:
                # R*V^2 would overflow the int64 composite key.
                _bump_stat("factorizations")
                order = np.lexsort((self.receivers, self.senders, self.rels))  # lint: allow-trace-lexsort
                rel_s = self.rels[order]
                snd_s = self.senders[order]
                rcv_s = self.receivers[order]
                change = np.empty(E, dtype=bool)
                change[0] = True
                np.logical_or.reduce([rel_s[1:] != rel_s[:-1],
                                      snd_s[1:] != snd_s[:-1],
                                      rcv_s[1:] != rcv_s[:-1]],
                                     out=change[1:])
                idx = np.flatnonzero(change)
                u_rel = rel_s[idx].astype(np.int64, copy=False)
                u_snd = snd_s[idx]
                u_rcv = rcv_s[idx]
            mult_prefix = np.empty(idx.size + 1, dtype=np.int64)
            mult_prefix[:-1] = idx
            mult_prefix[-1] = E
            rel_ptr = np.searchsorted(u_rel, np.arange(R + 1)).astype(np.int64)
            self._fact = (u_rel, u_snd, u_rcv, mult_prefix, rel_ptr)
        return self._fact

    # -- per-relation traces -----------------------------------------------
    def relation(self, r: int) -> GraphTrace:
        """The single-relation :class:`GraphTrace` of relation ``r``.

        Carved from the shared typed factorization: the slice
        ``rel_ptr[r]:rel_ptr[r+1]`` is already a sender-major unique-pair
        factorization of relation r's edge multiset, so the trace is
        built edge-list-free through :meth:`GraphTrace.from_factorization`
        with its multiplicity prefix rebased — no per-relation sort, no
        edge list.  Traces (and their per-capacity schedule LRUs) are
        cached per relation.
        """
        r = int(r)
        if not 0 <= r < self.n_relations:
            raise ValueError(f"relation must lie in [0, {self.n_relations}), "
                             f"got {r}")
        with self._lock:
            trace = self._relation_traces.get(r)
            if trace is None:
                _, u_snd, u_rcv, mp, rel_ptr = self._typed_factorization()
                lo, hi = int(rel_ptr[r]), int(rel_ptr[r + 1])
                local_prefix = mp[lo:hi + 1] - mp[lo]
                trace = GraphTrace.from_factorization(
                    self.n_nodes, u_snd[lo:hi], u_rcv[lo:hi], local_prefix)
                self._relation_traces[r] = trace
            return trace

    def relation_traces(self) -> tuple[GraphTrace, ...]:
        """All per-relation traces, in relation order (one shared sort)."""
        return tuple(self.relation(r) for r in range(self.n_relations))

    def relation_schedules(self, tile_vertices, *,
                           engine: str = "numpy") -> tuple[TraceSchedule, ...]:
        """One capacity across every relation: ``(R,)`` schedules.

        All relations share the trace's vertex set, so the partition
        geometry (``n_tiles``, ``K``, per-tile vertex counts) is common;
        only the edge/halo/cut counts differ per relation.
        """
        return tuple(self.relation(r).schedule(tile_vertices, engine=engine)
                     for r in range(self.n_relations))


# ---------------------------------------------------------------------------
# Dataset registry: names a scenario file can reference, resolving to the
# deterministic generators in repro.data.synthetic (pure data stays pure).
# ---------------------------------------------------------------------------
_TRACE_DATASETS: dict[str, tuple[Callable[..., GraphTrace], Optional[str]]] = {}
_TRACE_CACHE: "OrderedDict[tuple, GraphTrace]" = OrderedDict()
#: In-process resolved-trace budget; oldest entries evict beyond it (the
#: most recent trace always stays, even when alone it exceeds the budget).
_TRACE_CACHE_BUDGET_BYTES = 1 << 30


def register_trace_dataset(name: str, builder: Callable[..., GraphTrace], *,
                           overwrite: bool = False,
                           cache_token: Optional[str] = None) -> None:
    """Register a named trace dataset builder (kwargs -> GraphTrace).

    Builders must be deterministic in their parameters so a serialized
    trace scenario replays bit-identically; anything random must be keyed
    by an explicit ``seed`` parameter.  ``cache_token`` opts the dataset
    into the on-disk graph/schedule cache (:mod:`repro.core.
    schedule_cache`): it is the builder's manual version stamp — bump it
    whenever the builder's output changes for identical parameters.
    Datasets without a token (e.g. throwaway in-memory graphs) never
    touch the disk cache.
    """
    if not name or not isinstance(name, str):
        raise ValueError(f"dataset name must be a non-empty string, got {name!r}")
    with _CACHE_LOCK:
        if name in _TRACE_DATASETS and not overwrite:
            raise ValueError(f"trace dataset {name!r} already registered "
                             "(pass overwrite=True to replace)")
        _TRACE_DATASETS[name] = (builder, cache_token)
        # Replacing a builder must invalidate any traces resolved under the
        # old one, or resolve_trace_dataset would keep serving stale graphs.
        for key in [k for k in _TRACE_CACHE if k[0] == name]:
            del _TRACE_CACHE[key]


def trace_dataset_names() -> tuple[str, ...]:
    with _CACHE_LOCK:
        return tuple(sorted(_TRACE_DATASETS))


def _canonical_params(params: Mapping[str, Any]) -> str:
    """Sorted-JSON canonical form of a params mapping.

    Nested dicts/lists and numpy scalars — which a JSON scenario file or
    a direct caller may legally hand over — serialize deterministically
    instead of exploding ``tuple(sorted(...))`` hashing on unhashable
    values (the PR-5 satellite bugfix; regression-tested).  Integer-valued
    floats canonicalize to their integer (``1000000.0`` == ``1000000``,
    matching the old tuple key's ``hash(1000) == hash(1000.0)``
    behaviour), so the scenario front door (which normalizes params to
    floats) and direct int-passing callers share one cache entry.
    """
    def canon(o):
        if isinstance(o, np.ndarray):
            o = o.tolist()
        if isinstance(o, np.generic):
            o = o.item()
        if isinstance(o, Mapping):
            return {str(k): canon(v) for k, v in o.items()}
        if isinstance(o, (list, tuple)):
            return [canon(v) for v in o]
        if isinstance(o, float) and not isinstance(o, bool) and o.is_integer():
            return int(o)
        return o

    def default(o):
        return repr(o)

    return json.dumps(canon(dict(params)), sort_keys=True,
                      separators=(",", ":"), default=default)


def _cache_key(name: str, params: Mapping[str, Any]) -> tuple:
    return (name, _canonical_params(params))


def _evict_to_budget() -> None:
    """Evict oldest traces until the byte budget holds (the most recent
    entry always survives).  Sizes are snapshotted once per call."""
    sizes = {k: t.nbytes for k, t in _TRACE_CACHE.items()}
    total = sum(sizes.values())
    while len(_TRACE_CACHE) > 1 and total > _TRACE_CACHE_BUDGET_BYTES:
        key, _ = _TRACE_CACHE.popitem(last=False)
        total -= sizes[key]


def _trace_cache_insert(key: tuple, trace: GraphTrace) -> None:
    _TRACE_CACHE[key] = trace
    _TRACE_CACHE.move_to_end(key)
    _evict_to_budget()


def set_trace_cache_budget(n_bytes: int) -> None:
    """Set the in-process resolved-trace LRU budget (bytes) and evict."""
    global _TRACE_CACHE_BUDGET_BYTES
    n_bytes = int(n_bytes)
    if n_bytes < 0:
        raise ValueError(f"trace cache budget must be >= 0 bytes, "
                         f"got {n_bytes!r}")
    with _CACHE_LOCK:
        _TRACE_CACHE_BUDGET_BYTES = n_bytes
        _evict_to_budget()


def trace_cache_info() -> dict:
    """Entries / bytes / budget of the in-process resolved-trace LRU,
    plus the process-wide work counters (``stats``: factorizations,
    schedule computes/hits, builder invocations — see
    :func:`reset_trace_stats`)."""
    with _CACHE_LOCK:
        entries = len(_TRACE_CACHE)
        nbytes = int(sum(t.nbytes for t in _TRACE_CACHE.values()))
        budget = int(_TRACE_CACHE_BUDGET_BYTES)
    with _STATS_LOCK:
        stats = dict(_TRACE_STATS)
    return {"entries": entries, "bytes": nbytes,
            "budget_bytes": budget, "stats": stats}


def resolve_trace_dataset(name: str,
                          params: Optional[Mapping[str, Any]] = None,
                          ) -> GraphTrace:
    """Build (or fetch from the in-process / on-disk cache) a dataset.

    Thread-safe: the whole resolve (LRU probe, disk-cache load, builder
    call, insert) holds the process-wide cache lock, so concurrent
    resolutions of the same key cost exactly one build — the §18 serve
    engine leans on that single-flight guarantee for its warm-cache
    metrics.
    """
    params = dict(params or {})
    with _CACHE_LOCK:
        return _resolve_trace_dataset_locked(name, params)


def _resolve_trace_dataset_locked(name: str,
                                  params: dict) -> GraphTrace:
    if name not in _TRACE_DATASETS:
        raise KeyError(f"unknown trace dataset {name!r}; "
                       f"registered: {list(trace_dataset_names())}")
    builder, token = _TRACE_DATASETS[name]
    key = _cache_key(name, params)
    cached = _TRACE_CACHE.get(key)
    if cached is not None:
        _TRACE_CACHE.move_to_end(key)
        return cached
    canonical = key[1]
    trace = None
    if token is not None:
        from . import schedule_cache
        gkey = schedule_cache.graph_cache_key(name, canonical, token)
        payload = schedule_cache.load_graph(gkey)
        if payload is not None:
            trace = GraphTrace._from_cached(payload)
            trace._disk_identity = (name, canonical, token)
    if trace is None:
        _bump_stat("trace_builds")
        try:
            trace = _TRACE_DATASETS[name][0](**params)
        except TypeError as exc:
            raise ValueError(
                f"bad parameters {sorted(params)} for trace dataset "
                f"{name!r}: {exc}") from exc
        if token is not None:
            trace._disk_identity = (name, canonical, token)
            from . import schedule_cache
            if trace.n_edges >= schedule_cache.min_cached_edges():
                # Persist the factorization (and the edge list when the
                # builder materialized one) so a warm process skips the
                # generator AND every sort.  csr_senders is stored only
                # if already built — forcing its E-sized sort here would
                # charge every cold resolve for a rarely-read array.
                u_snd, u_rcv, _, mp = trace._pair_factorization()
                kw = {}
                if trace.has_edge_list and trace.n_edges:
                    kw["senders"] = trace.senders
                    kw["receivers"] = trace.receivers
                if trace._csr_senders is not None:
                    kw["csr_senders"] = trace._csr_senders
                schedule_cache.store_graph(
                    schedule_cache.graph_cache_key(name, canonical, token),
                    n_nodes=trace.n_nodes, n_edges=trace.n_edges,
                    row_ptr=trace.row_ptr,
                    fact_u_snd=u_snd, fact_u_rcv=u_rcv,
                    fact_mult_prefix=mp, **kw)
    _trace_cache_insert(key, trace)
    return trace


def clear_trace_cache() -> None:
    """Drop resolved traces (tests / long-lived services reclaiming memory).

    Also clears each cached trace's per-capacity schedule LRU, so a
    service holding an external reference to a trace does not keep the
    schedule memory alive through this call.
    """
    with _CACHE_LOCK:
        for trace in list(_TRACE_CACHE.values()):
            trace.clear_schedules()
        _TRACE_CACHE.clear()


def _power_law_trace(*, n_nodes, n_edges, seed=0, alpha=1.6) -> GraphTrace:
    from repro.data import synthetic

    ga = synthetic.power_law_graph(
        int(seed), n_nodes=int(n_nodes), n_edges=int(n_edges), d_feat=1,
        alpha=float(alpha), self_loops=False)
    return GraphTrace.from_arrays(ga)


def _power_law_stream_trace(*, n_nodes, n_edges, seed=0,
                            alpha=1.6) -> GraphTrace:
    """Chunk-streamed power-law graph: the ≥10⁶-edge scaling dataset.

    Identical contract to ``power_law`` (deterministic in params, no
    self loops) but generated through
    :func:`repro.data.synthetic.power_law_edges`, whose peak memory is
    bounded by the fixed chunk size instead of the edge count — the
    registry path to 10⁷-edge graphs (DESIGN.md §13).
    """
    from repro.data import synthetic

    snd, rcv = synthetic.power_law_edges(
        int(seed), n_nodes=int(n_nodes), n_edges=int(n_edges),
        alpha=float(alpha))
    return GraphTrace(snd, rcv, int(n_nodes))


def _power_law_sharded_trace(*, n_nodes, n_edges, seed=0,
                             alpha=1.6) -> GraphTrace:
    """Device-parallel sharded build of the ``power_law_stream`` graph.

    Same edge multiset as ``power_law_stream`` for identical parameters
    (the drift gate pins the factorizations bit-identical), but built by
    :mod:`repro.distributed.trace_shard`: per-shard chunk generation,
    local composite-key sorts, a range-bucketed exchange, and per-bucket
    unique-pair merges — the full edge list never materializes on one
    host, so the builder reaches 10⁸–10⁹ edges (DESIGN.md §14).  The
    shard count is an execution detail, *not* graph identity: it comes
    from ``REPRO_TRACE_SHARDS`` (else the host's device/CPU count) and
    never enters the cache key.
    """
    from repro.distributed import trace_shard

    return trace_shard.build_power_law_trace(
        n_nodes=int(n_nodes), n_edges=int(n_edges), seed=int(seed),
        alpha=float(alpha))


def _cora_trace(*, seed=0, alpha=1.6) -> GraphTrace:
    """Cora-sized deterministic power-law graph (V/E from the Cora config)."""
    return _power_law_trace(n_nodes=CORA_V, n_edges=CORA_E,
                            seed=int(seed), alpha=float(alpha))


def _molecule_trace(*, batch=128, n_nodes=30, n_edges=64, seed=0,
                    step=0) -> GraphTrace:
    """A molecule batch as one block-diagonal disjoint-union graph."""
    from repro.data import synthetic

    b = synthetic.molecule_batch(int(seed), int(step), batch=int(batch),
                                 n_nodes=int(n_nodes), n_edges=int(n_edges),
                                 d_feat=1)
    offsets = (np.arange(int(batch), dtype=np.int64) * int(n_nodes))[:, None]
    snd = (b["senders"].astype(np.int64) + offsets).ravel()
    rcv = (b["receivers"].astype(np.int64) + offsets).ravel()
    return GraphTrace(snd, rcv, int(batch) * int(n_nodes))


def _ring_of_tiles_trace(*, n_nodes, n_tiles) -> GraphTrace:
    from repro.data import synthetic

    ga = synthetic.ring_of_tiles_graph(n_nodes=int(n_nodes),
                                       n_tiles=int(n_tiles))
    return GraphTrace.from_arrays(ga)


def _relation_assignment(seed, n_edges: int, n_relations: int) -> np.ndarray:
    """Deterministic per-edge relation ids (seed-keyed, like synthetic)."""
    rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0x9e37]))
    return rng.integers(0, int(n_relations), size=int(n_edges),
                        dtype=np.int64)


def _typed_power_law_trace(*, n_nodes, n_edges, n_relations, seed=0,
                           alpha=1.6) -> TypedGraphTrace:
    """The ``power_law`` edge list with seed-keyed random edge types.

    Same (sender, receiver) multiset as ``power_law`` for identical
    ``(n_nodes, n_edges, seed, alpha)`` — the typed drift gate exploits
    this to compare per-relation schedules against independently-built
    single-relation traces.
    """
    from repro.data import synthetic

    ga = synthetic.power_law_graph(
        int(seed), n_nodes=int(n_nodes), n_edges=int(n_edges), d_feat=1,
        alpha=float(alpha), self_loops=False)
    rels = _relation_assignment(seed, int(n_edges), int(n_relations))
    return TypedGraphTrace(ga.senders, ga.receivers, rels, int(n_nodes),
                           int(n_relations))


def _typed_blocks_trace(*, n_relations, n_nodes, n_edges, seed=0,
                        alpha=1.6) -> TypedGraphTrace:
    """Block-diagonal typed fixture: relation r's edges live entirely in
    vertex block ``[r*n_nodes, (r+1)*n_nodes)`` (R disjoint power-law
    graphs under one vertex numbering) — the bit-identity fixture for
    ``RelationalGraphModel`` vs an R-loop of homogeneous evaluations.
    """
    from repro.data import synthetic

    R = int(n_relations)
    nn = int(n_nodes)
    snd_parts, rcv_parts, rel_parts = [], [], []
    for r in range(R):
        ga = synthetic.power_law_graph(
            int(seed) * 7919 + r, n_nodes=nn, n_edges=int(n_edges),
            d_feat=1, alpha=float(alpha), self_loops=False)
        snd_parts.append(ga.senders.astype(np.int64) + r * nn)
        rcv_parts.append(ga.receivers.astype(np.int64) + r * nn)
        rel_parts.append(np.full(int(n_edges), r, dtype=np.int64))
    return TypedGraphTrace(np.concatenate(snd_parts),
                           np.concatenate(rcv_parts),
                           np.concatenate(rel_parts), R * nn, R)


def _typed_cora_trace(*, n_relations=3, seed=0, alpha=1.6) -> TypedGraphTrace:
    """Cora-sized typed graph (RGCN-on-Cora analogue: same V/E, R edge
    types assigned deterministically from the seed)."""
    return _typed_power_law_trace(
        n_nodes=CORA_V, n_edges=CORA_E, n_relations=int(n_relations),
        seed=int(seed), alpha=float(alpha))


register_trace_dataset("power_law", _power_law_trace, cache_token="v1")
register_trace_dataset("power_law_stream", _power_law_stream_trace,
                       cache_token="v1")
register_trace_dataset("power_law_sharded", _power_law_sharded_trace,
                       cache_token="v1")
register_trace_dataset("cora", _cora_trace)
register_trace_dataset("molecule", _molecule_trace)
register_trace_dataset("ring_of_tiles", _ring_of_tiles_trace)
register_trace_dataset("typed_power_law", _typed_power_law_trace)
register_trace_dataset("typed_blocks", _typed_blocks_trace)
register_trace_dataset("typed_cora", _typed_cora_trace)
