"""Composition layer: from one tile-layer to L-layer, full-graph totals.

The paper's Tables III/IV model **one GNN layer over one graph tile**.
This module composes any registered dataflow upward (DESIGN.md §7):

* :class:`MultiLayerModel` — chain L GNN layers, propagating the feature
  width (layer l maps ``widths[l] -> widths[l+1]`` elements per vertex),
  with an inter-layer **residency policy**: ``"spill"`` (every layer writes
  its outputs to L2 and the next layer reloads them — generalizing HyGCN's
  inter-phase terms to inter-*layer*) or ``"resident"`` (interior outputs
  stay on-array; the interior vertex_out/vertex_in movement levels are
  replaced by a single on-chip hand-off term).
* :class:`TiledGraphModel` — cover a full graph: a tile schedule is derived
  from (V, E) and the tile vertex capacity, every tile re-evaluates the
  inner model, and an inter-tile **halo-reload** term charges re-fetching
  remote source features for cut edges.  Passing a
  :class:`~repro.core.trace.GraphTrace` swaps the uniform approximation
  for the **exact** edge-list-driven schedule (per-tile K/L/P and
  deduplicated unique-remote-source halo counts, DESIGN.md §12).

Both compose: ``TiledGraphModel(MultiLayerModel("engn", widths))`` answers
the paper's open question "total movement for GCN-on-Cora end-to-end".
All arithmetic stays closed-form and broadcasting, so array-valued tile
capacities / graph sizes sweep in one vectorized call.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from .dataflow import DataflowSpec, SpecModel
from .notation import GraphTileParams, ParamArray
from .terms import ModelOutput, MovementTerm, ceil
from .trace import GraphTrace, TraceSchedule, TypedGraphTrace

__all__ = [
    "MultiLayerModel",
    "TiledGraphModel",
    "RelationalGraphModel",
    "FullGraphParams",
    "RESIDENCY_POLICIES",
    "COMPOSITION_FORMS",
    "tile_working_set_bits",
]

RESIDENCY_POLICIES = ("spill", "resident")

#: Tile-axis chunk for the capacity-batched trace evaluation.  MUST stay a
#: power of two: the pairwise reduction tree then decomposes into aligned
#: subtrees, so chunked partial sums combine bit-identically to one
#: unchunked pairwise pass (and to every per-capacity pass) while peak
#: memory stays O(batch x chunk) per term instead of O(batch x n_tiles).
TRACE_TILE_CHUNK = 1 << 16


def _f64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float64)


def _pairwise_sum(a: np.ndarray) -> np.ndarray:
    """Sum over the last axis by pairwise halving (deterministic tree).

    The trace evaluation reduces its tile axis with this so that a
    schedule of ``2^k`` identical tiles sums **bit-identically** to the
    uniform closed form's ``n_tiles * per_tile`` product (every halving
    step doubles an exactly-representable value) — the property the ring
    bit-match test pins.  Zero-padding to even length is exact.
    """
    a = _f64(a)
    while a.shape[-1] > 1:
        if a.shape[-1] % 2:
            a = np.concatenate(
                [a, np.zeros(a.shape[:-1] + (1,), dtype=np.float64)], axis=-1)
        a = a[..., 0::2] + a[..., 1::2]
    return a[..., 0]


def _resolve_spec(dataflow) -> DataflowSpec:
    if isinstance(dataflow, str):
        from . import registry
        return registry.get(dataflow)
    if isinstance(dataflow, DataflowSpec):
        return dataflow
    if isinstance(dataflow, SpecModel):
        return dataflow.spec
    raise TypeError(f"cannot resolve a DataflowSpec from {type(dataflow).__name__}")


class _TermAccumulator:
    """Sum (bits, iterations) contributions by (name, hierarchy), in order."""

    def __init__(self) -> None:
        self._order: list[tuple[str, str]] = []
        self._bits: dict[tuple[str, str], np.ndarray] = {}
        self._iters: dict[tuple[str, str], np.ndarray] = {}

    def add(self, name: str, hierarchy: str, bits, iterations) -> None:
        key = (name, hierarchy)
        if key not in self._bits:
            self._order.append(key)
            self._bits[key] = _f64(bits)
            self._iters[key] = _f64(iterations)
        else:
            self._bits[key] = self._bits[key] + _f64(bits)
            self._iters[key] = self._iters[key] + _f64(iterations)

    def terms(self) -> tuple[MovementTerm, ...]:
        return tuple(MovementTerm(n, h, self._bits[(n, h)], self._iters[(n, h)])
                     for n, h in self._order)


class MultiLayerModel:
    """L chained GNN layers of one dataflow, with width propagation.

    ``widths`` is the per-vertex feature-element sequence ``[N_0, ..., N_L]``;
    layer l evaluates the inner dataflow at ``N = widths[l], T = widths[l+1]``
    on the same tile topology (K, L, P from the input graph).  With the
    ``"spill"`` policy the total is the plain sum over layers (each layer
    pays its own vertex loads/stores); ``"resident"`` keeps interior
    activations on-array, dropping interior ``vertex_out``/``vertex_in``
    levels in favour of one ``residenthandoff`` L1-L1 term of
    ``K * widths[l+1] * sigma`` bits per boundary.
    """

    def __init__(self, dataflow, widths, *, residency: str = "spill") -> None:
        self.spec = _resolve_spec(dataflow)
        if len(widths) < 2:
            raise ValueError(f"need >= 2 widths (got {list(widths)}): "
                             "a layer maps widths[l] -> widths[l+1]")
        if residency not in RESIDENCY_POLICIES:
            raise ValueError(f"unknown residency {residency!r}; "
                             f"expected one of {RESIDENCY_POLICIES}")
        self.widths = tuple(widths)
        self.residency = residency
        self.name = f"{self.spec.name}_L{self.n_layers}_{residency}"

    @property
    def n_layers(self) -> int:
        return len(self.widths) - 1

    def resolve_hw(self, hw=None):
        return self.spec.resolve_hw(hw)

    def halo_feature_elems(self) -> np.ndarray:
        """Per-vertex elements fetched across tile boundaries, all layers."""
        return _f64(sum(_f64(w) for w in self.widths[:-1]))

    def evaluate(self, graph: GraphTileParams, hw=None) -> ModelOutput:
        hw = self.resolve_hw(hw)
        L = self.n_layers
        acc = _TermAccumulator()
        for l in range(L):
            g_l = graph.replace(N=self.widths[l], T=self.widths[l + 1])
            for m in self.spec.movements:
                if self.residency == "resident" and m.interior_at(l, L):
                    continue
                bits, iters = m.form(g_l, hw)
                acc.add(m.name, m.hierarchy, bits, iters)
        if self.residency == "resident":
            K = _f64(graph.K)
            s = _f64(hw.sigma)
            for l in range(L - 1):
                acc.add("residenthandoff", "L1-L1",
                        K * _f64(self.widths[l + 1]) * s, np.ones_like(K))
        return ModelOutput(
            accelerator=self.name,
            terms=acc.terms(),
            meta={"hw": hw, "graph": graph, "spec": self.spec,
                  "widths": self.widths, "residency": self.residency},
        )


def tile_working_set_bits(tile_vertices, *, V, widths, sigma,
                          residency: str = "spill", halo_dedup=1.0):
    """Closed-form on-chip working set (bits) of one tile pass (§15).

    The SRAM a configuration must hold to run one tile of the schedule
    (the tuner's feasibility model; broadcasting like every other closed
    form, so a capacity array sweeps in one call):

    * **weights** — ``sigma * sum_l widths[l] * widths[l+1]``: every
      layer's dense weight matrix is resident for the whole pass.
    * **activations** — per-vertex features for the tile's ``K =
      ceil(V / ceil(V / tile_vertices))`` vertices.  ``"spill"`` holds
      one layer's input and output at a time, so the peak is
      ``K * max_l (widths[l] + widths[l+1])``; ``"resident"`` keeps every
      interior activation on-array: ``K * sum(widths)``.
    * **halo-dedup cache** — ``halo_dedup > 1`` presumes a cache holding
      reused remote source features within a tile pass; it is charged
      ``K * widths[0] * (1 - 1/halo_dedup)`` (the fraction of halo
      traffic the divisor claims to serve from on-chip).

    ``K`` uses the same balanced-partition geometry as
    :meth:`TiledGraphModel.tile_schedule` and
    ``GraphTrace._geometry``, so feasibility and movement agree on what
    a "tile" is.
    """
    if residency not in RESIDENCY_POLICIES:
        raise ValueError(f"unknown residency {residency!r}; "
                         f"expected one of {RESIDENCY_POLICIES}")
    w = [_f64(x) for x in widths]
    if len(w) < 2:
        raise ValueError(f"need >= 2 widths (got {list(widths)}): "
                         "a layer maps widths[l] -> widths[l+1]")
    tv = _f64(tile_vertices)
    if not np.all(np.isfinite(tv)) or np.any(tv < 1):
        raise ValueError(f"tile_vertices must be >= 1, got {tile_vertices!r}")
    hd = _f64(halo_dedup)
    if not np.all(np.isfinite(hd)) or np.any(hd < 1.0):
        raise ValueError(f"halo_dedup must be finite and >= 1, "
                         f"got {halo_dedup!r}")
    Vv = _f64(V)
    n_tiles = np.maximum(ceil(Vv / tv), 1.0)
    K = ceil(Vv / n_tiles)
    weight_elems = _f64(0.0)
    for l in range(len(w) - 1):
        weight_elems = weight_elems + w[l] * w[l + 1]
    if residency == "resident":
        act_elems = _f64(0.0)
        for wl in w:
            act_elems = act_elems + wl
    else:
        act_elems = w[0] + w[1]
        for l in range(1, len(w) - 1):
            act_elems = np.maximum(act_elems, w[l] + w[l + 1])
    halo_elems = w[0] * (1.0 - 1.0 / hd)
    return _f64(sigma) * (weight_elems + K * (act_elems + halo_elems))


@dataclass(frozen=True)
class FullGraphParams:
    """A whole (untiled) graph plus the layer-level feature widths.

    Attributes:
      V: total vertex count.
      E: total edge count.
      N: input feature width (elements per vertex).
      T: output feature width.  For a MultiLayerModel inner model, N/T are
         superseded by its ``widths``.
      high_degree_fraction: fraction of each tile's vertices served by a
         dedicated degree-aware cache (EnGN's L; same L = K/10 default as
         :func:`repro.core.notation.paper_default_graph`).
    """

    V: ParamArray
    E: ParamArray
    N: ParamArray
    T: ParamArray
    high_degree_fraction: ParamArray = 0.1

    def __post_init__(self) -> None:
        for field in ("V", "E", "N", "T", "high_degree_fraction"):
            val = _f64(getattr(self, field))
            if not np.all(np.isfinite(val)):
                raise ValueError(f"FullGraphParams.{field} must be finite, "
                                 f"got {getattr(self, field)!r}")
            if np.any(val < 0):
                raise ValueError(
                    f"FullGraphParams.{field} must be non-negative "
                    f"(got {getattr(self, field)!r}); a negative value "
                    "would silently produce negative movement totals")
        hdf = _f64(self.high_degree_fraction)
        if np.any(hdf > 1.0):
            raise ValueError(
                f"FullGraphParams.high_degree_fraction is a fraction of the "
                f"tile's vertices and must be <= 1 "
                f"(got {self.high_degree_fraction!r})")

    def replace(self, **kw) -> "FullGraphParams":
        # dataclasses.replace re-runs __post_init__, so replaced values are
        # validated exactly like constructor arguments.
        return dataclasses.replace(self, **kw)


class TiledGraphModel:
    """Sum a per-tile model over the tile schedule of a full graph.

    The default (uniform) schedule slices V vertices into ``n_tiles =
    ceil(V / tile_vertices)`` balanced tiles of ``K = ceil(V / n_tiles)``
    vertices and ``P = ceil(E / n_tiles)`` intra-tile edges (the paper's
    uniform-tile assumption).  On top of ``n_tiles x`` the per-tile
    movement, an inter-tile ``haloreload`` L2-L1 term charges re-fetching
    remote source features for cut edges: with a random balanced partition
    the expected cut fraction is ``1 - 1/n_tiles``, and ``halo_dedup >= 1``
    (scalar or array) divides it for duplicate sources cached within a
    tile pass.

    Passing ``trace`` (a :class:`~repro.core.trace.GraphTrace`) replaces
    both approximations with the edge list's exact schedule (DESIGN.md
    §12): each tile is evaluated at its own exact ``(K_t, L_t, P_t)`` in
    one broadcast call over a trailing tile axis, and ``haloreload``
    charges the exact per-tile **unique**-remote-source counts, so
    ``halo_dedup`` must stay 1 (the dedup is measured, not estimated).
    With a trace, ``tile_vertices`` may be a scalar (one schedule, tile
    axis trailing) or a 1-D array of capacities — the **capacity axis**
    (DESIGN.md §13): entry ``b`` evaluates the exact schedule of capacity
    ``tile_vertices[b]``, all schedules amortized over one shared
    edge-list factorization, with the per-capacity tile axes padded to a
    common length, masked, and reduced chunk-by-chunk with the same
    pairwise tree — bit-identical to evaluating each capacity alone.
    Other array leaves must broadcast against the capacity axis (the
    scenario planner stacks batches exactly that way).
    """

    def __init__(self, inner, *, tile_vertices: ParamArray = 1024,
                 halo_dedup: ParamArray = 1.0,
                 trace: GraphTrace | None = None,
                 schedule: TraceSchedule | None = None) -> None:
        if isinstance(inner, MultiLayerModel):
            self.inner = inner
        else:
            spec = _resolve_spec(inner)
            self.inner = SpecModel(spec)
        if schedule is not None:
            # Explicit-schedule mode (the sampled-minibatch episode path):
            # each schedule "tile" is one measured episode, so the
            # capacity knob is meaningless and taken from the schedule.
            if trace is not None:
                raise ValueError("pass either trace or schedule, not both: "
                                 "an explicit schedule already carries its "
                                 "exact per-tile counts")
            if not isinstance(schedule, TraceSchedule):
                raise TypeError(f"schedule must be a TraceSchedule, "
                                f"got {type(schedule).__name__}")
            tile_vertices = schedule.capacity
        tv = _f64(tile_vertices)
        if not np.all(np.isfinite(tv)) or np.any(tv < 1):
            raise ValueError(
                f"tile_vertices must be >= 1 (got {tile_vertices!r}): a tile "
                "holds at least one vertex, and zero/negative capacities "
                "silently produce nonsense schedules")
        self.tile_vertices = tile_vertices
        hd = _f64(halo_dedup)
        if not np.all(np.isfinite(hd)) or np.any(hd < 1.0):
            raise ValueError(
                f"halo_dedup must be finite and >= 1 (it divides halo "
                f"traffic), got {halo_dedup!r}")
        self.halo_dedup = hd
        if trace is not None:
            if not isinstance(trace, GraphTrace):
                raise TypeError(f"trace must be a GraphTrace, "
                                f"got {type(trace).__name__}")
            if tv.ndim > 1:
                raise ValueError(
                    "tile capacities with a trace must be a scalar or a "
                    "1-D array (one capacity per batch member): the "
                    "capacity axis becomes the leading batch axis of the "
                    "evaluation (DESIGN.md §13)")
        if (trace is not None or schedule is not None) and np.any(hd != 1.0):
            raise ValueError(
                "halo_dedup must be 1 with a trace or an explicit "
                "schedule: the exact schedule already deduplicates remote "
                "sources per tile (unique-source halo counts), so an "
                "extra divisor would double-count the dedup")
        self.trace = trace
        self.schedule = schedule
        inner_name = getattr(self.inner, "name", type(self.inner).__name__)
        kind = ("episode" if schedule is not None
                else "trace" if trace is not None else "tiled")
        self.name = f"{inner_name}_{kind}"

    def resolve_hw(self, hw=None):
        return self.inner.spec.resolve_hw(hw)

    def tile_schedule(self, full: FullGraphParams) -> tuple[np.ndarray, GraphTileParams]:
        """(n_tiles, per-tile GraphTileParams) for the full graph."""
        V, E = _f64(full.V), _f64(full.E)
        n_tiles = np.maximum(ceil(V / _f64(self.tile_vertices)), 1.0)
        K = ceil(V / n_tiles)
        return n_tiles, GraphTileParams(
            N=_f64(full.N),
            T=_f64(full.T),
            K=K,
            L=np.floor(K * full.high_degree_fraction),
            P=ceil(E / n_tiles),
        )

    def _halo_width(self) -> np.ndarray:
        if isinstance(self.inner, MultiLayerModel):
            return self.inner.halo_feature_elems()
        return None  # use the full graph's N

    # -- exact (trace-driven) schedule ------------------------------------
    def _promoted_inner(self):
        """Inner model with every numeric leaf given a trailing singleton
        axis, so batch/sweep axes broadcast against the tile axis."""
        if isinstance(self.inner, MultiLayerModel):
            widths = tuple(_f64(w)[..., None] for w in self.inner.widths)
            return MultiLayerModel(self.inner.spec, widths,
                                   residency=self.inner.residency)
        return self.inner

    @staticmethod
    def _promoted_hw(hw):
        """Hardware record with a trailing singleton axis on every field."""
        kw = {f.name: _f64(getattr(hw, f.name))[..., None]
              for f in dataclasses.fields(hw)
              if getattr(hw, f.name) is not None}
        return hw.replace(**kw)

    def _evaluate_trace_multi(self, full: FullGraphParams, hw) -> ModelOutput:
        """Capacity-axis evaluation: one batched call over B capacities.

        Every capacity's exact schedule comes from the trace's shared
        sorted-edge factorization (one sort for the whole sweep); the
        per-capacity tile axes are right-padded to the longest, masked
        (padded tiles contribute exactly 0.0), and reduced in
        power-of-two chunks with the same pairwise tree — so row ``b``
        is bit-identical to a scalar-capacity evaluation at
        ``tile_vertices[b]`` (pinned in tests, DESIGN.md §13).
        """
        tr = self.trace
        caps = np.asarray(self.tile_vertices)
        scheds = tr.schedules([c for c in caps.tolist()])
        B = len(scheds)
        M = max(s.n_tiles for s in scheds)
        K_pad = np.zeros((B, M), dtype=np.float64)
        P_pad = np.zeros((B, M), dtype=np.float64)
        mask = np.zeros((B, M), dtype=np.float64)
        for b, s in enumerate(scheds):
            m = s.n_tiles
            K_pad[b, :m] = s.vertex_counts
            P_pad[b, :m] = s.edge_counts
            mask[b, :m] = 1.0
        N = _f64(full.N)[..., None]
        T = _f64(full.T)[..., None]
        hdf = _f64(full.high_degree_fraction)[..., None]
        inner = self._promoted_inner()
        phw = self._promoted_hw(hw)
        order: list[tuple[str, str]] = []
        partial_bits: dict[tuple[str, str], list] = {}
        partial_iters: dict[tuple[str, str], list] = {}
        for start in range(0, M, TRACE_TILE_CHUNK):
            sl = slice(start, start + TRACE_TILE_CHUNK)
            K_c = K_pad[:, sl]
            tile_c = GraphTileParams(N=N, T=T, K=K_c,
                                     L=np.floor(K_c * hdf), P=P_pad[:, sl])
            out_c = inner.evaluate(tile_c, phw)
            m_c = mask[:, sl]
            for t in out_c.terms:
                key = (t.name, t.hierarchy)
                if key not in partial_bits:
                    order.append(key)
                    partial_bits[key] = []
                    partial_iters[key] = []
                # The mask multiply zeroes padded tiles exactly (the
                # closed forms never divide by a graph field, so padded
                # values are finite) and is the identity on real tiles.
                partial_bits[key].append(
                    _pairwise_sum(_f64(t.data_bits) * m_c))
                partial_iters[key].append(
                    _pairwise_sum(_f64(t.iterations) * m_c))
        terms = [
            MovementTerm(name, hier,
                         _pairwise_sum(np.stack(partial_bits[(name, hier)],
                                                axis=-1)),
                         _pairwise_sum(np.stack(partial_iters[(name, hier)],
                                                axis=-1)))
            for name, hier in order]
        width = self._halo_width()
        if width is None:
            width = _f64(full.N)
        halo_totals = _f64([s.halo_total for s in scheds])
        halo_bits = halo_totals * width * _f64(hw.sigma)
        halo_iters = ceil(halo_bits / _f64(hw.B))
        terms.append(MovementTerm("haloreload", "L2-L1", halo_bits, halo_iters))
        return ModelOutput(
            accelerator=self.name,
            terms=tuple(terms),
            meta={"hw": hw, "graph": full,
                  "n_tiles": _f64([s.n_tiles for s in scheds]),
                  "schedules": scheds, "inner": self.inner, "trace": tr},
        )

    def _evaluate_trace(self, full: FullGraphParams, hw) -> ModelOutput:
        hw = self.resolve_hw(hw)
        tr = self.trace
        if np.any(_f64(full.V) != tr.n_nodes) or np.any(_f64(full.E) != tr.n_edges):
            raise ValueError(
                f"FullGraphParams (V={full.V!r}, E={full.E!r}) does not "
                f"match the trace (V={tr.n_nodes}, E={tr.n_edges}); a trace "
                "schedule is exact, so the declared graph must be the "
                "traced graph")
        if np.asarray(self.tile_vertices).ndim == 1:
            return self._evaluate_trace_multi(full, hw)
        sched = tr.schedule(self.tile_vertices)
        return self._evaluate_one_schedule(full, hw, sched,
                                           {"trace": tr})

    def _evaluate_schedule(self, full: FullGraphParams, hw) -> ModelOutput:
        """Explicit-schedule (episode) mode: the given schedule's tiles are
        measured episodes (seed batch + sampled subgraph), its halo counts
        the unique gathered non-seed sources — neighbor-sampling gather
        traffic charged exactly like the trace path's halo reload."""
        hw = self.resolve_hw(hw)
        sched = self.schedule
        if np.any(_f64(full.E) != _f64(sched.n_edges)):
            raise ValueError(
                f"FullGraphParams.E={full.E!r} does not match the explicit "
                f"schedule's total edge count {sched.n_edges}; an episode "
                "schedule is exact, so the declared edge total must be the "
                "measured one")
        return self._evaluate_one_schedule(full, hw, sched, {})

    def _evaluate_one_schedule(self, full: FullGraphParams, hw,
                               sched: TraceSchedule,
                               meta_extra: dict) -> ModelOutput:
        m = sched.n_tiles
        # Tile axis is the LAST axis: every non-tile numeric leaf gets a
        # trailing singleton so sweeps/batches broadcast against it.
        K_t = _f64(sched.vertex_counts)
        hdf = _f64(full.high_degree_fraction)[..., None]
        tile = GraphTileParams(
            N=_f64(full.N)[..., None],
            T=_f64(full.T)[..., None],
            K=K_t,
            L=np.floor(K_t * hdf),
            P=_f64(sched.edge_counts),
        )
        per_tile = self._promoted_inner().evaluate(tile, self._promoted_hw(hw))
        # Pairwise tile-axis reduction: bit-identical to the uniform path's
        # `n_tiles * per_tile` product when all tiles are equal and n_tiles
        # is a power of two (the ring bit-match invariant, DESIGN.md §12).
        def collapse(x):
            a = _f64(x)
            return _pairwise_sum(np.broadcast_to(
                a, np.broadcast_shapes(a.shape, (m,))))

        terms = [MovementTerm(t.name, t.hierarchy,
                              collapse(t.data_bits), collapse(t.iterations))
                 for t in per_tile.terms]
        width = self._halo_width()
        if width is None:
            width = _f64(full.N)
        halo_bits = _f64(sched.halo_total) * width * _f64(hw.sigma)
        halo_iters = ceil(halo_bits / _f64(hw.B))
        terms.append(MovementTerm("haloreload", "L2-L1", halo_bits, halo_iters))
        return ModelOutput(
            accelerator=self.name,
            terms=tuple(terms),
            meta={"hw": hw, "graph": full, "n_tiles": float(m), "tile": tile,
                  "inner": self.inner, "schedule": sched, **meta_extra},
        )

    def evaluate(self, full: FullGraphParams, hw=None) -> ModelOutput:
        if self.schedule is not None:
            return self._evaluate_schedule(full, hw)
        if self.trace is not None:
            return self._evaluate_trace(full, hw)
        hw = self.resolve_hw(hw)
        n_tiles, tile = self.tile_schedule(full)
        per_tile = self.inner.evaluate(tile, hw)
        terms = list(per_tile.scaled(n_tiles).terms)
        width = self._halo_width()
        if width is None:
            width = _f64(full.N)
        cut_edges = _f64(full.E) * (1.0 - 1.0 / n_tiles)
        halo_bits = cut_edges * width * _f64(hw.sigma) / self.halo_dedup
        halo_iters = ceil(halo_bits / _f64(hw.B))
        terms.append(MovementTerm("haloreload", "L2-L1", halo_bits, halo_iters))
        return ModelOutput(
            accelerator=self.name,
            terms=tuple(terms),
            meta={"hw": hw, "graph": full, "n_tiles": n_tiles,
                  "tile": tile, "inner": self.inner},
        )


def _normalize_residency(residency, n_relations: int):
    """-> (uniform policy or None, per-relation resident mask or None).

    A plain policy string applies to every relation (``mask=None``); a
    length-R sequence of policies collapses back to the uniform case when
    homogeneous, else yields an exact ``{0.0, 1.0}`` resident mask of
    shape ``(R, 1)`` (trailing tile axis) for the masked evaluation.
    """
    if isinstance(residency, str):
        if residency not in RESIDENCY_POLICIES:
            raise ValueError(f"unknown residency {residency!r}; "
                             f"expected one of {RESIDENCY_POLICIES}")
        return residency, None
    res = tuple(residency)
    if len(res) != n_relations:
        raise ValueError(
            f"per-relation residency needs one policy per relation "
            f"(R={n_relations}), got {len(res)}")
    for p in res:
        if p not in RESIDENCY_POLICIES:
            raise ValueError(f"unknown residency {p!r}; "
                             f"expected one of {RESIDENCY_POLICIES}")
    if len(set(res)) == 1:
        return res[0], None
    mask = np.asarray([1.0 if p == "resident" else 0.0 for p in res],
                      dtype=np.float64)[:, None]
    return None, mask


class RelationalGraphModel:
    """Evaluate one dataflow over every relation of a typed graph at once.

    The relational (RGCN-style) generalization of the trace path: a
    :class:`~repro.core.trace.TypedGraphTrace` supplies one exact
    schedule per ``(capacity, relation)`` — all carved from a single
    shared sort — and the inner dataflow's closed forms evaluate **once**
    over axes ``(capacity B, relation R, tile M)``.  Per-relation feature
    widths ride the relation axis (each relation r has its own weight
    matrices ``widths[l][r] x widths[l+1][r]``, the per-relation
    weight-load traffic of graphstorm's ``RelGraphConvEncoder``), padded
    tiles are masked with the same exact-``{0.0, 1.0}`` multiply rules as
    the tile axis, and the relation axis reduces with the same pairwise
    tree — so totals are **bit-identical** to an R-loop of homogeneous
    :class:`TiledGraphModel` evaluations whose per-term outputs are
    stacked and pairwise-reduced (the ``tests/test_hetero.py`` gate).

    ``residency`` may be one policy or a length-R sequence (the tuner's
    per-relation residency axis): mixed assignments evaluate interior
    ``vertex_out``/``vertex_in`` terms masked by an exact ``{0, 1}``
    spill mask and charge ``residenthandoff`` under the complementary
    mask, keeping every kept value bit-identical to its homogeneous
    counterpart.

    Evaluation always carries the capacity axis: scalar ``tile_vertices``
    yields shape-(1,) totals.
    """

    def __init__(self, dataflow, *, tile_vertices: ParamArray,
                 trace: TypedGraphTrace, widths=None,
                 residency="spill") -> None:
        self.spec = _resolve_spec(dataflow)
        if not isinstance(trace, TypedGraphTrace):
            raise TypeError(f"trace must be a TypedGraphTrace, "
                            f"got {type(trace).__name__}")
        self.trace = trace
        tv = _f64(tile_vertices)
        if tv.ndim > 1:
            raise ValueError(
                "tile_vertices must be a scalar or a 1-D capacity array "
                "(the leading batch axis of the evaluation)")
        if not np.all(np.isfinite(tv)) or np.any(tv < 1):
            raise ValueError(f"tile_vertices must be >= 1, "
                             f"got {tile_vertices!r}")
        self.tile_vertices = tile_vertices
        if widths is not None:
            widths = tuple(widths)
            if len(widths) < 2:
                raise ValueError(f"need >= 2 widths (got {len(widths)}): "
                                 "a layer maps widths[l] -> widths[l+1]")
        self.widths = widths
        uniform, mask = _normalize_residency(residency, trace.n_relations)
        if widths is None and not (uniform == "spill" and mask is None):
            raise ValueError(
                "residency other than uniform 'spill' needs layer widths: "
                "activation residency is an inter-layer property")
        self.residency = residency
        self._uniform_residency = uniform
        self._res_mask = mask
        self.name = f"{self.spec.name}_relational"

    @property
    def n_relations(self) -> int:
        return self.trace.n_relations

    def resolve_hw(self, hw=None):
        return self.spec.resolve_hw(hw)

    def halo_feature_elems(self):
        """Per-relation halo width: per-vertex elements fetched across
        tile boundaries over all layers (shape follows the widths)."""
        if self.widths is None:
            return None
        return _f64(sum(_f64(w) for w in self.widths[:-1]))

    def _layer_terms(self, tile: GraphTileParams, hw, acc) -> None:
        """Inner-dataflow terms over one (B, R, tile-chunk) block.

        Mirrors :class:`MultiLayerModel` exactly, plus the mixed
        per-relation residency mask: interior vertex terms are kept
        (x1.0) for spill relations and dropped (x0.0) for resident ones,
        and ``residenthandoff`` is charged under the complementary mask —
        both multiplies are exact, so each relation row stays
        bit-identical to its homogeneous evaluation.
        """
        if self.widths is None:
            for m in self.spec.movements:
                bits, iters = m.form(tile, hw)
                acc.add(m.name, m.hierarchy, bits, iters)
            return
        W = [_f64(w)[..., None] for w in self.widths]
        L = len(W) - 1
        mask = self._res_mask
        keep = None if mask is None else (1.0 - mask)
        for l in range(L):
            g_l = tile.replace(N=W[l], T=W[l + 1])
            for m in self.spec.movements:
                interior = m.interior_at(l, L)
                if interior and self._uniform_residency == "resident":
                    continue
                bits, iters = m.form(g_l, hw)
                if interior and keep is not None:
                    bits = _f64(bits) * keep
                    iters = _f64(iters) * keep
                acc.add(m.name, m.hierarchy, bits, iters)
        if self._uniform_residency == "resident" or mask is not None:
            K = _f64(tile.K)
            s = _f64(hw.sigma)
            gain = 1.0 if mask is None else mask
            for l in range(L - 1):
                acc.add("residenthandoff", "L1-L1",
                        K * W[l + 1] * s * gain, np.ones_like(K) * gain)

    def evaluate(self, full: FullGraphParams, hw=None) -> ModelOutput:
        hw = self.resolve_hw(hw)
        tr = self.trace
        if (np.any(_f64(full.V) != tr.n_nodes)
                or np.any(_f64(full.E) != tr.n_edges)):
            raise ValueError(
                f"FullGraphParams (V={full.V!r}, E={full.E!r}) does not "
                f"match the typed trace (V={tr.n_nodes}, E={tr.n_edges}); "
                "E counts edges across ALL relations")
        R = tr.n_relations
        caps = np.atleast_1d(np.asarray(self.tile_vertices)).tolist()
        B = len(caps)
        # One shared typed sort; per relation, the multi-capacity schedules
        # amortize over that relation's sliced factorization.
        rel_scheds = [tr.relation(r).schedules(caps) for r in range(R)]
        M = max(s.n_tiles for s in rel_scheds[0])
        # Partition geometry is relation-independent (same vertex set), so
        # the vertex counts ride a broadcast (B, 1, M) axis.
        K_pad = np.zeros((B, 1, M), dtype=np.float64)
        P_pad = np.zeros((B, R, M), dtype=np.float64)
        mask = np.zeros((B, 1, M), dtype=np.float64)
        for b in range(B):
            m = rel_scheds[0][b].n_tiles
            K_pad[b, 0, :m] = rel_scheds[0][b].vertex_counts
            mask[b, 0, :m] = 1.0
            for r in range(R):
                P_pad[b, r, :m] = rel_scheds[r][b].edge_counts
        # Relation-carrying graph fields broadcast with ONE trailing (tile)
        # axis; per-scenario scalars (hdf, hw) get TWO (relation + tile).
        N = _f64(full.N)[..., None]
        T = _f64(full.T)[..., None]
        hdf = _f64(full.high_degree_fraction)[..., None, None]
        phw_kw = {f.name: _f64(getattr(hw, f.name))[..., None, None]
                  for f in dataclasses.fields(hw)
                  if getattr(hw, f.name) is not None}
        phw = hw.replace(**phw_kw)
        order: list[tuple[str, str]] = []
        partial_bits: dict[tuple[str, str], list] = {}
        partial_iters: dict[tuple[str, str], list] = {}
        for start in range(0, M, TRACE_TILE_CHUNK):
            sl = slice(start, start + TRACE_TILE_CHUNK)
            K_c = K_pad[:, :, sl]
            tile_c = GraphTileParams(N=N, T=T, K=K_c,
                                     L=np.floor(K_c * hdf),
                                     P=P_pad[:, :, sl])
            acc = _TermAccumulator()
            self._layer_terms(tile_c, phw, acc)
            m_c = mask[:, :, sl]
            for t in acc.terms():
                key = (t.name, t.hierarchy)
                if key not in partial_bits:
                    order.append(key)
                    partial_bits[key] = []
                    partial_iters[key] = []
                partial_bits[key].append(
                    _pairwise_sum(_f64(t.data_bits) * m_c))
                partial_iters[key].append(
                    _pairwise_sum(_f64(t.iterations) * m_c))

        def collapse_rel(x):
            # Reduce the relation axis with the same pairwise tree the
            # R-loop comparison uses; terms that never picked up the R
            # axis (e.g. geometry-only iteration counts) broadcast to it
            # first, so they are charged once per relation.
            a = _f64(x)
            return _pairwise_sum(np.broadcast_to(
                a, np.broadcast_shapes(a.shape, (R,))))

        terms = []
        for name, hier in order:
            bits = _pairwise_sum(np.stack(partial_bits[(name, hier)],
                                          axis=-1))
            iters = _pairwise_sum(np.stack(partial_iters[(name, hier)],
                                           axis=-1))
            terms.append(MovementTerm(name, hier, collapse_rel(bits),
                                      collapse_rel(iters)))
        width = self.halo_feature_elems()
        if width is None:
            width = _f64(full.N)
        halo_totals = _f64([[rel_scheds[r][b].halo_total for r in range(R)]
                            for b in range(B)])
        sigma = _f64(hw.sigma)[..., None]
        bw = _f64(hw.B)[..., None]
        halo_bits = halo_totals * width * sigma
        halo_iters = ceil(halo_bits / bw)
        terms.append(MovementTerm("haloreload", "L2-L1",
                                  collapse_rel(halo_bits),
                                  collapse_rel(halo_iters)))
        return ModelOutput(
            accelerator=self.name,
            terms=tuple(terms),
            meta={"hw": hw, "graph": full, "trace": tr,
                  "n_relations": R,
                  "n_tiles": _f64([s.n_tiles for s in rel_scheds[0]]),
                  "relation_schedules": tuple(tuple(s) for s in rel_scheds),
                  "widths": self.widths, "residency": self.residency},
        )


# ---------------------------------------------------------------------------
# Auditable closed forms of the composition-layer terms (DESIGN.md §17).
#
# The relational / episode evaluations above charge three terms that no
# registered MovementSpec owns: the exact halo reload, the resident
# inter-layer hand-off, and the minibatch gather.  Each is restated here
# as a per-tile closed form over a declared parameter record
# (notation.RelationalScheduleParams x notation.CompositionHardwareParams)
# so `python -m repro.analysis` traces them like Table III/IV movements —
# units must reduce to bits^1 / bits^0, provenance must carry the `R`
# relation symbol, and the 2^53 interval propagates the R multiplicity.
# Value-parity with the array path is pinned in tests/test_hetero.py.
# ---------------------------------------------------------------------------

def _relational_halo_form(graph, hw):
    """R relations x (unique remote sources x halo width x sigma) bits."""
    per_relation = graph.H * graph.W * hw.sigma
    return graph.R * per_relation, graph.R * ceil(per_relation / hw.B)


def _relational_handoff_form(graph, hw):
    """Resident inter-layer hand-off: K x width x sigma bits per relation,
    one on-array iteration per (relation, tile, layer boundary)."""
    per_relation = graph.K * graph.W * hw.sigma
    return graph.R * per_relation, graph.R


def _minibatch_gather_form(graph, hw):
    """One episode's neighbor-sampling gather: unique non-seed sources
    fetched at the halo feature width (R=1 for homogeneous sampling)."""
    bits = graph.H * graph.W * hw.sigma
    return bits, ceil(bits / hw.B)


#: (name, form) pairs the analysis auditor traces alongside the registry
#: dataflows (see repro.analysis.audit.audit_composition_forms).
COMPOSITION_FORMS = (
    ("relationalhalo", _relational_halo_form),
    ("relationalhandoff", _relational_handoff_form),
    ("minibatchgather", _minibatch_gather_form),
)
