"""Analytical-vs-compiled validation — closing the loop the paper left open.

The paper (Sec. III): "Validation of the data movement models is difficult
as the authors of both accelerators ... do not explicitly study data
movement.  Moreover, their simulation tools are in-house and not open
source."  Our TPU adaptation has no such excuse: the XLA-compiled SPMD
program is the ground truth.  This module pairs each analytical traffic
model from :mod:`repro.core.tpu_model` with the measured collective bytes
from :mod:`repro.core.hlo_analysis` and reports the ratio.

Caveat recorded here and asserted in tests: the HLO parser performs STATIC
accounting — a collective inside a ``while``/``scan`` body is counted once,
not per iteration.  Models for loop-scheduled collectives (ring SpMM hops,
per-layer scans) therefore multiply by the trip count on the analytical
side and divide on comparison, or validate against unrolled programs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from .hlo_analysis import CollectiveStats, parse_collectives
from .tpu_model import CommModel

__all__ = [
    "ValidationRecord",
    "validate_traffic",
    "measured_collective_bytes",
    "SEC4_GOLDEN_TOTALS",
    "validate_dataflow_golden",
    "crosscheck_registry",
]

#: Pinned (total_bits, total_iterations) at the paper's Sec. IV defaults
#: (N=30, T=5, K=1024, L=102, P=10240, B=1000, sigma=4).  engn/hygcn were
#: captured from the seed row-function implementation before the DataflowSpec
#: refactor; the extension dataflows are pinned at their conformance-validated
#: closed forms (Bn=Bk=256 kernel blocks, DESIGN.md §10).  Any
#: registry-evaluated drift from these is a modelling regression, not an
#: interpretation change (DESIGN.md §8).
SEC4_GOLDEN_TOTALS: dict[str, tuple[float, float]] = {
    "engn": (2800200.0, 68.0),
    "hygcn": (2889460.0, 6248.0),
    "spmm_tiled": (5833304.0, 4749.0),
    "spmm_unfused": (6079064.0, 4997.0),
    "awb_gcn": (615680.0, 202.0),
}


@dataclass(frozen=True)
class ValidationRecord:
    name: str
    analytical_bytes: float
    measured_bytes: float

    @property
    def ratio(self) -> float:
        if self.measured_bytes == 0:
            return float("inf") if self.analytical_bytes else 1.0
        return self.analytical_bytes / self.measured_bytes

    def within(self, rel: float) -> bool:
        return abs(self.ratio - 1.0) <= rel

    def __str__(self) -> str:  # pragma: no cover - repr
        return (f"{self.name}: analytical={self.analytical_bytes:.4g}B "
                f"measured={self.measured_bytes:.4g}B ratio={self.ratio:.3f}")


def measured_collective_bytes(compiled) -> CollectiveStats:
    """Collective stats of a jax ``Compiled`` object."""
    return parse_collectives(compiled.as_text())


def validate_traffic(name: str, model: CommModel, compiled, *,
                     static_trip_count: int = 1) -> ValidationRecord:
    """Compare a CommModel's per-chip ICI bytes with the compiled program.

    ``static_trip_count`` divides the analytical total when the runtime
    schedule emits the collective once inside a loop of that many trips.
    """
    stats = measured_collective_bytes(compiled)
    return ValidationRecord(
        name=name,
        analytical_bytes=model.total("ici") / max(static_trip_count, 1),
        measured_bytes=stats.total_wire_bytes_per_chip,
    )


def validate_dataflow_golden(name: str) -> ValidationRecord:
    """Registry-evaluated total vs the seed golden value at Sec. IV defaults.

    The refactored DataflowSpec engine must be *bit-identical* to the seed
    row-function implementation, so a passing record has ratio exactly 1.0.
    """
    from . import registry
    from .notation import paper_default_graph

    if name not in SEC4_GOLDEN_TOTALS:
        raise KeyError(f"no golden totals recorded for {name!r}; "
                       f"have: {sorted(SEC4_GOLDEN_TOTALS)}")
    out = registry.evaluate(name, paper_default_graph())
    return ValidationRecord(
        name=f"{name}_sec4_golden",
        analytical_bytes=float(out.total_bits()),
        measured_bytes=SEC4_GOLDEN_TOTALS[name][0],
    )


def crosscheck_registry(graph=None, *, conformance: bool = False,
                        conformance_points=None, analysis: bool = False
                        ) -> dict[str, "ValidationRecord | None"]:
    """Structural sanity over every registered dataflow at one operating point.

    Evaluates each spec (finite, non-negative bits/iterations are asserted)
    and returns a golden-comparison record where one exists, else None.

    With ``conformance=True``, every dataflow declaring a runnable kernel
    analogue is additionally compiled and measured (:mod:`repro.core.
    conformance`, DESIGN.md §10) at ``conformance_points`` (default: one
    small point, so the crosscheck stays cheap).  A failing conformance
    record raises; passing ones are summarized under ``"<name>::conformance"``
    keys as analytical-vs-measured HBM-byte totals.

    With ``analysis=True``, every spec is additionally run through the
    static model auditor (:mod:`repro.analysis`, DESIGN.md §16): symbolic
    unit reduction, dead-hardware-parameter detection, and golden pinning.
    A strict audit error raises; each passing :class:`~repro.analysis.
    SpecAudit` is stored under ``"<name>::analysis"``.  Audits are cached
    by spec value, so a spec swapped in via ``registry.temporarily_
    registered`` is re-audited rather than served a stale result.
    """
    import numpy as np

    from . import registry
    from .notation import paper_default_graph

    g = graph if graph is not None else paper_default_graph()
    records: dict[str, ValidationRecord | None] = {}
    for name in registry.names():
        out = registry.evaluate(name, g)
        for t in out.terms:
            if not (np.all(np.isfinite(t.data_bits))
                    and np.all(np.isfinite(t.iterations))):
                raise AssertionError(f"{name}.{t.name}: non-finite movement")
            if np.any(t.data_bits < 0) or np.any(t.iterations < 0):
                raise AssertionError(f"{name}.{t.name}: negative movement")
        records[name] = (validate_dataflow_golden(name)
                        if name in SEC4_GOLDEN_TOTALS else None)
    if conformance:
        from .conformance import OperatingPoint, conformance_records

        points = (conformance_points if conformance_points is not None
                  else (OperatingPoint(256, 16, 8, 128, 128),))
        for name in registry.runnable_names():
            spec = registry.get(name)
            analogue = spec.runnable_analogue()
            analytical = measured = 0.0
            for pt in points:
                for rec in conformance_records(spec, pt, analogue=analogue):
                    if not rec.ok:
                        raise AssertionError(f"conformance failure: {rec}")
                    if rec.movement == "hbm_total":
                        analytical += rec.analytical_bytes
                        measured += rec.measured_bytes
            records[f"{name}::conformance"] = ValidationRecord(
                name=f"{name}_conformance_hbm",
                analytical_bytes=analytical, measured_bytes=measured)
    if analysis:
        from repro.analysis import audit_spec

        for name in registry.names():
            audit = audit_spec(registry.get(name))
            errors = audit.strict_errors()
            if errors:
                raise AssertionError(
                    f"model audit failure for {name}: " + "; ".join(errors))
            records[f"{name}::analysis"] = audit
    return records
