"""Analytical-vs-compiled validation — closing the loop the paper left open.

The paper (Sec. III): "Validation of the data movement models is difficult
as the authors of both accelerators ... do not explicitly study data
movement.  Moreover, their simulation tools are in-house and not open
source."  Our TPU adaptation has no such excuse: the XLA-compiled SPMD
program is the ground truth.  This module pairs each analytical traffic
model from :mod:`repro.core.tpu_model` with the measured collective bytes
from :mod:`repro.core.hlo_analysis` and reports the ratio.

Caveat recorded here and asserted in tests: the HLO parser performs STATIC
accounting — a collective inside a ``while``/``scan`` body is counted once,
not per iteration.  Models for loop-scheduled collectives (ring SpMM hops,
per-layer scans) therefore multiply by the trip count on the analytical
side and divide on comparison, or validate against unrolled programs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from .hlo_analysis import CollectiveStats, parse_collectives
from .tpu_model import CommModel

__all__ = ["ValidationRecord", "validate_traffic", "measured_collective_bytes"]


@dataclass(frozen=True)
class ValidationRecord:
    name: str
    analytical_bytes: float
    measured_bytes: float

    @property
    def ratio(self) -> float:
        if self.measured_bytes == 0:
            return float("inf") if self.analytical_bytes else 1.0
        return self.analytical_bytes / self.measured_bytes

    def within(self, rel: float) -> bool:
        return abs(self.ratio - 1.0) <= rel

    def __str__(self) -> str:  # pragma: no cover - repr
        return (f"{self.name}: analytical={self.analytical_bytes:.4g}B "
                f"measured={self.measured_bytes:.4g}B ratio={self.ratio:.3f}")


def measured_collective_bytes(compiled) -> CollectiveStats:
    """Collective stats of a jax ``Compiled`` object."""
    return parse_collectives(compiled.as_text())


def validate_traffic(name: str, model: CommModel, compiled, *,
                     static_trip_count: int = 1) -> ValidationRecord:
    """Compare a CommModel's per-chip ICI bytes with the compiled program.

    ``static_trip_count`` divides the analytical total when the runtime
    schedule emits the collective once inside a loop of that many trips.
    """
    stats = measured_collective_bytes(compiled)
    return ValidationRecord(
        name=name,
        analytical_bytes=model.total("ici") / max(static_trip_count, 1),
        measured_bytes=stats.total_wire_bytes_per_chip,
    )
